//! Offline stand-in for `once_cell`: just `sync::Lazy`, implemented on
//! `std::sync::OnceLock`. The init closure is `Fn` (not `FnOnce`) which
//! is sufficient for the `fn() -> T` statics this workspace declares.

pub mod sync {
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// A value initialized on first access.
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F> Lazy<T, F> {
        pub const fn new(init: F) -> Lazy<T, F> {
            Lazy { cell: OnceLock::new(), init }
        }
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        pub fn force(this: &Lazy<T, F>) -> &T {
            this.cell.get_or_init(|| (this.init)())
        }
    }

    impl<T, F: Fn() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }

    impl<T: std::fmt::Debug, F> std::fmt::Debug for Lazy<T, F> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Lazy").field("cell", &self.cell.get()).finish()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::Lazy;

    static N: Lazy<u32> = Lazy::new(|| 41 + 1);

    #[test]
    fn static_lazy_initializes_once() {
        assert_eq!(*N, 42);
        assert_eq!(*N, 42);
    }

    #[test]
    fn local_lazy() {
        let l: Lazy<String, _> = Lazy::new(|| "hi".to_string());
        assert_eq!(l.len(), 2);
    }
}
