//! Offline stand-in for `rand_core`: the `RngCore` trait and `Error`
//! type, API-compatible with rand_core 0.6 for the subset this
//! workspace implements (`util::rng::Pcg64`).

use std::fmt;

/// Error type for fallible RNG operations.
#[derive(Debug)]
pub struct Error {
    msg: &'static str,
}

impl Error {
    pub fn new(msg: &'static str) -> Error {
        Error { msg }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "rng error: {}", self.msg)
    }
}

impl std::error::Error for Error {}

/// Core random-number-generator interface (rand_core 0.6 shape).
pub trait RngCore {
    fn next_u32(&mut self) -> u32;
    fn next_u64(&mut self) -> u64;
    fn fill_bytes(&mut self, dest: &mut [u8]);
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error>;
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Counter(u32);

    impl RngCore for Counter {
        fn next_u32(&mut self) -> u32 {
            self.0 += 1;
            self.0
        }
        fn next_u64(&mut self) -> u64 {
            (self.next_u32() as u64) << 32 | self.next_u32() as u64
        }
        fn fill_bytes(&mut self, dest: &mut [u8]) {
            for b in dest {
                *b = self.next_u32() as u8;
            }
        }
        fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error> {
            self.fill_bytes(dest);
            Ok(())
        }
    }

    #[test]
    fn trait_object_usable() {
        let mut c = Counter(0);
        let r: &mut dyn RngCore = &mut c;
        assert_eq!(r.next_u32(), 1);
        assert!(r.try_fill_bytes(&mut [0u8; 3]).is_ok());
    }
}
