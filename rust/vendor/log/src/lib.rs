//! Offline stand-in for the `log` crate: the API-compatible subset the
//! asrkf crate uses (levels, the `Log` trait, `set_logger`/`max_level`,
//! and the `error!`..`trace!` macros). The container this repo builds in
//! has no crates.io access, so the workspace vendors this shim instead
//! of depending on the real facade. Swap back to crates.io `log` by
//! editing the root manifest — no source changes needed.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Log verbosity level, most severe first.
#[repr(usize)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Level filter: `Off` plus every `Level`.
#[repr(usize)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.write_str(s)
    }
}

/// Metadata about a log record (level + target).
#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the pre-formatted message arguments.
#[derive(Debug, Clone)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// Backend trait implemented by logger installations.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger was already installed")
    }
}

impl std::error::Error for SetLoggerError {}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Install the global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing — not public API, do not call directly.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if level > max_level() {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(record.metadata()) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => ($crate::__private_log($crate::Level::Error, module_path!(), format_args!($($arg)+)))
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => ($crate::__private_log($crate::Level::Warn, module_path!(), format_args!($($arg)+)))
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => ($crate::__private_log($crate::Level::Info, module_path!(), format_args!($($arg)+)))
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => ($crate::__private_log($crate::Level::Debug, module_path!(), format_args!($($arg)+)))
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => ($crate::__private_log($crate::Level::Trace, module_path!(), format_args!($($arg)+)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Trace > LevelFilter::Off);
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }
}
