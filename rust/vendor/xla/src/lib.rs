//! Offline stub for the `xla` (PJRT bindings) crate.
//!
//! The build container for this repo has no crates.io / XLA toolchain
//! access, so the workspace vendors this shim with the exact API subset
//! `asrkf::runtime` uses:
//!
//! * `Literal` is a REAL host-side container (create / `to_vec` /
//!   `copy_raw_to` / `element_count` round-trip correctly), so every
//!   literal-handling unit test passes against the stub.
//! * The PJRT entry points (`PjRtClient::cpu`, compilation, execution)
//!   return a descriptive error: artifact-driven integration tests and
//!   benches require the real `xla` crate and are expected to skip/fail
//!   cleanly in this environment.
//!
//! Swapping in the real crate is a one-line change in the root
//! Cargo.toml; no `asrkf` source changes are required.

use std::fmt;

const STUB_MSG: &str = "PJRT backend unavailable: built against the vendored `xla` stub \
     (offline container). Install the real xla crate to run artifact-driven programs";

/// Error type mirroring `xla::Error`'s role (message-only here).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err<T>() -> Result<T> {
    Err(Error(STUB_MSG.to_string()))
}

/// Element dtypes the asrkf runtime uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn byte_width(&self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
        }
    }
}

/// Plain-old-data element types storable in a `Literal`.
pub trait NativeType: Copy + Sized {
    const ELEMENT_TYPE: ElementType;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
}

/// Host-side literal: dtype + shape + raw bytes. Fully functional.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let want = dims.iter().product::<usize>() * ty.byte_width();
        if data.len() != want {
            return Err(Error(format!(
                "literal data size mismatch: {} bytes for shape {dims:?} ({want} expected)",
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn shape(&self) -> &[usize] {
        &self.dims
    }

    fn check_ty<T: NativeType>(&self) -> Result<()> {
        if self.ty != T::ELEMENT_TYPE {
            return Err(Error(format!(
                "literal dtype mismatch: stored {:?}, requested {:?}",
                self.ty,
                T::ELEMENT_TYPE
            )));
        }
        Ok(())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        self.check_ty::<T>()?;
        let n = self.element_count();
        let mut out = Vec::with_capacity(n);
        // SAFETY: data length is n * size_of::<T>() by construction and
        // T is POD (f32/i32); unaligned reads are handled explicitly.
        unsafe {
            let src = self.data.as_ptr() as *const T;
            for i in 0..n {
                out.push(src.add(i).read_unaligned());
            }
        }
        Ok(out)
    }

    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        self.check_ty::<T>()?;
        let n = self.element_count();
        if dst.len() != n {
            return Err(Error(format!(
                "copy_raw_to: destination holds {} elements, literal has {n}",
                dst.len()
            )));
        }
        // SAFETY: same POD invariants as `to_vec`.
        unsafe {
            let src = self.data.as_ptr() as *const T;
            for (i, slot) in dst.iter_mut().enumerate() {
                *slot = src.add(i).read_unaligned();
            }
        }
        Ok(())
    }

    /// Stub literals are never tuples (tuples only come out of PJRT
    /// execution, which the stub cannot perform).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        stub_err()
    }
}

/// Parsed HLO module handle (stub: file must at least exist).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        if !std::path::Path::new(path).exists() {
            return Err(Error(format!("hlo file not found: {path}")));
        }
        Ok(HloModuleProto(()))
    }
}

#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer handle. Unconstructible in the stub (execution always
/// errors first), so `to_literal_sync` is unreachable in practice.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err()
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err()
    }
}

#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub_err()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_f32_roundtrip() {
        let data: Vec<f32> = (0..6).map(|i| i as f32 * 0.25).collect();
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 3], &bytes).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        let mut dst = vec![0.0f32; 6];
        lit.copy_raw_to(&mut dst).unwrap();
        assert_eq!(dst, data);
    }

    #[test]
    fn literal_rejects_size_mismatch() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[3], &[0u8; 8]).is_err()
        );
    }

    #[test]
    fn literal_rejects_dtype_mismatch() {
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &[1, 0, 0, 0])
                .unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1]);
    }

    #[test]
    fn pjrt_entry_points_error_cleanly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
