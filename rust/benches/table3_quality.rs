//! Paper Table 3: qualitative comparison on an explanation task.
//!
//! Paper reports (explanation prompt, identical sampling): baseline 269
//! active tokens vs ASR-KF-EGR 119 active (55.76% compression), both
//! "coherent, on-topic". We reproduce the compression band at the same
//! generation length and report a quantitative fluency proxy (mean
//! next-token entropy + repetition score) alongside both outputs.
//!
//! Output: table + artifacts/table3_quality.csv

use asrkf::baselines::make_policy;
use asrkf::config::EngineConfig;
use asrkf::engine::Generator;
use asrkf::offload::CodecLadder;
use asrkf::runtime::Runtime;
use asrkf::util::bench::{self, Table};

const PROMPT: &str = "the recovery ladder monitors the entropy trace. the scheduler freezes \
                      the key value pairs then the engine restores the frozen rows. ";

/// Fraction of 8-byte windows that repeat earlier in the text (lower =
/// less degenerate repetition).
fn repetition_score(text: &str) -> f64 {
    let b = text.as_bytes();
    if b.len() < 16 {
        return 0.0;
    }
    let mut seen = std::collections::HashSet::new();
    let mut repeats = 0usize;
    let mut total = 0usize;
    for w in b.windows(8) {
        total += 1;
        if !seen.insert(w.to_vec()) {
            repeats += 1;
        }
    }
    repeats as f64 / total as f64
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let new_tokens = bench::smoke_size(200, 24);
    let cfg = EngineConfig::default();
    // Same policy, full compression ladder on the cold/spill tiers:
    // the quality gate must hold when demoted rows ride sub-byte rungs.
    let mut ladder_cfg = cfg.clone();
    ladder_cfg.offload.codec_ladder = CodecLadder::parse("0:u8,64:u4,512:ebq")?;

    let mut table = Table::new(
        "Table 3: explanation task (T=0.7, top-k=40, top-p=0.9)",
        &["Metric", "Baseline", "ASR-KF-EGR", "ASR-KF-EGR (ladder)"],
    );
    let rt = match Runtime::load(&cfg.artifacts_dir) {
        Ok(rt) => rt,
        Err(e) if bench::smoke() => {
            bench::smoke_schema_only(
                &table,
                "artifacts/table3_quality.csv",
                &format!("runtime unavailable ({e})"),
            )?;
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };
    let gen = Generator::new(&rt, cfg.clone());
    let _ = gen.generate(PROMPT, make_policy("full", &cfg.freeze)?, 4)?; // compile warmup
    let mut outs = Vec::new();
    for policy in ["full", "asrkf"] {
        outs.push(gen.generate(PROMPT, make_policy(policy, &cfg.freeze)?, new_tokens)?);
    }
    let ladder_gen = Generator::new(&rt, ladder_cfg);
    outs.push(ladder_gen.generate(PROMPT, make_policy("asrkf", &cfg.freeze)?, new_tokens)?);
    let ent = |o: &asrkf::engine::GenOutcome| {
        o.trace.iter().map(|t| t.entropy as f64).sum::<f64>() / o.trace.len() as f64
    };
    let cold_bpr = |o: &asrkf::engine::GenOutcome| {
        let v = o.stats.offload.bytes_per_row_cold;
        if v == 0 {
            "-".into()
        } else {
            format!("{v}")
        }
    };
    table.row(&[
        "Active KV".into(),
        format!("{} tokens", outs[0].stats.final_active_kv),
        format!("{} tokens", outs[1].stats.final_active_kv),
        format!("{} tokens", outs[2].stats.final_active_kv),
    ]);
    table.row(&[
        "Compression".into(),
        format!("{:.2}%", outs[0].stats.compression * 100.0),
        format!("{:.2}%", outs[1].stats.compression * 100.0),
        format!("{:.2}%", outs[2].stats.compression * 100.0),
    ]);
    table.row(&[
        "Mean entropy (nats)".into(),
        format!("{:.3}", ent(&outs[0])),
        format!("{:.3}", ent(&outs[1])),
        format!("{:.3}", ent(&outs[2])),
    ]);
    table.row(&[
        "Repetition score".into(),
        format!("{:.3}", repetition_score(&outs[0].text)),
        format!("{:.3}", repetition_score(&outs[1].text)),
        format!("{:.3}", repetition_score(&outs[2].text)),
    ]);
    table.row(&[
        "Cold bytes/row".into(),
        cold_bpr(&outs[0]),
        cold_bpr(&outs[1]),
        cold_bpr(&outs[2]),
    ]);
    table.row(&[
        "Wall time".into(),
        format!("{:.2}s", outs[0].stats.wall.as_secs_f64()),
        format!("{:.2}s", outs[1].stats.wall.as_secs_f64()),
        format!("{:.2}s", outs[2].stats.wall.as_secs_f64()),
    ]);
    table.print();
    table.write_csv("artifacts/table3_quality.csv")?;

    println!("\n--- baseline ---\n{}", outs[0].text);
    println!("\n--- asr-kf-egr ---\n{}", outs[1].text);
    println!("\n--- asr-kf-egr (ladder 0:u8,64:u4,512:ebq) ---\n{}", outs[2].text);
    println!("\npaper reference: 269 vs 119 active tokens (55.76% compression), comparable fluency");
    Ok(())
}
