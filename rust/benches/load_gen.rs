//! Closed-loop QoS load generator: the coordinator's scheduling layer
//! (`ClassQueues` + `AdmissionController`, the exact types the serving
//! batcher runs) driven by a deterministic virtual-clock queueing
//! model — Poisson arrivals with periodic bursts, a mixed
//! interactive/standard/batch class population, and mixed context
//! lengths.
//!
//! Three rows, same arrival trace:
//!
//! * `qos`          — class-priority scheduling, default weights;
//! * `single-class` — every request enqueued as `standard` with equal
//!                    weights: the pre-QoS FIFO coordinator. Latency is
//!                    still attributed to each request's *original*
//!                    class, so the two rows compare per-class p99 at
//!                    equal total load;
//! * `tiny-envelope`— a hot budget a few rows wide, so admission
//!                    projection actually sheds and rejects.
//!
//! The headline check (asserted, not just reported): interactive p99
//! under burst is strictly better with QoS scheduling than in the
//! single-class baseline. No PJRT runtime or trained artifacts are
//! needed — the model is host-only and fully deterministic, so the
//! row values are stable for a given seed.
//!
//! Output: table + artifacts/load_gen.csv (schema:
//! `metrics::LOAD_GEN_CSV_COLUMNS`, checked in tests/telemetry.rs).

use asrkf::config::{OffloadConfig, QosClass, QosConfig};
use asrkf::coordinator::{Admission, AdmissionController, ClassQueues};
use asrkf::metrics::load_gen_csv_headers;
use asrkf::util::bench::{self, Table};
use asrkf::util::rng::Pcg64;
use asrkf::workload::trace::{bursty_trace, BurstProfile};

/// f32 elements per KV row in the simulated model (1 KiB rows).
const ROW_FLOATS: usize = 256;
/// Decode-step cost: fixed dispatch overhead plus per-occupied-slot
/// work, in virtual microseconds.
const STEP_BASE_US: u64 = 2000;
const STEP_PER_SLOT_US: u64 = 500;
/// Prefill charge per prompt token, added to the step that admits.
const PREFILL_PER_TOK_US: u64 = 20;
/// Serving slots (decode bucket batch size).
const SLOTS: usize = 4;

#[derive(Debug, Clone, Copy)]
struct SimReq {
    class: QosClass,
    arrival_us: u64,
    prompt_toks: usize,
    max_new: usize,
}

struct SlotState {
    req_idx: usize,
    class: QosClass,
    remaining: usize,
}

#[derive(Default)]
struct SimResult {
    arrivals: usize,
    completed: usize,
    rejects: usize,
    sheds: usize,
    tokens: u64,
    steps: u64,
    occupancy_sum: u64,
    end_us: u64,
    /// (e2e, queue wait) per completed request, by original class.
    e2e_us: [Vec<u64>; QosClass::COUNT],
    wait_us: [Vec<u64>; QosClass::COUNT],
}

impl SimResult {
    fn goodput_tok_s(&self) -> f64 {
        if self.end_us == 0 {
            return 0.0;
        }
        self.tokens as f64 / (self.end_us as f64 / 1e6)
    }

    fn mean_occupancy(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.occupancy_sum as f64 / self.steps as f64
    }
}

/// Exact p99 over a sample list (ms), "-"-free: 0.0 when empty.
fn p99_ms(samples: &[u64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v = samples.to_vec();
    v.sort_unstable();
    let idx = ((0.99 * v.len() as f64).ceil() as usize).clamp(1, v.len()) - 1;
    v[idx] as f64 / 1000.0
}

/// Build the shared request population: bursty arrivals, class mix
/// ~30/50/20, context length and decode budget scaled by class
/// (interactive = short prompts and short answers, batch = long).
fn build_requests(seed: u64, n: usize) -> Vec<SimReq> {
    let profile = BurstProfile { every_s: 8.0, len_s: 2.0, factor: 6.0 };
    let trace = bursty_trace(seed, n, 12.0, profile, (64, 512), 0);
    let mut class_rng = Pcg64::with_stream(seed, 1);
    trace
        .iter()
        .map(|t| {
            let class = match class_rng.f64() {
                x if x < 0.3 => QosClass::Interactive,
                x if x < 0.8 => QosClass::Standard,
                _ => QosClass::Batch,
            };
            let (prompt_div, max_new) = match class {
                QosClass::Interactive => (8, 16),
                QosClass::Standard => (6, 32),
                QosClass::Batch => (4, 64),
            };
            SimReq {
                class,
                arrival_us: t.arrival_ms * 1000,
                prompt_toks: (t.prompt.len() / prompt_div).max(1),
                max_new,
            }
        })
        .collect()
}

/// Run the virtual-clock serving loop over `reqs`. `honor_class`
/// false enqueues everything as `standard` (the single-class
/// baseline); latency is attributed to the original class either way.
fn simulate(
    reqs: &[SimReq],
    qos: QosConfig,
    offload: &OffloadConfig,
    honor_class: bool,
) -> SimResult {
    let ctl = AdmissionController::new(qos.clone(), offload, ROW_FLOATS);
    let mut queues: ClassQueues<usize> = ClassQueues::new(qos.queue_depth);
    let mut slots: Vec<Option<SlotState>> = (0..SLOTS).map(|_| None).collect();
    let mut res = SimResult { arrivals: reqs.len(), ..SimResult::default() };
    let mut now = 0u64;
    let mut next = 0usize;
    loop {
        while next < reqs.len() && reqs[next].arrival_us <= now {
            let class = if honor_class { reqs[next].class } else { QosClass::Standard };
            if queues.push(class, next).is_err() {
                res.rejects += 1;
            }
            next += 1;
        }
        let mut prefill_charge = 0u64;
        while slots.iter().filter(|s| s.is_some()).count() < SLOTS {
            let Some((requested, i)) = queues.pop() else { break };
            let occupied: Vec<QosClass> =
                slots.iter().filter_map(|s| s.as_ref().map(|s| s.class)).collect();
            let effective = match ctl.admit(&occupied, requested) {
                Admission::Admit => requested,
                Admission::Shed(lower) => {
                    res.sheds += 1;
                    lower
                }
                Admission::Reject(_) => {
                    res.rejects += 1;
                    continue;
                }
            };
            let free = slots.iter().position(|s| s.is_none()).unwrap();
            slots[free] =
                Some(SlotState { req_idx: i, class: effective, remaining: reqs[i].max_new });
            res.wait_us[reqs[i].class.index()].push(now - reqs[i].arrival_us);
            prefill_charge += reqs[i].prompt_toks as u64 * PREFILL_PER_TOK_US;
        }
        let occupied = slots.iter().filter(|s| s.is_some()).count();
        if occupied == 0 {
            // the admit loop drained the queues, so idle means waiting
            // on the next arrival (or the end of the trace)
            if next >= reqs.len() {
                break;
            }
            now = now.max(reqs[next].arrival_us);
            continue;
        }
        now += STEP_BASE_US + STEP_PER_SLOT_US * occupied as u64 + prefill_charge;
        res.steps += 1;
        res.occupancy_sum += occupied as u64;
        res.tokens += occupied as u64;
        for slot in slots.iter_mut() {
            if let Some(s) = slot {
                s.remaining -= 1;
                if s.remaining == 0 {
                    let req = &reqs[s.req_idx];
                    res.e2e_us[req.class.index()].push(now - req.arrival_us);
                    res.completed += 1;
                    *slot = None;
                }
            }
        }
    }
    res.end_us = now;
    res
}

fn result_row(mode: &str, r: &SimResult) -> Vec<String> {
    let rate = |c: usize| {
        if r.arrivals == 0 { 0.0 } else { c as f64 / r.arrivals as f64 }
    };
    vec![
        mode.to_string(),
        r.arrivals.to_string(),
        r.completed.to_string(),
        format!("{:.1}", r.goodput_tok_s()),
        format!("{:.4}", rate(r.rejects)),
        format!("{:.4}", rate(r.sheds)),
        format!("{:.1}", p99_ms(&r.e2e_us[QosClass::Interactive.index()])),
        format!("{:.1}", p99_ms(&r.e2e_us[QosClass::Standard.index()])),
        format!("{:.1}", p99_ms(&r.e2e_us[QosClass::Batch.index()])),
        format!("{:.1}", p99_ms(&r.wait_us[QosClass::Interactive.index()])),
        format!("{:.1}", p99_ms(&r.wait_us[QosClass::Batch.index()])),
        format!("{:.2}", r.mean_occupancy()),
    ]
}

fn main() {
    let n = bench::smoke_size(2000, 300);
    let reqs = build_requests(42, n);
    let headers = load_gen_csv_headers();
    let mut table = Table::new("QoS load generator (virtual clock)", &headers);

    let _t = bench::section("load_gen_sim");
    // plenty of queue depth: the qos-vs-baseline comparison should
    // measure scheduling, not tail drops
    let roomy = QosConfig { queue_depth: 1 << 16, ..QosConfig::default() };
    let offload = OffloadConfig::default();

    let qos = simulate(&reqs, roomy.clone(), &offload, true);
    table.row(&result_row("qos", &qos));

    let flat = QosConfig { weights: [1, 1, 1], queue_depth: 1 << 16, ..QosConfig::default() };
    let baseline = simulate(&reqs, flat, &offload, false);
    table.row(&result_row("single-class", &baseline));

    // a hot budget four rows wide: the projection has to shed/reject
    let tiny_offload = OffloadConfig {
        hot_budget_bytes: 4 * ROW_FLOATS * std::mem::size_of::<f32>(),
        shards: 1,
        quantize_cold: true,
        ..OffloadConfig::default()
    };
    let tiny = simulate(&reqs, roomy, &tiny_offload, true);
    table.row(&result_row("tiny-envelope", &tiny));

    table.print();
    table.write_csv("artifacts/load_gen.csv").expect("write artifacts/load_gen.csv");
    println!("wrote artifacts/load_gen.csv");

    // headline guarantees, asserted so CI catches a scheduling
    // regression rather than shipping a quietly worse CSV
    let i = QosClass::Interactive.index();
    let qos_p99 = p99_ms(&qos.e2e_us[i]);
    let base_p99 = p99_ms(&baseline.e2e_us[i]);
    assert!(
        !qos.e2e_us[i].is_empty() && !baseline.e2e_us[i].is_empty(),
        "no interactive completions to compare"
    );
    assert!(
        qos_p99 < base_p99,
        "interactive p99 must beat the single-class baseline under burst \
         (qos {qos_p99:.1} ms vs baseline {base_p99:.1} ms)"
    );
    assert!(
        tiny.rejects + tiny.sheds > 0,
        "tiny-envelope mode must exercise the admission projection"
    );
    println!(
        "interactive p99 under burst: qos {qos_p99:.1} ms vs single-class {base_p99:.1} ms"
    );
}
