//! Entropy-guided recovery ablation (paper §3.6 — future work there,
//! implemented here): generation with aggressive freezing, recovery
//! ladder off vs on. Reports entropy statistics, intervention counts
//! per ladder level (SR/WR/FR/RR), and quality proxies.
//!
//! Output: table + artifacts/recovery_ablation.csv

use asrkf::baselines::make_policy;
use asrkf::config::EngineConfig;
use asrkf::engine::Generator;
use asrkf::runtime::Runtime;
use asrkf::util::bench::{self, Table};

const PROMPT: &str = "the system routes every request. ";

fn repetition_score(text: &str) -> f64 {
    let b = text.as_bytes();
    if b.len() < 16 {
        return 0.0;
    }
    let mut seen = std::collections::HashSet::new();
    let (mut repeats, mut total) = (0usize, 0usize);
    for w in b.windows(8) {
        total += 1;
        if !seen.insert(w.to_vec()) {
            repeats += 1;
        }
    }
    repeats as f64 / total as f64
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let new_tokens = bench::smoke_size(380, 24);
    let mut table = Table::new(
        "Recovery ladder ablation (aggressive freeze: k=1)",
        &["Variant", "Compression", "Mean H", "p95 H", "Repetition", "SR/WR/FR/RR", "Time"],
    );
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) if bench::smoke() => {
            bench::smoke_schema_only(
                &table,
                "artifacts/recovery_ablation.csv",
                &format!("runtime unavailable ({e})"),
            )?;
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };

    {
        // compile warmup so Time rows are compile-free
        let mut cfg = EngineConfig::default();
        cfg.freeze.softness_k = 1.0;
        let gen = Generator::new(&rt, cfg.clone());
        let _ = gen.generate(PROMPT, make_policy("asrkf", &cfg.freeze)?, 4)?;
    }
    for recovery in [false, true] {
        let mut cfg = EngineConfig::default();
        cfg.freeze.softness_k = 1.0;
        cfg.recovery.enabled = recovery;
        let gen = Generator::new(&rt, cfg.clone());
        let out = gen.generate(PROMPT, make_policy("asrkf", &cfg.freeze)?, new_tokens)?;

        let mut hs: Vec<f64> = out.trace.iter().map(|t| t.entropy as f64).collect();
        hs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean_h = hs.iter().sum::<f64>() / hs.len() as f64;
        let p95 = hs[(hs.len() as f64 * 0.95) as usize];
        let by = out.stats.recovery_by_level;

        table.row(&[
            if recovery { "recovery ON".into() } else { "recovery OFF".to_string() },
            format!("{:.1}%", out.stats.compression * 100.0),
            format!("{mean_h:.3}"),
            format!("{p95:.3}"),
            format!("{:.3}", repetition_score(&out.text)),
            format!("{}/{}/{}/{}", by[0], by[1], by[2], by[3]),
            format!("{:.2}s", out.stats.wall.as_secs_f64()),
        ]);
    }
    table.print();
    table.write_csv("artifacts/recovery_ablation.csv")?;
    println!("\npaper §3.6 proposes SR->WR->FR->RR as an escalation ladder (future work there).");
    Ok(())
}
