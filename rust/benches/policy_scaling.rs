//! Policy control-plane scaling: per-step `plan` + `observe` cost of
//! the indexed ASR-KF-EGR policy vs the retained brute-force full-scan
//! implementation, as context length grows 4k -> 1M positions.
//!
//! The scenario is the long-context steady state the ROADMAP targets:
//! almost the whole context is frozen (softness k is tiny, so one
//! detection earns a long Eq.3 duration and a setup plan with an
//! unbounded transfer budget freezes every stale position at once),
//! the sliding window advances one token per step, and each step does
//! bounded work — one fresh detection + freeze, empty expiry pops,
//! prefetch range probes. The indexed policy's cost tracks that work
//! (`flat-to-logarithmic` in context length); the full-scan column
//! pays `tick`/prefetch/detection sweeps over every position and grows
//! linearly. Correctness equivalence of the two implementations is
//! property-tested in `tests/prop_policy.rs`; this bench measures the
//! cost gap the index buys.
//!
//! `BENCH_SMOKE=1` shrinks the sweep to tiny contexts/steps. The bench
//! is host-only — it needs no trained artifacts, so CI smoke produces
//! a real (tiny) CSV, not a schema-only one.
//!
//! Output: table + artifacts/policy_scaling.csv

use std::time::Instant;

use asrkf::config::FreezeConfig;
use asrkf::kv::oracle::ScanAsrKfPolicy;
use asrkf::kv::policy::{AsrKfPolicy, KvPolicy, Plan};
use asrkf::util::bench::{self, Stats, Table};

fn cfg() -> FreezeConfig {
    FreezeConfig {
        window_k: 64,
        n_sink: 4,
        // absolute tau: scores are synthetic (stale rows 0.01, fresh
        // rows 1.0), so the detection set is exact by construction
        tau: 0.5,
        relative_tau: false,
        // tiny softness: c=1 -> d = floor(1/0.002) = 500 steps, so the
        // frozen archive outlives the measurement window
        softness_k: 0.002,
        history_w: 1 << 20,
        r_budget: 64,
    }
}

/// Drive one policy to the mostly-frozen steady state at context
/// length `ctx`, then time `measure` decode steps of plan+observe.
fn run_policy(policy: &mut dyn KvPolicy, ctx: usize, warm: usize, measure: usize) -> Stats {
    let c = cfg();
    let total = ctx + warm + measure + 1;
    // stale everywhere: every position outside the sliding window is
    // detected once and then frozen for ~500 steps
    let scores = vec![0.01f32; total];

    policy.on_prefill(&scores[..ctx], ctx);
    // setup plan with an unbounded budget: freeze the entire backlog
    let mut plan = Plan::default();
    policy.plan_into(1, ctx, ctx, &mut plan);

    let mut len = ctx;
    let mut step = 1u64;
    for _ in 0..warm {
        step += 1;
        len += 1;
        policy.observe(step, &scores[..len], len);
        policy.plan_into(step, len, c.r_budget, &mut plan);
    }

    let mut samples = Vec::with_capacity(measure);
    for _ in 0..measure {
        step += 1;
        len += 1;
        let t = Instant::now();
        policy.observe(step, &scores[..len], len);
        policy.plan_into(step, len, c.r_budget, &mut plan);
        samples.push(t.elapsed());
    }
    Stats::from_samples(samples)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let contexts: &[usize] = if bench::smoke() {
        &[1 << 10, 1 << 12]
    } else {
        &[1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20]
    };
    let warm = bench::smoke_size(16, 4);
    let measure = bench::smoke_size(64, 8);

    let mut table = Table::new(
        "Policy scaling: per-step plan+observe, indexed vs full scan",
        &[
            "context",
            "steps",
            "indexed mean (us)",
            "indexed p99 (us)",
            "scan mean (us)",
            "scan p99 (us)",
            "speedup (mean)",
        ],
    );

    for &ctx in contexts {
        let _section = bench::section(&format!("policy scaling ctx={ctx}"));
        let mut indexed = AsrKfPolicy::new(cfg());
        let si = run_policy(&mut indexed, ctx, warm, measure);
        let mut scan = ScanAsrKfPolicy::new(cfg());
        let ss = run_policy(&mut scan, ctx, warm, measure);
        println!(
            "ctx {ctx:>8}: indexed {:>10.3?}  scan {:>10.3?}  (frozen {} / {})",
            si.mean,
            ss.mean,
            indexed.frozen_count(),
            ctx
        );
        let speedup = if si.mean.as_nanos() == 0 {
            "-".to_string()
        } else {
            format!("{:.1}x", ss.mean.as_secs_f64() / si.mean.as_secs_f64())
        };
        table.row(&[
            ctx.to_string(),
            measure.to_string(),
            si.mean.as_micros().to_string(),
            si.p99.as_micros().to_string(),
            ss.mean.as_micros().to_string(),
            ss.p99.as_micros().to_string(),
            speedup,
        ]);
    }

    table.print();
    table.write_csv("artifacts/policy_scaling.csv")?;
    bench::section_summary().print();
    println!(
        "\nscaling claim: the indexed column stays flat-to-logarithmic in context length \
         (per-step cost tracks window/budget/expiry work); the full-scan column grows \
         linearly with context"
    );
    Ok(())
}
