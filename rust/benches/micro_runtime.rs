//! Micro-benchmarks for the §Perf iteration log: per-component costs of
//! the decode hot path — literal construction (host->device analog),
//! PJRT execute, output download — plus the host-only components that
//! run without trained artifacts: the codec-ladder encode/decode
//! kernels per rung (the restore-path cost the prefetch stages hide)
//! and the rust-side policy bookkeeping (indexed vs retained full-scan
//! implementation).
//!
//! Host-only rows are recorded before the runtime loads, so the
//! BENCH_SMOKE schema CSV carries real numbers for them even on
//! runners with no artifact set. The `encode MB/s` / `decode MB/s`
//! columns report f32-side throughput of each codec rung ("-" for
//! non-codec rows); CI smoke greps for them.
//!
//! Output: timing lines + artifacts/micro_runtime.csv

use asrkf::config::FreezeConfig;
use asrkf::kv::{AsrKfPolicy, KvPolicy, ScanAsrKfPolicy};
use asrkf::offload::{
    decode_ebq_into, dequantize_into, encode_ebq, pack_u4, quantize, unpack_u4_into,
};
use asrkf::runtime::{literal, DecodeInputs, Runtime};
use asrkf::util::bench::{self, Bencher, Stats, Table};
use asrkf::util::rng::Pcg64;

/// f32-side throughput of a timed kernel pass over `floats` floats.
fn mb_per_s(floats: usize, st: &Stats) -> String {
    let secs = st.mean.as_secs_f64();
    if secs <= 0.0 {
        return "-".into();
    }
    format!("{:.0}", (floats * 4) as f64 / secs / 1e6)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let mut table = Table::new(
        "Micro: decode hot-path components",
        &["component", "mean_us", "p50_us", "encode MB/s", "decode MB/s"],
    );
    let mut rng = Pcg64::new(7);
    let b = Bencher::new(bench::smoke_size(3, 1), bench::smoke_size(15, 3));

    // --- host-only components (no artifacts needed) ---------------------

    // codec ladder rungs over one 4 KB KV row (1024 floats): each row
    // times the rung's encode kernel (mean/p50 columns) and reports
    // both directions as throughput
    let row: Vec<f32> = (0..1024).map(|_| rng.f32() * 4.0 - 2.0).collect();
    let mut dst = vec![0.0f32; row.len()];

    // u8: per-row affine quantization
    let enc = b.run("codec u8: quantize 4KB row", || {
        std::hint::black_box(quantize(std::hint::black_box(&row)));
    });
    let qr = quantize(&row);
    let dec = b.run("codec u8: dequantize 4KB row", || {
        dequantize_into(std::hint::black_box(&qr), std::hint::black_box(&mut dst));
    });
    table.row(&[
        "codec_u8_row_4k".into(),
        enc.mean.as_micros().to_string(),
        enc.p50.as_micros().to_string(),
        mb_per_s(row.len(), &enc),
        mb_per_s(row.len(), &dec),
    ]);

    // u4: per-block affine, packed nibbles
    let enc = b.run("codec u4: pack 4KB row", || {
        std::hint::black_box(pack_u4(std::hint::black_box(&row)));
    });
    let pr = pack_u4(&row);
    let dec = b.run("codec u4: unpack 4KB row", || {
        unpack_u4_into(std::hint::black_box(&pr), std::hint::black_box(&mut dst));
    });
    table.row(&[
        "codec_u4_row_4k".into(),
        enc.mean.as_micros().to_string(),
        enc.p50.as_micros().to_string(),
        mb_per_s(row.len(), &enc),
        mb_per_s(row.len(), &dec),
    ]);

    // ebq: error-bounded variable-rate blocks at the default target
    let enc = b.run("codec ebq: encode 4KB row", || {
        std::hint::black_box(encode_ebq(std::hint::black_box(&row), 0.02));
    });
    let br = encode_ebq(&row, 0.02);
    let dec = b.run("codec ebq: decode 4KB row", || {
        decode_ebq_into(std::hint::black_box(&br), std::hint::black_box(&mut dst));
    });
    table.row(&[
        "codec_ebq_row_4k".into(),
        enc.mean.as_micros().to_string(),
        enc.p50.as_micros().to_string(),
        mb_per_s(row.len(), &enc),
        mb_per_s(row.len(), &dec),
    ]);

    // raw rung: a pair of memcpys — the bandwidth ceiling the encoded
    // rungs trade against
    let enc = b.run("codec raw: copy 4KB row", || {
        std::hint::black_box(std::hint::black_box(&row).clone());
    });
    let dec = b.run("codec raw: copy-back 4KB row", || {
        dst.copy_from_slice(std::hint::black_box(&row));
        std::hint::black_box(&mut dst);
    });
    table.row(&[
        "codec_raw_row_4k".into(),
        enc.mean.as_micros().to_string(),
        enc.p50.as_micros().to_string(),
        mb_per_s(row.len(), &enc),
        mb_per_s(row.len(), &dec),
    ]);

    // policy bookkeeping alone (no graph): indexed vs full-scan
    let cfg = FreezeConfig::default();
    let scores: Vec<f32> = (0..1000).map(|_| rng.f32()).collect();
    let st = b.run("policy: observe+plan x50 (indexed)", || {
        let mut p = AsrKfPolicy::new(cfg.clone());
        p.on_prefill(&scores[..500], 500);
        for step in 1..50 {
            p.observe(step, &scores, 1000);
            let _ = p.plan(step, 1000, 64);
        }
    });
    table.row(&[
        "policy_50_steps".into(),
        st.mean.as_micros().to_string(),
        st.p50.as_micros().to_string(),
        "-".into(),
        "-".into(),
    ]);

    let st = b.run("policy: observe+plan x50 (full scan)", || {
        let mut p = ScanAsrKfPolicy::new(cfg.clone());
        p.on_prefill(&scores[..500], 500);
        for step in 1..50 {
            p.observe(step, &scores, 1000);
            let _ = p.plan(step, 1000, 64);
        }
    });
    table.row(&[
        "policy_50_steps_scan".into(),
        st.mean.as_micros().to_string(),
        st.p50.as_micros().to_string(),
        "-".into(),
        "-".into(),
    ]);

    // --- runtime-backed components --------------------------------------

    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) if bench::smoke() => {
            bench::smoke_schema_only(
                &table,
                "artifacts/micro_runtime.csv",
                &format!("runtime unavailable ({e}); host-only rows recorded"),
            )?;
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };
    let model = rt.manifest.model.clone();
    let decode = rt.decode_for(1, 1024)?;
    let s = decode.kv_len;

    let kv: Vec<f32> = (0..decode.kv_floats()).map(|_| rng.f32() - 0.5).collect();
    let mut mask = vec![0.0f32; s];
    for m in mask.iter_mut().take(500) {
        *m = 1.0;
    }

    let st = b.run("literal: kv upload (16 MiB)", || {
        let _ = literal::lit_f32(&[model.n_layers, 2, 1, s, model.n_heads, model.d_head], &kv)
            .unwrap();
    });
    table.row(&[
        "kv_literal_build".into(),
        st.mean.as_micros().to_string(),
        st.p50.as_micros().to_string(),
        "-".into(),
        "-".into(),
    ]);

    let st = b.run("decode step (end to end)", || {
        let _ = decode
            .run(&DecodeInputs { tokens: &[65], kv: &kv, mask: &mask, pos: &[500] })
            .unwrap();
    });
    table.row(&[
        "decode_step".into(),
        st.mean.as_micros().to_string(),
        st.p50.as_micros().to_string(),
        "-".into(),
        "-".into(),
    ]);

    table.print();
    table.write_csv("artifacts/micro_runtime.csv")?;
    Ok(())
}
