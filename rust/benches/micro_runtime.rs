//! Micro-benchmarks for the §Perf iteration log: per-component costs of
//! the decode hot path — literal construction (host->device analog),
//! PJRT execute, output download, and the rust-side policy bookkeeping.
//!
//! Output: timing lines + artifacts/micro_runtime.csv

use asrkf::config::FreezeConfig;
use asrkf::kv::{AsrKfPolicy, KvPolicy};
use asrkf::runtime::{literal, DecodeInputs, Runtime};
use asrkf::util::bench::{self, Bencher, Table};
use asrkf::util::rng::Pcg64;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let mut table = Table::new("Micro: decode hot-path components", &["component", "mean_us", "p50_us"]);
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) if bench::smoke() => {
            bench::smoke_schema_only(
                &table,
                "artifacts/micro_runtime.csv",
                &format!("runtime unavailable ({e})"),
            )?;
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };
    let model = rt.manifest.model.clone();
    let decode = rt.decode_for(1, 1024)?;
    let s = decode.kv_len;

    let mut rng = Pcg64::new(7);
    let kv: Vec<f32> = (0..decode.kv_floats()).map(|_| rng.f32() - 0.5).collect();
    let mut mask = vec![0.0f32; s];
    for m in mask.iter_mut().take(500) {
        *m = 1.0;
    }
    let b = Bencher::new(bench::smoke_size(3, 1), bench::smoke_size(15, 3));

    let st = b.run("literal: kv upload (16 MiB)", || {
        let _ = literal::lit_f32(&[model.n_layers, 2, 1, s, model.n_heads, model.d_head], &kv)
            .unwrap();
    });
    table.row(&["kv_literal_build".into(), st.mean.as_micros().to_string(), st.p50.as_micros().to_string()]);

    let st = b.run("decode step (end to end)", || {
        let _ = decode
            .run(&DecodeInputs { tokens: &[65], kv: &kv, mask: &mask, pos: &[500] })
            .unwrap();
    });
    table.row(&["decode_step".into(), st.mean.as_micros().to_string(), st.p50.as_micros().to_string()]);

    // policy bookkeeping alone (no graph)
    let cfg = FreezeConfig::default();
    let scores: Vec<f32> = (0..1000).map(|_| rng.f32()).collect();
    let st = b.run("policy: observe+plan (1000 tokens)", || {
        let mut p = AsrKfPolicy::new(cfg.clone());
        p.on_prefill(&scores[..500], 500);
        for step in 1..50 {
            p.observe(step, &scores, 1000);
            let _ = p.plan(step, 1000, 64);
        }
    });
    table.row(&["policy_50_steps".into(), st.mean.as_micros().to_string(), st.p50.as_micros().to_string()]);

    table.print();
    table.write_csv("artifacts/micro_runtime.csv")?;
    Ok(())
}
