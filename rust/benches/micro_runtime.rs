//! Micro-benchmarks for the §Perf iteration log: per-component costs of
//! the decode hot path — literal construction (host->device analog),
//! PJRT execute, output download — plus the host-only components that
//! run without trained artifacts: cold-tier quantize/dequantize (the
//! restore-path cost the prefetch stages hide) and the rust-side
//! policy bookkeeping (indexed vs retained full-scan implementation).
//!
//! Host-only rows are recorded before the runtime loads, so the
//! BENCH_SMOKE schema CSV carries real numbers for them even on
//! runners with no artifact set.
//!
//! Output: timing lines + artifacts/micro_runtime.csv

use asrkf::config::FreezeConfig;
use asrkf::kv::{AsrKfPolicy, KvPolicy, ScanAsrKfPolicy};
use asrkf::offload::{dequantize_into, quantize};
use asrkf::runtime::{literal, DecodeInputs, Runtime};
use asrkf::util::bench::{self, Bencher, Table};
use asrkf::util::rng::Pcg64;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let mut table =
        Table::new("Micro: decode hot-path components", &["component", "mean_us", "p50_us"]);
    let mut rng = Pcg64::new(7);
    let b = Bencher::new(bench::smoke_size(3, 1), bench::smoke_size(15, 3));

    // --- host-only components (no artifacts needed) ---------------------

    // cold-tier row compression: 1024 floats = one 4 KB KV row
    let row: Vec<f32> = (0..1024).map(|_| rng.f32() * 4.0 - 2.0).collect();
    let st = b.run("quant: quantize 4KB row", || {
        std::hint::black_box(quantize(std::hint::black_box(&row)));
    });
    table.row(&[
        "quantize_row_4k".into(),
        st.mean.as_micros().to_string(),
        st.p50.as_micros().to_string(),
    ]);

    let qr = quantize(&row);
    let mut dst = vec![0.0f32; row.len()];
    let st = b.run("quant: dequantize_into 4KB row", || {
        dequantize_into(std::hint::black_box(&qr), std::hint::black_box(&mut dst));
    });
    table.row(&[
        "dequantize_row_4k".into(),
        st.mean.as_micros().to_string(),
        st.p50.as_micros().to_string(),
    ]);

    // policy bookkeeping alone (no graph): indexed vs full-scan
    let cfg = FreezeConfig::default();
    let scores: Vec<f32> = (0..1000).map(|_| rng.f32()).collect();
    let st = b.run("policy: observe+plan x50 (indexed)", || {
        let mut p = AsrKfPolicy::new(cfg.clone());
        p.on_prefill(&scores[..500], 500);
        for step in 1..50 {
            p.observe(step, &scores, 1000);
            let _ = p.plan(step, 1000, 64);
        }
    });
    table.row(&[
        "policy_50_steps".into(),
        st.mean.as_micros().to_string(),
        st.p50.as_micros().to_string(),
    ]);

    let st = b.run("policy: observe+plan x50 (full scan)", || {
        let mut p = ScanAsrKfPolicy::new(cfg.clone());
        p.on_prefill(&scores[..500], 500);
        for step in 1..50 {
            p.observe(step, &scores, 1000);
            let _ = p.plan(step, 1000, 64);
        }
    });
    table.row(&[
        "policy_50_steps_scan".into(),
        st.mean.as_micros().to_string(),
        st.p50.as_micros().to_string(),
    ]);

    // --- runtime-backed components --------------------------------------

    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) if bench::smoke() => {
            bench::smoke_schema_only(
                &table,
                "artifacts/micro_runtime.csv",
                &format!("runtime unavailable ({e}); host-only rows recorded"),
            )?;
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };
    let model = rt.manifest.model.clone();
    let decode = rt.decode_for(1, 1024)?;
    let s = decode.kv_len;

    let kv: Vec<f32> = (0..decode.kv_floats()).map(|_| rng.f32() - 0.5).collect();
    let mut mask = vec![0.0f32; s];
    for m in mask.iter_mut().take(500) {
        *m = 1.0;
    }

    let st = b.run("literal: kv upload (16 MiB)", || {
        let _ = literal::lit_f32(&[model.n_layers, 2, 1, s, model.n_heads, model.d_head], &kv)
            .unwrap();
    });
    table.row(&[
        "kv_literal_build".into(),
        st.mean.as_micros().to_string(),
        st.p50.as_micros().to_string(),
    ]);

    let st = b.run("decode step (end to end)", || {
        let _ = decode
            .run(&DecodeInputs { tokens: &[65], kv: &kv, mask: &mask, pos: &[500] })
            .unwrap();
    });
    table.row(&[
        "decode_step".into(),
        st.mean.as_micros().to_string(),
        st.p50.as_micros().to_string(),
    ]);

    table.print();
    table.write_csv("artifacts/micro_runtime.csv")?;
    Ok(())
}
