//! Paper §5.2: compression vs context length.
//!
//! The paper measures 67% at 500 tokens and *hypothesizes* 80%+ for 8K
//! contexts ("more tokens become stale as context grows"). This bench
//! measures the actual curve on our stack across generation lengths,
//! and sweeps the offload shard count on the longest configuration to
//! show sharding is compression-neutral (it only changes where frozen
//! rows live, never whether they are frozen).
//!
//! `BENCH_SMOKE=1` truncates the sweep to the two shortest rows.
//!
//! Output: table + artifacts/context_sweep.csv

use asrkf::baselines::make_policy;
use asrkf::config::EngineConfig;
use asrkf::engine::Generator;
use asrkf::runtime::Runtime;
use asrkf::util::bench::{self, Table};

const PROMPT: &str = "the system routes every request. ";

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let mut cfg = EngineConfig::default();
    cfg.freeze.softness_k = 1.0;

    let mut table = Table::new(
        "§5.2: compression vs context length (ASR-KF-EGR, k=1)",
        &[
            "New Tokens",
            "R budget",
            "Shards",
            "Total",
            "Active KV",
            "Mean Active",
            "Compression",
            "Frozen KB (raw)",
            "Cold KB",
            "Staged hit",
            "Restore par",
            "Time",
        ],
    );

    let rt = match Runtime::load(&cfg.artifacts_dir) {
        Ok(rt) => rt,
        Err(e) if bench::smoke() => {
            bench::smoke_schema_only(
                &table,
                "artifacts/context_sweep.csv",
                &format!("runtime unavailable ({e})"),
            )?;
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };

    // R is the per-step freeze/restore transfer budget (our PCIe-realism
    // extension). The paper's unbounded-python prototype corresponds to
    // large R; under small R the frozen population is capped at ~R*d,
    // so compression SATURATES with context instead of improving. The
    // shard column sweeps the longest configuration: N ∈ {1, 2, 4}.
    let full_sweep: Vec<(usize, usize, usize)> = vec![
        (120, 64, 1),
        (250, 64, 1),
        (480, 64, 1),
        (960, 64, 1),
        (960, 256, 1),
        (1900, 256, 1),
        (1900, 256, 2),
        (1900, 256, 4),
    ];
    let sweep: Vec<(usize, usize, usize)> = if bench::smoke() {
        full_sweep.into_iter().take(2).collect()
    } else {
        full_sweep
    };

    for &(n, r, shards) in &sweep {
        let mut c = cfg.clone();
        c.freeze.r_budget = r;
        c.offload.shards = shards;
        let gen = Generator::new(&rt, c.clone());
        let out = gen.generate(PROMPT, make_policy("asrkf", &c.freeze)?, n)?;
        let s = &out.stats;
        let o = &s.offload.occupancy;
        let hit = s.offload.staged_hits + s.offload.staged_misses;
        table.row(&[
            n.to_string(),
            r.to_string(),
            shards.to_string(),
            s.total_tokens.to_string(),
            s.final_active_kv.to_string(),
            format!("{:.0}", s.mean_active_kv),
            format!("{:.2}%", s.compression * 100.0),
            // what the resident frozen rows would cost uncompressed,
            // vs what the quantized cold tier actually holds
            format!("{:.1}", o.uncompressed_bytes as f64 / 1024.0),
            format!("{:.1}", o.cold_bytes as f64 / 1024.0),
            if hit == 0 {
                "-".to_string()
            } else {
                format!("{:.0}%", 100.0 * s.offload.staged_hits as f64 / hit as f64)
            },
            s.offload.restore_parallelism_max.to_string(),
            format!("{:.2}s", s.wall.as_secs_f64()),
        ]);
    }
    table.print();
    table.write_csv("artifacts/context_sweep.csv")?;
    println!("\npaper claim: compression improves with context (67% @ 500 -> 80%+ hypothesized @ 8K)");
    println!("tiering claim: Cold KB < Frozen KB (raw) whenever rows settle in the cold tier");
    println!("sharding claim: the Shards column leaves Compression unchanged at fixed (tokens, R)");
    Ok(())
}
