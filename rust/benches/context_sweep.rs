//! Paper §5.2: compression vs context length.
//!
//! The paper measures 67% at 500 tokens and *hypothesizes* 80%+ for 8K
//! contexts ("more tokens become stale as context grows"). This bench
//! measures the actual curve on our stack across generation lengths.
//!
//! Output: table + artifacts/context_sweep.csv

use asrkf::baselines::make_policy;
use asrkf::config::EngineConfig;
use asrkf::engine::Generator;
use asrkf::runtime::Runtime;
use asrkf::util::bench::Table;

const PROMPT: &str = "the system routes every request. ";

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let mut cfg = EngineConfig::default();
    cfg.freeze.softness_k = 1.0;
    let rt = Runtime::load(&cfg.artifacts_dir)?;
    let gen = Generator::new(&rt, cfg.clone());

    let mut table = Table::new(
        "§5.2: compression vs context length (ASR-KF-EGR, k=1)",
        &[
            "New Tokens",
            "R budget",
            "Total",
            "Active KV",
            "Mean Active",
            "Compression",
            "Frozen KB (raw)",
            "Cold KB",
            "Staged hit",
            "Time",
        ],
    );
    // R is the per-step freeze/restore transfer budget (our PCIe-realism
    // extension). The paper's unbounded-python prototype corresponds to
    // large R; under small R the frozen population is capped at ~R*d,
    // so compression SATURATES with context instead of improving.
    for &(n, r) in &[(120usize, 64usize), (250, 64), (480, 64), (960, 64), (960, 256), (1900, 256)] {
        let mut c = cfg.clone();
        c.freeze.r_budget = r;
        let gen = Generator::new(&rt, c.clone());
        let out = gen.generate(PROMPT, make_policy("asrkf", &c.freeze)?, n)?;
        let s = &out.stats;
        let o = &s.offload.occupancy;
        let hit = s.offload.staged_hits + s.offload.staged_misses;
        table.row(&[
            n.to_string(),
            r.to_string(),
            s.total_tokens.to_string(),
            s.final_active_kv.to_string(),
            format!("{:.0}", s.mean_active_kv),
            format!("{:.2}%", s.compression * 100.0),
            // what the resident frozen rows would cost uncompressed,
            // vs what the quantized cold tier actually holds
            format!("{:.1}", o.uncompressed_bytes as f64 / 1024.0),
            format!("{:.1}", o.cold_bytes as f64 / 1024.0),
            if hit == 0 {
                "-".to_string()
            } else {
                format!("{:.0}%", 100.0 * s.offload.staged_hits as f64 / hit as f64)
            },
            format!("{:.2}s", s.wall.as_secs_f64()),
        ]);
    }
    table.print();
    table.write_csv("artifacts/context_sweep.csv")?;
    println!("\npaper claim: compression improves with context (67% @ 500 -> 80%+ hypothesized @ 8K)");
    println!("tiering claim: Cold KB < Frozen KB (raw) whenever rows settle in the cold tier");
    Ok(())
}
