//! Serving throughput/latency: the continuous-batching coordinator
//! under a Poisson trace, batched (B=4) vs sequential (B=1 equivalent:
//! one request at a time through the single-sequence engine).
//!
//! Not a paper table — this validates that the paper's technique
//! composes with a production-style serving loop (the "memory-
//! constrained deployment" the paper motivates).
//!
//! The offload columns expose the tiered frozen-KV store's
//! memory/latency trade: per-tier peak occupancy, the staged-hit rate
//! (restores served without inline dequantization), and per-tier
//! restore latencies.
//!
//! Output: table + artifacts/serving_throughput.csv

use std::time::Instant;

use asrkf::baselines::make_policy;
use asrkf::config::{EngineConfig, ServerConfig};
use asrkf::coordinator::{spawn, GenParams};
use asrkf::engine::Generator;
use asrkf::offload::OffloadSummary;
use asrkf::runtime::Runtime;
use asrkf::util::bench::Table;
use asrkf::workload::trace::poisson_trace;

const N_REQ: usize = 12;
const MAX_NEW: usize = 32;

/// Aggregate per-request offload summaries into the seven CSV columns:
/// per-request peak hot/cold KB (the max high-water mark any single
/// session reached — summing peaks of sessions that never coexisted
/// would overstate the footprint), staged-hit %, mean hot / cold
/// restore µs weighted by restore count, and the restore-batching pair
/// (rows restored / spans copied — spans << rows is the coalescing
/// win of batched plan execution).
fn offload_columns(summaries: &[OffloadSummary]) -> [String; 7] {
    let peak_hot: usize =
        summaries.iter().map(|s| s.occupancy.peak_hot_bytes).max().unwrap_or(0);
    let peak_cold: usize =
        summaries.iter().map(|s| s.occupancy.peak_cold_bytes).max().unwrap_or(0);
    let hits: u64 = summaries.iter().map(|s| s.staged_hits).sum();
    let misses: u64 = summaries.iter().map(|s| s.staged_misses).sum();
    let hit_pct = if hits + misses == 0 {
        "-".to_string()
    } else {
        format!("{:.0}%", 100.0 * hits as f64 / (hits + misses) as f64)
    };
    let weighted_us = |n: fn(&OffloadSummary) -> u64, us: fn(&OffloadSummary) -> u64| {
        let total: u64 = summaries.iter().map(n).sum();
        if total == 0 {
            return "-".to_string();
        }
        let sum: u64 = summaries.iter().map(|s| n(s) * us(s)).sum();
        format!("{}", sum / total)
    };
    let batch_rows: u64 = summaries.iter().map(|s| s.restore_batch_rows).sum();
    let batch_spans: u64 = summaries.iter().map(|s| s.restore_batch_spans).sum();
    [
        format!("{:.1}", peak_hot as f64 / 1024.0),
        format!("{:.1}", peak_cold as f64 / 1024.0),
        hit_pct,
        weighted_us(|s| s.restores_hot, |s| s.restore_hot_mean_us),
        weighted_us(|s| s.restores_cold, |s| s.restore_cold_mean_us),
        batch_rows.to_string(),
        batch_spans.to_string(),
    ]
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let trace = poisson_trace(42, N_REQ, 100.0, 40, 120, MAX_NEW); // all arrive ~immediately
    let mut table = Table::new(
        "Serving: batched coordinator vs sequential engine",
        &[
            "Mode",
            "Requests",
            "Tokens",
            "Wall",
            "tok/s",
            "mean e2e (ms)",
            "hot KB (peak/req)",
            "cold KB (peak/req)",
            "staged hit",
            "restore hot (us)",
            "restore cold (us)",
            "restored rows",
            "restore spans",
        ],
    );

    // --- batched coordinator (B=4)
    {
        let cfg = EngineConfig::default();
        let server = ServerConfig { max_batch: 4, ..ServerConfig::default() };
        let (handle, join) = spawn(cfg, server)?;
        let t0 = Instant::now();
        let rxs: Vec<_> = trace
            .iter()
            .map(|r| {
                handle.submit(GenParams {
                    prompt: r.prompt.clone(),
                    max_new: r.max_new,
                    policy: "asrkf".into(),
                    seed: r.arrival_ms,
                })
            })
            .collect::<Result<_, _>>()?;
        let mut tokens = 0usize;
        let mut e2e_sum = 0.0;
        let mut summaries = Vec::new();
        for rx in rxs {
            let resp = rx.recv()?;
            assert!(resp.error.is_none(), "{:?}", resp.error);
            tokens += resp.generated_tokens;
            e2e_sum += resp.e2e.as_secs_f64() * 1000.0;
            summaries.push(resp.offload);
        }
        let wall = t0.elapsed();
        let off = offload_columns(&summaries);
        let mut row = vec![
            "continuous batch (B=4)".to_string(),
            N_REQ.to_string(),
            tokens.to_string(),
            format!("{:.2}s", wall.as_secs_f64()),
            format!("{:.1}", tokens as f64 / wall.as_secs_f64()),
            format!("{:.0}", e2e_sum / N_REQ as f64),
        ];
        row.extend(off);
        table.row(&row);
        drop(handle);
        let _ = join.join();
    }

    // --- sequential single-sequence engine
    {
        let cfg = EngineConfig::default();
        let rt = Runtime::load(&cfg.artifacts_dir)?;
        let gen = Generator::new(&rt, cfg.clone());
        let t0 = Instant::now();
        let mut tokens = 0usize;
        let mut e2e_sum = 0.0;
        let mut summaries = Vec::new();
        for r in &trace {
            let t1 = Instant::now();
            let out = gen.generate(&r.prompt, make_policy("asrkf", &cfg.freeze)?, r.max_new)?;
            tokens += out.stats.generated_tokens;
            e2e_sum += t1.elapsed().as_secs_f64() * 1000.0;
            summaries.push(out.stats.offload);
        }
        let wall = t0.elapsed();
        let off = offload_columns(&summaries);
        let mut row = vec![
            "sequential (B=1)".to_string(),
            N_REQ.to_string(),
            tokens.to_string(),
            format!("{:.2}s", wall.as_secs_f64()),
            format!("{:.1}", tokens as f64 / wall.as_secs_f64()),
            format!("{:.0}", e2e_sum / N_REQ as f64),
        ];
        row.extend(off);
        table.row(&row);
    }

    table.print();
    table.write_csv("artifacts/serving_throughput.csv")?;
    Ok(())
}
