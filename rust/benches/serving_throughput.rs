//! Serving throughput/latency: the continuous-batching coordinator
//! under a Poisson trace, batched (B=4) vs sequential (B=1 equivalent:
//! one request at a time through the single-sequence engine).
//!
//! Not a paper table — this validates that the paper's technique
//! composes with a production-style serving loop (the "memory-
//! constrained deployment" the paper motivates).
//!
//! Output: table + artifacts/serving_throughput.csv

use std::time::Instant;

use asrkf::baselines::make_policy;
use asrkf::config::{EngineConfig, ServerConfig};
use asrkf::coordinator::{spawn, GenParams};
use asrkf::engine::Generator;
use asrkf::runtime::Runtime;
use asrkf::util::bench::Table;
use asrkf::workload::trace::poisson_trace;

const N_REQ: usize = 12;
const MAX_NEW: usize = 32;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let trace = poisson_trace(42, N_REQ, 100.0, 40, 120, MAX_NEW); // all arrive ~immediately
    let mut table = Table::new(
        "Serving: batched coordinator vs sequential engine",
        &["Mode", "Requests", "Tokens", "Wall", "tok/s", "mean e2e (ms)"],
    );

    // --- batched coordinator (B=4)
    {
        let cfg = EngineConfig::default();
        let server = ServerConfig { max_batch: 4, ..ServerConfig::default() };
        let (handle, join) = spawn(cfg, server)?;
        let t0 = Instant::now();
        let rxs: Vec<_> = trace
            .iter()
            .map(|r| {
                handle.submit(GenParams {
                    prompt: r.prompt.clone(),
                    max_new: r.max_new,
                    policy: "asrkf".into(),
                    seed: r.arrival_ms,
                })
            })
            .collect::<Result<_, _>>()?;
        let mut tokens = 0usize;
        let mut e2e_sum = 0.0;
        for rx in rxs {
            let resp = rx.recv()?;
            assert!(resp.error.is_none(), "{:?}", resp.error);
            tokens += resp.generated_tokens;
            e2e_sum += resp.e2e.as_secs_f64() * 1000.0;
        }
        let wall = t0.elapsed();
        table.row(&[
            "continuous batch (B=4)".into(),
            N_REQ.to_string(),
            tokens.to_string(),
            format!("{:.2}s", wall.as_secs_f64()),
            format!("{:.1}", tokens as f64 / wall.as_secs_f64()),
            format!("{:.0}", e2e_sum / N_REQ as f64),
        ]);
        drop(handle);
        let _ = join.join();
    }

    // --- sequential single-sequence engine
    {
        let cfg = EngineConfig::default();
        let rt = Runtime::load(&cfg.artifacts_dir)?;
        let gen = Generator::new(&rt, cfg.clone());
        let t0 = Instant::now();
        let mut tokens = 0usize;
        let mut e2e_sum = 0.0;
        for r in &trace {
            let t1 = Instant::now();
            let out = gen.generate(&r.prompt, make_policy("asrkf", &cfg.freeze)?, r.max_new)?;
            tokens += out.stats.generated_tokens;
            e2e_sum += t1.elapsed().as_secs_f64() * 1000.0;
        }
        let wall = t0.elapsed();
        table.row(&[
            "sequential (B=1)".into(),
            N_REQ.to_string(),
            tokens.to_string(),
            format!("{:.2}s", wall.as_secs_f64()),
            format!("{:.1}", tokens as f64 / wall.as_secs_f64()),
            format!("{:.0}", e2e_sum / N_REQ as f64),
        ]);
    }

    table.print();
    table.write_csv("artifacts/serving_throughput.csv")?;
    Ok(())
}
