//! Serving throughput/latency: the continuous-batching coordinator
//! under a Poisson trace — swept across offload shard counts — vs the
//! sequential single-sequence engine, plus two host-only microbenches
//! that run even without trained artifacts: a sharded-store restore
//! burst and a persistent-spill crash-recovery burst (stash → drop →
//! resume → restore), so BENCH CSVs track recovery-path restore
//! latency alongside the in-process path.
//!
//! Not a paper table — this validates that the paper's technique
//! composes with a production-style serving loop (the "memory-
//! constrained deployment" the paper motivates) and measures what
//! position-sharding buys the restore path: the `Shards` column sweeps
//! N ∈ {1, 2, 4} and `restore par` reports the most shards a single
//! restore burst engaged (> 1 means bursts actually executed per-shard
//! in parallel on the worker pool).
//!
//! `BENCH_SMOKE=1` shrinks every knob to CI size and tolerates a
//! missing runtime (schema CSV still emitted).
//!
//! Output: table + artifacts/serving_throughput.csv

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use asrkf::baselines::make_policy;
use asrkf::config::{EngineConfig, ServerConfig, ShardPartition};
use asrkf::coordinator::{spawn, GenParams};
use asrkf::engine::Generator;
use asrkf::metrics::PlanLatency;
use asrkf::offload::{OffloadSummary, ShardedStore};
use asrkf::runtime::Runtime;
use asrkf::util::bench::{self, Table};
use asrkf::util::TempDir;
use asrkf::workload::trace::poisson_trace;

const SHARD_SWEEP: [usize; 3] = [1, 2, 4];

/// Aggregate per-request offload summaries into the fourteen CSV
/// columns: per-request peak hot/cold KB (the max high-water mark any
/// single session reached — summing peaks of sessions that never
/// coexisted would overstate the footprint), staged-hit %, mean hot /
/// cold restore µs weighted by restore count, the restore-batching
/// pair (rows restored / spans copied — spans << rows is the
/// coalescing win), the restore-parallelism high-water mark across
/// sessions, rows re-attached from a persistent spill directory at
/// resume, the pipelined-restore pair: total µs the decode path
/// blocked on in-flight speculative reads plus the takes that arrived
/// before their read finished (both 0 with the pipeline off or fully
/// hidden I/O), and the codec-ladder triple: mean admitted payload
/// bytes/row per tier ("-" until a tier admits a row — with a
/// sub-byte ladder armed, cold/spill drop below the u8 baseline of
/// `8 + row_floats`).
fn offload_columns(summaries: &[OffloadSummary]) -> [String; 14] {
    let peak_hot: usize =
        summaries.iter().map(|s| s.occupancy.peak_hot_bytes).max().unwrap_or(0);
    let peak_cold: usize =
        summaries.iter().map(|s| s.occupancy.peak_cold_bytes).max().unwrap_or(0);
    let hits: u64 = summaries.iter().map(|s| s.staged_hits).sum();
    let misses: u64 = summaries.iter().map(|s| s.staged_misses).sum();
    let hit_pct = if hits + misses == 0 {
        "-".to_string()
    } else {
        format!("{:.0}%", 100.0 * hits as f64 / (hits + misses) as f64)
    };
    let weighted_us = |n: fn(&OffloadSummary) -> u64, us: fn(&OffloadSummary) -> u64| {
        let total: u64 = summaries.iter().map(n).sum();
        if total == 0 {
            return "-".to_string();
        }
        let sum: u64 = summaries.iter().map(|s| n(s) * us(s)).sum();
        format!("{}", sum / total)
    };
    let batch_rows: u64 = summaries.iter().map(|s| s.restore_batch_rows).sum();
    let batch_spans: u64 = summaries.iter().map(|s| s.restore_batch_spans).sum();
    let par_max: u64 = summaries.iter().map(|s| s.restore_parallelism_max).max().unwrap_or(0);
    let recovered: u64 = summaries.iter().map(|s| s.recovered_rows).sum();
    let restore_wait: u64 = summaries.iter().map(|s| s.restore_wait_us).sum();
    let late: u64 = summaries.iter().map(|s| s.late_arrivals).sum();
    // per-session cumulative means, averaged over the sessions whose
    // tier actually admitted rows ("-" when none did)
    let bytes_per_row = |f: fn(&OffloadSummary) -> u64| {
        let vals: Vec<u64> = summaries.iter().map(f).filter(|&v| v > 0).collect();
        if vals.is_empty() {
            "-".to_string()
        } else {
            format!("{}", vals.iter().sum::<u64>() / vals.len() as u64)
        }
    };
    [
        format!("{:.1}", peak_hot as f64 / 1024.0),
        format!("{:.1}", peak_cold as f64 / 1024.0),
        hit_pct,
        weighted_us(|s| s.restores_hot, |s| s.restore_hot_mean_us),
        weighted_us(|s| s.restores_cold, |s| s.restore_cold_mean_us),
        batch_rows.to_string(),
        batch_spans.to_string(),
        par_max.to_string(),
        recovered.to_string(),
        restore_wait.to_string(),
        late.to_string(),
        bytes_per_row(|s| s.bytes_per_row_hot),
        bytes_per_row(|s| s.bytes_per_row_cold),
        bytes_per_row(|s| s.bytes_per_row_spill),
    ]
}

/// Aggregate per-request policy control-plane latencies into the
/// `plan mean (us)` / `plan p99 (us)` column pair: the mean is
/// weighted by each request's decode-step count, the p99 is the worst
/// per-request p99. "-" when no steps ran (host-only rows).
fn plan_columns(lats: &[PlanLatency]) -> [String; 2] {
    let steps: u64 = lats.iter().map(|l| l.steps).sum();
    if steps == 0 {
        return ["-".into(), "-".into()];
    }
    let mean = lats.iter().map(|l| l.steps * l.mean_us).sum::<u64>() / steps;
    let p99 = lats.iter().map(|l| l.p99_us).max().unwrap_or(0);
    [mean.to_string(), p99.to_string()]
}

/// The `rows lost` / `shard rebuilds` column pair: rows declared lost
/// to shard failures and supervisor rebuilds, summed across sessions.
/// Both stay 0 unless fault injection (or a real worker panic) fired.
fn fault_columns(summaries: &[OffloadSummary]) -> [String; 2] {
    let lost: u64 = summaries.iter().map(|s| s.rows_lost).sum();
    let rebuilds: u64 = summaries.iter().map(|s| s.shard_rebuilds).sum();
    let faults: u64 = summaries.iter().map(|s| s.faults_injected).sum();
    let retries: u64 = summaries.iter().map(|s| s.io_retries).sum();
    SMOKE_FAULTS.fetch_add(faults, Ordering::Relaxed);
    SMOKE_RETRIES.fetch_add(retries, Ordering::Relaxed);
    [lost.to_string(), rebuilds.to_string()]
}

/// Run-wide fault-smoke tallies, folded in by `fault_columns` as each
/// row lands (so the end-of-run smoke line covers every store built).
static SMOKE_FAULTS: AtomicU64 = AtomicU64::new(0);
static SMOKE_RETRIES: AtomicU64 = AtomicU64::new(0);

/// CI fault-smoke arming: with `ASRKF_FAULT_SEED` in the environment
/// the host-only rows run under deterministic fault injection —
/// transient spill I/O errors, torn record writes, and delayed worker
/// replies — with the retry budget raised so every op recovers and
/// the rows' own restored-count asserts still hold. Worker panics
/// stay off here: a panic fails the whole bench process, and the
/// chaos suite (`tests/chaos.rs`) owns that regime. Without the env
/// var the config passes through untouched and the injector stays a
/// `None` check.
fn fault_smoke(mut cfg: asrkf::config::OffloadConfig) -> asrkf::config::OffloadConfig {
    if let Some(seed) = std::env::var("ASRKF_FAULT_SEED").ok().and_then(|s| s.parse().ok()) {
        cfg.fault_seed = Some(seed);
        cfg.fault_io_rate = 0.05;
        cfg.fault_torn_rate = 0.02;
        cfg.fault_panic_rate = 0.0;
        cfg.fault_delay_rate = 0.05;
        cfg.fault_delay_us = 50;
        cfg.io_retry_attempts = 6;
        cfg.io_retry_backoff_us = 10;
        cfg.io_retry_deadline_ms = 1000;
    }
    cfg
}

/// Host-only restore-burst microbench: stash cold rows into a
/// `ShardedStore`, then restore them in sorted bursts — the exact
/// shape of an entropy-triggered recovery. Runs without artifacts, so
/// CI smoke exercises the worker pool and the parallel dequantization
/// path every time.
fn sharded_burst_rows(table: &mut Table) -> Result<(), Box<dyn std::error::Error>> {
    const ROW_FLOATS: usize = 512; // 2 KB rows
    let waves = bench::smoke_size(24, 4);
    let burst = bench::smoke_size(256, 64);
    // the u8-only sharded sweep, plus one row with the full codec
    // ladder armed — its far-thaw stashes land on the sub-byte rungs,
    // so `bytes/row (cold)` must drop below the u8 sweep's value
    let full_ladder = asrkf::offload::CodecLadder::parse("0:u8,64:u4,512:ebq")?;
    let variants: Vec<(&str, usize, asrkf::offload::CodecLadder)> = SHARD_SWEEP
        .iter()
        .map(|&n| ("store burst (hash)", n, asrkf::offload::CodecLadder::default()))
        .chain(std::iter::once(("store burst (ladder)", 4, full_ladder)))
        .collect();
    for (label, n, ladder) in variants {
        let _section = bench::section(&format!("store burst n={n} {label}"));
        let cfg = fault_smoke(asrkf::config::OffloadConfig {
            cold_after_steps: 4,
            shards: n,
            shard_partition: ShardPartition::Hash,
            codec_ladder: ladder,
            ..Default::default()
        });
        let mut store = ShardedStore::new(ROW_FLOATS, cfg)?;
        let row: Vec<f32> = (0..ROW_FLOATS).map(|i| (i as f32 * 0.37).sin()).collect();
        let t0 = Instant::now();
        let mut e2e_sum = 0.0f64;
        let mut restored = 0usize;
        for wave in 0..waves {
            let base = wave * burst;
            let positions: Vec<usize> = (base..base + burst).collect();
            let items: Vec<(usize, Vec<f32>, u64)> = positions
                .iter()
                .map(|&p| (p, row.clone(), u64::MAX >> 1)) // far thaw: straight to cold
                .collect();
            store.stash_batch(items, wave as u64)?;
            let t1 = Instant::now();
            // the burst pays per-shard parallel dequantization
            let got = store.take_batch(&positions)?;
            e2e_sum += t1.elapsed().as_secs_f64() * 1000.0;
            restored += got.iter().filter(|p| p.is_some()).count();
        }
        let wall = t0.elapsed();
        let sum = store.summary();
        let mut cells = vec![
            label.to_string(),
            n.to_string(),
            waves.to_string(),
            restored.to_string(),
            format!("{:.2}s", wall.as_secs_f64()),
            format!("{:.1}", restored as f64 / wall.as_secs_f64()),
            format!("{:.1}", e2e_sum / waves as f64),
        ];
        let sums = [sum];
        cells.extend(offload_columns(&sums));
        cells.extend(plan_columns(&[])); // no decode steps: policy never ran
        cells.extend(fault_columns(&sums));
        table.row(&cells);
    }
    Ok(())
}

/// Host-only pipelined-restore microbench: the same cold-burst shape
/// as `sharded_burst_rows`, but with rows stashed at the edge of the
/// speculation horizon and a `pipeline_advance` step boundary plus
/// host "decode" work between stash and restore — so with the
/// pipeline ON the speculative reads run overlapped with the host
/// work and `take_batch` drains landed copies, while the OFF row pays
/// the same dequantization inline. The two rows differ only in the
/// `--no-restore-pipeline` switch; `restore wait (us)` / `late
/// arrivals` quantify how much tier I/O the overlap failed to hide.
fn pipelined_burst_rows(table: &mut Table) -> Result<(), Box<dyn std::error::Error>> {
    const ROW_FLOATS: usize = 512; // 2 KB rows
    let waves = bench::smoke_size(24, 4);
    let burst = bench::smoke_size(256, 64);
    for &pipeline in &[true, false] {
        let label = if pipeline { "pipelined burst (on)" } else { "pipelined burst (off)" };
        let _section = bench::section(&format!("pipelined burst on={pipeline}"));
        let cfg = fault_smoke(asrkf::config::OffloadConfig {
            cold_after_steps: 4,
            prefetch_ahead: 4,
            shards: 4,
            shard_partition: ShardPartition::Hash,
            pipeline,
            stage_burst_rows: burst,
            ..Default::default()
        });
        let mut store = ShardedStore::new(ROW_FLOATS, cfg)?;
        let row: Vec<f32> = (0..ROW_FLOATS).map(|i| (i as f32 * 0.37).sin()).collect();
        let t0 = Instant::now();
        let mut e2e_sum = 0.0f64;
        let mut restored = 0usize;
        let mut sink = 0.0f32;
        for wave in 0..waves {
            let step = wave as u64;
            let base = wave * burst;
            let positions: Vec<usize> = (base..base + burst).collect();
            let items: Vec<(usize, Vec<f32>, u64)> = positions
                .iter()
                // thaw eta exactly cold_after_steps out: admitted
                // straight to cold, yet due within prefetch_ahead
                .map(|&p| (p, row.clone(), step + 4))
                .collect();
            store.stash_batch(items, step)?;
            // step boundary: speculative reads launch here (no-op off)
            store.pipeline_advance(step)?;
            // the "decode step" the tier I/O should hide behind
            for i in 0..200_000u32 {
                sink = std::hint::black_box(sink * 0.999_9 + i as f32 * 1e-9);
            }
            let t1 = Instant::now();
            let got = store.take_batch(&positions)?;
            e2e_sum += t1.elapsed().as_secs_f64() * 1000.0;
            restored += got.iter().filter(|p| p.is_some()).count();
        }
        // flush the final wave's wait sample into the histogram
        store.pipeline_advance(waves as u64)?;
        let wall = t0.elapsed();
        let sum = store.summary();
        std::hint::black_box(sink);
        let mut cells = vec![
            label.to_string(),
            "4".to_string(),
            waves.to_string(),
            restored.to_string(),
            format!("{:.2}s", wall.as_secs_f64()),
            format!("{:.1}", restored as f64 / wall.as_secs_f64()),
            format!("{:.1}", e2e_sum / waves as f64),
        ];
        let sums = [sum];
        cells.extend(offload_columns(&sums));
        cells.extend(plan_columns(&[])); // host-only: policy never ran
        cells.extend(fault_columns(&sums));
        table.row(&cells);
    }
    Ok(())
}

/// Host-only persistent-spill recovery microbench: spill a burst of
/// cold rows to a `--spill-persist` directory, drop the store with no
/// shutdown (the crash), then resume and restore everything — the
/// recovery-path restore latency the crash-safe tier adds over the
/// in-process burst above. Runs without artifacts, so CI smoke
/// exercises manifest attach, the record scan, and checksummed
/// recovered-row reads every time.
fn persistent_recovery_rows(table: &mut Table) -> Result<(), Box<dyn std::error::Error>> {
    const ROW_FLOATS: usize = 512; // 2 KB rows
    let rows = bench::smoke_size(2048, 128);
    for &n in &[1usize, 4] {
        let _section = bench::section(&format!("persist recover n={n}"));
        let dir = TempDir::new("bench-spill-persist")?;
        let cfg = fault_smoke(asrkf::config::OffloadConfig {
            cold_budget_bytes: 1, // every stash spills straight to disk
            cold_after_steps: 4,
            shards: n,
            shard_partition: ShardPartition::Hash,
            spill_dir: Some(dir.path_str()),
            spill_persist: true,
            ..Default::default()
        });
        let row: Vec<f32> = (0..ROW_FLOATS).map(|i| (i as f32 * 0.37).sin()).collect();
        let positions: Vec<usize> = (0..rows).collect();
        {
            let mut store = ShardedStore::new(ROW_FLOATS, cfg.clone())?;
            let items: Vec<(usize, Vec<f32>, u64)> =
                positions.iter().map(|&p| (p, row.clone(), u64::MAX >> 1)).collect();
            store.stash_batch(items, 0)?;
            // crash: ungraceful drop, records stay on disk
        }
        let t0 = Instant::now();
        let mut store = ShardedStore::resume(ROW_FLOATS, cfg)?;
        let t1 = Instant::now();
        let got = store.take_batch(&positions)?;
        let restore = t1.elapsed();
        let restored = got.iter().filter(|p| p.is_some()).count();
        assert_eq!(restored, rows, "recovery must hand back every spilled row");
        let wall = t0.elapsed();
        let sum = store.summary();
        // Wall covers manifest attach + record scan + the restore
        // burst; "mean e2e" is the restore burst alone, so the scan
        // cost is the difference
        let mut cells = vec![
            "persist recover (hash)".to_string(),
            n.to_string(),
            "1".to_string(),
            restored.to_string(),
            format!("{:.2}s", wall.as_secs_f64()),
            format!("{:.1}", restored as f64 / wall.as_secs_f64()),
            format!("{:.1}", restore.as_secs_f64() * 1000.0),
        ];
        let sums = [sum];
        cells.extend(offload_columns(&sums));
        cells.extend(plan_columns(&[])); // host-only: policy never ran
        cells.extend(fault_columns(&sums));
        table.row(&cells);
    }
    Ok(())
}

/// Runtime-backed rows: the batched coordinator across the shard sweep
/// and the sequential single-sequence engine.
fn runtime_rows(
    table: &mut Table,
    n_req: usize,
    max_new: usize,
) -> Result<(), Box<dyn std::error::Error>> {
    let trace = poisson_trace(42, n_req, 100.0, 40, 120, max_new); // all arrive ~immediately

    // --- batched coordinator (B=4), shard sweep
    for &n in &SHARD_SWEEP {
        let mut cfg = EngineConfig::default();
        cfg.offload.shards = n;
        let server = ServerConfig { max_batch: 4, ..ServerConfig::default() };
        let (handle, join) = spawn(cfg, server)?;
        let t0 = Instant::now();
        let tickets: Vec<_> = trace
            .iter()
            .map(|r| {
                handle.submit(
                    GenParams::builder(r.prompt.clone())
                        .max_new(r.max_new)
                        .seed(r.arrival_ms)
                        .build(),
                )
            })
            .collect::<Result<_, _>>()?;
        let mut tokens = 0usize;
        let mut e2e_sum = 0.0;
        let mut summaries = Vec::new();
        let mut plan_lats = Vec::new();
        for ticket in tickets {
            let resp = ticket.wait()?;
            assert!(resp.error.is_none(), "{:?}", resp.error);
            tokens += resp.generated_tokens;
            e2e_sum += resp.e2e.as_secs_f64() * 1000.0;
            summaries.push(resp.offload);
            plan_lats.push(resp.plan_latency);
        }
        let wall = t0.elapsed();
        let off = offload_columns(&summaries);
        let mut row = vec![
            "continuous batch (B=4)".to_string(),
            n.to_string(),
            n_req.to_string(),
            tokens.to_string(),
            format!("{:.2}s", wall.as_secs_f64()),
            format!("{:.1}", tokens as f64 / wall.as_secs_f64()),
            format!("{:.0}", e2e_sum / n_req as f64),
        ];
        row.extend(off);
        row.extend(plan_columns(&plan_lats));
        row.extend(fault_columns(&summaries));
        table.row(&row);
        drop(handle);
        let _ = join.join();
    }

    // --- sequential single-sequence engine
    {
        let cfg = EngineConfig::default();
        let rt = Runtime::load(&cfg.artifacts_dir)?;
        let gen = Generator::new(&rt, cfg.clone());
        let t0 = Instant::now();
        let mut tokens = 0usize;
        let mut e2e_sum = 0.0;
        let mut summaries = Vec::new();
        let mut plan_lats = Vec::new();
        for r in &trace {
            let t1 = Instant::now();
            let out = gen.generate(&r.prompt, make_policy("asrkf", &cfg.freeze)?, r.max_new)?;
            tokens += out.stats.generated_tokens;
            e2e_sum += t1.elapsed().as_secs_f64() * 1000.0;
            summaries.push(out.stats.offload);
            plan_lats.push(out.stats.plan_latency);
        }
        let wall = t0.elapsed();
        let off = offload_columns(&summaries);
        let mut row = vec![
            "sequential (B=1)".to_string(),
            "1".to_string(),
            n_req.to_string(),
            tokens.to_string(),
            format!("{:.2}s", wall.as_secs_f64()),
            format!("{:.1}", tokens as f64 / wall.as_secs_f64()),
            format!("{:.0}", e2e_sum / n_req as f64),
        ];
        row.extend(off);
        row.extend(plan_columns(&plan_lats));
        row.extend(fault_columns(&summaries));
        table.row(&row);
    }
    Ok(())
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let n_req = bench::smoke_size(12, 4);
    let max_new = bench::smoke_size(32, 8);
    // headers come from the registry's declared CSV schema, so the
    // bench cannot drift from the metric catalog (checked in CI)
    let headers = asrkf::metrics::serving_csv_headers();
    let mut table = Table::new(
        "Serving: sharded restore bursts + batched coordinator vs sequential engine",
        &headers,
    );

    sharded_burst_rows(&mut table)?;
    pipelined_burst_rows(&mut table)?;
    persistent_recovery_rows(&mut table)?;

    if let Err(e) = runtime_rows(&mut table, n_req, max_new) {
        if bench::smoke() {
            println!("BENCH_SMOKE: skipping runtime-driven rows ({e})");
        } else {
            return Err(e);
        }
    }

    table.print();
    table.write_csv("artifacts/serving_throughput.csv")?;
    if std::env::var("ASRKF_FAULT_SEED").is_ok() {
        let faults = SMOKE_FAULTS.load(Ordering::Relaxed);
        let retries = SMOKE_RETRIES.load(Ordering::Relaxed);
        // every row above already asserted its restored counts, so
        // reaching here means the injected faults were all absorbed
        println!("fault smoke: {faults} faults injected, {retries} io retries, all rows completed");
        assert!(
            faults > 0,
            "ASRKF_FAULT_SEED set but no faults fired — injector wiring is broken"
        );
    }
    // one end-of-run wall-clock table from the registry's section
    // gauges (recorded by the RAII timers around the host-only rows)
    bench::section_summary().print();
    println!(
        "\nsharding claim: `restore par` > 1 for Shards > 1 — restore bursts split at shard \
         boundaries and execute on the worker pool in parallel\n\
         pipeline claim: compare the `pipelined burst (on)` vs `(off)` rows — `mean e2e` drops \
         when speculative reads overlap the host work, and `restore wait (us)` / `late arrivals` \
         bound the tier I/O the overlap failed to hide\n\
         ladder claim: compare `bytes/row (cold)` on the `store burst (ladder)` row vs the \
         `store burst (hash)` sweep — sub-byte rungs pull admitted bytes/row below the u8 \
         baseline of 8 + row_floats"
    );
    Ok(())
}
