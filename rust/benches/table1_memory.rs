//! Paper Table 1: memory efficiency on a 500-token generation task.
//!
//! Paper reports (LLaMA-3 8B): Full KV 514/514 active, 7.55s;
//! ASR-KF-EGR 170/514 active (66.93% compression), 38.96s (5x overhead
//! from Python bookkeeping + per-token transfers).
//!
//! We reproduce the *shape*: the compression band and the relative
//! overhead of the freeze policy vs Full KV on identical settings.
//! Two ASR-KF-EGR rows: the paper's softness k=2 and k=1 (which, under
//! our budget-limited transfer engine, lands on the paper's 67% — see
//! EXPERIMENTS.md discussion).
//!
//! Output: table + artifacts/table1_memory.csv

use asrkf::baselines::make_policy;
use asrkf::config::EngineConfig;
use asrkf::engine::Generator;
use asrkf::runtime::Runtime;
use asrkf::util::bench::{self, Table};

const PROMPT: &str = "the system routes every request. ";

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let new_tokens = bench::smoke_size(480, 24);
    let base = EngineConfig::default();

    let mut table = Table::new(
        "Table 1: memory efficiency, 500-token generation",
        &["Method", "Total Tokens", "Active KV", "Mean Active", "Compression", "Time", "Freezes"],
    );
    let rt = match Runtime::load(&base.artifacts_dir) {
        Ok(rt) => rt,
        Err(e) if bench::smoke() => {
            bench::smoke_schema_only(
                &table,
                "artifacts/table1_memory.csv",
                &format!("runtime unavailable ({e})"),
            )?;
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };

    // warmup: compile prefill+decode programs so Time rows are compile-free
    {
        let gen = Generator::new(&rt, base.clone());
        let _ = gen.generate(PROMPT, make_policy("full", &base.freeze)?, 4)?;
    }

    let runs: Vec<(&str, &str, f32)> = vec![
        ("Full KV (Baseline)", "full", 2.0),
        ("ASR-KF-EGR (k=2)", "asrkf", 2.0),
        ("ASR-KF-EGR (k=1)", "asrkf", 1.0),
    ];
    for (label, policy, softness) in runs {
        let mut cfg = base.clone();
        cfg.freeze.softness_k = softness;
        let gen = Generator::new(&rt, cfg.clone());
        let out = gen.generate(PROMPT, make_policy(policy, &cfg.freeze)?, new_tokens)?;
        let s = &out.stats;
        table.row(&[
            label.to_string(),
            s.total_tokens.to_string(),
            s.final_active_kv.to_string(),
            format!("{:.0}", s.mean_active_kv),
            format!("{:.2}%", s.compression * 100.0),
            format!("{:.2}s", s.wall.as_secs_f64()),
            s.freezes.to_string(),
        ]);
    }
    table.print();
    table.write_csv("artifacts/table1_memory.csv")?;
    println!("\npaper reference: Full KV 514/514 0% 7.55s | ASR-KF-EGR 170/514 66.93% 38.96s");
    println!("csv: artifacts/table1_memory.csv");
    Ok(())
}
