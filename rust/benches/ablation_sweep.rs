//! §6 "Threshold Sensitivity" ablation: sweep the paper's three
//! hyper-parameters (tau, K, k) one factor at a time around the
//! defaults and report compression + quality proxies. Also ablates the
//! sink-pinning extension (DESIGN.md §5).
//!
//! Output: table + artifacts/ablation_sweep.csv

use asrkf::baselines::make_policy;
use asrkf::config::EngineConfig;
use asrkf::engine::Generator;
use asrkf::runtime::Runtime;
use asrkf::util::bench::{self, Table};

const PROMPT: &str = "the system routes every request. ";

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let new_tokens = bench::smoke_size(250, 24);
    let base = EngineConfig::default();

    let mut table = Table::new(
        "Ablation: tau / window K / softness k / sinks",
        &["Variant", "Active KV", "Mean Active", "Compression", "Mean Entropy", "Freezes"],
    );
    let rt = match Runtime::load(&base.artifacts_dir) {
        Ok(rt) => rt,
        Err(e) if bench::smoke() => {
            bench::smoke_schema_only(
                &table,
                "artifacts/ablation_sweep.csv",
                &format!("runtime unavailable ({e})"),
            )?;
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };

    type Mut = Box<dyn Fn(&mut EngineConfig)>;
    let variants: Vec<(String, Mut)> = vec![
        ("defaults (tau=1.0 K=32 k=2 sinks=4)".into(), Box::new(|_| {})),
        ("tau=0.5".into(), Box::new(|c| c.freeze.tau = 0.5)),
        ("tau=1.5".into(), Box::new(|c| c.freeze.tau = 1.5)),
        ("K=16".into(), Box::new(|c| c.freeze.window_k = 16)),
        ("K=64".into(), Box::new(|c| c.freeze.window_k = 64)),
        ("k=1".into(), Box::new(|c| c.freeze.softness_k = 1.0)),
        ("k=4".into(), Box::new(|c| c.freeze.softness_k = 4.0)),
        ("no sinks".into(), Box::new(|c| c.freeze.n_sink = 0)),
        ("W=64".into(), Box::new(|c| c.freeze.history_w = 64)),
    ];

    for (label, mutate) in variants {
        let mut cfg = base.clone();
        mutate(&mut cfg);
        let gen = Generator::new(&rt, cfg.clone());
        let out = gen.generate(PROMPT, make_policy("asrkf", &cfg.freeze)?, new_tokens)?;
        let s = &out.stats;
        let ent =
            out.trace.iter().map(|t| t.entropy as f64).sum::<f64>() / out.trace.len() as f64;
        table.row(&[
            label,
            s.final_active_kv.to_string(),
            format!("{:.0}", s.mean_active_kv),
            format!("{:.2}%", s.compression * 100.0),
            format!("{:.3}", ent),
            s.freezes.to_string(),
        ]);
    }
    table.print();
    table.write_csv("artifacts/ablation_sweep.csv")?;
    Ok(())
}
