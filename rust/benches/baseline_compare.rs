//! Related-work baseline comparison (paper §2): ASR-KF-EGR vs H2O
//! (heavy-hitter eviction) vs StreamingLLM (sinks + window) vs Full KV,
//! on BOTH axes the paper cares about — memory compression and
//! retrieval capability. The punchline the paper claims: eviction
//! methods "cannot recover evicted information"; the soft freeze can.
//!
//! Output: table + artifacts/baseline_compare.csv

use asrkf::baselines::make_policy;
use asrkf::config::EngineConfig;
use asrkf::engine::Generator;
use asrkf::runtime::Runtime;
use asrkf::util::bench::{self, Table};
use asrkf::workload::passkey::run_passkey;

const PROMPT: &str = "the system routes every request. ";

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let new_tokens = bench::smoke_size(250, 16);
    let seeds = bench::smoke_size(3, 1) as u64;
    let haystack = bench::smoke_size(600, 200);
    let mut cfg = EngineConfig::default();
    cfg.freeze.softness_k = 1.0;

    let mut table = Table::new(
        "Baselines: memory + retrieval",
        &["Method", "Active KV", "Compression", "Reversible", "Needle recoverable", "Time"],
    );
    let rt = match Runtime::load(&cfg.artifacts_dir) {
        Ok(rt) => rt,
        Err(e) if bench::smoke() => {
            bench::smoke_schema_only(
                &table,
                "artifacts/baseline_compare.csv",
                &format!("runtime unavailable ({e})"),
            )?;
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };
    let gen = Generator::new(&rt, cfg.clone());

    let _ = gen.generate(PROMPT, make_policy("full", &cfg.freeze)?, 4)?; // compile warmup
    for policy in ["full", "asrkf", "h2o", "streaming"] {
        let out = gen.generate(PROMPT, make_policy(policy, &cfg.freeze)?, new_tokens)?;
        let mut recov = 0.0;
        for seed in 1..=seeds {
            recov += run_passkey(&rt, &cfg, policy, haystack, seed)?.needle_recoverable;
        }
        let s = &out.stats;
        table.row(&[
            policy.to_string(),
            format!("{}/{}", s.final_active_kv, s.total_tokens),
            format!("{:.1}%", s.compression * 100.0),
            (policy == "asrkf" || policy == "full").to_string(),
            format!("{:.0}%", recov / seeds as f64 * 100.0),
            format!("{:.2}s", s.wall.as_secs_f64()),
        ]);
    }
    table.print();
    table.write_csv("artifacts/baseline_compare.csv")?;
    Ok(())
}
