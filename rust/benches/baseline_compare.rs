//! Related-work baseline comparison (paper §2): ASR-KF-EGR vs H2O
//! (heavy-hitter eviction) vs StreamingLLM (sinks + window) vs Full KV,
//! on BOTH axes the paper cares about — memory compression and
//! retrieval capability. The punchline the paper claims: eviction
//! methods "cannot recover evicted information"; the soft freeze can.
//!
//! Output: table + artifacts/baseline_compare.csv

use asrkf::baselines::make_policy;
use asrkf::config::EngineConfig;
use asrkf::engine::Generator;
use asrkf::runtime::Runtime;
use asrkf::util::bench::Table;
use asrkf::workload::passkey::run_passkey;

const PROMPT: &str = "the system routes every request. ";
const NEW_TOKENS: usize = 250;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let mut cfg = EngineConfig::default();
    cfg.freeze.softness_k = 1.0;
    let rt = Runtime::load(&cfg.artifacts_dir)?;
    let gen = Generator::new(&rt, cfg.clone());

    let _ = gen.generate(PROMPT, make_policy("full", &cfg.freeze)?, 4)?; // compile warmup
    let mut table = Table::new(
        "Baselines: memory + retrieval",
        &["Method", "Active KV", "Compression", "Reversible", "Needle recoverable", "Time"],
    );
    for policy in ["full", "asrkf", "h2o", "streaming"] {
        let out = gen.generate(PROMPT, make_policy(policy, &cfg.freeze)?, NEW_TOKENS)?;
        let mut recov = 0.0;
        for seed in 1..=3u64 {
            recov += run_passkey(&rt, &cfg, policy, 600, seed)?.needle_recoverable;
        }
        let s = &out.stats;
        table.row(&[
            policy.to_string(),
            format!("{}/{}", s.final_active_kv, s.total_tokens),
            format!("{:.1}%", s.compression * 100.0),
            (policy == "asrkf" || policy == "full").to_string(),
            format!("{:.0}%", recov / 3.0 * 100.0),
            format!("{:.2}s", s.wall.as_secs_f64()),
        ]);
    }
    table.print();
    table.write_csv("artifacts/baseline_compare.csv")?;
    Ok(())
}
