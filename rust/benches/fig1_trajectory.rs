//! Paper Figure 1: active KV cache size during 500-token generation —
//! linear growth for the Full KV baseline vs sublinear, oscillating
//! growth for ASR-KF-EGR (plateaus, downward freeze slopes, upward
//! expiry spikes; §5.1).
//!
//! Output: ASCII plot + artifacts/fig1_trajectory.csv (step, series).

use asrkf::baselines::make_policy;
use asrkf::config::EngineConfig;
use asrkf::engine::Generator;
use asrkf::runtime::Runtime;
use asrkf::util::bench::{self, Series};

const PROMPT: &str = "the system routes every request. ";

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let new_tokens = bench::smoke_size(480, 24);
    let mut cfg = EngineConfig::default();
    cfg.freeze.softness_k = 1.0; // paper-compression operating point
    let rt = match Runtime::load(&cfg.artifacts_dir) {
        Ok(rt) => rt,
        Err(e) if bench::smoke() => {
            // schema-only CSV: the named-but-empty series pin the header
            let empty = [Series::new("full_kv"), Series::new("asr_kf_egr")];
            let refs: Vec<&Series> = empty.iter().collect();
            Series::write_csv(&refs, "artifacts/fig1_trajectory.csv")?;
            println!("BENCH_SMOKE: runtime unavailable ({e}); wrote schema CSV");
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };
    let gen = Generator::new(&rt, cfg.clone());

    let mut series = Vec::new();
    for policy in ["full", "asrkf"] {
        let out = gen.generate(PROMPT, make_policy(policy, &cfg.freeze)?, new_tokens)?;
        let mut s = Series::new(if policy == "full" { "full_kv" } else { "asr_kf_egr" });
        for t in &out.trace {
            s.push(t.step as f64, t.active as f64);
        }
        series.push(s);
    }
    let refs: Vec<&Series> = series.iter().collect();
    println!("Figure 1: active KV during generation (x = decode step)");
    println!("{}", Series::ascii_plot(&refs, 96, 24));
    Series::write_csv(&refs, "artifacts/fig1_trajectory.csv")?;
    println!("csv: artifacts/fig1_trajectory.csv");

    // quantify the figure's qualitative claims for EXPERIMENTS.md
    let asr = &series[1];
    let last_quarter: Vec<f64> = asr.points[asr.points.len() * 3 / 4..]
        .iter()
        .map(|p| p.1)
        .collect();
    let mean_late = last_quarter.iter().sum::<f64>() / last_quarter.len() as f64;
    let min_late = last_quarter.iter().cloned().fold(f64::MAX, f64::min);
    let max_late = last_quarter.iter().cloned().fold(f64::MIN, f64::max);
    println!(
        "late-phase active KV: mean {mean_late:.0}, oscillation band [{min_late:.0}, {max_late:.0}] (paper: stabilizes ~100-170)"
    );
    Ok(())
}
