//! Paper Table 2: passkey retrieval (greedy decoding, T=0).
//!
//! Paper reports PASS for ASR-KF-EGR with a 5-digit needle in ~1500
//! tokens of filler. Our stand-in model was trained with passkey
//! curriculum up to its 256-byte training horizon; we sweep haystack
//! sizes and — crucially — report Full KV on the same sizes, because
//! the paper's claim is that freezing does not *lose* the needle
//! relative to the baseline. StreamingLLM is included to show what
//! irreversible eviction does to the same task.
//!
//! Output: table + artifacts/table2_passkey.csv

use asrkf::config::EngineConfig;
use asrkf::offload::CodecLadder;
use asrkf::runtime::Runtime;
use asrkf::util::bench::{self, Table};
use asrkf::workload::passkey::run_passkey;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    asrkf::util::logging::init();
    let seeds = bench::smoke_size(3, 1) as u64;
    let haystacks: &[usize] =
        if bench::smoke() { &[200] } else { &[200, 400, 600, 900] };
    let cfg = EngineConfig::default();
    // Reversibility must survive the full compression ladder: frozen
    // needle rows demoted onto sub-byte rungs still have to come back.
    let mut ladder_cfg = cfg.clone();
    ladder_cfg.offload.codec_ladder = CodecLadder::parse("0:u8,64:u4,512:ebq")?;

    let mut table = Table::new(
        "Table 2: passkey retrieval (greedy, T=0)",
        &["Method", "Haystack", "Target", "Retrieved", "E2E", "Needle-KV recoverable", "Compression"],
    );
    let rt = match Runtime::load(&cfg.artifacts_dir) {
        Ok(rt) => rt,
        Err(e) if bench::smoke() => {
            bench::smoke_schema_only(
                &table,
                "artifacts/table2_passkey.csv",
                &format!("runtime unavailable ({e})"),
            )?;
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };
    let mut recover_counts = std::collections::BTreeMap::new();
    let variants: [(&str, &str, &EngineConfig); 5] = [
        ("full", "full", &cfg),
        ("asrkf", "asrkf", &cfg),
        ("asrkf (ladder)", "asrkf", &ladder_cfg),
        ("h2o", "h2o", &cfg),
        ("streaming", "streaming", &cfg),
    ];
    for &haystack in haystacks {
        for &(label, policy, vcfg) in &variants {
            let mut passes = 0;
            let mut recov = 0.0;
            let mut last = None;
            for seed in 1..=seeds {
                let o = run_passkey(&rt, vcfg, policy, haystack, seed)?;
                if o.pass {
                    passes += 1;
                }
                recov += o.needle_recoverable;
                last = Some(o);
            }
            let o = last.unwrap();
            *recover_counts.entry(label).or_insert(0.0) += recov;
            table.row(&[
                label.to_string(),
                format!("{haystack}B"),
                o.target.clone(),
                o.retrieved.clone(),
                format!("{passes}/{seeds}"),
                format!("{:.0}%", recov / seeds as f64 * 100.0),
                format!("{:.1}%", o.stats.compression * 100.0),
            ]);
        }
    }
    table.print();
    table.write_csv("artifacts/table2_passkey.csv")?;
    println!("\nmean needle-KV recoverability across cells: {recover_counts:?}");
    println!("paper reference: ASR-KF-EGR retrieves 44181 -> PASS (~1500-token haystack, 8B model).");
    println!("NOTE: the 3.3M stand-in model lacks induction-copy skill (E2E column fails for ALL");
    println!("policies incl. Full KV — model limitation, not a KV-policy effect; EXPERIMENTS.md).");
    println!("The recoverability column measures the paper's reversibility claim directly.");
    println!("`asrkf (ladder)` runs the same policy with the 0:u8,64:u4,512:ebq codec ladder armed.");
    println!("csv: artifacts/table2_passkey.csv");
    Ok(())
}
