//! Property-based equivalence for the pipelined restore path
//! (`ShardedStore::pipeline_advance`), in the style of
//! `prop_offload.rs`'s sharding oracle:
//!
//! A pipelined `ShardedStore` — speculative reads issued at every step
//! boundary, executing on the worker pool with a randomized artificial
//! completion delay (`pipeline_test_delay_us`) so landings race the
//! foreground trace — must be *observably identical* to a synchronous
//! single `TieredStore` over random stash/take/drop/stage/sweep
//! traces:
//!
//! * every restored payload is bit-exact against the oracle's
//!   synchronous `take` (the payload-stability argument: speculation
//!   only touches cold/spill rows, whose quantized payload is the
//!   restore source either way);
//! * conservation holds on the pipelined side at every step —
//!   `total_stashed == total_restored + total_dropped + resident` —
//!   including through cancellations (re-freeze fences, deadline
//!   expiry, drain), which must never leak or double-count a row;
//! * lifetime stash/restore/drop counters match the oracle exactly
//!   (staged hit/miss and promotion counters are intentionally NOT
//!   compared: speculative promotion shifts rows hot ahead of time,
//!   which is the point of the pipeline).
//!
//! Swept across shard counts {1, 4} × both partition schemes, with a
//! mix of ample-budget (eviction-free) and spill-everything configs.

use asrkf::config::{OffloadConfig, ShardPartition};
use asrkf::offload::{ShardedStore, TieredStore};
use asrkf::prop_assert;
use asrkf::util::prop::{prop_check, G};

const RF: usize = 32;

fn random_row(g: &mut G) -> Vec<f32> {
    g.vec_f32(RF, -4.0, 4.0)
}

fn pipeline_cfg(g: &mut G, shards: usize, partition: ShardPartition) -> OffloadConfig {
    // spill-everything with probability ~0.3: cold budget of one byte
    // forces every cold admission straight to disk on both sides, so
    // speculative reads exercise the spill tier too
    let spill_everything = g.bool(0.3);
    OffloadConfig {
        hot_budget_bytes: 1 << 24,
        cold_budget_bytes: if spill_everything { 1 } else { 1 << 24 },
        cold_after_steps: g.usize(2, 6) as u64,
        quantize_cold: g.bool(0.85),
        spill_dir: if spill_everything {
            Some(
                std::env::temp_dir()
                    .join("asrkf-prop-pipeline")
                    .to_string_lossy()
                    .into_owned(),
            )
        } else {
            None
        },
        prefetch_ahead: g.usize(1, 6) as u64,
        block_rows: g.usize(1, 8),
        shards,
        shard_partition: partition,
        pipeline: true,
        // small per-advance burst keeps worker sleep time bounded
        stage_burst_rows: 8,
        restore_deadline_steps: g.usize(1, 3) as u64,
        // half the traces race in-flight landings against the
        // foreground ops; the other half land near-instantly
        pipeline_test_delay_us: if g.bool(0.5) { g.usize(1, 200) as u64 } else { 0 },
        ..OffloadConfig::default()
    }
}

#[test]
fn prop_pipelined_store_matches_synchronous_oracle() {
    prop_check(8, |g| {
        for &n in &[1usize, 4] {
            for &partition in &[ShardPartition::Hash, ShardPartition::Range] {
                let cfg = pipeline_cfg(g, n, partition);
                let mut single_cfg = cfg.clone();
                single_cfg.shards = 1;
                single_cfg.pipeline = false;
                let mut piped =
                    ShardedStore::new(RF, cfg).map_err(|e| format!("sharded new: {e}"))?;
                let mut oracle = TieredStore::new(RF, single_cfg);
                let mut resident: Vec<usize> = Vec::new();
                let mut next_pos = 0usize;

                for step in 0..60u64 {
                    // step boundary: launch speculative reads for rows
                    // due to thaw within the horizon (oracle: no-op)
                    piped.pipeline_advance(step).map_err(|e| format!("pipeline_advance: {e}"))?;

                    match g.usize(0, 9) {
                        // stash fresh rows (weighted heaviest); etas
                        // straddle the cold-admission horizon
                        0..=3 => {
                            let k = g.usize(1, 4);
                            let mut items: Vec<(usize, Vec<f32>, u64)> = Vec::with_capacity(k);
                            for _ in 0..k {
                                let eta = step + g.usize(0, 12) as u64;
                                items.push((next_pos, random_row(g), eta));
                                resident.push(next_pos);
                                next_pos += 1;
                            }
                            for (pos, row, eta) in &items {
                                oracle
                                    .stash(*pos, row.clone(), step, *eta)
                                    .map_err(|e| format!("oracle stash: {e}"))?;
                            }
                            piped.stash_batch(items, step).map_err(|e| format!("stash: {e}"))?;
                        }
                        // restore a sorted burst: landed speculative
                        // copies drain from the staging buffer, the
                        // rest pays the tier path — either way the
                        // bytes must match a synchronous take
                        4..=5 => {
                            let mut burst: Vec<usize> =
                                resident.iter().copied().filter(|_| g.bool(0.4)).collect();
                            burst.sort_unstable();
                            if burst.is_empty() {
                                continue;
                            }
                            resident.retain(|p| !burst.contains(p));
                            let got = piped
                                .take_batch(&burst)
                                .map_err(|e| format!("take_batch: {e}"))?;
                            for (&pos, payload) in burst.iter().zip(got) {
                                let want = oracle
                                    .take(pos)
                                    .map_err(|e| format!("oracle take: {e}"))?;
                                prop_assert!(
                                    payload == want,
                                    "restored payload diverged at pos {pos} \
                                     (n={n}, {partition:?}, step {step})"
                                );
                            }
                        }
                        // drop a resident row: fences any landed copy
                        6 => {
                            if !resident.is_empty() {
                                let pos = resident.swap_remove(g.usize(0, resident.len() - 1));
                                piped.drop_row(pos).map_err(|e| format!("drop: {e}"))?;
                                oracle.drop_row(pos).map_err(|e| format!("drop: {e}"))?;
                            }
                        }
                        // thaw-and-refreeze: restore one row, compare,
                        // then re-stash the SAME position with a new
                        // payload — a landed or in-flight speculative
                        // copy of the old bytes must be fenced, never
                        // served for a later take
                        7 => {
                            if !resident.is_empty() {
                                let pos = resident[g.usize(0, resident.len() - 1)];
                                let a = piped.take(pos).map_err(|e| format!("take: {e}"))?;
                                let b = oracle.take(pos).map_err(|e| format!("take: {e}"))?;
                                prop_assert!(
                                    a == b,
                                    "refreeze take diverged at pos {pos} (n={n}, {partition:?})"
                                );
                                let row = random_row(g);
                                let eta = step + g.usize(0, 12) as u64;
                                piped
                                    .stash(pos, row.clone(), step, eta)
                                    .map_err(|e| format!("restash: {e}"))?;
                                oracle
                                    .stash(pos, row, step, eta)
                                    .map_err(|e| format!("restash: {e}"))?;
                            }
                        }
                        // prefetch staging on both sides (promoted-row
                        // counts are NOT compared: the pipeline may
                        // have promoted some of these already)
                        8 => {
                            let horizon = g.usize(0, 8) as u64;
                            piped
                                .stage_upcoming(step, horizon, 16)
                                .map_err(|e| format!("stage_upcoming: {e}"))?;
                            oracle
                                .stage_upcoming(step, horizon, 16)
                                .map_err(|e| format!("stage_upcoming: {e}"))?;
                        }
                        // residency sweep
                        _ => {
                            piped.on_step(step).map_err(|e| format!("on_step: {e}"))?;
                            oracle.on_step(step).map_err(|e| format!("on_step: {e}"))?;
                        }
                    }

                    // land everything in flight, then check the
                    // aggregate invariants (in-flight shards are
                    // checked out, so aggregates need a settled store)
                    piped.settle().map_err(|e| format!("settle: {e}"))?;
                    prop_assert!(
                        piped.len() == oracle.len() && piped.len() == resident.len(),
                        "resident mismatch at step {step}: piped {} vs oracle {} vs model {}",
                        piped.len(),
                        oracle.len(),
                        resident.len()
                    );
                    prop_assert!(
                        piped.total_stashed()
                            == piped.total_restored() + piped.total_dropped() + piped.len() as u64,
                        "pipelined conservation violated at step {step}: {} != {} + {} + {}",
                        piped.total_stashed(),
                        piped.total_restored(),
                        piped.total_dropped(),
                        piped.len()
                    );
                    prop_assert!(
                        piped.total_stashed() == oracle.total_stashed
                            && piped.total_restored() == oracle.total_restored
                            && piped.total_dropped() == oracle.total_dropped,
                        "lifetime counters diverged at step {step} (n={n}, {partition:?})"
                    );
                }

                // speculative bookkeeping sanity: everything issued
                // either landed or was cancelled at landing, and only
                // landed copies can be consumed
                prop_assert!(
                    piped.spec_landed <= piped.spec_issued,
                    "landed {} > issued {}",
                    piped.spec_landed,
                    piped.spec_issued
                );
                prop_assert!(
                    piped.spec_consumed <= piped.spec_landed,
                    "consumed {} > landed {}",
                    piped.spec_consumed,
                    piped.spec_landed
                );

                // drain discards unconsumed landed copies (counted as
                // cancels) and must still hand back identical contents
                let mut a = piped.drain_all().map_err(|e| format!("drain: {e}"))?;
                let mut b = oracle.drain_all().map_err(|e| format!("drain: {e}"))?;
                a.sort_by_key(|(p, _)| *p);
                b.sort_by_key(|(p, _)| *p);
                prop_assert!(a == b, "drained contents diverged (n={n}, {partition:?})");
                prop_assert!(piped.is_empty() && oracle.is_empty(), "drain left residents");
                prop_assert!(
                    piped.total_stashed() == piped.total_restored() + piped.total_dropped(),
                    "post-drain conservation violated"
                );
            }
        }
        Ok(())
    });
}
