//! Property-based tests on the tiered frozen-KV storage invariants
//! (`offload::TieredStore`), in the style of `prop_policy.rs`:
//!
//! * conservation — every stashed row is restored, dropped, or still
//!   resident: `total_stashed == total_restored + total_dropped +
//!   resident_rows`, across random stash/take/drop/demote/stage
//!   sequences;
//! * payload fidelity — hot restores are bit-exact, cold/spill
//!   restores round-trip within the configured quantization bound;
//! * occupancy — per-tier gauges stay consistent with the resident
//!   set, and the cold tier is always smaller than the uncompressed
//!   footprint of the rows it holds;
//! * scheduler equivalence — the eta-indexed thaw scheduler demotes
//!   and stages the exact same row set as a brute-force full-scan
//!   oracle across randomized stash/take/stage/step traces.

use std::collections::HashMap;

use asrkf::config::OffloadConfig;
use asrkf::metrics::TierKind;
use asrkf::offload::{dequantize, quantize, TieredStore};
use asrkf::prop_assert;
use asrkf::util::prop::{prop_check, G};

const RF: usize = 32;

fn random_cfg(g: &mut G) -> OffloadConfig {
    let row_bytes = RF * 4;
    OffloadConfig {
        // budgets from "tiny" (heavy demotion) to "ample"
        hot_budget_bytes: g.usize(1, 64) * row_bytes,
        cold_budget_bytes: g.usize(1, 64) * (RF + 8),
        cold_after_steps: g.usize(0, 12) as u64,
        quantize_cold: g.bool(0.85),
        spill_dir: if g.bool(0.3) {
            Some(
                std::env::temp_dir()
                    .join("asrkf-prop-offload")
                    .to_string_lossy()
                    .into_owned(),
            )
        } else {
            None
        },
        prefetch_ahead: g.usize(0, 4) as u64,
        block_rows: g.usize(1, 16),
        ..OffloadConfig::default()
    }
}

fn random_row(g: &mut G) -> Vec<f32> {
    g.vec_f32(RF, -4.0, 4.0)
}

#[test]
fn prop_conservation_across_random_op_sequences() {
    prop_check(40, |g| {
        let cfg = random_cfg(g);
        let mut store = TieredStore::new(RF, cfg);
        let mut resident: Vec<usize> = Vec::new();
        let mut next_pos = 0usize;
        for step in 0..120u64 {
            match g.usize(0, 9) {
                // stash a new row (weighted heaviest)
                0..=4 => {
                    let eta = step + g.usize(0, 30) as u64;
                    store
                        .stash(next_pos, random_row(g), step, eta)
                        .map_err(|e| format!("stash failed: {e}"))?;
                    resident.push(next_pos);
                    next_pos += 1;
                }
                // restore a random resident row
                5..=6 => {
                    if !resident.is_empty() {
                        let idx = g.usize(0, resident.len() - 1);
                        let pos = resident.swap_remove(idx);
                        let got = store.take(pos).map_err(|e| format!("take: {e}"))?;
                        prop_assert!(got.is_some(), "resident pos {pos} had no payload");
                    }
                }
                // drop a random resident row
                7 => {
                    if !resident.is_empty() {
                        let idx = g.usize(0, resident.len() - 1);
                        store
                            .drop_row(resident.swap_remove(idx))
                            .map_err(|e| format!("drop: {e}"))?;
                    }
                }
                // prefetch staging
                8 => {
                    let horizon = g.usize(0, 16) as u64;
                    store
                        .stage_upcoming(step, horizon, g.usize(0, 8))
                        .map_err(|e| format!("stage: {e}"))?;
                }
                // residency sweep
                _ => store.on_step(step).map_err(|e| format!("on_step: {e}"))?,
            }
            prop_assert!(
                store.total_stashed == store.total_restored + store.total_dropped + store.len() as u64,
                "conservation violated at step {step}: {} != {} + {} + {}",
                store.total_stashed,
                store.total_restored,
                store.total_dropped,
                store.len()
            );
            prop_assert!(
                store.len() == resident.len(),
                "resident mismatch: store {} vs model {}",
                store.len(),
                resident.len()
            );
            let o = store.occupancy();
            prop_assert!(
                o.total_rows() == store.len(),
                "tier rows {} != resident {}",
                o.total_rows(),
                store.len()
            );
        }
        // the store's resident set must be exactly the model's
        let mut store_pos: Vec<usize> = store.positions().collect();
        store_pos.sort_unstable();
        let mut model_pos = resident.clone();
        model_pos.sort_unstable();
        prop_assert!(store_pos == model_pos, "position sets diverged");
        // drain the rest: everything stashed must come back out
        let drained = store.drain_all().map_err(|e| format!("drain: {e}"))?;
        prop_assert!(drained.len() == resident.len(), "drain lost rows");
        prop_assert!(
            store.total_stashed == store.total_restored + store.total_dropped,
            "conservation violated after drain"
        );
        Ok(())
    });
}

#[test]
fn prop_restored_payloads_within_quant_bound() {
    prop_check(40, |g| {
        let cfg = random_cfg(g);
        let bound_rel = cfg.cold_quant_rel_error;
        let lossless = !cfg.quantize_cold;
        let mut store = TieredStore::new(RF, cfg);
        let mut originals: HashMap<usize, Vec<f32>> = HashMap::new();
        for pos in 0..40usize {
            let row = random_row(g);
            let eta = g.usize(0, 40) as u64;
            store
                .stash(pos, row.clone(), 0, eta)
                .map_err(|e| format!("stash: {e}"))?;
            originals.insert(pos, row);
        }
        // random staging churn moves rows across tiers
        store.stage_upcoming(0, g.usize(0, 40) as u64, g.usize(0, 40)).map_err(|e| e.to_string())?;
        store.on_step(g.usize(0, 20) as u64).map_err(|e| e.to_string())?;
        for (pos, orig) in originals {
            let got = store
                .take(pos)
                .map_err(|e| format!("take: {e}"))?
                .ok_or_else(|| format!("pos {pos} lost"))?;
            let lo = orig.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = orig.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let bound = if lossless { 1e-6 } else { bound_rel * (hi - lo) + 1e-5 };
            for (a, b) in orig.iter().zip(&got) {
                prop_assert!(
                    (a - b).abs() <= bound,
                    "pos {pos}: {a} -> {b} exceeds bound {bound}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quantize_roundtrip_bound() {
    prop_check(200, |g| {
        let n = g.usize(1, 256);
        let scale = g.f32(1e-3, 100.0);
        let offset = g.f32(-50.0, 50.0);
        let row: Vec<f32> = (0..n).map(|_| offset + g.f32(-1.0, 1.0) * scale).collect();
        let qr = quantize(&row);
        let back = dequantize(&qr);
        let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        // the configured bound: error <= cold_quant_rel_error * range,
        // plus f32 rounding at the row's magnitude (the affine decode
        // `min + q*scale` rounds at ulp(|min| + range))
        let mag = hi.abs().max(lo.abs());
        let bound = OffloadConfig::default().cold_quant_rel_error * (hi - lo)
            + mag * f32::EPSILON * 8.0
            + 1e-7;
        for (a, b) in row.iter().zip(&back) {
            prop_assert!((a - b).abs() <= bound, "{a} -> {b} (bound {bound}, n {n})");
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Codec-ladder oracles

#[test]
fn prop_codec_rungs_roundtrip_within_declared_bound() {
    use asrkf::offload::codec::{self, CodecId, CodecSet};
    prop_check(120, |g| {
        let set = CodecSet { ebq_rel_error: g.f32(0.005, 0.1) };
        let n = g.usize(1, 200);
        let scale = g.f32(1e-3, 50.0);
        let offset = g.f32(-25.0, 25.0);
        let row: Vec<f32> = (0..n).map(|_| offset + g.f32(-1.0, 1.0) * scale).collect();
        let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let (range, mag) = (hi - lo, hi.abs().max(lo.abs()));
        for id in CodecId::ALL {
            let c = set.codec(id);
            let payload = c.encode(&row);
            prop_assert!(
                payload.codec() == id,
                "codec {} tagged its payload as {}",
                id.as_str(),
                payload.codec().as_str()
            );
            prop_assert!(
                payload.bytes() <= id.max_encoded_bytes(n),
                "codec {}: {} bytes exceeds declared ceiling {}",
                id.as_str(),
                payload.bytes(),
                id.max_encoded_bytes(n)
            );
            // reconstruction within the rung's declared bound (plus
            // f32 rounding at the row magnitude, as in the u8 test)
            let mut dst = vec![0.0f32; n];
            c.decode_into(&payload, &mut dst).map_err(|e| format!("decode: {e}"))?;
            let bound = c.error_bound(range) + mag * f32::EPSILON * 8.0 + 1e-6;
            for (a, b) in row.iter().zip(&dst) {
                prop_assert!(
                    (a - b).abs() <= bound,
                    "codec {}: {a} -> {b} exceeds bound {bound} (n {n})",
                    id.as_str()
                );
            }
            // spill body serialization is exact: `bytes()` matches the
            // emitted body, the body round-trips byte for byte, and
            // the reconstructed payload decodes bit-identically
            let body = codec::payload_to_bytes(&payload);
            prop_assert!(
                body.len() == payload.bytes(),
                "codec {}: body {} bytes != bytes() {}",
                id.as_str(),
                body.len(),
                payload.bytes()
            );
            let back = codec::payload_from_bytes(id, n, &body)
                .map_err(|e| format!("from_bytes: {e}"))?;
            prop_assert!(
                codec::payload_to_bytes(&back) == body,
                "codec {}: serialization round trip not exact",
                id.as_str()
            );
            let mut dst2 = vec![0.0f32; n];
            back.decode_into(&mut dst2);
            prop_assert!(
                dst.iter().zip(&dst2).all(|(a, b)| a.to_bits() == b.to_bits()),
                "codec {}: deserialized payload decodes differently",
                id.as_str()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_u8_ladder_reproduces_legacy_cold_bytes() {
    use asrkf::offload::QuantRow;
    use asrkf::util::TempDir;
    prop_check(25, |g| {
        // The default (u8-only) ladder is an on-disk and in-memory
        // no-op relative to the pre-ladder store: every cold/spilled
        // row holds exactly the bytes direct `quantize` produces, so
        // restores decode to bit-identical floats.
        let spill = g.bool(0.5);
        let dir = TempDir::new("prop-u8-ladder").map_err(|e| e.to_string())?;
        let cfg = OffloadConfig {
            cold_after_steps: 0, // admit everything cold
            cold_budget_bytes: if spill { (RF + 8) * 4 } else { 1 << 24 },
            spill_dir: if spill { Some(dir.path_str()) } else { None },
            ..OffloadConfig::default()
        };
        let mut store = TieredStore::new(RF, cfg);
        let mut shadow: HashMap<usize, QuantRow> = HashMap::new();
        let n = g.usize(8, 40);
        for pos in 0..n {
            let row = random_row(g);
            shadow.insert(pos, quantize(&row));
            store.stash(pos, row, 0, 1_000).map_err(|e| format!("stash: {e}"))?;
        }
        let o = store.occupancy();
        if spill {
            prop_assert!(o.spill_rows > 0, "tiny cold budget pushed nothing to disk");
        } else {
            let want: usize = shadow.values().map(|q| q.bytes()).sum();
            prop_assert!(o.cold_rows == n, "expected all {n} rows cold, got {}", o.cold_rows);
            prop_assert!(
                o.cold_bytes == want,
                "u8 ladder cold bytes {} != legacy quantizer bytes {want}",
                o.cold_bytes
            );
        }
        for (pos, qr) in &shadow {
            let got = store
                .take(*pos)
                .map_err(|e| format!("take: {e}"))?
                .ok_or_else(|| format!("pos {pos} lost"))?;
            let want = dequantize(qr);
            prop_assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "pos {pos}: u8-ladder restore diverged from the legacy quantizer bits"
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Scheduler oracle: a brute-force full-scan mirror of the store's
// residency rules. `TieredStore` answers every per-step question (who
// demotes, who stages) from its eta index; the oracle answers them by
// scanning all rows, the way the store itself used to. Both must place
// every row in the same tier with the same staged flag after every op.

const HOT_ROW_BYTES: usize = RF * 4;
const COLD_ROW_BYTES: usize = RF + 8; // u8 codes + (min, scale) header

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum OTier {
    Hot { staged: bool },
    Cold,
    Spill,
}

struct Oracle {
    hot_budget: usize,
    cold_budget: usize,
    cold_after: u64,
    quantize_cold: bool,
    spill_enabled: bool,
    rows: HashMap<usize, (u64, OTier)>, // pos -> (thaw_eta, tier)
}

impl Oracle {
    fn new(cfg: &OffloadConfig) -> Oracle {
        Oracle {
            hot_budget: cfg.hot_budget_bytes,
            cold_budget: cfg.cold_budget_bytes,
            cold_after: cfg.cold_after_steps,
            quantize_cold: cfg.quantize_cold,
            spill_enabled: cfg.spill_dir.is_some(),
            rows: HashMap::new(),
        }
    }

    fn hot_bytes(&self) -> usize {
        self.rows.values().filter(|(_, t)| matches!(t, OTier::Hot { .. })).count()
            * HOT_ROW_BYTES
    }

    fn cold_bytes(&self) -> usize {
        self.rows.values().filter(|(_, t)| matches!(t, OTier::Cold)).count() * COLD_ROW_BYTES
    }

    fn stash(&mut self, pos: usize, step: u64, eta: u64) {
        let tier = if self.quantize_cold && eta.saturating_sub(step) >= self.cold_after {
            OTier::Cold
        } else {
            OTier::Hot { staged: false }
        };
        self.rows.insert(pos, (eta, tier));
        self.enforce();
    }

    /// Full-scan budget eviction: farthest (eta, pos) demotes first,
    /// staged rows exempt from the hot sweep.
    fn enforce(&mut self) {
        if !self.quantize_cold {
            return;
        }
        while self.hot_bytes() > self.hot_budget {
            let victim = self
                .rows
                .iter()
                .filter(|(_, (_, t))| matches!(t, OTier::Hot { staged: false }))
                .map(|(&p, &(eta, _))| (eta, p))
                .max();
            let Some((_, p)) = victim else { break };
            self.rows.get_mut(&p).unwrap().1 = OTier::Cold;
        }
        if self.spill_enabled {
            while self.cold_bytes() > self.cold_budget {
                let victim = self
                    .rows
                    .iter()
                    .filter(|(_, (_, t))| matches!(t, OTier::Cold))
                    .map(|(&p, &(eta, _))| (eta, p))
                    .max();
                let Some((_, p)) = victim else { break };
                self.rows.get_mut(&p).unwrap().1 = OTier::Spill;
            }
        }
    }

    fn promote(&mut self, pos: usize) -> bool {
        let Some(&(_, tier)) = self.rows.get(&pos) else { return false };
        if matches!(tier, OTier::Hot { .. }) {
            return false;
        }
        if self.hot_bytes() + HOT_ROW_BYTES > self.hot_budget {
            return false;
        }
        self.rows.get_mut(&pos).unwrap().1 = OTier::Hot { staged: true };
        true
    }

    fn stage(&mut self, hints: &[(usize, u64)]) {
        for &(pos, eta) in hints {
            if let Some(e) = self.rows.get_mut(&pos) {
                e.0 = eta;
            }
            self.promote(pos);
        }
    }

    fn stage_upcoming(&mut self, now: u64, horizon: u64, max_rows: usize) {
        let horizon = horizon.min(self.cold_after);
        let limit = now.saturating_add(horizon);
        let mut due: Vec<(u64, usize)> = self
            .rows
            .iter()
            .filter(|(_, (eta, t))| !matches!(t, OTier::Hot { .. }) && *eta <= limit)
            .map(|(&p, &(eta, _))| (eta, p))
            .collect();
        due.sort_unstable();
        for (_, p) in due.into_iter().take(max_rows) {
            self.promote(p);
        }
    }

    fn on_step(&mut self, now: u64) {
        if !self.quantize_cold {
            return;
        }
        let limit = now.saturating_add(self.cold_after);
        let overdue: Vec<usize> = self
            .rows
            .iter()
            .filter(|(_, (eta, t))| matches!(t, OTier::Hot { .. }) && *eta > limit)
            .map(|(&p, _)| p)
            .collect();
        for p in overdue {
            self.rows.get_mut(&p).unwrap().1 = OTier::Cold;
        }
        self.enforce();
    }
}

fn sorted_residents(model: &Oracle) -> Vec<usize> {
    let mut ps: Vec<usize> = model.rows.keys().copied().collect();
    ps.sort_unstable();
    ps
}

#[test]
fn prop_eta_index_matches_full_scan_oracle() {
    prop_check(40, |g| {
        let cfg = random_cfg(g);
        let mut store = TieredStore::new(RF, cfg.clone());
        let mut model = Oracle::new(&cfg);
        let mut next_pos = 0usize;
        for step in 0..150u64 {
            match g.usize(0, 9) {
                // stash a new row (weighted heaviest)
                0..=3 => {
                    let eta = step + g.usize(0, 30) as u64;
                    store
                        .stash(next_pos, random_row(g), step, eta)
                        .map_err(|e| format!("stash: {e}"))?;
                    model.stash(next_pos, step, eta);
                    next_pos += 1;
                }
                // restore a random resident row
                4..=5 => {
                    let ps = sorted_residents(&model);
                    if !ps.is_empty() {
                        let pos = ps[g.usize(0, ps.len() - 1)];
                        let got = store.take(pos).map_err(|e| format!("take: {e}"))?;
                        prop_assert!(got.is_some(), "resident pos {pos} had no payload");
                        model.rows.remove(&pos);
                    }
                }
                // drop a random resident row
                6 => {
                    let ps = sorted_residents(&model);
                    if !ps.is_empty() {
                        let pos = ps[g.usize(0, ps.len() - 1)];
                        store.drop_row(pos).map_err(|e| format!("drop: {e}"))?;
                        model.rows.remove(&pos);
                    }
                }
                // entropy-pressure staging sweep
                7 => {
                    let horizon = g.usize(0, 16) as u64;
                    let max_rows = g.usize(0, 8);
                    store
                        .stage_upcoming(step, horizon, max_rows)
                        .map_err(|e| format!("stage_upcoming: {e}"))?;
                    model.stage_upcoming(step, horizon, max_rows);
                }
                // policy prefetch hints (also refresh thaw predictions)
                8 => {
                    let ps = sorted_residents(&model);
                    let mut hints = Vec::new();
                    for _ in 0..g.usize(0, 3) {
                        if ps.is_empty() {
                            break;
                        }
                        let pos = ps[g.usize(0, ps.len() - 1)];
                        hints.push((pos, step + g.usize(0, 30) as u64));
                    }
                    store.stage(&hints).map_err(|e| format!("stage: {e}"))?;
                    model.stage(&hints);
                }
                // residency sweep
                _ => {
                    store.on_step(step).map_err(|e| format!("on_step: {e}"))?;
                    model.on_step(step);
                }
            }
            // the index-driven store and the full-scan oracle must
            // agree on every row's tier and staged flag
            prop_assert!(
                store.len() == model.rows.len(),
                "resident mismatch at step {step}: store {} vs oracle {}",
                store.len(),
                model.rows.len()
            );
            for (&pos, &(_, tier)) in &model.rows {
                let want = match tier {
                    OTier::Hot { staged } => (TierKind::Hot, staged),
                    OTier::Cold => (TierKind::Cold, false),
                    OTier::Spill => (TierKind::Spill, false),
                };
                let got = store.tier_of(pos);
                prop_assert!(
                    got == Some(want),
                    "step {step} pos {pos}: store placed {got:?}, oracle wants {want:?}"
                );
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Sharding oracle: a ShardedStore must be an invisible storage-layout
// decision. With ample byte budgets, residency is a per-row rule
// (admission horizon, staging, the on_step sweep), so a randomized
// stash/take/stage/step trace must leave every row in the same tier
// with the same staged flag — and the same conservation totals — as a
// single TieredStore holding the combined budget. Budget *eviction* is
// shard-local by design (each shard defends its own slice), so the
// equivalence domain is the eviction-free regime.

#[test]
fn prop_sharded_matches_unsharded_oracle() {
    use asrkf::config::ShardPartition;
    use asrkf::offload::ShardedStore;
    prop_check(12, |g| {
        for &n in &[1usize, 2, 4] {
            for &partition in &[ShardPartition::Hash, ShardPartition::Range] {
                let cfg = OffloadConfig {
                    hot_budget_bytes: 1 << 24,
                    cold_budget_bytes: 1 << 24,
                    cold_after_steps: g.usize(0, 12) as u64,
                    quantize_cold: g.bool(0.8),
                    spill_dir: None,
                    block_rows: g.usize(1, 8),
                    shards: n,
                    shard_partition: partition,
                    ..OffloadConfig::default()
                };
                let mut single_cfg = cfg.clone();
                single_cfg.shards = 1;
                let mut sharded =
                    ShardedStore::new(RF, cfg).map_err(|e| format!("sharded new: {e}"))?;
                let mut single = TieredStore::new(RF, single_cfg);
                let mut resident: Vec<usize> = Vec::new();
                let mut next_pos = 0usize;

                for step in 0..100u64 {
                    match g.usize(0, 9) {
                        // stash a batch of fresh rows (weighted heaviest)
                        0..=3 => {
                            let k = g.usize(1, 4);
                            let mut items: Vec<(usize, Vec<f32>, u64)> = Vec::with_capacity(k);
                            for _ in 0..k {
                                let eta = step + g.usize(0, 30) as u64;
                                items.push((next_pos, random_row(g), eta));
                                resident.push(next_pos);
                                next_pos += 1;
                            }
                            for (pos, row, eta) in &items {
                                single
                                    .stash(*pos, row.clone(), step, *eta)
                                    .map_err(|e| format!("single stash: {e}"))?;
                            }
                            sharded
                                .stash_batch(items, step)
                                .map_err(|e| format!("sharded stash: {e}"))?;
                        }
                        // restore a sorted burst (parallel path on the
                        // sharded side, one take() per row on the oracle)
                        4..=5 => {
                            let mut burst: Vec<usize> =
                                resident.iter().copied().filter(|_| g.bool(0.4)).collect();
                            burst.sort_unstable();
                            if burst.is_empty() {
                                continue;
                            }
                            resident.retain(|p| !burst.contains(p));
                            let got = sharded
                                .take_batch(&burst)
                                .map_err(|e| format!("take_batch: {e}"))?;
                            for (&pos, payload) in burst.iter().zip(got) {
                                let want = single
                                    .take(pos)
                                    .map_err(|e| format!("single take: {e}"))?;
                                prop_assert!(
                                    payload == want,
                                    "restored payload diverged at pos {pos} (n={n}, {partition:?})"
                                );
                            }
                        }
                        // drop a random resident row
                        6 => {
                            if !resident.is_empty() {
                                let pos = resident.swap_remove(g.usize(0, resident.len() - 1));
                                sharded.drop_row(pos).map_err(|e| format!("drop: {e}"))?;
                                single.drop_row(pos).map_err(|e| format!("drop: {e}"))?;
                            }
                        }
                        // prefetch hints (also refresh thaw predictions)
                        7 => {
                            let mut hints = Vec::new();
                            for _ in 0..g.usize(0, 3) {
                                if resident.is_empty() {
                                    break;
                                }
                                let pos = resident[g.usize(0, resident.len() - 1)];
                                hints.push((pos, step + g.usize(0, 30) as u64));
                            }
                            let a = sharded.stage(&hints).map_err(|e| format!("stage: {e}"))?;
                            let b = single.stage(&hints).map_err(|e| format!("stage: {e}"))?;
                            prop_assert!(a == b, "stage promoted {a} vs {b} rows");
                        }
                        // pressure sweep: an uncapped row budget keeps
                        // the per-shard cap split out of the picture
                        8 => {
                            let horizon = g.usize(0, 16) as u64;
                            let a = sharded
                                .stage_upcoming(step, horizon, 10_000)
                                .map_err(|e| format!("stage_upcoming: {e}"))?;
                            let b = single
                                .stage_upcoming(step, horizon, 10_000)
                                .map_err(|e| format!("stage_upcoming: {e}"))?;
                            prop_assert!(a == b, "stage_upcoming promoted {a} vs {b} rows");
                        }
                        // residency sweep
                        _ => {
                            sharded.on_step(step).map_err(|e| format!("on_step: {e}"))?;
                            single.on_step(step).map_err(|e| format!("on_step: {e}"))?;
                        }
                    }

                    prop_assert!(
                        sharded.len() == single.len() && sharded.len() == resident.len(),
                        "resident mismatch at step {step}: sharded {} vs single {} vs model {}",
                        sharded.len(),
                        single.len(),
                        resident.len()
                    );
                    for &pos in &resident {
                        let a = sharded.tier_of(pos);
                        let b = single.tier_of(pos);
                        prop_assert!(
                            a == b,
                            "step {step} pos {pos} (n={n}, {partition:?}): sharded {a:?} vs single {b:?}"
                        );
                    }
                    prop_assert!(
                        sharded.total_stashed() == single.total_stashed
                            && sharded.total_restored() == single.total_restored
                            && sharded.total_dropped() == single.total_dropped,
                        "lifetime counters diverged at step {step}"
                    );
                }

                // conservation on both sides, then drain to empty
                prop_assert!(
                    sharded.total_stashed()
                        == sharded.total_restored() + sharded.total_dropped() + sharded.len() as u64,
                    "sharded conservation violated"
                );
                let mut a = sharded.drain_all().map_err(|e| format!("drain: {e}"))?;
                let mut b = single.drain_all().map_err(|e| format!("drain: {e}"))?;
                a.sort_by_key(|(p, _)| *p);
                b.sort_by_key(|(p, _)| *p);
                prop_assert!(a == b, "drained contents diverged (n={n}, {partition:?})");
                prop_assert!(sharded.is_empty() && single.is_empty(), "drain left residents");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cold_tier_smaller_than_uncompressed() {
    prop_check(30, |g| {
        let mut cfg = random_cfg(g);
        cfg.quantize_cold = true;
        cfg.spill_dir = None;
        cfg.cold_after_steps = 0; // admit everything cold
        let mut store = TieredStore::new(RF, cfg);
        let n = g.usize(4, 64);
        for pos in 0..n {
            store
                .stash(pos, random_row(g), 0, 1_000)
                .map_err(|e| format!("stash: {e}"))?;
        }
        let o = store.occupancy();
        prop_assert!(o.cold_rows > 0, "nothing went cold");
        let cold_uncompressed = o.cold_rows * RF * 4;
        prop_assert!(
            o.cold_bytes < cold_uncompressed,
            "cold tier not compressed: {} >= {}",
            o.cold_bytes,
            cold_uncompressed
        );
        Ok(())
    });
}
