//! Session-level tests without the PJRT runtime: drive `Session`
//! directly with synthetic logits/scores and a host KV buffer, checking
//! the bookkeeping invariants the engine relies on (mask/store/plan/KV
//! consistency, RR rewind, entropy-trigger wiring).

use std::time::Duration;

use asrkf::config::{EngineConfig, FreezeConfig, RecoveryConfig, SamplingConfig};
use asrkf::engine::layout::{gather_row, KvGeom};
use asrkf::engine::Session;
use asrkf::kv::policy::KvPolicy;
use asrkf::recovery::Action;
use asrkf::runtime::{CallTiming, ModelSpec};

const S: usize = 128;
const R: usize = 8;

fn spec() -> ModelSpec {
    ModelSpec {
        vocab: 256,
        d_model: 8,
        n_layers: 2,
        n_heads: 2,
        d_head: 4,
        d_ff: 16,
        max_len: S,
        kv_row_floats: 2 * 2 * 2 * 4,
    }
}

fn cfg() -> EngineConfig {
    EngineConfig {
        freeze: FreezeConfig {
            window_k: 8,
            n_sink: 2,
            tau: 1.0,
            relative_tau: true,
            softness_k: 1.0,
            history_w: 256,
            r_budget: R,
        },
        sampling: SamplingConfig { temperature: 0.7, top_k: 40, top_p: 0.9, seed: 3 },
        ..EngineConfig::default()
    }
}

struct Harness {
    session: Session,
    kv: Vec<f32>,
    geom: KvGeom,
    /// reusable plan buffer, as the engines hold it
    plan: asrkf::kv::Plan,
}

impl Harness {
    fn new(cfg: &EngineConfig, prompt_len: usize, max_new: usize, policy: &str) -> Harness {
        let geom = KvGeom::new(&spec(), 1, S);
        let mut kv = vec![0.0f32; geom.floats()];
        // prefill rows: row at pos p carries marker p+1
        for p in 0..prompt_len {
            for plane in 0..geom.planes() {
                let o = geom.offset(plane, 0, p);
                kv[o..o + geom.hd].fill(p as f32 + 1.0);
            }
        }
        let policy = asrkf::baselines::make_policy(policy, &cfg.freeze).unwrap();
        let tokens: Vec<i32> = (0..prompt_len as i32).map(|i| 65 + (i % 26)).collect();
        let mut session =
            Session::new(1, tokens, max_new, policy, cfg, S, spec().kv_row_floats).unwrap();
        session.seed_prefill(vec![0.0f32; 256], &vec![1.0; prompt_len], prompt_len);
        Harness { session, kv, geom, plan: asrkf::kv::Plan::default() }
    }

    /// Simulate the engine side of one step with synthetic outputs.
    fn step(&mut self, low_score_positions: &[usize], logits: Vec<f32>) -> Action {
        let token = self.session.next_token();
        self.session.apply_plan(&mut self.kv, &self.geom, 0, R, &mut self.plan).unwrap();
        // "graph output": new row with marker len+1
        let pos = self.session.len;
        for plane in 0..self.geom.planes() {
            let o = self.geom.offset(plane, 0, pos);
            self.kv[o..o + self.geom.hd].fill(pos as f32 + 1.0);
        }
        let mut scores = vec![1.0f32; pos + 1];
        for &p in low_score_positions {
            if p < scores.len() {
                scores[p] = 0.001;
            }
        }
        let action = self
            .session
            .absorb(token, logits, &scores, &self.plan, CallTiming::default(), Duration::ZERO)
            .unwrap();
        // land in-flight speculative restores before the tests below
        // inspect store aggregates (the engines settle the same way
        // before reading counters; see ShardedStore::settle)
        self.session.store.settle().unwrap();
        action
    }
}

fn flat_logits() -> Vec<f32> {
    vec![0.1f32; 256]
}

#[test]
fn mask_matches_policy_state_every_step() {
    let cfg = cfg();
    let mut h = Harness::new(&cfg, 24, 60, "asrkf");
    let stale: Vec<usize> = (2..16).collect();
    for _ in 0..60 {
        h.step(&stale, flat_logits());
        for pos in 0..h.session.len {
            let active = !h.session.policy.is_frozen(pos);
            assert_eq!(
                h.session.mask[pos] > 0.5,
                active,
                "mask/policy mismatch at pos {pos} (len {})",
                h.session.len
            );
        }
        for pos in h.session.len..S {
            assert!(h.session.mask[pos] < 0.5);
        }
    }
    assert!(h.session.is_done());
}

#[test]
fn frozen_rows_zeroed_in_kv_and_recoverable_from_store() {
    let cfg = cfg();
    let mut h = Harness::new(&cfg, 24, 50, "asrkf");
    let stale: Vec<usize> = (2..16).collect();
    for _ in 0..50 {
        h.step(&stale, flat_logits());
        for pos in h.session.policy.frozen_positions() {
            // zeroed in the cache ...
            let row = gather_row(&h.kv, &h.geom, 0, pos);
            assert!(row.iter().all(|&v| v == 0.0), "frozen pos {pos} not zeroed");
            // ... and its payload is intact in the store
            assert!(h.session.store.contains(pos));
        }
    }
}

#[test]
fn restored_rows_carry_original_payload() {
    let cfg = cfg();
    let mut h = Harness::new(&cfg, 24, 60, "asrkf");
    let stale: Vec<usize> = (2..16).collect();
    let mut restores_seen = 0;
    for _ in 0..60 {
        h.step(&stale, flat_logits());
        // every ACTIVE position must carry its original marker pos+1
        for pos in 0..h.session.len {
            if !h.session.policy.is_frozen(pos) {
                let row = gather_row(&h.kv, &h.geom, 0, pos);
                assert!(
                    row.iter().all(|&v| v == pos as f32 + 1.0),
                    "active pos {pos} corrupted: {:?}",
                    &row[..4]
                );
            }
        }
        restores_seen += h.session.trace.last().map(|t| t.restored).unwrap_or(0);
    }
    assert!(restores_seen > 0, "no restore ever happened — test ineffective");
}

#[test]
fn store_holds_exactly_frozen_positions() {
    let cfg = cfg();
    let mut h = Harness::new(&cfg, 24, 40, "asrkf");
    let stale: Vec<usize> = (2..16).collect();
    for _ in 0..40 {
        h.step(&stale, flat_logits());
        let frozen = h.session.policy.frozen_positions();
        assert_eq!(h.session.store.len(), frozen.len());
        for &p in &frozen {
            assert!(h.session.store.contains(p), "no payload for frozen pos {p}");
        }
    }
}

#[test]
fn rewind_truncates_and_reactivates() {
    let mut cfg = cfg();
    cfg.recovery = RecoveryConfig { enabled: true, ..RecoveryConfig::default() };
    let mut h = Harness::new(&cfg, 24, 40, "asrkf");
    let stale: Vec<usize> = (2..16).collect();
    for _ in 0..20 {
        h.step(&stale, flat_logits());
    }
    let len_before = h.session.len;
    let gen_before = h.session.generated();
    // emulate the generator's RR path: drain store into kv, then rewind
    for (pos, row) in h.session.store.drain_all().unwrap() {
        asrkf::engine::layout::scatter_row(&mut h.kv, &h.geom, 0, pos, &row);
    }
    h.session.rewind(4);
    assert_eq!(h.session.len, len_before - 4);
    assert_eq!(h.session.generated(), gen_before - 4);
    assert_eq!(h.session.policy.frozen_count(), 0);
    for pos in 0..h.session.len {
        assert!(h.session.mask[pos] > 0.5, "pos {pos} inactive after rewind");
        let row = gather_row(&h.kv, &h.geom, 0, pos);
        assert!(row.iter().all(|&v| v == pos as f32 + 1.0), "pos {pos} data lost");
    }
    let _ = h.session.next_token();
}

#[test]
fn cold_rows_restore_via_staging_never_inline() {
    // Aggressive cold admission: any freeze predicted to last >= 3
    // steps is quantized into the cold tier. The policy's prefetch
    // hints must stage those rows back to hot BEFORE the restoring
    // plan, so no restore ever dequantizes inside the decode step.
    let mut cfg = cfg();
    cfg.offload.cold_after_steps = 3;
    // 6 stale rows < r_budget 8: every imminent thaw fits in the hint list
    let stale: Vec<usize> = (2..8).collect();
    let mut h = Harness::new(&cfg, 24, 250, "asrkf");
    for _ in 0..100 {
        h.step(&stale, flat_logits());
        if h.session.store.staged_hits() > 0 || h.session.is_done() {
            break;
        }
    }
    let sum = h.session.store.summary();
    assert!(sum.demotions_cold > 0, "cold tier never engaged — test ineffective");
    assert!(sum.staged_hits > 0, "no staged restore ever happened");
    assert_eq!(
        sum.restores_cold, 0,
        "a restore paid inline dequantization inside the decode step: {sum:?}"
    );
    assert_eq!(sum.staged_misses, 0);
}

#[test]
fn entropy_spike_triggers_ladder() {
    let mut cfg = cfg();
    cfg.recovery = RecoveryConfig { enabled: true, lambda: 2.0, ..RecoveryConfig::default() };
    let mut h = Harness::new(&cfg, 24, 200, "asrkf");
    let calm = {
        let mut l = vec![0.0f32; 256];
        l[65] = 12.0;
        l
    };
    let mut actions = Vec::new();
    for i in 0..60 {
        let logits = if i > 30 && i % 3 == 0 { vec![0.0f32; 256] } else { calm.clone() };
        let a = h.step(&[], logits);
        if a != Action::None {
            actions.push(a);
        }
    }
    assert!(!actions.is_empty(), "no recovery action despite entropy spikes");
    assert_eq!(actions[0], Action::SoftReset, "ladder must start at SR");
}

#[test]
fn full_kv_session_never_freezes_anything() {
    let cfg = cfg();
    let mut h = Harness::new(&cfg, 24, 30, "full");
    for _ in 0..30 {
        let a = h.step(&(2..16).collect::<Vec<_>>(), flat_logits());
        assert_eq!(a, Action::None);
    }
    assert_eq!(h.session.store.len(), 0);
    assert_eq!(h.session.active_kv(), h.session.len);
}

#[test]
fn sharded_session_matches_unsharded_flow() {
    // identical trace through a 1-shard and a 4-shard session: sharding
    // is a storage-layout decision and must not change tokens, masks,
    // KV contents, or conservation totals
    let mut sharded_cfg = cfg();
    sharded_cfg.offload.shards = 4;
    let stale: Vec<usize> = (2..16).collect();
    let mut a = Harness::new(&cfg(), 24, 60, "asrkf");
    let mut b = Harness::new(&sharded_cfg, 24, 60, "asrkf");
    for _ in 0..60 {
        a.step(&stale, flat_logits());
        b.step(&stale, flat_logits());
    }
    assert_eq!(a.session.tokens, b.session.tokens, "sharding changed sampling");
    assert_eq!(a.session.mask, b.session.mask, "sharding changed the activity mask");
    assert_eq!(a.kv, b.kv, "sharding changed KV contents");
    assert_eq!(a.session.store.len(), b.session.store.len());
    assert_eq!(a.session.store.total_restored(), b.session.store.total_restored());
    let sum = b.session.store.summary();
    assert_eq!(sum.shards, 4);
    if b.session.batch.restore_batch.max() >= 2 {
        assert!(
            sum.restore_parallelism_max > 1,
            "a multi-row restore burst never engaged a second shard: {sum:?}"
        );
    }
}

#[test]
fn h2o_drops_payloads_permanently() {
    let cfg = cfg();
    let mut h = Harness::new(&cfg, 60, 30, "h2o");
    for _ in 0..30 {
        h.step(&[], flat_logits());
    }
    let frozen = h.session.policy.frozen_count();
    assert!(frozen > 0, "h2o should have evicted under budget pressure");
    // payloads were dropped, not stashed
    assert_eq!(h.session.store.len(), 0);
    assert_eq!(h.session.store.total_dropped(), 0); // never stashed at all
    for pos in h.session.policy.frozen_positions() {
        let row = gather_row(&h.kv, &h.geom, 0, pos);
        assert!(row.iter().all(|&v| v == 0.0), "evicted pos {pos} not zeroed");
    }
}
