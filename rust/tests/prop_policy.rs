//! Property-based tests on KV-policy + scheduler invariants (the L3
//! analog of the python hypothesis sweeps). Uses the in-repo
//! `util::prop` harness (proptest is unavailable offline — DESIGN.md §3).

use asrkf::baselines::{H2oPolicy, StreamingLlmPolicy};
use asrkf::config::FreezeConfig;
use asrkf::kv::freeze::freeze_duration;
use asrkf::kv::policy::{AsrKfPolicy, KvPolicy, UnfreezeScope};
use asrkf::prop_assert;
use asrkf::util::prop::{prop_check, G};

fn random_cfg(g: &mut G) -> FreezeConfig {
    FreezeConfig {
        window_k: g.usize(2, 48),
        tau: g.f32(0.2, 1.5),
        softness_k: g.f32(0.5, 4.0),
        history_w: g.usize(16, 512),
        n_sink: g.usize(0, 6),
        r_budget: g.usize(1, 64),
        relative_tau: g.bool(0.5),
    }
}

#[test]
fn prop_asrkf_freeze_restore_disjoint_and_budgeted() {
    prop_check(60, |g| {
        let cfg = random_cfg(g);
        let r = cfg.r_budget;
        let mut p = AsrKfPolicy::new(cfg);
        let start = g.usize(1, 64);
        p.on_prefill(&g.vec_f32(start, 0.0, 1.0), start);
        let mut len = start;
        for step in 1..=80u64 {
            let plan = p.plan(step, len, r);
            prop_assert!(plan.freeze.len() <= r, "freeze budget exceeded: {}", plan.freeze.len());
            prop_assert!(plan.restore.len() <= r, "restore budget exceeded");
            for f in &plan.freeze {
                prop_assert!(!plan.restore.contains(f), "pos {f} frozen and restored in one step");
            }
            prop_assert!(!plan.drop_payload, "asrkf must never drop payloads");
            len += 1;
            let scores = g.vec_f32(len, 0.0, 1.0);
            p.observe(step, &scores, len);
        }
        Ok(())
    });
}

#[test]
fn prop_asrkf_conservation_active_plus_frozen() {
    prop_check(40, |g| {
        let cfg = random_cfg(g);
        let r = cfg.r_budget;
        let mut p = AsrKfPolicy::new(cfg);
        let start = g.usize(4, 32);
        p.on_prefill(&g.vec_f32(start, 0.0, 1.0), start);
        let mut len = start;
        for step in 1..=60u64 {
            p.plan(step, len, r);
            len += 1;
            p.observe(step, &g.vec_f32(len, 0.0, 1.0), len);
            prop_assert!(
                p.active_count() + p.frozen_count() == len,
                "conservation violated at step {step}: {} + {} != {len}",
                p.active_count(),
                p.frozen_count()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_asrkf_sinks_and_window_never_frozen() {
    prop_check(40, |g| {
        let cfg = random_cfg(g);
        let r = cfg.r_budget;
        let n_sink = cfg.n_sink;
        let window_k = cfg.window_k;
        let mut p = AsrKfPolicy::new(cfg);
        let start = g.usize(8, 64);
        p.on_prefill(&g.vec_f32(start, 0.0, 0.01), start);
        let mut len = start;
        for step in 1..=60u64 {
            let plan = p.plan(step, len, r);
            let window_start = len.saturating_sub(window_k);
            for &f in &plan.freeze {
                prop_assert!(f >= n_sink, "sink {f} frozen (n_sink {n_sink})");
                prop_assert!(f < window_start, "window pos {f} frozen (start {window_start})");
            }
            len += 1;
            // adversarially low scores to maximize freeze pressure
            p.observe(step, &g.vec_f32(len, 0.0, 0.01), len);
        }
        Ok(())
    });
}

#[test]
fn prop_full_reset_eventually_restores_everything() {
    prop_check(30, |g| {
        let cfg = random_cfg(g);
        let r = cfg.r_budget.max(4);
        let mut p = AsrKfPolicy::new(cfg);
        let start = g.usize(16, 64);
        p.on_prefill(&g.vec_f32(start, 0.0, 0.01), start);
        let mut len = start;
        for step in 1..=40u64 {
            p.plan(step, len, r);
            len += 1;
            p.observe(step, &g.vec_f32(len, 0.0, 0.01), len);
        }
        p.request_unfreeze(UnfreezeScope::Full);
        // drain restores (budget-capped, so iterate)
        for step in 41..=200u64 {
            let plan = p.plan(step, len, r);
            if plan.restore.is_empty() && p.frozen_count() == 0 {
                break;
            }
        }
        prop_assert!(p.frozen_count() == 0, "still {} frozen after FR drain", p.frozen_count());
        Ok(())
    });
}

#[test]
fn prop_freeze_duration_matches_formula() {
    prop_check(200, |g| {
        let c = g.u32(0, 100_000);
        let k = g.f32(0.25, 8.0);
        let d = freeze_duration(c, k);
        let expected = ((c as f64).sqrt() / k as f64).floor() as u32;
        prop_assert!(d == expected, "c={c} k={k}: got {d}, want {expected}");
        Ok(())
    });
}

#[test]
fn prop_h2o_active_set_bounded_after_drain() {
    prop_check(30, |g| {
        let cfg = random_cfg(g);
        let r = cfg.r_budget.max(8);
        let frac = g.f32(0.2, 0.8);
        let floor = cfg.n_sink + cfg.window_k;
        let mut p = H2oPolicy::with_budget(cfg, frac);
        let len = g.usize(40, 160);
        p.on_prefill(&g.vec_f32(len, 0.0, 1.0), len);
        for step in 1..=100u64 {
            let plan = p.plan(step, len, r);
            prop_assert!(!plan.freeze.iter().any(|f| plan.restore.contains(f)), "overlap");
            prop_assert!(plan.restore.is_empty(), "h2o never restores");
            if plan.freeze.is_empty() {
                break;
            }
        }
        let budget = ((len as f32 * frac) as usize).max(floor);
        prop_assert!(
            p.active_count() <= budget.max(floor),
            "active {} exceeds budget {budget}",
            p.active_count()
        );
        Ok(())
    });
}

#[test]
fn prop_streaming_converges_to_sinks_plus_window() {
    prop_check(30, |g| {
        let cfg = random_cfg(g);
        let r = cfg.r_budget.max(8);
        let n_sink = cfg.n_sink;
        let window_k = cfg.window_k;
        let mut p = StreamingLlmPolicy::new(cfg);
        let len = g.usize(window_k + n_sink + 1, 200);
        p.on_prefill(&g.vec_f32(len, 0.0, 1.0), len);
        for step in 1..=100u64 {
            if p.plan(step, len, r).freeze.is_empty() {
                break;
            }
        }
        prop_assert!(
            p.active_count() == n_sink + window_k,
            "active {} != sinks {n_sink} + window {window_k}",
            p.active_count()
        );
        Ok(())
    });
}
