//! Property-based tests on KV-policy + scheduler invariants (the L3
//! analog of the python hypothesis sweeps). Uses the in-repo
//! `util::prop` harness (proptest is unavailable offline — DESIGN.md §3).

use asrkf::baselines::{H2oPolicy, StreamingLlmPolicy};
use asrkf::config::FreezeConfig;
use asrkf::kv::freeze::freeze_duration;
use asrkf::kv::oracle::ScanAsrKfPolicy;
use asrkf::kv::policy::{AsrKfPolicy, KvPolicy, UnfreezeScope};
use asrkf::prop_assert;
use asrkf::util::prop::{prop_check, G};

fn random_cfg(g: &mut G) -> FreezeConfig {
    FreezeConfig {
        window_k: g.usize(2, 48),
        tau: g.f32(0.2, 1.5),
        softness_k: g.f32(0.5, 4.0),
        history_w: g.usize(16, 512),
        n_sink: g.usize(0, 6),
        r_budget: g.usize(1, 64),
        relative_tau: g.bool(0.5),
    }
}

#[test]
fn prop_asrkf_freeze_restore_disjoint_and_budgeted() {
    prop_check(60, |g| {
        let cfg = random_cfg(g);
        let r = cfg.r_budget;
        let mut p = AsrKfPolicy::new(cfg);
        let start = g.usize(1, 64);
        p.on_prefill(&g.vec_f32(start, 0.0, 1.0), start);
        let mut len = start;
        for step in 1..=80u64 {
            let plan = p.plan(step, len, r);
            prop_assert!(plan.freeze.len() <= r, "freeze budget exceeded: {}", plan.freeze.len());
            prop_assert!(plan.restore.len() <= r, "restore budget exceeded");
            for f in &plan.freeze {
                prop_assert!(!plan.restore.contains(f), "pos {f} frozen and restored in one step");
            }
            prop_assert!(!plan.drop_payload, "asrkf must never drop payloads");
            len += 1;
            let scores = g.vec_f32(len, 0.0, 1.0);
            p.observe(step, &scores, len);
        }
        Ok(())
    });
}

#[test]
fn prop_asrkf_conservation_active_plus_frozen() {
    prop_check(40, |g| {
        let cfg = random_cfg(g);
        let r = cfg.r_budget;
        let mut p = AsrKfPolicy::new(cfg);
        let start = g.usize(4, 32);
        p.on_prefill(&g.vec_f32(start, 0.0, 1.0), start);
        let mut len = start;
        for step in 1..=60u64 {
            p.plan(step, len, r);
            len += 1;
            p.observe(step, &g.vec_f32(len, 0.0, 1.0), len);
            prop_assert!(
                p.active_count() + p.frozen_count() == len,
                "conservation violated at step {step}: {} + {} != {len}",
                p.active_count(),
                p.frozen_count()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_asrkf_sinks_and_window_never_frozen() {
    prop_check(40, |g| {
        let cfg = random_cfg(g);
        let r = cfg.r_budget;
        let n_sink = cfg.n_sink;
        let window_k = cfg.window_k;
        let mut p = AsrKfPolicy::new(cfg);
        let start = g.usize(8, 64);
        p.on_prefill(&g.vec_f32(start, 0.0, 0.01), start);
        let mut len = start;
        for step in 1..=60u64 {
            let plan = p.plan(step, len, r);
            let window_start = len.saturating_sub(window_k);
            for &f in &plan.freeze {
                prop_assert!(f >= n_sink, "sink {f} frozen (n_sink {n_sink})");
                prop_assert!(f < window_start, "window pos {f} frozen (start {window_start})");
            }
            len += 1;
            // adversarially low scores to maximize freeze pressure
            p.observe(step, &g.vec_f32(len, 0.0, 0.01), len);
        }
        Ok(())
    });
}

#[test]
fn prop_full_reset_eventually_restores_everything() {
    prop_check(30, |g| {
        let cfg = random_cfg(g);
        let r = cfg.r_budget.max(4);
        let mut p = AsrKfPolicy::new(cfg);
        let start = g.usize(16, 64);
        p.on_prefill(&g.vec_f32(start, 0.0, 0.01), start);
        let mut len = start;
        for step in 1..=40u64 {
            p.plan(step, len, r);
            len += 1;
            p.observe(step, &g.vec_f32(len, 0.0, 0.01), len);
        }
        p.request_unfreeze(UnfreezeScope::Full);
        // drain restores (budget-capped, so iterate)
        for step in 41..=200u64 {
            let plan = p.plan(step, len, r);
            if plan.restore.is_empty() && p.frozen_count() == 0 {
                break;
            }
        }
        prop_assert!(p.frozen_count() == 0, "still {} frozen after FR drain", p.frozen_count());
        Ok(())
    });
}

/// The tentpole contract of the indexed control plane: the indexed
/// `AsrKfPolicy` (thaw/active/frozen BTree indexes, candidate heap,
/// scratch reuse) is plan-for-plan identical to the retained
/// brute-force full-scan implementation over random score traces —
/// including recovery unfreezes of every scope, RR force-resets, and
/// both tau modes (`random_cfg` randomizes `relative_tau`; the trace
/// exercises whichever mode the case drew, and 80 cases cover both
/// many times over).
#[test]
fn prop_indexed_policy_matches_scan_oracle() {
    prop_check(80, |g| {
        let cfg = random_cfg(g);
        let r = cfg.r_budget;
        let mut indexed = AsrKfPolicy::new(cfg.clone());
        let mut oracle = ScanAsrKfPolicy::new(cfg);
        let start = g.usize(4, 48);
        let prefill = g.vec_f32(start, 0.0, 1.0);
        indexed.on_prefill(&prefill, start);
        oracle.on_prefill(&prefill, start);
        let mut len = start;
        for step in 1..=70u64 {
            // occasional recovery traffic between steps (the engine
            // calls request_unfreeze from absorb)
            if g.bool(0.12) {
                let scope = match g.usize(0, 2) {
                    0 => UnfreezeScope::Soft,
                    1 => UnfreezeScope::Window { n: g.usize(0, 20) as u64, now: step },
                    _ => UnfreezeScope::Full,
                };
                let a = indexed.request_unfreeze(scope);
                let b = oracle.request_unfreeze(scope);
                prop_assert!(a == b, "step {step}: unfreeze({scope:?}) {a} != {b}");
            }
            if g.bool(0.03) {
                indexed.force_all_active();
                oracle.force_all_active();
            }
            let pa = indexed.plan(step, len, r);
            let pb = oracle.plan(step, len, r);
            prop_assert!(
                pa == pb,
                "step {step} (len {len}, r {r}): plans diverge\n indexed: {pa:?}\n  oracle: {pb:?}"
            );
            prop_assert!(
                indexed.active_count() == oracle.active_count(),
                "step {step}: active_count {} != {}",
                indexed.active_count(),
                oracle.active_count()
            );
            prop_assert!(
                indexed.frozen_positions() == oracle.frozen_positions(),
                "step {step}: frozen sets diverge"
            );
            len += 1;
            let scores = g.vec_f32(len, 0.0, 1.0);
            indexed.observe(step, &scores, len);
            oracle.observe(step, &scores, len);
        }
        Ok(())
    });
}

#[test]
fn prop_freeze_duration_matches_formula() {
    prop_check(200, |g| {
        let c = g.u32(0, 100_000);
        let k = g.f32(0.25, 8.0);
        let d = freeze_duration(c, k);
        let expected = ((c as f64).sqrt() / k as f64).floor() as u32;
        prop_assert!(d == expected, "c={c} k={k}: got {d}, want {expected}");
        Ok(())
    });
}

#[test]
fn prop_h2o_active_set_bounded_after_drain() {
    prop_check(30, |g| {
        let cfg = random_cfg(g);
        let r = cfg.r_budget.max(8);
        let frac = g.f32(0.2, 0.8);
        let floor = cfg.n_sink + cfg.window_k;
        let mut p = H2oPolicy::with_budget(cfg, frac);
        let len = g.usize(40, 160);
        p.on_prefill(&g.vec_f32(len, 0.0, 1.0), len);
        for step in 1..=100u64 {
            let plan = p.plan(step, len, r);
            prop_assert!(!plan.freeze.iter().any(|f| plan.restore.contains(f)), "overlap");
            prop_assert!(plan.restore.is_empty(), "h2o never restores");
            if plan.freeze.is_empty() {
                break;
            }
        }
        let budget = ((len as f32 * frac) as usize).max(floor);
        prop_assert!(
            p.active_count() <= budget.max(floor),
            "active {} exceeds budget {budget}",
            p.active_count()
        );
        Ok(())
    });
}

#[test]
fn prop_streaming_converges_to_sinks_plus_window() {
    prop_check(30, |g| {
        let cfg = random_cfg(g);
        let r = cfg.r_budget.max(8);
        let n_sink = cfg.n_sink;
        let window_k = cfg.window_k;
        let mut p = StreamingLlmPolicy::new(cfg);
        let len = g.usize(window_k + n_sink + 1, 200);
        p.on_prefill(&g.vec_f32(len, 0.0, 1.0), len);
        for step in 1..=100u64 {
            if p.plan(step, len, r).freeze.is_empty() {
                break;
            }
        }
        prop_assert!(
            p.active_count() == n_sink + window_k,
            "active {} != sinks {n_sink} + window {window_k}",
            p.active_count()
        );
        Ok(())
    });
}
