//! Telemetry-subsystem integration tests (artifact-free):
//!
//! * sharded snapshot sum — a registry snapshot published across N
//!   shards carries the same counter totals as an unsharded oracle
//!   store driven through the same trace;
//! * flight-recorder reconciliation — the cause taxonomy of the
//!   flight events count-reconciles against the store's conservation
//!   counters (`Freeze`+`Recover` == stashed, `Restore`+`Emergency` ==
//!   restored, `Drop`+`Supersede` == dropped);
//! * Chrome-trace export — `--trace-out` JSON parses back, every
//!   flight event lands on a shard track, and the decode-step segment
//!   spans sum to the segments' accounted time;
//! * stats plane — a `{"stats": true}` request over a real TCP socket
//!   returns the global registry as JSON plus Prometheus text that
//!   `parse_exposition` accepts, and the connection survives errors;
//! * bench CSV schemas — every serving-CSV and load-gen-CSV column's
//!   metric exists in the catalog (CI bench-smoke runs this against
//!   the emitted CSVs).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use asrkf::config::{OffloadConfig, ShardPartition};
use asrkf::metrics::registry::spec_for;
use asrkf::metrics::{
    load_gen_csv_headers, parse_exposition, serving_csv_headers, Registry, StepSegments,
    StepSpan, LOAD_GEN_CSV_COLUMNS, SERVING_CSV_COLUMNS,
};
use asrkf::offload::{ShardedStore, TieredStore};
use asrkf::prop_assert;
use asrkf::util::json::Json;
use asrkf::util::prop::{prop_check, G};
use asrkf::util::TempDir;

const RF: usize = 32;

fn random_row(g: &mut G) -> Vec<f32> {
    g.vec_f32(RF, -4.0, 4.0)
}

/// Eviction-free config: residency is then a per-row rule, so sharded
/// and unsharded stores walk identical tier states and the snapshot
/// totals must agree exactly.
fn ample_cfg(g: &mut G, shards: usize, partition: ShardPartition) -> OffloadConfig {
    OffloadConfig {
        hot_budget_bytes: 1 << 24,
        cold_budget_bytes: 1 << 24,
        cold_after_steps: g.usize(0, 12) as u64,
        quantize_cold: g.bool(0.8),
        spill_dir: None,
        block_rows: g.usize(1, 8),
        shards,
        shard_partition: partition,
        ..OffloadConfig::default()
    }
}

// ---------------------------------------------------------------------------
// Tentpole: snapshot aggregation across shards

#[test]
fn prop_sharded_snapshot_counters_match_unsharded_sum() {
    prop_check(10, |g| {
        for &n in &[1usize, 2, 4] {
            let partition =
                if g.bool(0.5) { ShardPartition::Hash } else { ShardPartition::Range };
            let cfg = ample_cfg(g, n, partition);
            let mut single_cfg = cfg.clone();
            single_cfg.shards = 1;
            let mut sharded =
                ShardedStore::new(RF, cfg).map_err(|e| format!("sharded new: {e}"))?;
            let mut single = TieredStore::new(RF, single_cfg);
            let mut resident: Vec<usize> = Vec::new();
            let mut next_pos = 0usize;

            for step in 0..80u64 {
                match g.usize(0, 9) {
                    // stash a batch of fresh rows (weighted heaviest)
                    0..=3 => {
                        let k = g.usize(1, 4);
                        let mut items: Vec<(usize, Vec<f32>, u64)> = Vec::with_capacity(k);
                        for _ in 0..k {
                            let eta = step + g.usize(0, 30) as u64;
                            items.push((next_pos, random_row(g), eta));
                            resident.push(next_pos);
                            next_pos += 1;
                        }
                        for (pos, row, eta) in &items {
                            single
                                .stash(*pos, row.clone(), step, *eta)
                                .map_err(|e| format!("single stash: {e}"))?;
                        }
                        sharded
                            .stash_batch(items, step)
                            .map_err(|e| format!("sharded stash: {e}"))?;
                    }
                    // restore a sorted burst
                    4..=5 => {
                        let mut burst: Vec<usize> =
                            resident.iter().copied().filter(|_| g.bool(0.4)).collect();
                        burst.sort_unstable();
                        if burst.is_empty() {
                            continue;
                        }
                        resident.retain(|p| !burst.contains(p));
                        sharded.take_batch(&burst).map_err(|e| format!("take_batch: {e}"))?;
                        for pos in burst {
                            single.take(pos).map_err(|e| format!("single take: {e}"))?;
                        }
                    }
                    // drop a random resident row
                    6 => {
                        if !resident.is_empty() {
                            let pos = resident.swap_remove(g.usize(0, resident.len() - 1));
                            sharded.drop_row(pos).map_err(|e| format!("drop: {e}"))?;
                            single.drop_row(pos).map_err(|e| format!("drop: {e}"))?;
                        }
                    }
                    // prefetch staging sweep (uncapped row budget: the
                    // per-shard cap split stays out of the picture)
                    7..=8 => {
                        let horizon = g.usize(0, 16) as u64;
                        sharded
                            .stage_upcoming(step, horizon, 10_000)
                            .map_err(|e| format!("stage_upcoming: {e}"))?;
                        single
                            .stage_upcoming(step, horizon, 10_000)
                            .map_err(|e| format!("stage_upcoming: {e}"))?;
                    }
                    // residency sweep
                    _ => {
                        sharded.on_step(step).map_err(|e| format!("on_step: {e}"))?;
                        single.on_step(step).map_err(|e| format!("on_step: {e}"))?;
                    }
                }
            }

            // the N-shard snapshot's counter totals must equal the
            // unsharded oracle's lifetime counters, summed over the
            // per-shard label sets
            let snap = sharded.snapshot();
            let checks: &[(&str, &[(&str, &str)], u64)] = &[
                ("asrkf_stash_total", &[], single.total_stashed),
                ("asrkf_restore_total", &[], single.total_restored),
                ("asrkf_drop_total", &[], single.total_dropped),
                ("asrkf_staged_total", &[("result", "hit")], single.staged_hits),
                ("asrkf_staged_total", &[("result", "miss")], single.staged_misses),
                ("asrkf_demotion_total", &[("to", "cold")], single.demotions_cold),
                ("asrkf_promotion_total", &[], single.prefetch_promotions),
            ];
            for (name, filter, want) in checks {
                let got = snap.counter_sum(name, filter);
                prop_assert!(
                    got == *want,
                    "{name}{filter:?} diverged (n={n}, {partition:?}): sharded {got} vs single {want}"
                );
            }
            prop_assert!(
                snap.gauge_sum("asrkf_shard_rows", &[]) as usize == single.len(),
                "shard_rows gauges sum {} != resident {}",
                snap.gauge_sum("asrkf_shard_rows", &[]),
                single.len()
            );
            // the flat summary view is derived from the same snapshot
            let summary = sharded.summary();
            prop_assert!(
                summary.staged_hits == single.staged_hits
                    && summary.staged_misses == single.staged_misses
                    && summary.shards == n as u64,
                "OffloadSummary view diverged from snapshot (n={n})"
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Flight recorder: cause taxonomy reconciles with conservation

fn cause_counts(store: &TieredStore) -> std::collections::HashMap<&'static str, u64> {
    let mut counts = std::collections::HashMap::new();
    for ev in store.flight().events() {
        *counts.entry(ev.cause.as_str()).or_insert(0) += 1;
    }
    counts
}

#[test]
fn prop_flight_causes_reconcile_with_conservation_counters() {
    prop_check(25, |g| {
        let cfg = OffloadConfig {
            hot_budget_bytes: g.usize(1, 64) * RF * 4,
            cold_budget_bytes: g.usize(1, 64) * (RF + 8),
            cold_after_steps: g.usize(0, 12) as u64,
            quantize_cold: g.bool(0.85),
            spill_dir: if g.bool(0.3) {
                Some(
                    std::env::temp_dir()
                        .join("asrkf-telemetry-flight")
                        .to_string_lossy()
                        .into_owned(),
                )
            } else {
                None
            },
            block_rows: g.usize(1, 16),
            ..OffloadConfig::default()
        };
        let mut store = TieredStore::new(RF, cfg);
        let mut resident: Vec<usize> = Vec::new();
        let mut next_pos = 0usize;
        for step in 0..100u64 {
            match g.usize(0, 9) {
                0..=4 => {
                    let eta = step + g.usize(0, 30) as u64;
                    store
                        .stash(next_pos, random_row(g), step, eta)
                        .map_err(|e| format!("stash: {e}"))?;
                    resident.push(next_pos);
                    next_pos += 1;
                }
                5..=6 => {
                    if !resident.is_empty() {
                        let pos = resident.swap_remove(g.usize(0, resident.len() - 1));
                        store.take(pos).map_err(|e| format!("take: {e}"))?;
                    }
                }
                7 => {
                    if !resident.is_empty() {
                        store
                            .drop_row(resident.swap_remove(g.usize(0, resident.len() - 1)))
                            .map_err(|e| format!("drop: {e}"))?;
                    }
                }
                8 => {
                    store
                        .stage_upcoming(step, g.usize(0, 16) as u64, g.usize(0, 8))
                        .map_err(|e| format!("stage: {e}"))?;
                }
                _ => store.on_step(step).map_err(|e| format!("on_step: {e}"))?,
            }
        }
        // emergency drain exercises the fourth restore cause
        store.drain_all().map_err(|e| format!("drain: {e}"))?;

        // nothing wrapped (default cap far above this trace), so the
        // retained ring is the complete history
        prop_assert!(
            store.flight().dropped() == 0,
            "{} events evicted below the default cap",
            store.flight().dropped()
        );
        let counts = cause_counts(&store);
        let c = |k: &str| counts.get(k).copied().unwrap_or(0);
        prop_assert!(
            c("freeze") + c("recover") == store.total_stashed,
            "freeze {} + recover {} != stashed {}",
            c("freeze"),
            c("recover"),
            store.total_stashed
        );
        prop_assert!(
            c("restore") + c("emergency") == store.total_restored,
            "restore {} + emergency {} != restored {}",
            c("restore"),
            c("emergency"),
            store.total_restored
        );
        prop_assert!(
            c("drop") + c("supersede") == store.total_dropped,
            "drop {} + supersede {} != dropped {}",
            c("drop"),
            c("supersede"),
            store.total_dropped
        );
        // ordering: seq strictly increasing, timestamps monotone
        let evs: Vec<_> = store.flight().events().collect();
        for w in evs.windows(2) {
            prop_assert!(w[0].seq < w[1].seq, "seq order broken");
            prop_assert!(w[0].ts_us <= w[1].ts_us, "timestamp order broken");
        }
        Ok(())
    });
}

#[test]
fn flight_ring_wraps_through_store_config() {
    let cfg = OffloadConfig {
        hot_budget_bytes: 1 << 24,
        cold_budget_bytes: 1 << 24,
        quantize_cold: false,
        spill_dir: None,
        flight_recorder_cap: 4,
        ..OffloadConfig::default()
    };
    let mut store = TieredStore::new(RF, cfg);
    for pos in 0..10usize {
        store.stash(pos, vec![0.5; RF], 0, 100).unwrap();
    }
    let f = store.flight();
    assert_eq!(f.len(), 4, "ring must retain exactly the configured cap");
    assert_eq!(f.recorded(), 10);
    assert_eq!(f.dropped(), 6, "evictions must be visible, not silent");
    let kept: Vec<usize> = f.events().map(|e| e.pos).collect();
    assert_eq!(kept, vec![6, 7, 8, 9], "oldest events evicted first");
}

// ---------------------------------------------------------------------------
// Chrome trace export reconciles against the store totals

#[test]
fn chrome_trace_reconciles_against_conservation_totals() {
    let cfg = OffloadConfig {
        hot_budget_bytes: 1 << 24,
        cold_budget_bytes: 1 << 24,
        cold_after_steps: 2,
        quantize_cold: true,
        spill_dir: None,
        shards: 2,
        shard_partition: ShardPartition::Hash,
        ..OffloadConfig::default()
    };
    let mut store = ShardedStore::new(RF, cfg).unwrap();
    let items: Vec<(usize, Vec<f32>, u64)> =
        (0..24).map(|pos| (pos, vec![pos as f32; RF], 3 + (pos as u64 % 7))).collect();
    store.stash_batch(items, 0).unwrap();
    store.take_batch(&[0, 1, 2, 3, 8, 9]).unwrap();
    store.drop_row(4).unwrap();
    store.drop_row(5).unwrap();
    store.stage_upcoming(1, 4, 8).unwrap();
    store.on_step(2).unwrap();

    let events = store.flight_events();
    assert!(!events.is_empty());
    assert_eq!(store.flight_dropped(), 0);

    // fabricated decode-step spans (the engine builds these from its
    // per-step trace; the writer must preserve their durations)
    let steps: Vec<StepSpan> = (0..3)
        .map(|i| StepSpan {
            step: i,
            start_us: 1_000 * i,
            plan_us: 100,
            restore_us: 50,
            restore_wait_us: 20,
            freeze_us: 30,
            compute_us: 200,
        })
        .collect();

    let dir = TempDir::new("telemetry-trace").unwrap();
    let path = dir.path().join("trace.json").to_string_lossy().into_owned();
    asrkf::metrics::write_chrome_trace(&path, &events, &steps).unwrap();

    let text = std::fs::read_to_string(&path).unwrap();
    let doc = asrkf::util::json::parse(&text).unwrap();
    let trace = doc.get("traceEvents").as_arr().expect("traceEvents array").clone();

    // every flight event appears exactly once on a shard track
    let shard_instants: Vec<&Json> = trace
        .iter()
        .filter(|e| {
            e.get("ph").as_str() == Some("i")
                && e.get("tid").as_f64().map(|t| t >= 100.0).unwrap_or(false)
        })
        .collect();
    assert_eq!(shard_instants.len(), events.len(), "one shard-track instant per event");

    // cause categories on the shard tracks reconcile with the store's
    // conservation counters (no recover/supersede in this trace)
    let cat = |name: &str| -> u64 {
        shard_instants.iter().filter(|e| e.get("cat").as_str() == Some(name)).count() as u64
    };
    assert_eq!(cat("freeze") + cat("recover"), store.total_stashed());
    assert_eq!(cat("restore") + cat("emergency"), store.total_restored());
    assert_eq!(cat("drop") + cat("supersede"), store.total_dropped());

    // tier tracks carry the same events, keyed by destination tier
    let tier_instants = trace
        .iter()
        .filter(|e| {
            e.get("ph").as_str() == Some("i")
                && e.get("tid").as_f64().map(|t| t < 100.0).unwrap_or(false)
        })
        .count();
    assert_eq!(tier_instants, events.len(), "one tier-track instant per event");

    // the decode-step track preserves every nonzero segment duration
    let spans: Vec<&Json> =
        trace.iter().filter(|e| e.get("ph").as_str() == Some("X")).collect();
    assert_eq!(
        spans.len(),
        5 * steps.len(),
        "plan/restore/restore wait/freeze/compute per step"
    );
    let dur_sum: f64 = spans.iter().filter_map(|e| e.get("dur").as_f64()).sum();
    assert_eq!(dur_sum as u64, 3 * (100 + 50 + 20 + 30 + 200));
    for name in ["plan", "restore", "restore wait", "freeze", "compute"] {
        assert!(
            spans.iter().any(|e| e.get("name").as_str() == Some(name)),
            "missing {name} segment track"
        );
    }

    // the summary view over the same store agrees with the trace
    let summary = store.summary();
    assert_eq!(
        summary.restores_hot + summary.restores_cold + summary.restores_spill,
        store.total_restored(),
        "restore latency histograms must cover every restore"
    );

    // flight events reconcile with Freeze cause == stash total even
    // after re-sorting (merged stream is (ts, seq)-ordered)
    for w in events.windows(2) {
        assert!(
            (w[0].1.ts_us, w[0].1.seq) <= (w[1].1.ts_us, w[1].1.seq),
            "merged flight stream out of order"
        );
    }
}

// ---------------------------------------------------------------------------
// Stats plane: TCP round-trip against the global registry

#[test]
fn stats_request_round_trips_over_tcp() {
    use asrkf::server::protocol::{self, Request};

    // seed the process-global registry under a label value no other
    // test uses, so parallel tests in this binary cannot interfere
    let mut store = TieredStore::new(
        RF,
        OffloadConfig {
            hot_budget_bytes: 1 << 24,
            cold_budget_bytes: 1 << 24,
            quantize_cold: false,
            spill_dir: None,
            ..OffloadConfig::default()
        },
    );
    for pos in 0..9usize {
        store.stash(pos, vec![1.0; RF], 0, 50).unwrap();
    }
    store.take(0).unwrap();
    store.take(1).unwrap();
    store.drop_row(2).unwrap();
    Registry::global().publish(|b| store.publish_flows(b, 7777));

    // a stats-only accept loop wired from the same protocol pieces the
    // real server uses (serve_blocking never returns; generation needs
    // artifacts, so the generate arm answers with an error line)
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let stream = conn.unwrap();
            std::thread::spawn(move || {
                let mut writer = stream.try_clone().unwrap();
                let reader = BufReader::new(stream);
                for line in reader.lines() {
                    let line = line.unwrap();
                    let reply = match protocol::parse_line(&line) {
                        Err(e) => protocol::error_line(&e),
                        Ok(Request::Stats) => {
                            protocol::stats_line(&Registry::global().snapshot())
                        }
                        Ok(Request::Generate(_)) => {
                            protocol::error_line("generation disabled in telemetry test")
                        }
                    };
                    writer.write_all(reply.as_bytes()).unwrap();
                }
            });
        }
    });

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    writer.write_all(b"{\"stats\": true}\n").unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let v = asrkf::util::json::parse(resp.trim()).unwrap();

    // JSON plane: the per-shard counter series carries the exact store totals
    let find = |name: &str| -> Option<f64> {
        v.get("stats").get(name).as_arr().and_then(|arr| {
            arr.iter()
                .find(|e| e.get("labels").get("shard").as_str() == Some("7777"))
                .and_then(|e| e.get("value").as_f64())
        })
    };
    assert_eq!(find("asrkf_stash_total"), Some(store.total_stashed as f64), "{resp}");
    assert_eq!(find("asrkf_restore_total"), Some(store.total_restored as f64));
    assert_eq!(find("asrkf_drop_total"), Some(store.total_dropped as f64));

    // Prometheus plane: embedded text parses and carries the series
    let prom = v.get("prometheus").as_str().expect("prometheus text").to_string();
    let samples = parse_exposition(&prom).expect("prometheus text must parse");
    assert!(samples >= 3, "only {samples} prometheus samples");
    assert!(prom.contains("asrkf_stash_total{shard=\"7777\"}"), "{prom}");

    // a malformed line answers with an error and keeps the connection
    writer.write_all(b"not json\n").unwrap();
    let mut resp2 = String::new();
    reader.read_line(&mut resp2).unwrap();
    assert!(resp2.contains("error"));

    writer.write_all(b"{\"stats\": true}\n").unwrap();
    let mut resp3 = String::new();
    reader.read_line(&mut resp3).unwrap();
    let v3 = asrkf::util::json::parse(resp3.trim()).unwrap();
    assert!(v3.get("stats").get("asrkf_stash_total").as_arr().is_some());
}

// ---------------------------------------------------------------------------
// Bench CSV schema stays anchored to the catalog (run in CI bench-smoke)

#[test]
fn serving_csv_schema_is_catalog_consistent() {
    for col in SERVING_CSV_COLUMNS {
        if !col.metric.is_empty() {
            assert!(
                spec_for(col.metric).is_some(),
                "CSV column {:?} references unknown metric {:?}",
                col.header,
                col.metric
            );
        }
    }
    let headers = serving_csv_headers();
    assert_eq!(headers.len(), SERVING_CSV_COLUMNS.len());
    assert_eq!(headers[0], "Mode");

    // when the bench has produced its CSV (CI bench-smoke runs the
    // bench first), the emitted header row must match the schema
    if let Ok(text) = std::fs::read_to_string("artifacts/serving_throughput.csv") {
        let first = text.lines().next().unwrap_or("");
        assert_eq!(first, headers.join(","), "serving_throughput.csv header drifted");
    }
}

#[test]
fn load_gen_csv_schema_is_catalog_consistent() {
    for col in LOAD_GEN_CSV_COLUMNS {
        if !col.metric.is_empty() {
            assert!(
                spec_for(col.metric).is_some(),
                "CSV column {:?} references unknown metric {:?}",
                col.header,
                col.metric
            );
        }
    }
    let headers = load_gen_csv_headers();
    assert_eq!(headers.len(), LOAD_GEN_CSV_COLUMNS.len());
    assert_eq!(headers[0], "Mode");

    // CI bench-smoke runs benches/load_gen.rs before this test; when
    // its CSV is present the emitted header row must match the schema
    if let Ok(text) = std::fs::read_to_string("artifacts/load_gen.csv") {
        let first = text.lines().next().unwrap_or("");
        assert_eq!(first, headers.join(","), "load_gen.csv header drifted");
    }
}

// ---------------------------------------------------------------------------
// Step-segment accounting

#[test]
fn step_segments_account_for_wall_clock() {
    // segments built by the engine partition the measured wall-clock
    // exactly; the acceptance bound is 5%, exactness is by construction
    let seg = StepSegments {
        steps: 3,
        plan_us: 100,
        restore_us: 50,
        restore_wait_us: 20,
        compute_us: 780,
        freeze_us: 50,
        wall_us: 1000,
    };
    assert_eq!(seg.accounted_us(), 1000);
    assert!((seg.coverage() - 1.0).abs() < f64::EPSILON);

    // a lossy attribution still clears the acceptance threshold check
    let lossy = StepSegments { wall_us: 1040, ..seg };
    assert!(lossy.coverage() >= 0.95, "coverage {}", lossy.coverage());

    // zero measured wall-clock counts as fully covered (no div-by-zero)
    let empty = StepSegments::default();
    assert_eq!(empty.accounted_us(), 0);
    assert!((empty.coverage() - 1.0).abs() < f64::EPSILON);
}
