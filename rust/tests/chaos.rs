//! Chaos property test: a fault-armed `ShardedStore` driven through
//! random op traces against a fault-free oracle.
//!
//! The contract under injected faults (I/O errors, torn writes, worker
//! panics, delayed replies) is **containment**, not perfection:
//!
//! * surviving payloads are correct — bit-exact against the oracle
//!   when the trace drew no faults, within the error bound of the
//!   worst armed codec rung always (traces randomly arm the full
//!   compression ladder, and a rebuild re-stashes recovered rows, so
//!   a row may legally cross a codec one extra time);
//! * every row is accounted for — the conservation identity holds
//!   after every op, extended by the declared-lost set:
//!   `stashed == restored + dropped + rows_lost + resident`;
//! * losses are declared, never silent — a position disappears only by
//!   appearing in `lost_rows()` / `Error::RowsLost`, and the loss is
//!   sticky until a caller acknowledges it (re-stash or drop);
//! * the store stays usable — after any fault trace, fresh rows stash
//!   and restore normally.
//!
//! A separate case proves the fault layer is inert when disabled: an
//! armed-but-zero-rate injector must be bit-identical to no injector.

use std::collections::{BTreeSet, HashMap};

use asrkf::config::OffloadConfig;
use asrkf::error::Error;
use asrkf::offload::{CodecLadder, ShardedStore};
use asrkf::prop_assert;
use asrkf::util::prop::{prop_check, G};
use asrkf::util::TempDir;

const RF: usize = 32;

fn random_row(g: &mut G) -> Vec<f32> {
    g.vec_f32(RF, -4.0, 4.0)
}

/// Tiny tier budgets so demotion and spill I/O (the fault surface) run
/// constantly; persistent spill so a panicked shard has something to
/// rebuild from. Half the traces arm the full compression ladder with
/// thresholds small enough that trace etas (distance <= 20 steps) land
/// rows on every rung, so faults interleave with sub-byte payloads.
fn chaos_cfg(g: &mut G, dir: &str, fault_seed: Option<u64>) -> OffloadConfig {
    let codec_ladder = if g.bool(0.5) {
        CodecLadder::parse("0:u8,6:u4,14:ebq").expect("chaos ladder spec")
    } else {
        CodecLadder::default()
    };
    OffloadConfig {
        codec_ladder,
        hot_budget_bytes: g.usize(2, 8) * RF * 4,
        cold_budget_bytes: g.usize(0, 4) * (RF + 8),
        cold_after_steps: g.usize(0, 4) as u64,
        quantize_cold: true,
        spill_dir: Some(dir.to_owned()),
        spill_persist: true,
        block_rows: g.usize(1, 8),
        shards: g.usize(1, 3),
        fault_seed,
        fault_io_rate: 0.08,
        fault_torn_rate: 0.04,
        fault_panic_rate: 0.015,
        fault_delay_rate: 0.05,
        fault_delay_us: 50,
        io_retry_attempts: 3,
        io_retry_backoff_us: 10,
        io_retry_deadline_ms: 100,
        ..OffloadConfig::default()
    }
}

/// Quantization-bound payload check. `hops` is how many times the row
/// may have crossed the quantizer (2 after a rebuild re-stash).
fn within_bound(orig: &[f32], got: &[f32], rel: f32, hops: f32) -> bool {
    let lo = orig.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = orig.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let bound = hops * rel * (hi - lo) + 1e-4;
    orig.iter().zip(got).all(|(a, b)| (a - b).abs() <= bound)
}

#[test]
fn prop_chaos_traces_contain_faults_and_conserve_rows() {
    prop_check(8, |g| {
        let tmp = TempDir::new("chaos").map_err(|e| format!("tempdir: {e}"))?;
        let f_dir = tmp.path().join("faulty");
        let o_dir = tmp.path().join("oracle");
        let seed = g.usize(0, u32::MAX as usize) as u64;
        let cfg = chaos_cfg(g, &f_dir.to_string_lossy(), Some(seed));
        let mut oracle_cfg = cfg.clone();
        oracle_cfg.spill_dir = Some(o_dir.to_string_lossy().into_owned());
        oracle_cfg.fault_seed = None;
        // A surviving payload may have ridden any armed rung depending
        // on its thaw distance, so containment uses the worst rung's
        // relative bound.
        let rel = cfg
            .codec_ladder
            .rungs()
            .iter()
            .map(|&(_, id)| id.rel_error_bound(cfg.cold_quant_rel_error, cfg.ebq_rel_error))
            .fold(cfg.cold_quant_rel_error, f32::max);

        let mut faulty =
            ShardedStore::new(RF, cfg).map_err(|e| format!("faulty new: {e}"))?;
        let mut oracle =
            ShardedStore::new(RF, oracle_cfg).map_err(|e| format!("oracle new: {e}"))?;

        // membership model: `tracked` rows are known resident on both
        // sides; `uncertain` rows rode an errored burst (consumed or
        // not — the burst semantics discard mid-burst siblings);
        // `lost_model` mirrors the store's declared-lost set.
        let mut tracked: BTreeSet<usize> = BTreeSet::new();
        let mut uncertain: BTreeSet<usize> = BTreeSet::new();
        let mut lost_model: BTreeSet<usize> = BTreeSet::new();
        let mut originals: HashMap<usize, Vec<f32>> = HashMap::new();
        let mut next_pos = 0usize;

        for step in 0..90u64 {
            match g.usize(0, 9) {
                // stash a fresh batch (weighted heaviest)
                0..=3 => {
                    let k = g.usize(1, 4);
                    let mut items = Vec::with_capacity(k);
                    for _ in 0..k {
                        let eta = step + g.usize(0, 20) as u64;
                        let row = random_row(g);
                        originals.insert(next_pos, row.clone());
                        items.push((next_pos, row, eta));
                        next_pos += 1;
                    }
                    for (pos, row, eta) in &items {
                        oracle
                            .stash(*pos, row.clone(), step, *eta)
                            .map_err(|e| format!("oracle stash: {e}"))?;
                    }
                    let batch: Vec<usize> = items.iter().map(|it| it.0).collect();
                    match faulty.stash_batch(items, step) {
                        Ok(()) => tracked.extend(batch),
                        // partial failure: per-shard slices may or may
                        // not have landed
                        Err(_) => uncertain.extend(batch),
                    }
                }
                // restore a burst of tracked rows
                4..=5 => {
                    let burst: Vec<usize> =
                        tracked.iter().copied().filter(|_| g.bool(0.3)).collect();
                    if burst.is_empty() {
                        continue;
                    }
                    match faulty.take_batch(&burst) {
                        Ok(got) => {
                            for (&pos, payload) in burst.iter().zip(&got) {
                                tracked.remove(&pos);
                                let p = payload.as_ref().ok_or_else(|| {
                                    format!("tracked pos {pos} silently missing")
                                })?;
                                let want = oracle
                                    .take(pos)
                                    .map_err(|e| format!("oracle take: {e}"))?
                                    .ok_or_else(|| format!("oracle lost pos {pos}"))?;
                                prop_assert!(
                                    p == &want
                                        || within_bound(&originals[&pos], p, rel, 2.0),
                                    "pos {pos}: surviving payload out of bound"
                                );
                            }
                        }
                        Err(Error::RowsLost(ps)) => {
                            // typed loss: every named row was in play
                            for p in ps {
                                prop_assert!(
                                    tracked.remove(&p)
                                        || uncertain.remove(&p)
                                        || lost_model.contains(&p),
                                    "RowsLost named unknown pos {p}"
                                );
                                lost_model.insert(p);
                            }
                            // siblings were not consumed; still tracked
                        }
                        Err(_) => {
                            // mid-burst failure: earlier takes consumed
                            // and discarded their rows
                            for p in burst {
                                tracked.remove(&p);
                                uncertain.insert(p);
                            }
                        }
                    }
                }
                // drop one tracked row
                6 => {
                    if let Some(&pos) = tracked.iter().next() {
                        match faulty.drop_row(pos) {
                            Ok(()) => {
                                tracked.remove(&pos);
                                oracle.drop_row(pos).map_err(|e| format!("oracle drop: {e}"))?;
                            }
                            Err(_) => {
                                tracked.remove(&pos);
                                uncertain.insert(pos);
                            }
                        }
                    }
                }
                // staging churn (promotion faults are transient; no
                // membership change either way)
                7 => {
                    let _ = faulty.stage_upcoming(step, g.usize(0, 8) as u64, g.usize(0, 8));
                    let _ = oracle.stage_upcoming(step, g.usize(0, 8) as u64, g.usize(0, 8));
                }
                // residency sweep
                _ => {
                    let _ = faulty.on_step(step);
                    oracle.on_step(step).map_err(|e| format!("oracle on_step: {e}"))?;
                }
            }

            // losses declared by a mid-op rebuild surface here even
            // when the op's own error was untyped
            for p in faulty.lost_rows() {
                if !lost_model.contains(&p) {
                    prop_assert!(
                        tracked.remove(&p) || uncertain.remove(&p),
                        "store declared unknown pos {p} lost"
                    );
                    lost_model.insert(p);
                }
            }
            // conservation, extended by the declared-lost set
            prop_assert!(
                faulty.total_stashed()
                    == faulty.total_restored()
                        + faulty.total_dropped()
                        + faulty.rows_lost_total()
                        + faulty.len() as u64,
                "conservation violated at step {step}: {} != {} + {} + {} + {}",
                faulty.total_stashed(),
                faulty.total_restored(),
                faulty.total_dropped(),
                faulty.rows_lost_total(),
                faulty.len()
            );
        }

        // --- final sweep: every in-play row survives or is declared ---
        let no_faults = faulty.summary().faults_injected == 0;
        for &pos in tracked.iter().chain(uncertain.iter()) {
            let was_tracked = tracked.contains(&pos);
            match faulty.take(pos) {
                Ok(Some(p)) => {
                    let want =
                        oracle.take(pos).map_err(|e| format!("oracle take: {e}"))?;
                    if no_faults {
                        prop_assert!(
                            Some(&p) == want.as_ref(),
                            "pos {pos}: armed-but-silent injector changed bits"
                        );
                    }
                    prop_assert!(
                        Some(&p) == want.as_ref()
                            || within_bound(&originals[&pos], &p, rel, 2.0),
                        "pos {pos}: surviving payload out of bound at sweep"
                    );
                }
                Ok(None) => {
                    prop_assert!(
                        !was_tracked,
                        "tracked pos {pos} vanished without a declared loss"
                    );
                }
                Err(Error::RowsLost(ps)) => {
                    prop_assert!(ps.contains(&pos), "RowsLost missed pos {pos}");
                    lost_model.insert(pos);
                }
                // a transient injected read fault at sweep time: the
                // row is still resident, just unreadable this instant
                Err(_) => {}
            }
        }
        // --- declared losses are sticky until acknowledged ---
        if let Some(&pos) = lost_model.iter().next() {
            if faulty.lost_rows().contains(&pos) {
                prop_assert!(
                    matches!(faulty.take(pos), Err(Error::RowsLost(_))),
                    "lost pos {pos} must stay typed-fatal until acknowledged"
                );
                faulty.drop_row(pos).map_err(|e| format!("ack drop: {e}"))?;
                prop_assert!(
                    !faulty.lost_rows().contains(&pos),
                    "drop must acknowledge the loss of pos {pos}"
                );
            }
        }
        // --- the store stays usable after any fault trace ---
        let base = next_pos;
        for i in 0..8usize {
            let row: Vec<f32> = (0..RF).map(|j| (i * RF + j) as f32 * 0.01).collect();
            faulty
                .stash(base + i, row, 1_000, 1_000 + i as u64)
                .map_err(|e| format!("post-trace stash: {e}"))?;
        }
        for i in 0..8usize {
            let got = faulty
                .take(base + i)
                .map_err(|e| format!("post-trace take: {e}"))?
                .ok_or_else(|| format!("post-trace row {i} missing"))?;
            let want: Vec<f32> = (0..RF).map(|j| (i * RF + j) as f32 * 0.01).collect();
            prop_assert!(
                within_bound(&want, &got, rel, 1.0),
                "post-trace row {i} corrupted"
            );
        }
        prop_assert!(
            faulty.total_stashed()
                == faulty.total_restored()
                    + faulty.total_dropped()
                    + faulty.rows_lost_total()
                    + faulty.len() as u64,
            "conservation violated after the post-trace probe"
        );
        Ok(())
    });
}

#[test]
fn prop_disabled_fault_layer_is_inert() {
    // An armed injector whose every rate is zero must be bit-identical
    // to no injector at all: same payloads, same counters, zero faults
    // and retries recorded. This is the "provably inert when off"
    // guarantee the config default relies on.
    prop_check(6, |g| {
        let tmp = TempDir::new("chaos-inert").map_err(|e| format!("tempdir: {e}"))?;
        let a_dir = tmp.path().join("armed");
        let b_dir = tmp.path().join("bare");
        let mut armed_cfg = chaos_cfg(g, &a_dir.to_string_lossy(), Some(7));
        armed_cfg.fault_io_rate = 0.0;
        armed_cfg.fault_torn_rate = 0.0;
        armed_cfg.fault_panic_rate = 0.0;
        armed_cfg.fault_delay_rate = 0.0;
        let mut bare_cfg = armed_cfg.clone();
        bare_cfg.spill_dir = Some(b_dir.to_string_lossy().into_owned());
        bare_cfg.fault_seed = None;

        let mut armed = ShardedStore::new(RF, armed_cfg).map_err(|e| format!("new: {e}"))?;
        let mut bare = ShardedStore::new(RF, bare_cfg).map_err(|e| format!("new: {e}"))?;
        let mut resident: Vec<usize> = Vec::new();
        let mut next_pos = 0usize;
        for step in 0..80u64 {
            match g.usize(0, 7) {
                0..=3 => {
                    let eta = step + g.usize(0, 20) as u64;
                    let row = random_row(g);
                    armed
                        .stash(next_pos, row.clone(), step, eta)
                        .map_err(|e| format!("armed stash: {e}"))?;
                    bare.stash(next_pos, row, step, eta).map_err(|e| format!("bare stash: {e}"))?;
                    resident.push(next_pos);
                    next_pos += 1;
                }
                4..=5 => {
                    if !resident.is_empty() {
                        let pos = resident.swap_remove(g.usize(0, resident.len() - 1));
                        let a = armed.take(pos).map_err(|e| format!("armed take: {e}"))?;
                        let b = bare.take(pos).map_err(|e| format!("bare take: {e}"))?;
                        prop_assert!(a == b, "pos {pos}: zero-rate injector changed bits");
                    }
                }
                6 => {
                    if !resident.is_empty() {
                        let pos = resident.swap_remove(g.usize(0, resident.len() - 1));
                        armed.drop_row(pos).map_err(|e| format!("drop: {e}"))?;
                        bare.drop_row(pos).map_err(|e| format!("drop: {e}"))?;
                    }
                }
                _ => {
                    armed.on_step(step).map_err(|e| format!("on_step: {e}"))?;
                    bare.on_step(step).map_err(|e| format!("on_step: {e}"))?;
                }
            }
        }
        let sa = armed.summary();
        let sb = bare.summary();
        prop_assert!(sa.faults_injected == 0, "zero-rate injector fired");
        prop_assert!(sa.io_retries == sb.io_retries, "retry counts diverged");
        prop_assert!(
            armed.total_stashed() == bare.total_stashed()
                && armed.total_restored() == bare.total_restored()
                && armed.total_dropped() == bare.total_dropped()
                && armed.rows_lost_total() == 0
                && armed.shard_rebuilds() == 0,
            "armed-but-silent store diverged from bare store"
        );
        let mut a = armed.drain_all().map_err(|e| format!("drain: {e}"))?;
        let mut b = bare.drain_all().map_err(|e| format!("drain: {e}"))?;
        a.sort_by_key(|(p, _)| *p);
        b.sort_by_key(|(p, _)| *p);
        prop_assert!(a == b, "drained contents diverged");
        Ok(())
    });
}
