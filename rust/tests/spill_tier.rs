//! Spill-tier integration tests, CI-runnable without artifacts: the
//! file-backed tier runs under a throwaway `util::TempDir` (the
//! `--spill-dir` configuration path), so `SpillFile` I/O and the
//! stale-handle `Error::Offload` paths are exercised on every CI run —
//! not just the in-memory hot/cold tiers.

use asrkf::config::{OffloadConfig, ShardPartition};
use asrkf::error::Error;
use asrkf::metrics::TierKind;
use asrkf::offload::{quantize, RowPayload, ShardedStore, SpillFile, SpillTier, Tier, TieredStore};
use asrkf::util::TempDir;

const RF: usize = 16;

fn row(v: f32) -> Vec<f32> {
    (0..RF).map(|i| v + i as f32 * 0.01).collect()
}

/// Everything-cold-must-spill configuration pointing at `dir`.
fn spill_cfg(dir: &TempDir) -> OffloadConfig {
    OffloadConfig {
        hot_budget_bytes: 1 << 20,
        cold_budget_bytes: 1, // any cold row overflows straight to disk
        cold_after_steps: 4,
        spill_dir: Some(dir.path_str()),
        block_rows: 4,
        ..OffloadConfig::default()
    }
}

#[test]
fn tiered_store_spills_to_tempdir_and_restores() {
    let dir = TempDir::new("spill-ci").unwrap();
    let mut store = TieredStore::new(RF, spill_cfg(&dir));
    for p in 0..6 {
        // eta far beyond cold_after: straight to cold, then spilled
        store.stash(p, row(p as f32), 0, 100).unwrap();
    }
    let o = store.occupancy();
    assert_eq!(o.spill_rows, 6, "cold budget of 1 byte must spill everything");
    assert!(o.spill_bytes > 0);
    let spill_files = std::fs::read_dir(dir.path()).unwrap().count();
    assert_eq!(spill_files, 1, "one lazily-created spill file expected");

    // restores cross the disk boundary within the quantization bound
    for p in 0..6 {
        let back = store.take(p).unwrap().unwrap();
        let orig = row(p as f32);
        let range = 0.01 * (RF - 1) as f32;
        let bound = store.config().cold_quant_rel_error * range + 1e-5;
        for (a, b) in orig.iter().zip(&back) {
            assert!((a - b).abs() <= bound, "pos {p}: {a} -> {b}");
        }
    }
    assert_eq!(store.occupancy().spill_bytes, 0);
    assert_eq!(store.summary().restores_spill, 6);
}

#[test]
fn sharded_store_spill_io_runs_on_worker_threads() {
    // every shard lazily creates its own spill file inside the TempDir,
    // and the batched take crosses file I/O on the worker pool
    let dir = TempDir::new("spill-sharded").unwrap();
    let mut cfg = spill_cfg(&dir);
    cfg.shards = 4;
    cfg.shard_partition = ShardPartition::Hash;
    let mut store = ShardedStore::new(RF, cfg).unwrap();
    let positions: Vec<usize> = (0..12).collect();
    let items: Vec<(usize, Vec<f32>, u64)> =
        positions.iter().map(|&p| (p, row(p as f32), 100)).collect();
    store.stash_batch(items, 0).unwrap();
    assert_eq!(store.summary().occupancy.spill_rows, 12);
    assert_eq!(
        std::fs::read_dir(dir.path()).unwrap().count(),
        4,
        "one spill file per engaged shard"
    );
    for &p in &positions {
        assert_eq!(store.tier_of(p), Some((TierKind::Spill, false)));
    }
    let got = store.take_batch(&positions).unwrap();
    assert!(got.iter().all(Option::is_some));
    assert!(store.restore_parallelism.max() > 1, "spill restores must fan out");
    assert_eq!(store.summary().restores_spill, 12);
    assert!(store.is_empty());
}

#[test]
fn stale_spill_handles_surface_offload_errors() {
    let dir = TempDir::new("spill-stale").unwrap();
    let mut f = SpillFile::create(&dir.path_str(), RF).unwrap();
    let qr = quantize(&row(1.0));
    let slot = f.write_row(7, &qr).unwrap();
    f.free_slot(slot, 7).unwrap();
    // double free and freed-slot reads are hard errors, not silent
    // free-list corruption
    assert!(f.free_slot(slot, 7).is_err());
    assert!(f.read_row(slot, 7).is_err());
    assert!(f.take_row(slot, 7).is_err());
    assert!(f.free_slot(99, 7).is_err(), "never-allocated handle must error");
}

#[test]
fn persistent_spill_fresh_attach_reclaims_instead_of_failing() {
    // a restarted process re-attaches to the same directory: no
    // create_new collision, and the dead life's records are reclaimed
    // (this store does not resume them — see tests/spill_recovery.rs
    // for the resume path)
    let dir = TempDir::new("spill-fresh-attach").unwrap();
    let mut cfg = spill_cfg(&dir);
    cfg.spill_persist = true;
    {
        let mut store = ShardedStore::new(RF, cfg.clone()).unwrap();
        store.stash(0, row(0.0), 0, 100).unwrap();
        store.stash(1, row(1.0), 0, 100).unwrap();
        assert_eq!(store.summary().occupancy.spill_rows, 2);
        // ungraceful drop: the record file survives
    }
    let store = ShardedStore::new(RF, cfg).unwrap();
    assert!(store.is_empty(), "fresh attach must not resurrect leftovers");
    let sum = store.summary();
    assert_eq!(sum.recovered_rows, 0);
    assert_eq!(sum.recovery_errors, 0, "intact leftovers reclaim cleanly");
}

#[test]
fn disabled_spill_tier_reports_offload_error_on_stash() {
    let mut t = SpillTier::new(None, RF);
    let err = t.stash(0, RowPayload::Raw(row(0.0))).unwrap_err();
    assert!(
        matches!(err, Error::Offload(_)),
        "spill without a dir must be Error::Offload, got {err:?}"
    );
}

#[test]
fn tempdir_cleanup_removes_spill_files() {
    let kept;
    {
        let dir = TempDir::new("spill-drop").unwrap();
        kept = dir.path().to_path_buf();
        let mut store = TieredStore::new(RF, spill_cfg(&dir));
        store.stash(0, row(0.0), 0, 100).unwrap();
        assert_eq!(std::fs::read_dir(&kept).unwrap().count(), 1);
        drop(store); // store removes its spill file first
        assert_eq!(std::fs::read_dir(&kept).unwrap().count(), 0);
    }
    assert!(!kept.exists(), "TempDir must clean up after the store");
}
