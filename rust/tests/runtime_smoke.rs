//! Integration smoke: load real artifacts, run prefill + decode steps,
//! and check the numerics are sane. Requires `make artifacts`.

use asrkf::engine::layout::{insert_prefill, write_new_row, zero_row, KvGeom};
use asrkf::model::tokenizer;
use asrkf::runtime::{DecodeInputs, Runtime};

#[test]
fn prefill_and_decode_roundtrip() {
    let rt = Runtime::load("artifacts").expect("run `make artifacts` first");
    let m = rt.manifest.model.clone();

    // --- prefill a short prompt
    let prompt = "the scheduler freezes the key value pairs. ";
    let toks = tokenizer::encode(prompt);
    let prefill = rt.prefill_for(toks.len()).unwrap();
    let l = prefill.len;
    let mut padded = toks.clone();
    padded.resize(l, 32);
    let out = prefill.run(&padded, &[toks.len() as i32]).unwrap();

    assert_eq!(out.logits_last.len(), m.vocab);
    assert!(out.logits_last.iter().all(|v| v.is_finite()));
    assert_eq!(out.kv.len(), m.n_layers * 2 * l * m.n_heads * m.d_head);
    assert_eq!(out.scores_last.len(), l);
    assert!(out.scores_last[..toks.len()].iter().all(|&s| s >= 0.0));
    assert!(out.scores_last[toks.len()..].iter().all(|&s| s == 0.0));

    // --- move prefill KV into the decode cache layout
    let decode = rt.decode_for(1, toks.len() + 8).unwrap();
    let s = decode.kv_len;
    let geom = KvGeom::new(&m, 1, s);
    let mut kv = vec![0.0f32; geom.floats()];
    insert_prefill(&mut kv, &geom, 0, &out.kv, l, toks.len());
    let mut mask = vec![0.0f32; s];
    for i in 0..toks.len() {
        mask[i] = 1.0;
    }

    // --- greedy-decode a few tokens (engine writes the rows itself)
    let mut logits = out.logits_last.clone();
    let mut len = toks.len();
    let mut generated = Vec::new();
    for _ in 0..8 {
        let next = asrkf::model::logits::argmax(&logits) as i32;
        generated.push(next);
        let o = decode
            .run(&DecodeInputs { tokens: &[next], kv: &kv, mask: &mask, pos: &[len as i32] })
            .unwrap();
        assert_eq!(o.logits.len(), m.vocab);
        assert!(o.logits.iter().all(|v| v.is_finite()), "non-finite logits");
        assert_eq!(o.k_new.len(), m.n_layers * m.n_heads * m.d_head);
        assert_eq!(o.scores.len(), s);
        write_new_row(&mut kv, &geom, 0, len, &o.k_new, &o.v_new);
        mask[len] = 1.0;
        len += 1;
        logits = o.logits;
    }
    let text = tokenizer::decode(&generated);
    println!("generated: {text:?}");
    assert!(
        generated.iter().all(|&t| (9..=126).contains(&t)),
        "unexpected bytes: {generated:?}"
    );
}

#[test]
fn frozen_rows_do_not_affect_decode() {
    // freezing = host-side zero + mask 0. The graph must be invariant
    // to the CONTENT of masked rows (they're excluded from attention).
    let rt = Runtime::load("artifacts").expect("run `make artifacts` first");
    let m = rt.manifest.model.clone();
    let decode = rt.decode_for(1, 64).unwrap();
    let s = decode.kv_len;
    let geom = KvGeom::new(&m, 1, s);

    // synthetic cache: 40 live rows of pseudo-random values
    let mut rng = asrkf::util::rng::Pcg64::new(11);
    let len = 40usize;
    let mut kv = vec![0.0f32; geom.floats()];
    for p in 0..geom.planes() {
        for pos in 0..len {
            let o = geom.offset(p, 0, pos);
            for x in 0..geom.hd {
                kv[o + x] = rng.f32() - 0.5;
            }
        }
    }
    let mut mask = vec![0.0f32; s];
    for i in 0..len {
        mask[i] = 1.0;
    }

    // baseline: rows 5 and 9 masked out, content untouched
    let mut mask_frozen = mask.clone();
    mask_frozen[5] = 0.0;
    mask_frozen[9] = 0.0;
    let inp = |kv: &[f32], mask: &[f32]| -> asrkf::runtime::DecodeOutputs {
        decode
            .run(&DecodeInputs { tokens: &[65], kv, mask, pos: &[len as i32] })
            .unwrap()
    };
    let a = inp(&kv, &mask_frozen);

    // freeze path: rows additionally zeroed (what the engine does)
    let mut kv_zeroed = kv.clone();
    zero_row(&mut kv_zeroed, &geom, 0, 5);
    zero_row(&mut kv_zeroed, &geom, 0, 9);
    let b = inp(&kv_zeroed, &mask_frozen);
    for (x, y) in a.logits.iter().zip(&b.logits) {
        assert!((x - y).abs() < 1e-5, "masked-row content leaked into logits");
    }
    for (x, y) in a.scores.iter().zip(&b.scores) {
        assert!((x - y).abs() < 1e-5, "masked-row content leaked into scores");
    }
    // frozen rows score exactly zero
    assert_eq!(b.scores[5], 0.0);
    assert_eq!(b.scores[9], 0.0);

    // and the content DOES matter when active (sanity: masking changed output)
    let c = inp(&kv, &mask);
    let diff: f32 = a.logits.iter().zip(&c.logits).map(|(x, y)| (x - y).abs()).sum();
    assert!(diff > 1e-4, "masking rows had no effect at all");
}
