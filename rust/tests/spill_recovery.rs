//! Crash-recovery tests for the persistent spill tier
//! (`--spill-persist`): a store is driven through a random trace,
//! dropped WITHOUT graceful shutdown at a random prefix, and reopened
//! on the same directory — every surviving row must restore bit-exact
//! to a shadow model, stale/poisoned records must be reclaimed (never
//! served as bad floats), and the spill/store error paths must leave
//! bookkeeping aligned with tier contents so a retry still reaches the
//! row. CI runs this file in release too: the pre-fix stale-handle
//! bugs hid behind `debug_assert!`s that release builds compiled out.

use std::collections::HashMap;

use asrkf::config::{OffloadConfig, ShardPartition};
use asrkf::error::Error;
use asrkf::metrics::TierKind;
use asrkf::offload::spill::REC_HEADER_BYTES;
use asrkf::offload::{dequantize, quantize, record_bytes_for, record_path, ShardedStore};
use asrkf::prop_assert;
use asrkf::util::prop::{prop_check, G};
use asrkf::util::TempDir;

const RF: usize = 16;

fn row(v: f32) -> Vec<f32> {
    (0..RF).map(|i| v + i as f32 * 0.01).collect()
}

/// What a spilled row restores to: rows admitted past the cold horizon
/// are quantized once at stash time and the record then moves verbatim
/// (cold -> spill -> disk -> recovery), so the restored floats are
/// exactly the dequantized lattice points.
fn expected_roundtrip(r: &[f32]) -> Vec<f32> {
    dequantize(&quantize(r))
}

/// FNV-1a over a record minus its checksum field (bytes 20..28) — the
/// on-disk integrity contract shared by the v1 and v2 record formats
/// (the exclusion window is the checksum *field*, not the header, so
/// it stays at 20..28 even though the v2 header is 36 bytes).
fn record_checksum(rec: &[u8]) -> u64 {
    fn fnv(mut h: u64, bytes: &[u8]) -> u64 {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        h
    }
    fnv(fnv(0xcbf2_9ce4_8422_2325, &rec[..20]), &rec[28..])
}

/// Everything-cold-must-spill persistent configuration rooted at `dir`.
fn persist_cfg(dir: &TempDir, shards: usize, partition: ShardPartition) -> OffloadConfig {
    OffloadConfig {
        hot_budget_bytes: 1 << 20,
        cold_budget_bytes: 1, // any cold row overflows straight to disk
        cold_after_steps: 4,
        spill_dir: Some(dir.path_str()),
        spill_persist: true,
        shards,
        shard_partition: partition,
        block_rows: 4,
        ..OffloadConfig::default()
    }
}

const COMBOS: [(usize, ShardPartition); 4] = [
    (1, ShardPartition::Hash),
    (4, ShardPartition::Hash),
    (1, ShardPartition::Range),
    (4, ShardPartition::Range),
];

#[test]
fn prop_crash_recovery_restores_surviving_rows_bit_exact() {
    prop_check(8, |g| {
        for (shards, partition) in COMBOS {
            let dir = TempDir::new("spill-recovery-prop")
                .map_err(|e| format!("tempdir: {e}"))?;
            let cfg = persist_cfg(&dir, shards, partition);
            let mut store = ShardedStore::new(RF, cfg.clone())
                .map_err(|e| format!("new: {e}"))?;
            // shadow model: pos -> expected restored floats
            let mut shadow: HashMap<usize, Vec<f32>> = HashMap::new();
            let mut next_pos = 0usize;
            let ops = g.usize(5, 50);
            for step in 0..ops as u64 {
                match g.usize(0, 5) {
                    // stash a fresh row (weighted heaviest); far thaw
                    // eta -> quantized at admission -> spilled by the
                    // 1-byte cold budget
                    0..=3 => {
                        let r = g.vec_f32(RF, -4.0, 4.0);
                        store
                            .stash(next_pos, r.clone(), step, step + 100)
                            .map_err(|e| format!("stash {next_pos}: {e}"))?;
                        shadow.insert(next_pos, expected_roundtrip(&r));
                        next_pos += 1;
                    }
                    // restore a random resident row (verified live too)
                    4 => {
                        let mut keys: Vec<usize> = shadow.keys().copied().collect();
                        keys.sort_unstable();
                        if !keys.is_empty() {
                            let pos = keys[g.usize(0, keys.len() - 1)];
                            let got = store
                                .take(pos)
                                .map_err(|e| format!("take {pos}: {e}"))?;
                            let want = shadow.remove(&pos).unwrap();
                            prop_assert!(
                                got.as_deref() == Some(want.as_slice()),
                                "mid-trace restore of pos {pos} diverged"
                            );
                        }
                    }
                    // drop a random resident row
                    _ => {
                        let mut keys: Vec<usize> = shadow.keys().copied().collect();
                        keys.sort_unstable();
                        if !keys.is_empty() {
                            let pos = keys[g.usize(0, keys.len() - 1)];
                            store.drop_row(pos).map_err(|e| format!("drop {pos}: {e}"))?;
                            shadow.remove(&pos);
                        }
                    }
                }
            }

            // crash: drop the store with no graceful shutdown at all
            drop(store);

            // reopen the same directory and recover
            let mut re = ShardedStore::resume(RF, cfg)
                .map_err(|e| format!("resume ({shards} shards, {partition:?}): {e}"))?;
            let sum = re.summary();
            prop_assert!(
                sum.recovery_errors == 0,
                "clean crash must scan clean, got {} errors ({shards} shards, {partition:?})",
                sum.recovery_errors
            );
            prop_assert!(
                sum.recovered_rows == shadow.len() as u64,
                "recovered {} rows, shadow holds {} ({shards} shards, {partition:?})",
                sum.recovered_rows,
                shadow.len()
            );
            prop_assert!(
                sum.occupancy.spill_rows == shadow.len(),
                "recovered rows must be spill-resident"
            );
            let mut survivors: Vec<usize> = shadow.keys().copied().collect();
            survivors.sort_unstable();
            for pos in survivors {
                prop_assert!(
                    re.tier_of(pos) == Some((TierKind::Spill, false)),
                    "pos {pos} not spill-resident after recovery"
                );
                let got = re
                    .take(pos)
                    .map_err(|e| format!("recovered take {pos}: {e}"))?
                    .ok_or(format!("surviving pos {pos} lost by recovery"))?;
                let want = &shadow[&pos];
                prop_assert!(
                    &got == want,
                    "pos {pos} not bit-exact after crash recovery ({shards} shards, \
                     {partition:?})"
                );
            }
            prop_assert!(re.is_empty(), "every surviving row accounted for");
        }
        Ok(())
    });
}

/// Poisoned payload detected at restore time: `Error::Offload`, never
/// bad floats — and (the error-path bookkeeping fix) the store's
/// indexes stay aligned with the tier, so repairing the record and
/// retrying reaches the row. The pre-fix code popped the entry before
/// the tier read: the first failure made every retry report
/// `Ok(None)` for a row the tier still held.
#[test]
fn checksum_corruption_surfaces_offload_error_and_retry_survives() {
    let dir = TempDir::new("spill-poison").unwrap();
    let cfg = persist_cfg(&dir, 1, ShardPartition::Hash);
    let mut store = ShardedStore::new(RF, cfg).unwrap();
    let r = row(1.0);
    store.stash(0, r.clone(), 0, 100).unwrap();
    assert_eq!(store.tier_of(0), Some((TierKind::Spill, false)));

    let path = record_path(&dir.path_str(), 0);
    let pristine = std::fs::read(&path).unwrap();
    let mut poisoned = pristine.clone();
    poisoned[REC_HEADER_BYTES + 10] ^= 0xFF; // flip one payload byte
    std::fs::write(&path, &poisoned).unwrap();

    let err = store.take(0).unwrap_err();
    assert!(matches!(err, Error::Offload(_)), "got {err:?}");
    assert!(format!("{err}").contains("checksum"), "{err}");
    // bookkeeping must still see the row (retryable), not Ok(None)
    assert!(store.contains(0), "failed take must not pop the entry");
    assert_eq!(store.len(), 1);
    assert_eq!(store.summary().occupancy.spill_rows, 1);

    std::fs::write(&path, &pristine).unwrap();
    let got = store.take(0).unwrap().expect("repaired record must restore");
    assert_eq!(got, expected_roundtrip(&r), "restored bit-exact after repair");
    assert!(store.is_empty());
}

/// Same alignment contract on the discard path: a header that fails
/// verification surfaces `Error::Offload` and leaves the row mapped,
/// so the drop can be retried once the record is repaired.
#[test]
fn discard_error_keeps_store_and_tier_aligned() {
    let dir = TempDir::new("spill-discard-err").unwrap();
    let cfg = persist_cfg(&dir, 1, ShardPartition::Hash);
    let mut store = ShardedStore::new(RF, cfg).unwrap();
    store.stash(0, row(2.0), 0, 100).unwrap();

    let path = record_path(&dir.path_str(), 0);
    let pristine = std::fs::read(&path).unwrap();
    let mut broken = pristine.clone();
    broken[0] ^= 0xFF; // break the record magic
    std::fs::write(&path, &broken).unwrap();

    let err = store.drop_row(0).unwrap_err();
    assert!(matches!(err, Error::Offload(_)), "got {err:?}");
    assert!(store.contains(0), "failed discard must not pop the entry");
    assert_eq!(store.summary().occupancy.spill_rows, 1);

    std::fs::write(&path, &pristine).unwrap();
    store.drop_row(0).unwrap();
    assert!(store.is_empty());
    assert_eq!(store.total_dropped(), 1);
}

/// A record poisoned while the process was down is reclaimed by the
/// recovery scan (counted as a recovery error), not re-served.
#[test]
fn poisoned_record_is_reclaimed_at_recovery_not_served() {
    let dir = TempDir::new("spill-poison-recover").unwrap();
    let cfg = persist_cfg(&dir, 1, ShardPartition::Hash);
    let r0 = row(0.0);
    {
        let mut store = ShardedStore::new(RF, cfg.clone()).unwrap();
        store.stash(0, r0.clone(), 0, 100).unwrap();
        store.stash(1, row(1.0), 0, 100).unwrap();
    }
    // poison the second record's payload on disk
    let path = record_path(&dir.path_str(), 0);
    let mut bytes = std::fs::read(&path).unwrap();
    let rb = record_bytes_for(RF);
    bytes[rb + REC_HEADER_BYTES] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();

    let mut re = ShardedStore::resume(RF, cfg).unwrap();
    let sum = re.summary();
    assert_eq!(sum.recovered_rows, 1, "only the intact record recovers");
    assert_eq!(sum.recovery_errors, 1, "the poisoned record is counted");
    assert_eq!(re.take(0).unwrap().unwrap(), expected_roundtrip(&r0));
    assert!(re.take(1).unwrap().is_none(), "poisoned row reclaimed, not served");
}

/// A record claiming a generation at or beyond the manifest's is a
/// fenced-off concurrent writer: reclaimed, never re-served. The test
/// forges the generation AND recomputes a valid checksum (the on-disk
/// format contract: FNV-1a over the record minus the checksum field),
/// so it is the generation fence itself that rejects the record, not
/// the integrity check.
#[test]
fn stale_generation_records_are_fenced_and_reclaimed() {
    let dir = TempDir::new("spill-stale-gen").unwrap();
    let cfg = persist_cfg(&dir, 1, ShardPartition::Hash);
    {
        let mut store = ShardedStore::new(RF, cfg.clone()).unwrap();
        store.stash(0, row(0.0), 0, 100).unwrap();
    }
    // forge the record's generation far past any real attach, with a
    // checksum a real (fenced) writer would have produced
    let path = record_path(&dir.path_str(), 0);
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[4..12].copy_from_slice(&u64::MAX.to_le_bytes());
    let sum = record_checksum(&bytes);
    bytes[20..28].copy_from_slice(&sum.to_le_bytes());
    std::fs::write(&path, &bytes).unwrap();

    let re = ShardedStore::resume(RF, cfg).unwrap();
    let sum = re.summary();
    assert_eq!(sum.recovered_rows, 0);
    assert_eq!(sum.recovery_errors, 1);
    assert!(re.is_empty());
}

/// A directory written under one store shape cannot be reopened under
/// another: the manifest rejects width/shard/partition mismatches
/// instead of mis-decoding records.
#[test]
fn manifest_rejects_mismatched_store_shapes() {
    let dir = TempDir::new("spill-mismatch").unwrap();
    let cfg = persist_cfg(&dir, 4, ShardPartition::Hash);
    {
        let mut store = ShardedStore::new(RF, cfg.clone()).unwrap();
        store.stash(0, row(0.0), 0, 100).unwrap();
    }
    // different shard count
    let err = ShardedStore::resume(RF, persist_cfg(&dir, 1, ShardPartition::Hash)).unwrap_err();
    assert!(matches!(err, Error::Offload(_)), "{err:?}");
    // different partition
    assert!(ShardedStore::resume(RF, persist_cfg(&dir, 4, ShardPartition::Range)).is_err());
    // different row width
    assert!(ShardedStore::resume(RF * 2, persist_cfg(&dir, 4, ShardPartition::Hash)).is_err());
    // the matching shape still resumes
    let re = ShardedStore::resume(RF, cfg).unwrap();
    assert_eq!(re.summary().recovered_rows, 1);
}

/// One hand-crafted v1 (pre-codec-ladder) record: 28-byte header
/// (magic "KVR1", generation, position, checksum) followed by the
/// fixed u8 payload `min f32 | scale f32 | rf code bytes`.
fn v1_record(generation: u64, pos: u64, r: &[f32]) -> Vec<u8> {
    let q = quantize(r);
    let mut rec = vec![0u8; 28 + 8 + RF];
    rec[0..4].copy_from_slice(&0x3152_564Bu32.to_le_bytes()); // "KVR1"
    rec[4..12].copy_from_slice(&generation.to_le_bytes());
    rec[12..20].copy_from_slice(&pos.to_le_bytes());
    rec[28..32].copy_from_slice(&q.min.to_le_bytes());
    rec[32..36].copy_from_slice(&q.scale.to_le_bytes());
    rec[36..36 + RF].copy_from_slice(&q.q);
    let sum = record_checksum(&rec);
    rec[20..28].copy_from_slice(&sum.to_le_bytes());
    rec
}

/// Write a version-1 manifest the way the pre-ladder release did:
/// same identity keys, v1 record size, no codec byte anywhere.
fn write_v1_manifest(dir: &TempDir, generation: u64) {
    let manifest = format!(
        "{{\"magic\":\"asrkf-spill\",\"version\":1,\"row_floats\":{RF},\
         \"record_bytes\":{},\"shards\":1,\"partition\":\"hash\",\
         \"generation\":{generation}}}",
        28 + 8 + RF
    );
    std::fs::write(
        std::path::Path::new(&dir.path_str()).join("spill-manifest.json"),
        manifest,
    )
    .unwrap();
}

/// Forward compatibility: a directory written by the pre-ladder (v1)
/// release resumes under the codec-ladder store. The shard file
/// migrates to the v2 codec-tagged record format at open — keeping
/// each record's original generation stamp so fencing still applies —
/// and every v1 row recovers bit-exact as a u8 record. This is an
/// on-disk compatibility refactor, not a reset.
#[test]
fn v1_format_directory_resumes_migrates_and_restores_bit_exact() {
    let dir = TempDir::new("spill-v1-compat").unwrap();
    let rows = [row(1.0), row(2.0), row(3.0)];
    let mut file = Vec::new();
    for (pos, r) in rows.iter().enumerate() {
        file.extend_from_slice(&v1_record(1, pos as u64, r));
    }
    std::fs::write(record_path(&dir.path_str(), 0), &file).unwrap();
    write_v1_manifest(&dir, 1);

    let mut re = ShardedStore::resume(RF, persist_cfg(&dir, 1, ShardPartition::Hash)).unwrap();
    let sum = re.summary();
    assert_eq!(sum.recovered_rows, 3, "every v1 record must recover");
    assert_eq!(sum.recovery_errors, 0);
    for (pos, r) in rows.iter().enumerate() {
        assert_eq!(
            re.take(pos).unwrap().unwrap(),
            expected_roundtrip(r),
            "v1 row {pos} must restore the exact u8 lattice it was written with"
        );
    }

    // the shard file is now v2: wider records, codec byte = u8 (1)
    drop(re);
    let dir2 = TempDir::new("spill-v1-compat-b").unwrap();
    let mut file2 = Vec::new();
    for (pos, r) in rows.iter().enumerate() {
        file2.extend_from_slice(&v1_record(1, pos as u64, r));
    }
    std::fs::write(record_path(&dir2.path_str(), 0), &file2).unwrap();
    write_v1_manifest(&dir2, 1);
    let re2 = ShardedStore::resume(RF, persist_cfg(&dir2, 1, ShardPartition::Hash)).unwrap();
    let migrated = std::fs::read(record_path(&dir2.path_str(), 0)).unwrap();
    let rb = record_bytes_for(RF);
    assert_eq!(migrated.len(), 3 * rb, "migrated file must use v2 record slots");
    for slot in 0..3 {
        let rec = &migrated[slot * rb..(slot + 1) * rb];
        assert_eq!(&rec[0..4], &0x3252_564Bu32.to_le_bytes(), "v2 magic (KVR2)");
        assert_eq!(rec[28], 1, "migrated record must carry the u8 codec byte");
        assert_eq!(
            u64::from_le_bytes(rec[4..12].try_into().unwrap()),
            1,
            "migration must preserve the original generation stamp"
        );
    }
    drop(re2);

    // a second resume scans the directory as native v2
    let re3 = ShardedStore::resume(RF, persist_cfg(&dir2, 1, ShardPartition::Hash)).unwrap();
    let sum = re3.summary();
    assert_eq!(sum.recovered_rows, 3);
    assert_eq!(sum.recovery_errors, 0);
}

/// Backward-compat scan safety: a v1 record corrupted while the
/// process was down is tombstoned during migration (counted as a
/// recovery error), never decoded into wrong floats, while intact v1
/// neighbors still recover.
#[test]
fn corrupt_v1_record_is_reclaimed_during_migration() {
    let dir = TempDir::new("spill-v1-corrupt").unwrap();
    let good = row(7.0);
    let mut file = Vec::new();
    file.extend_from_slice(&v1_record(1, 0, &good));
    let mut bad = v1_record(1, 1, &row(8.0));
    bad[30] ^= 0xFF; // flip a payload byte under the checksum
    file.extend_from_slice(&bad);
    std::fs::write(record_path(&dir.path_str(), 0), &file).unwrap();
    write_v1_manifest(&dir, 1);

    let mut re = ShardedStore::resume(RF, persist_cfg(&dir, 1, ShardPartition::Hash)).unwrap();
    let sum = re.summary();
    assert_eq!(sum.recovered_rows, 1, "only the intact v1 record recovers");
    assert_eq!(sum.recovery_errors, 1, "the corrupt v1 record is counted");
    assert_eq!(re.take(0).unwrap().unwrap(), expected_roundtrip(&good));
    assert!(re.take(1).unwrap().is_none(), "corrupt v1 row reclaimed, not served");
}

/// Recovery compacts as it scans: a trace that freed its tail leaves a
/// shrunken file, and a resume that drains everything truncates to 0.
#[test]
fn recovery_and_drain_compact_the_record_file() {
    let dir = TempDir::new("spill-compact").unwrap();
    let cfg = persist_cfg(&dir, 1, ShardPartition::Hash);
    let rb = record_bytes_for(RF) as u64;
    {
        let mut store = ShardedStore::new(RF, cfg.clone()).unwrap();
        for p in 0..6 {
            store.stash(p, row(p as f32), 0, 100).unwrap();
        }
        // free the tail three: the file must shrink, not high-water
        for p in (3..6).rev() {
            store.take(p).unwrap().unwrap();
        }
        let path = record_path(&dir.path_str(), 0);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 3 * rb);
    }
    let mut re = ShardedStore::resume(RF, cfg).unwrap();
    assert_eq!(re.summary().recovered_rows, 3);
    for p in 0..3 {
        re.take(p).unwrap().unwrap();
    }
    let path = record_path(&dir.path_str(), 0);
    assert_eq!(
        std::fs::metadata(&path).unwrap().len(),
        0,
        "a drained persistent file must truncate to zero"
    );
}
