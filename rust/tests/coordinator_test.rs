//! Coordinator + server integration tests (need `make artifacts`).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use asrkf::config::{EngineConfig, ServerConfig};
use asrkf::coordinator::{spawn, GenParams};

fn params(prompt: &str, max_new: usize, policy: &str, seed: u64) -> GenParams {
    GenParams { prompt: prompt.into(), max_new, policy: policy.into(), seed, resume_spill: false }
}

#[test]
fn batched_coordinator_serves_concurrent_requests() {
    let cfg = EngineConfig::default();
    let server = ServerConfig { max_batch: 4, ..ServerConfig::default() };
    let (handle, join) = spawn(cfg, server).expect("run `make artifacts` first");

    let prompts = [
        "the scheduler freezes the key value pairs. ",
        "the router balances every request. ",
        "a batch monitors the entropy trace. ",
        "the engine restores the frozen rows. ",
        "the queue evicts the next token. ",
        "memory tracks the attention scores. ",
    ];
    let rxs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| handle.submit(params(p, 24, "asrkf", i as u64)).unwrap())
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "req {i}: {:?}", resp.error);
        assert_eq!(resp.generated_tokens, 24, "req {i}");
        assert!(!resp.text.is_empty());
        assert!(resp.e2e >= resp.ttft);
    }
    drop(handle);
    join.join().unwrap();
}

#[test]
fn admission_control_rejects_oversized_requests() {
    let cfg = EngineConfig::default();
    let server = ServerConfig { max_batch: 4, ..ServerConfig::default() };
    let (handle, join) = spawn(cfg, server).unwrap();

    // B=4 bucket has S=1024; this request cannot fit
    let huge: String = "the cache stores the context. ".repeat(40);
    let resp = handle.generate_blocking(params(&huge, 2000, "asrkf", 0)).unwrap();
    assert!(resp.error.is_some(), "oversized request must be rejected");
    assert!(resp.error.unwrap().contains("admission"));

    // but a normal request still succeeds afterwards
    let ok = handle.generate_blocking(params("the engine decodes. ", 8, "full", 0)).unwrap();
    assert!(ok.error.is_none());
    drop(handle);
    join.join().unwrap();
}

#[test]
fn per_request_policies_coexist_in_one_batch() {
    let cfg = EngineConfig::default();
    let server = ServerConfig { max_batch: 4, ..ServerConfig::default() };
    let (handle, join) = spawn(cfg, server).unwrap();

    let prompt = format!("{} ", asrkf::workload::synthetic::prose(&mut asrkf::util::rng::Pcg64::new(5), 300));
    let rx_full = handle.submit(params(&prompt, 80, "full", 1)).unwrap();
    let rx_asrkf = handle.submit(params(&prompt, 80, "asrkf", 1)).unwrap();
    let full = rx_full.recv().unwrap();
    let asrkf_resp = rx_asrkf.recv().unwrap();
    assert!(full.error.is_none() && asrkf_resp.error.is_none());
    assert_eq!(full.compression, 0.0);
    assert!(
        asrkf_resp.compression > 0.05,
        "asrkf compressed only {:.3} in a shared batch",
        asrkf_resp.compression
    );
    drop(handle);
    join.join().unwrap();
}

#[test]
fn tcp_roundtrip_json_lines() {
    // bind an ephemeral port, run the accept loop manually (the public
    // serve_blocking never returns, so tests wire the pieces directly)
    let cfg = EngineConfig::default();
    let server_cfg = ServerConfig { max_batch: 4, ..ServerConfig::default() };
    let (handle, _join) = spawn(cfg, server_cfg).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let stream = conn.unwrap();
            let h = handle.clone();
            std::thread::spawn(move || {
                let mut writer = stream.try_clone().unwrap();
                let reader = BufReader::new(stream);
                for line in reader.lines() {
                    let line = line.unwrap();
                    let reply = match asrkf::server::protocol::parse_request(&line) {
                        Err(e) => asrkf::server::protocol::error_line(&e),
                        Ok(p) => match h.generate_blocking(p) {
                            Ok(r) => asrkf::server::protocol::response_line(&r),
                            Err(e) => asrkf::server::protocol::error_line(&format!("{e}")),
                        },
                    };
                    writer.write_all(reply.as_bytes()).unwrap();
                }
            });
        }
    });

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    writer
        .write_all(b"{\"prompt\": \"the engine decodes the next token. \", \"max_new\": 12}\n")
        .unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let v = asrkf::util::json::parse(resp.trim()).unwrap();
    assert!(v.get("error").as_str().is_none(), "{resp}");
    assert_eq!(v.get("generated_tokens").as_usize(), Some(12));

    // malformed request -> error line, connection stays usable
    writer.write_all(b"not json\n").unwrap();
    let mut resp2 = String::new();
    reader.read_line(&mut resp2).unwrap();
    assert!(resp2.contains("error"));

    writer
        .write_all(b"{\"prompt\": \"the queue routes a request. \", \"max_new\": 4, \"policy\": \"full\"}\n")
        .unwrap();
    let mut resp3 = String::new();
    reader.read_line(&mut resp3).unwrap();
    let v3 = asrkf::util::json::parse(resp3.trim()).unwrap();
    assert_eq!(v3.get("generated_tokens").as_usize(), Some(4));
}
