//! Coordinator + server integration tests (need `make artifacts`).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use asrkf::config::{EngineConfig, QosClass, ServerConfig};
use asrkf::coordinator::{spawn, GenParams, RejectReason, Ticket};

fn params(prompt: &str, max_new: usize, policy: &str, seed: u64) -> GenParams {
    GenParams::builder(prompt).max_new(max_new).policy(policy).seed(seed).build()
}

#[test]
fn batched_coordinator_serves_concurrent_requests() {
    let cfg = EngineConfig::default();
    let server = ServerConfig { max_batch: 4, ..ServerConfig::default() };
    let (handle, join) = spawn(cfg, server).expect("run `make artifacts` first");

    let prompts = [
        "the scheduler freezes the key value pairs. ",
        "the router balances every request. ",
        "a batch monitors the entropy trace. ",
        "the engine restores the frozen rows. ",
        "the queue evicts the next token. ",
        "memory tracks the attention scores. ",
    ];
    let tickets: Vec<Ticket> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| handle.submit(params(p, 24, "asrkf", i as u64)).unwrap())
        .collect();
    // ids are assigned at submission, in order
    assert!(tickets.windows(2).all(|w| w[0].id < w[1].id));
    for (i, ticket) in tickets.into_iter().enumerate() {
        let resp = ticket.wait().unwrap();
        assert!(resp.error.is_none(), "req {i}: {:?}", resp.error);
        assert_eq!(resp.generated_tokens, 24, "req {i}");
        assert!(!resp.text.is_empty());
        assert!(resp.e2e >= resp.ttft);
    }
    drop(handle);
    join.join().unwrap();
}

#[test]
fn admission_control_rejects_oversized_requests() {
    let cfg = EngineConfig::default();
    let server = ServerConfig { max_batch: 4, ..ServerConfig::default() };
    let (handle, join) = spawn(cfg, server).unwrap();

    // B=4 bucket has S=1024; this request cannot fit
    let huge: String = "the cache stores the context. ".repeat(40);
    let resp = handle.generate_blocking(params(&huge, 2000, "asrkf", 0)).unwrap();
    assert!(resp.error.is_some(), "oversized request must be rejected");
    assert!(resp.error.unwrap().contains("admission"));
    // the reject is typed, not just a string
    let reject = resp.reject.expect("KV-capacity reject must carry the typed reason");
    assert_eq!(reject.reason, RejectReason::KvCapacity);

    // but a normal request still succeeds afterwards
    let ok = handle.generate_blocking(params("the engine decodes. ", 8, "full", 0)).unwrap();
    assert!(ok.error.is_none());
    drop(handle);
    join.join().unwrap();
}

#[test]
fn per_request_policies_coexist_in_one_batch() {
    let cfg = EngineConfig::default();
    let server = ServerConfig { max_batch: 4, ..ServerConfig::default() };
    let (handle, join) = spawn(cfg, server).unwrap();

    let prompt = format!("{} ", asrkf::workload::synthetic::prose(&mut asrkf::util::rng::Pcg64::new(5), 300));
    let t_full = handle.submit(params(&prompt, 80, "full", 1)).unwrap();
    let t_asrkf = handle.submit(params(&prompt, 80, "asrkf", 1)).unwrap();
    let full = t_full.wait().unwrap();
    let asrkf_resp = t_asrkf.wait().unwrap();
    assert!(full.error.is_none() && asrkf_resp.error.is_none());
    assert_eq!(full.compression, 0.0);
    assert!(
        asrkf_resp.compression > 0.05,
        "asrkf compressed only {:.3} in a shared batch",
        asrkf_resp.compression
    );
    drop(handle);
    join.join().unwrap();
}

#[test]
fn mixed_qos_sessions_join_and_leave_mid_flight() {
    let cfg = EngineConfig::default();
    let server = ServerConfig { max_batch: 4, ..ServerConfig::default() };
    let (handle, join) = spawn(cfg, server).unwrap();

    // different classes AND different lengths: sessions retire at
    // different steps, so the slot population (and therefore the
    // class-weighted budget split) changes mid-flight many times
    let mix = [
        (QosClass::Interactive, 8usize),
        (QosClass::Batch, 40),
        (QosClass::Standard, 16),
        (QosClass::Interactive, 12),
        (QosClass::Batch, 32),
        (QosClass::Standard, 24),
    ];
    let tickets: Vec<(QosClass, Ticket)> = mix
        .iter()
        .enumerate()
        .map(|(i, &(class, max_new))| {
            let p = GenParams::builder("the engine schedules a mixed batch. ")
                .max_new(max_new)
                .seed(i as u64)
                .qos(class)
                .build();
            (class, handle.submit(p).unwrap())
        })
        .collect();
    for (i, (class, ticket)) in tickets.into_iter().enumerate() {
        let resp = ticket.wait().unwrap();
        assert!(resp.error.is_none(), "req {i}: {:?}", resp.error);
        assert_eq!(resp.generated_tokens, mix[i].1, "req {i}");
        // budgets are roomy: nothing sheds, every request runs at the
        // class it asked for
        assert_eq!(resp.class, class, "req {i}");
        assert!(resp.reject.is_none());
    }
    drop(handle);
    join.join().unwrap();
}

#[test]
fn interactive_requests_overtake_batch_under_contention() {
    let cfg = EngineConfig::default();
    let server = ServerConfig { max_batch: 4, ..ServerConfig::default() };
    let (handle, join) = spawn(cfg, server).unwrap();

    // fill all four slots with long batch-class sessions...
    let occupiers: Vec<Ticket> = (0..4)
        .map(|i| {
            let p = GenParams::builder("a long batch job holds a slot. ")
                .max_new(48)
                .seed(i)
                .qos(QosClass::Batch)
                .build();
            handle.submit(p).unwrap()
        })
        .collect();
    // ...then queue batch-class work FIRST and interactive work after
    // it. Priority scheduling must admit the interactive requests into
    // freed slots ahead of the earlier-queued batch requests.
    let queued_batch: Vec<Ticket> = (0..2)
        .map(|i| {
            let p = GenParams::builder("queued batch work waits. ")
                .max_new(8)
                .seed(10 + i)
                .qos(QosClass::Batch)
                .build();
            handle.submit(p).unwrap()
        })
        .collect();
    let queued_interactive: Vec<Ticket> = (0..2)
        .map(|i| {
            let p = GenParams::builder("an interactive user is waiting. ")
                .max_new(8)
                .seed(20 + i)
                .qos(QosClass::Interactive)
                .build();
            handle.submit(p).unwrap()
        })
        .collect();

    let e2e = |tickets: Vec<Ticket>| -> f64 {
        let mut sum = 0.0;
        let n = tickets.len();
        for t in tickets {
            let r = t.wait().unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
            sum += r.e2e.as_secs_f64();
        }
        sum / n as f64
    };
    let batch_e2e = e2e(queued_batch);
    let interactive_e2e = e2e(queued_interactive);
    assert!(
        interactive_e2e < batch_e2e,
        "interactive requests queued after batch must still finish first \
         (interactive {interactive_e2e:.3}s vs batch {batch_e2e:.3}s)"
    );
    for t in occupiers {
        assert!(t.wait().unwrap().error.is_none());
    }
    drop(handle);
    join.join().unwrap();
}

#[test]
fn tiny_hot_budget_turns_into_typed_envelope_rejects() {
    // size the hot tier so exactly one session's slice clears the
    // admission envelope: one KV row is kv_row_floats * 4 bytes, the
    // floor is 1.25x that (default headroom), and two members at any
    // class mix push someone below it (see AdmissionController docs)
    let mut cfg = EngineConfig::default();
    let manifest = asrkf::runtime::Manifest::load(&cfg.artifacts_dir)
        .expect("run `make artifacts` first");
    let row_bytes = manifest.model.kv_row_floats * std::mem::size_of::<f32>();
    cfg.offload.hot_budget_bytes = 2 * row_bytes;
    let server = ServerConfig { max_batch: 4, ..ServerConfig::default() };
    let (handle, join) = spawn(cfg, server).unwrap();

    let first = handle
        .submit(params("the first session occupies the envelope. ", 48, "asrkf", 1))
        .unwrap();
    let second = handle
        .submit(params("the second session must not fit the envelope. ", 8, "asrkf", 2))
        .unwrap();
    let rejected = second.wait().unwrap();
    assert!(rejected.error.as_deref().unwrap_or("").contains("admission"), "{rejected:?}");
    let reject = rejected.reject.expect("envelope reject must be typed");
    assert_eq!(reject.reason, RejectReason::HotEnvelope);
    assert_eq!(reject.requested, QosClass::Standard);

    let ok = first.wait().unwrap();
    assert!(ok.error.is_none(), "the admitted session must still finish: {:?}", ok.error);
    drop(handle);
    join.join().unwrap();
}

#[test]
fn worker_kill_fails_one_session_while_siblings_survive() {
    // Degraded-mode serving, end to end: one session's shard worker is
    // killed mid-flight. The supervised panic must fail *that* session
    // with a typed error on its ticket, leave the rest of the batch
    // decoding, and leave the rebuilt slot usable for the next arrival.
    let tmp = asrkf::util::TempDir::new("coord-kill").unwrap();
    let mut cfg = EngineConfig::default();
    cfg.offload.spill_persist = true;
    cfg.offload.spill_dir = Some(tmp.path_str());
    let server = ServerConfig { max_batch: 4, ..ServerConfig::default() };
    let (handle, join) = spawn(cfg, server).expect("run `make artifacts` first");

    // the first submission lands in slot 0 (lowest free slot); its
    // store's spill dir is <tmp>/slot-0, so arming the kill on that
    // subdirectory targets exactly this session's shards. The one-shot
    // fires on the doomed store's first shard op (its first freeze).
    asrkf::offload::fault::arm_worker_kill(tmp.path().join("slot-0"));

    let prompt = format!(
        "{} ",
        asrkf::workload::synthetic::prose(&mut asrkf::util::rng::Pcg64::new(11), 300)
    );
    let doomed = handle.submit(params(&prompt, 80, "asrkf", 1)).unwrap();
    let siblings: Vec<Ticket> = (0..3)
        .map(|i| handle.submit(params(&prompt, 40, "asrkf", 2 + i)).unwrap())
        .collect();

    let failed = doomed.wait().unwrap();
    let msg = failed.error.expect("killed session must resolve to a typed error");
    assert!(
        msg.contains("panicked") || msg.contains("lost"),
        "error must name the supervised failure: {msg}"
    );
    for (i, t) in siblings.into_iter().enumerate() {
        let r = t.wait().unwrap();
        assert!(r.error.is_none(), "sibling {i} must survive the kill: {:?}", r.error);
        assert_eq!(r.generated_tokens, 40, "sibling {i}");
    }
    // the freed slot 0 (its store rebuilt before the error surfaced)
    // admits and serves a fresh request
    let next = handle.submit(params(&prompt, 24, "asrkf", 9)).unwrap().wait().unwrap();
    assert!(next.error.is_none(), "rebuilt slot must serve again: {:?}", next.error);
    assert_eq!(next.generated_tokens, 24);
    drop(handle);
    join.join().unwrap();
}

#[test]
fn equal_weights_reproduce_the_static_partition() {
    // the pre-QoS coordinator gave every slot a static 1/B slice
    // (OffloadConfig::partitioned); equal class weights must reproduce
    // it byte-for-byte through the admission controller's projection,
    // whatever the class mix of the population. Artifact-free: pure
    // budget arithmetic.
    use asrkf::config::{OffloadConfig, QosConfig};
    use asrkf::coordinator::AdmissionController;

    let offload =
        OffloadConfig { hot_budget_bytes: 101, cold_budget_bytes: 31, ..Default::default() };
    let qos = QosConfig { weights: [5, 5, 5], ..QosConfig::default() };
    let ctl = AdmissionController::new(qos, &offload, 64);
    for b in 1..=4usize {
        let members: Vec<QosClass> =
            (0..b).map(|i| QosClass::ALL[i % QosClass::COUNT]).collect();
        let shares = ctl.shares(&members, offload.cold_budget_bytes);
        for (i, &(hot, cold)) in shares.iter().enumerate() {
            let p = offload.partitioned(b, i);
            assert_eq!(hot, p.hot_budget_bytes, "hot {b}@{i}");
            assert_eq!(cold, p.cold_budget_bytes, "cold {b}@{i}");
        }
    }
}

#[test]
fn tcp_roundtrip_json_lines() {
    // bind an ephemeral port, run the accept loop manually (the public
    // serve_blocking never returns, so tests wire the pieces directly)
    let cfg = EngineConfig::default();
    let server_cfg = ServerConfig { max_batch: 4, ..ServerConfig::default() };
    let (handle, _join) = spawn(cfg, server_cfg).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let stream = conn.unwrap();
            let h = handle.clone();
            std::thread::spawn(move || {
                let mut writer = stream.try_clone().unwrap();
                let reader = BufReader::new(stream);
                for line in reader.lines() {
                    let line = line.unwrap();
                    let reply = match asrkf::server::protocol::parse_request(&line) {
                        Err(e) => asrkf::server::protocol::error_line(&e),
                        Ok(p) => match h.generate_blocking(p) {
                            Ok(r) => asrkf::server::protocol::response_line(&r),
                            Err(e) => asrkf::server::protocol::error_line(&format!("{e}")),
                        },
                    };
                    writer.write_all(reply.as_bytes()).unwrap();
                }
            });
        }
    });

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    writer
        .write_all(b"{\"prompt\": \"the engine decodes the next token. \", \"max_new\": 12}\n")
        .unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let v = asrkf::util::json::parse(resp.trim()).unwrap();
    assert!(v.get("error").as_str().is_none(), "{resp}");
    assert_eq!(v.get("generated_tokens").as_usize(), Some(12));

    // malformed request -> error line, connection stays usable
    writer.write_all(b"not json\n").unwrap();
    let mut resp2 = String::new();
    reader.read_line(&mut resp2).unwrap();
    assert!(resp2.contains("error"));

    // the versioned v1 format with a class rides the same connection;
    // the effective class comes back on the response
    writer
        .write_all(
            b"{\"v\": 1, \"op\": \"generate\", \"prompt\": \"the queue routes a request. \", \
              \"max_new\": 4, \"policy\": \"full\", \"class\": \"interactive\"}\n",
        )
        .unwrap();
    let mut resp3 = String::new();
    reader.read_line(&mut resp3).unwrap();
    let v3 = asrkf::util::json::parse(resp3.trim()).unwrap();
    assert_eq!(v3.get("generated_tokens").as_usize(), Some(4));
    assert_eq!(v3.get("class").as_str(), Some("interactive"));
}
