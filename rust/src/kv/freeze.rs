//! Sublinear freeze scheduling (paper §3.4, Eq. 3):
//! `d_j = floor(sqrt(c_j) / k)`, where `c_j` counts low-importance detections for token j within the
//! history window W, and `k` is the softness parameter (default 2.0).

/// Freeze duration for a detection count `c` and softness `k`.
///
/// Paper properties this must satisfy (§3.4):
///   * gentle early penalty: c=1 -> d=0 (no freeze)
///   * gradual escalation:   c=4 -> 1, c=9 -> 1, c=16 -> 2 (k=2)
///   * bounded growth:       d grows as O(sqrt(c))
pub fn freeze_duration(c: u32, k: f32) -> u32 {
    debug_assert!(k > 0.0, "softness k must be positive");
    ((c as f32).sqrt() / k).floor() as u32
}

/// Detection counter over a rolling history window of W steps.
///
/// Stores the step numbers of the most recent detections and prunes
/// those older than `step - w` — an exact implementation of "count
/// within a history window W" rather than a decayed approximation.
#[derive(Debug, Clone, Default)]
pub struct DetectionWindow {
    steps: std::collections::VecDeque<u64>,
}

impl DetectionWindow {
    /// Record a detection at `step`, prune to window `w`, return c.
    pub fn record(&mut self, step: u64, w: u64) -> u32 {
        self.steps.push_back(step);
        self.prune(step, w);
        self.steps.len() as u32
    }

    /// Count without recording (pruned to window at `step`).
    pub fn count(&mut self, step: u64, w: u64) -> u32 {
        self.prune(step, w);
        self.steps.len() as u32
    }

    pub fn clear(&mut self) {
        self.steps.clear();
    }

    fn prune(&mut self, step: u64, w: u64) {
        while let Some(&front) = self.steps.front() {
            if front + w <= step {
                self.steps.pop_front();
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_examples_k2() {
        // §3.4: c=1 -> 0, c=4 -> 1, c=9 -> 1, c=16 -> 2
        assert_eq!(freeze_duration(1, 2.0), 0);
        assert_eq!(freeze_duration(4, 2.0), 1);
        assert_eq!(freeze_duration(9, 2.0), 1);
        assert_eq!(freeze_duration(16, 2.0), 2);
    }

    #[test]
    fn first_detection_never_freezes() {
        for k in [1.5f32, 2.0, 3.0] {
            assert_eq!(freeze_duration(1, k), 0, "k={k}");
        }
    }

    #[test]
    fn monotone_nondecreasing_in_c() {
        let mut prev = 0;
        for c in 0..1000 {
            let d = freeze_duration(c, 2.0);
            assert!(d >= prev);
            prev = d;
        }
    }

    #[test]
    fn sublinear_growth() {
        // doubling c must far-less-than-double d for large c
        let d100 = freeze_duration(100, 2.0);
        let d400 = freeze_duration(400, 2.0);
        assert_eq!(d100, 5);
        assert_eq!(d400, 10); // sqrt scaling: 4x count -> 2x duration
    }

    #[test]
    fn softer_k_means_shorter_freezes() {
        for c in [4u32, 16, 64, 256] {
            assert!(freeze_duration(c, 3.0) <= freeze_duration(c, 2.0));
            assert!(freeze_duration(c, 2.0) <= freeze_duration(c, 1.0));
        }
    }

    #[test]
    fn window_prunes_old_detections() {
        let mut w = DetectionWindow::default();
        assert_eq!(w.record(0, 10), 1);
        assert_eq!(w.record(5, 10), 2);
        // step 10: detection at 0 has aged out (0 + 10 <= 10)
        assert_eq!(w.record(10, 10), 2);
        // step 30: everything aged out except the new one
        assert_eq!(w.record(30, 10), 1);
    }

    #[test]
    fn count_does_not_record() {
        let mut w = DetectionWindow::default();
        w.record(1, 100);
        assert_eq!(w.count(2, 100), 1);
        assert_eq!(w.count(3, 100), 1);
    }
}
