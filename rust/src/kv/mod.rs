//! KV-cache management — the paper's core contribution.
//!
//! * `state`     — per-token Active/Frozen state machine, indexed for
//!   O(log n) control-plane queries (see `README.md` in this directory)
//! * `freeze`    — sublinear freeze scheduling (Eq. 3) + detection windows
//! * `relevance` — Eq. 2 thresholding and candidate selection
//! * `policy`    — the `KvPolicy` trait and the indexed ASR-KF-EGR policy
//! * `oracle`    — retained brute-force full-scan ASR-KF-EGR (equivalence
//!   oracle for tests, old-implementation column for `policy_scaling`)
//! * `store`     — minimal flat frozen-row store (reference/baseline)
//!
//! The engine's production storage lives in `crate::offload`: plans
//! carry tier hints (`Plan::freeze_thaw_eta`, `Plan::prefetch`) that
//! the tiered store turns into hot/cold/spill placement:
//!
//! ```text
//!   policy.plan() ──freeze──► offload::TieredStore ──restore──► cache
//!        │                      hot │ cold │ spill
//!        └──prefetch hints──► stage() ahead of thaw
//! ```

pub mod freeze;
pub mod oracle;
pub mod policy;
pub mod relevance;
pub mod state;
pub mod store;

pub use oracle::ScanAsrKfPolicy;
pub use policy::{AsrKfPolicy, KvPolicy, Plan, UnfreezeScope, PREFETCH_HORIZON};
pub use state::{TokenMeta, TokenState, TokenTable};
pub use store::FrozenStore;
