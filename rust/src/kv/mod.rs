//! KV-cache management — the paper's core contribution.
//!
//! * `state`     — per-token Active/Frozen state machine
//! * `freeze`    — sublinear freeze scheduling (Eq. 3) + detection windows
//! * `relevance` — Eq. 2 thresholding and candidate selection
//! * `policy`    — the `KvPolicy` trait and the ASR-KF-EGR policy
//! * `store`     — host-side frozen-row storage (the paper's "CPU storage")

pub mod freeze;
pub mod policy;
pub mod relevance;
pub mod state;
pub mod store;

pub use policy::{AsrKfPolicy, KvPolicy, Plan, UnfreezeScope};
pub use state::{TokenMeta, TokenState, TokenTable};
pub use store::FrozenStore;
