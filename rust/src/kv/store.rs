//! Flat frozen-row storage: the minimal reference implementation of
//! the paper's off-GPU ("CPU") side of the soft freeze. The serving
//! engine uses the tiered `crate::offload::TieredStore` instead (byte
//! budgets, cold-tier compression, prefetch-ahead staging); this store
//! remains the single-level baseline for tests and ablations.
//!
//! Rows are keyed by sequence position. One row bundle = the token's
//! K and V vectors across all layers = `kv_row_floats` f32s.

use std::collections::HashMap;

use crate::error::{Error, Result};

#[derive(Debug, Default)]
pub struct FrozenStore {
    rows: HashMap<usize, Vec<f32>>,
    row_floats: usize,
    /// lifetime counters for memory-accounting traces
    pub total_stashed: u64,
    pub total_restored: u64,
    pub total_dropped: u64,
}

impl FrozenStore {
    pub fn new(row_floats: usize) -> Self {
        FrozenStore { rows: HashMap::new(), row_floats, ..Default::default() }
    }

    /// Stash a gathered row bundle for `pos` (moves active -> frozen).
    ///
    /// Double-freezing or a mis-sized bundle is an engine invariant
    /// breach and returns `Error::Offload` — this used to be a
    /// `debug_assert!` that silently overwrote (and mis-counted) in
    /// release builds.
    pub fn stash(&mut self, pos: usize, row: Vec<f32>) -> Result<()> {
        if row.len() != self.row_floats {
            return Err(Error::Offload(format!(
                "row bundle for pos {pos} has {} floats, store expects {}",
                row.len(),
                self.row_floats
            )));
        }
        if self.rows.contains_key(&pos) {
            return Err(Error::Offload(format!("double-freeze of pos {pos}")));
        }
        self.rows.insert(pos, row);
        self.total_stashed += 1;
        Ok(())
    }

    /// Take the payload for a restore (frozen -> active).
    pub fn take(&mut self, pos: usize) -> Option<Vec<f32>> {
        let r = self.rows.remove(&pos);
        if r.is_some() {
            self.total_restored += 1;
        }
        r
    }

    /// Drop a payload permanently (irreversible-eviction baselines).
    pub fn drop_row(&mut self, pos: usize) {
        if self.rows.remove(&pos).is_some() {
            self.total_dropped += 1;
        }
    }

    pub fn contains(&self, pos: usize) -> bool {
        self.rows.contains_key(&pos)
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Bytes currently held in off-GPU storage.
    pub fn bytes(&self) -> usize {
        self.rows.len() * self.row_floats * std::mem::size_of::<f32>()
    }

    /// Drain everything (pos, payload) — used by the engine's emergency
    /// full restore (RR recovery rewind).
    pub fn drain_all(&mut self) -> Vec<(usize, Vec<f32>)> {
        let n = self.rows.len() as u64;
        self.total_restored += n;
        self.rows.drain().collect()
    }

    pub fn positions(&self) -> Vec<usize> {
        let mut p: Vec<usize> = self.rows.keys().copied().collect();
        p.sort_unstable();
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stash_take_roundtrip() {
        let mut s = FrozenStore::new(4);
        s.stash(7, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert!(s.contains(7));
        assert_eq!(s.bytes(), 16);
        assert_eq!(s.take(7), Some(vec![1.0, 2.0, 3.0, 4.0]));
        assert!(!s.contains(7));
        assert_eq!(s.take(7), None);
    }

    #[test]
    fn drop_is_permanent() {
        let mut s = FrozenStore::new(2);
        s.stash(1, vec![5.0, 6.0]).unwrap();
        s.drop_row(1);
        assert_eq!(s.take(1), None);
        assert_eq!(s.total_dropped, 1);
    }

    #[test]
    fn double_stash_is_an_error_and_preserves_payload() {
        let mut s = FrozenStore::new(1);
        s.stash(3, vec![0.5]).unwrap();
        let e = s.stash(3, vec![1.0]).unwrap_err();
        assert!(format!("{e}").contains("double-freeze"));
        // original payload and accounting untouched
        assert_eq!(s.total_stashed, 1);
        assert_eq!(s.take(3), Some(vec![0.5]));
    }

    #[test]
    fn wrong_row_size_is_an_error() {
        let mut s = FrozenStore::new(4);
        assert!(s.stash(0, vec![1.0, 2.0]).is_err());
        assert!(s.is_empty());
        assert_eq!(s.total_stashed, 0);
    }

    #[test]
    fn drain_all_returns_everything() {
        let mut s = FrozenStore::new(1);
        s.stash(1, vec![1.0]).unwrap();
        s.stash(9, vec![9.0]).unwrap();
        let mut all = s.drain_all();
        all.sort_by_key(|(p, _)| *p);
        assert_eq!(all.len(), 2);
        assert_eq!(all[1], (9, vec![9.0]));
        assert!(s.is_empty());
    }
}
