//! Per-token state machine shared by all KV policies, indexed for
//! O(log n) control-plane queries.
//!
//! The original table stored a countdown timer per frozen row and
//! answered every policy question by scanning `meta` end to end:
//! `tick_timers` decremented all n timers per decode step,
//! `active_count`/`frozen_positions` were full filters, and the
//! prefetch scan walked the whole table looking for imminent thaws.
//! At million-token contexts that put an O(context_length) sweep on
//! every decode step regardless of how little work the step did.
//!
//! This version keeps *absolute* thaw steps and three incremental
//! indexes updated on each freeze/unfreeze (mirroring
//! `offload::sched::ThawScheduler`):
//!
//! * `thaw: BTreeSet<(thaw_step, pos)>` — finite-thaw frozen rows.
//!   [`TokenTable::pop_expired`] is a range pop of actually-expired
//!   entries and [`TokenTable::thaw_range`] answers the prefetch
//!   horizon query, each O(hits·log n) instead of O(n).
//! * `frozen: BTreeSet<usize>` — every frozen position, sorted, so
//!   recovery scopes walk frozen rows only.
//! * `active: BTreeSet<usize>` — the complement, so low-importance
//!   detection iterates active candidates in `[n_sink, window_start)`
//!   without filtering the full position range.
//!
//! Detection-window clearing (Full reset / RR) is epoch-based: bumping
//! [`TokenTable::clear_windows`] lazily invalidates every window in
//! O(1); windows reset on their next recorded detection.
//!
//! All state changes go through methods so the indexes can never drift
//! from `meta` — the brute-force equivalence oracle lives in
//! `crate::kv::oracle` and is property-tested against this table
//! through `AsrKfPolicy` in `tests/prop_policy.rs`.

use std::collections::BTreeSet;
use std::ops::Bound;

use crate::kv::freeze::DetectionWindow;

/// Lifecycle of a token's KV row (paper §3.3: Active <-> Frozen).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenState {
    /// Row is in the active cache and participates in attention.
    Active,
    /// Row was moved to off-GPU storage; `thaw_step` is the absolute
    /// step at which its timer expires ([`TokenTable::NEVER`] =
    /// permanent eviction — baselines only; ASR-KF-EGR never does
    /// this).
    Frozen { thaw_step: u64 },
}

#[derive(Debug, Clone)]
pub struct TokenMeta {
    state: TokenState,
    /// Low-importance detection history within window W.
    window: DetectionWindow,
    /// Epoch of the last window write (see [`TokenTable::clear_windows`]).
    window_epoch: u64,
    /// Total times this token has been frozen (stats/traces).
    freezes: u32,
    /// Step at which the current freeze began (WR recovery scope).
    frozen_at: u64,
    /// Timer expired and was reported by [`TokenTable::pop_expired`];
    /// the row stays frozen (awaiting a budgeted restore) but is no
    /// longer in the thaw index.
    queued: bool,
}

impl Default for TokenMeta {
    fn default() -> Self {
        TokenMeta {
            state: TokenState::Active,
            window: DetectionWindow::default(),
            window_epoch: 0,
            freezes: 0,
            frozen_at: 0,
            queued: false,
        }
    }
}

/// Token table: per-position metadata for one sequence, plus the
/// incremental indexes described in the module docs.
#[derive(Debug, Default)]
pub struct TokenTable {
    meta: Vec<TokenMeta>,
    /// Sorted index of active positions (detection candidates).
    active: BTreeSet<usize>,
    /// Sorted index of every frozen position (recovery scopes).
    frozen: BTreeSet<usize>,
    /// `(thaw_step, pos)` for frozen rows with finite timers that have
    /// not yet expired.
    thaw: BTreeSet<(u64, usize)>,
    /// Detection-window epoch (lazy O(1) clear-all).
    epoch: u64,
}

impl TokenTable {
    /// Sentinel thaw step for permanent eviction (never expires).
    pub const NEVER: u64 = u64::MAX;

    /// Grow the table to cover `len` tokens (new tokens start Active).
    pub fn grow_to(&mut self, len: usize) {
        while self.meta.len() < len {
            self.active.insert(self.meta.len());
            self.meta.push(TokenMeta { window_epoch: self.epoch, ..TokenMeta::default() });
        }
    }

    pub fn len(&self) -> usize {
        self.meta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.meta.is_empty()
    }

    /// Current state (positions beyond the table are Active).
    pub fn state(&self, pos: usize) -> TokenState {
        self.meta.get(pos).map(|m| m.state).unwrap_or(TokenState::Active)
    }

    pub fn is_active(&self, pos: usize) -> bool {
        matches!(self.state(pos), TokenState::Active)
    }

    pub fn is_frozen(&self, pos: usize) -> bool {
        matches!(self.state(pos), TokenState::Frozen { .. })
    }

    /// O(1): active rows within the table.
    pub fn active_count(&self) -> usize {
        self.meta.len() - self.frozen.len()
    }

    /// O(1): frozen rows within the table.
    pub fn frozen_count(&self) -> usize {
        self.frozen.len()
    }

    /// Sorted frozen positions (O(frozen), not O(len)).
    pub fn frozen_positions(&self) -> Vec<usize> {
        self.frozen.iter().copied().collect()
    }

    /// Active positions in `[lo, hi)`, ascending — the detection
    /// candidate walk. Cost tracks the matches, not the range width.
    pub fn active_range(&self, lo: usize, hi: usize) -> impl Iterator<Item = usize> + '_ {
        self.active.range(lo.min(hi)..hi).copied()
    }

    /// Finite-thaw frozen rows with `lo <= thaw_step <= hi`, soonest
    /// first — the prefetch-horizon query. Rows already expired and
    /// reported (queued for restore) are not in the index.
    pub fn thaw_range(&self, lo: u64, hi: u64) -> impl Iterator<Item = (u64, usize)> + '_ {
        let lo = Bound::Included((lo.min(hi), 0usize));
        let hi = Bound::Included((hi, usize::MAX));
        self.thaw.range((lo, hi)).copied()
    }

    /// Times `pos` has been frozen (stats/traces).
    pub fn freezes(&self, pos: usize) -> u32 {
        self.meta.get(pos).map(|m| m.freezes).unwrap_or(0)
    }

    /// Step at which the current freeze began.
    pub fn frozen_at(&self, pos: usize) -> u64 {
        self.meta.get(pos).map(|m| m.frozen_at).unwrap_or(0)
    }

    /// Freeze an active row until absolute step `thaw_step`
    /// ([`TokenTable::NEVER`] = permanent), recording the freeze step.
    pub fn freeze(&mut self, pos: usize, thaw_step: u64, step: u64) {
        let m = &mut self.meta[pos];
        debug_assert_eq!(m.state, TokenState::Active, "freezing non-active pos {pos}");
        m.state = TokenState::Frozen { thaw_step };
        m.freezes += 1;
        m.frozen_at = step;
        m.queued = false;
        self.active.remove(&pos);
        self.frozen.insert(pos);
        if thaw_step != Self::NEVER {
            self.thaw.insert((thaw_step, pos));
        }
    }

    pub fn unfreeze(&mut self, pos: usize) {
        let m = &mut self.meta[pos];
        let TokenState::Frozen { thaw_step } = m.state else {
            debug_assert!(
                matches!(m.state, TokenState::Frozen { .. }),
                "unfreezing non-frozen pos {pos}"
            );
            return;
        };
        if !m.queued && thaw_step != Self::NEVER {
            self.thaw.remove(&(thaw_step, pos));
        }
        m.state = TokenState::Active;
        m.queued = false;
        self.frozen.remove(&pos);
        self.active.insert(pos);
    }

    /// Pop every indexed row whose thaw step has arrived (`<= now`),
    /// appending positions to `out` in `(thaw_step, pos)` order. Each
    /// expiry is reported exactly once; the rows stay frozen (awaiting
    /// a budgeted restore). O(expiries · log n).
    pub fn pop_expired(&mut self, now: u64, out: &mut Vec<usize>) {
        while let Some(&(eta, pos)) = self.thaw.iter().next() {
            if eta > now {
                break;
            }
            self.thaw.remove(&(eta, pos));
            self.meta[pos].queued = true;
            out.push(pos);
        }
    }

    /// Rewrite a frozen row's thaw step (recovery). Re-indexes the row;
    /// a row already reported by [`TokenTable::pop_expired`] re-enters
    /// the index (and will be reported again — the policy's restore
    /// loop tolerates duplicate queue entries).
    pub fn schedule_thaw(&mut self, pos: usize, new_thaw: u64) {
        let m = &mut self.meta[pos];
        let TokenState::Frozen { thaw_step } = m.state else {
            debug_assert!(
                matches!(m.state, TokenState::Frozen { .. }),
                "scheduling thaw for non-frozen pos {pos}"
            );
            return;
        };
        if !m.queued && thaw_step != Self::NEVER {
            self.thaw.remove(&(thaw_step, pos));
        }
        self.meta[pos].state = TokenState::Frozen { thaw_step: new_thaw };
        self.meta[pos].queued = false;
        self.thaw.insert((new_thaw, pos));
    }

    /// SR scope: expire every frozen row whose thaw lies strictly
    /// beyond `now` (rows already due are left to the normal restore
    /// path). Returns the number of rows touched. O(hits · log n) via
    /// the thaw index — permanently evicted rows are not in the index
    /// and are never touched.
    pub fn soft_expire(&mut self, now: u64) -> usize {
        let lo = Bound::Excluded((now, usize::MAX));
        let hits: Vec<(u64, usize)> = self.thaw.range((lo, Bound::Unbounded)).copied().collect();
        for &(_, pos) in &hits {
            self.schedule_thaw(pos, now);
        }
        hits.len()
    }

    /// WR scope: expire every frozen row whose freeze began within the
    /// last `n` steps (`frozen_at + n >= now`). Walks frozen rows only.
    pub fn window_expire(&mut self, n: u64, now: u64) -> usize {
        let hits: Vec<usize> = self
            .frozen
            .iter()
            .copied()
            .filter(|&p| self.meta[p].frozen_at.saturating_add(n) >= now)
            .collect();
        for &pos in &hits {
            self.schedule_thaw(pos, now);
        }
        hits.len()
    }

    /// FR scope: expire every frozen row and clear all detection
    /// counters. O(frozen · log n) + O(1) for the counter clear.
    pub fn full_expire(&mut self, now: u64) -> usize {
        let hits: Vec<usize> = self.frozen.iter().copied().collect();
        for &pos in &hits {
            self.schedule_thaw(pos, now);
        }
        self.clear_windows();
        hits.len()
    }

    /// Lazily clear every position's detection window (O(1) epoch bump;
    /// each window resets on its next write).
    pub fn clear_windows(&mut self) {
        self.epoch += 1;
    }

    /// Record a low-importance detection for `pos` at `step` within
    /// history window `w`; returns the updated count c.
    pub fn record_detection(&mut self, pos: usize, step: u64, w: u64) -> u32 {
        let epoch = self.epoch;
        let m = &mut self.meta[pos];
        if m.window_epoch != epoch {
            m.window.clear();
            m.window_epoch = epoch;
        }
        m.window.record(step, w)
    }

    /// RR reset: every row active, all counters cleared.
    pub fn force_all_active(&mut self) {
        for &pos in &self.frozen {
            let m = &mut self.meta[pos];
            m.state = TokenState::Active;
            m.queued = false;
            self.active.insert(pos);
        }
        self.frozen.clear();
        self.thaw.clear();
        self.clear_windows();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grow_makes_active_tokens() {
        let mut t = TokenTable::default();
        t.grow_to(5);
        assert_eq!(t.active_count(), 5);
        assert!(t.is_active(3));
        t.grow_to(3); // never shrinks
        assert_eq!(t.len(), 5);
        assert_eq!(t.active_range(0, 5).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn freeze_unfreeze_cycle() {
        let mut t = TokenTable::default();
        t.grow_to(4);
        t.freeze(2, 13, 10);
        assert!(t.is_frozen(2));
        assert_eq!(t.active_count(), 3);
        assert_eq!(t.frozen_count(), 1);
        assert_eq!(t.freezes(2), 1);
        assert_eq!(t.frozen_at(2), 10);
        assert_eq!(t.frozen_positions(), vec![2]);
        assert_eq!(t.active_range(0, 4).collect::<Vec<_>>(), vec![0, 1, 3]);
        t.unfreeze(2);
        assert!(t.is_active(2));
        assert_eq!(t.thaw_range(0, u64::MAX - 1).count(), 0, "index entry must be gone");
    }

    #[test]
    fn expiries_pop_in_thaw_then_position_order() {
        let mut t = TokenTable::default();
        t.grow_to(4);
        t.freeze(0, 1, 0);
        t.freeze(1, 2, 0);
        t.freeze(3, 2, 0);
        let mut out = Vec::new();
        t.pop_expired(1, &mut out);
        assert_eq!(out, vec![0]);
        out.clear();
        t.pop_expired(2, &mut out);
        assert_eq!(out, vec![1, 3]);
        out.clear();
        t.pop_expired(100, &mut out);
        assert!(out.is_empty(), "expiries are reported exactly once");
        assert!(t.is_frozen(0), "popped rows stay frozen until restored");
    }

    #[test]
    fn permanent_eviction_never_expires() {
        let mut t = TokenTable::default();
        t.grow_to(1);
        t.freeze(0, TokenTable::NEVER, 0);
        let mut out = Vec::new();
        t.pop_expired(u64::MAX, &mut out);
        assert!(out.is_empty());
        assert!(t.is_frozen(0));
        assert_eq!(t.soft_expire(10), 0, "SR must not touch permanent evictions");
    }

    #[test]
    fn positions_beyond_table_are_active() {
        let t = TokenTable::default();
        assert!(t.is_active(99));
        assert!(!t.is_frozen(99));
    }

    #[test]
    fn thaw_range_covers_prefetch_horizon() {
        let mut t = TokenTable::default();
        t.grow_to(6);
        t.freeze(1, 11, 10);
        t.freeze(2, 13, 10);
        t.freeze(3, 14, 10);
        t.freeze(4, 11, 10);
        let hits: Vec<(u64, usize)> = t.thaw_range(11, 13).collect();
        assert_eq!(hits, vec![(11, 1), (11, 4), (13, 2)]);
    }

    #[test]
    fn soft_expire_spares_already_due_rows() {
        let mut t = TokenTable::default();
        t.grow_to(4);
        t.freeze(0, 11, 10); // due at now+1: untouched by SR
        t.freeze(1, 20, 10);
        t.freeze(2, 30, 10);
        assert_eq!(t.soft_expire(11), 2);
        let mut out = Vec::new();
        t.pop_expired(11, &mut out);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn window_expire_hits_recent_freezes_only() {
        let mut t = TokenTable::default();
        t.grow_to(4);
        t.freeze(0, 100, 2); // old freeze
        t.freeze(1, 100, 9); // recent
        assert_eq!(t.window_expire(3, 10), 1);
        let mut out = Vec::new();
        t.pop_expired(10, &mut out);
        assert_eq!(out, vec![1]);
    }

    #[test]
    fn full_expire_reschedules_queued_rows() {
        let mut t = TokenTable::default();
        t.grow_to(3);
        t.freeze(0, 5, 0);
        t.freeze(1, 50, 0);
        let mut out = Vec::new();
        t.pop_expired(5, &mut out); // pos 0 now queued, out of the index
        assert_eq!(out, vec![0]);
        assert_eq!(t.full_expire(6), 2, "FR touches queued and indexed rows");
        out.clear();
        t.pop_expired(6, &mut out);
        assert_eq!(out, vec![0, 1], "queued row re-reported after FR");
    }

    #[test]
    fn window_epoch_lazily_clears_counters() {
        let mut t = TokenTable::default();
        t.grow_to(2);
        assert_eq!(t.record_detection(0, 1, 100), 1);
        assert_eq!(t.record_detection(0, 2, 100), 2);
        t.clear_windows();
        assert_eq!(t.record_detection(0, 3, 100), 1, "epoch bump resets the count");
        // a position never touched after the bump also starts fresh
        assert_eq!(t.record_detection(1, 3, 100), 1);
    }

    #[test]
    fn force_all_active_resets_everything() {
        let mut t = TokenTable::default();
        t.grow_to(5);
        t.record_detection(2, 1, 100);
        t.freeze(1, 10, 1);
        t.freeze(3, TokenTable::NEVER, 1);
        t.force_all_active();
        assert_eq!(t.active_count(), 5);
        assert_eq!(t.frozen_count(), 0);
        assert_eq!(t.thaw_range(0, u64::MAX - 1).count(), 0);
        assert_eq!(t.record_detection(2, 2, 100), 1, "counters cleared");
    }
}
