//! Per-token state machine shared by all KV policies.

use crate::kv::freeze::DetectionWindow;

/// Lifecycle of a token's KV row (paper §3.3: Active <-> Frozen).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenState {
    /// Row is in the active cache and participates in attention.
    Active,
    /// Row was moved to off-GPU storage; `remaining` steps until the
    /// timer expires and it is restored. `u32::MAX` = permanent
    /// eviction (baselines only — ASR-KF-EGR never does this).
    Frozen { remaining: u32 },
}

#[derive(Debug, Clone)]
pub struct TokenMeta {
    pub state: TokenState,
    /// Low-importance detection history within window W.
    pub window: DetectionWindow,
    /// Total times this token has been frozen (stats/traces).
    pub freezes: u32,
    /// Step at which the current freeze began (WR recovery scope).
    pub frozen_at: u64,
}

impl Default for TokenMeta {
    fn default() -> Self {
        TokenMeta {
            state: TokenState::Active,
            window: DetectionWindow::default(),
            freezes: 0,
            frozen_at: 0,
        }
    }
}

/// Token table: per-position metadata for one sequence.
#[derive(Debug, Default)]
pub struct TokenTable {
    pub meta: Vec<TokenMeta>,
}

impl TokenTable {
    /// Grow the table to cover `len` tokens (new tokens start Active).
    pub fn grow_to(&mut self, len: usize) {
        if self.meta.len() < len {
            self.meta.resize_with(len, TokenMeta::default);
        }
    }

    pub fn len(&self) -> usize {
        self.meta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.meta.is_empty()
    }

    pub fn is_active(&self, pos: usize) -> bool {
        matches!(self.meta.get(pos).map(|m| m.state), Some(TokenState::Active) | None)
    }

    pub fn is_frozen(&self, pos: usize) -> bool {
        matches!(self.meta.get(pos).map(|m| m.state), Some(TokenState::Frozen { .. }))
    }

    pub fn active_count(&self) -> usize {
        self.meta.iter().filter(|m| m.state == TokenState::Active).count()
    }

    pub fn frozen_positions(&self) -> Vec<usize> {
        self.meta
            .iter()
            .enumerate()
            .filter(|(_, m)| matches!(m.state, TokenState::Frozen { .. }))
            .map(|(p, _)| p)
            .collect()
    }

    pub fn freeze(&mut self, pos: usize, duration: u32, step: u64) {
        let m = &mut self.meta[pos];
        debug_assert_eq!(m.state, TokenState::Active, "freezing non-active pos {pos}");
        m.state = TokenState::Frozen { remaining: duration };
        m.freezes += 1;
        m.frozen_at = step;
    }

    pub fn unfreeze(&mut self, pos: usize) {
        let m = &mut self.meta[pos];
        debug_assert!(matches!(m.state, TokenState::Frozen { .. }));
        m.state = TokenState::Active;
    }

    /// Decrement all finite freeze timers; return positions whose timer
    /// just expired (1 -> 0). Positions already at 0 (expired earlier,
    /// awaiting a budget slot to restore) are not re-reported.
    pub fn tick_timers(&mut self) -> Vec<usize> {
        let mut expired = Vec::new();
        for (pos, m) in self.meta.iter_mut().enumerate() {
            if let TokenState::Frozen { remaining } = &mut m.state {
                if *remaining == u32::MAX || *remaining == 0 {
                    continue; // permanent eviction / already awaiting restore
                }
                *remaining -= 1;
                if *remaining == 0 {
                    expired.push(pos);
                }
            }
        }
        expired
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grow_makes_active_tokens() {
        let mut t = TokenTable::default();
        t.grow_to(5);
        assert_eq!(t.active_count(), 5);
        assert!(t.is_active(3));
        t.grow_to(3); // never shrinks
        assert_eq!(t.len(), 5);
    }

    #[test]
    fn freeze_unfreeze_cycle() {
        let mut t = TokenTable::default();
        t.grow_to(4);
        t.freeze(2, 3, 10);
        assert!(t.is_frozen(2));
        assert_eq!(t.active_count(), 3);
        assert_eq!(t.meta[2].freezes, 1);
        assert_eq!(t.meta[2].frozen_at, 10);
        t.unfreeze(2);
        assert!(t.is_active(2));
    }

    #[test]
    fn timers_expire_in_order() {
        let mut t = TokenTable::default();
        t.grow_to(3);
        t.freeze(0, 1, 0);
        t.freeze(1, 2, 0);
        assert_eq!(t.tick_timers(), vec![0]);
        assert_eq!(t.tick_timers(), vec![1]);
        assert!(t.tick_timers().is_empty());
    }

    #[test]
    fn permanent_eviction_never_expires() {
        let mut t = TokenTable::default();
        t.grow_to(1);
        t.freeze(0, u32::MAX, 0);
        for _ in 0..1000 {
            assert!(t.tick_timers().is_empty());
        }
        assert!(t.is_frozen(0));
    }

    #[test]
    fn positions_beyond_table_are_active() {
        let t = TokenTable::default();
        assert!(t.is_active(99));
        assert!(!t.is_frozen(99));
    }
}
