//! Relevance thresholding (paper §3.2).
//!
//! The graph returns Eq.2 scores `s_j` for every active row each step;
//! this module decides which of those constitute a "low-importance
//! detection": active, outside the sliding window of the K most recent
//! tokens, not a pinned sink, and `s_j < tau_eff`.
//!
//! `tau_eff` is either the raw paper threshold (tau=0.5 on LLaMA-3) or,
//! by default, `tau * mean(candidate scores)` — the stand-in model's
//! score scale differs from LLaMA-3's, so relative thresholding keeps
//! the paper's "half as relevant as typical" semantics (DESIGN.md §5).

use crate::config::FreezeConfig;

/// Positions eligible for scoring this step: active, unpinned, and
/// outside the sliding window `[len - window_k, len)`.
pub fn scoreable_positions<'a>(
    cfg: &'a FreezeConfig,
    len: usize,
    is_active: impl Fn(usize) -> bool + 'a,
) -> impl Iterator<Item = usize> + 'a {
    let window_start = len.saturating_sub(cfg.window_k);
    (cfg.n_sink.min(window_start)..window_start).filter(move |&p| is_active(p))
}

/// Effective threshold given this step's candidate scores.
pub fn effective_tau(cfg: &FreezeConfig, candidate_scores: &[f32]) -> f32 {
    if !cfg.relative_tau || candidate_scores.is_empty() {
        return cfg.tau;
    }
    let mean = candidate_scores.iter().sum::<f32>() / candidate_scores.len() as f32;
    cfg.tau * mean
}

/// Detect low-importance positions among an already-enumerated
/// candidate walk, writing (position, score) pairs with
/// `score < tau_eff` into `out` (cleared first). `out` doubles as the
/// candidate scratch, so a caller that keeps it across steps pays no
/// per-step allocation — the policy hot path feeds this from the token
/// table's active-position index instead of filtering the full range.
///
/// The relative-tau mean is accumulated in candidate order, so callers
/// that enumerate the same candidate set get bit-identical thresholds
/// regardless of how the walk is implemented (the oracle-equivalence
/// property tests rely on this).
pub fn detect_low_importance_into(
    cfg: &FreezeConfig,
    scores: &[f32],
    candidates: impl Iterator<Item = usize>,
    out: &mut Vec<(usize, f32)>,
) {
    out.clear();
    out.extend(candidates.map(|p| (p, scores[p])));
    if out.is_empty() {
        return;
    }
    let tau_eff = if cfg.relative_tau {
        let mean = out.iter().map(|&(_, s)| s).sum::<f32>() / out.len() as f32;
        cfg.tau * mean
    } else {
        cfg.tau
    };
    out.retain(|&(_, s)| s < tau_eff);
}

/// Detect low-importance positions: returns (position, score) pairs
/// with score < tau_eff among scoreable positions. Allocating
/// convenience wrapper over [`detect_low_importance_into`] (the
/// brute-force oracle and tests use it; the indexed policy reuses a
/// scratch buffer).
pub fn detect_low_importance(
    cfg: &FreezeConfig,
    scores: &[f32],
    len: usize,
    is_active: impl Fn(usize) -> bool + Copy,
) -> Vec<(usize, f32)> {
    let mut out = Vec::new();
    detect_low_importance_into(cfg, scores, scoreable_positions(cfg, len, is_active), &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> FreezeConfig {
        FreezeConfig { window_k: 4, n_sink: 2, relative_tau: false, tau: 0.5, ..Default::default() }
    }

    #[test]
    fn window_and_sinks_excluded() {
        let c = cfg();
        // len=10, window covers 6..10, sinks 0..2 -> scoreable = 2..6
        let pos: Vec<usize> = scoreable_positions(&c, 10, |_| true).collect();
        assert_eq!(pos, vec![2, 3, 4, 5]);
    }

    #[test]
    fn short_context_has_no_candidates() {
        let c = cfg();
        let pos: Vec<usize> = scoreable_positions(&c, 4, |_| true).collect();
        assert!(pos.is_empty());
        let pos: Vec<usize> = scoreable_positions(&c, 1, |_| true).collect();
        assert!(pos.is_empty());
    }

    #[test]
    fn frozen_positions_not_rescored() {
        let c = cfg();
        let pos: Vec<usize> = scoreable_positions(&c, 10, |p| p != 3).collect();
        assert_eq!(pos, vec![2, 4, 5]);
    }

    #[test]
    fn absolute_tau_detection() {
        let c = cfg();
        let mut scores = vec![1.0f32; 10];
        scores[2] = 0.1; // low
        scores[5] = 0.49; // low
        scores[7] = 0.0; // inside window - must NOT be detected
        let det = detect_low_importance(&c, &scores, 10, |_| true);
        let positions: Vec<usize> = det.iter().map(|d| d.0).collect();
        assert_eq!(positions, vec![2, 5]);
    }

    #[test]
    fn relative_tau_scales_with_score_magnitude() {
        let c = FreezeConfig { relative_tau: true, ..cfg() };
        // scores 100x larger than tau=0.5; mean=100 -> tau_eff=50
        let mut scores = vec![100.0f32; 10];
        scores[3] = 10.0;
        let det = detect_low_importance(&c, &scores, 10, |_| true);
        assert_eq!(det.len(), 1);
        assert_eq!(det[0].0, 3);
    }

    #[test]
    fn empty_candidates_return_raw_tau() {
        let c = FreezeConfig { relative_tau: true, ..cfg() };
        assert_eq!(effective_tau(&c, &[]), c.tau);
    }
}
