//! The `KvPolicy` trait — the interface between the engine's decode
//! loop and a KV-cache management strategy — plus the paper's
//! ASR-KF-EGR policy. Baselines (Full KV, H2O, StreamingLLM) implement
//! the same trait in `crate::baselines` so every bench drives each
//! method through the identical engine.

use crate::config::FreezeConfig;
use crate::kv::freeze::freeze_duration;
use crate::kv::relevance::detect_low_importance;
use crate::kv::state::{TokenState, TokenTable};

/// How many steps before a predicted thaw a frozen row becomes a
/// prefetch hint (`Plan::prefetch`) for the tiered store's staging
/// path. Small: hints are cheap (a host-side tier move at most) and
/// the tiered store de-duplicates already-hot rows.
pub const PREFETCH_HORIZON: u32 = 3;

/// What the engine must do before the next decode step.
///
/// Position lists are sorted strictly ascending (policies call
/// [`Plan::normalize`] before returning) so the engine can coalesce
/// contiguous runs into batched span transfers
/// (`engine::layout::coalesce_runs` + `gather_rows`/`scatter_rows`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Plan {
    /// Rows to move active -> frozen storage (gathered + zeroed by the
    /// graph; payload stashed by the engine). Sorted ascending.
    pub freeze: Vec<usize>,
    /// Rows to move frozen storage -> active (scattered by the graph).
    /// Sorted ascending.
    pub restore: Vec<usize>,
    /// If true, frozen payloads are DISCARDED (irreversible eviction —
    /// baselines only; ASR-KF-EGR always keeps payloads).
    pub drop_payload: bool,
    /// Tier hint, parallel to `freeze`: the step at which each frozen
    /// row is predicted to thaw (freeze step + Eq.3 duration). Drives
    /// hot/cold admission in `offload::TieredStore`. Empty for
    /// drop-payload baselines.
    pub freeze_thaw_eta: Vec<u64>,
    /// Tier hint: `(position, predicted thaw step)` for frozen rows
    /// expected to restore within `PREFETCH_HORIZON` steps — the store
    /// stages these back into its hot tier ahead of the actual restore
    /// and refreshes its stored thaw prediction (recovery unfreezes
    /// rewrite timers, so stash-time etas go stale).
    pub prefetch: Vec<(usize, u64)>,
}

impl Plan {
    /// Sort the position lists ascending — `freeze_thaw_eta` follows
    /// `freeze` through the permutation — so the engine can coalesce
    /// contiguous runs into single span copies per plane. `prefetch`
    /// keeps its soonest-thaw order (it feeds the staging queue, not a
    /// batched transfer). Every policy calls this before returning a
    /// plan; the engine debug-asserts the invariant.
    pub fn normalize(&mut self) {
        debug_assert!(
            self.freeze_thaw_eta.is_empty() || self.freeze_thaw_eta.len() == self.freeze.len(),
            "freeze_thaw_eta must be empty or parallel to freeze ({} vs {})",
            self.freeze_thaw_eta.len(),
            self.freeze.len()
        );
        self.restore.sort_unstable();
        if self.freeze_thaw_eta.len() == self.freeze.len() {
            let mut zipped: Vec<(usize, u64)> = self
                .freeze
                .iter()
                .copied()
                .zip(self.freeze_thaw_eta.iter().copied())
                .collect();
            zipped.sort_unstable_by_key(|&(pos, _)| pos);
            for (i, (pos, eta)) in zipped.into_iter().enumerate() {
                self.freeze[i] = pos;
                self.freeze_thaw_eta[i] = eta;
            }
        } else {
            self.freeze.sort_unstable();
        }
    }
}

/// Scope of a recovery-triggered unfreeze (paper §3.6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnfreezeScope {
    /// SR: tokens with remaining duration > 1.
    Soft,
    /// WR: tokens frozen within the last `n` steps.
    Window { n: u64, now: u64 },
    /// FR: every frozen token; also clears all detection counters.
    Full,
}

pub trait KvPolicy {
    fn name(&self) -> &'static str;

    /// Called once after prefill with the last query's Eq.2 relevance
    /// scores over the prompt (`scores[0..len]`).
    fn on_prefill(&mut self, scores: &[f32], len: usize);

    /// Called before decode step `step`; `len` tokens exist so far.
    /// Returned lists must each respect the engine's r_budget.
    fn plan(&mut self, step: u64, len: usize, r_budget: usize) -> Plan;

    /// Called after the decode step with fresh Eq.2 scores
    /// (`scores[pos]` valid for pos < len; frozen rows score 0).
    fn observe(&mut self, step: u64, scores: &[f32], len: usize);

    /// Recovery request: schedule unfreezes (applied by later plans).
    /// Returns the number of tokens scheduled.
    fn request_unfreeze(&mut self, scope: UnfreezeScope) -> usize;

    /// Force-reset after an engine-level emergency restore (RR): all
    /// tokens active, counters cleared.
    fn force_all_active(&mut self);

    fn active_count(&self) -> usize;
    fn frozen_count(&self) -> usize {
        self.frozen_positions().len()
    }
    fn frozen_positions(&self) -> Vec<usize>;
    fn is_frozen(&self, pos: usize) -> bool;
}

// ---------------------------------------------------------------------------
// ASR-KF-EGR (the paper's Algorithm 1)

pub struct AsrKfPolicy {
    cfg: FreezeConfig,
    pub table: TokenTable,
    /// Freeze candidates queued by `observe` (score-ascending), applied
    /// by the next `plan` within the transfer budget.
    pending_freeze: Vec<(usize, u32, f32)>, // (pos, duration, score)
    /// Restores whose timers expired but exceeded the budget.
    pending_restore: std::collections::VecDeque<usize>,
    len: usize,
    pub stat_freezes: u64,
    pub stat_restores: u64,
}

impl AsrKfPolicy {
    pub fn new(cfg: FreezeConfig) -> Self {
        AsrKfPolicy {
            cfg,
            table: TokenTable::default(),
            pending_freeze: Vec::new(),
            pending_restore: std::collections::VecDeque::new(),
            len: 0,
            stat_freezes: 0,
            stat_restores: 0,
        }
    }

    fn detect(&mut self, step: u64, scores: &[f32], len: usize) {
        self.table.grow_to(len);
        self.len = len;
        let table = &self.table;
        let detections = detect_low_importance(&self.cfg, scores, len, |p| table.is_active(p));
        for (pos, score) in detections {
            let c = self.table.meta[pos].window.record(step, self.cfg.history_w as u64);
            let d = freeze_duration(c, self.cfg.softness_k);
            if d > 0 && !self.pending_freeze.iter().any(|&(p, _, _)| p == pos) {
                self.pending_freeze.push((pos, d, score));
            }
        }
        // freeze least-relevant first when the budget binds
        self.pending_freeze
            .sort_by(|a, b| a.2.partial_cmp(&b.2).unwrap_or(std::cmp::Ordering::Equal));
    }
}

impl KvPolicy for AsrKfPolicy {
    fn name(&self) -> &'static str {
        "asrkf"
    }

    fn on_prefill(&mut self, scores: &[f32], len: usize) {
        // Seed detection counters from the prompt's relevance profile;
        // freezing itself only begins during decode.
        self.detect(0, scores, len);
    }

    fn plan(&mut self, step: u64, len: usize, r_budget: usize) -> Plan {
        self.table.grow_to(len);

        // Rolling re-evaluation (§3.5): decrement timers, queue expired.
        for pos in self.table.tick_timers() {
            self.pending_restore.push_back(pos);
        }

        // Budget-capped restores (oldest first).
        let mut restore = Vec::new();
        while restore.len() < r_budget {
            match self.pending_restore.pop_front() {
                Some(pos) if self.table.is_frozen(pos) => {
                    self.table.unfreeze(pos);
                    restore.push(pos);
                }
                Some(_) => continue, // already active (e.g. recovery raced)
                None => break,
            }
        }
        self.stat_restores += restore.len() as u64;

        // Budget-capped freezes (lowest score first).
        let window_start = len.saturating_sub(self.cfg.window_k);
        let mut freeze = Vec::new();
        let mut freeze_thaw_eta = Vec::new();
        let mut rest = Vec::new();
        for (pos, d, score) in self.pending_freeze.drain(..) {
            let eligible = self.table.is_active(pos)
                && pos < window_start
                && pos >= self.cfg.n_sink
                && !restore.contains(&pos);
            if !eligible {
                continue; // stale candidate — drop
            }
            if freeze.len() < r_budget {
                self.table.freeze(pos, d, step);
                freeze.push(pos);
                // tier hint: the timer ticks down once per plan, so the
                // row is predicted back in `d` steps
                freeze_thaw_eta.push(step + d as u64);
            } else {
                rest.push((pos, d, score));
            }
        }
        self.pending_freeze = rest;
        self.stat_freezes += freeze.len() as u64;

        // Tier hint: rows about to thaw (the store stages them hot so
        // the restore never dequantizes inside the decode step).
        let mut prefetch: Vec<(u32, usize)> = self
            .table
            .meta
            .iter()
            .enumerate()
            .filter_map(|(pos, m)| match m.state {
                TokenState::Frozen { remaining }
                    if (1..=PREFETCH_HORIZON).contains(&remaining) =>
                {
                    Some((remaining, pos))
                }
                _ => None,
            })
            .collect();
        prefetch.sort_unstable();
        let prefetch = prefetch
            .into_iter()
            .take(r_budget)
            .map(|(rem, p)| (p, step + rem as u64))
            .collect();

        let mut plan = Plan { freeze, restore, drop_payload: false, freeze_thaw_eta, prefetch };
        plan.normalize();
        plan
    }

    fn observe(&mut self, step: u64, scores: &[f32], len: usize) {
        self.detect(step, scores, len);
    }

    fn request_unfreeze(&mut self, scope: UnfreezeScope) -> usize {
        let mut n = 0;
        for pos in 0..self.table.len() {
            let m = &mut self.table.meta[pos];
            let hit = match (m.state, scope) {
                (TokenState::Frozen { remaining }, UnfreezeScope::Soft) => remaining > 1,
                (TokenState::Frozen { .. }, UnfreezeScope::Window { n, now }) => {
                    m.frozen_at + n >= now
                }
                (TokenState::Frozen { .. }, UnfreezeScope::Full) => true,
                _ => false,
            };
            if hit {
                // expire the timer; the normal tick/restore path (with
                // its transfer budget) brings the row back
                m.state = TokenState::Frozen { remaining: 1 };
                n += 1;
            }
            if matches!(scope, UnfreezeScope::Full) {
                m.window.clear();
            }
        }
        if matches!(scope, UnfreezeScope::Full) {
            self.pending_freeze.clear();
        }
        n
    }

    fn force_all_active(&mut self) {
        for m in &mut self.table.meta {
            m.state = TokenState::Active;
            m.window.clear();
        }
        self.pending_freeze.clear();
        self.pending_restore.clear();
    }

    fn active_count(&self) -> usize {
        // tokens beyond the table (not yet observed) are active
        self.table.active_count() + self.len.saturating_sub(self.table.len())
    }

    fn frozen_positions(&self) -> Vec<usize> {
        self.table.frozen_positions()
    }

    fn is_frozen(&self, pos: usize) -> bool {
        self.table.is_frozen(pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> FreezeConfig {
        FreezeConfig {
            window_k: 4,
            n_sink: 1,
            tau: 0.5,
            softness_k: 2.0,
            history_w: 64,
            r_budget: 4,
            relative_tau: false,
        }
    }

    /// Feed low scores for `pos` until it freezes; returns steps taken.
    fn freeze_pos_by_detections(p: &mut AsrKfPolicy, pos: usize, len: usize) -> u64 {
        for step in 1..100 {
            let mut scores = vec![1.0f32; len];
            scores[pos] = 0.0;
            p.observe(step, &scores, len);
            let plan = p.plan(step + 1, len, 4);
            if plan.freeze.contains(&pos) {
                return step;
            }
        }
        panic!("pos {pos} never froze");
    }

    #[test]
    fn needs_four_detections_to_freeze() {
        // d = floor(sqrt(c)/2) > 0 requires c >= 4
        let mut p = AsrKfPolicy::new(cfg());
        let steps = freeze_pos_by_detections(&mut p, 2, 12);
        assert_eq!(steps, 4);
    }

    #[test]
    fn frozen_token_restores_after_duration() {
        let mut p = AsrKfPolicy::new(cfg());
        freeze_pos_by_detections(&mut p, 2, 12);
        assert!(p.is_frozen(2));
        // c=4 -> d=1: one tick later the timer expires and it restores
        let plan = p.plan(50, 12, 4);
        assert!(plan.restore.contains(&2));
        assert!(!p.is_frozen(2));
    }

    #[test]
    fn sink_and_window_tokens_never_freeze() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 12;
        let mut total_freezes = 0usize;
        for step in 1..40 {
            let scores = vec![0.0f32; len]; // everything looks irrelevant
            p.observe(step, &scores, len);
            let plan = p.plan(step, len, 16);
            total_freezes += plan.freeze.len();
            assert!(!plan.freeze.contains(&0), "sink frozen at step {step}");
            for w in len - 4..len {
                assert!(!plan.freeze.contains(&w), "window pos {w} frozen");
            }
        }
        // but middle tokens did freeze (and may oscillate back)
        assert!(total_freezes > 0);
    }

    #[test]
    fn budget_caps_freeze_rate() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 40;
        for step in 1..=4 {
            p.observe(step, &vec![0.0f32; len], len);
        }
        let plan = p.plan(5, len, 4);
        assert_eq!(plan.freeze.len(), 4); // 35 candidates, budget 4
        let plan = p.plan(6, len, 4);
        assert_eq!(plan.freeze.len(), 4); // queue drains over steps
    }

    #[test]
    fn soft_reset_unfreezes_long_timers_only() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 30;
        // accumulate many detections so some durations exceed 1
        for step in 1..=40 {
            p.observe(step, &vec![0.0f32; len], len);
            p.plan(step, len, 16);
        }
        let frozen_before = p.frozen_count();
        assert!(frozen_before > 0);
        let n = p.request_unfreeze(UnfreezeScope::Soft);
        // Soft touches only remaining > 1 tokens; afterwards all frozen
        // tokens have remaining <= 1, so one plan restores up to budget
        let plan = p.plan(100, len, 64);
        assert!(plan.restore.len() >= n.min(1));
    }

    #[test]
    fn full_reset_clears_counters() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 20;
        for step in 1..=10 {
            p.observe(step, &vec![0.0f32; len], len);
            p.plan(step, len, 16);
        }
        p.request_unfreeze(UnfreezeScope::Full);
        let plan = p.plan(11, len, 64);
        assert_eq!(p.frozen_count(), 0, "all restored after FR, {plan:?}");
        // counters cleared: next detection is c=1 -> d=0 -> no freeze
        p.observe(12, &vec![0.0f32; len], len);
        let plan = p.plan(13, len, 64);
        assert!(plan.freeze.is_empty());
    }

    #[test]
    fn thaw_eta_hint_parallels_freeze_list() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 40;
        for step in 1..=6 {
            p.observe(step, &vec![0.0f32; len], len);
        }
        let plan = p.plan(7, len, 8);
        assert!(!plan.freeze.is_empty());
        assert_eq!(plan.freeze.len(), plan.freeze_thaw_eta.len());
        for &eta in &plan.freeze_thaw_eta {
            assert!(eta > 7, "thaw eta must be in the future, got {eta}");
        }
    }

    #[test]
    fn prefetch_hints_cover_imminent_thaws() {
        let mut p = AsrKfPolicy::new(cfg());
        freeze_pos_by_detections(&mut p, 2, 12);
        assert!(p.is_frozen(2));
        // c=4 -> d=1: pos 2 thaws on the next tick, so it must be a
        // prefetch hint before the restoring plan
        let plan = p.plan(40, 12, 4);
        assert!(
            plan.restore.contains(&2) || plan.prefetch.iter().any(|&(p, _)| p == 2),
            "imminent thaw neither restored nor hinted: {plan:?}"
        );
    }

    #[test]
    fn normalize_keeps_eta_parallel_to_freeze() {
        let mut p = Plan {
            freeze: vec![9, 2, 5],
            restore: vec![7, 1],
            freeze_thaw_eta: vec![90, 20, 50],
            ..Plan::default()
        };
        p.normalize();
        assert_eq!(p.freeze, vec![2, 5, 9]);
        assert_eq!(p.freeze_thaw_eta, vec![20, 50, 90]);
        assert_eq!(p.restore, vec![1, 7]);
        // drop-payload plans have no eta list: freeze still sorts
        let mut q = Plan { freeze: vec![3, 1], drop_payload: true, ..Plan::default() };
        q.normalize();
        assert_eq!(q.freeze, vec![1, 3]);
    }

    #[test]
    fn plans_are_sorted_for_run_coalescing() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 40;
        for step in 1..=30 {
            p.observe(step, &vec![0.0f32; len], len);
            let plan = p.plan(step, len, 8);
            assert!(plan.freeze.windows(2).all(|w| w[0] < w[1]), "freeze unsorted: {plan:?}");
            assert!(plan.restore.windows(2).all(|w| w[0] < w[1]), "restore unsorted: {plan:?}");
        }
    }

    #[test]
    fn restore_and_freeze_disjoint() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 30;
        for step in 1..=50 {
            p.observe(step, &vec![0.0f32; len], len);
            let plan = p.plan(step, len, 8);
            for r in &plan.restore {
                assert!(!plan.freeze.contains(r), "pos {r} in both lists");
            }
        }
    }

    #[test]
    fn active_count_conserved() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 25;
        for step in 1..=60 {
            p.observe(step, &vec![0.1f32; len], len);
            p.plan(step, len, 8);
            assert_eq!(p.active_count() + p.frozen_count(), len);
        }
    }
}
