//! The `KvPolicy` trait — the interface between the engine's decode
//! loop and a KV-cache management strategy — plus the paper's
//! ASR-KF-EGR policy. Baselines (Full KV, H2O, StreamingLLM) implement
//! the same trait in `crate::baselines` so every bench drives each
//! method through the identical engine.
//!
//! The ASR-KF-EGR implementation here is the *indexed* control plane
//! (see `README.md` in this directory): every per-step decision is
//! answered by the token table's thaw/active/frozen indexes and a
//! score-ordered candidate heap, so `plan` + `observe` cost
//! O(window_k + r_budget + expiries·log n) instead of O(context_len).
//! The retained brute-force implementation lives in
//! [`crate::kv::oracle`] and is property-tested plan-for-plan
//! identical (`tests/prop_policy.rs`).

use std::collections::BinaryHeap;

use crate::config::FreezeConfig;
use crate::kv::freeze::freeze_duration;
use crate::kv::relevance::detect_low_importance_into;
use crate::kv::state::TokenTable;
use crate::util::bitset::BitSet;

/// How many steps before a predicted thaw a frozen row becomes a
/// prefetch hint (`Plan::prefetch`) for the tiered store's staging
/// path. Small: hints are cheap (a host-side tier move at most) and
/// the tiered store de-duplicates already-hot rows.
pub const PREFETCH_HORIZON: u32 = 3;

/// What the engine must do before the next decode step.
///
/// Position lists are sorted strictly ascending (policies establish
/// the invariant before returning, via [`Plan::normalize`] or by
/// construction) so the engine can coalesce contiguous runs into
/// batched span transfers (`engine::layout::coalesce_runs` +
/// `gather_rows`/`scatter_rows`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Plan {
    /// Rows to move active -> frozen storage (gathered + zeroed by the
    /// graph; payload stashed by the engine). Sorted ascending.
    pub freeze: Vec<usize>,
    /// Rows to move frozen storage -> active (scattered by the graph).
    /// Sorted ascending.
    pub restore: Vec<usize>,
    /// If true, frozen payloads are DISCARDED (irreversible eviction —
    /// baselines only; ASR-KF-EGR always keeps payloads).
    pub drop_payload: bool,
    /// Tier hint, parallel to `freeze`: the step at which each frozen
    /// row is predicted to thaw (freeze step + Eq.3 duration). Drives
    /// hot/cold admission in `offload::TieredStore`. Empty for
    /// drop-payload baselines.
    pub freeze_thaw_eta: Vec<u64>,
    /// Tier hint: `(position, predicted thaw step)` for frozen rows
    /// expected to restore within `PREFETCH_HORIZON` steps — the store
    /// stages these back into its hot tier ahead of the actual restore
    /// and refreshes its stored thaw prediction (recovery unfreezes
    /// rewrite timers, so stash-time etas go stale).
    pub prefetch: Vec<(usize, u64)>,
}

impl Plan {
    /// Reset to the empty plan, keeping list capacity — engines hold
    /// one `Plan` buffer and refill it each step
    /// ([`KvPolicy::plan_into`]), so the per-step lists never
    /// reallocate in steady state.
    pub fn clear(&mut self) {
        self.freeze.clear();
        self.restore.clear();
        self.freeze_thaw_eta.clear();
        self.prefetch.clear();
        self.drop_payload = false;
    }

    /// Sort the position lists ascending — `freeze_thaw_eta` follows
    /// `freeze` through the permutation — so the engine can coalesce
    /// contiguous runs into single span copies per plane. `prefetch`
    /// keeps its soonest-thaw order (it feeds the staging queue, not a
    /// batched transfer). Policies that build their lists out of order
    /// call this before returning; the engine debug-asserts the
    /// invariant.
    pub fn normalize(&mut self) {
        debug_assert!(
            self.freeze_thaw_eta.is_empty() || self.freeze_thaw_eta.len() == self.freeze.len(),
            "freeze_thaw_eta must be empty or parallel to freeze ({} vs {})",
            self.freeze_thaw_eta.len(),
            self.freeze.len()
        );
        self.restore.sort_unstable();
        if self.freeze_thaw_eta.len() == self.freeze.len() {
            let mut zipped: Vec<(usize, u64)> = self
                .freeze
                .iter()
                .copied()
                .zip(self.freeze_thaw_eta.iter().copied())
                .collect();
            zipped.sort_unstable_by_key(|&(pos, _)| pos);
            for (i, (pos, eta)) in zipped.into_iter().enumerate() {
                self.freeze[i] = pos;
                self.freeze_thaw_eta[i] = eta;
            }
        } else {
            self.freeze.sort_unstable();
        }
    }
}

/// Scope of a recovery-triggered unfreeze (paper §3.6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnfreezeScope {
    /// SR: tokens whose thaw lies more than one step out.
    Soft,
    /// WR: tokens frozen within the last `n` steps.
    Window { n: u64, now: u64 },
    /// FR: every frozen token; also clears all detection counters.
    Full,
}

pub trait KvPolicy {
    fn name(&self) -> &'static str;

    /// Called once after prefill with the last query's Eq.2 relevance
    /// scores over the prompt (`scores[0..len]`).
    fn on_prefill(&mut self, scores: &[f32], len: usize);

    /// Called before decode step `step`; `len` tokens exist so far.
    /// Clears `out` and fills it with this step's plan; the returned
    /// lists must each respect the engine's r_budget. Engines keep one
    /// `Plan` buffer alive across steps so plan construction is
    /// allocation-free in steady state.
    fn plan_into(&mut self, step: u64, len: usize, r_budget: usize, out: &mut Plan);

    /// Allocating convenience wrapper over [`KvPolicy::plan_into`]
    /// (tests and one-shot callers).
    fn plan(&mut self, step: u64, len: usize, r_budget: usize) -> Plan {
        let mut out = Plan::default();
        self.plan_into(step, len, r_budget, &mut out);
        out
    }

    /// Called after the decode step with fresh Eq.2 scores
    /// (`scores[pos]` valid for pos < len; frozen rows score 0).
    fn observe(&mut self, step: u64, scores: &[f32], len: usize);

    /// Recovery request: schedule unfreezes (applied by later plans).
    /// Returns the number of tokens scheduled.
    fn request_unfreeze(&mut self, scope: UnfreezeScope) -> usize;

    /// Force-reset after an engine-level emergency restore (RR): all
    /// tokens active, counters cleared.
    fn force_all_active(&mut self);

    fn active_count(&self) -> usize;
    fn frozen_count(&self) -> usize {
        self.frozen_positions().len()
    }
    fn frozen_positions(&self) -> Vec<usize>;
    fn is_frozen(&self, pos: usize) -> bool;
}

/// Map an f32 score onto a total order that matches `partial_cmp` for
/// non-NaN values (sign-magnitude to biased-unsigned). The candidate
/// heap and the brute-force oracle both sort by `(score_key, pos)`, so
/// freeze selection under a binding budget is deterministic and
/// identical across implementations.
pub(crate) fn score_order_key(s: f32) -> u32 {
    let b = s.to_bits();
    if b & 0x8000_0000 != 0 {
        !b
    } else {
        b | 0x8000_0000
    }
}

/// Score-ordered freeze-candidate queue: a min-heap on
/// `(score_key, pos)` plus a membership bitset, replacing the old
/// `Vec` that paid an O(pending) dedup probe per detection and a full
/// re-sort per observe. Push/pop are O(log m); membership is O(1).
#[derive(Default)]
struct CandidateQueue {
    /// `Reverse` makes `BinaryHeap` a min-heap: lowest (score, pos)
    /// pops first — freeze the least-relevant row when the budget
    /// binds, ties broken by position.
    heap: BinaryHeap<std::cmp::Reverse<(u32, usize, u32)>>,
    member: BitSet,
}

impl CandidateQueue {
    fn grow(&mut self, len: usize) {
        self.member.grow(len);
    }

    /// Queue `pos` with Eq.3 duration `d` unless already pending
    /// (keep-first: the duration computed at first queueing sticks,
    /// matching the original dedup semantics).
    fn push(&mut self, pos: usize, d: u32, score: f32) {
        if self.member.get(pos) {
            return;
        }
        self.member.set(pos);
        self.heap.push(std::cmp::Reverse((score_order_key(score), pos, d)));
    }

    /// Lowest-score candidate, or None.
    fn pop(&mut self) -> Option<(usize, u32)> {
        let std::cmp::Reverse((_, pos, d)) = self.heap.pop()?;
        self.member.clear(pos);
        Some((pos, d))
    }

    fn clear(&mut self) {
        self.heap.clear();
        self.member.clear_all();
    }
}

// ---------------------------------------------------------------------------
// ASR-KF-EGR (the paper's Algorithm 1), indexed control plane

pub struct AsrKfPolicy {
    cfg: FreezeConfig,
    pub table: TokenTable,
    /// Freeze candidates queued by `observe`, popped score-ascending by
    /// `plan` within the transfer budget.
    pending: CandidateQueue,
    /// Restores whose timers expired but exceeded the budget, tagged
    /// with the freeze-episode counter at expiry time: recovery can
    /// re-report a queued row (duplicate entries), and a row restored
    /// through one entry may be re-frozen before a stale duplicate
    /// reaches the queue front — the tag keeps that stale entry from
    /// prematurely thawing the new freeze episode.
    pending_restore: std::collections::VecDeque<(usize, u32)>,
    len: usize,
    /// Most recent step seen by `plan`/`observe` — the "now" for
    /// recovery scopes.
    last_step: u64,
    pub stat_freezes: u64,
    pub stat_restores: u64,
    // --- per-step scratch, reused across plans (no steady-state allocs)
    expired: Vec<usize>,
    freeze_buf: Vec<(usize, u64)>,
    restore_marks: BitSet,
    detections: Vec<(usize, f32)>,
}

impl AsrKfPolicy {
    pub fn new(cfg: FreezeConfig) -> Self {
        AsrKfPolicy {
            cfg,
            table: TokenTable::default(),
            pending: CandidateQueue::default(),
            pending_restore: std::collections::VecDeque::new(),
            len: 0,
            last_step: 0,
            stat_freezes: 0,
            stat_restores: 0,
            expired: Vec::new(),
            freeze_buf: Vec::new(),
            restore_marks: BitSet::new(),
            detections: Vec::new(),
        }
    }

    fn detect(&mut self, step: u64, scores: &[f32], len: usize) {
        self.table.grow_to(len);
        self.len = len;
        self.last_step = step;
        let window_start = len.saturating_sub(self.cfg.window_k);
        let lo = self.cfg.n_sink.min(window_start);
        // Candidate walk over the active-position index: cost tracks
        // the number of active candidates, not the full position range.
        let table = &self.table;
        let mut detections = std::mem::take(&mut self.detections);
        detect_low_importance_into(
            &self.cfg,
            scores,
            table.active_range(lo, window_start),
            &mut detections,
        );
        self.pending.grow(len);
        for &(pos, score) in &detections {
            let c = self.table.record_detection(pos, step, self.cfg.history_w as u64);
            let d = freeze_duration(c, self.cfg.softness_k);
            if d > 0 {
                self.pending.push(pos, d, score);
            }
        }
        self.detections = detections;
    }
}

impl KvPolicy for AsrKfPolicy {
    fn name(&self) -> &'static str {
        "asrkf"
    }

    fn on_prefill(&mut self, scores: &[f32], len: usize) {
        // Seed detection counters from the prompt's relevance profile;
        // freezing itself only begins during decode.
        self.detect(0, scores, len);
    }

    fn plan_into(&mut self, step: u64, len: usize, r_budget: usize, out: &mut Plan) {
        out.clear();
        self.table.grow_to(len);
        self.len = len;
        self.last_step = step;

        // Rolling re-evaluation (§3.5): pop actually-expired timers
        // from the thaw index — O(expiries·log n), not O(len).
        self.expired.clear();
        self.table.pop_expired(step, &mut self.expired);
        let table = &self.table;
        self.pending_restore.extend(self.expired.drain(..).map(|p| (p, table.freezes(p))));

        // Budget-capped restores (oldest first). An entry restores only
        // the freeze episode it was queued for: stale entries (row
        // already restored, possibly re-frozen since) are dropped.
        while out.restore.len() < r_budget {
            match self.pending_restore.pop_front() {
                Some((pos, gen))
                    if self.table.is_frozen(pos) && self.table.freezes(pos) == gen =>
                {
                    self.table.unfreeze(pos);
                    out.restore.push(pos);
                }
                Some(_) => continue, // stale entry (recovery raced / re-frozen)
                None => break,
            }
        }
        out.restore.sort_unstable();
        self.restore_marks.grow(len);
        for &pos in &out.restore {
            self.restore_marks.set(pos);
        }
        self.stat_restores += out.restore.len() as u64;

        // Budget-capped freezes, lowest score first off the candidate
        // heap; candidates beyond the budget stay queued. Eligibility
        // is re-checked at pop (stale entries drop), and the restore
        // probe is an O(1) bitset lookup instead of a list scan.
        let window_start = len.saturating_sub(self.cfg.window_k);
        self.freeze_buf.clear();
        while self.freeze_buf.len() < r_budget {
            let Some((pos, d)) = self.pending.pop() else { break };
            let eligible = self.table.is_active(pos)
                && pos < window_start
                && pos >= self.cfg.n_sink
                && !self.restore_marks.get(pos);
            if !eligible {
                continue; // stale candidate — drop
            }
            // tier hint: the row's timer expires at absolute step
            // `step + d` (Eq.3 duration from the freeze step)
            self.table.freeze(pos, step + d as u64, step);
            self.freeze_buf.push((pos, step + d as u64));
        }
        self.freeze_buf.sort_unstable();
        for &(pos, eta) in &self.freeze_buf {
            out.freeze.push(pos);
            out.freeze_thaw_eta.push(eta);
        }
        self.stat_freezes += out.freeze.len() as u64;

        // Tier hint: rows about to thaw (the store stages them hot so
        // the restore never dequantizes inside the decode step) — a
        // range query over the thaw index, soonest first.
        for (eta, pos) in self.table.thaw_range(step + 1, step + PREFETCH_HORIZON as u64) {
            if out.prefetch.len() >= r_budget {
                break;
            }
            out.prefetch.push((pos, eta));
        }

        for &pos in &out.restore {
            self.restore_marks.clear(pos);
        }
        debug_assert!(out.freeze.windows(2).all(|w| w[0] < w[1]));
        debug_assert!(out.restore.windows(2).all(|w| w[0] < w[1]));
    }

    fn observe(&mut self, step: u64, scores: &[f32], len: usize) {
        self.detect(step, scores, len);
    }

    fn request_unfreeze(&mut self, scope: UnfreezeScope) -> usize {
        match scope {
            UnfreezeScope::Soft => self.table.soft_expire(self.last_step),
            UnfreezeScope::Window { n, now } => self.table.window_expire(n, now),
            UnfreezeScope::Full => {
                let n = self.table.full_expire(self.last_step);
                self.pending.clear();
                n
            }
        }
    }

    fn force_all_active(&mut self) {
        self.table.force_all_active();
        self.pending.clear();
        self.pending_restore.clear();
    }

    fn active_count(&self) -> usize {
        // tokens beyond the table (not yet observed) are active
        self.table.active_count() + self.len.saturating_sub(self.table.len())
    }

    fn frozen_count(&self) -> usize {
        self.table.frozen_count()
    }

    fn frozen_positions(&self) -> Vec<usize> {
        self.table.frozen_positions()
    }

    fn is_frozen(&self, pos: usize) -> bool {
        self.table.is_frozen(pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> FreezeConfig {
        FreezeConfig {
            window_k: 4,
            n_sink: 1,
            tau: 0.5,
            softness_k: 2.0,
            history_w: 64,
            r_budget: 4,
            relative_tau: false,
        }
    }

    /// Feed low scores for `pos` until it freezes; returns steps taken.
    fn freeze_pos_by_detections(p: &mut AsrKfPolicy, pos: usize, len: usize) -> u64 {
        for step in 1..100 {
            let mut scores = vec![1.0f32; len];
            scores[pos] = 0.0;
            p.observe(step, &scores, len);
            let plan = p.plan(step + 1, len, 4);
            if plan.freeze.contains(&pos) {
                return step;
            }
        }
        panic!("pos {pos} never froze");
    }

    #[test]
    fn needs_four_detections_to_freeze() {
        // d = floor(sqrt(c)/2) > 0 requires c >= 4
        let mut p = AsrKfPolicy::new(cfg());
        let steps = freeze_pos_by_detections(&mut p, 2, 12);
        assert_eq!(steps, 4);
    }

    #[test]
    fn frozen_token_restores_after_duration() {
        let mut p = AsrKfPolicy::new(cfg());
        freeze_pos_by_detections(&mut p, 2, 12);
        assert!(p.is_frozen(2));
        // c=4 -> d=1: the absolute thaw step has long passed by 50
        let plan = p.plan(50, 12, 4);
        assert!(plan.restore.contains(&2));
        assert!(!p.is_frozen(2));
    }

    #[test]
    fn sink_and_window_tokens_never_freeze() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 12;
        let mut total_freezes = 0usize;
        for step in 1..40 {
            let scores = vec![0.0f32; len]; // everything looks irrelevant
            p.observe(step, &scores, len);
            let plan = p.plan(step, len, 16);
            total_freezes += plan.freeze.len();
            assert!(!plan.freeze.contains(&0), "sink frozen at step {step}");
            for w in len - 4..len {
                assert!(!plan.freeze.contains(&w), "window pos {w} frozen");
            }
        }
        // but middle tokens did freeze (and may oscillate back)
        assert!(total_freezes > 0);
    }

    #[test]
    fn budget_caps_freeze_rate() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 40;
        for step in 1..=4 {
            p.observe(step, &vec![0.0f32; len], len);
        }
        let plan = p.plan(5, len, 4);
        assert_eq!(plan.freeze.len(), 4); // 35 candidates, budget 4
        let plan = p.plan(6, len, 4);
        assert_eq!(plan.freeze.len(), 4); // queue drains over steps
    }

    #[test]
    fn soft_reset_unfreezes_long_timers_only() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 30;
        // accumulate many detections so some durations exceed 1
        for step in 1..=40 {
            p.observe(step, &vec![0.0f32; len], len);
            p.plan(step, len, 16);
        }
        let frozen_before = p.frozen_count();
        assert!(frozen_before > 0);
        let n = p.request_unfreeze(UnfreezeScope::Soft);
        // Soft touches only rows thawing more than one step out;
        // afterwards every timer is due, so one plan restores up to
        // budget
        let plan = p.plan(100, len, 64);
        assert!(plan.restore.len() >= n.min(1));
    }

    #[test]
    fn full_reset_clears_counters() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 20;
        for step in 1..=10 {
            p.observe(step, &vec![0.0f32; len], len);
            p.plan(step, len, 16);
        }
        p.request_unfreeze(UnfreezeScope::Full);
        let plan = p.plan(11, len, 64);
        assert_eq!(p.frozen_count(), 0, "all restored after FR, {plan:?}");
        // counters cleared: next detection is c=1 -> d=0 -> no freeze
        p.observe(12, &vec![0.0f32; len], len);
        let plan = p.plan(13, len, 64);
        assert!(plan.freeze.is_empty());
    }

    #[test]
    fn thaw_eta_hint_parallels_freeze_list() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 40;
        for step in 1..=6 {
            p.observe(step, &vec![0.0f32; len], len);
        }
        let plan = p.plan(7, len, 8);
        assert!(!plan.freeze.is_empty());
        assert_eq!(plan.freeze.len(), plan.freeze_thaw_eta.len());
        for &eta in &plan.freeze_thaw_eta {
            assert!(eta > 7, "thaw eta must be in the future, got {eta}");
        }
    }

    #[test]
    fn prefetch_hints_cover_imminent_thaws() {
        let mut p = AsrKfPolicy::new(cfg());
        freeze_pos_by_detections(&mut p, 2, 12);
        assert!(p.is_frozen(2));
        // c=4 -> d=1: pos 2 thaws on the next tick, so it must be a
        // prefetch hint before the restoring plan
        let plan = p.plan(40, 12, 4);
        assert!(
            plan.restore.contains(&2) || plan.prefetch.iter().any(|&(p, _)| p == 2),
            "imminent thaw neither restored nor hinted: {plan:?}"
        );
    }

    #[test]
    fn normalize_keeps_eta_parallel_to_freeze() {
        let mut p = Plan {
            freeze: vec![9, 2, 5],
            restore: vec![7, 1],
            freeze_thaw_eta: vec![90, 20, 50],
            ..Plan::default()
        };
        p.normalize();
        assert_eq!(p.freeze, vec![2, 5, 9]);
        assert_eq!(p.freeze_thaw_eta, vec![20, 50, 90]);
        assert_eq!(p.restore, vec![1, 7]);
        // drop-payload plans have no eta list: freeze still sorts
        let mut q = Plan { freeze: vec![3, 1], drop_payload: true, ..Plan::default() };
        q.normalize();
        assert_eq!(q.freeze, vec![1, 3]);
    }

    #[test]
    fn plan_clear_resets_lists_and_flag() {
        let mut p = Plan {
            freeze: vec![1],
            restore: vec![2],
            freeze_thaw_eta: vec![3],
            prefetch: vec![(4, 5)],
            drop_payload: true,
        };
        p.clear();
        assert_eq!(p, Plan::default());
    }

    #[test]
    fn plans_are_sorted_for_run_coalescing() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 40;
        for step in 1..=30 {
            p.observe(step, &vec![0.0f32; len], len);
            let plan = p.plan(step, len, 8);
            assert!(plan.freeze.windows(2).all(|w| w[0] < w[1]), "freeze unsorted: {plan:?}");
            assert!(plan.restore.windows(2).all(|w| w[0] < w[1]), "restore unsorted: {plan:?}");
        }
    }

    #[test]
    fn restore_and_freeze_disjoint() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 30;
        for step in 1..=50 {
            p.observe(step, &vec![0.0f32; len], len);
            let plan = p.plan(step, len, 8);
            for r in &plan.restore {
                assert!(!plan.freeze.contains(r), "pos {r} in both lists");
            }
        }
    }

    #[test]
    fn active_count_conserved() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 25;
        for step in 1..=60 {
            p.observe(step, &vec![0.1f32; len], len);
            p.plan(step, len, 8);
            assert_eq!(p.active_count() + p.frozen_count(), len);
        }
    }

    #[test]
    fn plan_into_reuses_buffers_across_steps() {
        let mut p = AsrKfPolicy::new(cfg());
        let len = 40;
        let mut plan = Plan::default();
        for step in 1..=20 {
            p.observe(step, &vec![0.0f32; len], len);
            p.plan_into(step, len, 8, &mut plan);
            assert!(plan.freeze.len() <= 8 && plan.restore.len() <= 8);
        }
        // the buffer carries no state between steps beyond capacity
        p.plan_into(100, len, 0, &mut plan);
        assert!(plan.freeze.is_empty() && plan.restore.is_empty() && plan.prefetch.is_empty());
    }

    #[test]
    fn score_order_key_is_total_and_monotone() {
        let xs = [-3.5f32, -0.0, 0.0, 0.1, 0.5, 2.0, 100.0];
        for w in xs.windows(2) {
            assert!(score_order_key(w[0]) <= score_order_key(w[1]), "{} !<= {}", w[0], w[1]);
        }
        assert!(score_order_key(-1.0) < score_order_key(1.0));
    }
}
