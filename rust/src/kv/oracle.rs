//! Brute-force full-scan ASR-KF-EGR — the reference the indexed
//! control plane is checked against, retained on purpose.
//!
//! [`ScanAsrKfPolicy`] implements the same freeze/restore semantics as
//! [`crate::kv::policy::AsrKfPolicy`] but answers every per-step
//! question the way the pre-index implementation did: timer expiry is
//! a full sweep over all positions, the prefetch horizon is a
//! full-table scan, `active_count`/`frozen_positions` are filters,
//! recovery scopes walk every position, and the pending-freeze list is
//! a flat `Vec` re-sorted each plan. Per-step cost is O(context_len)
//! by construction.
//!
//! Two consumers:
//! * `tests/prop_policy.rs::prop_indexed_policy_matches_scan_oracle`
//!   drives both implementations through identical random score /
//!   recovery traces and asserts plan-for-plan equality.
//! * `benches/policy_scaling.rs` reports the old-vs-new per-step
//!   `plan`+`observe` cost as context length grows (this column grows
//!   linearly; the indexed column tracks the work done).
//!
//! The one deliberate upgrade over the historical code is O(1)
//! pending-membership (a `Vec<bool>` instead of an O(pending) linear
//! probe per detection): the probe was a correctness-neutral
//! inefficiency (satellite fix of the same PR), and keeping it would
//! make million-token oracle columns O(n^2) and unrunnable.

use crate::config::FreezeConfig;
use crate::kv::freeze::{freeze_duration, DetectionWindow};
use crate::kv::policy::{score_order_key, KvPolicy, Plan, UnfreezeScope, PREFETCH_HORIZON};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ScanState {
    Active,
    Frozen { thaw_step: u64 },
}

struct ScanMeta {
    state: ScanState,
    window: DetectionWindow,
    frozen_at: u64,
    /// Freeze-episode counter (restore-queue staleness tag).
    freezes: u32,
    /// Expiry already reported; awaiting a budgeted restore.
    queued: bool,
}

impl Default for ScanMeta {
    fn default() -> Self {
        ScanMeta {
            state: ScanState::Active,
            window: DetectionWindow::default(),
            frozen_at: 0,
            freezes: 0,
            queued: false,
        }
    }
}

/// Full-scan reference implementation of the ASR-KF-EGR policy.
pub struct ScanAsrKfPolicy {
    cfg: FreezeConfig,
    meta: Vec<ScanMeta>,
    /// (pos, duration, score), unordered; re-sorted every plan.
    pending: Vec<(usize, u32, f32)>,
    pending_member: Vec<bool>,
    /// (position, freeze-episode at expiry) — see the indexed policy's
    /// `pending_restore` for the staleness-tag rationale.
    pending_restore: std::collections::VecDeque<(usize, u32)>,
    len: usize,
    last_step: u64,
}

impl ScanAsrKfPolicy {
    pub fn new(cfg: FreezeConfig) -> Self {
        ScanAsrKfPolicy {
            cfg,
            meta: Vec::new(),
            pending: Vec::new(),
            pending_member: Vec::new(),
            pending_restore: std::collections::VecDeque::new(),
            len: 0,
            last_step: 0,
        }
    }

    fn grow_to(&mut self, len: usize) {
        if self.meta.len() < len {
            self.meta.resize_with(len, ScanMeta::default);
            self.pending_member.resize(len, false);
        }
    }

    fn is_active_pos(&self, pos: usize) -> bool {
        self.meta.get(pos).map(|m| m.state == ScanState::Active).unwrap_or(true)
    }

    fn detect(&mut self, step: u64, scores: &[f32], len: usize) {
        self.grow_to(len);
        self.len = len;
        self.last_step = step;
        let meta = &self.meta;
        let detections = crate::kv::relevance::detect_low_importance(
            &self.cfg,
            scores,
            len,
            |p| meta.get(p).map(|m| m.state == ScanState::Active).unwrap_or(true),
        );
        for (pos, score) in detections {
            let c = self.meta[pos].window.record(step, self.cfg.history_w as u64);
            let d = freeze_duration(c, self.cfg.softness_k);
            if d > 0 && !self.pending_member[pos] {
                self.pending_member[pos] = true;
                self.pending.push((pos, d, score));
            }
        }
    }
}

impl KvPolicy for ScanAsrKfPolicy {
    fn name(&self) -> &'static str {
        "asrkf-scan"
    }

    fn on_prefill(&mut self, scores: &[f32], len: usize) {
        self.detect(0, scores, len);
    }

    fn plan_into(&mut self, step: u64, len: usize, r_budget: usize, out: &mut Plan) {
        out.clear();
        self.grow_to(len);
        self.len = len;
        self.last_step = step;

        // Expiry: full sweep over every position (the old tick_timers),
        // reported in (thaw_step, pos) order.
        let mut expired: Vec<(u64, usize, u32)> = Vec::new();
        for (pos, m) in self.meta.iter_mut().enumerate() {
            if let ScanState::Frozen { thaw_step } = m.state {
                if thaw_step != u64::MAX && !m.queued && thaw_step <= step {
                    m.queued = true;
                    expired.push((thaw_step, pos, m.freezes));
                }
            }
        }
        expired.sort_unstable();
        self.pending_restore.extend(expired.into_iter().map(|(_, p, gen)| (p, gen)));

        // Budget-capped restores (oldest first); entries restore only
        // the freeze episode they were queued for.
        while out.restore.len() < r_budget {
            match self.pending_restore.pop_front() {
                Some((pos, gen)) if !self.is_active_pos(pos) && self.meta[pos].freezes == gen => {
                    let m = &mut self.meta[pos];
                    m.state = ScanState::Active;
                    m.queued = false;
                    out.restore.push(pos);
                }
                Some(_) => continue,
                None => break,
            }
        }

        // Budget-capped freezes: full re-sort of the pending list by
        // (score, pos), linear restore-membership probes.
        let window_start = len.saturating_sub(self.cfg.window_k);
        self.pending.sort_unstable_by_key(|&(pos, _, score)| (score_order_key(score), pos));
        let mut kept: Vec<(usize, u32, f32)> = Vec::new();
        let mut budget_full = out.freeze.len() >= r_budget;
        let pending = std::mem::take(&mut self.pending);
        for (pos, d, score) in pending {
            if budget_full {
                kept.push((pos, d, score)); // stays queued, untouched
                continue;
            }
            let eligible = self.is_active_pos(pos)
                && pos < window_start
                && pos >= self.cfg.n_sink
                && !out.restore.contains(&pos);
            if !eligible {
                self.pending_member[pos] = false; // stale candidate — drop
                continue;
            }
            let m = &mut self.meta[pos];
            m.state = ScanState::Frozen { thaw_step: step + d as u64 };
            m.frozen_at = step;
            m.freezes += 1;
            m.queued = false;
            self.pending_member[pos] = false;
            out.freeze.push(pos);
            out.freeze_thaw_eta.push(step + d as u64);
            budget_full = out.freeze.len() >= r_budget;
        }
        self.pending = kept;

        // Prefetch horizon: full-table scan for imminent thaws.
        let mut imminent: Vec<(u64, usize)> = Vec::new();
        for (pos, m) in self.meta.iter().enumerate() {
            if let ScanState::Frozen { thaw_step } = m.state {
                if !m.queued
                    && thaw_step != u64::MAX
                    && thaw_step > step
                    && thaw_step <= step + PREFETCH_HORIZON as u64
                {
                    imminent.push((thaw_step, pos));
                }
            }
        }
        imminent.sort_unstable();
        out.prefetch.extend(imminent.into_iter().take(r_budget).map(|(eta, pos)| (pos, eta)));

        out.normalize();
    }

    fn observe(&mut self, step: u64, scores: &[f32], len: usize) {
        self.detect(step, scores, len);
    }

    fn request_unfreeze(&mut self, scope: UnfreezeScope) -> usize {
        let mut n = 0;
        let last = self.last_step;
        for m in self.meta.iter_mut() {
            let hit = match (m.state, scope) {
                (ScanState::Frozen { thaw_step }, UnfreezeScope::Soft) => {
                    thaw_step != u64::MAX && !m.queued && thaw_step > last
                }
                (ScanState::Frozen { .. }, UnfreezeScope::Window { n: horizon, now }) => {
                    m.frozen_at.saturating_add(horizon) >= now
                }
                (ScanState::Frozen { .. }, UnfreezeScope::Full) => true,
                _ => false,
            };
            if hit {
                let new_thaw = match scope {
                    UnfreezeScope::Window { now, .. } => now,
                    _ => last,
                };
                m.state = ScanState::Frozen { thaw_step: new_thaw };
                m.queued = false;
                n += 1;
            }
            if matches!(scope, UnfreezeScope::Full) {
                m.window.clear();
            }
        }
        if matches!(scope, UnfreezeScope::Full) {
            self.pending.clear();
            self.pending_member.fill(false);
        }
        n
    }

    fn force_all_active(&mut self) {
        for m in &mut self.meta {
            m.state = ScanState::Active;
            m.queued = false;
            m.window.clear();
        }
        self.pending.clear();
        self.pending_member.fill(false);
        self.pending_restore.clear();
    }

    fn active_count(&self) -> usize {
        self.meta.iter().filter(|m| m.state == ScanState::Active).count()
            + self.len.saturating_sub(self.meta.len())
    }

    fn frozen_positions(&self) -> Vec<usize> {
        self.meta
            .iter()
            .enumerate()
            .filter(|(_, m)| matches!(m.state, ScanState::Frozen { .. }))
            .map(|(p, _)| p)
            .collect()
    }

    fn is_frozen(&self, pos: usize) -> bool {
        matches!(self.meta.get(pos).map(|m| m.state), Some(ScanState::Frozen { .. }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> FreezeConfig {
        FreezeConfig {
            window_k: 4,
            n_sink: 1,
            tau: 0.5,
            softness_k: 2.0,
            history_w: 64,
            r_budget: 4,
            relative_tau: false,
        }
    }

    #[test]
    fn scan_policy_freezes_and_restores() {
        let mut p = ScanAsrKfPolicy::new(cfg());
        let len = 12;
        for step in 1..=4 {
            let mut scores = vec![1.0f32; len];
            scores[2] = 0.0;
            p.observe(step, &scores, len);
        }
        let plan = p.plan(5, len, 4);
        assert_eq!(plan.freeze, vec![2]);
        assert_eq!(plan.freeze_thaw_eta, vec![6]);
        assert!(p.is_frozen(2));
        assert_eq!(p.active_count(), len - 1);
        let plan = p.plan(6, len, 4);
        assert_eq!(plan.restore, vec![2]);
        assert!(!p.is_frozen(2));
    }

    #[test]
    fn full_reset_restores_everything() {
        let mut p = ScanAsrKfPolicy::new(cfg());
        let len = 20;
        for step in 1..=10 {
            p.observe(step, &vec![0.0f32; len], len);
            p.plan(step, len, 16);
        }
        assert!(p.frozen_count() > 0);
        let n = p.request_unfreeze(UnfreezeScope::Full);
        assert_eq!(n, p.frozen_count());
        p.plan(11, len, 64);
        assert_eq!(p.frozen_count(), 0);
    }
}
