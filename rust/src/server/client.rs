//! Blocking client for the JSON-lines protocol + a synthetic-workload
//! bench client (used by `asrkf bench-client` and the serving bench).
//!
//! Emits the v1 tagged request format (`{"op": "generate", ...}`,
//! see `protocol.rs` / `README.md`); servers still accept the legacy
//! flat format from older clients.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

use crate::config::QosClass;
use crate::error::{Error, Result};
use crate::util::json::{parse, Json};
use crate::util::rng::Pcg64;
use crate::workload::synthetic::prose;

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

#[derive(Debug, Clone)]
pub struct ClientResult {
    pub text: String,
    pub compression: f64,
    pub generated_tokens: usize,
    pub ttft_ms: f64,
    pub e2e_ms: f64,
    /// Effective QoS class the server ran the request under.
    pub class: Option<QosClass>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Generate at the default (`standard`) QoS class.
    pub fn generate(
        &mut self,
        prompt: &str,
        max_new: usize,
        policy: &str,
        seed: u64,
    ) -> Result<ClientResult> {
        self.generate_as(prompt, max_new, policy, seed, QosClass::Standard)
    }

    /// Generate at an explicit QoS class.
    pub fn generate_as(
        &mut self,
        prompt: &str,
        max_new: usize,
        policy: &str,
        seed: u64,
        class: QosClass,
    ) -> Result<ClientResult> {
        let req = Json::obj(vec![
            ("v", Json::num(1.0)),
            ("op", Json::str("generate")),
            ("prompt", Json::str(prompt)),
            ("max_new", Json::num(max_new as f64)),
            ("policy", Json::str(policy)),
            ("seed", Json::num(seed as f64)),
            ("class", Json::str(class.as_str())),
        ]);
        let mut line = String::new();
        crate::util::json::write_json(&req, &mut line);
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        self.writer.flush()?;

        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        let v = parse(resp.trim()).map_err(Error::Server)?;
        if let Some(err) = v.get("error").as_str() {
            return Err(Error::Server(err.to_string()));
        }
        Ok(ClientResult {
            text: v.get("text").as_str().unwrap_or_default().to_string(),
            compression: v.get("compression").as_f64().unwrap_or(0.0),
            generated_tokens: v.get("generated_tokens").as_usize().unwrap_or(0),
            ttft_ms: v.get("ttft_ms").as_f64().unwrap_or(0.0),
            e2e_ms: v.get("e2e_ms").as_f64().unwrap_or(0.0),
            class: v.get("class").as_str().and_then(|s| QosClass::parse(s).ok()),
        })
    }
}

/// Drive a running server with `n` requests over `concurrency`
/// connections at `class`; prints latency/throughput.
pub fn run_bench_client(
    addr: &str,
    n: usize,
    concurrency: usize,
    max_new: usize,
    class: QosClass,
) -> Result<()> {
    let t0 = Instant::now();
    let per = n.div_ceil(concurrency);
    let addr = addr.to_string();
    let mut handles = Vec::new();
    for c in 0..concurrency {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> Result<Vec<(f64, f64, usize)>> {
            let mut rng = Pcg64::new(1000 + c as u64);
            let mut client = Client::connect(&addr)?;
            let mut out = Vec::new();
            for i in 0..per {
                let prompt = prose(&mut rng, 48 + (i * 13) % 64);
                let seed = c as u64 * 100 + i as u64;
                let r = client.generate_as(&prompt, max_new, "asrkf", seed, class)?;
                out.push((r.ttft_ms, r.e2e_ms, r.generated_tokens));
            }
            Ok(out)
        }));
    }
    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().map_err(|_| Error::Server("client thread panicked".into()))??);
    }
    let wall = t0.elapsed();
    let total_tokens: usize = all.iter().map(|a| a.2).sum();
    let mean_ttft = all.iter().map(|a| a.0).sum::<f64>() / all.len() as f64;
    let mean_e2e = all.iter().map(|a| a.1).sum::<f64>() / all.len() as f64;
    println!(
        "bench-client: {} requests ({}), {} tokens in {:.2?}  ({:.1} tok/s)",
        all.len(),
        class.as_str(),
        total_tokens,
        wall,
        total_tokens as f64 / wall.as_secs_f64()
    );
    println!("  mean ttft {mean_ttft:.1} ms, mean e2e {mean_e2e:.1} ms");
    Ok(())
}
