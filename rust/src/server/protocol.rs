//! JSON-lines wire protocol, versioned.
//!
//! v1 requests are tagged with an `op` field (`v` is optional and
//! defaults to 1 — the only version so far):
//!   {"v": 1, "op": "generate", "prompt": "...", "max_new": 64,
//!    "policy": "asrkf", "seed": 0, "class": "interactive"}
//!   {"v": 1, "op": "stats"}
//!
//! The pre-versioning (v0) formats still parse — a flat generate
//! object `{"prompt": "...", ...}` and the stats probe
//! `{"stats": true}` — so old clients keep working unchanged.
//!
//! A generate response is one line:
//!   {"id": 3, "text": "...", "class": "standard", "prompt_tokens": 12,
//!    "generated_tokens": 64, "final_active_kv": 40,
//!    "compression": 0.47, "ttft_ms": 12.1, "e2e_ms": 480.9, ...}
//! or, on failure, {"id": 3, "error": "...", "class": "..."} — plus a
//! typed `"reject": {"reason": "queue_full" | "kv_capacity" |
//! "hot_envelope", "class": "..."}` object when admission control
//! turned the request away. A stats request answers with the live
//! metrics-registry snapshot:
//!   {"stats": {<metric name>: {<label set>: value, ...}, ...},
//!    "prometheus": "<text exposition>"}
//!
//! The full schema is documented in `rust/src/server/README.md`.

use crate::config::QosClass;
use crate::coordinator::{GenParams, GenResponse};
use crate::metrics::Snapshot;
use crate::util::json::{parse, Json};

/// One parsed protocol line: either a generation to enqueue or a stats
/// query answered inline from the registry.
#[derive(Debug)]
pub enum Request {
    Generate(GenParams),
    Stats,
}

/// Parse any protocol line. A line carrying an `op` field is a v1
/// request and routes by its tag; otherwise the legacy v0 forms apply
/// (`{"stats": true}` is recognized before generation parsing, so a
/// prompt named "stats" is unaffected).
pub fn parse_line(line: &str) -> Result<Request, String> {
    let v = parse(line).map_err(|e| format!("bad json: {e}"))?;
    if let Some(op) = v.get("op").as_str() {
        let ver = v.get("v");
        if !matches!(ver, Json::Null) && ver.as_usize() != Some(1) {
            return Err(format!("unsupported protocol version {ver:?} (expected 1)"));
        }
        return match op {
            "generate" => parse_generate(&v).map(Request::Generate),
            "stats" => Ok(Request::Stats),
            other => Err(format!("unknown op '{other}'")),
        };
    }
    if v.get("stats").as_bool() == Some(true) {
        return Ok(Request::Stats);
    }
    parse_generate(&v).map(Request::Generate)
}

/// Shared generate-body parser (v1 and legacy lines carry the same
/// fields; v1 adds the optional `class`).
fn parse_generate(v: &Json) -> Result<GenParams, String> {
    let prompt = v.get("prompt").as_str().ok_or("missing 'prompt'")?;
    if prompt.is_empty() {
        return Err("empty prompt".into());
    }
    let mut b = GenParams::builder(prompt);
    if let Some(n) = v.get("max_new").as_usize() {
        b = b.max_new(n);
    }
    if let Some(p) = v.get("policy").as_str() {
        b = b.policy(p);
    }
    if let Some(s) = v.get("seed").as_f64() {
        b = b.seed(s as u64);
    }
    if let Some(r) = v.get("resume_spill").as_bool() {
        b = b.resume_spill(r);
    }
    if let Some(c) = v.get("class").as_str() {
        b = b.qos(QosClass::parse(c)?);
    }
    Ok(b.build())
}

/// Parse one generate line (legacy entry point, kept for callers that
/// bypass [`parse_line`]'s routing).
pub fn parse_request(line: &str) -> Result<GenParams, String> {
    let v = parse(line).map_err(|e| format!("bad json: {e}"))?;
    parse_generate(&v)
}

pub fn response_line(resp: &GenResponse) -> String {
    let v = match &resp.error {
        Some(e) => {
            let mut fields = vec![
                ("id", Json::num(resp.id as f64)),
                ("error", Json::str(e)),
                ("class", Json::str(resp.class.as_str())),
            ];
            if let Some(rej) = &resp.reject {
                fields.push((
                    "reject",
                    Json::obj(vec![
                        ("reason", Json::str(rej.reason.as_str())),
                        ("class", Json::str(rej.requested.as_str())),
                    ]),
                ));
            }
            Json::obj(fields)
        }
        None => Json::obj(vec![
            ("id", Json::num(resp.id as f64)),
            ("text", Json::str(&resp.text)),
            ("class", Json::str(resp.class.as_str())),
            ("prompt_tokens", Json::num(resp.prompt_tokens as f64)),
            ("generated_tokens", Json::num(resp.generated_tokens as f64)),
            ("final_active_kv", Json::num(resp.final_active_kv as f64)),
            ("compression", Json::num((resp.compression * 1e4).round() / 1e4)),
            ("ttft_ms", Json::num((resp.ttft.as_secs_f64() * 1e4).round() / 10.0)),
            ("e2e_ms", Json::num((resp.e2e.as_secs_f64() * 1e4).round() / 10.0)),
            ("offload_bytes", Json::num(resp.offload.occupancy.total_bytes() as f64)),
            ("staged_hits", Json::num(resp.offload.staged_hits as f64)),
            ("restore_rows", Json::num(resp.offload.restore_batch_rows as f64)),
            ("restore_spans", Json::num(resp.offload.restore_batch_spans as f64)),
            ("shards", Json::num(resp.offload.shards as f64)),
            ("restore_par_max", Json::num(resp.offload.restore_parallelism_max as f64)),
            ("shard_imbalance", Json::num(resp.offload.shard_imbalance as f64)),
            ("recovered_rows", Json::num(resp.offload.recovered_rows as f64)),
            ("recovery_errors", Json::num(resp.offload.recovery_errors as f64)),
            ("plan_mean_us", Json::num(resp.plan_latency.mean_us as f64)),
            ("plan_p99_us", Json::num(resp.plan_latency.p99_us as f64)),
        ]),
    };
    let mut s = String::new();
    crate::util::json::write_json(&v, &mut s);
    s.push('\n');
    s
}

/// One-line stats reply: the snapshot as structured JSON plus the same
/// snapshot rendered as Prometheus text exposition (embedded string).
pub fn stats_line(snap: &Snapshot) -> String {
    let v = Json::obj(vec![
        ("stats", snap.to_json()),
        ("prometheus", Json::str(snap.to_prometheus())),
    ]);
    let mut s = String::new();
    crate::util::json::write_json(&v, &mut s);
    s.push('\n');
    s
}

pub fn error_line(msg: &str) -> String {
    let v = Json::obj(vec![("error", Json::str(msg))]);
    let mut s = String::new();
    crate::util::json::write_json(&v, &mut s);
    s.push('\n');
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Reject, RejectReason};
    use std::time::Duration;

    #[test]
    fn request_roundtrip() {
        let p = parse_request(
            r#"{"prompt": "hello", "max_new": 10, "policy": "full", "resume_spill": true}"#,
        )
        .unwrap();
        assert_eq!(p.prompt, "hello");
        assert_eq!(p.max_new, 10);
        assert_eq!(p.policy, "full");
        assert_eq!(p.seed, 0);
        assert!(p.resume_spill);
    }

    #[test]
    fn request_defaults() {
        let p = parse_request(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(p.max_new, 64);
        assert_eq!(p.policy, "asrkf");
        assert!(!p.resume_spill, "resume is opt-in per request");
        assert_eq!(p.qos, QosClass::Standard, "class defaults to standard");
    }

    #[test]
    fn request_rejects_bad_input() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"max_new": 5}"#).is_err());
        assert!(parse_request(r#"{"prompt": ""}"#).is_err());
    }

    #[test]
    fn versioned_generate_roundtrips() {
        let line = r#"{"v": 1, "op": "generate", "prompt": "hi", "class": "interactive"}"#;
        match parse_line(line) {
            Ok(Request::Generate(p)) => {
                assert_eq!(p.prompt, "hi");
                assert_eq!(p.qos, QosClass::Interactive);
            }
            other => panic!("expected Generate, got {other:?}"),
        }
        // v is optional; op alone selects the v1 path
        match parse_line(r#"{"op": "stats"}"#) {
            Ok(Request::Stats) => {}
            other => panic!("expected Stats, got {other:?}"),
        }
    }

    #[test]
    fn versioned_rejects_unknown_op_and_bad_version() {
        let err = parse_line(r#"{"op": "frobnicate"}"#).unwrap_err();
        assert!(err.contains("unknown op"), "{err}");
        let err = parse_line(r#"{"v": 2, "op": "generate", "prompt": "x"}"#).unwrap_err();
        assert!(err.contains("unsupported protocol version"), "{err}");
        let err = parse_line(r#"{"op": "generate", "prompt": "x", "class": "vip"}"#).unwrap_err();
        assert!(err.contains("qos class"), "{err}");
    }

    fn ok_response() -> GenResponse {
        GenResponse {
            id: 7,
            text: "hi".into(),
            error: None,
            class: QosClass::Standard,
            reject: None,
            prompt_tokens: 3,
            generated_tokens: 2,
            final_active_kv: 4,
            compression: 0.25,
            ttft: Duration::from_millis(12),
            e2e: Duration::from_millis(100),
            offload: Default::default(),
            plan_latency: Default::default(),
        }
    }

    #[test]
    fn response_line_shape() {
        let line = response_line(&ok_response());
        assert!(line.ends_with('\n'));
        let v = parse(line.trim()).unwrap();
        assert_eq!(v.get("id").as_usize(), Some(7));
        assert_eq!(v.get("text").as_str(), Some("hi"));
        assert_eq!(v.get("compression").as_f64(), Some(0.25));
        // the effective QoS class rides along on every response
        assert_eq!(v.get("class").as_str(), Some("standard"));
        assert!(matches!(v.get("reject"), Json::Null), "no reject on success");
        // sharding telemetry rides along on every response
        assert_eq!(v.get("shards").as_usize(), Some(0)); // default summary
        assert_eq!(v.get("restore_par_max").as_usize(), Some(0));
        assert_eq!(v.get("shard_imbalance").as_usize(), Some(0));
        // spill-recovery telemetry does too
        assert_eq!(v.get("recovered_rows").as_usize(), Some(0));
        assert_eq!(v.get("recovery_errors").as_usize(), Some(0));
        // policy control-plane latency does too
        assert_eq!(v.get("plan_mean_us").as_usize(), Some(0));
        assert_eq!(v.get("plan_p99_us").as_usize(), Some(0));
    }

    #[test]
    fn error_response() {
        let r = GenResponse::error(1, "boom");
        let v = parse(response_line(&r).trim()).unwrap();
        assert_eq!(v.get("error").as_str(), Some("boom"));
    }

    #[test]
    fn reject_response_carries_typed_reason() {
        let r = GenResponse::rejected(
            9,
            Reject {
                reason: RejectReason::HotEnvelope,
                requested: QosClass::Interactive,
                detail: "projected hot-tier slice below the envelope".into(),
            },
        );
        let v = parse(response_line(&r).trim()).unwrap();
        assert!(v.get("error").as_str().unwrap().contains("admission control"));
        assert_eq!(v.get("class").as_str(), Some("interactive"));
        assert_eq!(v.get("reject").get("reason").as_str(), Some("hot_envelope"));
        assert_eq!(v.get("reject").get("class").as_str(), Some("interactive"));
    }

    #[test]
    fn parse_line_routes_stats_and_generate() {
        assert!(matches!(parse_line(r#"{"stats": true}"#), Ok(Request::Stats)));
        // a prompt that merely mentions stats still generates
        match parse_line(r#"{"prompt": "stats", "max_new": 1}"#) {
            Ok(Request::Generate(p)) => assert_eq!(p.prompt, "stats"),
            other => panic!("expected Generate, got {other:?}"),
        }
        // stats must be literally true; anything else is a generation
        // parse (and fails on the missing prompt)
        assert!(parse_line(r#"{"stats": 1}"#).is_err());
        assert!(parse_line("not json").is_err());
    }

    #[test]
    fn stats_line_embeds_json_and_parseable_prometheus() {
        use crate::metrics::{parse_exposition, SnapshotBuilder};
        let mut b = SnapshotBuilder::default();
        b.counter_add("asrkf_stash_total", &[("shard", "0")], 5);
        b.gauge_set("asrkf_tier_rows", &[("tier", "hot"), ("shard", "0")], 3.0);
        let snap = b.finish();
        let line = stats_line(&snap);
        assert!(line.ends_with('\n'));
        let v = parse(line.trim()).unwrap();
        let stats = v.get("stats");
        assert!(stats.get("asrkf_stash_total").as_arr().is_some());
        let prom = v.get("prometheus").as_str().unwrap().to_string();
        assert!(parse_exposition(&prom).unwrap() >= 2);
    }
}
