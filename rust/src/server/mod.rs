//! TCP serving frontend: one thread per connection, JSON-lines
//! protocol, bounded handoff to the coordinator thread. (tokio is
//! unavailable offline; a thread-per-connection frontend is fully
//! adequate at the batch sizes the single-core CPU backend supports.)

pub mod client;
pub mod protocol;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use crate::config::{EngineConfig, ServerConfig};
use crate::coordinator::{self, CoordinatorHandle};
use crate::error::Result;

/// Start the coordinator and serve on `server.addr` until process exit.
pub fn serve_blocking(cfg: EngineConfig, server: ServerConfig) -> Result<()> {
    let (handle, _join) = coordinator::spawn(cfg, server.clone())?;
    let listener = TcpListener::bind(&server.addr)?;
    log::info!("listening on {}", server.addr);
    println!("asrkf serving on {}", server.addr);
    for conn in listener.incoming() {
        match conn {
            Ok(stream) => {
                let h = handle.clone();
                std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, h) {
                        log::debug!("connection closed: {e}");
                    }
                });
            }
            Err(e) => log::warn!("accept failed: {e}"),
        }
    }
    Ok(())
}

/// Compute the one-line reply for one protocol line. Stats queries are
/// answered inline from the process-wide metrics registry (they never
/// queue behind generation); generations block on the coordinator.
fn reply_for_line(line: &str, handle: &CoordinatorHandle) -> String {
    match protocol::parse_line(line) {
        Err(e) => protocol::error_line(&e),
        Ok(protocol::Request::Stats) => {
            protocol::stats_line(&crate::metrics::Registry::global().snapshot())
        }
        Ok(protocol::Request::Generate(params)) => match handle.generate_blocking(params) {
            Ok(resp) => protocol::response_line(&resp),
            Err(e) => protocol::error_line(&format!("{e}")),
        },
    }
}

fn handle_conn(stream: TcpStream, handle: CoordinatorHandle) -> std::io::Result<()> {
    let peer = stream.peer_addr()?;
    log::debug!("connection from {peer}");
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = reply_for_line(&line, &handle);
        writer.write_all(reply.as_bytes())?;
        writer.flush()?;
    }
    Ok(())
}
