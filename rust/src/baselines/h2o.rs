//! H2O (Heavy-Hitter Oracle, Zhang et al. 2024) baseline.
//!
//! Keeps attention sinks + a recent window + the "heavy hitters" with
//! the largest cumulative attention mass, evicting the rest
//! permanently. We use the Eq.2 relevance scores as the attention-mass
//! proxy (both are |q.k|-derived; the original uses post-softmax
//! weights — the ranking behaviour is equivalent for this comparison
//! and documented in DESIGN.md §3).
//!
//! The active-set budget is `budget_frac * total_len`, floored at
//! sinks + window, matching H2O's "20-40% heavy hitter" operating
//! range (we default to 33%).

use crate::config::FreezeConfig;
use crate::kv::policy::{KvPolicy, Plan, UnfreezeScope};
use crate::kv::state::TokenTable;

pub struct H2oPolicy {
    cfg: FreezeConfig,
    pub budget_frac: f32,
    table: TokenTable,
    cum: Vec<f32>,
    len: usize,
}

impl H2oPolicy {
    pub fn new(cfg: FreezeConfig) -> Self {
        H2oPolicy { cfg, budget_frac: 0.33, table: TokenTable::default(), cum: Vec::new(), len: 0 }
    }

    pub fn with_budget(cfg: FreezeConfig, budget_frac: f32) -> Self {
        H2oPolicy { budget_frac, ..Self::new(cfg) }
    }

    fn budget(&self, len: usize) -> usize {
        let floor = self.cfg.n_sink + self.cfg.window_k;
        ((len as f32 * self.budget_frac) as usize).max(floor)
    }
}

impl KvPolicy for H2oPolicy {
    fn name(&self) -> &'static str {
        "h2o"
    }

    fn on_prefill(&mut self, scores: &[f32], len: usize) {
        self.table.grow_to(len);
        self.cum.resize(len, 0.0);
        for (i, &s) in scores.iter().take(len).enumerate() {
            self.cum[i] += s;
        }
        self.len = len;
    }

    fn plan_into(&mut self, step: u64, len: usize, r_budget: usize, out: &mut Plan) {
        out.clear();
        out.drop_payload = true;
        self.table.grow_to(len);
        self.cum.resize(len, 0.0);
        self.len = len;

        let budget = self.budget(len);
        let window_start = len.saturating_sub(self.cfg.window_k);
        let mut active = self.table.active_count();
        while active > budget && out.freeze.len() < r_budget {
            // lowest cumulative attention among evictable positions —
            // the active-position index walks candidates directly, and
            // already-evicted rows drop out of it (the old
            // `!evict.contains(p)` O(evictions^2) probe is gone)
            let victim = self
                .table
                .active_range(self.cfg.n_sink, window_start)
                .min_by(|&a, &b| self.cum[a].partial_cmp(&self.cum[b]).unwrap());
            match victim {
                Some(p) => {
                    self.table.freeze(p, TokenTable::NEVER, step); // permanent
                    out.freeze.push(p);
                    active -= 1;
                }
                None => break,
            }
        }
        out.normalize(); // engine batches freezes over sorted runs
    }

    fn observe(&mut self, _step: u64, scores: &[f32], len: usize) {
        self.table.grow_to(len);
        self.cum.resize(len, 0.0);
        for p in 0..len {
            if self.table.is_active(p) {
                self.cum[p] += scores[p];
            }
        }
        self.len = len;
    }

    fn request_unfreeze(&mut self, _scope: UnfreezeScope) -> usize {
        0 // evicted rows are gone; recovery cannot help H2O
    }

    fn force_all_active(&mut self) {}

    fn active_count(&self) -> usize {
        self.table.active_count() + self.len.saturating_sub(self.table.len())
    }

    fn frozen_count(&self) -> usize {
        self.table.frozen_count()
    }

    fn frozen_positions(&self) -> Vec<usize> {
        self.table.frozen_positions()
    }

    fn is_frozen(&self, pos: usize) -> bool {
        self.table.is_frozen(pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> FreezeConfig {
        FreezeConfig { n_sink: 2, window_k: 4, ..Default::default() }
    }

    #[test]
    fn evicts_down_to_budget() {
        let mut p = H2oPolicy::with_budget(cfg(), 0.5);
        let len = 40;
        let scores: Vec<f32> = (0..len).map(|i| i as f32).collect(); // early = cold
        p.on_prefill(&scores, len);
        let mut evicted = 0;
        for step in 0..10 {
            let plan = p.plan(step, len, 16);
            assert!(plan.drop_payload);
            assert!(plan.restore.is_empty());
            evicted += plan.freeze.len();
        }
        assert_eq!(evicted, len - p.budget(len));
        assert_eq!(p.active_count(), p.budget(len));
    }

    #[test]
    fn evicts_coldest_first_and_spares_sinks_window() {
        let mut p = H2oPolicy::with_budget(cfg(), 0.5);
        let len = 20;
        let mut scores = vec![10.0f32; len];
        scores[7] = 0.0; // coldest evictable
        p.on_prefill(&scores, len);
        let plan = p.plan(0, len, 1);
        assert_eq!(plan.freeze, vec![7]);
        // sinks (0,1) and window (16..20) never evicted
        for step in 1..20 {
            let plan = p.plan(step, len, 4);
            for &f in &plan.freeze {
                assert!(f >= 2 && f < 16, "evicted protected pos {f}");
            }
        }
    }

    #[test]
    fn eviction_is_permanent() {
        let mut p = H2oPolicy::with_budget(cfg(), 0.3);
        let len = 40;
        p.on_prefill(&vec![1.0; len], len);
        while !p.plan(0, len, 16).freeze.is_empty() {}
        let frozen = p.frozen_count();
        assert!(frozen > 0);
        assert_eq!(p.request_unfreeze(UnfreezeScope::Full), 0);
        let plan = p.plan(1, len, 16);
        assert!(plan.restore.is_empty());
        assert_eq!(p.frozen_count(), frozen);
    }
}
