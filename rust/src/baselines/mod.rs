//! Baseline KV policies the paper compares against (related work §2):
//! Full KV (the paper's Table 1/3 baseline), H2O heavy-hitter eviction,
//! and StreamingLLM sinks+window. All drive the exact same engine as
//! ASR-KF-EGR via the `KvPolicy` trait; the crucial behavioural
//! difference is `Plan::drop_payload = true` — their evictions are
//! irreversible.

pub mod full;
pub mod h2o;
pub mod streaming;

pub use full::FullKvPolicy;
pub use h2o::H2oPolicy;
pub use streaming::StreamingLlmPolicy;

use crate::config::FreezeConfig;
use crate::kv::KvPolicy;

/// Policy factory used by the CLI, server, and benches.
pub fn make_policy(name: &str, cfg: &FreezeConfig) -> Result<Box<dyn KvPolicy>, String> {
    match name {
        "asrkf" | "asr-kf-egr" => Ok(Box::new(crate::kv::AsrKfPolicy::new(cfg.clone()))),
        // retained full-scan reference implementation (A/B + oracle)
        "asrkf-scan" => Ok(Box::new(crate::kv::ScanAsrKfPolicy::new(cfg.clone()))),
        "full" | "baseline" => Ok(Box::new(FullKvPolicy::default())),
        "h2o" => Ok(Box::new(H2oPolicy::new(cfg.clone()))),
        "streaming" | "streamingllm" => Ok(Box::new(StreamingLlmPolicy::new(cfg.clone()))),
        other => Err(format!(
            "unknown policy '{other}' (expected asrkf|asrkf-scan|full|h2o|streaming)"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_knows_all_policies() {
        let cfg = FreezeConfig::default();
        for name in ["asrkf", "asrkf-scan", "full", "h2o", "streaming"] {
            assert!(make_policy(name, &cfg).is_ok(), "{name}");
        }
        assert!(make_policy("nope", &cfg).is_err());
    }
}
