//! StreamingLLM (Xiao et al. 2024) baseline: keep only the attention
//! sinks (first `n_sink` tokens) and a recent sliding window; evict
//! everything else permanently as it ages out of the window. Enables
//! unbounded generation but loses mid-context access — exactly the
//! failure mode the paper's passkey test (Table 2) exposes.

use crate::config::FreezeConfig;
use crate::kv::policy::{KvPolicy, Plan, UnfreezeScope};
use crate::kv::state::TokenTable;

pub struct StreamingLlmPolicy {
    cfg: FreezeConfig,
    table: TokenTable,
    len: usize,
    /// Every position below this is already evicted (evictions are
    /// permanent and in ascending order, so the sweep never re-scans
    /// frozen prefix positions — amortized O(1) per eviction instead of
    /// an O(len) rescan per plan).
    evict_cursor: usize,
}

impl StreamingLlmPolicy {
    pub fn new(cfg: FreezeConfig) -> Self {
        let evict_cursor = cfg.n_sink;
        StreamingLlmPolicy { cfg, table: TokenTable::default(), len: 0, evict_cursor }
    }
}

impl KvPolicy for StreamingLlmPolicy {
    fn name(&self) -> &'static str {
        "streaming"
    }

    fn on_prefill(&mut self, _scores: &[f32], len: usize) {
        self.table.grow_to(len);
        self.len = len;
    }

    fn plan_into(&mut self, step: u64, len: usize, r_budget: usize, out: &mut Plan) {
        out.clear();
        out.drop_payload = true;
        self.table.grow_to(len);
        self.len = len;
        let window_start = len.saturating_sub(self.cfg.window_k);
        while self.evict_cursor < window_start && out.freeze.len() < r_budget {
            let p = self.evict_cursor;
            self.evict_cursor += 1;
            if self.table.is_active(p) {
                self.table.freeze(p, TokenTable::NEVER, step);
                out.freeze.push(p);
            }
        }
        // freezes are built in ascending position order; normalize
        // keeps the sorted-plan contract explicit for the engine
        out.normalize();
    }

    fn observe(&mut self, _step: u64, _scores: &[f32], len: usize) {
        self.table.grow_to(len);
        self.len = len;
    }

    fn request_unfreeze(&mut self, _scope: UnfreezeScope) -> usize {
        0
    }

    fn force_all_active(&mut self) {}

    fn active_count(&self) -> usize {
        self.table.active_count() + self.len.saturating_sub(self.table.len())
    }

    fn frozen_count(&self) -> usize {
        self.table.frozen_count()
    }

    fn frozen_positions(&self) -> Vec<usize> {
        self.table.frozen_positions()
    }

    fn is_frozen(&self, pos: usize) -> bool {
        self.table.is_frozen(pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> FreezeConfig {
        FreezeConfig { n_sink: 4, window_k: 8, r_budget: 16, ..Default::default() }
    }

    #[test]
    fn keeps_exactly_sinks_plus_window() {
        let mut p = StreamingLlmPolicy::new(cfg());
        let len = 50;
        p.on_prefill(&vec![1.0; len], len);
        // drain the eviction backlog
        for step in 0..10 {
            if p.plan(step, len, 16).freeze.is_empty() {
                break;
            }
        }
        assert_eq!(p.active_count(), 4 + 8);
        // active set is exactly sinks + window
        for pos in 0..len {
            let should_be_active = pos < 4 || pos >= len - 8;
            assert_eq!(!p.is_frozen(pos), should_be_active, "pos {pos}");
        }
    }

    #[test]
    fn evicts_as_window_slides() {
        let mut p = StreamingLlmPolicy::new(cfg());
        let mut len = 12; // sinks + window exactly: nothing evictable
        p.on_prefill(&vec![1.0; len], len);
        assert!(p.plan(0, len, 16).freeze.is_empty());
        // each new token pushes one position out of the window
        for step in 1..=5u64 {
            len += 1;
            let plan = p.plan(step, len, 16);
            assert_eq!(plan.freeze, vec![3 + step as usize]);
        }
    }

    #[test]
    fn short_context_untouched() {
        let mut p = StreamingLlmPolicy::new(cfg());
        p.on_prefill(&vec![1.0; 10], 10);
        let plan = p.plan(0, 10, 16);
        assert!(plan.freeze.is_empty());
        assert_eq!(p.active_count(), 10);
    }
}
