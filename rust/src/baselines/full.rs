//! Full KV baseline: no compression, every token stays active forever.
//! This is the paper's Table 1 / Table 3 comparison point.

use crate::kv::policy::{KvPolicy, Plan, UnfreezeScope};

#[derive(Debug, Default)]
pub struct FullKvPolicy {
    len: usize,
}

impl KvPolicy for FullKvPolicy {
    fn name(&self) -> &'static str {
        "full"
    }

    fn on_prefill(&mut self, _scores: &[f32], len: usize) {
        self.len = len;
    }

    fn plan_into(&mut self, _step: u64, len: usize, _r_budget: usize, out: &mut Plan) {
        out.clear();
        self.len = len;
    }

    fn observe(&mut self, _step: u64, _scores: &[f32], len: usize) {
        self.len = len;
    }

    fn request_unfreeze(&mut self, _scope: UnfreezeScope) -> usize {
        0
    }

    fn force_all_active(&mut self) {}

    fn active_count(&self) -> usize {
        self.len
    }

    fn frozen_positions(&self) -> Vec<usize> {
        Vec::new()
    }

    fn is_frozen(&self, _pos: usize) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_plans_anything() {
        let mut p = FullKvPolicy::default();
        p.on_prefill(&[0.0; 10], 10);
        for step in 0..100 {
            p.observe(step, &vec![0.0; 10 + step as usize], 10 + step as usize);
            let plan = p.plan(step, 10 + step as usize, 16);
            assert!(plan.freeze.is_empty() && plan.restore.is_empty());
        }
        assert_eq!(p.active_count(), 109);
        assert_eq!(p.frozen_count(), 0);
    }
}
