//! Eta-indexed thaw scheduler: orders every resident frozen row by its
//! predicted thaw step so demotion and staging are incremental.
//!
//! The store used to answer "which row thaws farthest out?" and "which
//! rows thaw within the horizon?" by scanning its whole entry map —
//! O(n) per decode step in `on_step`/`stage_upcoming` and O(victims·n)
//! in the budget-eviction loops. This index keeps one ordered set of
//! `(thaw_eta, pos)` keys per residency class, so those queries become
//! O(log n) point lookups / O(k) range walks:
//!
//! * `farthest(class)` — the budget-eviction victim (max eta wins; pos
//!   breaks ties deterministically, unlike the old hash-map scan);
//! * `due_frozen(limit, max)` — staging candidates across the cold and
//!   spill classes, soonest first;
//! * `overdue_hot(limit)` — hot rows whose predicted thaw aged past
//!   the residency horizon (the `on_step` sweep).
//!
//! `BTreeSet` rather than `BinaryHeap`: the store always knows a row's
//! current `(eta, pos)` key, so entries are removed exactly on
//! `take`/`drop_row`/tier moves instead of lazily skipping stale heap
//! entries — the index never holds ghosts and its length is the true
//! queue depth (recorded per step in `TieredStore::sched_depth`).

use std::collections::BTreeSet;
use std::ops::Bound;

/// Residency class of an indexed row. Hot rows are split by the staged
/// flag because budget eviction exempts staged rows while the
/// `on_step` residency sweep covers both.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedClass {
    /// Hot tier, admitted at stash time (eviction victim pool).
    HotResident,
    /// Hot tier, promoted by the prefetch path (eviction-exempt).
    HotStaged,
    Cold,
    Spill,
}

#[derive(Debug, Default)]
pub struct ThawScheduler {
    hot: BTreeSet<(u64, usize)>,
    staged: BTreeSet<(u64, usize)>,
    cold: BTreeSet<(u64, usize)>,
    spill: BTreeSet<(u64, usize)>,
}

impl ThawScheduler {
    fn set(&mut self, class: SchedClass) -> &mut BTreeSet<(u64, usize)> {
        match class {
            SchedClass::HotResident => &mut self.hot,
            SchedClass::HotStaged => &mut self.staged,
            SchedClass::Cold => &mut self.cold,
            SchedClass::Spill => &mut self.spill,
        }
    }

    pub fn insert(&mut self, class: SchedClass, eta: u64, pos: usize) {
        let fresh = self.set(class).insert((eta, pos));
        debug_assert!(fresh, "pos {pos} already indexed in {class:?}");
    }

    pub fn remove(&mut self, class: SchedClass, eta: u64, pos: usize) {
        let present = self.set(class).remove(&(eta, pos));
        debug_assert!(present, "pos {pos} (eta {eta}) missing from {class:?} index");
    }

    /// Re-key `pos` within its class after a thaw-prediction refresh.
    pub fn retarget(&mut self, class: SchedClass, pos: usize, old_eta: u64, new_eta: u64) {
        if old_eta == new_eta {
            return;
        }
        self.remove(class, old_eta, pos);
        self.insert(class, new_eta, pos);
    }

    /// The row with the farthest predicted thaw in `class` — the
    /// demotion victim under budget pressure. Ties break toward the
    /// highest position.
    pub fn farthest(&self, class: SchedClass) -> Option<(u64, usize)> {
        let set = match class {
            SchedClass::HotResident => &self.hot,
            SchedClass::HotStaged => &self.staged,
            SchedClass::Cold => &self.cold,
            SchedClass::Spill => &self.spill,
        };
        set.iter().next_back().copied()
    }

    /// Up to `max_rows` frozen rows (cold + spill classes) predicted to
    /// thaw at or before `limit`, soonest first.
    pub fn due_frozen(&self, limit: u64, max_rows: usize) -> Vec<(u64, usize)> {
        let hi = Bound::Included((limit, usize::MAX));
        let mut a = self.cold.range((Bound::Unbounded, hi)).peekable();
        let mut b = self.spill.range((Bound::Unbounded, hi)).peekable();
        let mut out = Vec::new();
        while out.len() < max_rows {
            let take_a = match (a.peek(), b.peek()) {
                (Some(x), Some(y)) => x <= y,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => break,
            };
            let next = if take_a { a.next() } else { b.next() };
            out.push(*next.expect("peeked iterator yielded nothing"));
        }
        out
    }

    /// Hot rows (both classes) whose predicted thaw lies strictly
    /// beyond `limit` — they no longer belong in the hot tier.
    pub fn overdue_hot(&self, limit: u64) -> Vec<(u64, usize)> {
        let lo = Bound::Excluded((limit, usize::MAX));
        let mut out: Vec<(u64, usize)> =
            self.hot.range((lo, Bound::Unbounded)).copied().collect();
        out.extend(self.staged.range((lo, Bound::Unbounded)).copied());
        out
    }

    /// Whether `overdue_hot(limit)` would return anything — an
    /// allocation-free existence probe so per-step sweeps can skip the
    /// full walk (and the sharded facade can skip worker dispatch)
    /// when nothing is due.
    pub fn has_overdue_hot(&self, limit: u64) -> bool {
        let lo = Bound::Excluded((limit, usize::MAX));
        self.hot.range((lo, Bound::Unbounded)).next().is_some()
            || self.staged.range((lo, Bound::Unbounded)).next().is_some()
    }

    /// Rows awaiting staging (cold + spill) — the scheduler's queue
    /// depth gauge.
    pub fn queued_frozen(&self) -> usize {
        self.cold.len() + self.spill.len()
    }

    pub fn len(&self) -> usize {
        self.hot.len() + self.staged.len() + self.cold.len() + self.spill.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn farthest_breaks_ties_by_position() {
        let mut s = ThawScheduler::default();
        s.insert(SchedClass::HotResident, 10, 1);
        s.insert(SchedClass::HotResident, 10, 5);
        s.insert(SchedClass::HotResident, 3, 9);
        assert_eq!(s.farthest(SchedClass::HotResident), Some((10, 5)));
        s.remove(SchedClass::HotResident, 10, 5);
        assert_eq!(s.farthest(SchedClass::HotResident), Some((10, 1)));
        assert_eq!(s.farthest(SchedClass::Cold), None);
    }

    #[test]
    fn due_frozen_merges_cold_and_spill_soonest_first() {
        let mut s = ThawScheduler::default();
        s.insert(SchedClass::Cold, 5, 0);
        s.insert(SchedClass::Cold, 9, 1);
        s.insert(SchedClass::Spill, 7, 2);
        s.insert(SchedClass::Spill, 20, 3); // beyond limit
        assert_eq!(s.due_frozen(10, 8), vec![(5, 0), (7, 2), (9, 1)]);
        assert_eq!(s.due_frozen(10, 2), vec![(5, 0), (7, 2)]);
        assert_eq!(s.due_frozen(4, 8), vec![]);
        // eta exactly at the limit is due
        assert_eq!(s.due_frozen(5, 1), vec![(5, 0)]);
    }

    #[test]
    fn overdue_hot_spans_both_hot_classes() {
        let mut s = ThawScheduler::default();
        s.insert(SchedClass::HotResident, 4, 0);
        s.insert(SchedClass::HotResident, 11, 1);
        s.insert(SchedClass::HotStaged, 12, 2);
        s.insert(SchedClass::HotStaged, 10, 3);
        let mut over = s.overdue_hot(10);
        over.sort_unstable();
        // eta == limit is NOT overdue
        assert_eq!(over, vec![(11, 1), (12, 2)]);
        // the existence probe agrees with the full walk
        assert!(s.has_overdue_hot(10));
        assert!(!s.has_overdue_hot(12));
    }

    #[test]
    fn retarget_rekeys_within_class() {
        let mut s = ThawScheduler::default();
        s.insert(SchedClass::Cold, 30, 4);
        s.retarget(SchedClass::Cold, 4, 30, 6);
        assert_eq!(s.due_frozen(10, 8), vec![(6, 4)]);
        s.retarget(SchedClass::Cold, 4, 6, 6); // no-op
        assert_eq!(s.len(), 1);
        assert_eq!(s.queued_frozen(), 1);
        s.remove(SchedClass::Cold, 6, 4);
        assert!(s.is_empty());
    }
}
