//! The `Tier` trait — the pluggable storage-backend surface of the
//! tiered frozen-KV store.
//!
//! `TieredStore` used to be one monolithic struct that knew how to
//! pool hot rows, quantize cold rows, and talk to the spill file. The
//! trait splits those responsibilities: each tier is a self-contained
//! backend that stores row payloads keyed by sequence position and
//! accounts for its own bytes, while residency *policy* (which row
//! lives in which tier, driven by predicted thaw step) stays in
//! `TieredStore` + `ThawScheduler`. New backends — pinned host memory,
//! GPUDirect staging buffers, a remote KV service (ARKV,
//! arXiv 2603.08727) — implement this trait and slot in without
//! touching the scheduler or the engine.
//!
//! Payloads move between tiers as [`RowPayload`]: a raw f32 row or one
//! of the `offload::codec` ladder's encoded representations, tagged by
//! [`CodecId`]. A tier stores the payload it is handed verbatim
//! (`into_raw` / `into_quant` convert on demand), so a cold -> spill
//! demotion moves the encoded record as-is instead of paying a
//! decode/re-encode round trip.

use crate::error::Result;
use crate::metrics::{TierKind, TierOccupancy};
use crate::offload::codec::CodecId;
use crate::offload::quant::{self, BoundedRow, PackedRow, QuantRow};

/// A frozen-row payload in transit between tiers, tagged by the codec
/// rung that produced it (`RowPayload::codec`).
#[derive(Debug, Clone)]
pub enum RowPayload {
    /// Full-precision row bundle (`row_floats` f32s).
    Raw(Vec<f32>),
    /// u8-quantized row with per-row affine header.
    Quant(QuantRow),
    /// u4 block-quantized row (per-block affine, packed nibbles).
    Packed(PackedRow),
    /// Error-bounded variable-rate row (0/2/4/8-bit blocks).
    Bounded(BoundedRow),
}

impl RowPayload {
    /// The codec rung this payload is encoded with.
    pub fn codec(&self) -> CodecId {
        match self {
            RowPayload::Raw(_) => CodecId::Raw,
            RowPayload::Quant(_) => CodecId::U8,
            RowPayload::Packed(_) => CodecId::U4,
            RowPayload::Bounded(_) => CodecId::Ebq,
        }
    }

    /// Bytes this payload occupies in its current representation.
    pub fn bytes(&self) -> usize {
        match self {
            RowPayload::Raw(r) => r.len() * std::mem::size_of::<f32>(),
            RowPayload::Quant(q) => q.bytes(),
            RowPayload::Packed(p) => p.bytes(),
            RowPayload::Bounded(b) => b.bytes(),
        }
    }

    /// Number of floats the reconstructed row carries.
    pub fn row_floats(&self) -> usize {
        match self {
            RowPayload::Raw(r) => r.len(),
            RowPayload::Quant(q) => q.q.len(),
            RowPayload::Packed(p) => p.floats,
            RowPayload::Bounded(b) => b.floats,
        }
    }

    /// Decode the full-precision row into a caller-provided buffer
    /// (len must match) without consuming the payload.
    pub fn decode_into(&self, dst: &mut [f32]) {
        match self {
            RowPayload::Raw(r) => dst.copy_from_slice(r),
            RowPayload::Quant(q) => quant::dequantize_into(q, dst),
            RowPayload::Packed(p) => quant::unpack_u4_into(p, dst),
            RowPayload::Bounded(b) => quant::decode_ebq_into(b, dst),
        }
    }

    /// Reconstruct the full-precision row (decodes if needed).
    pub fn into_raw(self) -> Vec<f32> {
        match self {
            RowPayload::Raw(r) => r,
            RowPayload::Quant(q) => quant::dequantize(&q),
            RowPayload::Packed(p) => quant::unpack_u4(&p),
            RowPayload::Bounded(b) => quant::decode_ebq(&b),
        }
    }

    /// Convert to the u8-quantized representation (encodes a raw row;
    /// decodes-then-requantizes a sub-byte one — a representation
    /// *change*, so callers on the data path should prefer storing the
    /// payload verbatim).
    ///
    /// Re-quantizing a row that was itself dequantized from a u8
    /// record is exact: quantization always assigns code 0 to the row
    /// minimum and 255 to the maximum, so the reconstructed extremes
    /// regenerate the identical lattice.
    pub fn into_quant(self) -> QuantRow {
        match self {
            RowPayload::Quant(q) => q,
            other => quant::quantize(&other.into_raw()),
        }
    }
}

/// One storage backend for frozen KV rows.
///
/// Implementations store payloads keyed by sequence position and own
/// their byte accounting. They do NOT decide *which* rows they hold —
/// admission, demotion, and staging policy live in `TieredStore`,
/// driven by the `ThawScheduler`'s predicted-thaw ordering.
///
/// Contract: `stash` on an occupied position is an error (the store
/// guards residency, so a collision is an invariant breach); `take` /
/// `stage` / `discard` on an absent position report absence rather
/// than erroring (`Ok(None)` / `Ok(false)`) — the store converts
/// absence into `Error::Offload` where it implies corruption.
pub trait Tier {
    /// Which occupancy gauge family this backend feeds.
    fn kind(&self) -> TierKind;

    /// Admit a payload for `pos`.
    fn stash(&mut self, pos: usize, payload: RowPayload) -> Result<()>;

    /// Remove and return the payload for `pos` (restore / demotion
    /// source). `Ok(None)` when the tier does not hold `pos`.
    fn take(&mut self, pos: usize) -> Result<Option<RowPayload>>;

    /// Remove the payload for promotion into a warmer tier. Same data
    /// movement as `take`, but kept separate on the trait so
    /// asynchronous backends can overlap it with compute (read-ahead
    /// into a pinned staging buffer) without conflating it with the
    /// latency-critical restore path.
    fn stage(&mut self, pos: usize) -> Result<Option<RowPayload>> {
        self.take(pos)
    }

    /// Drop the payload without reconstructing it. Returns whether the
    /// tier actually held `pos`; bookkeeping failures (e.g. a stale
    /// spill handle) surface as `Error::Offload`.
    fn discard(&mut self, pos: usize) -> Result<bool>;

    /// Bytes currently held by this backend.
    fn bytes(&self) -> usize;

    /// Rows currently held by this backend.
    fn rows(&self) -> usize;

    /// Fold this backend's gauges into an occupancy snapshot.
    fn occupancy(&self, out: &mut TierOccupancy);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_conversions_roundtrip() {
        let row: Vec<f32> = (0..16).map(|i| i as f32 * 0.5 - 4.0).collect();
        let raw = RowPayload::Raw(row.clone());
        assert_eq!(raw.row_floats(), 16);
        assert_eq!(raw.bytes(), 64);
        let q = raw.into_quant();
        let back = RowPayload::Quant(q.clone()).into_raw();
        let bound = q.error_bound();
        for (a, b) in row.iter().zip(&back) {
            assert!((a - b).abs() <= bound, "{a} vs {b}");
        }
        // quant -> quant is a no-op move
        let q2 = RowPayload::Quant(q.clone()).into_quant();
        assert_eq!(q2, q);
    }

    #[test]
    fn requantization_does_not_drift() {
        // dequantize -> requantize regenerates the same code lattice
        // (code 0 / 255 pin the row extremes), so stage + demote churn
        // never accumulates error beyond the single-quantization bound.
        let row: Vec<f32> = (0..32).map(|i| (i as f32 * 0.7).sin() * 3.0 + 1.0).collect();
        let q1 = RowPayload::Raw(row.clone()).into_quant();
        let dequant = RowPayload::Quant(q1.clone()).into_raw();
        let q2 = RowPayload::Raw(dequant).into_quant();
        assert_eq!(q1.q, q2.q, "codes must survive a requantization round trip");
        let bound = q1.error_bound();
        let back = RowPayload::Quant(q2).into_raw();
        for (a, b) in row.iter().zip(&back) {
            assert!((a - b).abs() <= 2.0 * bound, "{a} drifted to {b}");
        }
    }
}
