//! Hot tier: uncompressed f32 rows in fixed-size block-pooled slabs.
//!
//! Restores served from here are plain copies — this is where the
//! prefetch path (`TieredStore::stage`/`stage_upcoming`) parks rows it
//! promotes ahead of their predicted thaw. The block layout keeps the
//! tier's footprint at its high-water mark (freed slots are reused)
//! and keeps rows slab-contiguous for batched gather/scatter.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::metrics::{TierKind, TierOccupancy};
use crate::offload::tier::{RowPayload, Tier};

/// Uncompressed host rows in fixed-size slabs (`block_rows` rows per
/// slab). Slots are stable u32 handles; freed slots are reused, so a
/// long-running session's hot tier stays at its high-water footprint
/// instead of fragmenting the allocator.
#[derive(Debug)]
struct HotPool {
    row_floats: usize,
    block_rows: usize,
    slabs: Vec<Vec<f32>>,
    free: Vec<u32>,
}

impl HotPool {
    fn new(row_floats: usize, block_rows: usize) -> HotPool {
        HotPool { row_floats, block_rows: block_rows.max(1), slabs: Vec::new(), free: Vec::new() }
    }

    fn alloc(&mut self, row: &[f32]) -> u32 {
        let slot = self.free.pop().unwrap_or_else(|| {
            let slot = (self.slabs.len() * self.block_rows) as u32;
            self.slabs.push(vec![0.0; self.block_rows * self.row_floats]);
            for s in (1..self.block_rows as u32).rev() {
                self.free.push(slot + s);
            }
            slot
        });
        self.row_mut(slot).copy_from_slice(row);
        slot
    }

    fn row(&self, slot: u32) -> &[f32] {
        let (b, i) = (slot as usize / self.block_rows, slot as usize % self.block_rows);
        &self.slabs[b][i * self.row_floats..(i + 1) * self.row_floats]
    }

    fn row_mut(&mut self, slot: u32) -> &mut [f32] {
        let (b, i) = (slot as usize / self.block_rows, slot as usize % self.block_rows);
        &mut self.slabs[b][i * self.row_floats..(i + 1) * self.row_floats]
    }

    fn release(&mut self, slot: u32) {
        debug_assert!(!self.free.contains(&slot), "double free of hot slot {slot}");
        self.free.push(slot);
    }
}

/// The in-memory uncompressed tier.
#[derive(Debug)]
pub struct HotTier {
    pool: HotPool,
    slots: HashMap<usize, u32>,
    bytes: usize,
    row_floats: usize,
}

impl HotTier {
    pub fn new(row_floats: usize, block_rows: usize) -> HotTier {
        HotTier {
            pool: HotPool::new(row_floats, block_rows),
            slots: HashMap::new(),
            bytes: 0,
            row_floats,
        }
    }

    pub fn row_bytes(&self) -> usize {
        self.row_floats * std::mem::size_of::<f32>()
    }

    /// Whether one more row fits under `budget_bytes`.
    pub fn has_headroom(&self, budget_bytes: usize) -> bool {
        self.bytes + self.row_bytes() <= budget_bytes
    }
}

impl Tier for HotTier {
    fn kind(&self) -> TierKind {
        TierKind::Hot
    }

    fn stash(&mut self, pos: usize, payload: RowPayload) -> Result<()> {
        if self.slots.contains_key(&pos) {
            return Err(Error::Offload(format!("hot tier already holds pos {pos}")));
        }
        let row = payload.into_raw();
        if row.len() != self.row_floats {
            return Err(Error::Offload(format!(
                "hot row for pos {pos} has {} floats, tier expects {}",
                row.len(),
                self.row_floats
            )));
        }
        let slot = self.pool.alloc(&row);
        self.slots.insert(pos, slot);
        self.bytes += self.row_bytes();
        Ok(())
    }

    fn take(&mut self, pos: usize) -> Result<Option<RowPayload>> {
        let Some(slot) = self.slots.remove(&pos) else { return Ok(None) };
        let row = self.pool.row(slot).to_vec();
        self.pool.release(slot);
        self.bytes -= self.row_bytes();
        Ok(Some(RowPayload::Raw(row)))
    }

    fn discard(&mut self, pos: usize) -> Result<bool> {
        let Some(slot) = self.slots.remove(&pos) else { return Ok(false) };
        self.pool.release(slot);
        self.bytes -= self.row_bytes();
        Ok(true)
    }

    fn bytes(&self) -> usize {
        self.bytes
    }

    fn rows(&self) -> usize {
        self.slots.len()
    }

    fn occupancy(&self, out: &mut TierOccupancy) {
        out.hot_rows += self.slots.len();
        out.hot_bytes += self.bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(rf: usize, v: f32) -> Vec<f32> {
        (0..rf).map(|i| v + i as f32 * 0.01).collect()
    }

    #[test]
    fn stash_take_is_exact() {
        let mut t = HotTier::new(8, 4);
        let r = row(8, 1.0);
        t.stash(3, RowPayload::Raw(r.clone())).unwrap();
        assert_eq!(t.rows(), 1);
        assert_eq!(t.bytes(), 32);
        assert_eq!(t.take(3).unwrap().unwrap().into_raw(), r);
        assert_eq!(t.rows(), 0);
        assert_eq!(t.bytes(), 0);
        assert!(t.take(3).unwrap().is_none());
    }

    #[test]
    fn slots_reused_across_release() {
        let mut t = HotTier::new(4, 2);
        for pos in 0..6 {
            t.stash(pos, RowPayload::Raw(row(4, pos as f32))).unwrap();
        }
        for pos in 0..6 {
            assert!(t.discard(pos).unwrap());
        }
        // the pool keeps its slabs; re-stashing allocates no new blocks
        for pos in 10..16 {
            t.stash(pos, RowPayload::Raw(row(4, pos as f32))).unwrap();
        }
        assert_eq!(t.pool.slabs.len(), 3);
        assert_eq!(t.take(12).unwrap().unwrap().into_raw(), row(4, 12.0));
    }

    #[test]
    fn double_stash_and_headroom() {
        let mut t = HotTier::new(4, 2);
        t.stash(0, RowPayload::Raw(row(4, 0.0))).unwrap();
        assert!(t.stash(0, RowPayload::Raw(row(4, 1.0))).is_err());
        assert!(t.has_headroom(32));
        assert!(!t.has_headroom(31));
        assert!(!t.discard(9).unwrap());
    }
}
