//! Tiered off-GPU frozen-KV storage — the production-shaped successor
//! to the flat `kv::FrozenStore`.
//!
//! The paper's core promise is that soft-frozen rows are *preserved*
//! off-GPU and restored on demand. At serving scale that needs more
//! than a `HashMap<usize, Vec<f32>>`: byte budgets, a layout that
//! batches transfers, compression for rows that will stay frozen, and
//! a restore path that does not stall the decode step. This module
//! provides all four:
//!
//! ```text
//!              stash (freeze)                 take (restore)
//!   active KV ───────────────► TieredStore ───────────────► active KV
//!                                   │
//!               ┌───────────────────┼──────────────────────┐
//!               ▼                   ▼                      ▼
//!          HOT tier            COLD tier              SPILL tier
//!      uncompressed f32     codec-encoded rows     file-backed codec
//!      block-pooled rows    (u8 / u4 / ebq by      records (very long
//!      (byte budget)        thaw eta; budget)      contexts; optional)
//!               ▲                   │                      │
//!               └── stage() / stage_upcoming() ◄───────────┘
//!                   prefetch-ahead: dequantize BETWEEN decode
//!                   steps, so take() from a staged row is a copy
//! ```
//!
//! * **Admission/demotion** is driven by the freeze ladder's predicted
//!   thaw step (`Plan::freeze_thaw_eta`): rows predicted back within
//!   `OffloadConfig::cold_after_steps` stay hot, the rest are encoded
//!   at stash time with the [`codec::CodecLadder`] rung picked from
//!   the predicted thaw distance (`--codec-ladder`, default u8-only).
//!   `on_step` re-applies the rule so stale prefetches drain back to
//!   cold.
//! * **Prefetch-ahead** (`stage`, `stage_upcoming`) is fed by two
//!   signals: the policy's imminent-thaw hints (`Plan::prefetch`) and
//!   the `recovery::EntropyMonitor` trending toward a trigger
//!   (`pressure()` ≥ `OffloadConfig::stage_pressure`), so recovery
//!   unfreezes land on already-staged rows.
//! * **Pipelined restore** (`ShardedStore::pipeline_advance`): at each
//!   step boundary the facade asks every idle shard's eta index for
//!   rows due to thaw within the prefetch horizon and ships them to
//!   the worker pool as non-destructive speculative reads (promote +
//!   decode, nothing consumed). The reads execute while the next step
//!   computes; `take_batch` serves landed copies with a map lookup
//!   and mutations fence stale copies by position (see
//!   `README.md` for the in-flight state machine).
//! * **Accounting** feeds `metrics::TierOccupancy` gauges and
//!   per-tier `metrics::RestoreLatency` histograms; the conservation
//!   invariant `total_stashed == total_restored + total_dropped +
//!   resident` is property-tested in `tests/prop_offload.rs`.
//!
//! Architecture (see `README.md` in this directory): storage backends
//! implement the [`Tier`] trait (`hot` / `cold` / `spill` modules) so
//! pinned-host or remote backends can slot in; `TieredStore` owns only
//! residency *policy*, and every per-step decision is answered by the
//! [`ThawScheduler`]'s eta index instead of a full-map scan —
//! equivalence with the brute-force scan is property-tested by the
//! scheduler oracle in `tests/prop_offload.rs`. Above the store,
//! [`ShardedStore`] (`sharded` module) partitions sequence positions
//! across N independent stores on a persistent worker pool, so one
//! session's restore burst executes per-shard in parallel; it is the
//! handle `Session`/`BatchEngine` actually hold (`shards = 1`
//! degenerates to the single-store behavior).
//!
//! References: FreeKV (arXiv 2505.13109) for speculative double-
//! buffered retrieval; KVComp (arXiv 2509.00579) for lossy compression
//! of frozen rows; ARKV (arXiv 2603.08727) for pluggable storage
//! backends under a fixed budget.

pub mod codec;
pub mod cold;
pub mod fault;
pub mod hot;
pub mod quant;
pub mod sched;
pub mod sharded;
pub mod spill;
pub mod store;
pub mod tier;

pub use codec::{Codec, CodecId, CodecLadder, CodecSet};
pub use cold::ColdTier;
pub use fault::{FaultInjector, FaultSite, RetryOp, RetryOutcome, RetryPolicy};
pub use hot::HotTier;
pub use quant::{
    decode_ebq, decode_ebq_into, dequantize, dequantize_into, encode_ebq, pack_u4, quantize,
    unpack_u4, unpack_u4_into, BoundedRow, PackedRow, QuantRow,
};
pub use sched::{SchedClass, ThawScheduler};
pub use sharded::{ShardedStore, MAX_SHARDS};
pub use spill::{record_bytes_for, record_path, SpillFile, SpillManifest, SpillTier};
pub use store::TieredStore;
pub use tier::{RowPayload, Tier};

use crate::metrics::{Snapshot, TierOccupancy};

/// Per-session offload snapshot: occupancy gauges + restore counters.
/// Attached to `GenStats` / `GenResponse` so benches can trace the
/// memory/latency trade of tiering per request.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OffloadSummary {
    pub occupancy: TierOccupancy,
    /// restores served from a prefetch-staged hot row (no inline work)
    pub staged_hits: u64,
    /// restores that paid inline dequantization / spill I/O
    pub staged_misses: u64,
    pub demotions_cold: u64,
    pub demotions_spill: u64,
    pub prefetch_promotions: u64,
    pub restores_hot: u64,
    pub restores_cold: u64,
    pub restores_spill: u64,
    pub restore_hot_mean_us: u64,
    pub restore_cold_mean_us: u64,
    /// high-water mark of the thaw scheduler's frozen queue
    pub sched_depth_max: u64,
    /// rows re-attached from a persistent spill directory at resume
    /// (`--spill-persist`; see `spill::SpillManifest`)
    pub recovered_rows: u64,
    /// records the recovery scan rejected (corrupt, fenced-generation,
    /// duplicate, or torn) — reclaimed, never re-served
    pub recovery_errors: u64,
    /// rows restored through batched plan execution (engine-side;
    /// filled by `Session::offload_summary`)
    pub restore_batch_rows: u64,
    /// contiguous spans those restored rows coalesced into — spans <<
    /// rows is the batching win
    pub restore_batch_spans: u64,
    /// shard count of the store behind this summary (1 = unsharded)
    pub shards: u64,
    /// most shards engaged by a single restore burst — > 1 means
    /// restores actually executed per-shard in parallel
    pub restore_parallelism_max: u64,
    /// restore bursts where one shard carried at least twice the even
    /// share (partition scheme fighting the access pattern)
    pub shard_imbalance: u64,
    /// resident rows on the emptiest shard (imbalance gauge floor)
    pub shard_rows_min: u64,
    /// resident rows on the fullest shard (imbalance gauge ceiling)
    pub shard_rows_max: u64,
    /// speculative restore reads issued by the pipeline driver
    pub spec_issued: u64,
    /// speculative reads that landed a valid (current-generation) copy
    pub spec_landed: u64,
    /// speculative work discarded: stale generation, fence on
    /// mutation, deadline expiry, or drain
    pub spec_cancelled: u64,
    /// takes served straight from the landing buffer (tier I/O fully
    /// hidden behind decode)
    pub spec_consumed: u64,
    /// takes that had to block on a still-in-flight speculative read
    pub late_arrivals: u64,
    /// total per-step wall time blocked waiting for in-flight reads
    pub restore_wait_us: u64,
    /// mean in-worker service time of speculative reads — the tier
    /// latency that ran overlapped with decode
    pub restore_overlap_mean_us: u64,
    /// faults the seeded injector fired, all sites (0 unless armed)
    pub faults_injected: u64,
    /// spill I/O retries taken (attempts beyond the first), all
    /// ops and outcomes
    pub io_retries: u64,
    /// shard rebuilds the supervisor performed after a worker panic
    /// or loss (re-adopting spilled rows via the recovery path)
    pub shard_rebuilds: u64,
    /// rows a rebuild could not recover (no spilled copy) — declared
    /// lost in the typed per-position loss set, never served as
    /// wrong bytes
    pub rows_lost: u64,
    /// cumulative mean payload bytes per row admitted to each tier —
    /// the codec ladder's compression win shows up as cold/spill
    /// bytes/row dropping below the u8 baseline (`8 + row_floats`)
    pub bytes_per_row_hot: u64,
    pub bytes_per_row_cold: u64,
    pub bytes_per_row_spill: u64,
    /// resident rows currently held in a sub-byte encoding (u4 + ebq)
    pub codec_rows_sub_byte: u64,
    /// mean ladder encode / decode kernel time across codec rungs
    pub codec_encode_mean_us: u64,
    pub codec_decode_mean_us: u64,
}

impl OffloadSummary {
    /// Build the flat summary view from a registry snapshot (the
    /// output of `TieredStore::snapshot` / `ShardedStore::snapshot`).
    /// The snapshot is the source of truth — this struct only flattens
    /// it for responses and bench CSVs. Engine-side batching counters
    /// (`restore_batch_*`) stay zero here; `Session::offload_summary`
    /// overlays them.
    pub fn from_snapshot(s: &Snapshot) -> OffloadSummary {
        let tier_gauge = |name: &str, tier: &str| s.gauge_sum(name, &[("tier", tier)]) as usize;
        let restore = |tier: &str| s.hist("asrkf_restore_us", &[("tier", tier)]);
        let bytes_per_row = |tier: &str| {
            let rows = s.counter_sum("asrkf_tier_rows_stored_total", &[("tier", tier)]);
            if rows == 0 {
                0
            } else {
                s.counter_sum("asrkf_tier_row_bytes_total", &[("tier", tier)]) / rows
            }
        };
        let codec_mean = |name: &str| {
            let (mut count, mut sum) = (0u64, 0.0f64);
            for id in CodecId::ALL {
                if let Some(h) = s.hist(name, &[("codec", id.as_str())]) {
                    count += h.count;
                    sum += h.sum;
                }
            }
            if count == 0 {
                0
            } else {
                (sum / count as f64) as u64
            }
        };
        let occupancy = TierOccupancy {
            hot_rows: tier_gauge("asrkf_tier_rows", "hot"),
            hot_bytes: tier_gauge("asrkf_tier_bytes", "hot"),
            cold_rows: tier_gauge("asrkf_tier_rows", "cold"),
            cold_bytes: tier_gauge("asrkf_tier_bytes", "cold"),
            spill_rows: tier_gauge("asrkf_tier_rows", "spill"),
            spill_bytes: tier_gauge("asrkf_tier_bytes", "spill"),
            peak_hot_bytes: tier_gauge("asrkf_tier_peak_bytes", "hot"),
            peak_cold_bytes: tier_gauge("asrkf_tier_peak_bytes", "cold"),
            peak_spill_bytes: tier_gauge("asrkf_tier_peak_bytes", "spill"),
            uncompressed_bytes: s.gauge_sum("asrkf_uncompressed_bytes", &[]) as usize,
        };
        OffloadSummary {
            occupancy,
            staged_hits: s.counter_sum("asrkf_staged_total", &[("result", "hit")]),
            staged_misses: s.counter_sum("asrkf_staged_total", &[("result", "miss")]),
            demotions_cold: s.counter_sum("asrkf_demotion_total", &[("to", "cold")]),
            demotions_spill: s.counter_sum("asrkf_demotion_total", &[("to", "spill")]),
            prefetch_promotions: s.counter_sum("asrkf_promotion_total", &[]),
            restores_hot: restore("hot").map(|h| h.count).unwrap_or(0),
            restores_cold: restore("cold").map(|h| h.count).unwrap_or(0),
            restores_spill: restore("spill").map(|h| h.count).unwrap_or(0),
            restore_hot_mean_us: restore("hot").map(|h| h.mean as u64).unwrap_or(0),
            restore_cold_mean_us: restore("cold").map(|h| h.mean as u64).unwrap_or(0),
            sched_depth_max: s.hist("asrkf_sched_depth", &[]).map(|h| h.max as u64).unwrap_or(0),
            recovered_rows: s.counter_sum("asrkf_recovered_rows_total", &[]),
            recovery_errors: s.counter_sum("asrkf_recovery_errors_total", &[]),
            restore_batch_rows: s.counter_sum("asrkf_restore_batch_rows_total", &[]),
            restore_batch_spans: s.counter_sum("asrkf_restore_batch_spans_total", &[]),
            shards: s.gauge("asrkf_shards", &[]) as u64,
            restore_parallelism_max: s
                .hist("asrkf_restore_parallelism", &[])
                .map(|h| h.max as u64)
                .unwrap_or(0),
            shard_imbalance: s.counter_sum("asrkf_shard_imbalance_total", &[]),
            shard_rows_min: s.gauge_min("asrkf_shard_rows", &[]).unwrap_or(0.0) as u64,
            shard_rows_max: s.gauge_max("asrkf_shard_rows", &[]).unwrap_or(0.0) as u64,
            spec_issued: s.counter_sum("asrkf_spec_issued_total", &[]),
            spec_landed: s.counter_sum("asrkf_spec_landed_total", &[]),
            spec_cancelled: s.counter_sum("asrkf_spec_cancelled_total", &[]),
            spec_consumed: s.counter_sum("asrkf_spec_consumed_total", &[]),
            late_arrivals: s.counter_sum("asrkf_late_arrivals_total", &[]),
            restore_wait_us: s
                .hist("asrkf_restore_wait_us", &[])
                .map(|h| h.sum as u64)
                .unwrap_or(0),
            restore_overlap_mean_us: s
                .hist("asrkf_restore_overlap_us", &[])
                .map(|h| h.mean as u64)
                .unwrap_or(0),
            faults_injected: s.counter_sum("asrkf_faults_injected_total", &[]),
            io_retries: s.counter_sum("asrkf_io_retries_total", &[]),
            shard_rebuilds: s.counter_sum("asrkf_shard_rebuilds_total", &[]),
            rows_lost: s.counter_sum("asrkf_rows_lost_total", &[]),
            bytes_per_row_hot: bytes_per_row("hot"),
            bytes_per_row_cold: bytes_per_row("cold"),
            bytes_per_row_spill: bytes_per_row("spill"),
            codec_rows_sub_byte: s.gauge_sum("asrkf_codec_rows", &[("codec", "u4")]) as u64
                + s.gauge_sum("asrkf_codec_rows", &[("codec", "ebq")]) as u64,
            codec_encode_mean_us: codec_mean("asrkf_codec_encode_us"),
            codec_decode_mean_us: codec_mean("asrkf_codec_decode_us"),
        }
    }

    /// Fraction of restores that never touched a compressed row at
    /// restore time (hot-tier hits, staged or resident).
    pub fn hot_restore_frac(&self) -> f64 {
        let total = self.restores_hot + self.restores_cold + self.restores_spill;
        if total == 0 {
            return 1.0;
        }
        self.restores_hot as f64 / total as f64
    }
}
