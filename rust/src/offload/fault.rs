//! Deterministic, seeded fault injection + retry policy for the
//! offload I/O and worker-pool boundaries.
//!
//! Two cooperating pieces:
//!
//! * [`FaultInjector`] — a config-gated probability gate consulted at
//!   the `SpillFile` / `Tier` / worker-pool seams. Disabled (the
//!   default: no `--fault-seed`) it is a `None` check and costs
//!   nothing; armed, every draw comes from a dedicated seeded
//!   [`Pcg64`] stream so a fault trace replays bit-for-bit from its
//!   seed. Sites: spill read/write/free I/O errors, torn (partial)
//!   record writes, worker panics, and delayed worker replies.
//! * [`RetryPolicy`] — bounded retry with exponential backoff,
//!   seeded jitter, and a per-op wall-clock deadline, wrapped around
//!   the spill read/write/free paths so a *transient* I/O error (real
//!   or injected) no longer surfaces as a fail-fast `Error::Offload`.
//!   `RetryPolicy::none()` (one attempt, the tier-level default)
//!   reproduces the pre-retry behavior exactly.
//!
//! Both keep per-site / per-op atomic counters that `publish_flows`
//! folds into `asrkf_faults_injected_total{site}` and
//! `asrkf_io_retries_total{op,outcome}`.
//!
//! A third, test-only seam: [`arm_worker_kill`] registers a spill
//! directory in a process-global one-shot kill list; the next worker
//! op executed by a store whose spill dir lives under a registered
//! path panics. This is how the coordinator test kills exactly one
//! session's shard mid-flight without arming random injection for the
//! whole batch. The fast path is a single relaxed atomic load.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::config::OffloadConfig;
use crate::error::{Error, Result};
use crate::util::rng::Pcg64;

/// Where a fault is injected. Doubles as the `site` label on
/// `asrkf_faults_injected_total`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// `SpillFile::read_row` returns an I/O error.
    SpillRead,
    /// `SpillFile::write_row` returns an I/O error before writing.
    SpillWrite,
    /// `SpillFile::free_slot` returns an I/O error.
    SpillFree,
    /// `SpillFile` writes a truncated record, then errors — the torn
    /// bytes must be rejected by the recovery scan, never re-served.
    TornWrite,
    /// A worker-pool op panics at entry (before mutating its shard).
    WorkerPanic,
    /// A worker-pool op sleeps before executing — a delayed reply.
    ReplyDelay,
}

/// Number of fault sites (array-index space for the counters).
pub const FAULT_SITES: usize = 6;

impl FaultSite {
    pub const ALL: [FaultSite; FAULT_SITES] = [
        FaultSite::SpillRead,
        FaultSite::SpillWrite,
        FaultSite::SpillFree,
        FaultSite::TornWrite,
        FaultSite::WorkerPanic,
        FaultSite::ReplyDelay,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            FaultSite::SpillRead => "spill_read",
            FaultSite::SpillWrite => "spill_write",
            FaultSite::SpillFree => "spill_free",
            FaultSite::TornWrite => "torn_write",
            FaultSite::WorkerPanic => "worker_panic",
            FaultSite::ReplyDelay => "reply_delay",
        }
    }
}

struct FaultState {
    /// Per-site injection probability in [0, 1], indexed by site.
    rates: [f64; FAULT_SITES],
    /// Sleep applied when a `ReplyDelay` fires.
    delay_us: u64,
    /// Dedicated draw stream — one per store, so a shard's fault
    /// trace is a pure function of (seed, its own op sequence).
    rng: Mutex<Pcg64>,
    injected: [AtomicU64; FAULT_SITES],
}

/// Config-gated fault injector. `Clone` shares the underlying state
/// (counters and rng stream), so the spill file, the tier, and the
/// store all observe one coherent trace.
#[derive(Clone, Default)]
pub struct FaultInjector {
    state: Option<Arc<FaultState>>,
    /// Spill directory of the owning store — the kill-switch routing
    /// key. Present even when injection is disabled so a targeted
    /// test kill needs no `--fault-seed`.
    dir: Option<Arc<PathBuf>>,
}

impl std::fmt::Debug for FaultInjector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultInjector").field("enabled", &self.state.is_some()).finish()
    }
}

impl FaultInjector {
    /// Inert injector: every check is a `None` branch.
    pub fn disabled() -> Self {
        FaultInjector::default()
    }

    /// Build from config. Armed only when `fault_seed` is set; the
    /// spill dir (when configured) is always recorded for kill-switch
    /// routing.
    pub fn from_cfg(cfg: &OffloadConfig) -> Self {
        let dir = cfg.spill_dir.as_ref().map(|s| Arc::new(PathBuf::from(s)));
        let Some(seed) = cfg.fault_seed else {
            return FaultInjector { state: None, dir };
        };
        let mut rates = [0.0; FAULT_SITES];
        rates[FaultSite::SpillRead as usize] = cfg.fault_io_rate;
        rates[FaultSite::SpillWrite as usize] = cfg.fault_io_rate;
        rates[FaultSite::SpillFree as usize] = cfg.fault_io_rate;
        rates[FaultSite::TornWrite as usize] = cfg.fault_torn_rate;
        rates[FaultSite::WorkerPanic as usize] = cfg.fault_panic_rate;
        rates[FaultSite::ReplyDelay as usize] = cfg.fault_delay_rate;
        FaultInjector {
            state: Some(Arc::new(FaultState {
                rates,
                delay_us: cfg.fault_delay_us,
                rng: Mutex::new(Pcg64::with_stream(seed, 0xfa17)),
                injected: std::array::from_fn(|_| AtomicU64::new(0)),
            })),
            dir,
        }
    }

    pub fn enabled(&self) -> bool {
        self.state.is_some()
    }

    /// Draw once against `site`'s rate; count and report a hit.
    #[inline]
    pub fn fire(&self, site: FaultSite) -> bool {
        let Some(st) = &self.state else { return false };
        let rate = st.rates[site as usize];
        if rate <= 0.0 {
            return false;
        }
        let hit = st.rng.lock().unwrap_or_else(|p| p.into_inner()).f64() < rate;
        if hit {
            st.injected[site as usize].fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// `fire` packaged as the typed error the I/O seams return.
    #[inline]
    pub fn io_error(&self, site: FaultSite) -> Result<()> {
        if self.fire(site) {
            return Err(Error::Offload(format!("injected fault: {}", site.as_str())));
        }
        Ok(())
    }

    /// Worker-op entry hook: honor a targeted one-shot kill, then the
    /// probabilistic panic/delay sites. Called *before* the op touches
    /// its shard, so a panicked op is guaranteed to have done nothing.
    #[inline]
    pub fn worker_op(&self) {
        if KILL_ARMED.load(Ordering::Relaxed) {
            if let Some(dir) = &self.dir {
                if take_kill(dir) {
                    self.count(FaultSite::WorkerPanic);
                    panic!("injected worker kill ({})", dir.display());
                }
            }
        }
        if self.state.is_none() {
            return;
        }
        if self.fire(FaultSite::WorkerPanic) {
            panic!("injected worker panic");
        }
        if self.fire(FaultSite::ReplyDelay) {
            let us = self.state.as_ref().map(|s| s.delay_us).unwrap_or(0);
            if us > 0 {
                std::thread::sleep(Duration::from_micros(us));
            }
        }
    }

    fn count(&self, site: FaultSite) {
        if let Some(st) = &self.state {
            st.injected[site as usize].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Faults injected at `site` so far.
    pub fn injected(&self, site: FaultSite) -> u64 {
        self.state
            .as_ref()
            .map(|st| st.injected[site as usize].load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Total faults injected across all sites.
    pub fn injected_total(&self) -> u64 {
        FaultSite::ALL.iter().map(|&s| self.injected(s)).sum()
    }
}

// ---------------------------------------------------------------------------
// Targeted one-shot worker kill (test seam)

static KILL_ARMED: AtomicBool = AtomicBool::new(false);
static KILL_DIRS: OnceLock<Mutex<Vec<PathBuf>>> = OnceLock::new();

/// Arm a one-shot kill: the next worker op executed by a store whose
/// spill directory is `dir` or lives under it panics at op entry (the
/// panic is supervised like any injected `WorkerPanic`). Used by
/// tests to fail exactly one session's shard without probabilistic
/// injection. Process-global; each armed dir fires at most once.
pub fn arm_worker_kill<P: Into<PathBuf>>(dir: P) {
    let mut g = KILL_DIRS
        .get_or_init(|| Mutex::new(Vec::new()))
        .lock()
        .unwrap_or_else(|p| p.into_inner());
    g.push(dir.into());
    KILL_ARMED.store(true, Ordering::SeqCst);
}

fn take_kill(dir: &Path) -> bool {
    let mut g = KILL_DIRS
        .get_or_init(|| Mutex::new(Vec::new()))
        .lock()
        .unwrap_or_else(|p| p.into_inner());
    let Some(i) = g.iter().position(|k| dir.starts_with(k)) else {
        return false;
    };
    g.remove(i);
    if g.is_empty() {
        KILL_ARMED.store(false, Ordering::SeqCst);
    }
    true
}

// ---------------------------------------------------------------------------
// Retry policy

/// Which spill operation a retry wraps. Doubles as the `op` label on
/// `asrkf_io_retries_total`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetryOp {
    Read,
    Write,
    Free,
}

pub const RETRY_OPS: usize = 3;

impl RetryOp {
    pub const ALL: [RetryOp; RETRY_OPS] = [RetryOp::Read, RetryOp::Write, RetryOp::Free];

    pub fn as_str(self) -> &'static str {
        match self {
            RetryOp::Read => "read",
            RetryOp::Write => "write",
            RetryOp::Free => "free",
        }
    }
}

/// How a retried op ended. Doubles as the `outcome` label on
/// `asrkf_io_retries_total` (the counter value is the number of
/// *retries*, i.e. attempts beyond the first).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetryOutcome {
    /// The op eventually succeeded after >= 1 retry.
    Recovered,
    /// Attempts (or the deadline) ran out; the last error surfaced.
    Exhausted,
}

pub const RETRY_OUTCOMES: usize = 2;

impl RetryOutcome {
    pub const ALL: [RetryOutcome; RETRY_OUTCOMES] =
        [RetryOutcome::Recovered, RetryOutcome::Exhausted];

    pub fn as_str(self) -> &'static str {
        match self {
            RetryOutcome::Recovered => "recovered",
            RetryOutcome::Exhausted => "exhausted",
        }
    }
}

struct RetryStats {
    /// retries[op][outcome]
    counts: [[AtomicU64; RETRY_OUTCOMES]; RETRY_OPS],
}

/// Bounded retry with exponential backoff + seeded jitter + per-op
/// deadline. `Clone` shares the counters and jitter stream.
#[derive(Clone)]
pub struct RetryPolicy {
    /// Total attempts (1 = no retries, the pre-PR fail-fast behavior).
    pub attempts: u32,
    /// First backoff; doubles per retry.
    pub backoff_us: u64,
    /// Wall-clock budget for one logical op including retries.
    pub deadline_ms: u64,
    jitter: Option<Arc<Mutex<Pcg64>>>,
    stats: Arc<RetryStats>,
}

impl std::fmt::Debug for RetryPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RetryPolicy")
            .field("attempts", &self.attempts)
            .field("backoff_us", &self.backoff_us)
            .field("deadline_ms", &self.deadline_ms)
            .finish()
    }
}

impl RetryPolicy {
    fn fresh_stats() -> Arc<RetryStats> {
        Arc::new(RetryStats {
            counts: std::array::from_fn(|_| std::array::from_fn(|_| AtomicU64::new(0))),
        })
    }

    /// One attempt, no backoff — identical to the pre-retry error
    /// path. The tier-level constructor default, so direct `SpillTier`
    /// users (and their one-shot fault tests) see no behavior change.
    pub fn none() -> Self {
        RetryPolicy {
            attempts: 1,
            backoff_us: 0,
            deadline_ms: 0,
            jitter: None,
            stats: Self::fresh_stats(),
        }
    }

    /// Build from config. Jitter draws come from a stream derived
    /// from `fault_seed` when set (so chaos runs replay exactly) and
    /// from a fixed constant otherwise — jitter only shapes sleep
    /// durations, never outcomes.
    pub fn from_cfg(cfg: &OffloadConfig) -> Self {
        let seed = cfg.fault_seed.unwrap_or(0x7e7);
        RetryPolicy {
            attempts: cfg.io_retry_attempts.max(1),
            backoff_us: cfg.io_retry_backoff_us,
            deadline_ms: cfg.io_retry_deadline_ms,
            jitter: Some(Arc::new(Mutex::new(Pcg64::with_stream(seed, 0xba0f)))),
            stats: Self::fresh_stats(),
        }
    }

    /// Run `f` with up to `attempts` tries. Backoff before attempt
    /// `k` (1-based retries) is `backoff_us * 2^(k-1)` plus up to 50%
    /// seeded jitter; the loop stops early once `deadline_ms` of wall
    /// clock has elapsed. All errors are treated as retryable — the
    /// spill seams only produce I/O-shaped errors.
    pub fn run<T>(&self, op: RetryOp, mut f: impl FnMut() -> Result<T>) -> Result<T> {
        if self.attempts <= 1 {
            return f();
        }
        let start = Instant::now();
        let mut retries: u64 = 0;
        loop {
            match f() {
                Ok(v) => {
                    if retries > 0 {
                        self.add(op, RetryOutcome::Recovered, retries);
                    }
                    return Ok(v);
                }
                Err(e) => {
                    let out_of_attempts = retries + 1 >= self.attempts as u64;
                    let out_of_time = self.deadline_ms > 0
                        && start.elapsed() >= Duration::from_millis(self.deadline_ms);
                    if out_of_attempts || out_of_time {
                        if retries > 0 {
                            self.add(op, RetryOutcome::Exhausted, retries);
                        }
                        return Err(e);
                    }
                    let base = self.backoff_us.saturating_mul(1u64 << retries.min(16));
                    let jit = match &self.jitter {
                        Some(j) if base > 0 => j
                            .lock()
                            .unwrap_or_else(|p| p.into_inner())
                            .gen_range(0, base / 2 + 1),
                        _ => 0,
                    };
                    if base + jit > 0 {
                        std::thread::sleep(Duration::from_micros(base + jit));
                    }
                    retries += 1;
                }
            }
        }
    }

    fn add(&self, op: RetryOp, outcome: RetryOutcome, n: u64) {
        self.stats.counts[op as usize][outcome as usize].fetch_add(n, Ordering::Relaxed);
    }

    /// Retries recorded for (op, outcome).
    pub fn retries(&self, op: RetryOp, outcome: RetryOutcome) -> u64 {
        self.stats.counts[op as usize][outcome as usize].load(Ordering::Relaxed)
    }

    /// Total retries across every (op, outcome) pair.
    pub fn retries_total(&self) -> u64 {
        RetryOp::ALL
            .iter()
            .flat_map(|&op| RetryOutcome::ALL.iter().map(move |&o| self.retries(op, o)))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn armed_cfg(seed: u64, io: f64) -> OffloadConfig {
        OffloadConfig {
            fault_seed: Some(seed),
            fault_io_rate: io,
            fault_torn_rate: 0.0,
            fault_panic_rate: 0.0,
            fault_delay_rate: 0.0,
            ..OffloadConfig::default()
        }
    }

    #[test]
    fn disabled_injector_never_fires() {
        let inj = FaultInjector::disabled();
        assert!(!inj.enabled());
        for _ in 0..1000 {
            assert!(!inj.fire(FaultSite::SpillRead));
        }
        assert_eq!(inj.injected_total(), 0);
        inj.worker_op(); // must not panic
    }

    #[test]
    fn seeded_injector_is_deterministic() {
        let a = FaultInjector::from_cfg(&armed_cfg(42, 0.3));
        let b = FaultInjector::from_cfg(&armed_cfg(42, 0.3));
        let trace_a: Vec<bool> = (0..200).map(|_| a.fire(FaultSite::SpillRead)).collect();
        let trace_b: Vec<bool> = (0..200).map(|_| b.fire(FaultSite::SpillRead)).collect();
        assert_eq!(trace_a, trace_b);
        assert!(trace_a.iter().any(|&h| h), "rate 0.3 over 200 draws must hit");
        assert_eq!(a.injected(FaultSite::SpillRead), trace_a.iter().filter(|&&h| h).count() as u64);
    }

    #[test]
    fn zero_rate_site_never_fires_even_when_armed() {
        let inj = FaultInjector::from_cfg(&armed_cfg(7, 0.0));
        for _ in 0..500 {
            assert!(!inj.fire(FaultSite::SpillRead));
            assert!(!inj.fire(FaultSite::TornWrite));
        }
        assert_eq!(inj.injected_total(), 0);
    }

    #[test]
    fn retry_recovers_after_transient_failures() {
        let p = RetryPolicy {
            attempts: 4,
            backoff_us: 1,
            deadline_ms: 1000,
            jitter: None,
            stats: RetryPolicy::fresh_stats(),
        };
        let mut left = 2; // fail twice, then succeed
        let out = p.run(RetryOp::Read, || {
            if left > 0 {
                left -= 1;
                Err(Error::Offload("transient".into()))
            } else {
                Ok(99)
            }
        });
        assert_eq!(out.unwrap(), 99);
        assert_eq!(p.retries(RetryOp::Read, RetryOutcome::Recovered), 2);
        assert_eq!(p.retries(RetryOp::Read, RetryOutcome::Exhausted), 0);
    }

    #[test]
    fn retry_exhausts_and_surfaces_last_error() {
        let p = RetryPolicy {
            attempts: 3,
            backoff_us: 1,
            deadline_ms: 1000,
            jitter: None,
            stats: RetryPolicy::fresh_stats(),
        };
        let mut calls = 0;
        let out: Result<()> = p.run(RetryOp::Write, || {
            calls += 1;
            Err(Error::Offload(format!("boom {calls}")))
        });
        assert!(matches!(out, Err(Error::Offload(ref m)) if m == "boom 3"));
        assert_eq!(calls, 3);
        assert_eq!(p.retries(RetryOp::Write, RetryOutcome::Exhausted), 2);
    }

    #[test]
    fn retry_none_is_single_attempt() {
        let p = RetryPolicy::none();
        let mut calls = 0;
        let out: Result<()> = p.run(RetryOp::Free, || {
            calls += 1;
            Err(Error::Offload("once".into()))
        });
        assert!(out.is_err());
        assert_eq!(calls, 1);
        assert_eq!(p.retries_total(), 0);
    }

    #[test]
    fn kill_switch_targets_only_its_dir() {
        let inj_hit = FaultInjector {
            state: None,
            dir: Some(Arc::new(PathBuf::from("/tmp/asrkf-kill-test/slot-0"))),
        };
        let inj_miss = FaultInjector {
            state: None,
            dir: Some(Arc::new(PathBuf::from("/tmp/asrkf-kill-test/slot-1"))),
        };
        arm_worker_kill("/tmp/asrkf-kill-test/slot-0");
        inj_miss.worker_op(); // different dir: no panic
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| inj_hit.worker_op()));
        assert!(hit.is_err(), "armed dir must panic");
        inj_hit.worker_op(); // one-shot: disarmed after firing
    }
}
