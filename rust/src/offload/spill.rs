//! File-backed spill tier: fixed-slot storage for codec-encoded rows
//! that overflow the cold tier's byte budget on very long contexts.
//!
//! Two lifetimes, one record format:
//!
//! * **Ephemeral** ([`SpillFile::create`]) — the historical behavior:
//!   one per-process file (PID + counter in the name), created lazily
//!   on first demotion and deleted on drop.
//! * **Persistent** ([`SpillFile::open_or_create`], `--spill-persist`)
//!   — deterministic per-shard file names plus a per-directory
//!   [`SpillManifest`], so a restarted process re-attaches to its spill
//!   directory and recovers every surviving record instead of
//!   `create_new`-failing or orphaning the old files. Released slots
//!   are tombstoned on disk so a crash never resurrects a row that was
//!   already restored or dropped.
//!
//! # Record format (v2, "KVR2")
//!
//! Slots are fixed-size — [`REC_HEADER_BYTES`] plus the worst-case
//! encoded payload across the spillable codec rungs
//! ([`codec::max_spill_payload_bytes`]) — at `slot * record_bytes`
//! offsets, with a free list so released slots are reused and a
//! contiguous free tail truncates the file (disk usage is not a
//! permanent high-water mark). The header is:
//!
//! | offset | bytes | field |
//! |-------:|------:|-------|
//! | 0      | 4     | magic (`"KVR2"` live, `"KVFR"` tombstone) |
//! | 4      | 8     | writer generation (u64 LE) |
//! | 12     | 8     | sequence position (u64 LE) |
//! | 20     | 8     | FNV-1a 64 checksum (u64 LE) |
//! | 28     | 1     | codec byte ([`CodecId::as_byte`]) |
//! | 29     | 4     | payload length (u32 LE) |
//! | 33     | 3     | zero padding |
//!
//! The payload ([`codec::payload_to_bytes`]) follows at offset 36; the
//! slot's slack is zero-filled. The checksum covers the whole record
//! with only the checksum field itself excluded (`rec[..20]` +
//! `rec[28..]`), so a bit flip anywhere — the position field, the
//! codec byte, the length, the payload — fails verification instead of
//! silently serving another position's (or another precision's) data.
//! I/O errors leave the in-memory bookkeeping untouched (the failed
//! record stays reachable for a retry) and surface through
//! `TieredStore`'s fallible API — the engine fails the affected
//! session rather than corrupting it.
//!
//! # v1 compatibility
//!
//! Pre-ladder directories hold `"KVR1"` records: a 28-byte header (no
//! codec byte, no length) followed by one u8-quantized payload of
//! exactly `ROW_HEADER_BYTES + row_floats` bytes. Opening such a shard
//! file migrates it in place — every checksum-valid v1 record is
//! rewritten as a v2 record with the u8 codec byte and its original
//! generation stamp (so generation fencing still applies), tombstones
//! stay tombstones, and corrupt v1 records are reclaimed and counted
//! in `recovery_errors` exactly like corrupt v2 records. A v1 manifest
//! (version < 2.0) is accepted if its identity matches and upgraded on
//! attach.
//!
//! On-disk format and recovery semantics are documented in this
//! module's `README.md` (section "Persistent spill").

use std::collections::{BTreeSet, HashMap};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::config::ShardPartition;
use crate::error::{Error, Result};
use crate::metrics::{Histogram, TierKind, TierOccupancy};
use crate::offload::codec::{self, CodecId};
use crate::offload::fault::{FaultInjector, FaultSite, RetryOp, RetryPolicy};
use crate::offload::quant::{QuantRow, ROW_HEADER_BYTES};
use crate::offload::tier::{RowPayload, Tier};
use crate::util::json::{parse, write_json, Json};

static NEXT_FILE_ID: AtomicU64 = AtomicU64::new(0);

/// v2 record header: magic (u32) + writer generation (u64) + sequence
/// position (u64) + FNV-1a checksum (u64) + codec byte + payload
/// length (u32) + 3 bytes zero padding.
pub const REC_HEADER_BYTES: usize = 36;

/// v1 (pre-codec-ladder) record header: magic + generation + position
/// + checksum, directly followed by one u8-quantized payload.
pub const REC_V1_HEADER_BYTES: usize = 28;

/// Marker of a live v2 record ("KVR2").
const REC_MAGIC_LIVE: u32 = 0x3252_564B;
/// Marker of a live v1 record ("KVR1"); accepted by migration only.
const REC_MAGIC_LIVE_V1: u32 = 0x3152_564B;
/// Tombstone marker of a released slot ("KVFR"; shared by v1 and v2).
const REC_MAGIC_FREE: u32 = 0x5246_564B;

/// Manifest file name inside a persistent spill directory.
pub const MANIFEST_FILE: &str = "spill-manifest.json";
const MANIFEST_MAGIC: &str = "asrkf-spill";
const MANIFEST_VERSION: f64 = 2.0;

/// Total on-disk bytes of one v2 record for `row_floats`-wide rows:
/// the fixed slot fits the worst-case payload of every spillable
/// codec rung, so a slot can be reused across rungs without resizing.
pub fn record_bytes_for(row_floats: usize) -> usize {
    REC_HEADER_BYTES + codec::max_spill_payload_bytes(row_floats)
}

/// Total on-disk bytes of one legacy v1 record (u8 payload only).
pub fn record_bytes_v1_for(row_floats: usize) -> usize {
    REC_V1_HEADER_BYTES + ROW_HEADER_BYTES + row_floats
}

/// Deterministic record file path for `shard` in persistent mode.
/// (`.rec`, distinct from the ephemeral per-PID `.bin` pattern so
/// manifest attachment can reclaim dead processes' ephemeral files
/// without touching persistent state.)
pub fn record_path(dir: &str, shard: usize) -> PathBuf {
    Path::new(dir).join(format!("asrkf-spill-shard-{shard}.rec"))
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

fn fnv1a64_update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// FNV-1a 64 over the whole record with the checksum field (bytes
/// 20..28) excluded: the header identity (magic, generation, position)
/// is covered along with the codec byte, payload length, payload, and
/// slack, so a bit flip in any of them fails the checksum instead of
/// silently serving wrong data. The same boundary holds for v1 records
/// (their header simply ends where the payload begins).
fn record_checksum(rec: &[u8]) -> u64 {
    fnv1a64_update(fnv1a64_update(FNV_OFFSET, &rec[..20]), &rec[28..])
}

/// The per-directory manifest of a persistent spill store: identity
/// (row width, record size, shard count, partition) plus the current
/// writer generation. Attaching validates the identity, bumps the
/// generation, and rewrites the manifest atomically (temp file +
/// rename) — records written by earlier generations are recoverable,
/// records claiming the new generation or beyond are fenced off as
/// stale (a concurrent writer) and reclaimed, never re-served.
#[derive(Debug)]
pub struct SpillManifest {
    /// Generation claimed by this attach (previous + 1, or 1 for a
    /// fresh directory).
    pub generation: u64,
    /// Ephemeral per-PID spill files from dead processes that were
    /// deleted during the attach.
    pub stale_files_reclaimed: u64,
}

impl SpillManifest {
    /// Attach to (or initialize) `dir` for a store of this shape.
    /// Identity mismatches (different row width, shard count, or
    /// partition than the directory was written with) are hard errors:
    /// the records would be unreadable or mis-routed. A version-1
    /// manifest is validated against the v1 record size and upgraded
    /// to version 2 (the shard files migrate at open).
    ///
    /// Concurrency contract: **one live writer per directory at a
    /// time**. The generation fence protects against a *dead*
    /// predecessor's leftovers (and detects its stragglers'
    /// higher-generation records at the next scan); it is not a lock —
    /// two processes attaching the same directory concurrently would
    /// both claim the same bumped generation and corrupt each other's
    /// record files. The coordinator upholds the contract by giving
    /// every batch slot its own subdirectory.
    pub fn attach(
        dir: &str,
        row_floats: usize,
        shards: usize,
        partition: ShardPartition,
    ) -> Result<SpillManifest> {
        std::fs::create_dir_all(dir)?;
        let path = Path::new(dir).join(MANIFEST_FILE);
        let mut generation = 1u64;
        if path.exists() {
            let text = std::fs::read_to_string(&path)?;
            let v = parse(&text)
                .map_err(|e| Error::Offload(format!("spill manifest {}: {e}", path.display())))?;
            if v.get("magic").as_str() != Some(MANIFEST_MAGIC) {
                return Err(Error::Offload(format!(
                    "{} is not an asrkf spill manifest",
                    path.display()
                )));
            }
            let check = |key: &str, want: usize| -> Result<()> {
                match v.get(key).as_usize() {
                    Some(got) if got == want => Ok(()),
                    got => Err(Error::Offload(format!(
                        "spill dir {dir}: manifest {key} {got:?} does not match this store's {want}"
                    ))),
                }
            };
            check("row_floats", row_floats)?;
            let version = v.get("version").as_f64().unwrap_or(MANIFEST_VERSION);
            let want_rb = if version < 2.0 {
                record_bytes_v1_for(row_floats)
            } else {
                record_bytes_for(row_floats)
            };
            check("record_bytes", want_rb)?;
            check("shards", shards)?;
            match v.get("partition").as_str() {
                Some(p) if p == partition.as_str() => {}
                p => {
                    return Err(Error::Offload(format!(
                        "spill dir {dir}: manifest partition {p:?} does not match this store's \
                         '{}'",
                        partition.as_str()
                    )))
                }
            }
            if version < 2.0 {
                log::info!(
                    "spill dir {dir}: upgrading v{version} manifest to v{MANIFEST_VERSION} \
                     (record files migrate at open)"
                );
            }
            generation = v.get("generation").as_f64().unwrap_or(0.0) as u64 + 1;
        }
        // claim the directory before any record I/O: once the bumped
        // generation is durable, records written by a straggler of the
        // previous generation are fenced off at the next scan
        let m = Json::obj(vec![
            ("magic", Json::str(MANIFEST_MAGIC)),
            ("version", Json::num(MANIFEST_VERSION)),
            ("row_floats", Json::num(row_floats as f64)),
            ("record_bytes", Json::num(record_bytes_for(row_floats) as f64)),
            ("shards", Json::num(shards as f64)),
            ("partition", Json::str(partition.as_str())),
            ("generation", Json::num(generation as f64)),
        ]);
        let mut text = String::new();
        write_json(&m, &mut text);
        let tmp = path.with_extension("json.tmp");
        {
            // sync before the rename: without it a power loss can
            // surface the rename with an empty temp file behind it,
            // leaving the directory unattachable
            let mut f = File::create(&tmp)?;
            f.write_all(text.as_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, &path)?;
        // make the rename itself durable (best effort: directory
        // handles are not syncable on every platform)
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
        // reclaim ephemeral spill files orphaned by dead processes
        // (never re-served: they carry no recoverable identity)
        let mut stale = 0u64;
        for entry in std::fs::read_dir(dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if name.starts_with("asrkf-spill-") && name.ends_with(".bin") {
                let _ = std::fs::remove_file(entry.path());
                stale += 1;
            }
        }
        if stale > 0 {
            log::warn!("spill dir {dir}: reclaimed {stale} ephemeral file(s) from dead processes");
        }
        Ok(SpillManifest { generation, stale_files_reclaimed: stale })
    }
}

pub struct SpillFile {
    file: File,
    path: PathBuf,
    record_bytes: usize,
    row_floats: usize,
    /// released slots awaiting reuse; ordered so handle checks,
    /// lowest-slot-first reuse, and the free-tail truncation probe are
    /// O(log n), not a linear scan on the restore path
    free: BTreeSet<u32>,
    next_slot: u32,
    /// generation stamped into written records (0 in ephemeral mode)
    generation: u64,
    /// persistent files survive drop, tombstone released slots on
    /// disk, and were scanned for recoverable records at open
    persist: bool,
    /// live records found by the open-time scan, awaiting
    /// `take_recovered` (resume) or `reclaim_recovered` (fresh attach)
    recovered: Vec<(usize, u32, CodecId)>,
    /// records the scan rejected (bad magic/checksum, fenced
    /// generation, duplicate position, torn tail)
    pub recovery_errors: u64,
    /// fault injection for the error-path bookkeeping tests (private;
    /// only in-module tests set these)
    fault_next_read: bool,
    fault_next_free: bool,
    /// seeded probabilistic fault injection (`offload::fault`) at the
    /// read / write / free / torn-write seams; inert by default
    fault: FaultInjector,
}

impl std::fmt::Debug for SpillFile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpillFile")
            .field("path", &self.path)
            .field("slots", &self.next_slot)
            .field("free", &self.free.len())
            .field("generation", &self.generation)
            .field("persist", &self.persist)
            .finish()
    }
}

impl SpillFile {
    fn empty(file: File, path: PathBuf, row_floats: usize) -> SpillFile {
        SpillFile {
            file,
            path,
            record_bytes: record_bytes_for(row_floats),
            row_floats,
            free: BTreeSet::new(),
            next_slot: 0,
            generation: 0,
            persist: false,
            recovered: Vec::new(),
            recovery_errors: 0,
            fault_next_read: false,
            fault_next_free: false,
            fault: FaultInjector::disabled(),
        }
    }

    /// Create an ephemeral spill file under `dir` (created if
    /// missing): per-process name, deleted on drop.
    pub fn create(dir: &str, row_floats: usize) -> Result<SpillFile> {
        std::fs::create_dir_all(dir)?;
        let id = NEXT_FILE_ID.fetch_add(1, Ordering::Relaxed);
        let path = PathBuf::from(dir)
            .join(format!("asrkf-spill-{}-{id}.bin", std::process::id()));
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)?;
        Ok(SpillFile::empty(file, path, row_floats))
    }

    /// Open (or initialize) the persistent record file for `shard`,
    /// migrating a pre-ladder v1 file in place if needed, then
    /// scanning to rebuild the slot allocation, the free list, and the
    /// recoverable `(pos, slot, codec)` set. `generation` is the
    /// manifest's freshly-claimed generation: records from generations
    /// `1..generation` are recoverable; anything claiming `generation`
    /// or beyond was written by a fenced-off concurrent writer and is
    /// reclaimed, not re-served.
    pub fn open_or_create(
        dir: &str,
        row_floats: usize,
        shard: usize,
        generation: u64,
    ) -> Result<SpillFile> {
        std::fs::create_dir_all(dir)?;
        let path = record_path(dir, shard);
        let file = OpenOptions::new().read(true).write(true).create(true).open(&path)?;
        let mut s = SpillFile::empty(file, path, row_floats);
        s.generation = generation;
        s.persist = true;
        s.migrate_v1()?;
        s.scan()?;
        s.compact_tail()?;
        Ok(s)
    }

    /// Rewrite a pre-ladder v1 record file in the v2 layout. A file is
    /// migrated only when it is *fully* v1-consistent: its length is a
    /// multiple of the v1 record size and every slot opens with a
    /// v1-era magic — a v2 file fails that probe at slot 0 (different
    /// live magic, different stride) and is left untouched for the
    /// regular scan. Checksum-valid live records are re-emitted with
    /// the u8 codec byte and their original generation stamp (fencing
    /// still applies at scan); corrupt ones are tombstoned and counted
    /// as recovery errors.
    fn migrate_v1(&mut self) -> Result<()> {
        let len = self.file.metadata()?.len() as usize;
        let v1_rb = record_bytes_v1_for(self.row_floats);
        if len == 0 || len % v1_rb != 0 {
            return Ok(());
        }
        let nrec = len / v1_rb;
        let mut old = vec![0u8; len];
        self.file.seek(SeekFrom::Start(0))?;
        self.file.read_exact(&mut old)?;
        let magic_at = |i: usize| {
            u32::from_le_bytes(old[i * v1_rb..i * v1_rb + 4].try_into().unwrap())
        };
        if !(0..nrec).all(|i| matches!(magic_at(i), REC_MAGIC_LIVE_V1 | REC_MAGIC_FREE)) {
            return Ok(());
        }
        let mut new = Vec::with_capacity(nrec * self.record_bytes);
        let mut migrated = 0u64;
        for i in 0..nrec {
            let rec = &old[i * v1_rb..(i + 1) * v1_rb];
            let mut out = vec![0u8; self.record_bytes];
            if magic_at(i) == REC_MAGIC_FREE {
                out[0..4].copy_from_slice(&REC_MAGIC_FREE.to_le_bytes());
                new.extend_from_slice(&out);
                continue;
            }
            let sum = u64::from_le_bytes(rec[20..28].try_into().unwrap());
            if sum != record_checksum(rec) {
                // corrupt in its previous life: reclaim, don't carry
                // bad bytes into the new format under a fresh checksum
                self.recovery_errors += 1;
                out[0..4].copy_from_slice(&REC_MAGIC_FREE.to_le_bytes());
                new.extend_from_slice(&out);
                continue;
            }
            let body = &rec[REC_V1_HEADER_BYTES..];
            out[0..4].copy_from_slice(&REC_MAGIC_LIVE.to_le_bytes());
            out[4..20].copy_from_slice(&rec[4..20]); // generation + position
            out[28] = CodecId::U8.as_byte();
            out[29..33].copy_from_slice(&(body.len() as u32).to_le_bytes());
            out[REC_HEADER_BYTES..REC_HEADER_BYTES + body.len()].copy_from_slice(body);
            let sum = record_checksum(&out);
            out[20..28].copy_from_slice(&sum.to_le_bytes());
            new.extend_from_slice(&out);
            migrated += 1;
        }
        self.file.set_len(0)?;
        self.file.seek(SeekFrom::Start(0))?;
        self.file.write_all(&new)?;
        self.file.sync_all()?;
        log::info!(
            "spill file {}: migrated {migrated} v1 record(s) across {nrec} slot(s) to the v2 \
             codec-tagged format",
            self.path.display()
        );
        Ok(())
    }

    /// Rebuild in-memory state from the on-disk records (persistent
    /// open). Each slot is classified exactly once: tombstone -> free,
    /// valid live record -> recoverable, anything else (bad magic,
    /// fenced generation, checksum mismatch, bad codec byte or payload
    /// length, duplicate position) -> reclaimed (tombstoned + freed)
    /// and counted as a recovery error.
    fn scan(&mut self) -> Result<()> {
        let len = self.file.metadata()?.len();
        let rb = self.record_bytes as u64;
        let nrec = (len / rb) as u32;
        if len % rb != 0 {
            // torn tail write from a crash mid-record: drop it
            self.recovery_errors += 1;
            self.file.set_len(nrec as u64 * rb)?;
        }
        self.next_slot = nrec;
        let mut by_pos: HashMap<usize, (u32, u64, CodecId)> = HashMap::new();
        let mut reclaim: Vec<u32> = Vec::new();
        let mut rec = vec![0u8; self.record_bytes];
        let max_payload = self.record_bytes - REC_HEADER_BYTES;
        self.file.seek(SeekFrom::Start(0))?;
        for slot in 0..nrec {
            self.file.read_exact(&mut rec)?;
            let magic = u32::from_le_bytes(rec[0..4].try_into().unwrap());
            if magic == REC_MAGIC_FREE {
                self.free.insert(slot);
                continue;
            }
            let gen = u64::from_le_bytes(rec[4..12].try_into().unwrap());
            let pos = u64::from_le_bytes(rec[12..20].try_into().unwrap()) as usize;
            let sum = u64::from_le_bytes(rec[20..28].try_into().unwrap());
            let codec = CodecId::from_byte(rec[28]).filter(|&c| c != CodecId::Raw);
            let plen = u32::from_le_bytes(rec[29..33].try_into().unwrap()) as usize;
            let valid = magic == REC_MAGIC_LIVE
                && gen >= 1
                && gen < self.generation
                && codec.is_some()
                && plen <= max_payload
                && sum == record_checksum(&rec);
            let Some(codec) = codec.filter(|_| valid) else {
                self.recovery_errors += 1;
                reclaim.push(slot);
                continue;
            };
            match by_pos.entry(pos) {
                std::collections::hash_map::Entry::Vacant(v) => {
                    v.insert((slot, gen, codec));
                }
                std::collections::hash_map::Entry::Occupied(mut o) => {
                    // two generations claim the same position (a
                    // tombstone write lost in the crash): serve the
                    // newer copy, reclaim the other
                    self.recovery_errors += 1;
                    let (old_slot, old_gen, _) = *o.get();
                    if gen > old_gen {
                        o.insert((slot, gen, codec));
                        reclaim.push(old_slot);
                    } else {
                        reclaim.push(slot);
                    }
                }
            }
        }
        for slot in reclaim {
            self.tombstone(slot)?;
            self.free.insert(slot);
        }
        self.recovered =
            by_pos.into_iter().map(|(pos, (slot, _, codec))| (pos, slot, codec)).collect();
        self.recovered.sort_unstable();
        Ok(())
    }

    /// Occupied bytes (allocated records minus the free list).
    pub fn bytes(&self) -> usize {
        (self.next_slot as usize - self.free.len()) * self.record_bytes
    }

    pub fn record_bytes(&self) -> usize {
        self.record_bytes
    }

    /// Drain the open-time scan's recovered `(pos, slot, codec)`
    /// triples (resume path; sorted by position).
    pub fn take_recovered(&mut self) -> Vec<(usize, u32, CodecId)> {
        std::mem::take(&mut self.recovered)
    }

    /// Fresh-attach path: discard every record the scan recovered —
    /// leftovers of a previous life this store does not resume.
    /// Returns how many records were reclaimed.
    pub fn reclaim_recovered(&mut self) -> Result<u64> {
        let recovered = std::mem::take(&mut self.recovered);
        let n = recovered.len() as u64;
        if n == 0 {
            return Ok(0);
        }
        // the scan classified every slot as either free or recovered,
        // so discarding all recovered records empties the file: one
        // truncate instead of a per-slot tombstone write (a long dead
        // session can leave tens of thousands of records, and this
        // runs on the coordinator's admission path)
        if recovered.len() + self.free.len() == self.next_slot as usize {
            self.free.clear();
            self.next_slot = 0;
            self.file.set_len(0)?;
            return Ok(n);
        }
        // defensive fallback only: with today's single call site
        // (directly after open_or_create, before any write) the scan
        // invariant above always holds and this loop is unreachable
        debug_assert!(false, "reclaim_recovered called on a file with post-scan writes");
        for (_pos, slot, _codec) in recovered {
            self.release_slot(slot)?;
        }
        Ok(n)
    }

    /// Write a u8-quantized row for `pos` (legacy/direct path; the
    /// tier spills arbitrary encoded payloads via `write_payload`).
    pub fn write_row(&mut self, pos: usize, qr: &QuantRow) -> Result<u32> {
        self.write_payload(pos, &RowPayload::Quant(qr.clone()))
    }

    /// Write an encoded payload for `pos`; returns the slot to read it
    /// back from. Raw (f32) payloads are rejected — they exceed the
    /// fixed slot, and the ladder never demotes raw rows to disk. On a
    /// write error the allocated slot returns to the free list (no
    /// slot is leaked by a failed write).
    pub fn write_payload(&mut self, pos: usize, payload: &RowPayload) -> Result<u32> {
        if payload.row_floats() != self.row_floats {
            return Err(Error::Offload(format!(
                "spill row has {} floats, store expects {}",
                payload.row_floats(),
                self.row_floats
            )));
        }
        let codec = payload.codec();
        let body = codec::payload_to_bytes(payload);
        if codec == CodecId::Raw || body.len() > self.record_bytes - REC_HEADER_BYTES {
            return Err(Error::Offload(format!(
                "spill of pos {pos}: {} payload of {} bytes does not fit the {}-byte slot body",
                codec.as_str(),
                body.len(),
                self.record_bytes - REC_HEADER_BYTES
            )));
        }
        let slot = self.free.pop_first().unwrap_or_else(|| {
            let s = self.next_slot;
            self.next_slot += 1;
            s
        });
        match self.write_record(slot, pos, codec, &body) {
            Ok(()) => Ok(slot),
            Err(e) => {
                // the slot holds no live record: stamp a tombstone over
                // whatever torn bytes landed (best effort — otherwise a
                // clean later scan counts this slot as a corruption
                // event), then hand it back to the free list
                if self.persist {
                    let _ = self.tombstone(slot);
                }
                self.free.insert(slot);
                let _ = self.compact_tail();
                Err(e)
            }
        }
    }

    fn write_record(&mut self, slot: u32, pos: usize, codec: CodecId, body: &[u8]) -> Result<()> {
        self.fault.io_error(FaultSite::SpillWrite)?;
        let mut rec = vec![0u8; self.record_bytes];
        rec[0..4].copy_from_slice(&REC_MAGIC_LIVE.to_le_bytes());
        rec[4..12].copy_from_slice(&self.generation.to_le_bytes());
        rec[12..20].copy_from_slice(&(pos as u64).to_le_bytes());
        // 20..28: checksum, patched below
        rec[28] = codec.as_byte();
        rec[29..33].copy_from_slice(&(body.len() as u32).to_le_bytes());
        rec[REC_HEADER_BYTES..REC_HEADER_BYTES + body.len()].copy_from_slice(body);
        let sum = record_checksum(&rec);
        rec[20..28].copy_from_slice(&sum.to_le_bytes());
        self.file
            .seek(SeekFrom::Start(slot as u64 * self.record_bytes as u64))?;
        if self.fault.fire(FaultSite::TornWrite) {
            // torn write: half the record lands on disk, then the op
            // errors. The caller's error path tombstones the slot; if
            // even that is lost (a crash), the open-time scan rejects
            // the torn bytes by checksum — never serves them.
            self.file.write_all(&rec[..self.record_bytes / 2])?;
            return Err(Error::Offload(format!(
                "injected fault: torn write of pos {pos} (slot {slot})"
            )));
        }
        self.file.write_all(&rec)?;
        Ok(())
    }

    /// Reject handles that were never allocated or already released —
    /// a stale handle means the caller's bookkeeping diverged from the
    /// file's, and silently honouring it would corrupt the free list.
    fn check_live(&self, slot: u32) -> Result<()> {
        if slot >= self.next_slot {
            return Err(Error::Offload(format!(
                "stale spill handle {slot} (only {} slots allocated)",
                self.next_slot
            )));
        }
        if self.free.contains(&slot) {
            return Err(Error::Offload(format!("stale spill handle {slot} (already freed)")));
        }
        Ok(())
    }

    /// Validate a record header against the caller's expectation. A
    /// mismatch means the slot map diverged from the file (or the
    /// record was corrupted on disk) — served as `Error::Offload`
    /// rather than bad data.
    fn verify_header(&self, rec: &[u8], slot: u32, pos: usize) -> Result<()> {
        let magic = u32::from_le_bytes(rec[0..4].try_into().unwrap());
        if magic != REC_MAGIC_LIVE {
            return Err(Error::Offload(format!(
                "spill slot {slot} (pos {pos}) does not hold a live record (magic {magic:#x})"
            )));
        }
        let gen = u64::from_le_bytes(rec[4..12].try_into().unwrap());
        let gen_ok = if self.persist {
            gen >= 1 && gen <= self.generation
        } else {
            gen == self.generation
        };
        if !gen_ok {
            return Err(Error::Offload(format!(
                "spill slot {slot} (pos {pos}) carries fenced generation {gen} (current {})",
                self.generation
            )));
        }
        let rpos = u64::from_le_bytes(rec[12..20].try_into().unwrap());
        if rpos != pos as u64 {
            return Err(Error::Offload(format!(
                "spill slot {slot} holds pos {rpos}, expected {pos}"
            )));
        }
        Ok(())
    }

    /// Read a payload back and release its slot. The slot is released
    /// only after a verified read (and, in persistent mode, a durable
    /// tombstone), so an I/O error keeps the record reachable.
    pub fn take_payload(&mut self, slot: u32, pos: usize) -> Result<RowPayload> {
        let payload = self.read_payload(slot, pos)?;
        self.release_slot(slot)?;
        Ok(payload)
    }

    /// `take_payload` narrowed to the u8 rung (legacy/direct path).
    pub fn take_row(&mut self, slot: u32, pos: usize) -> Result<QuantRow> {
        let qr = self.read_row(slot, pos)?;
        self.release_slot(slot)?;
        Ok(qr)
    }

    /// Read a payload without releasing the slot (staging keeps the
    /// record until the hot copy is consumed or re-demoded). Verifies
    /// the header, the codec tag, and the checksum: a poisoned record
    /// surfaces `Error::Offload`, never bad floats.
    pub fn read_payload(&mut self, slot: u32, pos: usize) -> Result<RowPayload> {
        self.check_live(slot)?;
        if self.fault_next_read {
            self.fault_next_read = false;
            return Err(Error::Offload(format!("injected read fault for spill slot {slot}")));
        }
        self.fault.io_error(FaultSite::SpillRead)?;
        self.file
            .seek(SeekFrom::Start(slot as u64 * self.record_bytes as u64))?;
        let mut rec = vec![0u8; self.record_bytes];
        self.file.read_exact(&mut rec)?;
        self.verify_header(&rec, slot, pos)?;
        let sum = u64::from_le_bytes(rec[20..28].try_into().unwrap());
        if sum != record_checksum(&rec) {
            return Err(Error::Offload(format!(
                "spill record for pos {pos} (slot {slot}) failed its checksum"
            )));
        }
        let codec = CodecId::from_byte(rec[28])
            .filter(|&c| c != CodecId::Raw)
            .ok_or_else(|| {
                Error::Offload(format!(
                    "spill record for pos {pos} (slot {slot}) carries invalid codec byte {}",
                    rec[28]
                ))
            })?;
        let plen = u32::from_le_bytes(rec[29..33].try_into().unwrap()) as usize;
        if plen > self.record_bytes - REC_HEADER_BYTES {
            return Err(Error::Offload(format!(
                "spill record for pos {pos} (slot {slot}) claims {plen} payload bytes, slot \
                 body is {}",
                self.record_bytes - REC_HEADER_BYTES
            )));
        }
        codec::payload_from_bytes(codec, self.row_floats, &rec[REC_HEADER_BYTES..REC_HEADER_BYTES + plen])
    }

    /// `read_payload` narrowed to the u8 rung (legacy/direct path):
    /// a record encoded by another rung is a bookkeeping error here.
    pub fn read_row(&mut self, slot: u32, pos: usize) -> Result<QuantRow> {
        match self.read_payload(slot, pos)? {
            RowPayload::Quant(qr) => Ok(qr),
            other => Err(Error::Offload(format!(
                "spill slot {slot} (pos {pos}) holds a {} record, expected u8",
                other.codec().as_str()
            ))),
        }
    }

    /// Release a slot without reading its payload (row dropped by a
    /// baseline). Stale handles error instead of silently corrupting
    /// the free list; in persistent mode the record header is verified
    /// first and the slot is tombstoned on disk so a crash cannot
    /// resurrect the dropped row.
    pub fn free_slot(&mut self, slot: u32, pos: usize) -> Result<()> {
        self.check_live(slot)?;
        if self.fault_next_free {
            self.fault_next_free = false;
            return Err(Error::Offload(format!("injected free fault for spill slot {slot}")));
        }
        self.fault.io_error(FaultSite::SpillFree)?;
        if self.persist {
            self.file
                .seek(SeekFrom::Start(slot as u64 * self.record_bytes as u64))?;
            let mut hdr = [0u8; REC_HEADER_BYTES];
            self.file.read_exact(&mut hdr)?;
            self.verify_header(&hdr, slot, pos)?;
        }
        self.release_slot(slot)
    }

    /// Free a slot the caller has finished with: durable tombstone in
    /// persistent mode, then the free list, then tail truncation.
    fn release_slot(&mut self, slot: u32) -> Result<()> {
        if self.persist {
            self.tombstone(slot)?;
        }
        self.free.insert(slot);
        self.compact_tail()
    }

    fn tombstone(&mut self, slot: u32) -> Result<()> {
        self.file
            .seek(SeekFrom::Start(slot as u64 * self.record_bytes as u64))?;
        self.file.write_all(&REC_MAGIC_FREE.to_le_bytes())?;
        Ok(())
    }

    /// Truncate the file when a contiguous tail of slots is free — the
    /// `BTreeSet` free list makes the tail probe O(log n) per released
    /// slot, so disk usage tracks the live record span instead of the
    /// all-time high-water mark. Also run once at recovery time.
    fn compact_tail(&mut self) -> Result<()> {
        let mut shrunk = false;
        while self.next_slot > 0 && self.free.last() == Some(&(self.next_slot - 1)) {
            self.free.pop_last();
            self.next_slot -= 1;
            shrunk = true;
        }
        if shrunk {
            self.file
                .set_len(self.next_slot as u64 * self.record_bytes as u64)?;
        }
        Ok(())
    }
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        // persistent files ARE the crash-recovery state: only the
        // ephemeral per-process file is deleted with its owner
        if !self.persist {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// The file-backed tier: cold rows that overflowed their byte budget
/// on very long contexts. Payloads keep whatever codec rung encoded
/// them — a u4 demotion stays u4 on disk and comes back u4. The
/// ephemeral backing file is created lazily on first stash so
/// configurations that never spill touch no disk; the persistent
/// variant ([`SpillTier::open_persistent`]) opens and scans its record
/// file eagerly so recovery happens before any traffic.
#[derive(Debug)]
pub struct SpillTier {
    dir: Option<String>,
    row_floats: usize,
    file: Option<SpillFile>,
    slots: HashMap<usize, (u32, CodecId)>,
    /// resident rows per codec rung, indexed by `CodecId::index`
    codec_rows: [usize; CodecId::COUNT],
    /// record read+verify latency (restore and staging paths)
    pub read_us: Histogram,
    /// record write latency (demotion path)
    pub write_us: Histogram,
    /// seeded fault injection, propagated into the backing file;
    /// inert unless armed (`SpillTier::arm`)
    fault: FaultInjector,
    /// retry wrapper around the file ops. `RetryPolicy::none()` by
    /// default, so direct tier users keep the fail-fast behavior;
    /// `TieredStore::with_spill` arms the configured policy.
    retry: RetryPolicy,
}

impl SpillTier {
    /// `dir: None` builds a disabled tier: stash errors, everything
    /// else reports empty.
    pub fn new(dir: Option<String>, row_floats: usize) -> SpillTier {
        SpillTier {
            dir,
            row_floats,
            file: None,
            slots: HashMap::new(),
            codec_rows: [0; CodecId::COUNT],
            read_us: Histogram::default(),
            write_us: Histogram::default(),
            fault: FaultInjector::disabled(),
            retry: RetryPolicy::none(),
        }
    }

    /// Arm fault injection and the retry policy (store construction).
    /// Propagates the injector into an already-open backing file;
    /// lazily-created files inherit it at creation.
    pub fn arm(&mut self, fault: FaultInjector, retry: RetryPolicy) {
        if let Some(f) = self.file.as_mut() {
            f.fault = fault.clone();
        }
        self.fault = fault;
        self.retry = retry;
    }

    /// The armed retry policy (counter access for `publish_flows`).
    pub fn retry(&self) -> &RetryPolicy {
        &self.retry
    }

    /// Persistent tier for `shard`: opens the deterministic record
    /// file under `dir` and scans it for recoverable records. The
    /// caller decides their fate: [`SpillTier::adopt_recovered`]
    /// (resume) or [`SpillTier::reclaim_recovered`] (fresh attach).
    pub fn open_persistent(
        dir: &str,
        row_floats: usize,
        shard: usize,
        generation: u64,
    ) -> Result<SpillTier> {
        let file = SpillFile::open_or_create(dir, row_floats, shard, generation)?;
        Ok(SpillTier {
            dir: Some(dir.to_string()),
            row_floats,
            file: Some(file),
            slots: HashMap::new(),
            codec_rows: [0; CodecId::COUNT],
            read_us: Histogram::default(),
            write_us: Histogram::default(),
            fault: FaultInjector::disabled(),
            retry: RetryPolicy::none(),
        })
    }

    pub fn enabled(&self) -> bool {
        self.dir.is_some()
    }

    /// Resident rows per codec rung, indexed by `CodecId::index`.
    pub fn codec_rows(&self) -> [usize; CodecId::COUNT] {
        self.codec_rows
    }

    /// Records the open-time scan rejected (checksum/magic/generation
    /// failures, duplicates, torn tails). 0 for ephemeral tiers.
    pub fn recovery_errors(&self) -> u64 {
        self.file.as_ref().map(|f| f.recovery_errors).unwrap_or(0)
    }

    /// Adopt the open-time scan's recovered records into the live slot
    /// map and return their positions (resume path; ascending order).
    pub fn adopt_recovered(&mut self) -> Vec<usize> {
        let Some(file) = self.file.as_mut() else { return Vec::new() };
        let recovered = file.take_recovered();
        let mut out = Vec::with_capacity(recovered.len());
        for (pos, slot, codec) in recovered {
            self.slots.insert(pos, (slot, codec));
            self.codec_rows[codec.index()] += 1;
            out.push(pos);
        }
        out
    }

    /// Discard the open-time scan's recovered records (fresh attach:
    /// the previous life's leftovers are reclaimed, not resurrected).
    pub fn reclaim_recovered(&mut self) -> Result<u64> {
        match self.file.as_mut() {
            Some(f) => f.reclaim_recovered(),
            None => Ok(0),
        }
    }
}

impl Tier for SpillTier {
    fn kind(&self) -> TierKind {
        TierKind::Spill
    }

    fn stash(&mut self, pos: usize, payload: RowPayload) -> Result<()> {
        let Some(dir) = self.dir.clone() else {
            return Err(Error::Offload(format!(
                "spill of pos {pos} but no spill dir configured"
            )));
        };
        if self.slots.contains_key(&pos) {
            return Err(Error::Offload(format!("spill tier already holds pos {pos}")));
        }
        if self.file.is_none() {
            let mut f = SpillFile::create(&dir, self.row_floats)?;
            f.fault = self.fault.clone();
            self.file = Some(f);
        }
        // raw rows are u8-normalized (f32 exceeds the fixed slot and
        // this tier is colder than the ladder's base rung); encoded
        // payloads spill verbatim — no decode/re-encode round trip
        let payload = match payload {
            RowPayload::Raw(_) => RowPayload::Quant(payload.into_quant()),
            encoded => encoded,
        };
        let codec = payload.codec();
        let t0 = Instant::now();
        // retries re-run the whole write: a failed attempt already
        // returned its slot to the free list (write_payload's error
        // path), so each attempt allocates cleanly
        let file = self.file.as_mut().unwrap();
        let slot = self.retry.run(RetryOp::Write, || file.write_payload(pos, &payload))?;
        self.write_us.record(t0.elapsed());
        self.slots.insert(pos, (slot, codec));
        self.codec_rows[codec.index()] += 1;
        Ok(())
    }

    fn take(&mut self, pos: usize) -> Result<Option<RowPayload>> {
        let Some(&(slot, codec)) = self.slots.get(&pos) else { return Ok(None) };
        let file = self
            .file
            .as_mut()
            .ok_or_else(|| Error::Offload(format!("pos {pos} spilled but no file")))?;
        // file op first: an I/O error must leave the pos -> slot
        // mapping intact so the record stays reachable for a retry
        // (removing it first stranded the slot forever: never freed,
        // counted by bytes(), unreachable by position).
        // take_payload is idempotent until its release succeeds (the
        // record stays live through a failed read or a failed
        // tombstone), so re-running the whole op is safe.
        let t0 = Instant::now();
        let payload = self.retry.run(RetryOp::Read, || file.take_payload(slot, pos))?;
        self.read_us.record(t0.elapsed());
        self.slots.remove(&pos);
        self.codec_rows[codec.index()] -= 1;
        Ok(Some(payload))
    }

    fn discard(&mut self, pos: usize) -> Result<bool> {
        let Some(&(slot, codec)) = self.slots.get(&pos) else { return Ok(false) };
        let file = self
            .file
            .as_mut()
            .ok_or_else(|| Error::Offload(format!("pos {pos} spilled but no file")))?;
        // same ordering as take: only unmap after the slot is freed
        self.retry.run(RetryOp::Free, || file.free_slot(slot, pos))?;
        self.slots.remove(&pos);
        self.codec_rows[codec.index()] -= 1;
        Ok(true)
    }

    fn bytes(&self) -> usize {
        self.file.as_ref().map(|f| f.bytes()).unwrap_or(0)
    }

    fn rows(&self) -> usize {
        self.slots.len()
    }

    fn occupancy(&self, out: &mut TierOccupancy) {
        out.spill_rows += self.slots.len();
        out.spill_bytes += self.bytes();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offload::quant::{pack_u4, quantize};
    use crate::util::TempDir;

    fn tmpdir() -> String {
        std::env::temp_dir()
            .join("asrkf-spill-test")
            .to_string_lossy()
            .into_owned()
    }

    fn file_len(f: &SpillFile) -> u64 {
        std::fs::metadata(&f.path).unwrap().len()
    }

    #[test]
    fn write_take_roundtrip() {
        let mut s = SpillFile::create(&tmpdir(), 8).unwrap();
        let qr = quantize(&[0.5f32, -1.0, 2.0, 0.0, 1.0, 1.5, -0.25, 0.75]);
        let slot = s.write_row(3, &qr).unwrap();
        assert_eq!(s.bytes(), s.record_bytes());
        let back = s.take_row(slot, 3).unwrap();
        assert_eq!(back, qr);
        assert_eq!(s.bytes(), 0);
    }

    #[test]
    fn slots_are_reused_after_free() {
        let mut s = SpillFile::create(&tmpdir(), 4).unwrap();
        let a = s.write_row(0, &quantize(&[1.0; 4])).unwrap();
        let b = s.write_row(1, &quantize(&[2.0; 4])).unwrap();
        assert_ne!(a, b);
        let _ = s.take_row(a, 0).unwrap();
        let c = s.write_row(2, &quantize(&[3.0; 4])).unwrap();
        assert_eq!(c, a, "freed slot not reused");
        // b untouched by the reuse
        let back = s.take_row(b, 1).unwrap();
        assert_eq!(back.min, 2.0);
    }

    #[test]
    fn ephemeral_file_removed_on_drop() {
        let path;
        {
            let s = SpillFile::create(&tmpdir(), 2).unwrap();
            path = s.path.clone();
            assert!(path.exists());
        }
        assert!(!path.exists());
    }

    #[test]
    fn rejects_wrong_row_width() {
        let mut s = SpillFile::create(&tmpdir(), 4).unwrap();
        assert!(s.write_row(0, &quantize(&[1.0; 3])).is_err());
    }

    #[test]
    fn rejects_raw_payloads() {
        let mut s = SpillFile::create(&tmpdir(), 4).unwrap();
        let err = s.write_payload(0, &RowPayload::Raw(vec![1.0; 4])).unwrap_err();
        assert!(format!("{err}").contains("raw"), "{err}");
        assert_eq!(s.bytes(), 0, "rejected write must not allocate a slot");
    }

    #[test]
    fn sub_byte_payload_roundtrips_through_the_fixed_slot() {
        let mut s = SpillFile::create(&tmpdir(), 64).unwrap();
        let row: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin()).collect();
        let pr = pack_u4(&row);
        let payload_bytes = pr.bytes();
        assert!(payload_bytes < s.record_bytes() - REC_HEADER_BYTES);
        let slot = s.write_payload(5, &RowPayload::Packed(pr.clone())).unwrap();
        match s.take_payload(slot, 5).unwrap() {
            RowPayload::Packed(back) => {
                assert_eq!(back.bytes(), payload_bytes);
                assert_eq!(back.q, pr.q, "nibble codes must survive the disk round trip");
                assert_eq!(back.blocks, pr.blocks);
            }
            other => panic!("u4 record must come back u4, got {:?}", other.codec()),
        }
        assert_eq!(s.bytes(), 0);
    }

    #[test]
    fn read_without_release_keeps_slot() {
        let mut s = SpillFile::create(&tmpdir(), 4).unwrap();
        let slot = s.write_row(9, &quantize(&[1.0, 2.0, 3.0, 4.0])).unwrap();
        let a = s.read_row(slot, 9).unwrap();
        let b = s.read_row(slot, 9).unwrap();
        assert_eq!(a, b);
        assert_eq!(s.bytes(), s.record_bytes());
        s.free_slot(slot, 9).unwrap();
        assert_eq!(s.bytes(), 0);
    }

    #[test]
    fn read_of_wrong_position_is_an_error() {
        let mut s = SpillFile::create(&tmpdir(), 4).unwrap();
        let slot = s.write_row(7, &quantize(&[1.0; 4])).unwrap();
        let err = s.read_row(slot, 8).unwrap_err();
        assert!(format!("{err}").contains("expected 8"), "{err}");
    }

    #[test]
    fn stale_handles_error_instead_of_corrupting() {
        let mut s = SpillFile::create(&tmpdir(), 4).unwrap();
        let keep = s.write_row(0, &quantize(&[0.5; 4])).unwrap();
        let slot = s.write_row(1, &quantize(&[1.0; 4])).unwrap();
        assert!(s.free_slot(99, 1).is_err(), "unallocated slot must error");
        s.free_slot(slot, 1).unwrap();
        // the freed tail slot was truncated away: both stale paths err
        assert!(s.free_slot(slot, 1).is_err(), "double free must error");
        assert!(s.read_row(slot, 1).is_err(), "read of freed slot must error");
        assert_eq!(s.bytes(), s.record_bytes(), "slot 0 still live");
        let _ = keep;
    }

    #[test]
    fn contiguous_free_tail_truncates_the_file() {
        let mut s = SpillFile::create(&tmpdir(), 4).unwrap();
        let rb = s.record_bytes() as u64;
        let s0 = s.write_row(0, &quantize(&[0.0; 4])).unwrap();
        let s1 = s.write_row(1, &quantize(&[1.0; 4])).unwrap();
        let s2 = s.write_row(2, &quantize(&[2.0; 4])).unwrap();
        assert_eq!(file_len(&s), 3 * rb);
        // freeing the tail slot shrinks immediately
        s.free_slot(s2, 2).unwrap();
        assert_eq!(file_len(&s), 2 * rb);
        // freeing a middle slot leaves a reusable hole, no shrink
        s.free_slot(s0, 0).unwrap();
        assert_eq!(file_len(&s), 2 * rb);
        // once the hole connects to the tail, the whole span truncates
        s.free_slot(s1, 1).unwrap();
        assert_eq!(file_len(&s), 0);
        assert_eq!(s.bytes(), 0);
        // the file keeps working after a full truncation
        let s3 = s.write_row(9, &quantize(&[9.0; 4])).unwrap();
        assert_eq!(s3, 0, "allocation restarts at slot 0");
        assert_eq!(file_len(&s), rb);
    }

    #[test]
    fn take_io_error_keeps_tier_bookkeeping_intact() {
        let mut t = SpillTier::new(Some(tmpdir()), 4);
        t.stash(5, RowPayload::Raw(vec![1.0, 2.0, 3.0, 4.0])).unwrap();
        t.file.as_mut().unwrap().fault_next_read = true;
        let err = t.take(5).unwrap_err();
        assert!(format!("{err}").contains("injected"), "{err}");
        // the old code removed the pos -> slot mapping before the file
        // op: the record was stranded (never freed, still counted,
        // unreachable). The mapping must survive the error:
        assert_eq!(t.rows(), 1, "failed take must not unmap the row");
        assert!(t.bytes() > 0);
        assert_eq!(t.codec_rows()[CodecId::U8.index()], 1, "codec gauge must survive too");
        let back = t.take(5).unwrap().expect("retry must reach the record");
        assert_eq!(back.into_raw().len(), 4);
        assert_eq!(t.rows(), 0);
        assert_eq!(t.bytes(), 0);
        assert_eq!(t.codec_rows()[CodecId::U8.index()], 0);
    }

    #[test]
    fn discard_io_error_keeps_tier_bookkeeping_intact() {
        let mut t = SpillTier::new(Some(tmpdir()), 4);
        t.stash(6, RowPayload::Raw(vec![1.0; 4])).unwrap();
        t.file.as_mut().unwrap().fault_next_free = true;
        let err = t.discard(6).unwrap_err();
        assert!(format!("{err}").contains("injected"), "{err}");
        assert_eq!(t.rows(), 1, "failed discard must not unmap the row");
        assert!(t.bytes() > 0);
        assert!(t.discard(6).unwrap(), "retry must free the record");
        assert_eq!(t.rows(), 0);
        assert_eq!(t.bytes(), 0);
    }

    #[test]
    fn armed_tier_retries_through_injected_faults() {
        use crate::offload::fault::RetryOutcome;
        let dir = TempDir::new("spill-fault-retry").unwrap();
        let cfg = crate::config::OffloadConfig {
            spill_dir: Some(dir.path_str()),
            fault_seed: Some(7),
            fault_io_rate: 0.4,
            fault_torn_rate: 0.2,
            fault_panic_rate: 0.0,
            fault_delay_rate: 0.0,
            io_retry_attempts: 16,
            io_retry_backoff_us: 1,
            io_retry_deadline_ms: 0,
            ..Default::default()
        };
        let mut t = SpillTier::new(cfg.spill_dir.clone(), 4);
        t.arm(FaultInjector::from_cfg(&cfg), RetryPolicy::from_cfg(&cfg));
        for pos in 0..32usize {
            t.stash(pos, RowPayload::Raw(vec![pos as f32; 4])).unwrap();
        }
        for pos in 0..32usize {
            let back = t.take(pos).unwrap().expect("row present").into_raw();
            assert_eq!(back[0], pos as f32, "payload survives retried I/O");
        }
        assert!(t.fault.injected_total() > 0, "rates 0.4/0.2 over 64 ops must inject");
        let recovered: u64 = RetryOp::ALL
            .iter()
            .map(|&op| t.retry().retries(op, RetryOutcome::Recovered))
            .sum();
        assert!(recovered > 0, "retries must have absorbed the injected faults");
        assert_eq!(t.rows(), 0);
        assert_eq!(t.bytes(), 0, "no slot leaked through the fault/retry churn");
    }

    #[test]
    fn failed_write_returns_slot_to_free_list() {
        let mut s = SpillFile::create(&tmpdir(), 4).unwrap();
        let a = s.write_row(0, &quantize(&[1.0; 4])).unwrap();
        // wrong width fails before any allocation side effect
        assert!(s.write_row(1, &quantize(&[1.0; 3])).is_err());
        assert_eq!(s.bytes(), s.record_bytes());
        let b = s.write_row(1, &quantize(&[2.0; 4])).unwrap();
        assert_eq!(b, a + 1, "no slot leaked by the failed write");
    }

    #[test]
    fn spill_tier_roundtrip_and_disabled_mode() {
        let mut t = SpillTier::new(Some(tmpdir()), 4);
        assert!(t.enabled());
        assert_eq!(t.bytes(), 0, "no file until first stash");
        let row = vec![1.0f32, 2.0, 3.0, 4.0];
        t.stash(7, RowPayload::Raw(row)).unwrap();
        assert_eq!(t.rows(), 1);
        assert!(t.bytes() > 0);
        assert!(t.stash(7, RowPayload::Raw(vec![0.0; 4])).is_err(), "collision");
        let back = t.take(7).unwrap().unwrap().into_raw();
        assert_eq!(back.len(), 4);
        assert!(t.take(7).unwrap().is_none());
        assert!(!t.discard(7).unwrap());

        let mut off = SpillTier::new(None, 4);
        assert!(!off.enabled());
        assert!(off.stash(0, RowPayload::Raw(vec![0.0; 4])).is_err());
        assert_eq!(off.bytes(), 0);
    }

    #[test]
    fn spill_tier_keeps_sub_byte_payloads_verbatim() {
        let mut t = SpillTier::new(Some(tmpdir()), 64);
        let row: Vec<f32> = (0..64).map(|i| (i as f32 * 0.21).cos()).collect();
        let pr = pack_u4(&row);
        let expect = pr.bytes();
        t.stash(3, RowPayload::Packed(pr)).unwrap();
        assert_eq!(t.codec_rows()[CodecId::U4.index()], 1);
        match t.take(3).unwrap().unwrap() {
            RowPayload::Packed(back) => assert_eq!(back.bytes(), expect),
            other => panic!("spill must keep the u4 record, got {:?}", other.codec()),
        }
        assert_eq!(t.codec_rows()[CodecId::U4.index()], 0);
    }

    // --- persistent mode ---

    #[test]
    fn manifest_attach_bumps_generation_and_validates_identity() {
        let dir = TempDir::new("spill-manifest").unwrap();
        let d = dir.path_str();
        let m1 = SpillManifest::attach(&d, 16, 2, ShardPartition::Hash).unwrap();
        assert_eq!(m1.generation, 1);
        let m2 = SpillManifest::attach(&d, 16, 2, ShardPartition::Hash).unwrap();
        assert_eq!(m2.generation, 2);
        // identity mismatches are hard errors
        assert!(SpillManifest::attach(&d, 32, 2, ShardPartition::Hash).is_err());
        assert!(SpillManifest::attach(&d, 16, 4, ShardPartition::Hash).is_err());
        assert!(SpillManifest::attach(&d, 16, 2, ShardPartition::Range).is_err());
    }

    #[test]
    fn manifest_attach_reclaims_ephemeral_leftovers() {
        let dir = TempDir::new("spill-reclaim").unwrap();
        let d = dir.path_str();
        // a dead process's ephemeral spill file
        let stale = dir.path().join("asrkf-spill-99999-0.bin");
        std::fs::write(&stale, b"junk").unwrap();
        let m = SpillManifest::attach(&d, 8, 1, ShardPartition::Hash).unwrap();
        assert_eq!(m.stale_files_reclaimed, 1);
        assert!(!stale.exists(), "dead-process file must be reclaimed");
    }

    #[test]
    fn persistent_file_survives_drop_and_recovers_records() {
        let dir = TempDir::new("spill-persist").unwrap();
        let d = dir.path_str();
        let qr = quantize(&[1.0, -2.0, 0.5, 3.0]);
        let path;
        {
            let m = SpillManifest::attach(&d, 4, 1, ShardPartition::Hash).unwrap();
            let mut f = SpillFile::open_or_create(&d, 4, 0, m.generation).unwrap();
            f.write_row(11, &qr).unwrap();
            f.write_row(12, &quantize(&[4.0; 4])).unwrap();
            let freed = f.write_row(13, &quantize(&[5.0; 4])).unwrap();
            f.free_slot(freed, 13).unwrap();
            path = f.path.clone();
            // ungraceful: drop without any shutdown protocol
        }
        assert!(path.exists(), "persistent file must survive drop");
        let m = SpillManifest::attach(&d, 4, 1, ShardPartition::Hash).unwrap();
        let mut f = SpillFile::open_or_create(&d, 4, 0, m.generation).unwrap();
        assert_eq!(f.recovery_errors, 0);
        let rec = f.take_recovered();
        let positions: Vec<usize> = rec.iter().map(|&(p, _, _)| p).collect();
        assert_eq!(positions, vec![11, 12], "freed slot 13 must not resurrect");
        assert!(rec.iter().all(|&(_, _, c)| c == CodecId::U8), "u8 records recover as u8");
        let (_, slot, _) = rec[0];
        assert_eq!(f.read_row(slot, 11).unwrap(), qr, "recovered payload bit-exact");
    }

    #[test]
    fn persistent_sub_byte_records_recover_with_their_codec() {
        let dir = TempDir::new("spill-persist-u4").unwrap();
        let d = dir.path_str();
        let row: Vec<f32> = (0..64).map(|i| (i as f32 * 0.13).sin()).collect();
        let pr = pack_u4(&row);
        {
            let m = SpillManifest::attach(&d, 64, 1, ShardPartition::Hash).unwrap();
            let mut f = SpillFile::open_or_create(&d, 64, 0, m.generation).unwrap();
            f.write_payload(21, &RowPayload::Packed(pr.clone())).unwrap();
        }
        let m = SpillManifest::attach(&d, 64, 1, ShardPartition::Hash).unwrap();
        let mut f = SpillFile::open_or_create(&d, 64, 0, m.generation).unwrap();
        assert_eq!(f.recovery_errors, 0);
        let rec = f.take_recovered();
        assert_eq!(rec.len(), 1);
        assert_eq!(rec[0], (21, 0, CodecId::U4), "codec tag must survive the restart");
        match f.read_payload(rec[0].1, 21).unwrap() {
            RowPayload::Packed(back) => assert_eq!(back.q, pr.q, "nibbles bit-exact"),
            other => panic!("u4 record must recover as u4, got {:?}", other.codec()),
        }
    }

    #[test]
    fn corrupted_position_field_fails_the_checksum() {
        let dir = TempDir::new("spill-posflip").unwrap();
        let d = dir.path_str();
        {
            let m = SpillManifest::attach(&d, 4, 1, ShardPartition::Hash).unwrap();
            let mut f = SpillFile::open_or_create(&d, 4, 0, m.generation).unwrap();
            f.write_row(3, &quantize(&[1.0; 4])).unwrap();
        }
        let path = record_path(&d, 0);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[12] ^= 0x01; // pos 3 -> pos 2: header-only corruption
        std::fs::write(&path, &bytes).unwrap();
        let m = SpillManifest::attach(&d, 4, 1, ShardPartition::Hash).unwrap();
        let mut f = SpillFile::open_or_create(&d, 4, 0, m.generation).unwrap();
        assert_eq!(f.recovery_errors, 1, "a flipped pos byte must fail the checksum");
        assert!(
            f.take_recovered().is_empty(),
            "a record with corrupt identity must never be served under the wrong position"
        );
    }

    #[test]
    fn corrupted_codec_byte_is_rejected_by_the_checksum() {
        let dir = TempDir::new("spill-codecflip").unwrap();
        let d = dir.path_str();
        {
            let m = SpillManifest::attach(&d, 4, 1, ShardPartition::Hash).unwrap();
            let mut f = SpillFile::open_or_create(&d, 4, 0, m.generation).unwrap();
            f.write_row(3, &quantize(&[1.0; 4])).unwrap();
        }
        let path = record_path(&d, 0);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[28] = CodecId::U4.as_byte(); // u8 record relabeled as u4
        std::fs::write(&path, &bytes).unwrap();
        let m = SpillManifest::attach(&d, 4, 1, ShardPartition::Hash).unwrap();
        let mut f = SpillFile::open_or_create(&d, 4, 0, m.generation).unwrap();
        assert_eq!(f.recovery_errors, 1, "a relabeled codec byte must fail the checksum");
        assert!(f.take_recovered().is_empty(), "never decode u8 bytes as u4");
    }

    #[test]
    fn fresh_attach_reclaim_truncates_leftovers() {
        let dir = TempDir::new("spill-fresh").unwrap();
        let d = dir.path_str();
        {
            let m = SpillManifest::attach(&d, 4, 1, ShardPartition::Hash).unwrap();
            let mut f = SpillFile::open_or_create(&d, 4, 0, m.generation).unwrap();
            f.write_row(0, &quantize(&[1.0; 4])).unwrap();
            f.write_row(1, &quantize(&[2.0; 4])).unwrap();
        }
        let m = SpillManifest::attach(&d, 4, 1, ShardPartition::Hash).unwrap();
        let mut f = SpillFile::open_or_create(&d, 4, 0, m.generation).unwrap();
        assert_eq!(f.reclaim_recovered().unwrap(), 2);
        assert_eq!(f.bytes(), 0);
        assert_eq!(file_len(&f), 0, "reclaimed leftovers must truncate away");
    }

    #[test]
    fn scan_rejects_corrupt_and_fenced_records() {
        let dir = TempDir::new("spill-scan").unwrap();
        let d = dir.path_str();
        let rb = record_bytes_for(4);
        {
            let m = SpillManifest::attach(&d, 4, 1, ShardPartition::Hash).unwrap();
            let mut f = SpillFile::open_or_create(&d, 4, 0, m.generation).unwrap();
            f.write_row(0, &quantize(&[1.0; 4])).unwrap();
            f.write_row(1, &quantize(&[2.0; 4])).unwrap();
            f.write_row(2, &quantize(&[3.0; 4])).unwrap();
        }
        let path = record_path(&d, 0);
        let mut bytes = std::fs::read(&path).unwrap();
        // poison slot 1's payload (checksum mismatch)
        bytes[rb + REC_HEADER_BYTES + 2] ^= 0xFF;
        // fence slot 2's generation far into the future
        bytes[2 * rb + 4..2 * rb + 12].copy_from_slice(&u64::MAX.to_le_bytes());
        // torn tail: a partial fourth record
        bytes.extend_from_slice(&[0xAB; 10]);
        std::fs::write(&path, &bytes).unwrap();

        let m = SpillManifest::attach(&d, 4, 1, ShardPartition::Hash).unwrap();
        let mut f = SpillFile::open_or_create(&d, 4, 0, m.generation).unwrap();
        assert_eq!(f.recovery_errors, 3, "poisoned + fenced + torn tail");
        let rec = f.take_recovered();
        assert_eq!(rec.len(), 1, "only the intact record survives");
        assert_eq!(rec[0].0, 0);
        let back = f.read_row(rec[0].1, 0).unwrap();
        assert_eq!(back, quantize(&[1.0; 4]));
    }

    // --- v1 on-disk compatibility ---

    /// Hand-craft one v1-format record ("KVR1" + 28-byte header + u8
    /// payload) exactly as the pre-ladder writer laid it out.
    fn v1_record(generation: u64, pos: u64, row: &[f32]) -> Vec<u8> {
        let qr = quantize(row);
        let mut rec = Vec::with_capacity(record_bytes_v1_for(row.len()));
        rec.extend_from_slice(&REC_MAGIC_LIVE_V1.to_le_bytes());
        rec.extend_from_slice(&generation.to_le_bytes());
        rec.extend_from_slice(&pos.to_le_bytes());
        rec.extend_from_slice(&[0u8; 8]);
        rec.extend_from_slice(&qr.min.to_le_bytes());
        rec.extend_from_slice(&qr.scale.to_le_bytes());
        rec.extend_from_slice(&qr.q);
        let sum = record_checksum(&rec);
        rec[20..28].copy_from_slice(&sum.to_le_bytes());
        rec
    }

    fn write_v1_manifest(d: &str, row_floats: usize, generation: u64) {
        let m = Json::obj(vec![
            ("magic", Json::str(MANIFEST_MAGIC)),
            ("version", Json::num(1.0)),
            ("row_floats", Json::num(row_floats as f64)),
            ("record_bytes", Json::num(record_bytes_v1_for(row_floats) as f64)),
            ("shards", Json::num(1.0)),
            ("partition", Json::str("hash")),
            ("generation", Json::num(generation as f64)),
        ]);
        let mut text = String::new();
        write_json(&m, &mut text);
        std::fs::write(Path::new(d).join(MANIFEST_FILE), text).unwrap();
    }

    #[test]
    fn v1_directory_migrates_on_open_and_records_recover() {
        let dir = TempDir::new("spill-v1-compat").unwrap();
        let d = dir.path_str();
        let v1_rb = record_bytes_v1_for(4);
        // a pre-ladder generation-1 shard file: two live records and a
        // tombstoned slot between lives and tail
        let mut bytes = v1_record(1, 11, &[1.0, -2.0, 0.5, 3.0]);
        bytes.extend_from_slice(&v1_record(1, 12, &[4.0; 4]));
        let mut tomb = vec![0u8; v1_rb];
        tomb[0..4].copy_from_slice(&REC_MAGIC_FREE.to_le_bytes());
        bytes.extend_from_slice(&tomb);
        std::fs::create_dir_all(&d).unwrap();
        std::fs::write(record_path(&d, 0), &bytes).unwrap();
        write_v1_manifest(&d, 4, 1);

        let m = SpillManifest::attach(&d, 4, 1, ShardPartition::Hash).unwrap();
        assert_eq!(m.generation, 2, "v1 generation must carry forward through the upgrade");
        let mut f = SpillFile::open_or_create(&d, 4, 0, m.generation).unwrap();
        assert_eq!(f.recovery_errors, 0, "clean v1 records must migrate without loss");
        let rec = f.take_recovered();
        let positions: Vec<usize> = rec.iter().map(|&(p, _, _)| p).collect();
        assert_eq!(positions, vec![11, 12]);
        assert!(rec.iter().all(|&(_, _, c)| c == CodecId::U8), "v1 payloads recover as u8");
        let back = f.read_row(rec[0].1, 11).unwrap();
        assert_eq!(back, quantize(&[1.0, -2.0, 0.5, 3.0]), "payload bit-exact across migration");
        drop(f);

        // the migrated directory is v2 now: a second restart scans it
        // as such (no second migration) and still recovers everything
        let m = SpillManifest::attach(&d, 4, 1, ShardPartition::Hash).unwrap();
        let mut f = SpillFile::open_or_create(&d, 4, 0, m.generation).unwrap();
        assert_eq!(f.recovery_errors, 0);
        assert_eq!(f.take_recovered().len(), 2);
    }

    #[test]
    fn v1_migration_reclaims_corrupt_records() {
        let dir = TempDir::new("spill-v1-corrupt").unwrap();
        let d = dir.path_str();
        let mut bytes = v1_record(1, 0, &[1.0; 4]);
        let mut bad = v1_record(1, 1, &[2.0; 4]);
        let last = bad.len() - 1;
        bad[last] ^= 0xFF; // poison the payload, keep the magic
        bytes.extend_from_slice(&bad);
        std::fs::create_dir_all(&d).unwrap();
        std::fs::write(record_path(&d, 0), &bytes).unwrap();
        write_v1_manifest(&d, 4, 1);

        let m = SpillManifest::attach(&d, 4, 1, ShardPartition::Hash).unwrap();
        let mut f = SpillFile::open_or_create(&d, 4, 0, m.generation).unwrap();
        assert_eq!(f.recovery_errors, 1, "corrupt v1 record must be counted, not carried");
        let rec = f.take_recovered();
        assert_eq!(rec.len(), 1, "only the intact v1 record survives migration");
        assert_eq!(rec[0].0, 0);
    }

    #[test]
    fn v1_manifest_with_mismatched_identity_still_errors() {
        let dir = TempDir::new("spill-v1-identity").unwrap();
        let d = dir.path_str();
        std::fs::create_dir_all(&d).unwrap();
        write_v1_manifest(&d, 4, 1);
        // wrong row width against a v1 manifest is still a hard error
        assert!(SpillManifest::attach(&d, 8, 1, ShardPartition::Hash).is_err());
    }
}
