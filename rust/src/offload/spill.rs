//! File-backed spill tier: fixed-record storage for quantized rows that
//! overflow the cold tier's byte budget on very long contexts.
//!
//! One spill file per `TieredStore`, created lazily on first demotion
//! and deleted on drop. Records are fixed-size (`ROW_HEADER_BYTES` +
//! `row_floats` code bytes) at `slot * record_bytes` offsets, with a
//! free list so restored slots are reused. I/O errors surface as
//! `Error::Offload` through `TieredStore`'s fallible API — the engine
//! fails the affected session rather than corrupting it.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::offload::quant::{QuantRow, ROW_HEADER_BYTES};

static NEXT_FILE_ID: AtomicU64 = AtomicU64::new(0);

pub struct SpillFile {
    file: File,
    path: PathBuf,
    record_bytes: usize,
    row_floats: usize,
    free: Vec<u32>,
    next_slot: u32,
}

impl std::fmt::Debug for SpillFile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpillFile")
            .field("path", &self.path)
            .field("slots", &self.next_slot)
            .field("free", &self.free.len())
            .finish()
    }
}

impl SpillFile {
    /// Create the spill file under `dir` (created if missing).
    pub fn create(dir: &str, row_floats: usize) -> Result<SpillFile> {
        std::fs::create_dir_all(dir)?;
        let id = NEXT_FILE_ID.fetch_add(1, Ordering::Relaxed);
        let path = PathBuf::from(dir)
            .join(format!("asrkf-spill-{}-{id}.bin", std::process::id()));
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)?;
        Ok(SpillFile {
            file,
            path,
            record_bytes: ROW_HEADER_BYTES + row_floats,
            row_floats,
            free: Vec::new(),
            next_slot: 0,
        })
    }

    /// Occupied bytes (allocated records minus the free list).
    pub fn bytes(&self) -> usize {
        (self.next_slot as usize - self.free.len()) * self.record_bytes
    }

    pub fn record_bytes(&self) -> usize {
        self.record_bytes
    }

    /// Write a quantized row; returns the slot to read it back from.
    pub fn write_row(&mut self, qr: &QuantRow) -> Result<u32> {
        if qr.q.len() != self.row_floats {
            return Err(Error::Offload(format!(
                "spill row has {} codes, store expects {}",
                qr.q.len(),
                self.row_floats
            )));
        }
        let slot = self.free.pop().unwrap_or_else(|| {
            let s = self.next_slot;
            self.next_slot += 1;
            s
        });
        self.file
            .seek(SeekFrom::Start(slot as u64 * self.record_bytes as u64))?;
        let mut rec = Vec::with_capacity(self.record_bytes);
        rec.extend_from_slice(&qr.min.to_le_bytes());
        rec.extend_from_slice(&qr.scale.to_le_bytes());
        rec.extend_from_slice(&qr.q);
        self.file.write_all(&rec)?;
        Ok(slot)
    }

    /// Read a row back and release its slot.
    pub fn take_row(&mut self, slot: u32) -> Result<QuantRow> {
        let qr = self.read_row(slot)?;
        self.free.push(slot);
        Ok(qr)
    }

    /// Read a row without releasing the slot (staging keeps the record
    /// until the hot copy is consumed or re-demoted).
    pub fn read_row(&mut self, slot: u32) -> Result<QuantRow> {
        debug_assert!(slot < self.next_slot && !self.free.contains(&slot));
        self.file
            .seek(SeekFrom::Start(slot as u64 * self.record_bytes as u64))?;
        let mut rec = vec![0u8; self.record_bytes];
        self.file.read_exact(&mut rec)?;
        let min = f32::from_le_bytes(rec[0..4].try_into().unwrap());
        let scale = f32::from_le_bytes(rec[4..8].try_into().unwrap());
        Ok(QuantRow { q: rec[ROW_HEADER_BYTES..].to_vec(), min, scale })
    }

    /// Release a slot without reading it (row dropped by a baseline).
    pub fn free_slot(&mut self, slot: u32) {
        debug_assert!(slot < self.next_slot && !self.free.contains(&slot));
        self.free.push(slot);
    }
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offload::quant::quantize;

    fn tmpdir() -> String {
        std::env::temp_dir()
            .join("asrkf-spill-test")
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn write_take_roundtrip() {
        let mut s = SpillFile::create(&tmpdir(), 8).unwrap();
        let qr = quantize(&[0.5f32, -1.0, 2.0, 0.0, 1.0, 1.5, -0.25, 0.75]);
        let slot = s.write_row(&qr).unwrap();
        assert_eq!(s.bytes(), s.record_bytes());
        let back = s.take_row(slot).unwrap();
        assert_eq!(back, qr);
        assert_eq!(s.bytes(), 0);
    }

    #[test]
    fn slots_are_reused_after_free() {
        let mut s = SpillFile::create(&tmpdir(), 4).unwrap();
        let a = s.write_row(&quantize(&[1.0; 4])).unwrap();
        let b = s.write_row(&quantize(&[2.0; 4])).unwrap();
        assert_ne!(a, b);
        let _ = s.take_row(a).unwrap();
        let c = s.write_row(&quantize(&[3.0; 4])).unwrap();
        assert_eq!(c, a, "freed slot not reused");
        // b untouched by the reuse
        let back = s.take_row(b).unwrap();
        assert_eq!(back.min, 2.0);
    }

    #[test]
    fn file_removed_on_drop() {
        let path;
        {
            let s = SpillFile::create(&tmpdir(), 2).unwrap();
            path = s.path.clone();
            assert!(path.exists());
        }
        assert!(!path.exists());
    }

    #[test]
    fn rejects_wrong_row_width() {
        let mut s = SpillFile::create(&tmpdir(), 4).unwrap();
        assert!(s.write_row(&quantize(&[1.0; 3])).is_err());
    }

    #[test]
    fn read_without_release_keeps_slot() {
        let mut s = SpillFile::create(&tmpdir(), 4).unwrap();
        let slot = s.write_row(&quantize(&[1.0, 2.0, 3.0, 4.0])).unwrap();
        let a = s.read_row(slot).unwrap();
        let b = s.read_row(slot).unwrap();
        assert_eq!(a, b);
        assert_eq!(s.bytes(), s.record_bytes());
        s.free_slot(slot);
        assert_eq!(s.bytes(), 0);
    }
}
