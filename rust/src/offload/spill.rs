//! File-backed spill tier: fixed-record storage for quantized rows that
//! overflow the cold tier's byte budget on very long contexts.
//!
//! One spill file per `TieredStore`, created lazily on first demotion
//! and deleted on drop. Records are fixed-size (`ROW_HEADER_BYTES` +
//! `row_floats` code bytes) at `slot * record_bytes` offsets, with a
//! free list so restored slots are reused. I/O errors surface as
//! `Error::Offload` through `TieredStore`'s fallible API — the engine
//! fails the affected session rather than corrupting it.

use std::collections::{BTreeSet, HashMap};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::metrics::{TierKind, TierOccupancy};
use crate::offload::quant::{QuantRow, ROW_HEADER_BYTES};
use crate::offload::tier::{RowPayload, Tier};

static NEXT_FILE_ID: AtomicU64 = AtomicU64::new(0);

pub struct SpillFile {
    file: File,
    path: PathBuf,
    record_bytes: usize,
    row_floats: usize,
    /// released slots awaiting reuse; ordered so handle checks and
    /// lowest-slot-first reuse are O(log n), not a linear scan on the
    /// restore path
    free: BTreeSet<u32>,
    next_slot: u32,
}

impl std::fmt::Debug for SpillFile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpillFile")
            .field("path", &self.path)
            .field("slots", &self.next_slot)
            .field("free", &self.free.len())
            .finish()
    }
}

impl SpillFile {
    /// Create the spill file under `dir` (created if missing).
    pub fn create(dir: &str, row_floats: usize) -> Result<SpillFile> {
        std::fs::create_dir_all(dir)?;
        let id = NEXT_FILE_ID.fetch_add(1, Ordering::Relaxed);
        let path = PathBuf::from(dir)
            .join(format!("asrkf-spill-{}-{id}.bin", std::process::id()));
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)?;
        Ok(SpillFile {
            file,
            path,
            record_bytes: ROW_HEADER_BYTES + row_floats,
            row_floats,
            free: BTreeSet::new(),
            next_slot: 0,
        })
    }

    /// Occupied bytes (allocated records minus the free list).
    pub fn bytes(&self) -> usize {
        (self.next_slot as usize - self.free.len()) * self.record_bytes
    }

    pub fn record_bytes(&self) -> usize {
        self.record_bytes
    }

    /// Write a quantized row; returns the slot to read it back from.
    pub fn write_row(&mut self, qr: &QuantRow) -> Result<u32> {
        if qr.q.len() != self.row_floats {
            return Err(Error::Offload(format!(
                "spill row has {} codes, store expects {}",
                qr.q.len(),
                self.row_floats
            )));
        }
        let slot = self.free.pop_first().unwrap_or_else(|| {
            let s = self.next_slot;
            self.next_slot += 1;
            s
        });
        self.file
            .seek(SeekFrom::Start(slot as u64 * self.record_bytes as u64))?;
        let mut rec = Vec::with_capacity(self.record_bytes);
        rec.extend_from_slice(&qr.min.to_le_bytes());
        rec.extend_from_slice(&qr.scale.to_le_bytes());
        rec.extend_from_slice(&qr.q);
        self.file.write_all(&rec)?;
        Ok(slot)
    }

    /// Reject handles that were never allocated or already released —
    /// a stale handle means the caller's bookkeeping diverged from the
    /// file's, and silently honouring it would corrupt the free list.
    fn check_live(&self, slot: u32) -> Result<()> {
        if slot >= self.next_slot {
            return Err(Error::Offload(format!(
                "stale spill handle {slot} (only {} slots allocated)",
                self.next_slot
            )));
        }
        if self.free.contains(&slot) {
            return Err(Error::Offload(format!("stale spill handle {slot} (already freed)")));
        }
        Ok(())
    }

    /// Read a row back and release its slot.
    pub fn take_row(&mut self, slot: u32) -> Result<QuantRow> {
        let qr = self.read_row(slot)?;
        self.free.insert(slot);
        Ok(qr)
    }

    /// Read a row without releasing the slot (staging keeps the record
    /// until the hot copy is consumed or re-demoted).
    pub fn read_row(&mut self, slot: u32) -> Result<QuantRow> {
        self.check_live(slot)?;
        self.file
            .seek(SeekFrom::Start(slot as u64 * self.record_bytes as u64))?;
        let mut rec = vec![0u8; self.record_bytes];
        self.file.read_exact(&mut rec)?;
        let min = f32::from_le_bytes(rec[0..4].try_into().unwrap());
        let scale = f32::from_le_bytes(rec[4..8].try_into().unwrap());
        Ok(QuantRow { q: rec[ROW_HEADER_BYTES..].to_vec(), min, scale })
    }

    /// Release a slot without reading it (row dropped by a baseline).
    /// Stale handles error instead of silently corrupting the free
    /// list (this used to be a `debug_assert!` that release builds
    /// ignored).
    pub fn free_slot(&mut self, slot: u32) -> Result<()> {
        self.check_live(slot)?;
        self.free.insert(slot);
        Ok(())
    }
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// The file-backed tier: cold rows that overflowed their byte budget
/// on very long contexts. The backing `SpillFile` is created lazily on
/// first stash so configurations that never spill touch no disk.
#[derive(Debug)]
pub struct SpillTier {
    dir: Option<String>,
    row_floats: usize,
    file: Option<SpillFile>,
    slots: HashMap<usize, u32>,
}

impl SpillTier {
    /// `dir: None` builds a disabled tier: stash errors, everything
    /// else reports empty.
    pub fn new(dir: Option<String>, row_floats: usize) -> SpillTier {
        SpillTier { dir, row_floats, file: None, slots: HashMap::new() }
    }

    pub fn enabled(&self) -> bool {
        self.dir.is_some()
    }
}

impl Tier for SpillTier {
    fn kind(&self) -> TierKind {
        TierKind::Spill
    }

    fn stash(&mut self, pos: usize, payload: RowPayload) -> Result<()> {
        let Some(dir) = self.dir.clone() else {
            return Err(Error::Offload(format!(
                "spill of pos {pos} but no spill dir configured"
            )));
        };
        if self.slots.contains_key(&pos) {
            return Err(Error::Offload(format!("spill tier already holds pos {pos}")));
        }
        if self.file.is_none() {
            self.file = Some(SpillFile::create(&dir, self.row_floats)?);
        }
        let qr = payload.into_quant();
        let slot = self.file.as_mut().unwrap().write_row(&qr)?;
        self.slots.insert(pos, slot);
        Ok(())
    }

    fn take(&mut self, pos: usize) -> Result<Option<RowPayload>> {
        let Some(slot) = self.slots.remove(&pos) else { return Ok(None) };
        let file = self
            .file
            .as_mut()
            .ok_or_else(|| Error::Offload(format!("pos {pos} spilled but no file")))?;
        Ok(Some(RowPayload::Quant(file.take_row(slot)?)))
    }

    fn discard(&mut self, pos: usize) -> Result<bool> {
        let Some(slot) = self.slots.remove(&pos) else { return Ok(false) };
        let file = self
            .file
            .as_mut()
            .ok_or_else(|| Error::Offload(format!("pos {pos} spilled but no file")))?;
        file.free_slot(slot)?;
        Ok(true)
    }

    fn bytes(&self) -> usize {
        self.file.as_ref().map(|f| f.bytes()).unwrap_or(0)
    }

    fn rows(&self) -> usize {
        self.slots.len()
    }

    fn occupancy(&self, out: &mut TierOccupancy) {
        out.spill_rows += self.slots.len();
        out.spill_bytes += self.bytes();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offload::quant::quantize;

    fn tmpdir() -> String {
        std::env::temp_dir()
            .join("asrkf-spill-test")
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn write_take_roundtrip() {
        let mut s = SpillFile::create(&tmpdir(), 8).unwrap();
        let qr = quantize(&[0.5f32, -1.0, 2.0, 0.0, 1.0, 1.5, -0.25, 0.75]);
        let slot = s.write_row(&qr).unwrap();
        assert_eq!(s.bytes(), s.record_bytes());
        let back = s.take_row(slot).unwrap();
        assert_eq!(back, qr);
        assert_eq!(s.bytes(), 0);
    }

    #[test]
    fn slots_are_reused_after_free() {
        let mut s = SpillFile::create(&tmpdir(), 4).unwrap();
        let a = s.write_row(&quantize(&[1.0; 4])).unwrap();
        let b = s.write_row(&quantize(&[2.0; 4])).unwrap();
        assert_ne!(a, b);
        let _ = s.take_row(a).unwrap();
        let c = s.write_row(&quantize(&[3.0; 4])).unwrap();
        assert_eq!(c, a, "freed slot not reused");
        // b untouched by the reuse
        let back = s.take_row(b).unwrap();
        assert_eq!(back.min, 2.0);
    }

    #[test]
    fn file_removed_on_drop() {
        let path;
        {
            let s = SpillFile::create(&tmpdir(), 2).unwrap();
            path = s.path.clone();
            assert!(path.exists());
        }
        assert!(!path.exists());
    }

    #[test]
    fn rejects_wrong_row_width() {
        let mut s = SpillFile::create(&tmpdir(), 4).unwrap();
        assert!(s.write_row(&quantize(&[1.0; 3])).is_err());
    }

    #[test]
    fn read_without_release_keeps_slot() {
        let mut s = SpillFile::create(&tmpdir(), 4).unwrap();
        let slot = s.write_row(&quantize(&[1.0, 2.0, 3.0, 4.0])).unwrap();
        let a = s.read_row(slot).unwrap();
        let b = s.read_row(slot).unwrap();
        assert_eq!(a, b);
        assert_eq!(s.bytes(), s.record_bytes());
        s.free_slot(slot).unwrap();
        assert_eq!(s.bytes(), 0);
    }

    #[test]
    fn stale_handles_error_instead_of_corrupting() {
        let mut s = SpillFile::create(&tmpdir(), 4).unwrap();
        let slot = s.write_row(&quantize(&[1.0; 4])).unwrap();
        assert!(s.free_slot(99).is_err(), "unallocated slot must error");
        s.free_slot(slot).unwrap();
        assert!(s.free_slot(slot).is_err(), "double free must error");
        assert!(s.read_row(slot).is_err(), "read of freed slot must error");
        assert_eq!(s.free.len(), 1, "failed frees must not grow the free list");
    }

    #[test]
    fn spill_tier_roundtrip_and_disabled_mode() {
        let mut t = SpillTier::new(Some(tmpdir()), 4);
        assert!(t.enabled());
        assert_eq!(t.bytes(), 0, "no file until first stash");
        let row = vec![1.0f32, 2.0, 3.0, 4.0];
        t.stash(7, RowPayload::Raw(row)).unwrap();
        assert_eq!(t.rows(), 1);
        assert!(t.bytes() > 0);
        assert!(t.stash(7, RowPayload::Raw(vec![0.0; 4])).is_err(), "collision");
        let back = t.take(7).unwrap().unwrap().into_raw();
        assert_eq!(back.len(), 4);
        assert!(t.take(7).unwrap().is_none());
        assert!(!t.discard(7).unwrap());

        let mut off = SpillTier::new(None, 4);
        assert!(!off.enabled());
        assert!(off.stash(0, RowPayload::Raw(vec![0.0; 4])).is_err());
        assert_eq!(off.bytes(), 0);
    }
}
