//! The compression-codec ladder: eta-aware multi-precision payload
//! representations for the cold/spill tiers.
//!
//! The paper's soft freeze keeps every frozen row recoverable; *how*
//! each row is stored is a pure representation choice. KVComp
//! (arXiv 2509.00579) shows KV tolerates far more aggressive lossy
//! compression when precision is chosen per block — and this store
//! already predicts, per row, how far away its thaw is. This module
//! turns that prediction into a precision dial:
//!
//! | rung  | representation                  | bytes/row (rf floats)    | worst-case error      |
//! |-------|---------------------------------|--------------------------|-----------------------|
//! | `raw` | f32 verbatim                    | `4·rf`                   | 0                     |
//! | `u8`  | per-row affine u8               | `8 + rf`                 | `range / 510`         |
//! | `u4`  | per-block (32) affine u4        | `8·nb + ceil(rf/2)`      | `range / 30`          |
//! | `ebq` | error-bounded 0/2/4/8-bit blocks| `9·nb + Σ code bytes`    | `ebq_rel_error·range` |
//!
//! (`nb = ceil(rf/32)`, `range` = the row's value range.)
//!
//! A [`CodecLadder`] maps predicted thaw distance (`thaw_eta - now`,
//! in steps) to a rung: rows coming back soon stay cheap to decode and
//! near-exact, rows predicted frozen for hundreds of steps pay for
//! their distance with sub-byte codes. `TieredStore` consults the
//! ladder once per demotion; tiers and the spill file store the
//! codec-tagged [`RowPayload`] verbatim (the on-disk record header
//! carries the codec byte). The default ladder is single-rung `0:u8`,
//! which reproduces the pre-ladder cold tier byte-for-byte
//! (oracle-tested in `tests/prop_offload.rs`).
//!
//! The encode/decode hot loops live in [`quant`]; this module owns
//! identity (codec byte), policy (ladder), trait plumbing ([`Codec`])
//! and the byte-level payload serialization the spill tier records.

use crate::error::{Error, Result};
use crate::offload::quant::{
    self, ceil_div, BoundedRow, EbqBlock, PackedRow, QuantRow, EBQ_BLOCK,
    EBQ_BLOCK_HEADER_BYTES, ROW_HEADER_BYTES, U4_BLOCK, U4_BLOCK_HEADER_BYTES,
};
use crate::offload::tier::RowPayload;

/// Identity of one codec rung. The discriminant is the on-disk codec
/// byte in spill v2 record headers — append-only, never renumber.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CodecId {
    /// f32 verbatim (lossless; never demoted to disk).
    Raw = 0,
    /// Per-row affine u8 — the pre-ladder cold representation.
    U8 = 1,
    /// Per-block affine u4, two codes per byte.
    U4 = 2,
    /// Error-bounded variable-rate blocks (0/2/4/8-bit).
    Ebq = 3,
}

/// Documented worst-case u4 reconstruction error as a fraction of the
/// row value range: half a 15-level step of a block's range (≤ the row
/// range), plus f32 headroom. Verified by `tests/spill_recovery.rs`.
pub const U4_REL_ERROR: f32 = 1.0 / 30.0 + 0.001;

impl CodecId {
    pub const COUNT: usize = 4;
    /// All rungs, discriminant order (also the metrics label order).
    pub const ALL: [CodecId; CodecId::COUNT] =
        [CodecId::Raw, CodecId::U8, CodecId::U4, CodecId::Ebq];

    /// The on-disk codec byte (spill v2 record header offset 28).
    pub fn as_byte(self) -> u8 {
        self as u8
    }

    /// Parse an on-disk codec byte.
    pub fn from_byte(b: u8) -> Option<CodecId> {
        CodecId::ALL.get(b as usize).copied()
    }

    /// Flag-value spelling (also the metrics `codec` label value).
    pub fn as_str(self) -> &'static str {
        match self {
            CodecId::Raw => "raw",
            CodecId::U8 => "u8",
            CodecId::U4 => "u4",
            CodecId::Ebq => "ebq",
        }
    }

    /// Parse a `--cold-codec` / `--codec-ladder` rung name.
    pub fn parse(s: &str) -> std::result::Result<CodecId, String> {
        match s {
            "raw" => Ok(CodecId::Raw),
            "u8" => Ok(CodecId::U8),
            "u4" => Ok(CodecId::U4),
            "ebq" => Ok(CodecId::Ebq),
            other => Err(format!("codec: expected 'raw', 'u8', 'u4' or 'ebq', got '{other}'")),
        }
    }

    /// Stable index into per-codec arrays (discriminant order).
    pub fn index(self) -> usize {
        self as usize
    }

    /// Worst-case encoded payload bytes for a `row_floats`-wide row
    /// (`ebq` is variable-rate; this is its 8-bit-everywhere ceiling).
    pub fn max_encoded_bytes(self, row_floats: usize) -> usize {
        let nb = ceil_div(row_floats.max(1), U4_BLOCK);
        match self {
            CodecId::Raw => row_floats * std::mem::size_of::<f32>(),
            CodecId::U8 => ROW_HEADER_BYTES + row_floats,
            CodecId::U4 => nb * U4_BLOCK_HEADER_BYTES + ceil_div(row_floats, 2),
            CodecId::Ebq => nb * EBQ_BLOCK_HEADER_BYTES + row_floats,
        }
    }

    /// Documented worst-case reconstruction error as a fraction of the
    /// row value range. `u8_rel` / `ebq_rel` come from
    /// `OffloadConfig::{cold_quant_rel_error, ebq_rel_error}`.
    pub fn rel_error_bound(self, u8_rel: f32, ebq_rel: f32) -> f32 {
        match self {
            CodecId::Raw => 0.0,
            CodecId::U8 => u8_rel,
            CodecId::U4 => U4_REL_ERROR,
            // an 8-bit block always meets any target the CLI accepts,
            // so the effective bound never exceeds the u8 rung's
            CodecId::Ebq => ebq_rel.max(u8_rel),
        }
    }
}

/// Fixed spill-slot payload ceiling: the widest worst case across the
/// spillable (non-raw) rungs, so one record size fits any codec the
/// ladder may hand the spill tier.
pub fn max_spill_payload_bytes(row_floats: usize) -> usize {
    [CodecId::U8, CodecId::U4, CodecId::Ebq]
        .iter()
        .map(|c| c.max_encoded_bytes(row_floats))
        .max()
        .unwrap_or(0)
}

/// One codec rung as a pluggable encoder/decoder. Tiers and the store
/// mostly dispatch through [`CodecSet`] (static match, no allocation);
/// the trait is the extension seam for future rungs (e.g. an
/// entropy-coded backend) and the surface the round-trip property
/// tests drive.
pub trait Codec {
    /// Which rung this is (and the on-disk codec byte it stamps).
    fn id(&self) -> CodecId;

    /// Encode a full-precision row into a codec-tagged payload.
    fn encode(&self, row: &[f32]) -> RowPayload;

    /// Decode a payload of this codec into a caller-provided buffer
    /// (len must match). Errors on a payload carrying another codec.
    fn decode_into(&self, payload: &RowPayload, dst: &mut [f32]) -> Result<()>;

    /// Worst-case encoded bytes for a `row_floats`-wide row.
    fn bytes_per_row(&self, row_floats: usize) -> usize {
        self.id().max_encoded_bytes(row_floats)
    }

    /// Worst-case absolute reconstruction error for a row with value
    /// range `row_range`.
    fn error_bound(&self, row_range: f32) -> f32;
}

fn codec_mismatch(want: CodecId, got: CodecId) -> Error {
    Error::Offload(format!("codec mismatch: decoding {} payload as {}", got.as_str(), want.as_str()))
}

/// Lossless f32 rung.
pub struct RawCodec;

impl Codec for RawCodec {
    fn id(&self) -> CodecId {
        CodecId::Raw
    }

    fn encode(&self, row: &[f32]) -> RowPayload {
        RowPayload::Raw(row.to_vec())
    }

    fn decode_into(&self, payload: &RowPayload, dst: &mut [f32]) -> Result<()> {
        match payload {
            RowPayload::Raw(r) => {
                dst.copy_from_slice(r);
                Ok(())
            }
            p => Err(codec_mismatch(self.id(), p.codec())),
        }
    }

    fn error_bound(&self, _row_range: f32) -> f32 {
        0.0
    }
}

/// Per-row affine u8 rung (the pre-ladder cold representation).
pub struct U8Codec;

impl Codec for U8Codec {
    fn id(&self) -> CodecId {
        CodecId::U8
    }

    fn encode(&self, row: &[f32]) -> RowPayload {
        RowPayload::Quant(quant::quantize(row))
    }

    fn decode_into(&self, payload: &RowPayload, dst: &mut [f32]) -> Result<()> {
        match payload {
            RowPayload::Quant(q) => {
                quant::dequantize_into(q, dst);
                Ok(())
            }
            p => Err(codec_mismatch(self.id(), p.codec())),
        }
    }

    fn error_bound(&self, row_range: f32) -> f32 {
        row_range / 510.0 + row_range * f32::EPSILON * 8.0
    }
}

/// Per-block affine u4 rung.
pub struct U4Codec;

impl Codec for U4Codec {
    fn id(&self) -> CodecId {
        CodecId::U4
    }

    fn encode(&self, row: &[f32]) -> RowPayload {
        RowPayload::Packed(quant::pack_u4(row))
    }

    fn decode_into(&self, payload: &RowPayload, dst: &mut [f32]) -> Result<()> {
        match payload {
            RowPayload::Packed(p) => {
                quant::unpack_u4_into(p, dst);
                Ok(())
            }
            p => Err(codec_mismatch(self.id(), p.codec())),
        }
    }

    fn error_bound(&self, row_range: f32) -> f32 {
        row_range * U4_REL_ERROR
    }
}

/// Error-bounded variable-rate rung for far-future rows.
pub struct EbqCodec {
    /// Per-block error target as a fraction of the row value range.
    pub rel_target: f32,
}

impl Codec for EbqCodec {
    fn id(&self) -> CodecId {
        CodecId::Ebq
    }

    fn encode(&self, row: &[f32]) -> RowPayload {
        RowPayload::Bounded(quant::encode_ebq(row, self.rel_target))
    }

    fn decode_into(&self, payload: &RowPayload, dst: &mut [f32]) -> Result<()> {
        match payload {
            RowPayload::Bounded(b) => {
                quant::decode_ebq_into(b, dst);
                Ok(())
            }
            p => Err(codec_mismatch(self.id(), p.codec())),
        }
    }

    fn error_bound(&self, row_range: f32) -> f32 {
        // the 8-bit fallback caps the error even when the target is
        // tighter than a block can meet
        (row_range * self.rel_target).max(row_range / 510.0) + row_range * f32::EPSILON * 8.0
    }
}

/// The rung dispatcher a store holds: encode/decode by [`CodecId`]
/// with static dispatch (no per-row allocation or vtable), carrying
/// the one config-dependent rung parameter.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CodecSet {
    /// `ebq` rung error target ([`OffloadConfig::ebq_rel_error`]).
    pub ebq_rel_error: f32,
}

impl Default for CodecSet {
    fn default() -> Self {
        CodecSet { ebq_rel_error: 0.02 }
    }
}

impl CodecSet {
    /// Encode a row under the given rung.
    pub fn encode(&self, id: CodecId, row: Vec<f32>) -> RowPayload {
        match id {
            CodecId::Raw => RowPayload::Raw(row),
            CodecId::U8 => RowPayload::Quant(quant::quantize(&row)),
            CodecId::U4 => RowPayload::Packed(quant::pack_u4(&row)),
            CodecId::Ebq => RowPayload::Bounded(quant::encode_ebq(&row, self.ebq_rel_error)),
        }
    }

    /// The rung as a trait object (the property-test / extension
    /// surface; the store itself uses [`CodecSet::encode`]).
    pub fn codec(&self, id: CodecId) -> Box<dyn Codec> {
        match id {
            CodecId::Raw => Box::new(RawCodec),
            CodecId::U8 => Box::new(U8Codec),
            CodecId::U4 => Box::new(U4Codec),
            CodecId::Ebq => Box::new(EbqCodec { rel_target: self.ebq_rel_error }),
        }
    }
}

/// Thaw-distance → codec rung map (`--codec-ladder`, e.g.
/// `0:u8,64:u4,512:ebq`): a demoted row whose predicted thaw is at
/// least `threshold` steps away is encoded with that rung (largest
/// matching threshold wins). Invariants enforced at parse: the base
/// rung's threshold is 0 (every distance maps to something), the
/// thresholds strictly increase, and `raw` may only appear as the sole
/// rung (it maps onto the legacy `--no-cold-quant` no-demotion mode —
/// a raw rung *above* lossy rungs would store far-future rows fatter
/// than near ones).
#[derive(Debug, Clone, PartialEq)]
pub struct CodecLadder {
    /// `(min thaw distance in steps, rung)`, ascending; first is 0.
    rungs: Vec<(u64, CodecId)>,
}

impl Default for CodecLadder {
    /// Single-rung `0:u8` — byte-for-byte the pre-ladder cold tier.
    fn default() -> Self {
        CodecLadder::single(CodecId::U8)
    }
}

impl CodecLadder {
    /// A one-rung ladder: every demotion uses `codec`.
    pub fn single(codec: CodecId) -> CodecLadder {
        CodecLadder { rungs: vec![(0, codec)] }
    }

    /// Parse a `--codec-ladder` spec (`threshold:codec`, comma
    /// separated).
    pub fn parse(spec: &str) -> std::result::Result<CodecLadder, String> {
        let mut rungs = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            let (t, c) = part
                .split_once(':')
                .ok_or_else(|| format!("--codec-ladder: expected 'steps:codec', got '{part}'"))?;
            let threshold = t
                .trim()
                .parse::<u64>()
                .map_err(|_| format!("--codec-ladder: '{t}' is not a step count"))?;
            let codec =
                CodecId::parse(c.trim()).map_err(|e| format!("--codec-ladder: {e}"))?;
            rungs.push((threshold, codec));
        }
        if rungs.is_empty() {
            return Err("--codec-ladder: at least one rung required".to_string());
        }
        if rungs[0].0 != 0 {
            return Err(format!(
                "--codec-ladder: the base rung must start at 0 (got {})",
                rungs[0].0
            ));
        }
        if !rungs.windows(2).all(|w| w[0].0 < w[1].0) {
            return Err("--codec-ladder: thresholds must strictly increase".to_string());
        }
        if rungs.iter().any(|&(_, c)| c == CodecId::Raw) && rungs.len() > 1 {
            return Err(
                "--codec-ladder: 'raw' disables demotion and must be the only rung".to_string()
            );
        }
        Ok(CodecLadder { rungs })
    }

    /// The rung for a row whose predicted thaw is `eta_distance` steps
    /// away: the largest threshold not exceeding the distance.
    pub fn pick(&self, eta_distance: u64) -> CodecId {
        self.rungs
            .iter()
            .rev()
            .find(|&&(t, _)| t <= eta_distance)
            .map(|&(_, c)| c)
            .unwrap_or(self.rungs[0].1)
    }

    /// The base (distance-0) rung — what the cold tier holds at the
    /// admission horizon.
    pub fn base(&self) -> CodecId {
        self.rungs[0].1
    }

    /// Whether this is the raw (no-demotion) ladder, the
    /// `--no-cold-quant` equivalent.
    pub fn is_raw(&self) -> bool {
        self.rungs.len() == 1 && self.rungs[0].1 == CodecId::Raw
    }

    /// The rungs, ascending by threshold.
    pub fn rungs(&self) -> &[(u64, CodecId)] {
        &self.rungs
    }

    /// Canonical flag spelling (`0:u8,64:u4,...`).
    pub fn as_spec(&self) -> String {
        self.rungs
            .iter()
            .map(|&(t, c)| format!("{t}:{}", c.as_str()))
            .collect::<Vec<_>>()
            .join(",")
    }
}

impl std::fmt::Display for CodecLadder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.as_spec())
    }
}

// --- spill payload serialization -------------------------------------
//
// The byte-level form of each payload in a spill record body, after
// the v2 record header (which carries the codec byte and payload
// length). Every non-raw layout's size equals `RowPayload::bytes()`
// exactly, so the admission byte accounting and the on-disk payload
// agree.
//
//   u8  : min f32 | scale f32 | rf code bytes
//   u4  : nb × (min f32 | scale f32) | ceil(rf/2) packed nibbles
//   ebq : nblk × (min f32 | scale f32 | bits u8) | code bytes
//   raw : rf × f32 LE (never written by the store; kept for
//         completeness and tested for symmetry)

fn push_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn read_f32(b: &[u8], off: usize) -> f32 {
    f32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

/// Serialize a payload into its spill record body form.
pub fn payload_to_bytes(payload: &RowPayload) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.bytes());
    match payload {
        RowPayload::Raw(r) => {
            for &x in r {
                push_f32(&mut out, x);
            }
        }
        RowPayload::Quant(q) => {
            push_f32(&mut out, q.min);
            push_f32(&mut out, q.scale);
            out.extend_from_slice(&q.q);
        }
        RowPayload::Packed(p) => {
            for &(min, scale) in &p.blocks {
                push_f32(&mut out, min);
                push_f32(&mut out, scale);
            }
            out.extend_from_slice(&p.q);
        }
        RowPayload::Bounded(b) => {
            for blk in &b.blocks {
                push_f32(&mut out, blk.min);
                push_f32(&mut out, blk.scale);
                out.push(blk.bits);
            }
            out.extend_from_slice(&b.q);
        }
    }
    debug_assert_eq!(out.len(), payload.bytes());
    out
}

/// Deserialize a spill record body back into a codec-tagged payload.
/// `body` must be exactly the payload bytes the record header declared;
/// every length is validated (a mismatch means record corruption the
/// checksum failed to catch, or a reader/writer version skew).
pub fn payload_from_bytes(codec: CodecId, row_floats: usize, body: &[u8]) -> Result<RowPayload> {
    let bad = |what: &str| {
        Error::Offload(format!(
            "spill payload corrupt: {what} (codec {}, {} body bytes, {row_floats} floats)",
            codec.as_str(),
            body.len()
        ))
    };
    match codec {
        CodecId::Raw => {
            if body.len() != row_floats * 4 {
                return Err(bad("raw length mismatch"));
            }
            let row = (0..row_floats).map(|i| read_f32(body, i * 4)).collect();
            Ok(RowPayload::Raw(row))
        }
        CodecId::U8 => {
            if body.len() != ROW_HEADER_BYTES + row_floats {
                return Err(bad("u8 length mismatch"));
            }
            let min = read_f32(body, 0);
            let scale = read_f32(body, 4);
            let q = body[ROW_HEADER_BYTES..].to_vec();
            Ok(RowPayload::Quant(QuantRow { q, min, scale }))
        }
        CodecId::U4 => {
            let nb = ceil_div(row_floats.max(1), U4_BLOCK);
            if body.len() != nb * U4_BLOCK_HEADER_BYTES + ceil_div(row_floats, 2) {
                return Err(bad("u4 length mismatch"));
            }
            let blocks = (0..nb)
                .map(|i| (read_f32(body, i * 8), read_f32(body, i * 8 + 4)))
                .collect();
            let q = body[nb * U4_BLOCK_HEADER_BYTES..].to_vec();
            Ok(RowPayload::Packed(PackedRow { q, blocks, floats: row_floats }))
        }
        CodecId::Ebq => {
            let nb = ceil_div(row_floats.max(1), EBQ_BLOCK);
            if body.len() < nb * EBQ_BLOCK_HEADER_BYTES {
                return Err(bad("ebq header truncated"));
            }
            let mut blocks = Vec::with_capacity(nb);
            let mut code_bytes = 0usize;
            for i in 0..nb {
                let off = i * EBQ_BLOCK_HEADER_BYTES;
                let bits = body[off + 8];
                if !matches!(bits, 0 | 2 | 4 | 8) {
                    return Err(bad("ebq code width invalid"));
                }
                let block_len = EBQ_BLOCK.min(row_floats - i * EBQ_BLOCK);
                if bits > 0 {
                    code_bytes += ceil_div(block_len, 8 / bits as usize);
                }
                blocks.push(EbqBlock {
                    min: read_f32(body, off),
                    scale: read_f32(body, off + 4),
                    bits,
                });
            }
            if body.len() != nb * EBQ_BLOCK_HEADER_BYTES + code_bytes {
                return Err(bad("ebq code length mismatch"));
            }
            let q = body[nb * EBQ_BLOCK_HEADER_BYTES..].to_vec();
            // the serialized form carries no bound; recompute the
            // guarantee from the block widths actually used
            let bound = blocks
                .iter()
                .map(|b| {
                    let range = if b.bits == 0 { b.scale } else { b.scale * ((1u32 << b.bits) - 1) as f32 };
                    let half = if b.bits == 0 { 0.5 * range } else { 0.5 * b.scale };
                    half + (b.min.abs() + range) * f32::EPSILON * 4.0
                })
                .fold(0.0f32, f32::max);
            Ok(RowPayload::Bounded(BoundedRow { blocks, q, floats: row_floats, bound }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wavy(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.53).sin() * 2.0 + 0.25).collect()
    }

    #[test]
    fn codec_byte_roundtrips_and_is_stable() {
        for c in CodecId::ALL {
            assert_eq!(CodecId::from_byte(c.as_byte()), Some(c));
            assert_eq!(CodecId::parse(c.as_str()).unwrap(), c);
        }
        // on-disk bytes are frozen: renumbering breaks old records
        assert_eq!(CodecId::Raw.as_byte(), 0);
        assert_eq!(CodecId::U8.as_byte(), 1);
        assert_eq!(CodecId::U4.as_byte(), 2);
        assert_eq!(CodecId::Ebq.as_byte(), 3);
        assert_eq!(CodecId::from_byte(4), None);
        assert!(CodecId::parse("fp8").is_err());
    }

    #[test]
    fn ladder_parses_picks_and_rejects() {
        let l = CodecLadder::parse("0:u8,64:u4,512:ebq").unwrap();
        assert_eq!(l.base(), CodecId::U8);
        assert_eq!(l.pick(0), CodecId::U8);
        assert_eq!(l.pick(63), CodecId::U8);
        assert_eq!(l.pick(64), CodecId::U4);
        assert_eq!(l.pick(511), CodecId::U4);
        assert_eq!(l.pick(512), CodecId::Ebq);
        assert_eq!(l.pick(u64::MAX), CodecId::Ebq);
        assert_eq!(l.as_spec(), "0:u8,64:u4,512:ebq");
        assert_eq!(CodecLadder::parse(&l.as_spec()).unwrap(), l, "spec roundtrips");
        assert_eq!(CodecLadder::default(), CodecLadder::single(CodecId::U8));
        assert!(CodecLadder::single(CodecId::Raw).is_raw());
        assert!(!CodecLadder::default().is_raw());
        for bad in [
            "",            // empty
            "64:u4",       // no base rung
            "0:u8,64",     // missing codec
            "0:u8,64:fp8", // unknown codec
            "0:u8,64:u4,64:ebq", // duplicate threshold
            "0:u8,64:raw", // raw above a lossy rung
            "x:u8",        // bad threshold
        ] {
            assert!(CodecLadder::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn trait_rungs_roundtrip_within_their_bound() {
        let row = wavy(100);
        let (lo, hi) = row.iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(lo, hi), &x| {
            (lo.min(x), hi.max(x))
        });
        let set = CodecSet::default();
        for id in CodecId::ALL {
            let c = set.codec(id);
            assert_eq!(c.id(), id);
            let payload = c.encode(&row);
            assert_eq!(payload.codec(), id);
            assert!(payload.bytes() <= c.bytes_per_row(row.len()), "{id:?} exceeds ceiling");
            let mut back = vec![0.0f32; row.len()];
            c.decode_into(&payload, &mut back).unwrap();
            let bound = c.error_bound(hi - lo);
            for (a, b) in row.iter().zip(&back) {
                assert!((a - b).abs() <= bound, "{id:?}: {a} vs {b} (bound {bound})");
            }
            // decoding under the wrong rung is a typed error
            if id != CodecId::U8 {
                let u8c = set.codec(CodecId::U8);
                assert!(u8c.decode_into(&payload, &mut back).is_err());
            }
        }
    }

    #[test]
    fn sub_byte_rungs_are_smaller_than_u8() {
        let rf = 1024;
        let row = wavy(rf);
        let set = CodecSet::default();
        let u8b = set.encode(CodecId::U8, row.clone()).bytes();
        let u4b = set.encode(CodecId::U4, row.clone()).bytes();
        let ebqb = set.encode(CodecId::Ebq, row).bytes();
        assert!(u4b < u8b, "u4 {u4b} vs u8 {u8b}");
        assert!(ebqb < u8b, "ebq {ebqb} vs u8 {u8b}");
    }

    #[test]
    fn payload_bytes_roundtrip_every_codec() {
        let set = CodecSet::default();
        for rf in [1usize, 31, 32, 33, 64, 100] {
            let row = wavy(rf);
            for id in CodecId::ALL {
                let payload = set.encode(id, row.clone());
                let body = payload_to_bytes(&payload);
                if id != CodecId::Raw {
                    assert_eq!(body.len(), payload.bytes(), "{id:?} rf={rf}");
                }
                let back = payload_from_bytes(id, rf, &body).unwrap();
                assert_eq!(
                    payload_to_bytes(&back),
                    body,
                    "{id:?} rf={rf} must survive a serialization round trip"
                );
                assert_eq!(back.codec(), id);
                // decoded floats are identical, not merely close: the
                // byte form is the payload, no re-encoding involved
                assert_eq!(back.into_raw(), payload.clone().into_raw(), "{id:?} rf={rf}");
            }
        }
    }

    #[test]
    fn payload_from_bytes_rejects_corrupt_lengths() {
        let set = CodecSet::default();
        let row = wavy(32);
        for id in CodecId::ALL {
            let body = payload_to_bytes(&set.encode(id, row.clone()));
            assert!(payload_from_bytes(id, 32, &body[..body.len() - 1]).is_err(), "{id:?}");
            let mut long = body.clone();
            long.push(0);
            assert!(payload_from_bytes(id, 32, &long).is_err(), "{id:?}");
        }
        // an ebq body with an invalid code width is rejected
        let mut body = payload_to_bytes(&set.encode(CodecId::Ebq, row));
        body[8] = 3;
        assert!(payload_from_bytes(CodecId::Ebq, 32, &body).is_err());
    }

    #[test]
    fn max_spill_payload_covers_every_spillable_rung() {
        for rf in [1usize, 16, 32, 33, 1024] {
            let cap = max_spill_payload_bytes(rf);
            for id in [CodecId::U8, CodecId::U4, CodecId::Ebq] {
                assert!(id.max_encoded_bytes(rf) <= cap, "{id:?} rf={rf}");
            }
        }
    }
}
