//! The tiered frozen-row store: residency policy over pluggable tiers.
//!
//! Replaces the flat `kv::FrozenStore` as the engine's off-GPU side of
//! the soft freeze. Every stashed row is kept (the paper's "no
//! permanent information loss") but residency is graded by the freeze
//! ladder's *predicted thaw step*:
//!
//! * rows predicted back within `cold_after_steps` stay **hot**
//!   (uncompressed, block-pooled for batched gather/scatter),
//! * rows predicted to stay frozen are encoded into the **cold** tier
//!   at stash time, with the codec rung picked by the configured
//!   `codec::CodecLadder` from the predicted thaw distance (u8 affine
//!   by default, ~4x smaller; u4 / error-bounded rungs for far-future
//!   rows),
//! * cold rows overflowing their byte budget demote to the
//!   file-backed **spill** tier when one is configured.
//!
//! Storage itself lives behind the [`Tier`] trait (`hot` / `cold` /
//! `spill` modules); this struct owns only the *policy*: which tier a
//! row belongs in, driven by the [`ThawScheduler`]'s eta index. All
//! per-step decisions — the `on_step` residency sweep, budget
//! eviction victims, `stage_upcoming` candidates — are answered by the
//! index in O(log n) / O(k), never by scanning the entry map.
//!
//! Restores (`take`) served from the hot tier are plain copies; the
//! prefetch path (`stage` / `stage_upcoming`) promotes
//! soon-to-thaw rows back to hot *between* decode steps so the decode
//! step itself never pays dequantization — the double-buffered
//! speculative-retrieval idea from FreeKV (arXiv 2505.13109).

use std::collections::HashMap;
use std::time::Instant;

use crate::config::OffloadConfig;
use crate::error::{Error, Result};
use crate::metrics::{
    Cause, CountHistogram, FlightRecorder, Histogram, RestoreLatency, Snapshot, SnapshotBuilder,
    TierKind, TierOccupancy,
};
use crate::offload::codec::{CodecId, CodecSet};
use crate::offload::cold::ColdTier;
use crate::offload::fault::{FaultInjector, FaultSite, RetryOp, RetryOutcome, RetryPolicy};
use crate::offload::hot::HotTier;
use crate::offload::sched::{SchedClass, ThawScheduler};
use crate::offload::spill::SpillTier;
use crate::offload::tier::{RowPayload, Tier};

#[derive(Debug)]
struct Entry {
    class: SchedClass,
    thaw_eta: u64,
    /// Re-attached by crash recovery ([`TieredStore::recover`]) rather
    /// than stashed by this process. The engine's policy knows nothing
    /// about recovered positions, so a re-freeze of one is not a
    /// double-freeze bug — the fresh row supersedes the stale copy.
    recovered: bool,
}

/// Tiered off-GPU storage for frozen KV rows. API superset of the old
/// `FrozenStore` (fallible where tier movement can fail).
pub struct TieredStore {
    row_floats: usize,
    cfg: OffloadConfig,
    entries: HashMap<usize, Entry>,
    hot: HotTier,
    cold: ColdTier,
    spill: SpillTier,
    sched: ThawScheduler,
    peak_hot_bytes: usize,
    peak_cold_bytes: usize,
    peak_spill_bytes: usize,
    /// lifetime counters for memory-accounting traces
    pub total_stashed: u64,
    pub total_restored: u64,
    pub total_dropped: u64,
    /// restores served from a prefetch-staged hot row
    pub staged_hits: u64,
    /// restores that paid inline dequantization / spill I/O
    pub staged_misses: u64,
    pub demotions_cold: u64,
    pub demotions_spill: u64,
    pub prefetch_promotions: u64,
    /// rows re-attached from a persistent spill file by `recover()`
    pub recovered_rows: u64,
    pub restore_latency: RestoreLatency,
    /// scheduler queue depth (rows awaiting staging), sampled per step
    pub sched_depth: CountHistogram,
    /// bounded ring of structured tier-transition events (`--trace-out`)
    flight: FlightRecorder,
    /// last decode step the store observed (stamps flight events whose
    /// trigger carries no step of its own, e.g. budget demotions)
    last_step: u64,
    /// seeded fault injection (`offload::fault`), shared with the
    /// spill tier; consulted by the worker pool at op entry. Inert
    /// unless `cfg.fault_seed` armed it.
    fault: FaultInjector,
    /// rows / payload bytes admitted per tier (hot=0, cold=1, spill=2)
    /// over the store's lifetime. `bytes / rows` is the achieved
    /// bytes/row per tier — the codec ladder's observable win (payload
    /// bytes, not disk slot size, so sub-byte rungs show through even
    /// though spill slots are fixed-width).
    pub tier_rows_stored: [u64; 3],
    pub tier_row_bytes_stored: [u64; 3],
    /// ladder encode / decode kernel latency, per codec rung
    /// (indexed by `CodecId::index`)
    pub codec_encode_us: [Histogram; CodecId::COUNT],
    pub codec_decode_us: [Histogram; CodecId::COUNT],
    /// codec rung implementations, parameterized by the config
    /// (`ebq_rel_error`)
    codecs: CodecSet,
}

impl std::fmt::Debug for TieredStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TieredStore")
            .field("rows", &self.entries.len())
            .field("occupancy", &self.occupancy())
            .finish()
    }
}

fn missing(pos: usize, class: SchedClass) -> Error {
    Error::Offload(format!("pos {pos} indexed as {class:?} but missing from its tier"))
}

fn class_tier(class: SchedClass) -> TierKind {
    match class {
        SchedClass::HotResident | SchedClass::HotStaged => TierKind::Hot,
        SchedClass::Cold => TierKind::Cold,
        SchedClass::Spill => TierKind::Spill,
    }
}

impl TieredStore {
    /// Build with the default (ephemeral, lazily-created) spill tier.
    /// Persistent spill is orchestrated one level up — see
    /// [`TieredStore::with_spill`] and `ShardedStore::resume`.
    pub fn new(row_floats: usize, cfg: OffloadConfig) -> Self {
        let spill = SpillTier::new(cfg.spill_dir.clone(), row_floats);
        TieredStore::with_spill(row_floats, cfg, spill)
    }

    /// Build around a caller-prepared spill tier (the persistent,
    /// already-scanned variant). Call [`TieredStore::recover`] next to
    /// adopt its recovered records, or leave them for the spill tier's
    /// `reclaim_recovered` (done by the fresh-attach path).
    pub fn with_spill(row_floats: usize, cfg: OffloadConfig, mut spill: SpillTier) -> Self {
        let hot = HotTier::new(row_floats, cfg.block_rows);
        let cold = ColdTier::new(row_floats);
        let flight_cap = cfg.flight_recorder_cap;
        // one injector per store: the spill tier shares it (and its
        // counters), so the whole store replays one coherent fault
        // trace from the seed. The configured retry policy (default 3
        // attempts) is armed here — direct `SpillTier` users keep the
        // fail-fast `RetryPolicy::none()` default.
        let fault = FaultInjector::from_cfg(&cfg);
        spill.arm(fault.clone(), RetryPolicy::from_cfg(&cfg));
        let codecs = CodecSet { ebq_rel_error: cfg.ebq_rel_error };
        TieredStore {
            row_floats,
            cfg,
            entries: HashMap::new(),
            hot,
            cold,
            spill,
            sched: ThawScheduler::default(),
            peak_hot_bytes: 0,
            peak_cold_bytes: 0,
            peak_spill_bytes: 0,
            total_stashed: 0,
            total_restored: 0,
            total_dropped: 0,
            staged_hits: 0,
            staged_misses: 0,
            demotions_cold: 0,
            demotions_spill: 0,
            prefetch_promotions: 0,
            recovered_rows: 0,
            restore_latency: RestoreLatency::default(),
            sched_depth: CountHistogram::default(),
            flight: FlightRecorder::new(flight_cap),
            last_step: 0,
            fault,
            tier_rows_stored: [0; 3],
            tier_row_bytes_stored: [0; 3],
            codec_encode_us: std::array::from_fn(|_| Histogram::default()),
            codec_decode_us: std::array::from_fn(|_| Histogram::default()),
            codecs,
        }
    }

    pub fn config(&self) -> &OffloadConfig {
        &self.cfg
    }

    /// The store's fault injector (worker-pool op-entry hook and
    /// counter access). Inert unless the config armed it.
    pub fn fault(&self) -> &FaultInjector {
        &self.fault
    }

    /// Adopt a re-sliced tier budget between steps (continuous-batching
    /// budget reflow). A shrink demotes immediately — the same
    /// farthest-thaw-first pressure path as `stash` — so the store is
    /// back inside the new envelope before the next decode step; a grow
    /// simply leaves headroom for future freezes. Rejects a hot slice
    /// below one row (same invariant as construction) so a reflow can
    /// never wedge the store in a state where no row fits.
    pub fn set_budgets(&mut self, hot_budget_bytes: usize, cold_budget_bytes: usize) -> Result<()> {
        if self.cfg.quantize_cold && hot_budget_bytes < self.row_bytes() {
            return Err(Error::Offload(format!(
                "hot budget re-slice to {hot_budget_bytes} B is below one {}-B row",
                self.row_bytes()
            )));
        }
        self.cfg.hot_budget_bytes = hot_budget_bytes;
        self.cfg.cold_budget_bytes = cold_budget_bytes;
        self.enforce_budgets()?;
        self.bump_peaks();
        Ok(())
    }

    /// Adopt the records a persistent spill tier recovered at open:
    /// each position is re-registered with the eta scheduler as a
    /// spill-resident row under a conservative `thaw_eta` of
    /// `now + cold_after_steps` (the crashed process's prediction is
    /// gone). Recovered rows stay on disk — the pressure-staging sweep
    /// skips them (see `stage_upcoming`), so their durable copy
    /// survives until an explicit take or a supersession re-freeze.
    /// Counted into `total_stashed` so the conservation invariant
    /// (`stashed == restored + dropped + resident`) spans restarts.
    pub fn recover(&mut self, now: u64) -> Result<u64> {
        let eta = now.saturating_add(self.cfg.cold_after_steps);
        let positions = self.spill.adopt_recovered();
        for &pos in &positions {
            if self.entries.contains_key(&pos) {
                return Err(Error::Offload(format!(
                    "recovered pos {pos} collides with a resident row"
                )));
            }
            self.entries
                .insert(pos, Entry { class: SchedClass::Spill, thaw_eta: eta, recovered: true });
            self.sched.insert(SchedClass::Spill, eta, pos);
            self.flight
                .record(now, pos, None, Some(TierKind::Spill), Cause::Recover, eta);
        }
        let n = positions.len() as u64;
        self.total_stashed += n;
        self.recovered_rows += n;
        self.bump_peaks();
        Ok(n)
    }

    /// Records the spill tier's open-time scan rejected (corrupt,
    /// fenced-generation, duplicate, or torn records). 0 when the
    /// spill tier is ephemeral or disabled.
    pub fn recovery_errors(&self) -> u64 {
        self.spill.recovery_errors()
    }

    fn row_bytes(&self) -> usize {
        self.row_floats * std::mem::size_of::<f32>()
    }

    fn bump_peaks(&mut self) {
        self.peak_hot_bytes = self.peak_hot_bytes.max(self.hot.bytes());
        self.peak_cold_bytes = self.peak_cold_bytes.max(self.cold.bytes());
        self.peak_spill_bytes = self.peak_spill_bytes.max(self.spill.bytes());
    }

    /// The tier backend currently holding `class` rows.
    fn tier_mut(&mut self, class: SchedClass) -> &mut dyn Tier {
        match class {
            SchedClass::HotResident | SchedClass::HotStaged => &mut self.hot,
            SchedClass::Cold => &mut self.cold,
            SchedClass::Spill => &mut self.spill,
        }
    }

    /// Record a tier admission for the bytes/row accounting
    /// (hot=0, cold=1, spill=2). Called at policy admissions only —
    /// `peek_decode`'s stash-back is a non-destructive read, not an
    /// admission, and is excluded.
    fn note_stored(&mut self, tier: usize, bytes: usize) {
        self.tier_rows_stored[tier] += 1;
        self.tier_row_bytes_stored[tier] += bytes as u64;
    }

    /// Encode a raw row with the ladder rung picked for a predicted
    /// thaw `distance` steps out, timing the kernel per codec.
    fn encode_for_distance(&mut self, row: Vec<f32>, distance: u64) -> RowPayload {
        let id = self.cfg.codec_ladder.pick(distance);
        let t0 = Instant::now();
        let payload = self.codecs.encode(id, row);
        self.codec_encode_us[id.index()].record(t0.elapsed());
        payload
    }

    /// Decode a payload to f32, timing the kernel per codec.
    fn decode_timed(&mut self, payload: RowPayload) -> Vec<f32> {
        let id = payload.codec();
        let t0 = Instant::now();
        let row = payload.into_raw();
        self.codec_decode_us[id.index()].record(t0.elapsed());
        row
    }

    /// Stash a gathered row bundle for `pos` (active -> frozen).
    /// `thaw_eta` is the policy's predicted restore step — it drives
    /// tier admission. Double-stashing is an engine invariant breach
    /// and returns `Error::Offload` (the old store corrupted silently
    /// in release builds).
    pub fn stash(&mut self, pos: usize, row: Vec<f32>, step: u64, thaw_eta: u64) -> Result<()> {
        if row.len() != self.row_floats {
            return Err(Error::Offload(format!(
                "row bundle for pos {pos} has {} floats, store expects {}",
                row.len(),
                self.row_floats
            )));
        }
        self.last_step = step;
        if let Some(e) = self.entries.get(&pos) {
            if e.recovered {
                // a resumed session re-froze a recovered position: the
                // fresh row supersedes the stale pre-crash copy (which
                // the policy never knew about)
                self.drop_inner(pos, Cause::Supersede)?;
            } else {
                return Err(Error::Offload(format!("double-freeze of pos {pos}")));
            }
        }
        let goes_cold = self.cfg.quantize_cold
            && thaw_eta.saturating_sub(step) >= self.cfg.cold_after_steps;
        let class = if goes_cold {
            // the ladder picks the rung from the predicted thaw
            // distance: rows expected back soon stay cheap to decode,
            // far-future rows compress hardest
            let payload = self.encode_for_distance(row, thaw_eta.saturating_sub(step));
            let bytes = payload.bytes();
            self.cold.stash(pos, payload)?;
            self.note_stored(1, bytes);
            self.demotions_cold += 1;
            SchedClass::Cold
        } else {
            self.hot.stash(pos, RowPayload::Raw(row))?;
            self.note_stored(0, self.row_bytes());
            SchedClass::HotResident
        };
        self.entries.insert(pos, Entry { class, thaw_eta, recovered: false });
        self.sched.insert(class, thaw_eta, pos);
        self.flight
            .record(step, pos, None, Some(class_tier(class)), Cause::Freeze, thaw_eta);
        self.total_stashed += 1;
        self.enforce_budgets()?;
        self.bump_peaks();
        Ok(())
    }

    /// Demote over-budget rows: hot -> cold (farthest predicted thaw
    /// first, staged rows exempt), then cold -> spill when configured.
    /// Victims come straight off the eta index — O(log n) each instead
    /// of a full-map scan per eviction.
    fn enforce_budgets(&mut self) -> Result<()> {
        if !self.cfg.quantize_cold {
            return Ok(()); // escape hatch: demotion saves nothing
        }
        while self.hot.bytes() > self.cfg.hot_budget_bytes {
            let Some((_, pos)) = self.sched.farthest(SchedClass::HotResident) else { break };
            self.demote_to_cold(pos, Cause::Pressure)?;
        }
        if self.spill.enabled() {
            while self.cold.bytes() > self.cfg.cold_budget_bytes {
                let Some((_, pos)) = self.sched.farthest(SchedClass::Cold) else { break };
                self.demote_to_spill(pos)?;
            }
        }
        self.bump_peaks();
        Ok(())
    }

    fn demote_to_cold(&mut self, pos: usize, cause: Cause) -> Result<()> {
        debug_assert!(self.cfg.quantize_cold, "demotion with quantization disabled");
        let (class, eta) = match self.entries.get(&pos) {
            Some(e) => (e.class, e.thaw_eta),
            None => return Err(Error::Offload(format!("demote of unknown pos {pos}"))),
        };
        if !matches!(class, SchedClass::HotResident | SchedClass::HotStaged) {
            return Err(Error::Offload(format!("demote of non-hot pos {pos}")));
        }
        let payload = self.hot.take(pos)?.ok_or_else(|| missing(pos, class))?;
        // hot rows are raw: encode with the rung for the remaining
        // predicted thaw distance (an already-encoded payload would
        // move verbatim, but the hot tier never holds one)
        let payload = match payload {
            RowPayload::Raw(row) => {
                self.encode_for_distance(row, eta.saturating_sub(self.last_step))
            }
            encoded => encoded,
        };
        let bytes = payload.bytes();
        self.cold.stash(pos, payload)?;
        self.note_stored(1, bytes);
        self.sched.remove(class, eta, pos);
        self.sched.insert(SchedClass::Cold, eta, pos);
        self.entries.get_mut(&pos).unwrap().class = SchedClass::Cold;
        self.demotions_cold += 1;
        self.flight
            .record(self.last_step, pos, Some(TierKind::Hot), Some(TierKind::Cold), cause, eta);
        Ok(())
    }

    fn demote_to_spill(&mut self, pos: usize) -> Result<()> {
        let (class, eta) = match self.entries.get(&pos) {
            Some(e) => (e.class, e.thaw_eta),
            None => return Err(Error::Offload(format!("spill of unknown pos {pos}"))),
        };
        if class != SchedClass::Cold {
            return Err(Error::Offload(format!("spill of non-cold pos {pos}")));
        }
        // the encoded record moves verbatim — no re-encoding
        let payload = self.cold.take(pos)?.ok_or_else(|| missing(pos, class))?;
        let bytes = payload.bytes();
        if let Err(e) = self.spill.stash(pos, payload.clone()) {
            // a failed spill write must not lose the row: put the
            // record back so the demotion is a clean no-op and the
            // caller can retry under pressure at the next sweep
            self.cold.stash(pos, payload)?;
            return Err(e);
        }
        self.note_stored(2, bytes);
        self.sched.remove(SchedClass::Cold, eta, pos);
        self.sched.insert(SchedClass::Spill, eta, pos);
        self.entries.get_mut(&pos).unwrap().class = SchedClass::Spill;
        self.demotions_spill += 1;
        self.flight.record(
            self.last_step,
            pos,
            Some(TierKind::Cold),
            Some(TierKind::Spill),
            Cause::Pressure,
            eta,
        );
        Ok(())
    }

    /// Promote one entry into the hot tier with the staged flag set.
    /// Decompression happens HERE — ahead of the decode step that will
    /// consume the row. Staging respects the hot-tier budget: when the
    /// hot tier is full the row stays put and the eventual restore pays
    /// the inline cost (visible as a staged miss) rather than blowing
    /// the budget the coordinator partitioned per slot.
    fn promote(&mut self, pos: usize, cause: Cause) -> Result<bool> {
        let (class, eta) = match self.entries.get(&pos) {
            None => return Ok(false),
            Some(e) => (e.class, e.thaw_eta),
        };
        if matches!(class, SchedClass::HotResident | SchedClass::HotStaged) {
            return Ok(false);
        }
        if !self.hot.has_headroom(self.cfg.hot_budget_bytes) {
            return Ok(false);
        }
        let payload = self
            .tier_mut(class)
            .stage(pos)?
            .ok_or_else(|| missing(pos, class))?;
        let row = self.decode_timed(payload);
        self.hot.stash(pos, RowPayload::Raw(row))?;
        self.note_stored(0, self.row_bytes());
        self.sched.remove(class, eta, pos);
        self.sched.insert(SchedClass::HotStaged, eta, pos);
        self.entries.get_mut(&pos).unwrap().class = SchedClass::HotStaged;
        self.prefetch_promotions += 1;
        self.flight
            .record(self.last_step, pos, Some(class_tier(class)), Some(TierKind::Hot), cause, eta);
        self.bump_peaks();
        Ok(true)
    }

    /// Stage specific rows (the policy's prefetch hints) into the hot
    /// tier. Each hint carries the policy's *live* predicted thaw step,
    /// which also re-keys the row in the eta index — recovery
    /// unfreezes rewrite freeze timers, so stash-time etas go stale.
    /// Returns how many rows were actually promoted.
    pub fn stage(&mut self, hints: &[(usize, u64)]) -> Result<usize> {
        let mut n = 0;
        for &(pos, eta) in hints {
            if let Some(e) = self.entries.get_mut(&pos) {
                let (class, old_eta) = (e.class, e.thaw_eta);
                e.thaw_eta = eta;
                self.sched.retarget(class, pos, old_eta, eta);
            }
            if self.promote(pos, Cause::Prefetch)? {
                n += 1;
            }
        }
        Ok(n)
    }

    /// Stage every row predicted to thaw within `horizon` steps of
    /// `now`, soonest first, up to `max_rows`. Used when the entropy
    /// monitor trends toward a recovery trigger: recovery unfreezes are
    /// served from hot rows instead of paying dequantization inside the
    /// decode step. The horizon is clamped to the admission horizon
    /// (`cold_after_steps`) so speculative promotions are never undone
    /// by the next residency sweep. Candidates come off the eta index
    /// (O(max_rows) range walk, not a full-map scan).
    pub fn stage_upcoming(&mut self, now: u64, horizon: u64, max_rows: usize) -> Result<usize> {
        let horizon = horizon.min(self.cfg.cold_after_steps);
        let limit = now.saturating_add(horizon);
        let mut n = 0;
        for (_, pos) in self.sched.due_frozen(limit, max_rows) {
            // crash-recovered rows have no imminent consumer (the
            // resumed policy never froze them, so it will never plan
            // their restore): promoting one would evict its only
            // durable copy from disk and park it in the hot tier
            // indefinitely. They leave the store via an explicit take
            // (drain / store-level resume) or supersession, never via
            // speculation.
            if self.entries.get(&pos).is_some_and(|e| e.recovered) {
                continue;
            }
            if self.promote(pos, Cause::Pressure)? {
                n += 1;
            }
        }
        Ok(n)
    }

    /// Rows the restore pipeline should read speculatively: frozen
    /// (cold/spill) rows predicted to thaw within `horizon` steps of
    /// `now`, soonest first, up to `max_rows`. Same candidate set as
    /// [`stage_upcoming`] (horizon clamped to `cold_after_steps`,
    /// recovered orphans excluded) but read-only — the caller ships
    /// the positions to a worker and the actual promotion happens
    /// there via [`promote_speculative`] + [`peek_decode`].
    ///
    /// [`stage_upcoming`]: TieredStore::stage_upcoming
    /// [`promote_speculative`]: TieredStore::promote_speculative
    /// [`peek_decode`]: TieredStore::peek_decode
    pub fn spec_candidates(&self, now: u64, horizon: u64, max_rows: usize) -> Vec<(usize, u64)> {
        let horizon = horizon.min(self.cfg.cold_after_steps);
        let limit = now.saturating_add(horizon);
        self.sched
            .due_frozen(limit, max_rows)
            .into_iter()
            .filter(|&(_, pos)| !self.entries.get(&pos).is_some_and(|e| e.recovered))
            .map(|(eta, pos)| (pos, eta))
            .collect()
    }

    /// Worker-side half of a speculative restore: promote `pos` into
    /// the staged hot tier if headroom allows (identical to the
    /// prefetch path, so tier state converges with the synchronous
    /// oracle). Returns whether a promotion happened; `Ok(false)` for
    /// absent/already-hot rows is not an error.
    pub fn promote_speculative(&mut self, pos: usize) -> Result<bool> {
        self.promote(pos, Cause::Prefetch)
    }

    /// Decode `pos`'s payload without consuming it: the tier contents,
    /// entry map, eta index, and every counter are exactly as before
    /// the call. This is the read half of a speculative restore — the
    /// landed copy is a pure cache, so a cancelled speculation needs no
    /// bookkeeping rollback. Implemented as take + stash-back on the
    /// same tier (the [`Tier`] trait has no non-destructive read); for
    /// the spill tier that costs one extra record write, paid inside
    /// the worker where it overlaps decode.
    pub fn peek_decode(&mut self, pos: usize) -> Result<Option<Vec<f32>>> {
        let Some(e) = self.entries.get(&pos) else { return Ok(None) };
        let class = e.class;
        let payload = self
            .tier_mut(class)
            .take(pos)?
            .ok_or_else(|| missing(pos, class))?;
        let row = self.decode_timed(payload.clone());
        self.tier_mut(class).stash(pos, payload)?;
        Ok(Some(row))
    }

    /// Consume `pos` exactly like [`take`] but serve the payload from a
    /// pre-decoded speculative copy: performs all of take's bookkeeping
    /// (tier discard, index pop, staged hit/miss attribution, restore
    /// latency, conservation counters, flight event) without decoding
    /// the row again. Errors if `pos` is absent — the caller's
    /// generation fence guarantees presence, so absence is a fencing
    /// bug, not a race to tolerate silently.
    ///
    /// [`take`]: TieredStore::take
    pub fn confirm_restore(&mut self, pos: usize) -> Result<()> {
        let Some(e) = self.entries.get(&pos) else {
            return Err(Error::Offload(format!(
                "confirm_restore of absent pos {pos} (stale speculative copy served?)"
            )));
        };
        let (class, eta) = (e.class, e.thaw_eta);
        let t0 = Instant::now();
        let held = self.tier_mut(class).discard(pos)?;
        if !held {
            return Err(missing(pos, class));
        }
        self.entries.remove(&pos);
        self.sched.remove(class, eta, pos);
        let tier = match class {
            SchedClass::HotResident | SchedClass::HotStaged => {
                if class == SchedClass::HotStaged {
                    self.staged_hits += 1;
                }
                TierKind::Hot
            }
            SchedClass::Cold => {
                self.staged_misses += 1;
                TierKind::Cold
            }
            SchedClass::Spill => {
                self.staged_misses += 1;
                TierKind::Spill
            }
        };
        self.restore_latency.record(tier, t0.elapsed());
        self.total_restored += 1;
        self.flight.record(self.last_step, pos, Some(tier), None, Cause::Restore, eta);
        Ok(())
    }

    /// Residency sweep, called once per decode step by the session.
    /// Applies the admission rule continuously: a hot row whose
    /// predicted thaw sits beyond the `cold_after_steps` horizon does
    /// not belong in the hot tier — the main source is a stale
    /// prefetch (a row staged for a recovery that never fired). The
    /// eta index hands over exactly the overdue rows, so the sweep is
    /// O(demoted) instead of O(resident).
    pub fn on_step(&mut self, now: u64) -> Result<()> {
        self.last_step = now;
        if !self.cfg.quantize_cold {
            return Ok(());
        }
        let limit = now.saturating_add(self.cfg.cold_after_steps);
        for (_, pos) in self.sched.overdue_hot(limit) {
            self.demote_to_cold(pos, Cause::Expire)?;
        }
        self.enforce_budgets()?;
        self.sched_depth.record(self.sched.queued_frozen() as u64);
        Ok(())
    }

    /// Whether the next `on_step(now)` sweep would demote anything —
    /// a cheap index probe (no allocation, no tier movement) used by
    /// `ShardedStore` to keep idle sweeps off the worker pool.
    pub fn sweep_pending(&self, now: u64) -> bool {
        self.cfg.quantize_cold
            && self
                .sched
                .has_overdue_hot(now.saturating_add(self.cfg.cold_after_steps))
    }

    /// Take the payload for a restore (frozen -> active). `Ok(None)`
    /// means nothing was stashed for `pos`; spill I/O failures error.
    ///
    /// The entry map and the eta index are popped only after the
    /// payload is in hand: a spill I/O error must leave the store's
    /// bookkeeping aligned with the tier's contents, so a retry still
    /// reaches the row. (The old order popped the indexes first — a
    /// failed take then reported `Ok(None)` forever for a row the
    /// tier still held.)
    pub fn take(&mut self, pos: usize) -> Result<Option<Vec<f32>>> {
        self.take_inner(pos, Cause::Restore)
    }

    fn take_inner(&mut self, pos: usize, cause: Cause) -> Result<Option<Vec<f32>>> {
        let Some(e) = self.entries.get(&pos) else { return Ok(None) };
        let (class, eta) = (e.class, e.thaw_eta);
        let t0 = Instant::now();
        let payload = self
            .tier_mut(class)
            .take(pos)?
            .ok_or_else(|| missing(pos, class))?;
        self.entries.remove(&pos);
        self.sched.remove(class, eta, pos);
        let tier = match class {
            SchedClass::HotResident | SchedClass::HotStaged => {
                if class == SchedClass::HotStaged {
                    self.staged_hits += 1;
                }
                TierKind::Hot
            }
            SchedClass::Cold => {
                self.staged_misses += 1;
                TierKind::Cold
            }
            SchedClass::Spill => {
                self.staged_misses += 1;
                TierKind::Spill
            }
        };
        let row = self.decode_timed(payload);
        self.restore_latency.record(tier, t0.elapsed());
        self.total_restored += 1;
        self.flight.record(self.last_step, pos, Some(tier), None, cause, eta);
        Ok(Some(row))
    }

    /// Drop a payload permanently (irreversible-eviction baselines).
    /// Absent positions are a no-op; tier bookkeeping failures (a
    /// stale spill handle) surface as `Error::Offload` instead of
    /// being silently ignored. Same mutation order as [`take`]: the
    /// indexes are only popped after the tier op succeeds, so a spill
    /// I/O error leaves the row reachable for a retry.
    ///
    /// [`take`]: TieredStore::take
    pub fn drop_row(&mut self, pos: usize) -> Result<()> {
        self.drop_inner(pos, Cause::Drop)
    }

    fn drop_inner(&mut self, pos: usize, cause: Cause) -> Result<()> {
        let Some(e) = self.entries.get(&pos) else { return Ok(()) };
        let (class, eta) = (e.class, e.thaw_eta);
        let held = self.tier_mut(class).discard(pos)?;
        self.entries.remove(&pos);
        self.sched.remove(class, eta, pos);
        if !held {
            return Err(missing(pos, class));
        }
        self.total_dropped += 1;
        self.flight
            .record(self.last_step, pos, Some(class_tier(class)), None, cause, eta);
        Ok(())
    }

    pub fn contains(&self, pos: usize) -> bool {
        self.entries.contains_key(&pos)
    }

    /// The tier currently holding `pos`, plus whether it sits in the
    /// hot tier via a prefetch promotion (staged). Diagnostics and the
    /// scheduler-oracle property test.
    pub fn tier_of(&self, pos: usize) -> Option<(TierKind, bool)> {
        self.entries.get(&pos).map(|e| match e.class {
            SchedClass::HotResident => (TierKind::Hot, false),
            SchedClass::HotStaged => (TierKind::Hot, true),
            SchedClass::Cold => (TierKind::Cold, false),
            SchedClass::Spill => (TierKind::Spill, false),
        })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Bytes currently held across all tiers.
    pub fn bytes(&self) -> usize {
        self.hot.bytes() + self.cold.bytes() + self.spill.bytes()
    }

    /// Drain everything (pos, payload) — the engine's emergency full
    /// restore (RR recovery rewind). Crosses every tier.
    pub fn drain_all(&mut self) -> Result<Vec<(usize, Vec<f32>)>> {
        let positions: Vec<usize> = self.entries.keys().copied().collect();
        let mut out = Vec::with_capacity(positions.len());
        for pos in positions {
            if let Some(row) = self.take_inner(pos, Cause::Emergency)? {
                out.push((pos, row));
            }
        }
        Ok(out)
    }

    /// Resident positions, in arbitrary order. Borrows instead of
    /// allocating — callers that need order sort their own collection.
    pub fn positions(&self) -> impl Iterator<Item = usize> + '_ {
        self.entries.keys().copied()
    }

    /// Point-in-time per-tier occupancy gauges. O(1): each tier owns
    /// its own row/byte accounting (the old implementation classified
    /// every entry on each call).
    pub fn occupancy(&self) -> TierOccupancy {
        let mut o = TierOccupancy {
            peak_hot_bytes: self.peak_hot_bytes,
            peak_cold_bytes: self.peak_cold_bytes,
            peak_spill_bytes: self.peak_spill_bytes,
            uncompressed_bytes: self.entries.len() * self.row_bytes(),
            ..TierOccupancy::default()
        };
        self.hot.occupancy(&mut o);
        self.cold.occupancy(&mut o);
        self.spill.occupancy(&mut o);
        o
    }

    /// The store's bounded ring of tier-transition events.
    pub fn flight(&self) -> &FlightRecorder {
        &self.flight
    }

    /// Publish the store's *flow* series — counters and latency
    /// histograms, all monotone over the store's lifetime — into a
    /// snapshot builder under the given `shard` label. Safe to add
    /// cumulatively into `Registry::global()` when a session retires
    /// (counters sum; gauges would collide, so they live in
    /// [`TieredStore::publish_gauges`]).
    pub fn publish_flows(&self, b: &mut SnapshotBuilder, shard: usize) {
        let sh = shard.to_string();
        let sh = sh.as_str();
        let l = [("shard", sh)];
        b.counter_add("asrkf_stash_total", &l, self.total_stashed);
        b.counter_add("asrkf_restore_total", &l, self.total_restored);
        b.counter_add("asrkf_drop_total", &l, self.total_dropped);
        b.counter_add("asrkf_staged_total", &[("result", "hit"), ("shard", sh)], self.staged_hits);
        b.counter_add(
            "asrkf_staged_total",
            &[("result", "miss"), ("shard", sh)],
            self.staged_misses,
        );
        b.counter_add("asrkf_demotion_total", &[("to", "cold"), ("shard", sh)], self.demotions_cold);
        b.counter_add(
            "asrkf_demotion_total",
            &[("to", "spill"), ("shard", sh)],
            self.demotions_spill,
        );
        b.counter_add("asrkf_promotion_total", &l, self.prefetch_promotions);
        b.counter_add("asrkf_recovered_rows_total", &l, self.recovered_rows);
        b.counter_add("asrkf_recovery_errors_total", &l, self.spill.recovery_errors());
        b.counter_add("asrkf_flight_events_dropped_total", &l, self.flight.dropped());
        for (i, tier) in ["hot", "cold", "spill"].iter().enumerate() {
            let lt = [("tier", *tier), ("shard", sh)];
            b.counter_add("asrkf_tier_rows_stored_total", &lt, self.tier_rows_stored[i]);
            b.counter_add("asrkf_tier_row_bytes_total", &lt, self.tier_row_bytes_stored[i]);
        }
        for id in CodecId::ALL {
            let lc = [("codec", id.as_str())];
            b.time_merge("asrkf_codec_encode_us", &lc, &self.codec_encode_us[id.index()]);
            b.time_merge("asrkf_codec_decode_us", &lc, &self.codec_decode_us[id.index()]);
        }
        b.time_merge("asrkf_restore_us", &[("tier", "hot")], &self.restore_latency.hot);
        b.time_merge("asrkf_restore_us", &[("tier", "cold")], &self.restore_latency.cold);
        b.time_merge("asrkf_restore_us", &[("tier", "spill")], &self.restore_latency.spill);
        b.time_merge("asrkf_spill_read_us", &[], &self.spill.read_us);
        b.time_merge("asrkf_spill_write_us", &[], &self.spill.write_us);
        b.count_merge("asrkf_sched_depth", &[], &self.sched_depth);
        for site in FaultSite::ALL {
            b.counter_add(
                "asrkf_faults_injected_total",
                &[("site", site.as_str()), ("shard", sh)],
                self.fault.injected(site),
            );
        }
        for op in RetryOp::ALL {
            for outcome in RetryOutcome::ALL {
                b.counter_add(
                    "asrkf_io_retries_total",
                    &[("op", op.as_str()), ("outcome", outcome.as_str()), ("shard", sh)],
                    self.spill.retry().retries(op, outcome),
                );
            }
        }
    }

    /// Publish the store's point-in-time occupancy gauges under the
    /// given `shard` label. Kept separate from the flows: per-shard
    /// gauges belong in per-store snapshots (and the single-session
    /// generate path) — publishing them from many concurrent sessions
    /// into one registry would overwrite each other.
    pub fn publish_gauges(&self, b: &mut SnapshotBuilder, shard: usize) {
        let sh = shard.to_string();
        let sh = sh.as_str();
        let o = self.occupancy();
        for (tier, rows, bytes, peak) in [
            ("hot", o.hot_rows, o.hot_bytes, o.peak_hot_bytes),
            ("cold", o.cold_rows, o.cold_bytes, o.peak_cold_bytes),
            ("spill", o.spill_rows, o.spill_bytes, o.peak_spill_bytes),
        ] {
            let l = [("tier", tier), ("shard", sh)];
            b.gauge_set("asrkf_tier_rows", &l, rows as f64);
            b.gauge_set("asrkf_tier_bytes", &l, bytes as f64);
            b.gauge_set("asrkf_tier_peak_bytes", &l, peak as f64);
        }
        b.gauge_set("asrkf_uncompressed_bytes", &[("shard", sh)], o.uncompressed_bytes as f64);
        b.gauge_set("asrkf_shard_rows", &[("shard", sh)], self.entries.len() as f64);
        // resident rows per codec rung: the hot tier is raw by
        // construction; cold and spill track their own per-codec counts
        b.gauge_set(
            "asrkf_codec_rows",
            &[("tier", "hot"), ("codec", "raw"), ("shard", sh)],
            self.hot.rows() as f64,
        );
        let (cold_codecs, spill_codecs) = (self.cold.codec_rows(), self.spill.codec_rows());
        for id in CodecId::ALL {
            for (tier, counts) in [("cold", &cold_codecs), ("spill", &spill_codecs)] {
                b.gauge_set(
                    "asrkf_codec_rows",
                    &[("tier", tier), ("codec", id.as_str()), ("shard", sh)],
                    counts[id.index()] as f64,
                );
            }
        }
    }

    /// Publish flows and gauges together (per-store snapshots).
    pub fn publish(&self, b: &mut SnapshotBuilder, shard: usize) {
        self.publish_flows(b, shard);
        self.publish_gauges(b, shard);
    }

    /// Freeze this store's series into a private snapshot (shard 0).
    /// `OffloadSummary` is a view over this — see
    /// `OffloadSummary::from_snapshot`.
    pub fn snapshot(&self) -> Snapshot {
        let mut b = SnapshotBuilder::default();
        self.publish(&mut b, 0);
        b.gauge_set("asrkf_shards", &[], 1.0);
        b.finish()
    }

    /// Counters + occupancy view for responses and bench CSVs, derived
    /// from the registry snapshot (the snapshot is the source of
    /// truth; this struct is the flat view legacy callers keep).
    /// Plan-batching counters are zero here — the session overlays its
    /// own (`Session::offload_summary`), since batching happens in the
    /// engine's plan execution, not in storage.
    pub fn summary(&self) -> super::OffloadSummary {
        super::OffloadSummary::from_snapshot(&self.snapshot())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> OffloadConfig {
        OffloadConfig {
            hot_budget_bytes: usize::MAX >> 1,
            cold_budget_bytes: usize::MAX >> 1,
            cold_after_steps: 8,
            block_rows: 4,
            ..OffloadConfig::default()
        }
    }

    fn row(rf: usize, v: f32) -> Vec<f32> {
        (0..rf).map(|i| v + i as f32 * 0.01).collect()
    }

    const RF: usize = 16;

    #[test]
    fn hot_stash_take_roundtrip_is_exact() {
        let mut s = TieredStore::new(RF, cfg());
        let r = row(RF, 1.0);
        s.stash(7, r.clone(), 0, 2).unwrap(); // thaws in 2 < cold_after 8 -> hot
        assert!(s.contains(7));
        assert_eq!(s.occupancy().hot_rows, 1);
        assert_eq!(s.tier_of(7), Some((TierKind::Hot, false)));
        assert_eq!(s.take(7).unwrap(), Some(r));
        assert_eq!(s.take(7).unwrap(), None);
        assert_eq!(s.total_restored, 1);
    }

    #[test]
    fn double_stash_is_an_error() {
        let mut s = TieredStore::new(RF, cfg());
        s.stash(3, row(RF, 0.0), 0, 1).unwrap();
        let e = s.stash(3, row(RF, 1.0), 0, 1).unwrap_err();
        assert!(format!("{e}").contains("double-freeze"));
        assert_eq!(s.total_stashed, 1);
    }

    #[test]
    fn far_thaw_eta_admits_straight_to_cold() {
        let mut s = TieredStore::new(RF, cfg());
        s.stash(1, row(RF, 1.0), 0, 100).unwrap(); // eta - step >= 8 -> cold
        let o = s.occupancy();
        assert_eq!(o.cold_rows, 1);
        assert_eq!(o.hot_rows, 0);
        assert!(o.cold_bytes < o.uncompressed_bytes, "cold tier not smaller");
    }

    #[test]
    fn cold_take_roundtrips_within_quant_bound() {
        let mut s = TieredStore::new(RF, cfg());
        let orig = row(RF, -2.0);
        s.stash(1, orig.clone(), 0, 100).unwrap();
        let back = s.take(1).unwrap().unwrap();
        let range = 0.01 * (RF - 1) as f32;
        let bound = cfg().cold_quant_rel_error * range + 1e-6;
        for (a, b) in orig.iter().zip(&back) {
            assert!((a - b).abs() <= bound, "{a} vs {b}");
        }
        assert_eq!(s.staged_misses, 1, "inline dequantization must count as a miss");
    }

    #[test]
    fn hot_budget_demotes_farthest_eta_first() {
        let mut c = cfg();
        c.hot_budget_bytes = 2 * RF * 4; // room for 2 hot rows
        let mut s = TieredStore::new(RF, c);
        s.stash(1, row(RF, 1.0), 0, 2).unwrap();
        s.stash(2, row(RF, 2.0), 0, 3).unwrap();
        s.stash(3, row(RF, 3.0), 0, 7).unwrap(); // over budget: pos 3 has farthest eta
        let o = s.occupancy();
        assert_eq!(o.hot_rows, 2);
        assert_eq!(o.cold_rows, 1);
        assert_eq!(s.tier_of(3), Some((TierKind::Cold, false)));
        // 1 and 2 still hot (exact roundtrip)
        assert_eq!(s.take(1).unwrap(), Some(row(RF, 1.0)));
        assert_eq!(s.take(2).unwrap(), Some(row(RF, 2.0)));
    }

    #[test]
    fn set_budgets_shrink_demotes_and_grow_leaves_headroom() {
        let mut c = cfg();
        c.hot_budget_bytes = 4 * RF * 4; // room for 4 hot rows
        let mut s = TieredStore::new(RF, c);
        for pos in 0..4 {
            s.stash(pos, row(RF, pos as f32), 0, 2 + pos as u64).unwrap();
        }
        assert_eq!(s.occupancy().hot_rows, 4);
        // shrink to 2 rows: the two farthest-eta rows demote immediately
        s.set_budgets(2 * RF * 4, usize::MAX >> 1).unwrap();
        let o = s.occupancy();
        assert_eq!(o.hot_rows, 2);
        assert_eq!(o.cold_rows, 2);
        assert_eq!(s.tier_of(3), Some((TierKind::Cold, false)), "farthest eta demoted first");
        assert_eq!(s.tier_of(0), Some((TierKind::Hot, false)));
        // grow back: nothing promotes eagerly, but new freezes fit hot
        s.set_budgets(8 * RF * 4, usize::MAX >> 1).unwrap();
        assert_eq!(s.occupancy().hot_rows, 2);
        s.stash(9, row(RF, 9.0), 1, 3).unwrap();
        assert_eq!(s.occupancy().hot_rows, 3);
        // a slice below one row is rejected and leaves budgets unchanged
        assert!(s.set_budgets(RF * 4 - 1, 0).is_err());
        assert_eq!(s.config().hot_budget_bytes, 8 * RF * 4);
    }

    #[test]
    fn staged_restore_never_decompresses_in_take() {
        let mut s = TieredStore::new(RF, cfg());
        s.stash(5, row(RF, 1.5), 0, 100).unwrap();
        assert_eq!(s.occupancy().cold_rows, 1);
        // prefetch-ahead: decompression happens in stage(), between
        // steps; the hint also refreshes the thaw prediction
        assert_eq!(s.stage(&[(5, 2)]).unwrap(), 1);
        assert_eq!(s.occupancy().hot_rows, 1);
        assert_eq!(s.tier_of(5), Some((TierKind::Hot, true)));
        let before_cold_restores = s.restore_latency.cold.count();
        let got = s.take(5).unwrap().unwrap();
        assert_eq!(got.len(), RF);
        assert_eq!(s.staged_hits, 1);
        assert_eq!(s.staged_misses, 0);
        assert_eq!(s.restore_latency.cold.count(), before_cold_restores);
        assert_eq!(s.restore_latency.hot.count(), 1);
    }

    #[test]
    fn stage_upcoming_promotes_soonest_first() {
        let mut s = TieredStore::new(RF, cfg());
        s.stash(1, row(RF, 1.0), 0, 20).unwrap();
        s.stash(2, row(RF, 2.0), 0, 12).unwrap();
        s.stash(3, row(RF, 3.0), 0, 50).unwrap();
        assert_eq!(s.occupancy().cold_rows, 3);
        // horizon covers 12 and 20; cap 1 -> soonest (pos 2) promoted
        assert_eq!(s.stage_upcoming(10, 10, 1).unwrap(), 1);
        let o = s.occupancy();
        assert_eq!(o.hot_rows, 1);
        s.take(2).unwrap().unwrap();
        assert_eq!(s.staged_hits, 1);
    }

    #[test]
    fn stale_staged_rows_demote_on_step() {
        let mut s = TieredStore::new(RF, cfg());
        s.stash(1, row(RF, 1.0), 0, 100).unwrap(); // far eta -> cold
        // a speculative hint whose prediction is still far away
        assert_eq!(s.stage(&[(1, 100)]).unwrap(), 1);
        assert_eq!(s.occupancy().hot_rows, 1);
        // the predicted thaw (100) is still beyond now + cold_after (8):
        // the speculation was a false alarm, the row goes back cold
        assert!(s.sweep_pending(10), "stale staged row must flag the sweep probe");
        s.on_step(10).unwrap();
        assert!(!s.sweep_pending(10), "probe must clear once the sweep ran");
        assert_eq!(s.occupancy().hot_rows, 0);
        assert_eq!(s.occupancy().cold_rows, 1);
        // a row staged near its thaw stays hot
        s.stash(2, row(RF, 2.0), 0, 12).unwrap();
        s.stage_upcoming(10, 5, 8).unwrap();
        s.on_step(10).unwrap();
        assert_eq!(s.occupancy().hot_rows, 1);
    }

    #[test]
    fn staging_respects_hot_budget() {
        let mut c = cfg();
        c.hot_budget_bytes = RF * 4; // room for exactly one hot row
        let mut s = TieredStore::new(RF, c);
        s.stash(1, row(RF, 1.0), 0, 2).unwrap(); // hot, fills the budget
        s.stash(2, row(RF, 2.0), 0, 100).unwrap(); // cold
        // no headroom: the speculative promotion must be refused ...
        assert_eq!(s.stage(&[(2, 3)]).unwrap(), 0);
        assert_eq!(s.occupancy().hot_rows, 1);
        // ... and the restore falls back to the inline path (a miss)
        s.take(2).unwrap().unwrap();
        assert_eq!(s.staged_misses, 1);
        // once the hot row leaves, staging works again
        s.stash(3, row(RF, 3.0), 0, 100).unwrap();
        s.take(1).unwrap().unwrap();
        assert_eq!(s.stage(&[(3, 3)]).unwrap(), 1);
    }

    #[test]
    fn spill_tier_engages_over_cold_budget() {
        let dir = std::env::temp_dir().join("asrkf-store-test").to_string_lossy().into_owned();
        let mut c = cfg();
        c.cold_budget_bytes = 1; // everything cold must spill
        c.spill_dir = Some(dir);
        let mut s = TieredStore::new(RF, c);
        s.stash(1, row(RF, 1.0), 0, 100).unwrap();
        let o = s.occupancy();
        assert_eq!(o.cold_rows, 0);
        assert_eq!(o.spill_rows, 1);
        assert!(o.spill_bytes > 0);
        assert_eq!(s.tier_of(1), Some((TierKind::Spill, false)));
        let back = s.take(1).unwrap().unwrap();
        assert_eq!(back.len(), RF);
        assert_eq!(s.restore_latency.spill.count(), 1);
        assert_eq!(s.occupancy().spill_bytes, 0);
    }

    #[test]
    fn quantize_escape_hatch_never_demotes() {
        let mut c = cfg();
        c.quantize_cold = false;
        c.hot_budget_bytes = 1;
        let mut s = TieredStore::new(RF, c);
        s.stash(1, row(RF, 1.0), 0, 1000).unwrap();
        s.on_step(500).unwrap();
        let o = s.occupancy();
        assert_eq!(o.hot_rows, 1, "escape hatch must keep rows uncompressed");
        assert_eq!(s.take(1).unwrap(), Some(row(RF, 1.0)), "must stay lossless");
    }

    #[test]
    fn drop_row_accounts_across_tiers() {
        let mut s = TieredStore::new(RF, cfg());
        s.stash(1, row(RF, 1.0), 0, 1).unwrap(); // hot
        s.stash(2, row(RF, 2.0), 0, 100).unwrap(); // cold
        s.drop_row(1).unwrap();
        s.drop_row(2).unwrap();
        s.drop_row(99).unwrap(); // absent: no-op, no count
        assert_eq!(s.total_dropped, 2);
        assert!(s.is_empty());
        assert_eq!(s.bytes(), 0);
    }

    #[test]
    fn drain_all_crosses_tiers() {
        let mut s = TieredStore::new(RF, cfg());
        s.stash(1, row(RF, 1.0), 0, 1).unwrap(); // hot
        s.stash(9, row(RF, 9.0), 0, 100).unwrap(); // cold
        let mut all = s.drain_all().unwrap();
        all.sort_by_key(|(p, _)| *p);
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].0, 1);
        assert_eq!(all[0].1, row(RF, 1.0));
        assert_eq!(all[1].0, 9);
        assert!(s.is_empty());
        assert_eq!(s.total_restored, 2);
    }

    #[test]
    fn conservation_counter_invariant() {
        let mut s = TieredStore::new(RF, cfg());
        for p in 0..10 {
            s.stash(p, row(RF, p as f32), 0, if p % 2 == 0 { 1 } else { 100 }).unwrap();
        }
        s.take(0).unwrap();
        s.take(1).unwrap();
        s.drop_row(2).unwrap();
        assert_eq!(
            s.total_stashed,
            s.total_restored + s.total_dropped + s.len() as u64
        );
    }

    #[test]
    fn peak_gauges_are_high_water_marks() {
        let mut s = TieredStore::new(RF, cfg());
        s.stash(1, row(RF, 1.0), 0, 1).unwrap();
        s.stash(2, row(RF, 2.0), 0, 1).unwrap();
        let peak = s.occupancy().peak_hot_bytes;
        assert_eq!(peak, 2 * RF * 4);
        s.take(1).unwrap();
        s.take(2).unwrap();
        let o = s.occupancy();
        assert_eq!(o.hot_bytes, 0);
        assert_eq!(o.peak_hot_bytes, peak);
    }

    #[test]
    fn positions_iterates_residents() {
        let mut s = TieredStore::new(RF, cfg());
        for p in [4usize, 1, 9] {
            s.stash(p, row(RF, p as f32), 0, 1).unwrap();
        }
        let mut ps: Vec<usize> = s.positions().collect();
        ps.sort_unstable();
        assert_eq!(ps, vec![1, 4, 9]);
    }

    #[test]
    fn recover_readopts_spilled_rows_and_restash_supersedes() {
        use crate::config::ShardPartition;
        use crate::offload::spill::{SpillManifest, SpillTier};
        use crate::util::TempDir;

        let dir = TempDir::new("store-recover").unwrap();
        let d = dir.path_str();
        let mut c = cfg();
        c.cold_budget_bytes = 1; // everything cold spills to disk
        c.spill_dir = Some(d.clone());
        c.spill_persist = true;

        // first life: two rows spilled, then an ungraceful drop
        {
            let m = SpillManifest::attach(&d, RF, 1, ShardPartition::Hash).unwrap();
            let spill = SpillTier::open_persistent(&d, RF, 0, m.generation).unwrap();
            let mut s = TieredStore::with_spill(RF, c.clone(), spill);
            s.stash(3, row(RF, 3.0), 0, 100).unwrap();
            s.stash(5, row(RF, 5.0), 0, 100).unwrap();
            assert_eq!(s.occupancy().spill_rows, 2);
        }

        // second life: re-attach and recover
        let m = SpillManifest::attach(&d, RF, 1, ShardPartition::Hash).unwrap();
        let spill = SpillTier::open_persistent(&d, RF, 0, m.generation).unwrap();
        let mut s = TieredStore::with_spill(RF, c, spill);
        assert_eq!(s.recover(0).unwrap(), 2);
        assert_eq!(s.recovered_rows, 2);
        assert_eq!(s.recovery_errors(), 0);
        assert_eq!(s.tier_of(3), Some((TierKind::Spill, false)));

        // a recovered row restores within the quantization bound
        let back = s.take(5).unwrap().unwrap();
        let orig = row(RF, 5.0);
        let bound = cfg().cold_quant_rel_error * (0.01 * (RF - 1) as f32) + 1e-5;
        for (a, b) in orig.iter().zip(&back) {
            assert!((a - b).abs() <= bound, "{a} vs {b}");
        }

        // re-freezing a recovered position supersedes the stale copy
        // instead of erroring as a double-freeze
        s.stash(3, row(RF, 30.0), 0, 1).unwrap();
        assert_eq!(s.take(3).unwrap(), Some(row(RF, 30.0)), "fresh copy wins");
        assert!(s.is_empty());
        // conservation spans the restart: 2 recovered + 1 stashed ==
        // 2 restored + 1 superseded-drop + 0 resident
        assert_eq!(
            s.total_stashed,
            s.total_restored + s.total_dropped + s.len() as u64
        );
    }

    #[test]
    fn pressure_staging_skips_recovered_orphans() {
        use crate::config::ShardPartition;
        use crate::offload::spill::{SpillManifest, SpillTier};
        use crate::util::TempDir;

        let dir = TempDir::new("store-recover-stage").unwrap();
        let d = dir.path_str();
        let mut c = cfg();
        c.cold_budget_bytes = 1; // everything cold spills to disk
        c.spill_dir = Some(d.clone());
        c.spill_persist = true;
        {
            let m = SpillManifest::attach(&d, RF, 1, ShardPartition::Hash).unwrap();
            let spill = SpillTier::open_persistent(&d, RF, 0, m.generation).unwrap();
            let mut s = TieredStore::with_spill(RF, c.clone(), spill);
            s.stash(3, row(RF, 3.0), 0, 100).unwrap();
        }
        let m = SpillManifest::attach(&d, RF, 1, ShardPartition::Hash).unwrap();
        let spill = SpillTier::open_persistent(&d, RF, 0, m.generation).unwrap();
        let mut s = TieredStore::with_spill(RF, c, spill);
        s.recover(0).unwrap();
        // a live spilled row the policy predicts back at step 100
        s.stash(10, row(RF, 10.0), 0, 100).unwrap();
        // pressure sweep near the live row's thaw: both rows are "due"
        // (recovered eta = 8, live eta = 100, limit = 103), but only
        // the live row may promote — speculation must not evict a
        // recovered orphan's only durable copy
        assert_eq!(s.stage_upcoming(95, 8, 8).unwrap(), 1);
        assert_eq!(s.tier_of(10), Some((TierKind::Hot, true)));
        assert_eq!(
            s.tier_of(3),
            Some((TierKind::Spill, false)),
            "recovered orphan must stay on disk through pressure staging"
        );
        // the orphan is still restorable the ordinary way
        assert!(s.take(3).unwrap().is_some());
    }

    #[test]
    fn peek_decode_is_non_destructive_and_matches_take() {
        let mut s = TieredStore::new(RF, cfg());
        s.stash(1, row(RF, 1.0), 0, 100).unwrap(); // cold
        s.stash(2, row(RF, 2.0), 0, 2).unwrap(); // hot
        let before = s.occupancy();
        let peek1 = s.peek_decode(1).unwrap().unwrap();
        let peek2 = s.peek_decode(2).unwrap().unwrap();
        assert_eq!(s.occupancy(), before, "peek must not move bytes or rows");
        assert_eq!(s.total_restored, 0);
        assert_eq!(s.staged_misses, 0);
        assert_eq!(s.peek_decode(99).unwrap(), None);
        // a later real take returns exactly the peeked bits (the
        // payload-stability invariant the speculative pipeline needs)
        assert_eq!(s.take(1).unwrap(), Some(peek1));
        assert_eq!(s.take(2).unwrap(), Some(peek2));
    }

    #[test]
    fn confirm_restore_bookkeeps_like_take() {
        let mut a = TieredStore::new(RF, cfg());
        let mut b = TieredStore::new(RF, cfg());
        for s in [&mut a, &mut b] {
            s.stash(1, row(RF, 1.0), 0, 100).unwrap(); // cold
            s.stash(2, row(RF, 2.0), 0, 2).unwrap(); // hot
            s.stage(&[(1, 2)]).unwrap(); // promote 1 -> staged hot
        }
        a.take(1).unwrap().unwrap();
        a.take(2).unwrap().unwrap();
        b.confirm_restore(1).unwrap();
        b.confirm_restore(2).unwrap();
        assert_eq!(a.total_restored, b.total_restored);
        assert_eq!(a.staged_hits, b.staged_hits);
        assert_eq!(a.staged_misses, b.staged_misses);
        assert_eq!(a.len(), b.len());
        assert_eq!(a.bytes(), b.bytes());
        assert!(b.confirm_restore(1).is_err(), "double confirm must error");
    }

    #[test]
    fn spec_candidates_mirror_stage_upcoming() {
        let mut s = TieredStore::new(RF, cfg());
        s.stash(1, row(RF, 1.0), 0, 20).unwrap();
        s.stash(2, row(RF, 2.0), 0, 12).unwrap();
        s.stash(3, row(RF, 3.0), 0, 50).unwrap();
        // horizon clamps to cold_after (8): limit 18 covers only pos 2
        let c = s.spec_candidates(10, 100, 8);
        assert_eq!(c, vec![(2, 12)]);
        // read-only: asking again returns the same set
        assert_eq!(s.spec_candidates(10, 100, 8), c);
        assert!(s.promote_speculative(2).unwrap());
        assert_eq!(s.tier_of(2), Some((TierKind::Hot, true)));
        assert!(s.spec_candidates(10, 100, 8).is_empty(), "promoted row leaves the frozen queue");
    }

    #[test]
    fn ladder_picks_rung_by_thaw_distance_and_accounts_bytes() {
        use crate::offload::codec::CodecLadder;
        use crate::offload::quant;

        let mut c = cfg();
        c.codec_ladder = CodecLadder::parse("0:u8,64:u4").unwrap();
        let mut s = TieredStore::new(RF, c);
        s.stash(1, row(RF, 1.0), 0, 20).unwrap(); // distance 20 -> u8
        s.stash(2, row(RF, 2.0), 0, 100).unwrap(); // distance 100 -> u4
        assert_eq!(s.occupancy().cold_rows, 2);
        let cold = s.cold.codec_rows();
        assert_eq!(cold[CodecId::U8.index()], 1);
        assert_eq!(cold[CodecId::U4.index()], 1);
        // admission accounting: the u4 rung must pull cold bytes/row
        // below the u8 baseline
        assert_eq!(s.tier_rows_stored[1], 2);
        let u8_bytes = (RF + quant::ROW_HEADER_BYTES) as u64;
        assert!(
            s.tier_row_bytes_stored[1] < 2 * u8_bytes,
            "u4 rung must shrink cold bytes/row ({} vs u8 baseline {})",
            s.tier_row_bytes_stored[1],
            2 * u8_bytes
        );
        // a u4 restore comes back within the rung's error bound and is
        // attributed to the rung that served it
        let back = s.take(2).unwrap().unwrap();
        let range = 0.01 * (RF - 1) as f32;
        let bound = range / 30.0 + 1e-5;
        for (a, b) in row(RF, 2.0).iter().zip(&back) {
            assert!((a - b).abs() <= bound, "{a} vs {b}");
        }
        assert_eq!(s.codec_encode_us[CodecId::U4.index()].count(), 1);
        assert_eq!(s.codec_decode_us[CodecId::U4.index()].count(), 1);
    }

    #[test]
    fn sched_depth_tracks_frozen_queue() {
        let mut s = TieredStore::new(RF, cfg());
        for p in 0..4 {
            s.stash(p, row(RF, p as f32), 0, 100).unwrap(); // all cold
        }
        s.on_step(1).unwrap();
        assert_eq!(s.sched_depth.count(), 1);
        assert_eq!(s.sched_depth.max(), 4);
    }
}
