//! Position-sharded tiered storage: N independent [`TieredStore`]
//! shards behind a facade that keeps the engine's single-store API.
//!
//! The paper's soft freeze keeps every frozen token recoverable, so an
//! entropy-triggered recovery late in a long session can demand a
//! large restore burst inside one decode step — the retrieval
//! bottleneck FreeKV (arXiv 2505.13109) attacks with parallelized KV
//! recall. Here the burst parallelizes across shards:
//!
//! ```text
//!                    take_batch(sorted positions)
//!                               │
//!              coalesce_runs ──► split_runs (shard boundaries)
//!                               │
//!        ┌──────────────────────┼──────────────────────┐
//!        ▼                      ▼                      ▼
//!   worker 0               worker 1               worker N-1
//!   TieredStore shard      TieredStore shard      TieredStore shard
//!   (own eta scheduler,    (own tiers + budget    (own spill file)
//!    1/N budget slice)      slice)
//!        └──────────────────────┼──────────────────────┘
//!                               ▼
//!                join (input order restored) -> decode step
//! ```
//!
//! * **Partitioning** is positional ([`ShardPartition`]): `Hash`
//!   (`pos % n`) spreads any burst across all shards; `Range`
//!   (block-cyclic over `block_rows` chunks) keeps span copies
//!   shard-contiguous. Plans already carry sorted position runs, so
//!   the shard split is a run split (`engine::layout::split_runs`).
//! * **Budgets**: each shard gets a `OffloadConfig::partitioned`
//!   slice of the per-tier byte budgets (remainder bytes spread across
//!   the leading shards; a hot slice below one row is rejected here,
//!   where the row size is known).
//! * **Execution**: a small process-wide persistent worker pool (std
//!   threads + channels, matching the coordinator architecture —
//!   tokio is unavailable offline), shared by every store so request
//!   churn never spawns threads. Shard stores are *moved* into job
//!   messages and handed back on a per-burst reply channel, so between
//!   bursts the facade answers every query without synchronization.
//!   `on_step`, `stage_upcoming`, and budget eviction (inside each
//!   shard's `stash`/`on_step`) fan out the same way.
//! * **Codecs**: each shard runs the same `offload::codec` ladder
//!   (config is cloned per slice), so codec-tagged payloads and the
//!   per-rung `asrkf_codec_rows` gauges aggregate cleanly across
//!   shards — a row's rung is decided by its own thaw distance, never
//!   by which shard holds it.
//! * **Telemetry**: shards engaged per restore burst
//!   ([`ShardedStore::restore_parallelism`]), a burst-imbalance
//!   counter, and per-shard occupancy gauges, all surfaced through
//!   [`OffloadSummary`] and the server JSON.
//! * **Supervision**: a shard whose op panics (on a pool worker or
//!   inline) is not poisoned forever — the facade rebuilds it from its
//!   slice of the persistent spill directory, recovering every row
//!   with a verified spilled copy and declaring the rest as a typed
//!   per-position loss set ([`Error::RowsLost`]). Takes of a declared
//!   lost position fail with that error — never a silent `None` and
//!   never wrong bytes — until a fresh stash supersedes the loss. See
//!   the *Failure model* section of the module README.
//!
//! `shards = 1` degenerates to exactly the single-store behavior (no
//! worker pool, every call inline) — property-tested against an
//! unsharded `TieredStore` oracle in `tests/prop_offload.rs`.

use std::collections::{BTreeSet, HashMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::config::{OffloadConfig, ShardPartition};
use crate::engine::layout::{coalesce_runs, split_runs};
use crate::error::{Error, Result};
use crate::metrics::{
    Cause, CountHistogram, FlightEvent, FlightRecorder, Histogram, RestoreLatency, Snapshot,
    SnapshotBuilder, TierKind, TierOccupancy,
};
use crate::offload::store::TieredStore;
use crate::offload::OffloadSummary;

/// Upper bound on the shard count (each shard may pin a worker thread
/// and a spill file; the CLI rejects larger `--shards` values).
pub const MAX_SHARDS: usize = 64;

/// One storage operation executed on a single shard, either inline or
/// on a pool worker. Variants mirror the `TieredStore` calls the
/// engine batches per step.
enum ShardOp {
    /// `(pos, row, thaw_eta)` triples stashed at `step`.
    Stash { items: Vec<(usize, Vec<f32>, u64)>, step: u64 },
    Take(Vec<usize>),
    Stage(Vec<(usize, u64)>),
    StageUpcoming { now: u64, horizon: u64, max_rows: usize },
    OnStep(u64),
    Drain,
    /// Speculative restore reads: promote + decode each `(pos, gen)`
    /// without consuming anything, returning generation-tagged copies.
    /// `delay_us` is test-only fault injection (slow-tier simulation).
    SpecRead { items: Vec<(usize, u64)>, delay_us: u64 },
}

enum ShardOut {
    Unit,
    Rows(Vec<(usize, Option<Vec<f32>>)>),
    Staged(usize),
    Drained(Vec<(usize, Vec<f32>)>),
    /// `(pos, generation, decoded row)` per speculative read, plus the
    /// in-worker service time — the tier latency the pipeline hid
    /// behind decode.
    Spec { rows: Vec<(usize, u64, Option<Vec<f32>>)>, service_us: u64 },
}

/// The single execution path for both the inline (n = 1 / one engaged
/// shard) and worker-pool branches, so they cannot drift.
fn exec(store: &mut TieredStore, op: ShardOp) -> Result<ShardOut> {
    // fault-injection hook at the worker boundary, *before* the op
    // touches the store: an injected panic therefore provably mutated
    // nothing, which is what lets the supervisor rebuild the shard
    // from its spill file without wondering about half-applied ops
    store.fault().worker_op();
    match op {
        ShardOp::Stash { items, step } => {
            for (pos, row, eta) in items {
                store.stash(pos, row, step, eta)?;
            }
            Ok(ShardOut::Unit)
        }
        ShardOp::Take(positions) => {
            let mut rows = Vec::with_capacity(positions.len());
            for pos in positions {
                rows.push((pos, store.take(pos)?));
            }
            Ok(ShardOut::Rows(rows))
        }
        ShardOp::Stage(hints) => Ok(ShardOut::Staged(store.stage(&hints)?)),
        ShardOp::StageUpcoming { now, horizon, max_rows } => {
            Ok(ShardOut::Staged(store.stage_upcoming(now, horizon, max_rows)?))
        }
        ShardOp::OnStep(now) => {
            store.on_step(now)?;
            Ok(ShardOut::Unit)
        }
        ShardOp::Drain => Ok(ShardOut::Drained(store.drain_all()?)),
        ShardOp::SpecRead { items, delay_us } => {
            let t0 = Instant::now();
            let mut rows = Vec::with_capacity(items.len());
            for (pos, gen) in items {
                if delay_us > 0 {
                    std::thread::sleep(Duration::from_micros(delay_us));
                }
                // same promotion as the synchronous prefetch path, so
                // tier residency converges with what staged reads
                // would have produced
                let _ = store.promote_speculative(pos)?;
                rows.push((pos, gen, store.peek_decode(pos)?));
            }
            Ok(ShardOut::Spec { rows, service_us: t0.elapsed().as_micros() as u64 })
        }
    }
}

struct Job {
    shard: usize,
    store: TieredStore,
    op: ShardOp,
    /// Per-burst reply channel: each `fan_out` call joins only its own
    /// responses, so concurrent sessions share one pool safely.
    reply: Sender<Done>,
}

struct Done {
    shard: usize,
    /// `None` when the op panicked: the store's invariants can no
    /// longer be trusted, so the shard is marked lost instead of being
    /// reinstalled in a corrupt state.
    store: Option<TieredStore>,
    out: Result<ShardOut>,
}

/// Process-wide persistent worker pool, shared by every `ShardedStore`
/// (spawning per session would churn N OS threads on each request
/// admission/retirement). Workers own nothing between bursts — each
/// job carries its shard's store by value and hands it back on the
/// job's reply channel. `exec` runs under `catch_unwind`, so a buggy
/// op can never strand a burst: the worker always replies (with the
/// shard marked lost on panic) and survives to serve the next job.
struct WorkerPool {
    /// Mutex-wrapped for `Sync` on the crate's 1.70 MSRV (`Sender`
    /// itself is only `Sync` from Rust 1.72); bursts lock once to
    /// clone a handle, never across sends.
    jobs: Mutex<Sender<Job>>,
}

fn worker_pool() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .clamp(2, MAX_SHARDS);
        let (jobs, job_rx) = channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        for w in 0..workers {
            let rx = Arc::clone(&job_rx);
            // thread spawn failure here is unrecoverable setup, and the
            // pool is created once per process: propagate the panic
            std::thread::Builder::new()
                .name(format!("asrkf-shard-{w}"))
                .spawn(move || loop {
                    // hold the queue lock only for the dequeue, never
                    // across the storage work
                    let job = match rx.lock() {
                        Ok(guard) => match guard.recv() {
                            Ok(j) => j,
                            Err(_) => return, // process shutdown
                        },
                        Err(_) => return,
                    };
                    let Job { shard, mut store, op, reply } = job;
                    let done = match catch_unwind(AssertUnwindSafe(|| exec(&mut store, op))) {
                        Ok(out) => Done { shard, store: Some(store), out },
                        Err(_) => Done {
                            shard,
                            store: None,
                            out: Err(Error::Offload(format!(
                                "shard {shard} op panicked on a pool worker"
                            ))),
                        },
                    };
                    // a receiver gone before the reply means the burst
                    // already failed; drop the result and keep serving
                    let _ = reply.send(done);
                })
                .expect("failed to spawn shard worker thread");
        }
        WorkerPool { jobs: Mutex::new(jobs) }
    })
}

/// A speculative read job outstanding on one shard. The shard's store
/// is out with the worker; `items` holds the `(pos, gen, eta)` triples
/// shipped with it, kept facade-side for in-flight bookkeeping and
/// flight-event stamping at landing time.
struct PendingSpec {
    reply: Receiver<Done>,
    items: Vec<(usize, u64, u64)>,
}

/// A shard's monotone flow counters as of its last reinstall. The
/// facade keeps one per shard so that when a worker panic destroys a
/// store (the unwind drops it, counters and all), the dead life's
/// history can still be folded into the facade totals — injected
/// panics fire before the op mutates anything, so the cached values
/// are exact at the moment of loss.
#[derive(Clone, Copy, Default)]
struct ShardFlows {
    stashed: u64,
    restored: u64,
    dropped: u64,
}

fn flows_of(s: &TieredStore) -> ShardFlows {
    ShardFlows { stashed: s.total_stashed, restored: s.total_restored, dropped: s.total_dropped }
}

/// A decoded speculative copy waiting in the landing buffer for its
/// consuming take. Valid by construction: every mutation of the
/// position fences (discards) it first, so presence implies
/// bit-exactness with what a synchronous take would return.
struct LandedSpec {
    row: Vec<f32>,
    /// Step (`pipeline_advance` clock) the copy landed; the deadline
    /// bounds how long an unconsumed copy may linger.
    landed_step: u64,
}

/// N independent `TieredStore` shards behind the single-store API the
/// engine already speaks, plus batched entry points (`take_batch`,
/// `stash_batch`) that execute per-shard slices in parallel.
pub struct ShardedStore {
    cfg: OffloadConfig,
    n: usize,
    partition: ShardPartition,
    /// `Range` partition chunk width (== `cfg.block_rows`).
    chunk: usize,
    /// Row size in floats (identical across shards); kept so budget
    /// re-slices can validate the per-shard one-row floor up front.
    row_floats: usize,
    /// `None` only transiently while a shard is out with a worker or
    /// between a mid-burst panic and the supervisor's rebuild
    /// (`rebuild_shard`). A slot stays `None` only if the rebuild
    /// itself failed; every touch then reports `Error::Offload`
    /// instead of panicking.
    shards: Vec<Option<TieredStore>>,
    /// Shards engaged per restore burst — `max() > 1` is restore
    /// parallelism actually happening.
    pub restore_parallelism: CountHistogram,
    /// Restore bursts where one shard carried at least twice the even
    /// share (`rows / n`) — sustained growth means the partition
    /// scheme fights the access pattern.
    pub shard_imbalance: u64,
    /// One outstanding speculative job per shard (`None` = shard home).
    /// A shard with a pending entry has its `shards` slot checked out;
    /// `ensure_home` is the only way back.
    pending: Vec<Option<PendingSpec>>,
    /// Generation fence per position, present only while the position
    /// is in flight or landed (bounded by the speculation window, not
    /// by context length). A mutation bumps the generation so a stale
    /// landing is discarded instead of resurrecting old payload.
    spec_gen: HashMap<usize, u64>,
    /// Positions currently out on a speculative read (pos -> gen).
    inflight: HashMap<usize, u64>,
    /// Landing buffer: decoded copies waiting for their consuming take.
    landed: HashMap<usize, LandedSpec>,
    /// Blocked-on-`recv` wall time since the session last drained it
    /// (`take_wait_us`), charged to the `restore_wait` step segment.
    wait_us_acc: u64,
    /// Same wall time, but reset every `pipeline_advance` — flushed as
    /// one per-step sample into `wait_hist` (zeros included, so the
    /// distribution honestly covers wait-free steps).
    step_wait_us: u64,
    wait_hist: Histogram,
    /// In-worker service time of speculative jobs — the latency that
    /// ran overlapped with decode instead of blocking it.
    overlap_hist: Histogram,
    /// Shards with a speculative read in flight, sampled per advance.
    inflight_depth: CountHistogram,
    pub spec_issued: u64,
    pub spec_landed: u64,
    pub spec_cancelled: u64,
    pub spec_consumed: u64,
    /// Takes that had to block on a still-in-flight speculative read.
    pub late_arrivals: u64,
    /// Facade-level flight recorder for speculation lifecycle events
    /// (issue/land/cancel) — per-shard recorders keep tier moves.
    spec_flight: FlightRecorder,
    /// Last step handed to `pipeline_advance` / `on_step`, used to
    /// stamp facade flight events between advances and to age the
    /// post-rebuild degraded window.
    last_step: u64,
    /// Facade shadow of each shard's resident position set, updated on
    /// every successful op (and re-derived from the store on the rare
    /// partial-error path, while the store is provably home). When a
    /// worker panic destroys a store, this is the only record of what
    /// it held — the rebuild diffs it against the recovered rows to
    /// produce the declared-lost set.
    resident: Vec<HashSet<usize>>,
    /// Flow counters per shard as of its last reinstall (see
    /// [`ShardFlows`]).
    flows_cache: Vec<ShardFlows>,
    /// Flow history of dead shard lives, folded in at rebuild so the
    /// facade totals (and the conservation identity) survive the loss.
    carried: ShardFlows,
    /// Positions declared lost by shard rebuilds and not yet
    /// superseded by a fresh stash. Takes of these fail with
    /// [`Error::RowsLost`]; a stash or drop clears the entry.
    lost: BTreeSet<usize>,
    /// Monotone count of rows ever declared lost (the conservation
    /// term: `stashed == restored + dropped + lost + resident`).
    rows_lost: u64,
    /// Shard rebuilds completed by the supervisor.
    shard_rebuilds: u64,
    /// Step each shard was last rebuilt at (`None` = never); drives
    /// the temporary admission-capacity discount.
    rebuilt_at: Vec<Option<u64>>,
}

impl std::fmt::Debug for ShardedStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardedStore")
            .field("shards", &self.n)
            .field("partition", &self.partition)
            .field("rows", &self.len())
            .finish()
    }
}

impl ShardedStore {
    /// Build `cfg.shards` shards, each with a `partitioned` slice of
    /// the byte budgets. Rejects configurations whose per-shard hot
    /// budget cannot hold a single row (the slice would demote every
    /// stash instantly); the `quantize_cold = false` escape hatch is
    /// exempt since budgets are advisory there.
    ///
    /// With `cfg.spill_persist` set (and a spill dir configured), this
    /// is a **fresh attach**: the directory's manifest is validated
    /// and its generation bumped, and leftover records from a previous
    /// life are reclaimed — never resurrected into a store that does
    /// not resume that life. Use [`ShardedStore::resume`] to recover
    /// them instead.
    pub fn new(row_floats: usize, cfg: OffloadConfig) -> Result<ShardedStore> {
        ShardedStore::build(row_floats, cfg, false)
    }

    /// Re-attach to a persistent spill directory and **recover** every
    /// surviving record: each shard scans its record file, adopts the
    /// rows that verify (magic, unfenced generation, checksum), and
    /// re-registers them with its eta scheduler under a conservative
    /// thaw eta. Without `spill_persist` this is identical to
    /// [`ShardedStore::new`]. Recovery telemetry lands in
    /// `OffloadSummary::{recovered_rows, recovery_errors}`.
    pub fn resume(row_floats: usize, cfg: OffloadConfig) -> Result<ShardedStore> {
        ShardedStore::build(row_floats, cfg, true)
    }

    fn build(row_floats: usize, cfg: OffloadConfig, resume: bool) -> Result<ShardedStore> {
        use crate::offload::spill::{SpillManifest, SpillTier};
        let n = cfg.shards.clamp(1, MAX_SHARDS);
        let row_bytes = row_floats * std::mem::size_of::<f32>();
        let persist_dir = if cfg.spill_persist { cfg.spill_dir.as_deref() } else { None };
        // the manifest claims the directory (generation bump) before
        // any shard opens its record file
        let manifest = match persist_dir {
            Some(dir) => {
                Some(SpillManifest::attach(dir, row_floats, n, cfg.shard_partition)?)
            }
            None => None,
        };
        let mut shards = Vec::with_capacity(n);
        for i in 0..n {
            let scfg = cfg.partitioned(n, i);
            if scfg.quantize_cold && scfg.hot_budget_bytes < row_bytes {
                return Err(Error::Offload(format!(
                    "hot budget {} B splits to {} B for shard {i}/{n} — below one {row_bytes}-B \
                     row; raise the hot budget or lower the shard count",
                    cfg.hot_budget_bytes, scfg.hot_budget_bytes
                )));
            }
            let store = match (&manifest, persist_dir) {
                (Some(m), Some(dir)) => {
                    let mut spill =
                        SpillTier::open_persistent(dir, row_floats, i, m.generation)?;
                    if resume {
                        let mut st = TieredStore::with_spill(row_floats, scfg, spill);
                        st.recover(0)?;
                        st
                    } else {
                        spill.reclaim_recovered()?;
                        TieredStore::with_spill(row_floats, scfg, spill)
                    }
                }
                _ => TieredStore::new(row_floats, scfg),
            };
            shards.push(Some(store));
        }
        if n > 1 || cfg.pipeline {
            worker_pool(); // warm the process-wide pool off the hot path
        }
        // seed the supervisor's shadow state from the freshly built
        // stores (non-empty only on a recovering resume)
        let resident: Vec<HashSet<usize>> = shards
            .iter()
            .map(|s| s.as_ref().map(|s| s.positions().collect()).unwrap_or_default())
            .collect();
        let flows_cache: Vec<ShardFlows> =
            shards.iter().map(|s| s.as_ref().map(flows_of).unwrap_or_default()).collect();
        let spec_flight = FlightRecorder::new(cfg.flight_recorder_cap);
        Ok(ShardedStore {
            n,
            partition: cfg.shard_partition,
            chunk: cfg.block_rows.max(1),
            row_floats,
            shards,
            cfg,
            restore_parallelism: CountHistogram::default(),
            shard_imbalance: 0,
            pending: (0..n).map(|_| None).collect(),
            spec_gen: HashMap::new(),
            inflight: HashMap::new(),
            landed: HashMap::new(),
            wait_us_acc: 0,
            step_wait_us: 0,
            wait_hist: Histogram::default(),
            overlap_hist: Histogram::default(),
            inflight_depth: CountHistogram::default(),
            spec_issued: 0,
            spec_landed: 0,
            spec_cancelled: 0,
            spec_consumed: 0,
            late_arrivals: 0,
            spec_flight,
            last_step: 0,
            resident,
            flows_cache,
            carried: ShardFlows::default(),
            lost: BTreeSet::new(),
            rows_lost: 0,
            shard_rebuilds: 0,
            rebuilt_at: (0..n).map(|_| None).collect(),
        })
    }

    /// The combined (unsplit) configuration — per-step knobs like
    /// `prefetch_ahead` and `stage_pressure` are shard-invariant.
    pub fn config(&self) -> &OffloadConfig {
        &self.cfg
    }

    pub fn shard_count(&self) -> usize {
        self.n
    }

    /// Adopt a re-sliced total budget between steps (continuous-batching
    /// budget reflow): settle outstanding speculative work, re-split the
    /// new totals across shards with the same `partitioned` math as
    /// construction, and forward each slice to its shard (a shrink
    /// demotes immediately, a grow leaves headroom). Every per-shard
    /// slice is validated against the one-row floor *before* any shard
    /// is mutated, so a rejected reflow leaves all budgets unchanged.
    pub fn set_budgets(&mut self, hot_budget_bytes: usize, cold_budget_bytes: usize) -> Result<()> {
        self.settle()?;
        let row_bytes = self.row_floats * std::mem::size_of::<f32>();
        let next = OffloadConfig { hot_budget_bytes, cold_budget_bytes, ..self.cfg.clone() };
        for i in 0..self.n {
            let scfg = next.partitioned(self.n, i);
            if scfg.quantize_cold && scfg.hot_budget_bytes < row_bytes {
                return Err(Error::Offload(format!(
                    "hot budget re-slice {hot_budget_bytes} B splits to {} B for shard {i}/{} — \
                     below one {row_bytes}-B row",
                    scfg.hot_budget_bytes, self.n
                )));
            }
        }
        for i in 0..self.n {
            let scfg = next.partitioned(self.n, i);
            self.shard_mut(i)?.set_budgets(scfg.hot_budget_bytes, scfg.cold_budget_bytes)?;
        }
        self.cfg = next;
        Ok(())
    }

    /// The shard owning `pos` under the configured partition.
    pub fn shard_of(&self, pos: usize) -> usize {
        match self.partition {
            ShardPartition::Hash => pos % self.n,
            ShardPartition::Range => (pos / self.chunk) % self.n,
        }
    }

    fn shard_mut(&mut self, idx: usize) -> Result<&mut TieredStore> {
        self.shards[idx]
            .as_mut()
            .ok_or_else(|| Error::Offload(format!("shard {idx} lost to a worker failure")))
    }

    fn live_shards(&self) -> impl Iterator<Item = &TieredStore> {
        self.shards.iter().flatten()
    }

    // --- shard supervision ---
    //
    // Per-shard state machine:
    //
    //   live ──op panic──► lost ──rebuild (spill recover)──► live
    //                        │            │
    //                  (rebuild fails)    └─► rows without a spilled
    //                        ▼                copy join the declared-
    //                  lost forever           lost set (typed error
    //                  (every touch errors)   on take, cleared by a
    //                                         fresh stash)
    //
    // An injected panic fires at `exec` entry, before the op touches
    // the store, so a lost store's shadow state (resident set + flow
    // counters, refreshed at every reinstall) is exact at the moment
    // of loss. The rebuild re-attaches the spill manifest (generation
    // bump, so the dead life's records verify as recoverable), adopts
    // every surviving record through the same `TieredStore::recover`
    // path a process restart uses, and diffs the shadow resident set
    // against the recovered rows to produce the loss set.

    /// Cache shard `idx`'s flow counters (cheap: three u64 reads).
    fn flows_refresh(&mut self, idx: usize) {
        if let Some(s) = self.shards[idx].as_ref() {
            self.flows_cache[idx] = flows_of(s);
        }
    }

    /// Re-derive shard `idx`'s shadow resident set from the store
    /// itself — used on error paths where an op may have partially
    /// applied before failing. The store is home there, so it is
    /// authoritative; no-op while the shard is lost.
    fn shadow_resync(&mut self, idx: usize) {
        if let Some(s) = self.shards[idx].as_ref() {
            self.resident[idx] = s.positions().collect();
        }
        self.flows_refresh(idx);
    }

    /// Fold a successful op's membership effects into shard `idx`'s
    /// shadow resident set. `stash_pos` carries the positions each
    /// `ShardOp::Stash` shipped (captured before dispatch, since the
    /// op itself is consumed by the worker).
    fn shadow_apply(
        &mut self,
        idx: usize,
        out: &ShardOut,
        stash_pos: &mut HashMap<usize, Vec<usize>>,
    ) {
        match out {
            ShardOut::Unit => {
                // Stash (inserts) or OnStep (tier moves only — absent
                // from the map, so the loop body never runs for it)
                if let Some(ps) = stash_pos.remove(&idx) {
                    for pos in ps {
                        self.lost.remove(&pos);
                        self.resident[idx].insert(pos);
                    }
                }
            }
            ShardOut::Rows(rows) => {
                for (pos, payload) in rows {
                    if payload.is_some() {
                        self.resident[idx].remove(pos);
                    }
                }
            }
            ShardOut::Drained(_) => self.resident[idx].clear(),
            // staging and speculative reads move rows between tiers
            // without changing membership
            ShardOut::Staged(_) | ShardOut::Spec { .. } => {}
        }
        self.flows_refresh(idx);
    }

    /// Respawn a shard lost to an op panic. With persistent spill the
    /// shard's record file is re-opened under a bumped manifest
    /// generation and every verifying record is adopted back via
    /// [`TieredStore::recover`]; rows that lived only in the dead
    /// store's hot/cold tiers are declared lost. Ephemeral-spill and
    /// memory-only stores recover nothing — every resident row is
    /// declared lost — but the shard still comes back empty and
    /// usable. Returns `Err` (shard stays lost) only if the rebuild's
    /// own I/O fails.
    fn rebuild_shard(&mut self, idx: usize, ctx: &str) -> Result<()> {
        use crate::offload::spill::{SpillManifest, SpillTier};
        // landed copies cached rows of a store that no longer exists
        let stale: Vec<usize> =
            self.landed.keys().copied().filter(|&p| self.shard_of(p) == idx).collect();
        for pos in stale {
            self.landed.remove(&pos);
            self.spec_gen.remove(&pos);
            self.spec_cancelled += 1;
            self.spec_flight.record(self.last_step, pos, None, None, Cause::SpecCancel, 0);
        }
        let scfg = self.cfg.partitioned(self.n, idx);
        let store = match (self.cfg.spill_persist, self.cfg.spill_dir.as_deref()) {
            (true, Some(dir)) => {
                // the re-attach bumps the generation, so records
                // written by the lost life verify as recoverable
                // instead of being fenced as a concurrent writer's
                let m = SpillManifest::attach(dir, self.row_floats, self.n, self.partition)?;
                let spill = SpillTier::open_persistent(dir, self.row_floats, idx, m.generation)?;
                let mut st = TieredStore::with_spill(self.row_floats, scfg, spill);
                st.recover(self.last_step)?;
                st
            }
            _ => TieredStore::new(self.row_floats, scfg),
        };
        let was = std::mem::take(&mut self.resident[idx]);
        let recovered: HashSet<usize> = store.positions().collect();
        let lost_now: Vec<usize> = {
            let mut v: Vec<usize> = was.difference(&recovered).copied().collect();
            v.sort_unstable();
            v
        };
        self.rows_lost += lost_now.len() as u64;
        self.lost.extend(lost_now.iter().copied());
        // fold the dead life's flows into the carried totals; its
        // recovered rows are re-counted as stashes of the new life
        // (recover() counts them), so subtract them here to keep
        // `stashed == restored + dropped + lost + resident` exact
        let dead = self.flows_cache[idx];
        self.carried.stashed += dead.stashed.saturating_sub(store.total_stashed);
        self.carried.restored += dead.restored;
        self.carried.dropped += dead.dropped;
        self.resident[idx] = recovered;
        self.shards[idx] = Some(store);
        self.flows_refresh(idx);
        self.shard_rebuilds += 1;
        self.rebuilt_at[idx] = Some(self.last_step);
        log::warn!(
            "shard {idx} rebuilt after {ctx}: {} row(s) recovered from spill, {} declared lost",
            self.resident[idx].len(),
            lost_now.len()
        );
        Ok(())
    }

    /// Rebuild every shard in `lost`, logging (not propagating) a
    /// rebuild failure — the burst's own error already describes the
    /// panic, and a shard whose rebuild failed keeps reporting on
    /// every touch.
    fn rebuild_lost(&mut self, lost: Vec<usize>, ctx: &str) {
        for idx in lost {
            if let Err(e) = self.rebuild_shard(idx, ctx) {
                log::error!("shard {idx} rebuild failed; shard stays lost: {e}");
            }
        }
    }

    /// Execute one op per engaged shard — inline when unsharded or
    /// only one shard has work, otherwise fanned out to the shared
    /// worker pool and joined before returning. The first shard error
    /// wins, but only after every returned store has been reinstalled.
    fn fan_out(&mut self, ops: Vec<(usize, ShardOp)>) -> Result<Vec<(usize, ShardOut)>> {
        if ops.is_empty() {
            return Ok(Vec::new());
        }
        // safety net: a shard out on a speculative read must land
        // before new work ships (idempotent; entry points settle the
        // shards they touch explicitly first, for fence ordering)
        for i in 0..ops.len() {
            self.ensure_home(ops[i].0)?;
        }
        // positions each Stash op will insert, captured facade-side so
        // the shadow resident set can be updated after the op (which
        // the worker consumes) succeeds
        let mut stash_pos: HashMap<usize, Vec<usize>> = HashMap::new();
        for (idx, op) in &ops {
            if let ShardOp::Stash { items, .. } = op {
                stash_pos.insert(*idx, items.iter().map(|it| it.0).collect());
            }
        }
        if self.n == 1 || ops.len() == 1 {
            let mut outs = Vec::with_capacity(ops.len());
            let mut first_err = None;
            let mut lost: Vec<usize> = Vec::new();
            for (idx, op) in ops {
                // supervise the inline path exactly like a pool
                // worker: a panicking op loses the shard, which is
                // then rebuilt from its spill file below
                let res = {
                    let store = self.shard_mut(idx)?;
                    catch_unwind(AssertUnwindSafe(|| exec(store, op)))
                };
                match res {
                    Ok(Ok(o)) => {
                        self.shadow_apply(idx, &o, &mut stash_pos);
                        outs.push((idx, o));
                    }
                    Ok(Err(e)) => {
                        // the op may have partially applied; the store
                        // is home, so re-derive its shadow from it
                        self.shadow_resync(idx);
                        first_err = first_err.or(Some(e));
                    }
                    Err(_) => {
                        // the store's invariants can no longer be
                        // trusted; drop it and rebuild from spill
                        self.shards[idx] = None;
                        lost.push(idx);
                        first_err = first_err
                            .or(Some(Error::Offload(format!("shard {idx} op panicked"))));
                    }
                }
            }
            self.rebuild_lost(lost, "an inline op panic");
            return match first_err {
                Some(e) => Err(e),
                None => Ok(outs),
            };
        }
        // a poisoned pool mutex only means some thread panicked while
        // *cloning a Sender* — the channel itself is untouched, so
        // recover the guard instead of failing every future burst
        let jobs = worker_pool().jobs.lock().unwrap_or_else(|p| p.into_inner()).clone();
        let (reply_tx, reply_rx) = channel::<Done>();
        let mut in_flight = 0usize;
        for (idx, op) in ops {
            let store = self.shards[idx]
                .take()
                .ok_or_else(|| Error::Offload(format!("shard {idx} lost to a worker failure")))?;
            let job = Job { shard: idx, store, op, reply: reply_tx.clone() };
            if let Err(std::sync::mpsc::SendError(job)) = jobs.send(job) {
                self.shards[job.shard] = Some(job.store);
                return Err(Error::Offload("shard worker pool is down".into()));
            }
            in_flight += 1;
        }
        // drop the local sender so the join loop can only block on
        // workers that actually hold one of this burst's jobs
        drop(reply_tx);
        let mut outs = Vec::with_capacity(in_flight);
        let mut first_err = None;
        let mut lost: Vec<usize> = Vec::new();
        for _ in 0..in_flight {
            match reply_rx.recv() {
                Ok(Done { shard, store, out }) => {
                    // a panicked op hands back no store: the shard is
                    // marked lost here and rebuilt after the join
                    let panicked = store.is_none();
                    self.shards[shard] = store;
                    if panicked {
                        lost.push(shard);
                    }
                    match out {
                        Ok(o) => {
                            self.shadow_apply(shard, &o, &mut stash_pos);
                            outs.push((shard, o));
                        }
                        Err(e) => {
                            self.shadow_resync(shard);
                            first_err = first_err.or(Some(e));
                        }
                    }
                }
                Err(_) => return Err(Error::Offload("shard worker died mid-burst".into())),
            }
        }
        self.rebuild_lost(lost, "a mid-burst worker panic");
        match first_err {
            Some(e) => Err(e),
            None => Ok(outs),
        }
    }

    /// Group `(key_of(item) -> shard)` items into per-shard op inputs.
    fn group_by_shard<T>(
        &self,
        items: impl IntoIterator<Item = T>,
        pos_of: impl Fn(&T) -> usize,
    ) -> Vec<Vec<T>> {
        let mut per: Vec<Vec<T>> = (0..self.n).map(|_| Vec::new()).collect();
        for it in items {
            per[self.shard_of(pos_of(&it))].push(it);
        }
        per
    }

    // --- speculative restore pipeline ---
    //
    // In-flight state machine (per position):
    //
    //   idle ──issue──► in-flight ──land──► landed ──take──► consumed
    //                      │                   │
    //                 (job error /        (fence on mutation,
    //                  stale gen)          deadline expiry, drain)
    //                      ▼                   ▼
    //                  cancelled           cancelled
    //
    // A shard with a job out has its store checked out (`shards[idx] =
    // None`), exactly like a `fan_out` burst — so a speculative read
    // can never race the facade's own tier mutations. Every entry
    // point settles the shards it touches (`ensure_home`) before
    // mutating, and `on_step` settles all shards so residency sweeps
    // (which include the *lossy* hot -> cold demotion) are never
    // deferred: a job therefore lives at most one step.

    /// Block until shard `idx`'s outstanding speculative job (if any)
    /// replies, reinstall its store, and process the landings. Blocked
    /// time is charged to the wait accumulators the session surfaces
    /// as the `restore_wait` step segment.
    fn ensure_home(&mut self, idx: usize) -> Result<()> {
        let Some(p) = self.pending[idx].take() else { return Ok(()) };
        let t0 = Instant::now();
        let timeout_ms = self.cfg.restore_wait_timeout_ms;
        let recvd = if timeout_ms == 0 {
            p.reply.recv().map_err(|_| ())
        } else {
            // bounded wait: a take that beats its speculative read by
            // more than the budget fails typed instead of blocking
            // forever on a dead or delayed shard reply
            match p.reply.recv_timeout(Duration::from_millis(timeout_ms)) {
                Ok(done) => Ok(done),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    let waited = t0.elapsed().as_micros() as u64;
                    self.wait_us_acc += waited;
                    self.step_wait_us += waited;
                    for &(pos, _, eta) in &p.items {
                        self.spec_flight.record(
                            self.last_step,
                            pos,
                            None,
                            None,
                            Cause::RestoreTimeout,
                            eta,
                        );
                    }
                    // the job may still land: keep it pending so a
                    // later settle (or Drop) can reclaim the store
                    self.pending[idx] = Some(p);
                    return Err(Error::Offload(format!(
                        "shard {idx} restore wait exceeded {timeout_ms} ms"
                    )));
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => Err(()),
            }
        };
        match recvd {
            Ok(done) => {
                let waited = t0.elapsed().as_micros() as u64;
                self.wait_us_acc += waited;
                self.step_wait_us += waited;
                self.land(idx, p, done);
                Ok(())
            }
            Err(()) => {
                for &(pos, _, _) in &p.items {
                    self.inflight.remove(&pos);
                    self.spec_gen.remove(&pos);
                }
                Err(Error::Offload(format!("shard {idx} speculative worker died mid-flight")))
            }
        }
    }

    /// Process one returned speculative job: reinstall the store,
    /// clear the in-flight set, and move current-generation rows into
    /// the landing buffer. Worker-side op errors are logged and
    /// swallowed — the speculative copy is a pure cache, so the
    /// eventual real take surfaces any real tier failure.
    fn land(&mut self, idx: usize, p: PendingSpec, done: Done) {
        let panicked = done.store.is_none();
        self.shards[idx] = done.store; // None on panic: rebuilt below
        for &(pos, _, _) in &p.items {
            self.inflight.remove(&pos);
        }
        match done.out {
            Ok(ShardOut::Spec { rows, service_us }) => {
                self.overlap_hist.record(Duration::from_micros(service_us));
                for (pos, gen, row) in rows {
                    let eta = p
                        .items
                        .iter()
                        .find(|&&(q, _, _)| q == pos)
                        .map(|&(_, _, e)| e)
                        .unwrap_or(0);
                    let valid = self.spec_gen.get(&pos).copied() == Some(gen);
                    match row {
                        Some(row) if valid => {
                            self.spec_landed += 1;
                            self.spec_flight
                                .record(self.last_step, pos, None, None, Cause::SpecLand, eta);
                            self.landed
                                .insert(pos, LandedSpec { row, landed_step: self.last_step });
                        }
                        _ => {
                            // superseded generation, or a row dropped
                            // before the worker could read it
                            self.spec_cancelled += 1;
                            self.spec_flight
                                .record(self.last_step, pos, None, None, Cause::SpecCancel, eta);
                            if !self.landed.contains_key(&pos) {
                                self.spec_gen.remove(&pos);
                            }
                        }
                    }
                }
            }
            Ok(_) => {
                log::error!("shard {idx} speculative job returned a non-speculative result")
            }
            Err(e) => {
                log::warn!(
                    "shard {idx} speculative read failed (the real take will retry inline): {e}"
                );
                for &(pos, _, _) in &p.items {
                    if !self.landed.contains_key(&pos) {
                        self.spec_gen.remove(&pos);
                    }
                }
                self.spec_cancelled += p.items.len() as u64;
            }
        }
        if panicked {
            self.rebuild_lost(vec![idx], "a speculative worker panic");
        } else {
            // spec reads never change membership, but they do promote
            // tiers; keep the flow cache fresh for the next loss
            self.flows_refresh(idx);
        }
    }

    /// Generation fence, called before any mutation of `pos` (stash /
    /// take / drop / drain). Discards a landed copy — it is never
    /// served across a mutation — and clears the recorded generation
    /// so a later speculation starts fresh. The owning shard must be
    /// home (`ensure_home`) before fencing, which makes an in-flight
    /// fence structurally impossible; the generation bump below is
    /// insurance, not a load-bearing path.
    fn fence(&mut self, pos: usize) {
        if !self.cfg.pipeline {
            return;
        }
        debug_assert!(
            !self.inflight.contains_key(&pos),
            "fence of in-flight pos {pos}: owning shard was not settled first"
        );
        if self.landed.remove(&pos).is_some() {
            self.spec_cancelled += 1;
            self.spec_flight.record(self.last_step, pos, None, None, Cause::SpecCancel, 0);
        }
        if self.inflight.contains_key(&pos) {
            if let Some(g) = self.spec_gen.get_mut(&pos) {
                *g += 1;
            }
        } else {
            self.spec_gen.remove(&pos);
        }
    }

    /// Ship one speculative read job to the worker pool. The shard's
    /// store travels with the job (same checkout discipline as
    /// `fan_out`); until it lands, `ensure_home` is the only way back.
    fn issue(&mut self, idx: usize, items: Vec<(usize, u64, u64)>, now: u64) -> Result<()> {
        // see fan_out: a poisoned guard still wraps a healthy Sender
        let jobs = worker_pool().jobs.lock().unwrap_or_else(|p| p.into_inner()).clone();
        let store = self.shards[idx]
            .take()
            .ok_or_else(|| Error::Offload(format!("shard {idx} lost to a worker failure")))?;
        let (reply_tx, reply_rx) = channel::<Done>();
        let op_items: Vec<(usize, u64)> = items.iter().map(|&(pos, gen, _)| (pos, gen)).collect();
        let job = Job {
            shard: idx,
            store,
            op: ShardOp::SpecRead { items: op_items, delay_us: self.cfg.pipeline_test_delay_us },
            reply: reply_tx,
        };
        if let Err(std::sync::mpsc::SendError(job)) = jobs.send(job) {
            self.shards[job.shard] = Some(job.store);
            return Err(Error::Offload("shard worker pool is down".into()));
        }
        for &(pos, gen, eta) in &items {
            self.inflight.insert(pos, gen);
            self.spec_issued += 1;
            self.spec_flight.record(now, pos, None, None, Cause::SpecIssue, eta);
        }
        self.pending[idx] = Some(PendingSpec { reply: reply_rx, items });
        Ok(())
    }

    /// The per-step pipeline driver, called once per decode step after
    /// the residency sweep: land completed jobs without blocking,
    /// expire unconsumed landed copies past the deadline, and issue
    /// fresh speculative reads for rows the eta index says are due to
    /// thaw within the prefetch horizon. The reads execute on pool
    /// workers while the next step computes; `take_batch` then serves
    /// the landed copies with a map lookup instead of a tier decode.
    pub fn pipeline_advance(&mut self, now: u64) -> Result<()> {
        if !self.cfg.pipeline {
            return Ok(());
        }
        self.last_step = now;
        // 1) land whatever completed, without blocking on stragglers
        for idx in 0..self.n {
            if let Some(p) = self.pending[idx].take() {
                match p.reply.try_recv() {
                    Ok(done) => self.land(idx, p, done),
                    Err(TryRecvError::Empty) => self.pending[idx] = Some(p),
                    Err(TryRecvError::Disconnected) => {
                        for &(pos, _, _) in &p.items {
                            self.inflight.remove(&pos);
                            self.spec_gen.remove(&pos);
                        }
                        return Err(Error::Offload(format!(
                            "shard {idx} speculative worker died mid-flight"
                        )));
                    }
                }
            }
        }
        // 2) expire landed copies nobody consumed within the deadline
        // (0 = keep forever; the CLI bounds the flag to >= 1)
        let deadline = self.cfg.restore_deadline_steps;
        if deadline > 0 {
            let expired: Vec<usize> = self
                .landed
                .iter()
                .filter(|(_, l)| l.landed_step.saturating_add(deadline) <= now)
                .map(|(&pos, _)| pos)
                .collect();
            for pos in expired {
                self.landed.remove(&pos);
                self.spec_gen.remove(&pos);
                self.spec_cancelled += 1;
                self.spec_flight.record(now, pos, None, None, Cause::SpecCancel, 0);
            }
        }
        // 3) issue fresh speculative reads on idle shards
        let per_cap = (self.cfg.stage_burst_rows + self.n - 1) / self.n;
        let horizon = self.cfg.prefetch_ahead;
        for idx in 0..self.n {
            if self.pending[idx].is_some() {
                continue;
            }
            let cands = match self.shards[idx].as_ref() {
                Some(s) => s.spec_candidates(now, horizon, per_cap),
                None => continue, // lost shard: every touch errors elsewhere
            };
            let mut items: Vec<(usize, u64, u64)> = Vec::with_capacity(cands.len());
            for (pos, eta) in cands {
                if self.landed.contains_key(&pos) || self.inflight.contains_key(&pos) {
                    continue;
                }
                let gen = *self.spec_gen.entry(pos).or_insert(0);
                items.push((pos, gen, eta));
            }
            if !items.is_empty() {
                self.issue(idx, items, now)?;
            }
        }
        let depth = self.pending.iter().filter(|p| p.is_some()).count() as u64;
        self.inflight_depth.record(depth);
        // 4) flush this step's blocked-wait total as one sample (zeros
        // included, so the distribution covers wait-free steps)
        self.wait_hist.record(Duration::from_micros(self.step_wait_us));
        self.step_wait_us = 0;
        Ok(())
    }

    /// Land every outstanding speculative job, blocking as needed.
    /// Required before aggregate `&self` queries (`len`, `occupancy`,
    /// counters, flight events) can see a complete picture — a shard
    /// out with a worker is invisible to them.
    pub fn settle(&mut self) -> Result<()> {
        for idx in 0..self.n {
            self.ensure_home(idx)?;
        }
        Ok(())
    }

    /// Drain the accumulated blocked-on-landing wall time (µs) since
    /// the last call. The session carves this out of whichever step
    /// segment the wait occurred inside.
    pub fn take_wait_us(&mut self) -> u64 {
        std::mem::take(&mut self.wait_us_acc)
    }

    /// Whether `pos` has speculation state (in flight or landed) — a
    /// prefetch hint for it would be redundant.
    pub fn spec_busy(&self, pos: usize) -> bool {
        self.inflight.contains_key(&pos) || self.landed.contains_key(&pos)
    }

    /// Whether `pos` is already staged hot (or conservatively assumed
    /// so while its owning shard is out on a speculative job).
    pub fn is_staged(&self, pos: usize) -> bool {
        if self.pending[self.shard_of(pos)].is_some() {
            return true;
        }
        self.tier_of(pos) == Some((TierKind::Hot, true))
    }

    // --- single-row API (unchanged semantics, routed to one shard) ---

    pub fn stash(&mut self, pos: usize, row: Vec<f32>, step: u64, thaw_eta: u64) -> Result<()> {
        let idx = self.shard_of(pos);
        self.ensure_home(idx)?;
        self.fence(pos);
        match self.shard_mut(idx)?.stash(pos, row, step, thaw_eta) {
            Ok(()) => {
                // a fresh stash supersedes any declared loss of pos
                self.lost.remove(&pos);
                self.resident[idx].insert(pos);
                self.flows_refresh(idx);
                Ok(())
            }
            Err(e) => {
                self.shadow_resync(idx);
                Err(e)
            }
        }
    }

    pub fn take(&mut self, pos: usize) -> Result<Option<Vec<f32>>> {
        let idx = self.shard_of(pos);
        if self.lost.contains(&pos) {
            return Err(Error::RowsLost(vec![pos]));
        }
        if self.inflight.contains_key(&pos) {
            self.late_arrivals += 1;
        }
        self.ensure_home(idx)?;
        if let Some(l) = self.landed.remove(&pos) {
            // take-equivalent bookkeeping, but the payload comes from
            // the landing buffer instead of a tier decode
            self.shard_mut(idx)?.confirm_restore(pos)?;
            self.spec_gen.remove(&pos);
            self.spec_consumed += 1;
            self.resident[idx].remove(&pos);
            self.flows_refresh(idx);
            return Ok(Some(l.row));
        }
        match self.shard_mut(idx)?.take(pos) {
            Ok(payload) => {
                if payload.is_some() {
                    self.resident[idx].remove(&pos);
                }
                self.flows_refresh(idx);
                Ok(payload)
            }
            Err(e) => {
                self.shadow_resync(idx);
                Err(e)
            }
        }
    }

    pub fn drop_row(&mut self, pos: usize) -> Result<()> {
        // dropping a declared-lost row is trivially complete: the data
        // is already gone and already accounted under `rows_lost`
        if self.lost.remove(&pos) {
            return Ok(());
        }
        let idx = self.shard_of(pos);
        self.ensure_home(idx)?;
        self.fence(pos);
        match self.shard_mut(idx)?.drop_row(pos) {
            Ok(()) => {
                self.resident[idx].remove(&pos);
                self.flows_refresh(idx);
                Ok(())
            }
            Err(e) => {
                self.shadow_resync(idx);
                Err(e)
            }
        }
    }

    // --- batched API (the parallel data path) ---

    /// Stash a freeze batch: items are grouped by shard and executed in
    /// parallel (each shard applies its own budget eviction inside).
    pub fn stash_batch(&mut self, items: Vec<(usize, Vec<f32>, u64)>, step: u64) -> Result<()> {
        if self.cfg.pipeline {
            for it in &items {
                self.ensure_home(self.shard_of(it.0))?;
                self.fence(it.0);
            }
        }
        let per = self.group_by_shard(items, |it| it.0);
        let ops: Vec<(usize, ShardOp)> = per
            .into_iter()
            .enumerate()
            .filter(|(_, v)| !v.is_empty())
            .map(|(i, v)| (i, ShardOp::Stash { items: v, step }))
            .collect();
        self.fan_out(ops)?;
        Ok(())
    }

    /// Restore a batch: split the positions' coalesced runs at shard
    /// boundaries, take each slice on its shard in parallel, and return
    /// payloads in input order (`None` where nothing was stashed).
    /// `positions` must be strictly ascending (a normalized plan list).
    pub fn take_batch(&mut self, positions: &[usize]) -> Result<Vec<Option<Vec<f32>>>> {
        if positions.is_empty() {
            return Ok(Vec::new());
        }
        // declared-lost positions fail the batch typed up front — a
        // silent None would decode garbage where real data once was
        if !self.lost.is_empty() {
            let hit: Vec<usize> =
                positions.iter().copied().filter(|p| self.lost.contains(p)).collect();
            if !hit.is_empty() {
                return Err(Error::RowsLost(hit));
            }
        }
        // pipeline consume path: count takes that beat their
        // speculative read (before settling hides the evidence), land
        // the owning shards, then serve whatever the landing buffer
        // holds — take-equivalent bookkeeping, no tier decode
        let mut served: HashMap<usize, Vec<f32>> = HashMap::new();
        if self.cfg.pipeline {
            for &pos in positions {
                if self.inflight.contains_key(&pos) {
                    self.late_arrivals += 1;
                }
            }
            for &pos in positions {
                let idx = self.shard_of(pos);
                self.ensure_home(idx)?;
                if let Some(l) = self.landed.remove(&pos) {
                    self.shard_mut(idx)?.confirm_restore(pos)?;
                    self.spec_gen.remove(&pos);
                    self.spec_consumed += 1;
                    self.resident[idx].remove(&pos);
                    self.flows_refresh(idx);
                    served.insert(pos, l.row);
                }
            }
        }
        let rest: Vec<usize> =
            positions.iter().copied().filter(|p| !served.contains_key(p)).collect();
        let mut by_pos: HashMap<usize, Option<Vec<f32>>> = HashMap::with_capacity(rest.len());
        if self.n == 1 {
            // unsharded fast path: no run split, no reassembly map
            if !rest.is_empty() {
                self.restore_parallelism.record(1);
                let mut err = None;
                {
                    let store = self.shard_mut(0)?;
                    for &pos in &rest {
                        match store.take(pos) {
                            Ok(payload) => {
                                by_pos.insert(pos, payload);
                            }
                            Err(e) => {
                                err = Some(e);
                                break;
                            }
                        }
                    }
                }
                if let Some(e) = err {
                    // takes before the failure still consumed rows
                    self.shadow_resync(0);
                    return Err(e);
                }
                for (pos, payload) in &by_pos {
                    if payload.is_some() {
                        self.resident[0].remove(pos);
                    }
                }
                self.flows_refresh(0);
            }
        } else if !rest.is_empty() {
            let runs = coalesce_runs(&rest);
            let per = split_runs(&runs, self.n, |p| self.shard_of(p));
            let engaged = per.iter().filter(|v| !v.is_empty()).count();
            self.restore_parallelism.record(engaged as u64);
            if rest.len() >= 2 {
                let max_share = per.iter().map(Vec::len).max().unwrap_or(0);
                // imbalanced: one shard carried at least twice the even
                // share len/n (ratio form so n = 2 can fire: an
                // all-on-one burst is exactly 2x the even share, never
                // more). The max_share >= 2 guard keeps single-row
                // shares of tiny bursts from counting.
                if max_share >= 2 && max_share * self.n >= 2 * rest.len() {
                    self.shard_imbalance += 1;
                }
            }
            let ops: Vec<(usize, ShardOp)> = per
                .into_iter()
                .enumerate()
                .filter(|(_, v)| !v.is_empty())
                .map(|(i, v)| (i, ShardOp::Take(v)))
                .collect();
            let outs = self.fan_out(ops)?;
            for (_, out) in outs {
                if let ShardOut::Rows(rows) = out {
                    for (pos, payload) in rows {
                        by_pos.insert(pos, payload);
                    }
                }
            }
        }
        Ok(positions
            .iter()
            .map(|p| match served.remove(p) {
                Some(row) => Some(row),
                None => by_pos.remove(p).flatten(),
            })
            .collect())
    }

    /// Stage specific prefetch hints; fans out when hints span shards.
    /// No fence: staging is payload-preserving (promotion only ever
    /// sources quantized rows), so a landed copy stays bit-exact.
    pub fn stage(&mut self, hints: &[(usize, u64)]) -> Result<usize> {
        if self.cfg.pipeline {
            for &(pos, _) in hints {
                self.ensure_home(self.shard_of(pos))?;
            }
        }
        let per = self.group_by_shard(hints.iter().copied(), |h| h.0);
        let ops: Vec<(usize, ShardOp)> = per
            .into_iter()
            .enumerate()
            .filter(|(_, v)| !v.is_empty())
            .map(|(i, v)| (i, ShardOp::Stage(v)))
            .collect();
        let outs = self.fan_out(ops)?;
        Ok(outs
            .into_iter()
            .map(|(_, o)| if let ShardOut::Staged(k) = o { k } else { 0 })
            .sum())
    }

    /// Entropy-pressure staging sweep across all shards. The global row
    /// cap is split as `ceil(max_rows / n)` per shard: each shard
    /// promotes its own soonest-first slice, so up to `n - 1` extra
    /// rows may stage versus an unsharded soonest-first pick — an
    /// accepted approximation (staging is speculative work).
    pub fn stage_upcoming(&mut self, now: u64, horizon: u64, max_rows: usize) -> Result<usize> {
        if max_rows == 0 {
            return Ok(0);
        }
        self.settle()?;
        let per_cap = (max_rows + self.n - 1) / self.n;
        let ops: Vec<(usize, ShardOp)> = (0..self.n)
            .map(|i| (i, ShardOp::StageUpcoming { now, horizon, max_rows: per_cap }))
            .collect();
        let outs = self.fan_out(ops)?;
        Ok(outs
            .into_iter()
            .map(|(_, o)| if let ShardOut::Staged(k) = o { k } else { 0 })
            .sum())
    }

    /// Per-step residency sweep. Most steps demote nothing, so each
    /// shard is probed first (`TieredStore::sweep_pending`, an O(log n)
    /// index peek) and only shards with real demotion work — per-row
    /// quantization — are dispatched to the pool; idle shards run the
    /// no-op sweep inline, keeping pool round-trips off the common
    /// per-step path.
    pub fn on_step(&mut self, now: u64) -> Result<()> {
        // settle first: residency sweeps include the *lossy*
        // hot -> cold demotion, which must never be deferred behind a
        // speculative job (a delayed demotion would let a pipelined
        // take return raw payload where a synchronous store would
        // already serve the quantized form)
        self.settle()?;
        // keep the facade step clock moving even without the pipeline:
        // it stamps flight events and ages the post-rebuild window
        self.last_step = self.last_step.max(now);
        let mut ops: Vec<(usize, ShardOp)> = Vec::new();
        for i in 0..self.n {
            let pending = self.shards[i]
                .as_ref()
                .ok_or_else(|| Error::Offload(format!("shard {i} lost to a worker failure")))?
                .sweep_pending(now);
            if pending {
                ops.push((i, ShardOp::OnStep(now)));
            } else {
                self.shard_mut(i)?.on_step(now)?;
            }
        }
        self.fan_out(ops)?;
        Ok(())
    }

    /// Drain every shard (RR emergency restore). Order across shards is
    /// arbitrary, matching the unsharded store's hash-map drain.
    pub fn drain_all(&mut self) -> Result<Vec<(usize, Vec<f32>)>> {
        self.settle()?;
        // the landing buffer only caches rows the tiers still hold —
        // discard it so the drain is the single source of payloads
        let cached: Vec<usize> = self.landed.keys().copied().collect();
        for pos in cached {
            self.fence(pos);
        }
        let ops: Vec<(usize, ShardOp)> = (0..self.n).map(|i| (i, ShardOp::Drain)).collect();
        let outs = self.fan_out(ops)?;
        let mut all = Vec::new();
        for (_, out) in outs {
            if let ShardOut::Drained(rows) = out {
                all.extend(rows);
            }
        }
        Ok(all)
    }

    // --- queries and aggregates ---

    pub fn contains(&self, pos: usize) -> bool {
        self.shards[self.shard_of(pos)]
            .as_ref()
            .map(|s| s.contains(pos))
            .unwrap_or(false)
    }

    pub fn tier_of(&self, pos: usize) -> Option<(TierKind, bool)> {
        self.shards[self.shard_of(pos)].as_ref().and_then(|s| s.tier_of(pos))
    }

    pub fn len(&self) -> usize {
        self.live_shards().map(TieredStore::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> usize {
        self.live_shards().map(TieredStore::bytes).sum()
    }

    pub fn positions(&self) -> impl Iterator<Item = usize> + '_ {
        self.live_shards().flat_map(|s| s.positions())
    }

    pub fn total_stashed(&self) -> u64 {
        self.carried.stashed + self.live_shards().map(|s| s.total_stashed).sum::<u64>()
    }

    pub fn total_restored(&self) -> u64 {
        self.carried.restored + self.live_shards().map(|s| s.total_restored).sum::<u64>()
    }

    pub fn total_dropped(&self) -> u64 {
        self.carried.dropped + self.live_shards().map(|s| s.total_dropped).sum::<u64>()
    }

    /// Rows ever declared lost by shard rebuilds — the fourth term of
    /// the conservation identity
    /// `stashed == restored + dropped + lost + resident`.
    pub fn rows_lost_total(&self) -> u64 {
        self.rows_lost
    }

    /// Shard rebuilds completed by the supervisor.
    pub fn shard_rebuilds(&self) -> u64 {
        self.shard_rebuilds
    }

    /// Positions currently declared lost (sorted ascending). A take of
    /// any of these fails with [`Error::RowsLost`]; a fresh stash or a
    /// drop clears the entry.
    pub fn lost_rows(&self) -> Vec<usize> {
        self.lost.iter().copied().collect()
    }

    /// Shards currently lost, or rebuilt within the last
    /// `cold_after_steps` steps — capacity the admission controller
    /// should temporarily discount while the rebuilt shard re-warms.
    pub fn degraded_shards(&self) -> usize {
        let window = self.cfg.cold_after_steps.max(1);
        self.shards
            .iter()
            .enumerate()
            .filter(|(i, s)| {
                s.is_none()
                    || self.rebuilt_at[*i]
                        .is_some_and(|t| self.last_step < t.saturating_add(window))
            })
            .count()
    }

    pub fn staged_hits(&self) -> u64 {
        self.live_shards().map(|s| s.staged_hits).sum()
    }

    pub fn staged_misses(&self) -> u64 {
        self.live_shards().map(|s| s.staged_misses).sum()
    }

    /// Per-tier restore-latency histograms merged across shards.
    pub fn restore_latency(&self) -> RestoreLatency {
        let mut merged = RestoreLatency::default();
        for s in self.live_shards() {
            merged.merge(&s.restore_latency);
        }
        merged
    }

    /// Combined occupancy. Peak gauges sum the per-shard high-water
    /// marks — an upper bound on the true concurrent peak (shards may
    /// peak at different steps), which is the conservative direction
    /// for a memory gauge.
    pub fn occupancy(&self) -> TierOccupancy {
        let mut o = TierOccupancy::default();
        for s in self.live_shards() {
            let so = s.occupancy();
            o.hot_rows += so.hot_rows;
            o.hot_bytes += so.hot_bytes;
            o.cold_rows += so.cold_rows;
            o.cold_bytes += so.cold_bytes;
            o.spill_rows += so.spill_rows;
            o.spill_bytes += so.spill_bytes;
            o.peak_hot_bytes += so.peak_hot_bytes;
            o.peak_cold_bytes += so.peak_cold_bytes;
            o.peak_spill_bytes += so.peak_spill_bytes;
            o.uncompressed_bytes += so.uncompressed_bytes;
        }
        o
    }

    /// Per-shard occupancy gauges, shard-indexed (lost shards report
    /// empty) — the imbalance view behind `shard_rows_min/max`.
    pub fn shard_occupancy(&self) -> Vec<TierOccupancy> {
        self.shards
            .iter()
            .map(|s| s.as_ref().map(|s| s.occupancy()).unwrap_or_default())
            .collect()
    }

    /// Publish monotone flow metrics (counters + latency histograms)
    /// from every live shard into `b` under its real shard index, plus
    /// the facade's own burst telemetry. Safe to accumulate repeatedly
    /// into a long-lived registry (e.g. at session retirement) because
    /// every series here only ever grows.
    pub fn publish_flows(&self, b: &mut SnapshotBuilder) {
        for (i, sh) in self.shards.iter().enumerate() {
            if let Some(s) = sh {
                s.publish_flows(b, i);
            }
        }
        b.counter_add("asrkf_shard_imbalance_total", &[], self.shard_imbalance);
        b.count_merge("asrkf_restore_parallelism", &[], &self.restore_parallelism);
        b.counter_add("asrkf_spec_issued_total", &[], self.spec_issued);
        b.counter_add("asrkf_spec_landed_total", &[], self.spec_landed);
        b.counter_add("asrkf_spec_cancelled_total", &[], self.spec_cancelled);
        b.counter_add("asrkf_spec_consumed_total", &[], self.spec_consumed);
        b.counter_add("asrkf_late_arrivals_total", &[], self.late_arrivals);
        b.counter_add("asrkf_shard_rebuilds_total", &[], self.shard_rebuilds);
        b.counter_add("asrkf_rows_lost_total", &[], self.rows_lost);
        b.time_merge("asrkf_restore_overlap_us", &[], &self.overlap_hist);
        b.time_merge("asrkf_restore_wait_us", &[], &self.wait_hist);
        b.count_merge("asrkf_spec_inflight_depth", &[], &self.inflight_depth);
    }

    /// Publish point-in-time occupancy gauges per shard. Lost shards
    /// still publish a zero `asrkf_shard_rows` gauge so the min/max
    /// imbalance view keeps the same denominator.
    pub fn publish_gauges(&self, b: &mut SnapshotBuilder) {
        for (i, sh) in self.shards.iter().enumerate() {
            let idx = i.to_string();
            match sh {
                Some(s) => s.publish_gauges(b, i),
                None => b.gauge_set("asrkf_shard_rows", &[("shard", idx.as_str())], 0.0),
            }
        }
        b.gauge_set("asrkf_shards", &[], self.n as f64);
    }

    /// Flows + gauges in one pass (a full per-store snapshot).
    pub fn publish(&self, b: &mut SnapshotBuilder) {
        self.publish_flows(b);
        self.publish_gauges(b);
    }

    /// A registry snapshot covering only this store — the source of
    /// truth behind [`ShardedStore::summary`] and the server stats
    /// plane's per-request view.
    pub fn snapshot(&self) -> Snapshot {
        let mut b = SnapshotBuilder::default();
        self.publish(&mut b);
        b.finish()
    }

    /// Merged counters + occupancy + sharding telemetry for responses
    /// and bench CSVs — a flat view over [`ShardedStore::snapshot`].
    pub fn summary(&self) -> OffloadSummary {
        OffloadSummary::from_snapshot(&self.snapshot())
    }

    /// Every shard's flight-recorder events tagged with the shard
    /// index, merged into one global timeline ordered by capture time
    /// (ties broken by per-shard sequence number).
    pub fn flight_events(&self) -> Vec<(usize, FlightEvent)> {
        let mut all: Vec<(usize, FlightEvent)> = Vec::new();
        for (i, sh) in self.shards.iter().enumerate() {
            if let Some(s) = sh {
                all.extend(s.flight().events().map(|ev| (i, *ev)));
            }
        }
        // facade-level speculation lifecycle events, tagged with the
        // owning shard so the timeline stays shard-addressable
        all.extend(self.spec_flight.events().map(|ev| (self.shard_of(ev.pos), *ev)));
        all.sort_by_key(|(_, ev)| (ev.ts_us, ev.seq));
        all
    }

    /// Total flight events evicted or rejected across shards (ring
    /// wraparound plus `flight_recorder_cap = 0` suppression).
    pub fn flight_dropped(&self) -> u64 {
        self.live_shards().map(|s| s.flight().dropped()).sum::<u64>() + self.spec_flight.dropped()
    }
}

impl Drop for ShardedStore {
    /// Reclaim shards still out on speculative jobs so their stores
    /// (and any `TempDir`-backed spill files) drop on this thread, not
    /// on a detached pool worker after the directory is gone.
    fn drop(&mut self) {
        for p in self.pending.iter_mut() {
            if let Some(p) = p.take() {
                if let Ok(done) = p.reply.recv() {
                    if let Some(store) = done.store {
                        drop(store);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const RF: usize = 16;

    fn cfg(n: usize, partition: ShardPartition) -> OffloadConfig {
        OffloadConfig {
            hot_budget_bytes: 1 << 20,
            cold_budget_bytes: 1 << 20,
            cold_after_steps: 8,
            block_rows: 4,
            shards: n,
            shard_partition: partition,
            ..OffloadConfig::default()
        }
    }

    fn row(v: f32) -> Vec<f32> {
        (0..RF).map(|i| v + i as f32 * 0.01).collect()
    }

    #[test]
    fn partitions_route_positions_to_expected_shards() {
        let s = ShardedStore::new(RF, cfg(4, ShardPartition::Hash)).unwrap();
        assert_eq!(s.shard_of(0), 0);
        assert_eq!(s.shard_of(7), 3);
        let r = ShardedStore::new(RF, cfg(4, ShardPartition::Range)).unwrap();
        // block_rows = 4: positions 0..4 -> shard 0, 4..8 -> shard 1, ...
        assert_eq!(r.shard_of(3), 0);
        assert_eq!(r.shard_of(4), 1);
        assert_eq!(r.shard_of(16), 0, "block-cyclic wraps");
    }

    #[test]
    fn set_budgets_resplits_across_shards_and_validates_floor() {
        let mut s = ShardedStore::new(RF, cfg(2, ShardPartition::Hash)).unwrap();
        for pos in 0..6 {
            s.stash(pos, row(pos as f32), 0, 2).unwrap(); // near eta -> hot
        }
        assert_eq!(s.occupancy().hot_rows, 6);
        // shrink to one hot row per shard: each shard demotes down to
        // its slice of the new total
        let row_bytes = RF * std::mem::size_of::<f32>();
        s.set_budgets(2 * row_bytes, 1 << 20).unwrap();
        let o = s.occupancy();
        assert_eq!(o.hot_rows, 2, "one row per shard survives the shrink");
        assert_eq!(o.hot_rows + o.cold_rows, 6, "no rows dropped");
        assert_eq!(s.config().hot_budget_bytes, 2 * row_bytes);
        // a total whose per-shard slice is below one row is rejected
        // before any shard is touched
        let err = s.set_budgets(2 * row_bytes - 1, 1 << 20).unwrap_err();
        assert!(format!("{err}").contains("below one"));
        assert_eq!(s.config().hot_budget_bytes, 2 * row_bytes, "budgets unchanged on reject");
        // growing back restores hot admission
        s.set_budgets(1 << 20, 1 << 20).unwrap();
        s.stash(100, row(1.0), 1, 3).unwrap();
        assert_eq!(s.occupancy().hot_rows, 3);
    }

    #[test]
    fn batched_roundtrip_crosses_shards_in_input_order() {
        for partition in [ShardPartition::Hash, ShardPartition::Range] {
            for n in [1usize, 2, 4] {
                let mut s = ShardedStore::new(RF, cfg(n, partition)).unwrap();
                let positions: Vec<usize> = (0..13).collect();
                let items: Vec<(usize, Vec<f32>, u64)> =
                    positions.iter().map(|&p| (p, row(p as f32), 2)).collect();
                s.stash_batch(items, 0).unwrap();
                assert_eq!(s.len(), 13);
                assert_eq!(s.total_stashed(), 13);
                let got = s.take_batch(&positions).unwrap();
                for (i, payload) in got.iter().enumerate() {
                    assert_eq!(payload.as_ref().unwrap(), &row(i as f32), "pos {i} (n={n})");
                }
                assert!(s.is_empty());
                assert_eq!(s.total_restored(), 13);
                if n > 1 {
                    assert!(
                        s.restore_parallelism.max() > 1,
                        "13-row burst must engage multiple shards (n={n})"
                    );
                }
            }
        }
    }

    #[test]
    fn take_batch_reports_absent_positions_as_none() {
        let mut s = ShardedStore::new(RF, cfg(2, ShardPartition::Hash)).unwrap();
        s.stash(1, row(1.0), 0, 2).unwrap();
        let got = s.take_batch(&[0, 1, 2]).unwrap();
        assert!(got[0].is_none());
        assert!(got[1].is_some());
        assert!(got[2].is_none());
    }

    #[test]
    fn summary_aggregates_across_shards() {
        let mut s = ShardedStore::new(RF, cfg(4, ShardPartition::Hash)).unwrap();
        for p in 0..8 {
            s.stash(p, row(p as f32), 0, 100).unwrap(); // all cold
        }
        let sum = s.summary();
        assert_eq!(sum.shards, 4);
        assert_eq!(sum.occupancy.cold_rows, 8);
        assert_eq!(sum.shard_rows_min, 2);
        assert_eq!(sum.shard_rows_max, 2);
        // stage everything, then restore: hits counted across shards
        assert_eq!(s.stage_upcoming(99, 8, 64).unwrap(), 8);
        let positions: Vec<usize> = (0..8).collect();
        let got = s.take_batch(&positions).unwrap();
        assert!(got.iter().all(Option::is_some));
        assert_eq!(s.summary().staged_hits, 8);
        assert_eq!(s.summary().restore_parallelism_max, 4);
    }

    #[test]
    fn range_partition_imbalance_is_counted() {
        for n in [2usize, 4] {
            let mut s = ShardedStore::new(RF, cfg(n, ShardPartition::Range)).unwrap();
            // one chunk-local burst: positions 0..4 all live on shard 0
            for p in 0..4 {
                s.stash(p, row(p as f32), 0, 2).unwrap();
            }
            let got = s.take_batch(&[0, 1, 2, 3]).unwrap();
            assert!(got.iter().all(Option::is_some));
            assert_eq!(s.restore_parallelism.max(), 1);
            assert_eq!(s.shard_imbalance, 1, "4 rows on 1 of {n} shards is imbalanced");
        }
        // an evenly-spread hash burst never counts
        let mut s = ShardedStore::new(RF, cfg(2, ShardPartition::Hash)).unwrap();
        for p in 0..4 {
            s.stash(p, row(p as f32), 0, 2).unwrap();
        }
        s.take_batch(&[0, 1, 2, 3]).unwrap();
        assert_eq!(s.shard_imbalance, 0, "2+2 across 2 shards is balanced");
    }

    #[test]
    fn hot_budget_below_one_row_per_shard_is_rejected() {
        let mut c = cfg(4, ShardPartition::Hash);
        c.hot_budget_bytes = RF * 4; // one row total -> 1/4 row per shard
        let err = ShardedStore::new(RF, c).unwrap_err();
        assert!(format!("{err}").contains("below one"), "{err}");
        // the escape hatch makes budgets advisory: accepted
        let mut c2 = cfg(4, ShardPartition::Hash);
        c2.hot_budget_bytes = RF * 4;
        c2.quantize_cold = false;
        assert!(ShardedStore::new(RF, c2).is_ok());
    }

    #[test]
    fn drain_all_crosses_shards_and_conserves() {
        let mut s = ShardedStore::new(RF, cfg(2, ShardPartition::Hash)).unwrap();
        for p in 0..6 {
            s.stash(p, row(p as f32), 0, if p % 2 == 0 { 2 } else { 100 }).unwrap();
        }
        s.drop_row(5).unwrap();
        let mut drained = s.drain_all().unwrap();
        drained.sort_by_key(|(p, _)| *p);
        assert_eq!(drained.len(), 5);
        assert_eq!(drained[0].1, row(0.0));
        assert!(s.is_empty());
        assert_eq!(s.total_stashed(), s.total_restored() + s.total_dropped());
    }

    /// Pipeline-friendly config: rows stashed with `eta - step >= 4`
    /// go cold immediately and sit within the speculation horizon.
    fn pcfg(n: usize, partition: ShardPartition) -> OffloadConfig {
        let mut c = cfg(n, partition);
        c.cold_after_steps = 4;
        c.prefetch_ahead = 4;
        c
    }

    #[test]
    fn speculative_pipeline_lands_and_serves_takes() {
        let mut s = ShardedStore::new(RF, pcfg(2, ShardPartition::Hash)).unwrap();
        for p in 0..6 {
            s.stash(p, row(p as f32), 0, 4).unwrap();
        }
        assert_eq!(s.occupancy().cold_rows, 6);
        s.pipeline_advance(0).unwrap();
        assert_eq!(s.spec_issued, 6, "cold rows due within the horizon must be speculated");
        s.settle().unwrap();
        assert_eq!(s.spec_landed, 6);
        let positions: Vec<usize> = (0..6).collect();
        let got = s.take_batch(&positions).unwrap();
        assert!(got.iter().all(Option::is_some));
        assert_eq!(s.spec_consumed, 6);
        assert_eq!(s.total_restored(), 6);
        assert!(s.is_empty());
        assert_eq!(s.total_stashed(), s.total_restored() + s.total_dropped());
        // the worker promoted each row hot-staged before decoding, so
        // the confirming restores count as staged hits
        assert_eq!(s.staged_hits(), 6);
    }

    #[test]
    fn refreeze_fences_landed_speculation() {
        let mut s = ShardedStore::new(RF, pcfg(1, ShardPartition::Hash)).unwrap();
        s.stash(3, row(3.0), 0, 4).unwrap();
        s.pipeline_advance(0).unwrap();
        s.settle().unwrap();
        assert_eq!(s.spec_landed, 1);
        let first = s.take(3).unwrap().unwrap();
        assert_eq!(s.spec_consumed, 1);
        // re-freeze with fresh data: the next speculation must serve
        // the new payload, never a stale copy
        s.stash(3, row(30.0), 5, 9).unwrap();
        s.pipeline_advance(5).unwrap();
        s.settle().unwrap();
        assert_eq!(s.spec_landed, 2);
        let second = s.take(3).unwrap().unwrap();
        assert_ne!(first, second, "fresh row must supersede the speculative copy");
        assert_eq!(s.total_stashed(), s.total_restored() + s.total_dropped());
    }

    #[test]
    fn unconsumed_landed_copies_expire_at_the_deadline() {
        let mut c = pcfg(1, ShardPartition::Hash);
        c.restore_deadline_steps = 2;
        let mut s = ShardedStore::new(RF, c).unwrap();
        s.stash(1, row(1.0), 0, 4).unwrap();
        s.pipeline_advance(0).unwrap();
        s.settle().unwrap();
        assert_eq!(s.spec_landed, 1);
        assert!(s.spec_busy(1));
        s.pipeline_advance(1).unwrap();
        assert_eq!(s.spec_cancelled, 0, "within the deadline the copy stays");
        // landed at step 0, deadline 2: expires at the advance for
        // step 2. The row itself is untouched — the worker promoted it
        // hot-staged, so it is not re-speculated (speculation only
        // targets cold/spill) and the take below is a plain staged hit
        s.pipeline_advance(2).unwrap();
        assert_eq!(s.spec_cancelled, 1);
        s.settle().unwrap();
        let got = s.take(1).unwrap().unwrap();
        assert_eq!(got.len(), RF);
        assert_eq!(s.total_restored(), 1);
        assert_eq!(s.total_stashed(), s.total_restored() + s.total_dropped());
    }

    #[test]
    fn late_arrivals_block_and_count() {
        let mut c = pcfg(1, ShardPartition::Hash);
        c.pipeline_test_delay_us = 20_000;
        let mut s = ShardedStore::new(RF, c).unwrap();
        s.stash(1, row(1.0), 0, 4).unwrap();
        s.pipeline_advance(0).unwrap();
        assert!(s.spec_busy(1), "the read is in flight behind the injected delay");
        let got = s.take(1).unwrap().unwrap();
        assert_eq!(got.len(), RF);
        assert_eq!(s.late_arrivals, 1);
        assert_eq!(s.total_restored(), 1);
        assert!(s.take_wait_us() > 0, "blocking on the in-flight read is charged as wait");
    }

    #[test]
    fn drain_discards_landed_copies_and_conserves() {
        let mut s = ShardedStore::new(RF, pcfg(2, ShardPartition::Range)).unwrap();
        for p in 0..8 {
            s.stash(p, row(p as f32), 0, 4).unwrap();
        }
        s.pipeline_advance(0).unwrap();
        s.settle().unwrap();
        assert_eq!(s.spec_landed, 8);
        let drained = s.drain_all().unwrap();
        assert_eq!(drained.len(), 8);
        assert_eq!(s.spec_consumed, 0);
        assert_eq!(s.spec_cancelled, 8, "unconsumed landed copies cancel at drain");
        assert_eq!(s.total_stashed(), s.total_restored() + s.total_dropped());
        assert!(s.is_empty());
    }

    #[test]
    fn pipeline_off_never_speculates() {
        let mut c = pcfg(2, ShardPartition::Hash);
        c.pipeline = false;
        let mut s = ShardedStore::new(RF, c).unwrap();
        for p in 0..4 {
            s.stash(p, row(p as f32), 0, 4).unwrap();
        }
        s.pipeline_advance(0).unwrap();
        s.settle().unwrap();
        assert_eq!(s.spec_issued, 0);
        let got = s.take_batch(&[0, 1, 2, 3]).unwrap();
        assert!(got.iter().all(Option::is_some));
        assert_eq!(s.take_wait_us(), 0);
    }

    #[test]
    fn restore_wait_timeout_fails_typed_then_recovers() {
        let mut c = pcfg(1, ShardPartition::Hash);
        c.pipeline_test_delay_us = 100_000; // 100 ms in-worker per row
        c.restore_wait_timeout_ms = 5;
        let mut s = ShardedStore::new(RF, c).unwrap();
        s.stash(1, row(1.0), 0, 4).unwrap();
        s.pipeline_advance(0).unwrap();
        assert!(s.spec_busy(1), "the read is in flight behind the injected delay");
        let err = s.take(1).unwrap_err();
        assert!(format!("{err}").contains("restore wait exceeded"), "{err}");
        assert!(
            s.flight_events().iter().any(|(_, ev)| ev.cause == Cause::RestoreTimeout),
            "the bounded wait must leave a restore_timeout flight event"
        );
        assert!(s.take_wait_us() > 0, "the timed-out wait is still charged");
        // the straggler lands once the delay elapses; nothing is lost
        std::thread::sleep(Duration::from_millis(150));
        s.settle().unwrap();
        let got = s.take(1).unwrap();
        assert_eq!(got.unwrap(), row(1.0));
        assert_eq!(s.total_stashed(), s.total_restored() + s.total_dropped());
    }

    /// Persistent-spill config with a zero cold budget: far-eta rows
    /// spill immediately (recoverable), near-eta rows stay hot (lost
    /// on a shard panic) — a deterministic mix for rebuild tests.
    fn spill_cfg(n: usize, dir: &crate::util::TempDir, persist: bool) -> OffloadConfig {
        let mut c = cfg(n, ShardPartition::Hash);
        c.spill_dir = Some(dir.path_str());
        c.spill_persist = persist;
        c.cold_budget_bytes = 0;
        c
    }

    #[test]
    fn inline_panic_rebuilds_shard_from_spill_and_declares_hot_rows_lost() {
        use crate::offload::fault::arm_worker_kill;
        let dir = crate::util::TempDir::new("sharded-rebuild-inline").unwrap();
        let mut s = ShardedStore::new(RF, spill_cfg(2, &dir, true)).unwrap();
        // shard 0 (even positions): pos 0 hot, pos 2 and 4 spilled
        s.stash(0, row(0.0), 0, 2).unwrap();
        s.stash(2, row(2.0), 0, 100).unwrap();
        s.stash(4, row(4.0), 0, 100).unwrap();
        // shard 1: one hot sibling, untouched by the failure
        s.stash(3, row(3.0), 0, 2).unwrap();
        assert_eq!(s.occupancy().spill_rows, 2);
        arm_worker_kill(dir.path());
        // single-shard burst -> inline exec path -> supervised panic
        let err = s.take_batch(&[2]).unwrap_err();
        assert!(format!("{err}").contains("panicked"), "{err}");
        assert_eq!(s.shard_rebuilds(), 1);
        assert_eq!(s.rows_lost_total(), 1, "only the hot row had no spilled copy");
        assert_eq!(s.lost_rows(), vec![0]);
        assert_eq!(s.degraded_shards(), 1, "a fresh rebuild discounts capacity");
        // the panicked op mutated nothing: both spilled rows survive
        // and restore through the rebuilt shard
        assert!(s.take(2).unwrap().is_some());
        assert!(s.take(4).unwrap().is_some());
        // a declared-lost take is a typed error, never a silent None
        let lost = s.take(0).unwrap_err();
        assert!(matches!(lost, Error::RowsLost(ref p) if p == &vec![0]), "{lost}");
        // the sibling shard never noticed
        assert_eq!(s.take(3).unwrap().unwrap(), row(3.0));
        // conservation modulo the declared-lost set
        assert_eq!(
            s.total_stashed(),
            s.total_restored() + s.total_dropped() + s.rows_lost_total() + s.len() as u64
        );
        // a fresh stash supersedes the loss and the store keeps working
        s.stash(0, row(9.0), 10, 12).unwrap();
        assert!(s.lost_rows().is_empty());
        assert_eq!(s.take(0).unwrap().unwrap(), row(9.0));
        // the step clock ages the rebuilt shard out of the window
        s.on_step(20).unwrap();
        assert_eq!(s.degraded_shards(), 0);
        assert_eq!(
            s.total_stashed(),
            s.total_restored() + s.total_dropped() + s.rows_lost_total() + s.len() as u64
        );
    }

    #[test]
    fn pool_panic_mid_burst_rebuilds_and_conserves() {
        use crate::offload::fault::arm_worker_kill;
        let dir = crate::util::TempDir::new("sharded-rebuild-pool").unwrap();
        let mut s = ShardedStore::new(RF, spill_cfg(2, &dir, true)).unwrap();
        for p in 0..4 {
            s.stash(p, row(p as f32), 0, 100).unwrap(); // all spilled
        }
        arm_worker_kill(dir.path());
        // both shards engaged -> pool path; exactly one worker takes
        // the one-shot kill (whichever dequeues first)
        let err = s.take_batch(&[0, 1, 2, 3]).unwrap_err();
        assert!(format!("{err}").contains("panicked"), "{err}");
        assert_eq!(s.shard_rebuilds(), 1);
        assert_eq!(s.rows_lost_total(), 0, "every row had a spilled copy");
        // the surviving shard's slice was consumed by the failed burst
        // (and discarded with the error); the panicked shard's slice
        // recovered from spill — two rows remain either way
        assert_eq!(s.len(), 2);
        let mut takeable = 0;
        for p in 0..4 {
            if s.take(p).unwrap().is_some() {
                takeable += 1;
            }
        }
        assert_eq!(takeable, 2);
        assert_eq!(
            s.total_stashed(),
            s.total_restored() + s.total_dropped() + s.rows_lost_total() + s.len() as u64
        );
        let sum = s.summary();
        assert_eq!(sum.shard_rebuilds, 1);
        assert_eq!(sum.rows_lost, 0);
    }

    #[test]
    fn panic_without_persistent_spill_loses_rows_but_store_stays_usable() {
        use crate::offload::fault::arm_worker_kill;
        let dir = crate::util::TempDir::new("sharded-rebuild-ephemeral").unwrap();
        // ephemeral spill: records die with the store, so a rebuild
        // recovers nothing — every resident row is declared lost
        let mut s = ShardedStore::new(RF, spill_cfg(2, &dir, false)).unwrap();
        s.stash(0, row(0.0), 0, 100).unwrap();
        s.stash(2, row(2.0), 0, 100).unwrap();
        arm_worker_kill(dir.path());
        let err = s.take_batch(&[0]).unwrap_err();
        assert!(format!("{err}").contains("panicked"), "{err}");
        assert_eq!(s.shard_rebuilds(), 1);
        assert_eq!(s.rows_lost_total(), 2);
        assert_eq!(s.lost_rows(), vec![0, 2]);
        assert!(matches!(s.take_batch(&[0, 2]), Err(Error::RowsLost(ref p)) if p == &vec![0, 2]));
        // dropping a lost row is trivially complete (already accounted)
        s.drop_row(2).unwrap();
        assert_eq!(s.lost_rows(), vec![0]);
        // the shard itself came back empty and usable
        s.stash(0, row(9.0), 1, 3).unwrap();
        assert_eq!(s.take(0).unwrap().unwrap(), row(9.0));
        assert_eq!(
            s.total_stashed(),
            s.total_restored() + s.total_dropped() + s.rows_lost_total() + s.len() as u64
        );
    }

    #[test]
    fn single_shard_runs_fully_inline() {
        let mut s = ShardedStore::new(RF, cfg(1, ShardPartition::Hash)).unwrap();
        assert_eq!(s.shard_count(), 1);
        // the whole batched surface works without the worker pool
        s.stash_batch(vec![(0, row(0.0), 2), (1, row(1.0), 2)], 0).unwrap();
        s.on_step(1).unwrap();
        let got = s.take_batch(&[0, 1]).unwrap();
        assert!(got.iter().all(Option::is_some));
        assert_eq!(s.restore_parallelism.max(), 1);
        assert_eq!(s.shard_imbalance, 0, "n = 1 never counts imbalance");
    }
}
