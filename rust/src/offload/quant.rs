//! Cold-tier row compression: affine u8-per-float quantization with a
//! per-row (min, scale) header.
//!
//! Frozen rows tolerate lossy storage (KVComp, arXiv 2509.00579): a
//! frozen row is excluded from attention until restored, and the
//! restore error is bounded by half a quantization step of the row's
//! own value range. With 255 levels that is `range / 510` — the bound
//! documented in `OffloadConfig::cold_quant_rel_error` and verified by
//! `tests/prop_offload.rs`.
//!
//! Encoding: `x ≈ min + q * scale`, `q ∈ [0, 255]`,
//! `scale = (max - min) / 255` (0 for constant rows).

/// One quantized row: `row_floats` u8 codes + per-row affine header.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantRow {
    pub q: Vec<u8>,
    pub min: f32,
    pub scale: f32,
}

/// Header bytes per stored row (min + scale as f32).
pub const ROW_HEADER_BYTES: usize = 8;

impl QuantRow {
    /// Bytes this row occupies in the cold tier.
    pub fn bytes(&self) -> usize {
        self.q.len() + ROW_HEADER_BYTES
    }

    /// Worst-case absolute reconstruction error for this row.
    pub fn error_bound(&self) -> f32 {
        // half a quantization step, plus f32 headroom for the affine
        // arithmetic on large-magnitude rows
        0.5 * self.scale + (self.min.abs() + 255.0 * self.scale) * f32::EPSILON * 4.0
    }
}

/// Quantize a full-precision row. Non-finite inputs are clamped into
/// the finite range of the row (NaN encodes as the row minimum).
pub fn quantize(row: &[f32]) -> QuantRow {
    let mut min = f32::INFINITY;
    let mut max = f32::NEG_INFINITY;
    for &x in row {
        if x.is_finite() {
            min = min.min(x);
            max = max.max(x);
        }
    }
    if !min.is_finite() {
        // all-NaN/inf row: store zeros
        (min, max) = (0.0, 0.0);
    }
    let scale = if max > min { (max - min) / 255.0 } else { 0.0 };
    let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
    let q = row
        .iter()
        .map(|&x| {
            let x = if x.is_finite() { x.clamp(min, max) } else { min };
            ((x - min) * inv).round().clamp(0.0, 255.0) as u8
        })
        .collect();
    QuantRow { q, min, scale }
}

/// Reconstruct into a caller-provided buffer (len must match).
pub fn dequantize_into(qr: &QuantRow, dst: &mut [f32]) {
    debug_assert_eq!(dst.len(), qr.q.len());
    for (d, &code) in dst.iter_mut().zip(&qr.q) {
        *d = qr.min + code as f32 * qr.scale;
    }
}

/// Reconstruct as a fresh row.
pub fn dequantize(qr: &QuantRow) -> Vec<f32> {
    let mut out = vec![0.0f32; qr.q.len()];
    dequantize_into(qr, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_within_bound() {
        let row: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin() * 3.0 - 1.0).collect();
        let qr = quantize(&row);
        let back = dequantize(&qr);
        let bound = qr.error_bound();
        for (a, b) in row.iter().zip(&back) {
            assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
        }
    }

    #[test]
    fn constant_row_is_exact() {
        let row = vec![2.5f32; 16];
        let qr = quantize(&row);
        assert_eq!(qr.scale, 0.0);
        assert_eq!(dequantize(&qr), row);
    }

    #[test]
    fn extremes_are_exact() {
        let row = vec![-1.0f32, 0.1, 0.2, 1.0];
        let qr = quantize(&row);
        let back = dequantize(&qr);
        assert_eq!(back[0], -1.0);
        assert!((back[3] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn bytes_accounting() {
        let qr = quantize(&[0.0; 32]);
        assert_eq!(qr.bytes(), 32 + ROW_HEADER_BYTES);
    }

    #[test]
    fn non_finite_inputs_do_not_poison_row() {
        let row = vec![1.0f32, f32::NAN, 3.0, f32::INFINITY];
        let qr = quantize(&row);
        let back = dequantize(&qr);
        assert!(back.iter().all(|v| v.is_finite()));
        assert!((back[0] - 1.0).abs() <= qr.error_bound());
        assert!((back[2] - 3.0).abs() <= qr.error_bound());
    }
}
