//! Cold-tier row compression kernels: the per-codec encode/decode hot
//! loops behind `offload::codec`.
//!
//! Frozen rows tolerate lossy storage (KVComp, arXiv 2509.00579): a
//! frozen row is excluded from attention until restored, and the
//! restore error is bounded by half a quantization step of the row's
//! own value range. Three lossy representations live here, all built
//! on the same fixed-width chunked loops so they auto-vectorize:
//!
//! * [`QuantRow`] — per-row affine u8 (`x ≈ min + q * scale`,
//!   `q ∈ [0, 255]`, `scale = (max - min) / 255`; 0 for constant
//!   rows). Worst case `range / 510`, the bound documented in
//!   `OffloadConfig::cold_quant_rel_error` and verified by
//!   `tests/prop_offload.rs`.
//! * [`PackedRow`] — per-block affine u4, two codes per byte over
//!   [`U4_BLOCK`]-float blocks with per-block (min, scale). Worst case
//!   half a 15-level step of the *block* range, ≤ `range / 30` of the
//!   row range.
//! * [`BoundedRow`] — error-bounded variable-rate blocks: each
//!   [`EBQ_BLOCK`]-float block independently picks the narrowest code
//!   width in {0, 2, 4, 8} bits that keeps its half-step error within
//!   an absolute target derived from the row range
//!   (`OffloadConfig::ebq_rel_error`). Near-constant blocks collapse
//!   to the 9-byte header alone.

/// One quantized row: `row_floats` u8 codes + per-row affine header.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantRow {
    pub q: Vec<u8>,
    pub min: f32,
    pub scale: f32,
}

/// Header bytes per stored row (min + scale as f32).
pub const ROW_HEADER_BYTES: usize = 8;

impl QuantRow {
    /// Bytes this row occupies in the cold tier.
    pub fn bytes(&self) -> usize {
        self.q.len() + ROW_HEADER_BYTES
    }

    /// Worst-case absolute reconstruction error for this row.
    pub fn error_bound(&self) -> f32 {
        // half a quantization step, plus f32 headroom for the affine
        // arithmetic on large-magnitude rows
        0.5 * self.scale + (self.min.abs() + 255.0 * self.scale) * f32::EPSILON * 4.0
    }
}

/// Lane width for the chunked hot loops below: wide enough for the
/// compiler to auto-vectorize (two 4-wide or one 8-wide SIMD op per
/// chunk), small enough that the scalar remainder stays trivial.
const LANES: usize = 8;

#[inline(always)]
fn encode(x: f32, min: f32, max: f32, inv: f32) -> u8 {
    // non-finite inputs select into the finite range branchlessly
    // (NaN encodes as the row minimum), keeping the loop body a
    // straight-line select + fma + round the compiler can vectorize
    let x = if x.is_finite() { x.clamp(min, max) } else { min };
    ((x - min) * inv).round().clamp(0.0, 255.0) as u8
}

/// Quantize a full-precision row. Non-finite inputs are clamped into
/// the finite range of the row (NaN encodes as the row minimum).
///
/// Both passes (min/max reduction, encode) run over fixed-width
/// chunks with per-lane accumulators so the restore path's inverse —
/// and this stash-path cost — show up as vector code; `micro_runtime`
/// tracks the per-row cost of both.
#[inline]
pub fn quantize(row: &[f32]) -> QuantRow {
    let mut lane_min = [f32::INFINITY; LANES];
    let mut lane_max = [f32::NEG_INFINITY; LANES];
    let mut chunks = row.chunks_exact(LANES);
    for ch in chunks.by_ref() {
        for j in 0..LANES {
            let x = ch[j];
            // map non-finite values to the identity of each reduction
            let finite = x.is_finite();
            lane_min[j] = lane_min[j].min(if finite { x } else { f32::INFINITY });
            lane_max[j] = lane_max[j].max(if finite { x } else { f32::NEG_INFINITY });
        }
    }
    let mut min = f32::INFINITY;
    let mut max = f32::NEG_INFINITY;
    for j in 0..LANES {
        min = min.min(lane_min[j]);
        max = max.max(lane_max[j]);
    }
    for &x in chunks.remainder() {
        if x.is_finite() {
            min = min.min(x);
            max = max.max(x);
        }
    }
    if !min.is_finite() {
        // all-NaN/inf row: store zeros
        (min, max) = (0.0, 0.0);
    }
    let scale = if max > min { (max - min) / 255.0 } else { 0.0 };
    let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };

    let mut q = vec![0u8; row.len()];
    let mut dst = q.chunks_exact_mut(LANES);
    let mut src = row.chunks_exact(LANES);
    for (qs, xs) in dst.by_ref().zip(src.by_ref()) {
        for j in 0..LANES {
            qs[j] = encode(xs[j], min, max, inv);
        }
    }
    for (d, &x) in dst.into_remainder().iter_mut().zip(src.remainder()) {
        *d = encode(x, min, max, inv);
    }
    QuantRow { q, min, scale }
}

/// Reconstruct into a caller-provided buffer (len must match). This is
/// the restore-path inner loop (every cold/spill `take()` and every
/// prefetch staging pass lands here), chunked so the affine decode
/// vectorizes.
#[inline]
pub fn dequantize_into(qr: &QuantRow, dst: &mut [f32]) {
    debug_assert_eq!(dst.len(), qr.q.len());
    let (min, scale) = (qr.min, qr.scale);
    let mut dc = dst.chunks_exact_mut(LANES);
    let mut qc = qr.q.chunks_exact(LANES);
    for (ds, qs) in dc.by_ref().zip(qc.by_ref()) {
        for j in 0..LANES {
            ds[j] = min + qs[j] as f32 * scale;
        }
    }
    for (d, &code) in dc.into_remainder().iter_mut().zip(qc.remainder()) {
        *d = min + code as f32 * scale;
    }
}

/// Reconstruct as a fresh row.
#[inline]
pub fn dequantize(qr: &QuantRow) -> Vec<f32> {
    let mut out = vec![0.0f32; qr.q.len()];
    dequantize_into(qr, &mut out);
    out
}

/// Finite-only (min, max) reduction over one block, 8-lane chunked
/// like [`quantize`]'s row pass. Returns `(0.0, 0.0)` for an
/// all-non-finite block.
#[inline]
fn block_min_max(block: &[f32]) -> (f32, f32) {
    let mut lane_min = [f32::INFINITY; LANES];
    let mut lane_max = [f32::NEG_INFINITY; LANES];
    let mut chunks = block.chunks_exact(LANES);
    for ch in chunks.by_ref() {
        for j in 0..LANES {
            let x = ch[j];
            let finite = x.is_finite();
            lane_min[j] = lane_min[j].min(if finite { x } else { f32::INFINITY });
            lane_max[j] = lane_max[j].max(if finite { x } else { f32::NEG_INFINITY });
        }
    }
    let mut min = f32::INFINITY;
    let mut max = f32::NEG_INFINITY;
    for j in 0..LANES {
        min = min.min(lane_min[j]);
        max = max.max(lane_max[j]);
    }
    for &x in chunks.remainder() {
        if x.is_finite() {
            min = min.min(x);
            max = max.max(x);
        }
    }
    if !min.is_finite() {
        (0.0, 0.0)
    } else {
        (min, max)
    }
}

/// Ceiling division (the crate's 1.70 MSRV predates `usize::div_ceil`).
#[inline]
pub(crate) fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

// --- u4 block quantization -------------------------------------------

/// Block width (floats) of the u4 codec: per-block affine params over
/// 32 values amortize the 8-byte header to 2 bits/value.
pub const U4_BLOCK: usize = 32;

/// Per-block header bytes of the u4 codec (min + scale as f32).
pub const U4_BLOCK_HEADER_BYTES: usize = 8;

/// One u4 block-quantized row: nibble codes packed two per byte (low
/// nibble first, row-continuous) plus per-block affine headers.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedRow {
    /// `ceil(floats / 2)` bytes of packed 4-bit codes.
    pub q: Vec<u8>,
    /// Per-[`U4_BLOCK`] `(min, scale)` affine params.
    pub blocks: Vec<(f32, f32)>,
    /// Row width in floats (not recoverable from `q.len()` when odd).
    pub floats: usize,
}

impl PackedRow {
    /// Bytes this row occupies (packed codes + block headers) — also
    /// its exact on-disk payload size in the spill record body.
    pub fn bytes(&self) -> usize {
        self.q.len() + self.blocks.len() * U4_BLOCK_HEADER_BYTES
    }

    /// Worst-case absolute reconstruction error for this row: half a
    /// 15-level step of the widest block, plus f32 headroom.
    pub fn error_bound(&self) -> f32 {
        let mut bound = 0.0f32;
        for &(min, scale) in &self.blocks {
            let b = 0.5 * scale + (min.abs() + 15.0 * scale) * f32::EPSILON * 4.0;
            bound = bound.max(b);
        }
        bound
    }
}

/// Quantize a row into [`U4_BLOCK`]-float blocks of 4-bit codes.
/// Non-finite inputs clamp into the block's finite range (NaN encodes
/// as the block minimum), matching [`quantize`].
#[inline]
pub fn pack_u4(row: &[f32]) -> PackedRow {
    let mut blocks = Vec::with_capacity(ceil_div(row.len(), U4_BLOCK));
    let mut q = vec![0u8; ceil_div(row.len(), 2)];
    let mut codes = [0u8; U4_BLOCK];
    for (bi, block) in row.chunks(U4_BLOCK).enumerate() {
        let (min, max) = block_min_max(block);
        let scale = if max > min { (max - min) / 15.0 } else { 0.0 };
        let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
        for (j, &x) in block.iter().enumerate() {
            let x = if x.is_finite() { x.clamp(min, max) } else { min };
            codes[j] = ((x - min) * inv).round().clamp(0.0, 15.0) as u8;
        }
        // row-continuous nibble packing: code i -> q[i / 2], low
        // nibble for even i (a block boundary can split a byte)
        let base = bi * U4_BLOCK;
        for (j, &c) in codes[..block.len()].iter().enumerate() {
            let i = base + j;
            q[i / 2] |= c << ((i & 1) * 4);
        }
        blocks.push((min, scale));
    }
    PackedRow { q, blocks, floats: row.len() }
}

/// Reconstruct a u4 row into a caller-provided buffer (len must match).
#[inline]
pub fn unpack_u4_into(pr: &PackedRow, dst: &mut [f32]) {
    debug_assert_eq!(dst.len(), pr.floats);
    for (bi, block) in dst.chunks_mut(U4_BLOCK).enumerate() {
        let (min, scale) = pr.blocks[bi];
        let base = bi * U4_BLOCK;
        for (j, d) in block.iter_mut().enumerate() {
            let i = base + j;
            let code = (pr.q[i / 2] >> ((i & 1) * 4)) & 0x0f;
            *d = min + code as f32 * scale;
        }
    }
}

/// Reconstruct a u4 row as a fresh vec.
#[inline]
pub fn unpack_u4(pr: &PackedRow) -> Vec<f32> {
    let mut out = vec![0.0f32; pr.floats];
    unpack_u4_into(pr, &mut out);
    out
}

// --- error-bounded variable-rate quantization ------------------------

/// Block width (floats) of the error-bounded codec.
pub const EBQ_BLOCK: usize = 32;

/// Per-block header bytes of the error-bounded codec (min + scale as
/// f32, plus the code width byte).
pub const EBQ_BLOCK_HEADER_BYTES: usize = 9;

/// One error-bounded block: affine params plus the code width this
/// block needed to stay within the row's error target.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EbqBlock {
    pub min: f32,
    /// Affine step for `bits > 0`; the full block range for
    /// `bits == 0` (midpoint reconstruction).
    pub scale: f32,
    /// Code width in bits: 0, 2, 4 or 8.
    pub bits: u8,
}

/// One error-bounded row: per-block variable-width codes, each block
/// byte-aligned in `q`.
#[derive(Debug, Clone, PartialEq)]
pub struct BoundedRow {
    pub blocks: Vec<EbqBlock>,
    /// Concatenated per-block code bytes
    /// (`ceil(block_len * bits / 8)` each, LSB-first within a byte).
    pub q: Vec<u8>,
    /// Row width in floats.
    pub floats: usize,
    /// Worst-case absolute reconstruction error actually guaranteed by
    /// the chosen per-block widths (≤ the encode-time target whenever
    /// the target was achievable).
    pub bound: f32,
}

/// Half-step error of encoding a `range`-wide block at `bits` width.
#[inline]
fn ebq_half_step(range: f32, bits: u8) -> f32 {
    match bits {
        0 => 0.5 * range, // midpoint reconstruction
        b => 0.5 * range / ((1u32 << b) - 1) as f32,
    }
}

impl BoundedRow {
    /// Bytes this row occupies (code bytes + block headers) — also its
    /// exact on-disk payload size in the spill record body.
    pub fn bytes(&self) -> usize {
        self.q.len() + self.blocks.len() * EBQ_BLOCK_HEADER_BYTES
    }

    /// Worst-case absolute reconstruction error for this row.
    pub fn error_bound(&self) -> f32 {
        self.bound
    }
}

/// Encode a row with per-block code widths chosen to keep each block's
/// half-step error within `rel_target` of the *row* value range. With
/// the default target (`OffloadConfig::ebq_rel_error`, 2% of range)
/// smooth blocks collapse to 2-bit codes or to the bare header, while
/// an 8-bit block (error ≤ range/510) always satisfies any target the
/// CLI accepts. Non-finite inputs clamp like [`quantize`].
#[inline]
pub fn encode_ebq(row: &[f32], rel_target: f32) -> BoundedRow {
    let (row_min, row_max) = block_min_max(row);
    let target = rel_target.max(0.0) * (row_max - row_min);
    let mut blocks = Vec::with_capacity(ceil_div(row.len(), EBQ_BLOCK));
    let mut q = Vec::with_capacity(row.len() / 4);
    let mut bound = 0.0f32;
    let mut codes = [0u8; EBQ_BLOCK];
    for block in row.chunks(EBQ_BLOCK) {
        let (min, max) = block_min_max(block);
        let range = max - min;
        let bits = *[0u8, 2, 4, 8]
            .iter()
            .find(|&&b| ebq_half_step(range, b) <= target)
            .unwrap_or(&8);
        let half = ebq_half_step(range, bits);
        bound = bound.max(half + (min.abs() + range) * f32::EPSILON * 4.0);
        if bits == 0 {
            // header-only block: reconstructs to the midpoint
            blocks.push(EbqBlock { min, scale: range, bits });
            continue;
        }
        let levels = ((1u32 << bits) - 1) as f32;
        let scale = if range > 0.0 { range / levels } else { 0.0 };
        let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
        for (j, &x) in block.iter().enumerate() {
            let x = if x.is_finite() { x.clamp(min, max) } else { min };
            codes[j] = ((x - min) * inv).round().clamp(0.0, levels) as u8;
        }
        // byte-aligned per block, LSB-first within each byte
        let per_byte = 8 / bits as usize;
        for chunk in codes[..block.len()].chunks(per_byte) {
            let mut byte = 0u8;
            for (k, &c) in chunk.iter().enumerate() {
                byte |= c << (k * bits as usize);
            }
            q.push(byte);
        }
        blocks.push(EbqBlock { min, scale, bits });
    }
    BoundedRow { blocks, q, floats: row.len(), bound }
}

/// Reconstruct an error-bounded row into a caller-provided buffer.
#[inline]
pub fn decode_ebq_into(br: &BoundedRow, dst: &mut [f32]) {
    debug_assert_eq!(dst.len(), br.floats);
    let mut off = 0usize;
    for (bi, block) in dst.chunks_mut(EBQ_BLOCK).enumerate() {
        let EbqBlock { min, scale, bits } = br.blocks[bi];
        if bits == 0 {
            let mid = min + 0.5 * scale;
            for d in block.iter_mut() {
                *d = mid;
            }
            continue;
        }
        let per_byte = 8 / bits as usize;
        let mask = ((1u32 << bits) - 1) as u8;
        for (j, d) in block.iter_mut().enumerate() {
            let byte = br.q[off + j / per_byte];
            let code = (byte >> ((j % per_byte) * bits as usize)) & mask;
            *d = min + code as f32 * scale;
        }
        off += ceil_div(block.len(), per_byte);
    }
}

/// Reconstruct an error-bounded row as a fresh vec.
#[inline]
pub fn decode_ebq(br: &BoundedRow) -> Vec<f32> {
    let mut out = vec![0.0f32; br.floats];
    decode_ebq_into(br, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_within_bound() {
        let row: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin() * 3.0 - 1.0).collect();
        let qr = quantize(&row);
        let back = dequantize(&qr);
        let bound = qr.error_bound();
        for (a, b) in row.iter().zip(&back) {
            assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
        }
    }

    #[test]
    fn constant_row_is_exact() {
        let row = vec![2.5f32; 16];
        let qr = quantize(&row);
        assert_eq!(qr.scale, 0.0);
        assert_eq!(dequantize(&qr), row);
    }

    #[test]
    fn extremes_are_exact() {
        let row = vec![-1.0f32, 0.1, 0.2, 1.0];
        let qr = quantize(&row);
        let back = dequantize(&qr);
        assert_eq!(back[0], -1.0);
        assert!((back[3] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn bytes_accounting() {
        let qr = quantize(&[0.0; 32]);
        assert_eq!(qr.bytes(), 32 + ROW_HEADER_BYTES);
    }

    #[test]
    fn non_finite_inputs_do_not_poison_row() {
        let row = vec![1.0f32, f32::NAN, 3.0, f32::INFINITY];
        let qr = quantize(&row);
        let back = dequantize(&qr);
        assert!(back.iter().all(|v| v.is_finite()));
        assert!((back[0] - 1.0).abs() <= qr.error_bound());
        assert!((back[2] - 3.0).abs() <= qr.error_bound());
    }

    fn wavy(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.37).sin() * 3.0 - 1.0).collect()
    }

    #[test]
    fn u4_roundtrip_within_bound_and_bytes_exact() {
        for n in [1usize, 2, 15, 32, 33, 64, 97] {
            let row = wavy(n);
            let pr = pack_u4(&row);
            assert_eq!(pr.bytes(), ceil_div(n, 2) + ceil_div(n, U4_BLOCK) * U4_BLOCK_HEADER_BYTES);
            let back = unpack_u4(&pr);
            let bound = pr.error_bound();
            for (a, b) in row.iter().zip(&back) {
                assert!((a - b).abs() <= bound, "n={n}: {a} vs {b} (bound {bound})");
            }
        }
    }

    #[test]
    fn u4_constant_block_is_exact_and_odd_tail_packs() {
        let mut row = vec![2.5f32; 32];
        row.push(7.0); // odd length: high nibble of the last byte
        let pr = pack_u4(&row);
        let back = unpack_u4(&pr);
        assert_eq!(&back[..32], &row[..32]);
        assert_eq!(back[32], 7.0);
    }

    #[test]
    fn ebq_roundtrip_within_declared_bound() {
        for n in [1usize, 31, 32, 64, 100] {
            for target in [0.5f32, 0.05, 0.02, 0.001] {
                let row = wavy(n);
                let br = encode_ebq(&row, target);
                assert_eq!(
                    br.bytes(),
                    br.q.len() + br.blocks.len() * EBQ_BLOCK_HEADER_BYTES
                );
                let back = decode_ebq(&br);
                for (a, b) in row.iter().zip(&back) {
                    assert!(
                        (a - b).abs() <= br.bound,
                        "n={n} target={target}: {a} vs {b} (bound {})",
                        br.bound
                    );
                }
            }
        }
    }

    #[test]
    fn ebq_spends_fewer_bits_on_looser_targets() {
        let row = wavy(256);
        let loose = encode_ebq(&row, 0.1);
        let tight = encode_ebq(&row, 0.001);
        assert!(loose.bytes() < tight.bytes(), "{} vs {}", loose.bytes(), tight.bytes());
        // a constant row costs headers only
        let flat = encode_ebq(&vec![1.5f32; 64], 0.02);
        assert!(flat.q.is_empty());
        assert_eq!(decode_ebq(&flat), vec![1.5f32; 64]);
    }

    #[test]
    fn ebq_non_finite_inputs_stay_finite() {
        let mut row = wavy(40);
        row[3] = f32::NAN;
        row[17] = f32::NEG_INFINITY;
        let br = encode_ebq(&row, 0.02);
        assert!(decode_ebq(&br).iter().all(|v| v.is_finite()));
        let pr = pack_u4(&row);
        assert!(unpack_u4(&pr).iter().all(|v| v.is_finite()));
    }
}
