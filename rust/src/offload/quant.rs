//! Cold-tier row compression: affine u8-per-float quantization with a
//! per-row (min, scale) header.
//!
//! Frozen rows tolerate lossy storage (KVComp, arXiv 2509.00579): a
//! frozen row is excluded from attention until restored, and the
//! restore error is bounded by half a quantization step of the row's
//! own value range. With 255 levels that is `range / 510` — the bound
//! documented in `OffloadConfig::cold_quant_rel_error` and verified by
//! `tests/prop_offload.rs`.
//!
//! Encoding: `x ≈ min + q * scale`, `q ∈ [0, 255]`,
//! `scale = (max - min) / 255` (0 for constant rows).

/// One quantized row: `row_floats` u8 codes + per-row affine header.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantRow {
    pub q: Vec<u8>,
    pub min: f32,
    pub scale: f32,
}

/// Header bytes per stored row (min + scale as f32).
pub const ROW_HEADER_BYTES: usize = 8;

impl QuantRow {
    /// Bytes this row occupies in the cold tier.
    pub fn bytes(&self) -> usize {
        self.q.len() + ROW_HEADER_BYTES
    }

    /// Worst-case absolute reconstruction error for this row.
    pub fn error_bound(&self) -> f32 {
        // half a quantization step, plus f32 headroom for the affine
        // arithmetic on large-magnitude rows
        0.5 * self.scale + (self.min.abs() + 255.0 * self.scale) * f32::EPSILON * 4.0
    }
}

/// Lane width for the chunked hot loops below: wide enough for the
/// compiler to auto-vectorize (two 4-wide or one 8-wide SIMD op per
/// chunk), small enough that the scalar remainder stays trivial.
const LANES: usize = 8;

#[inline(always)]
fn encode(x: f32, min: f32, max: f32, inv: f32) -> u8 {
    // non-finite inputs select into the finite range branchlessly
    // (NaN encodes as the row minimum), keeping the loop body a
    // straight-line select + fma + round the compiler can vectorize
    let x = if x.is_finite() { x.clamp(min, max) } else { min };
    ((x - min) * inv).round().clamp(0.0, 255.0) as u8
}

/// Quantize a full-precision row. Non-finite inputs are clamped into
/// the finite range of the row (NaN encodes as the row minimum).
///
/// Both passes (min/max reduction, encode) run over fixed-width
/// chunks with per-lane accumulators so the restore path's inverse —
/// and this stash-path cost — show up as vector code; `micro_runtime`
/// tracks the per-row cost of both.
#[inline]
pub fn quantize(row: &[f32]) -> QuantRow {
    let mut lane_min = [f32::INFINITY; LANES];
    let mut lane_max = [f32::NEG_INFINITY; LANES];
    let mut chunks = row.chunks_exact(LANES);
    for ch in chunks.by_ref() {
        for j in 0..LANES {
            let x = ch[j];
            // map non-finite values to the identity of each reduction
            let finite = x.is_finite();
            lane_min[j] = lane_min[j].min(if finite { x } else { f32::INFINITY });
            lane_max[j] = lane_max[j].max(if finite { x } else { f32::NEG_INFINITY });
        }
    }
    let mut min = f32::INFINITY;
    let mut max = f32::NEG_INFINITY;
    for j in 0..LANES {
        min = min.min(lane_min[j]);
        max = max.max(lane_max[j]);
    }
    for &x in chunks.remainder() {
        if x.is_finite() {
            min = min.min(x);
            max = max.max(x);
        }
    }
    if !min.is_finite() {
        // all-NaN/inf row: store zeros
        (min, max) = (0.0, 0.0);
    }
    let scale = if max > min { (max - min) / 255.0 } else { 0.0 };
    let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };

    let mut q = vec![0u8; row.len()];
    let mut dst = q.chunks_exact_mut(LANES);
    let mut src = row.chunks_exact(LANES);
    for (qs, xs) in dst.by_ref().zip(src.by_ref()) {
        for j in 0..LANES {
            qs[j] = encode(xs[j], min, max, inv);
        }
    }
    for (d, &x) in dst.into_remainder().iter_mut().zip(src.remainder()) {
        *d = encode(x, min, max, inv);
    }
    QuantRow { q, min, scale }
}

/// Reconstruct into a caller-provided buffer (len must match). This is
/// the restore-path inner loop (every cold/spill `take()` and every
/// prefetch staging pass lands here), chunked so the affine decode
/// vectorizes.
#[inline]
pub fn dequantize_into(qr: &QuantRow, dst: &mut [f32]) {
    debug_assert_eq!(dst.len(), qr.q.len());
    let (min, scale) = (qr.min, qr.scale);
    let mut dc = dst.chunks_exact_mut(LANES);
    let mut qc = qr.q.chunks_exact(LANES);
    for (ds, qs) in dc.by_ref().zip(qc.by_ref()) {
        for j in 0..LANES {
            ds[j] = min + qs[j] as f32 * scale;
        }
    }
    for (d, &code) in dc.into_remainder().iter_mut().zip(qc.remainder()) {
        *d = min + code as f32 * scale;
    }
}

/// Reconstruct as a fresh row.
#[inline]
pub fn dequantize(qr: &QuantRow) -> Vec<f32> {
    let mut out = vec![0.0f32; qr.q.len()];
    dequantize_into(qr, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_within_bound() {
        let row: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin() * 3.0 - 1.0).collect();
        let qr = quantize(&row);
        let back = dequantize(&qr);
        let bound = qr.error_bound();
        for (a, b) in row.iter().zip(&back) {
            assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
        }
    }

    #[test]
    fn constant_row_is_exact() {
        let row = vec![2.5f32; 16];
        let qr = quantize(&row);
        assert_eq!(qr.scale, 0.0);
        assert_eq!(dequantize(&qr), row);
    }

    #[test]
    fn extremes_are_exact() {
        let row = vec![-1.0f32, 0.1, 0.2, 1.0];
        let qr = quantize(&row);
        let back = dequantize(&qr);
        assert_eq!(back[0], -1.0);
        assert!((back[3] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn bytes_accounting() {
        let qr = quantize(&[0.0; 32]);
        assert_eq!(qr.bytes(), 32 + ROW_HEADER_BYTES);
    }

    #[test]
    fn non_finite_inputs_do_not_poison_row() {
        let row = vec![1.0f32, f32::NAN, 3.0, f32::INFINITY];
        let qr = quantize(&row);
        let back = dequantize(&qr);
        assert!(back.iter().all(|v| v.is_finite()));
        assert!((back[0] - 1.0).abs() <= qr.error_bound());
        assert!((back[2] - 3.0).abs() <= qr.error_bound());
    }
}
