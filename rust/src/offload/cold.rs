//! Cold tier: u8-quantized rows (~4x smaller than f32) for rows the
//! freeze ladder predicts will stay frozen past the admission horizon.
//!
//! Stashing a raw row quantizes it here (lossy within the documented
//! `OffloadConfig::cold_quant_rel_error` bound); stashing an
//! already-quantized payload (a spill promotion in transit) moves the
//! record verbatim. Restores served from this tier pay inline
//! dequantization — the prefetch path exists to avoid exactly that.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::metrics::{TierKind, TierOccupancy};
use crate::offload::quant::QuantRow;
use crate::offload::tier::{RowPayload, Tier};

/// The in-memory quantized tier.
#[derive(Debug, Default)]
pub struct ColdTier {
    rows: HashMap<usize, QuantRow>,
    bytes: usize,
    row_floats: usize,
}

impl ColdTier {
    pub fn new(row_floats: usize) -> ColdTier {
        ColdTier { rows: HashMap::new(), bytes: 0, row_floats }
    }
}

impl Tier for ColdTier {
    fn kind(&self) -> TierKind {
        TierKind::Cold
    }

    fn stash(&mut self, pos: usize, payload: RowPayload) -> Result<()> {
        if self.rows.contains_key(&pos) {
            return Err(Error::Offload(format!("cold tier already holds pos {pos}")));
        }
        if payload.row_floats() != self.row_floats {
            return Err(Error::Offload(format!(
                "cold row for pos {pos} has {} floats, tier expects {}",
                payload.row_floats(),
                self.row_floats
            )));
        }
        let qr = payload.into_quant();
        self.bytes += qr.bytes();
        self.rows.insert(pos, qr);
        Ok(())
    }

    fn take(&mut self, pos: usize) -> Result<Option<RowPayload>> {
        let Some(qr) = self.rows.remove(&pos) else { return Ok(None) };
        self.bytes -= qr.bytes();
        Ok(Some(RowPayload::Quant(qr)))
    }

    fn discard(&mut self, pos: usize) -> Result<bool> {
        let Some(qr) = self.rows.remove(&pos) else { return Ok(false) };
        self.bytes -= qr.bytes();
        Ok(true)
    }

    fn bytes(&self) -> usize {
        self.bytes
    }

    fn rows(&self) -> usize {
        self.rows.len()
    }

    fn occupancy(&self, out: &mut TierOccupancy) {
        out.cold_rows += self.rows.len();
        out.cold_bytes += self.bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offload::quant;

    #[test]
    fn stash_quantizes_and_take_roundtrips() {
        let mut t = ColdTier::new(16);
        let row: Vec<f32> = (0..16).map(|i| i as f32 * 0.25 - 2.0).collect();
        t.stash(5, RowPayload::Raw(row.clone())).unwrap();
        assert_eq!(t.rows(), 1);
        assert_eq!(t.bytes(), 16 + quant::ROW_HEADER_BYTES);
        assert!(t.bytes() < 16 * 4, "cold tier must be smaller than f32");
        let back = t.take(5).unwrap().unwrap().into_raw();
        assert_eq!(back.len(), 16);
        assert_eq!(t.bytes(), 0);
    }

    #[test]
    fn quant_payload_moves_verbatim() {
        let mut t = ColdTier::new(4);
        let qr = quant::quantize(&[1.0, 2.0, 3.0, 4.0]);
        t.stash(0, RowPayload::Quant(qr.clone())).unwrap();
        match t.take(0).unwrap().unwrap() {
            RowPayload::Quant(back) => assert_eq!(back, qr),
            RowPayload::Raw(_) => panic!("cold tier must keep the quantized record"),
        }
    }

    #[test]
    fn collision_and_width_errors() {
        let mut t = ColdTier::new(4);
        t.stash(1, RowPayload::Raw(vec![0.0; 4])).unwrap();
        assert!(t.stash(1, RowPayload::Raw(vec![1.0; 4])).is_err());
        assert!(t.stash(2, RowPayload::Raw(vec![1.0; 3])).is_err());
        assert!(!t.discard(7).unwrap());
        assert!(t.discard(1).unwrap());
        assert_eq!(t.bytes(), 0);
    }
}
