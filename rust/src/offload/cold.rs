//! Cold tier: codec-encoded rows (u8 / u4 / ebq, picked by the
//! `offload::codec` ladder) for rows the freeze ladder predicts will
//! stay frozen past the admission horizon.
//!
//! Stashing a raw row quantizes it here to the u8 rung (lossy within
//! the documented `OffloadConfig::cold_quant_rel_error` bound) — the
//! store's demotion path pre-encodes with the ladder, so a raw payload
//! reaching this tier is the legacy/direct path. Stashing an
//! already-encoded payload (a ladder demotion, or a spill promotion in
//! transit) moves the record verbatim: no decode/re-encode round trip,
//! no error accumulation. Restores served from this tier pay inline
//! decoding — the prefetch path exists to avoid exactly that.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::metrics::{TierKind, TierOccupancy};
use crate::offload::codec::CodecId;
use crate::offload::tier::{RowPayload, Tier};

/// The in-memory encoded tier.
#[derive(Debug, Default)]
pub struct ColdTier {
    rows: HashMap<usize, RowPayload>,
    bytes: usize,
    row_floats: usize,
    codec_rows: [usize; CodecId::COUNT],
}

impl ColdTier {
    pub fn new(row_floats: usize) -> ColdTier {
        ColdTier {
            rows: HashMap::new(),
            bytes: 0,
            row_floats,
            codec_rows: [0; CodecId::COUNT],
        }
    }

    /// Resident rows per codec rung, indexed by `CodecId::index`.
    pub fn codec_rows(&self) -> [usize; CodecId::COUNT] {
        self.codec_rows
    }
}

impl Tier for ColdTier {
    fn kind(&self) -> TierKind {
        TierKind::Cold
    }

    fn stash(&mut self, pos: usize, payload: RowPayload) -> Result<()> {
        if self.rows.contains_key(&pos) {
            return Err(Error::Offload(format!("cold tier already holds pos {pos}")));
        }
        if payload.row_floats() != self.row_floats {
            return Err(Error::Offload(format!(
                "cold row for pos {pos} has {} floats, tier expects {}",
                payload.row_floats(),
                self.row_floats
            )));
        }
        // Raw rows are normalized to the u8 rung (this tier never
        // holds f32); encoded payloads are kept verbatim.
        let payload = match payload {
            RowPayload::Raw(_) => RowPayload::Quant(payload.into_quant()),
            encoded => encoded,
        };
        self.bytes += payload.bytes();
        self.codec_rows[payload.codec().index()] += 1;
        self.rows.insert(pos, payload);
        Ok(())
    }

    fn take(&mut self, pos: usize) -> Result<Option<RowPayload>> {
        let Some(p) = self.rows.remove(&pos) else { return Ok(None) };
        self.bytes -= p.bytes();
        self.codec_rows[p.codec().index()] -= 1;
        Ok(Some(p))
    }

    fn discard(&mut self, pos: usize) -> Result<bool> {
        let Some(p) = self.rows.remove(&pos) else { return Ok(false) };
        self.bytes -= p.bytes();
        self.codec_rows[p.codec().index()] -= 1;
        Ok(true)
    }

    fn bytes(&self) -> usize {
        self.bytes
    }

    fn rows(&self) -> usize {
        self.rows.len()
    }

    fn occupancy(&self, out: &mut TierOccupancy) {
        out.cold_rows += self.rows.len();
        out.cold_bytes += self.bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offload::quant;

    #[test]
    fn stash_quantizes_and_take_roundtrips() {
        let mut t = ColdTier::new(16);
        let row: Vec<f32> = (0..16).map(|i| i as f32 * 0.25 - 2.0).collect();
        t.stash(5, RowPayload::Raw(row.clone())).unwrap();
        assert_eq!(t.rows(), 1);
        assert_eq!(t.bytes(), 16 + quant::ROW_HEADER_BYTES);
        assert!(t.bytes() < 16 * 4, "cold tier must be smaller than f32");
        assert_eq!(t.codec_rows()[CodecId::U8.index()], 1);
        let back = t.take(5).unwrap().unwrap().into_raw();
        assert_eq!(back.len(), 16);
        assert_eq!(t.bytes(), 0);
        assert_eq!(t.codec_rows()[CodecId::U8.index()], 0);
    }

    #[test]
    fn quant_payload_moves_verbatim() {
        let mut t = ColdTier::new(4);
        let qr = quant::quantize(&[1.0, 2.0, 3.0, 4.0]);
        t.stash(0, RowPayload::Quant(qr.clone())).unwrap();
        match t.take(0).unwrap().unwrap() {
            RowPayload::Quant(back) => assert_eq!(back, qr),
            other => panic!("cold tier must keep the quantized record, got {:?}", other.codec()),
        }
    }

    #[test]
    fn sub_byte_payload_moves_verbatim() {
        let mut t = ColdTier::new(64);
        let row: Vec<f32> = (0..64).map(|i| (i as f32 * 0.3).cos()).collect();
        let pr = quant::pack_u4(&row);
        let expect_bytes = pr.bytes();
        t.stash(9, RowPayload::Packed(pr)).unwrap();
        assert_eq!(t.bytes(), expect_bytes);
        assert_eq!(t.codec_rows()[CodecId::U4.index()], 1);
        match t.take(9).unwrap().unwrap() {
            RowPayload::Packed(back) => assert_eq!(back.bytes(), expect_bytes),
            other => panic!("cold tier must keep the u4 record, got {:?}", other.codec()),
        }
        assert_eq!(t.bytes(), 0);
    }

    #[test]
    fn collision_and_width_errors() {
        let mut t = ColdTier::new(4);
        t.stash(1, RowPayload::Raw(vec![0.0; 4])).unwrap();
        assert!(t.stash(1, RowPayload::Raw(vec![1.0; 4])).is_err());
        assert!(t.stash(2, RowPayload::Raw(vec![1.0; 3])).is_err());
        assert!(!t.discard(7).unwrap());
        assert!(t.discard(1).unwrap());
        assert_eq!(t.bytes(), 0);
    }
}
