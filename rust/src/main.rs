//! `asrkf` — ASR-KF-EGR serving CLI.
//!
//! Subcommands:
//!   generate  — single-sequence generation with a chosen KV policy
//!   passkey   — needle-in-haystack retrieval check (paper Table 2)
//!   serve     — start the TCP serving coordinator
//!   bench-client — drive a running server with a synthetic workload
//!   info      — print manifest / artifact info

use asrkf::baselines::make_policy;
use asrkf::config::EngineConfig;
use asrkf::engine::Generator;
use asrkf::error::Result;
use asrkf::runtime::Runtime;
use asrkf::util::cli::Args;
use asrkf::util::logging;

fn main() {
    logging::init();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("generate") => cmd_generate(args),
        Some("passkey") => cmd_passkey(args),
        Some("serve") => cmd_serve(args),
        Some("bench-client") => cmd_bench_client(args),
        Some("info") => cmd_info(args),
        other => {
            eprintln!("usage: asrkf <generate|passkey|serve|bench-client|info> [--flags]");
            if let Some(o) = other {
                eprintln!("unknown subcommand: {o}");
            }
            std::process::exit(2);
        }
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = Runtime::load(args.str_or("artifacts", "artifacts"))?;
    let m = &rt.manifest.model;
    println!(
        "model: vocab={} d_model={} layers={} heads={} d_head={} max_len={}",
        m.vocab, m.d_model, m.n_layers, m.n_heads, m.d_head, m.max_len
    );
    println!("programs:");
    for (name, p) in &rt.manifest.programs {
        println!("  {name}: kind={:?} batch={} file={}", p.kind, p.batch, p.file.display());
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let cfg = EngineConfig::from_args(args)?;
    let policy_name = args.str_or("policy", "asrkf");
    let prompt = args.str_or(
        "prompt",
        "the system routes every request then the scheduler freezes the key value pairs. ",
    );
    let max_new = args.usize_or("max-new-tokens", 200)?;

    // with --spill-persist, re-attach to the spill dir and recover a
    // crashed run's records instead of reclaiming them
    let resume_spill = args.bool("resume-spill");

    // periodic one-line registry summary on the log facade
    asrkf::metrics::start_interval_logger(args.u64_or("metrics-interval", 0)?);

    let rt = Runtime::load(&cfg.artifacts_dir)?;
    let gen = Generator::new(&rt, cfg.clone());
    let policy = make_policy(&policy_name, &cfg.freeze)?;
    let out = gen.generate_with_resume(&prompt, policy, max_new, resume_spill)?;

    println!("--- generated ({} tokens, policy={policy_name}) ---", out.stats.generated_tokens);
    println!("{}", out.text);
    let s = &out.stats;
    println!("--- stats ---");
    println!("total tokens      : {}", s.total_tokens);
    println!("active KV (final) : {}", s.final_active_kv);
    println!("active KV (mean)  : {:.1}", s.mean_active_kv);
    println!("compression       : {:.2}%", s.compression * 100.0);
    println!("freezes/restores  : {}/{}", s.freezes, s.restores);
    println!("recovery events   : {}", s.recovery_interventions);
    if s.offload.recovered_rows > 0 || s.offload.recovery_errors > 0 {
        println!(
            "spill recovery    : {} rows re-attached, {} records rejected",
            s.offload.recovered_rows, s.offload.recovery_errors
        );
    }
    println!(
        "wall {:.2?}  (upload {:.2?}, execute {:.2?}, download {:.2?}, host {:.2?})",
        s.wall, s.upload, s.execute, s.download, s.host
    );
    if let Some(path) = args.str_opt("trace-csv") {
        let rows: Vec<Vec<String>> = out
            .trace
            .iter()
            .map(|t| {
                vec![
                    t.step.to_string(),
                    t.total.to_string(),
                    t.active.to_string(),
                    t.frozen.to_string(),
                    format!("{:.4}", t.entropy),
                    t.froze.to_string(),
                    t.restored.to_string(),
                ]
            })
            .collect();
        asrkf::metrics::write_csv_rows(
            path,
            &["step", "total", "active", "frozen", "entropy", "froze", "restored"],
            &rows,
        )?;
        println!("trace written to {path}");
    }
    if let Some(path) = args.str_opt("trace-out") {
        // flight-recorder timeline + per-step segment spans as Chrome
        // trace-event JSON (open in chrome://tracing or Perfetto)
        asrkf::metrics::write_chrome_trace(path, &out.flight, &out.step_spans)?;
        let seg = &out.stats.segments;
        println!(
            "flight trace written to {path} ({} events, {} steps; segment coverage {:.1}%)",
            out.flight.len(),
            seg.steps,
            seg.coverage() * 100.0
        );
    }
    Ok(())
}

fn cmd_passkey(args: &Args) -> Result<()> {
    let cfg = EngineConfig::from_args(args)?;
    let policy_name = args.str_or("policy", "asrkf");
    let haystack = args.usize_or("haystack", 600)?;
    let seed = args.u64_or("workload-seed", 1)?;

    let rt = Runtime::load(&cfg.artifacts_dir)?;
    let outcome = asrkf::workload::passkey::run_passkey(&rt, &cfg, &policy_name, haystack, seed)?;
    println!("{}", outcome.report());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = EngineConfig::from_args(args)?;
    let server_cfg = asrkf::config::ServerConfig::from_args(args)?;
    asrkf::metrics::start_interval_logger(args.u64_or("metrics-interval", 0)?);
    asrkf::server::serve_blocking(cfg, server_cfg)
}

fn cmd_bench_client(args: &Args) -> Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:7341");
    let n = args.usize_or("requests", 16)?;
    let concurrency = args.usize_or("concurrency", 4)?;
    let max_new = args.usize_or("max-new-tokens", 48)?;
    let class = asrkf::config::QosClass::parse(&args.str_or("class", "standard"))?;
    asrkf::server::client::run_bench_client(&addr, n, concurrency, max_new, class)
}
