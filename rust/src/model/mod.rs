//! Model-side utilities that run on the request path: tokenizer,
//! sampling, logits math. The model weights themselves live inside the
//! AOT-compiled HLO (runtime/).

pub mod logits;
pub mod sampling;
pub mod tokenizer;
