//! Byte-level tokenizer (vocab = 256 raw bytes), matching the python
//! training pipeline. Lossless for arbitrary UTF-8 text.

/// Encode text into token ids (raw bytes).
pub fn encode(text: &str) -> Vec<i32> {
    text.as_bytes().iter().map(|&b| b as i32).collect()
}

/// Decode token ids back into text (lossy on invalid UTF-8 boundaries).
pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens.iter().map(|&t| (t.clamp(0, 255)) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Decode a single token for streaming output (may be a partial UTF-8
/// sequence; callers buffer until valid).
pub fn byte_of(token: i32) -> u8 {
    token.clamp(0, 255) as u8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_roundtrip() {
        let text = "the pass key is 44181.";
        assert_eq!(decode(&encode(text)), text);
    }

    #[test]
    fn utf8_roundtrip() {
        let text = "Бишкек — Kyrgyzstan";
        assert_eq!(decode(&encode(text)), text);
    }

    #[test]
    fn tokens_are_bytes() {
        let toks = encode("ab");
        assert_eq!(toks, vec![97, 98]);
    }

    #[test]
    fn out_of_range_clamped() {
        assert_eq!(byte_of(300), 255);
        assert_eq!(byte_of(-5), 0);
    }
}
