//! Logits utilities: softmax, entropy, argmax — computed on the rust
//! side each step (vocab = 256, negligible cost). The entropy feeds the
//! recovery monitor (paper §3.6).

/// Numerically-stable in-place softmax; returns the log-sum-exp.
pub fn softmax_inplace(logits: &mut [f32]) -> f32 {
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in logits.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in logits.iter_mut() {
        *v /= sum;
    }
    max + sum.ln()
}

/// Shannon entropy (nats) of a probability vector.
pub fn entropy(probs: &[f32]) -> f32 {
    -probs
        .iter()
        .filter(|&&p| p > 1e-12)
        .map(|&p| p * p.ln())
        .sum::<f32>()
}

/// Entropy of raw logits (softmax applied on a scratch copy).
pub fn logits_entropy(logits: &[f32]) -> f32 {
    let mut p = logits.to_vec();
    softmax_inplace(&mut p);
    entropy(&p)
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Max probability after softmax (confidence signal for recovery).
pub fn top1_prob(logits: &[f32]) -> f32 {
    let mut p = logits.to_vec();
    softmax_inplace(&mut p);
    p.iter().copied().fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let mut v = vec![1.0, 2.0, 3.0, 4.0];
        softmax_inplace(&mut v);
        let sum: f32 = v.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(v[3] > v[2] && v[2] > v[1]);
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let mut v = vec![1000.0, 999.0];
        softmax_inplace(&mut v);
        assert!(v.iter().all(|p| p.is_finite()));
        assert!((v[0] + v[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn uniform_entropy_is_log_n() {
        let probs = vec![0.25f32; 4];
        assert!((entropy(&probs) - (4.0f32).ln()).abs() < 1e-6);
    }

    #[test]
    fn deterministic_distribution_has_zero_entropy() {
        let probs = vec![1.0, 0.0, 0.0];
        assert!(entropy(&probs).abs() < 1e-6);
    }

    #[test]
    fn argmax_picks_peak() {
        assert_eq!(argmax(&[0.1, 5.0, -2.0]), 1);
    }
}
