//! Token sampling: temperature / top-k / top-p (paper §4.1 settings),
//! plus greedy decoding (T=0, used by the passkey test, paper Table 2).
//!
//! The sampler owns a `Pcg64` whose draw counter is checkpointable —
//! the RR recovery level rewinds generation by restoring the counter
//! and replaying (util::rng::Pcg64::fast_forward_to).

use crate::config::SamplingConfig;
use crate::model::logits::softmax_inplace;
use crate::util::rng::Pcg64;

pub struct Sampler {
    pub cfg: SamplingConfig,
    rng: Pcg64,
}

/// A checkpoint of the sampler's RNG stream position.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SamplerCheckpoint {
    draws: u64,
}

impl Sampler {
    pub fn new(cfg: SamplingConfig) -> Self {
        let rng = Pcg64::new(cfg.seed);
        Sampler { cfg, rng }
    }

    pub fn checkpoint(&self) -> SamplerCheckpoint {
        SamplerCheckpoint { draws: self.rng.draws }
    }

    /// Raw draw counter (per-token rewind bookkeeping in `Session`).
    pub fn checkpoint_draws(&self) -> u64 {
        self.rng.draws
    }

    /// Rewind to a raw draw counter (RR recovery).
    pub fn rewind_to_draws(&mut self, draws: u64) {
        self.restore(SamplerCheckpoint { draws });
    }

    /// Rewind to a previous stream position (RR recovery).
    pub fn restore(&mut self, cp: SamplerCheckpoint) {
        assert!(cp.draws <= self.rng.draws, "cannot rewind forward");
        let mut fresh = Pcg64::new(self.cfg.seed);
        fresh.fast_forward_to(cp.draws);
        self.rng = fresh;
    }

    /// Sample a token id from raw logits.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        if self.cfg.temperature <= 0.0 {
            return crate::model::logits::argmax(logits);
        }
        let mut probs: Vec<f32> =
            logits.iter().map(|&l| l / self.cfg.temperature).collect();
        softmax_inplace(&mut probs);

        // rank vocabulary by probability (vocab=256; full sort is cheap)
        let mut order: Vec<usize> = (0..probs.len()).collect();
        order.sort_unstable_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());

        // top-k cut
        let k = if self.cfg.top_k == 0 { order.len() } else { self.cfg.top_k.min(order.len()) };
        // top-p (nucleus) cut within the top-k prefix
        let mut kept = 0usize;
        let mut cum = 0.0f32;
        for &idx in order.iter().take(k) {
            kept += 1;
            cum += probs[idx];
            if cum >= self.cfg.top_p {
                break;
            }
        }
        let kept = kept.max(1);

        let total: f32 = order.iter().take(kept).map(|&i| probs[i]).sum();
        let mut u = self.rng.f32() * total;
        for &idx in order.iter().take(kept) {
            u -= probs[idx];
            if u <= 0.0 {
                return idx;
            }
        }
        order[kept - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits_peaked(n: usize, peak: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        v[peak] = 10.0;
        v
    }

    #[test]
    fn greedy_picks_argmax() {
        let mut s = Sampler::new(SamplingConfig::greedy());
        assert_eq!(s.sample(&logits_peaked(256, 42)), 42);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = SamplingConfig { seed: 7, ..SamplingConfig::default() };
        let logits: Vec<f32> = (0..256).map(|i| ((i * 37) % 11) as f32 * 0.3).collect();
        let mut a = Sampler::new(cfg.clone());
        let mut b = Sampler::new(cfg);
        let seq_a: Vec<usize> = (0..50).map(|_| a.sample(&logits)).collect();
        let seq_b: Vec<usize> = (0..50).map(|_| b.sample(&logits)).collect();
        assert_eq!(seq_a, seq_b);
    }

    #[test]
    fn top_k_restricts_support() {
        let cfg = SamplingConfig { temperature: 1.0, top_k: 2, top_p: 1.0, seed: 3 };
        let mut s = Sampler::new(cfg);
        let mut logits = vec![0.0f32; 16];
        logits[3] = 5.0;
        logits[9] = 4.0;
        for _ in 0..200 {
            let t = s.sample(&logits);
            assert!(t == 3 || t == 9, "sampled {t} outside top-2");
        }
    }

    #[test]
    fn top_p_keeps_at_least_one() {
        let cfg = SamplingConfig { temperature: 1.0, top_k: 0, top_p: 0.01, seed: 5 };
        let mut s = Sampler::new(cfg);
        let logits = logits_peaked(64, 7);
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 7);
        }
    }

    #[test]
    fn rewind_replays_stream() {
        let cfg = SamplingConfig { seed: 11, ..SamplingConfig::default() };
        let logits: Vec<f32> = (0..256).map(|i| ((i * 13) % 19) as f32 * 0.2).collect();
        let mut s = Sampler::new(cfg);
        for _ in 0..10 {
            s.sample(&logits);
        }
        let cp = s.checkpoint();
        let expected: Vec<usize> = (0..20).map(|_| s.sample(&logits)).collect();
        s.restore(cp);
        let replayed: Vec<usize> = (0..20).map(|_| s.sample(&logits)).collect();
        assert_eq!(expected, replayed);
    }

    #[test]
    fn temperature_sharpens() {
        // with very low T, almost always the argmax
        let cfg = SamplingConfig { temperature: 0.05, top_k: 0, top_p: 1.0, seed: 13 };
        let mut s = Sampler::new(cfg);
        let mut logits = vec![0.0f32; 32];
        logits[5] = 2.0;
        let hits = (0..200).filter(|_| s.sample(&logits) == 5).count();
        assert!(hits > 190, "hits {hits}");
    }
}
