//! Substrate utilities replacing crates unavailable in the offline
//! environment (DESIGN.md §3): JSON, CLI parsing, RNG, property testing,
//! micro-benchmarking and logging.

pub mod bench;
pub mod bitset;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod tempdir;

pub use tempdir::TempDir;
