//! Dense position bitset for O(1) membership probes on the policy hot
//! path (pending-freeze dedup, per-plan restore marks). `Vec<bool>`
//! would work; packing 64 positions per word keeps the whole set in a
//! few cache lines for realistic budgets and makes `clear_all` a
//! memset.

#[derive(Debug, Clone, Default)]
pub struct BitSet {
    words: Vec<u64>,
}

impl BitSet {
    pub fn new() -> Self {
        BitSet::default()
    }

    /// Ensure the set can index positions `0..bits` (new bits are 0).
    pub fn grow(&mut self, bits: usize) {
        let words = (bits + 63) / 64; // div_ceil needs rust >= 1.73, MSRV is 1.70
        if self.words.len() < words {
            self.words.resize(words, 0);
        }
    }

    /// Set bit `i` (the set must have been grown past `i`).
    pub fn set(&mut self, i: usize) {
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    /// Clear bit `i` (no-op beyond the grown range).
    pub fn clear(&mut self, i: usize) {
        if let Some(w) = self.words.get_mut(i / 64) {
            *w &= !(1u64 << (i % 64));
        }
    }

    /// Bit `i`, false beyond the grown range.
    pub fn get(&self, i: usize) -> bool {
        self.words
            .get(i / 64)
            .map(|w| w & (1u64 << (i % 64)) != 0)
            .unwrap_or(false)
    }

    /// Clear every bit, keeping capacity.
    pub fn clear_all(&mut self) {
        self.words.fill(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_clear() {
        let mut b = BitSet::new();
        b.grow(130);
        assert!(!b.get(0) && !b.get(129));
        b.set(0);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(64) && b.get(129));
        b.clear(64);
        assert!(!b.get(64) && b.get(129));
        b.clear_all();
        assert!(!b.get(0) && !b.get(129));
    }

    #[test]
    fn out_of_range_reads_false() {
        let b = BitSet::new();
        assert!(!b.get(1000));
        let mut b = BitSet::new();
        b.clear(1000); // no-op, no panic
        assert!(!b.get(1000));
    }

    #[test]
    fn grow_is_monotone() {
        let mut b = BitSet::new();
        b.grow(64);
        b.set(63);
        b.grow(10); // never shrinks
        assert!(b.get(63));
    }
}
