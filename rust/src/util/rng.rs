//! PCG-XSH-RR 64/32 pseudo-random generator + sampling helpers.
//!
//! The `rand` crate is unavailable offline; we implement `rand_core`'s
//! `RngCore` over a PCG so any future `rand`-based code interoperates.
//! Deterministic seeding is load-bearing: the RR recovery level rewinds
//! the sampler by reseeding from a recorded stream position, and every
//! bench/workload is reproducible from its seed.

use rand_core::RngCore;

/// PCG-XSH-RR 64/32 (O'Neill 2014), default stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
    /// number of `u32` draws so far — recorded/rewound by RR recovery.
    pub draws: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Self { state: 0, inc, draws: 0 };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.draws = 0;
        rng
    }

    #[inline]
    fn step(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        self.draws += 1;
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) single precision.
    pub fn f32(&mut self) -> f32 {
        (self.step() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [lo, hi) via Lemire's method (hi > lo).
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        let span = hi - lo;
        // rejection-free enough for our span sizes; simple modulo with
        // 64-bit draw keeps bias < 2^-40 for spans < 2^24
        lo + self.next_u64() % span
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.gen_range(0, items.len() as u64) as usize]
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate lambda (Poisson inter-arrival times).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    /// Advance the generator until `draws == target` (target >= draws).
    /// Used by RR recovery to replay the sampler from a checkpoint.
    pub fn fast_forward_to(&mut self, target: u64) {
        while self.draws < target {
            self.step();
        }
    }
}

impl RngCore for Pcg64 {
    fn next_u32(&mut self) -> u32 {
        self.step()
    }
    fn next_u64(&mut self) -> u64 {
        (self.step() as u64) << 32 | self.step() as u64
    }
    fn fill_bytes(&mut self, dest: &mut [u8]) {
        for chunk in dest.chunks_mut(4) {
            let v = self.step().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), rand_core::Error> {
        self.fill_bytes(dest);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_roughly_uniform() {
        let mut r = Pcg64::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Pcg64::new(3);
        for _ in 0..10_000 {
            let v = r.gen_range(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn fast_forward_replays_stream() {
        let mut a = Pcg64::new(5);
        for _ in 0..17 {
            a.next_u32();
        }
        let checkpoint = a.draws;
        let expected = a.next_u32();

        let mut b = Pcg64::new(5);
        b.fast_forward_to(checkpoint);
        assert_eq!(b.next_u32(), expected);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
