//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and a
//! positional subcommand. Typed getters parse on access with
//! contextual error messages.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.str_opt(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{key}: invalid integer '{v}' ({e})")),
        }
    }

    /// Like `usize_or`, but rejects values outside `[lo, hi_incl]`
    /// with a contextual message (bounded knobs like `--shards`, whose
    /// value sizes a persistent worker pool).
    pub fn usize_in(
        &self,
        key: &str,
        default: usize,
        lo: usize,
        hi_incl: usize,
    ) -> Result<usize, String> {
        let v = self.usize_or(key, default)?;
        if v < lo || v > hi_incl {
            return Err(format!("--{key}: {v} outside the supported range [{lo}, {hi_incl}]"));
        }
        Ok(v)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{key}: invalid integer '{v}' ({e})")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{key}: invalid number '{v}' ({e})")),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32, String> {
        self.f64_or(key, default as f64).map(|v| v as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["serve", "--port", "7777", "--verbose", "--tau=0.4"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.usize_or("port", 0).unwrap(), 7777);
        assert!(a.bool("verbose"));
        assert_eq!(a.f64_or("tau", 0.5).unwrap(), 0.4);
    }

    #[test]
    fn defaults() {
        let a = parse(&["gen"]);
        assert_eq!(a.usize_or("steps", 500).unwrap(), 500);
        assert_eq!(a.str_or("policy", "asrkf"), "asrkf");
        assert!(!a.bool("verbose"));
    }

    #[test]
    fn invalid_number_is_error() {
        let a = parse(&["gen", "--steps", "abc"]);
        assert!(a.usize_or("steps", 1).is_err());
    }

    #[test]
    fn bounded_getter_enforces_range() {
        let a = parse(&["serve", "--shards", "4"]);
        assert_eq!(a.usize_in("shards", 1, 1, 64).unwrap(), 4);
        assert!(a.usize_in("shards", 1, 8, 64).is_err());
        // default is returned unchecked-parse but still range-checked
        assert_eq!(a.usize_in("absent", 2, 1, 64).unwrap(), 2);
        let zero = parse(&["serve", "--shards", "0"]);
        assert!(zero.usize_in("shards", 1, 1, 64).is_err());
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse(&["run", "file1", "file2"]);
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }
}
