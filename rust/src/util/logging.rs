//! Minimal `log` facade backend: env-filtered stderr logger.
//!
//! Level comes from `ASRKF_LOG` (error|warn|info|debug|trace), default
//! `info`. Installed once by binaries via `logging::init()`.

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::time::Instant;

static START: once_cell::sync::Lazy<Instant> = once_cell::sync::Lazy::new(Instant::now);

struct StderrLogger;

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.elapsed();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!(
            "[{:>8.3}s {} {}] {}",
            t.as_secs_f64(),
            lvl,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the logger (idempotent).
pub fn init() {
    let level = match std::env::var("ASRKF_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}
