//! Minimal `log` facade backend: env-filtered stderr logger.
//!
//! Level comes from `ASRKF_LOG` (error|warn|info|debug|trace,
//! case-insensitive), default `info`; unrecognized values fall back to
//! `info` with a one-time warning instead of being silently ignored.
//! Installed once by binaries via `logging::init()`.

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::time::Instant;

static START: once_cell::sync::Lazy<Instant> = once_cell::sync::Lazy::new(Instant::now);

struct StderrLogger;

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.elapsed();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!(
            "[{:>8.3}s {} {}] {}",
            t.as_secs_f64(),
            lvl,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Map an `ASRKF_LOG` value to a level filter. The second field is
/// false when the value was present but unrecognized (caller warns).
fn parse_level(value: Option<&str>) -> (LevelFilter, bool) {
    let raw = match value {
        None => return (LevelFilter::Info, true),
        Some(r) => r.trim(),
    };
    if raw.is_empty() {
        return (LevelFilter::Info, true);
    }
    match raw.to_ascii_lowercase().as_str() {
        "error" => (LevelFilter::Error, true),
        "warn" => (LevelFilter::Warn, true),
        "info" => (LevelFilter::Info, true),
        "debug" => (LevelFilter::Debug, true),
        "trace" => (LevelFilter::Trace, true),
        _ => (LevelFilter::Info, false),
    }
}

/// Install the logger (idempotent).
pub fn init() {
    let var = std::env::var("ASRKF_LOG").ok();
    let (level, recognized) = parse_level(var.as_deref());
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
    if !recognized {
        static WARN_ONCE: std::sync::Once = std::sync::Once::new();
        WARN_ONCE.call_once(|| {
            log::warn!(
                "unrecognized ASRKF_LOG value {:?} (expected error|warn|info|debug|trace); defaulting to info",
                var.as_deref().unwrap_or("")
            );
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_levels_parse_case_insensitively() {
        assert_eq!(parse_level(Some("error")), (LevelFilter::Error, true));
        assert_eq!(parse_level(Some("ERROR")), (LevelFilter::Error, true));
        assert_eq!(parse_level(Some("Warn")), (LevelFilter::Warn, true));
        assert_eq!(parse_level(Some("DEBUG")), (LevelFilter::Debug, true));
        assert_eq!(parse_level(Some(" trace ")), (LevelFilter::Trace, true));
        assert_eq!(parse_level(Some("info")), (LevelFilter::Info, true));
    }

    #[test]
    fn absent_or_empty_defaults_quietly() {
        assert_eq!(parse_level(None), (LevelFilter::Info, true));
        assert_eq!(parse_level(Some("")), (LevelFilter::Info, true));
        assert_eq!(parse_level(Some("  ")), (LevelFilter::Info, true));
    }

    #[test]
    fn unrecognized_defaults_with_flag() {
        assert_eq!(parse_level(Some("verbose")), (LevelFilter::Info, false));
        assert_eq!(parse_level(Some("3")), (LevelFilter::Info, false));
    }
}
