//! Micro-benchmark + table-report harness.
//!
//! criterion is unavailable offline; `cargo bench` targets in
//! `rust/benches/` are `harness = false` binaries built on this module.
//! It provides (a) `Bencher` — warmup + timed iterations with robust
//! percentile stats, and (b) `Table`/`Series` — formatted reproduction
//! output matching the paper's tables and figures, also exported as CSV
//! under `artifacts/` for EXPERIMENTS.md.

use std::fmt::Write as _;
use std::time::{Duration, Instant};

/// True when `BENCH_SMOKE=1`: CI schema-check mode. Benches shrink
/// their iteration counts (`smoke_size`) and tolerate a missing
/// runtime by emitting schema-only CSVs (`smoke_schema_only`), so the
/// CI bench-smoke job validates CSV column layouts and the host-only
/// bench paths without a trained artifact set.
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Pick the full-run or smoke-run size for an iteration knob.
pub fn smoke_size(full: usize, smoke_n: usize) -> usize {
    if smoke() {
        smoke_n
    } else {
        full
    }
}

/// Smoke-mode fallback when the PJRT runtime cannot load: write the
/// table's CSV (headers plus any host-only rows already recorded) so
/// the artifact upload still checks the schema, and report why.
pub fn smoke_schema_only(table: &Table, path: &str, why: &str) -> std::io::Result<()> {
    table.write_csv(path)?;
    println!("BENCH_SMOKE: {why}; wrote schema CSV to {path}");
    Ok(())
}

/// RAII wall-clock timer for a named host-only bench section. Dropping
/// it accumulates the elapsed wall-clock into the global metrics
/// registry (`asrkf_bench_section_us{section=...}`); re-entering the
/// same section adds up. Render the end-of-run view with
/// [`section_summary`].
pub struct SectionTimer {
    name: String,
    start: Instant,
}

/// Start timing a named bench section (ends when the guard drops).
pub fn section(name: &str) -> SectionTimer {
    SectionTimer { name: name.to_string(), start: Instant::now() }
}

impl Drop for SectionTimer {
    fn drop(&mut self) {
        let us = self.start.elapsed().as_micros() as f64;
        crate::metrics::Registry::global()
            .publish(|b| b.gauge_add("asrkf_bench_section_us", &[("section", &self.name)], us));
    }
}

/// One end-of-run table of every section recorded in this process,
/// built from the registry (not from scattered locals), sorted by
/// accumulated wall-clock descending.
pub fn section_summary() -> Table {
    let snap = crate::metrics::Registry::global().snapshot();
    let mut sections: Vec<(String, f64)> = snap
        .gauge_series("asrkf_bench_section_us")
        .into_iter()
        .map(|(labels, us)| {
            let name = labels
                .iter()
                .find(|(k, _)| k == "section")
                .map(|(_, v)| v.clone())
                .unwrap_or_default();
            (name, us)
        })
        .collect();
    sections.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
    let mut t = Table::new("Host-only sections (wall-clock)", &["Section", "Wall (ms)"]);
    for (name, us) in sections {
        t.row(&[name, format!("{:.2}", us / 1000.0)]);
    }
    t
}

/// Timing statistics over a set of iterations.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p90: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort();
        let n = samples.len();
        let pick = |q: f64| samples[((n as f64 - 1.0) * q).round() as usize];
        let mean = samples.iter().sum::<Duration>() / n as u32;
        Stats {
            iters: n,
            mean,
            p50: pick(0.5),
            p90: pick(0.9),
            p99: pick(0.99),
            min: samples[0],
            max: samples[n - 1],
        }
    }
}

/// Warmup-then-measure runner.
pub struct Bencher {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 3, iters: 20 }
    }
}

impl Bencher {
    pub fn new(warmup_iters: usize, iters: usize) -> Self {
        Bencher { warmup_iters, iters }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Stats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        let stats = Stats::from_samples(samples);
        println!(
            "{name:<42} mean {:>10.3?}  p50 {:>10.3?}  p99 {:>10.3?}  ({} iters)",
            stats.mean, stats.p50, stats.p99, stats.iters
        );
        stats
    }
}

/// A paper-style results table.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join(" | ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("-+-"));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Write rows as CSV (headers included) for EXPERIMENTS.md
    /// ingestion. Creates the parent directory if missing, so benches
    /// emit CSVs on runners that never ran the artifact pipeline.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        ensure_parent_dir(path)?;
        std::fs::write(path, out)
    }
}

/// A per-step series (figure data), with ASCII sparkline rendering.
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str) -> Series {
        Series { name: name.to_string(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    /// Downsampled ASCII plot: `width` columns, `height` rows.
    pub fn ascii_plot(series: &[&Series], width: usize, height: usize) -> String {
        let all: Vec<(f64, f64)> = series.iter().flat_map(|s| s.points.iter().copied()).collect();
        if all.is_empty() {
            return String::new();
        }
        let (xmin, xmax) = all.iter().fold((f64::MAX, f64::MIN), |(a, b), p| (a.min(p.0), b.max(p.0)));
        let (ymin, ymax) = all.iter().fold((f64::MAX, f64::MIN), |(a, b), p| (a.min(p.1), b.max(p.1)));
        let yspan = (ymax - ymin).max(1e-9);
        let xspan = (xmax - xmin).max(1e-9);
        let mut grid = vec![vec![' '; width]; height];
        let marks = ['*', '+', 'o', 'x'];
        for (si, s) in series.iter().enumerate() {
            for &(x, y) in &s.points {
                let col = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
                let row = height - 1 - (((y - ymin) / yspan) * (height - 1) as f64).round() as usize;
                grid[row][col] = marks[si % marks.len()];
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "y: {ymin:.0}..{ymax:.0}   x: {xmin:.0}..{xmax:.0}");
        for (si, s) in series.iter().enumerate() {
            let _ = writeln!(out, "  [{}] {}", marks[si % marks.len()], s.name);
        }
        for row in grid {
            let _ = writeln!(out, "|{}", row.into_iter().collect::<String>());
        }
        out
    }

    /// Export one or more aligned series as CSV: x,name1,name2...
    pub fn write_csv(series: &[&Series], path: &str) -> std::io::Result<()> {
        let mut out = String::new();
        let names: Vec<&str> = series.iter().map(|s| s.name.as_str()).collect();
        let _ = writeln!(out, "x,{}", names.join(","));
        let n = series.iter().map(|s| s.points.len()).max().unwrap_or(0);
        for i in 0..n {
            let x = series
                .iter()
                .find_map(|s| s.points.get(i).map(|p| p.0))
                .unwrap_or(i as f64);
            let cells: Vec<String> = series
                .iter()
                .map(|s| s.points.get(i).map(|p| format!("{}", p.1)).unwrap_or_default())
                .collect();
            let _ = writeln!(out, "{x},{}", cells.join(","));
        }
        ensure_parent_dir(path)?;
        std::fs::write(path, out)
    }
}

fn ensure_parent_dir(path: &str) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_millis).collect();
        let s = Stats::from_samples(samples);
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.max, Duration::from_millis(100));
        assert_eq!(s.p50, Duration::from_millis(51)); // index round(99*0.5)=50 -> sample 51
        assert_eq!(s.p99, Duration::from_millis(99));
    }

    #[test]
    fn table_render_alignment() {
        let mut t = Table::new("Table 1", &["Method", "Active KV"]);
        t.row(&["Full KV".into(), "514".into()]);
        t.row(&["ASR-KF-EGR".into(), "170".into()]);
        let r = t.render();
        assert!(r.contains("Table 1"));
        assert!(r.contains("ASR-KF-EGR"));
    }

    #[test]
    #[should_panic]
    fn table_row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn series_plot_nonempty() {
        let mut s = Series::new("kv");
        for i in 0..100 {
            s.push(i as f64, (i as f64).sqrt());
        }
        let plot = Series::ascii_plot(&[&s], 40, 10);
        assert!(plot.contains('*'));
    }

    #[test]
    fn bencher_runs() {
        let b = Bencher::new(1, 5);
        let mut count = 0;
        b.run("noop", || count += 1);
        assert_eq!(count, 6);
    }
}
