//! Minimal JSON parser/writer.
//!
//! serde/serde_json are not available in this offline environment
//! (DESIGN.md §3); this module covers what the repo needs: the artifact
//! manifest, the TCP line protocol, and metrics export. It is strict
//! UTF-8 JSON with `\uXXXX` escape support and f64 numbers.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; returns Null for missing keys on non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            self.i += 4;
                            // surrogate pair support
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .and_then(|h| std::str::from_utf8(h).ok())
                                        .ok_or("bad low surrogate")?;
                                    let lo = u32::from_str_radix(hex2, 16).map_err(|e| e.to_string())?;
                                    self.i += 6;
                                    char::from_u32(0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00))
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or("invalid codepoint")?);
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 character
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

/// Serialize compactly (no whitespace).
pub fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(item, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" -1.5e2 ").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(v.get("c"), &Json::Null);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"active":170,"compression":0.6693,"ok":true,"tags":["a","b"],"x":null}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.to_string(), src);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(514.0).to_string(), "514");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
