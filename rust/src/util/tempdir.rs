//! Minimal RAII temporary directory (the `tempfile` crate is
//! unavailable offline). Each instance owns a process- and
//! instance-unique directory under the system temp root and removes it
//! recursively on drop, so parallel tests (and parallel CI jobs on a
//! shared runner) never collide on spill files.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static NEXT_ID: AtomicU64 = AtomicU64::new(0);

#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create `<tmp>/asrkf-<label>-<pid>-<seq>` (label keeps stray
    /// leftovers attributable to the test that leaked them).
    pub fn new(label: &str) -> std::io::Result<TempDir> {
        let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir()
            .join(format!("asrkf-{label}-{}-{id}", std::process::id()));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The path as an owned `String` (`OffloadConfig::spill_dir` shape).
    pub fn path_str(&self) -> String {
        self.path.to_string_lossy().into_owned()
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_unique_dirs_and_cleans_up() {
        let a = TempDir::new("utest").unwrap();
        let b = TempDir::new("utest").unwrap();
        assert_ne!(a.path(), b.path());
        assert!(a.path().is_dir());
        std::fs::write(a.path().join("f.bin"), b"x").unwrap();
        let kept = a.path().to_path_buf();
        drop(a);
        assert!(!kept.exists(), "drop must remove contents recursively");
        assert!(b.path().is_dir(), "sibling dir untouched");
    }
}
