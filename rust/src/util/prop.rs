//! Mini property-based testing harness (proptest is unavailable offline).
//!
//! Usage pattern, mirroring proptest's loop:
//!
//! ```ignore
//! prop_check(128, |g| {
//!     let len = g.usize(1, 100);
//!     let xs = g.vec_f32(len, -1.0, 1.0);
//!     // ... assert invariant, or return Err(msg) ...
//!     Ok(())
//! });
//! ```
//!
//! Each case runs with a distinct deterministic seed; failures report
//! the seed so the case can be replayed exactly. No shrinking — cases
//! are kept small by construction instead.

use super::rng::Pcg64;

/// Value generator handed to each property case.
pub struct G {
    pub rng: Pcg64,
    pub case_seed: u64,
}

impl G {
    pub fn usize(&mut self, lo: usize, hi_incl: usize) -> usize {
        self.rng.gen_range(lo as u64, hi_incl as u64 + 1) as usize
    }

    pub fn u32(&mut self, lo: u32, hi_incl: u32) -> u32 {
        self.rng.gen_range(lo as u64, hi_incl as u64 + 1) as u32
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.rng.f64() < p_true
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi_incl: usize) -> Vec<usize> {
        (0..len).map(|_| self.usize(lo, hi_incl)).collect()
    }

    /// Random subset of 0..n (each element included with probability p).
    pub fn subset(&mut self, n: usize, p: f64) -> Vec<usize> {
        (0..n).filter(|_| self.bool(p)).collect()
    }
}

/// Run `cases` property cases; panics with the failing seed on error.
pub fn prop_check<F>(cases: usize, mut property: F)
where
    F: FnMut(&mut G) -> Result<(), String>,
{
    let base = match std::env::var("ASRKF_PROP_SEED") {
        Ok(s) => s.parse::<u64>().expect("ASRKF_PROP_SEED must be u64"),
        Err(_) => 0x5eed,
    };
    for case in 0..cases {
        let case_seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = G { rng: Pcg64::new(case_seed), case_seed };
        if let Err(msg) = property(&mut g) {
            panic!(
                "property failed on case {case}/{cases} (replay with ASRKF_PROP_SEED={base}, case seed {case_seed:#x}):\n{msg}"
            );
        }
    }
}

/// Assert helper returning Err instead of panicking, for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check(50, |g| {
            count += 1;
            let len = g.usize(0, 10);
            let v = g.vec_f32(len, -1.0, 1.0);
            if v.iter().any(|x| !(-1.0..=1.0).contains(x)) {
                return Err("out of range".into());
            }
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        prop_check(10, |g| {
            let x = g.usize(0, 100);
            if x > 50 {
                return Err(format!("x={x} too big"));
            }
            Ok(())
        });
    }

    #[test]
    fn subset_is_sorted_unique() {
        prop_check(20, |g| {
            let s = g.subset(64, 0.3);
            if s.windows(2).any(|w| w[0] >= w[1]) {
                return Err("not strictly increasing".into());
            }
            Ok(())
        });
    }
}
