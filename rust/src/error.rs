//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`From` impls (no `thiserror`): the build
//! container has no crates.io access and derive macros cannot be
//! vendored as plainly as the facade crates under `rust/vendor/`.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    Xla(xla::Error),
    Io(std::io::Error),
    Manifest(String),
    Config(String),
    Engine(String),
    Server(String),
    Coordinator(String),
    /// Tiered frozen-KV storage (`crate::offload`) failures: double
    /// stash, missing payload, spill-tier I/O.
    Offload(String),
    /// Rows declared lost by a shard rebuild: the shard's worker died
    /// and these positions had no spilled copy to recover from. The
    /// positions are sorted and deduplicated. Unlike `Offload`, this
    /// is a *final* verdict on the named rows — retrying cannot bring
    /// them back — so callers should fail the owning session rather
    /// than the whole process.
    RowsLost(Vec<usize>),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(e) => write!(f, "xla: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Manifest(m) => write!(f, "manifest: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Engine(m) => write!(f, "engine: {m}"),
            Error::Server(m) => write!(f, "server: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator: {m}"),
            Error::Offload(m) => write!(f, "offload: {m}"),
            Error::RowsLost(p) => {
                let shown: Vec<String> = p.iter().take(8).map(|x| x.to_string()).collect();
                let more = if p.len() > 8 { ", .." } else { "" };
                write!(
                    f,
                    "offload: {} row(s) lost to a shard failure (positions [{}{more}])",
                    p.len(),
                    shown.join(", ")
                )
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::Engine(s)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(format!("{}", Error::Offload("x".into())), "offload: x");
        assert_eq!(format!("{}", Error::Engine("y".into())), "engine: y");
    }

    #[test]
    fn rows_lost_display_truncates() {
        let few = Error::RowsLost(vec![3, 7]);
        assert_eq!(
            format!("{few}"),
            "offload: 2 row(s) lost to a shard failure (positions [3, 7])"
        );
        let many = Error::RowsLost((0..12).collect());
        let s = format!("{many}");
        assert!(s.starts_with("offload: 12 row(s) lost"), "{s}");
        assert!(s.contains(", .."), "{s}");
    }

    #[test]
    fn io_error_converts() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(matches!(e, Error::Io(_)));
    }
}
