//! Crate-wide error type.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("xla: {0}")]
    Xla(#[from] xla::Error),

    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    #[error("manifest: {0}")]
    Manifest(String),

    #[error("config: {0}")]
    Config(String),

    #[error("engine: {0}")]
    Engine(String),

    #[error("server: {0}")]
    Server(String),

    #[error("coordinator: {0}")]
    Coordinator(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::Engine(s)
    }
}
