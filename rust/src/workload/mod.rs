//! Workload generation: synthetic template prompts (in-distribution for
//! the stand-in model), passkey retrieval tasks, and Poisson serving
//! traces.

pub mod passkey;
pub mod synthetic;
pub mod trace;
