//! Synthetic workload text generator — the rust port of
//! `python/compile/data.py`'s template corpus, so serving-time prompts
//! are in-distribution for the build-time-trained stand-in model.

use crate::util::rng::Pcg64;

pub const SUBJECTS: &[&str] = &[
    "the model", "the system", "the cache", "a token", "the scheduler",
    "the server", "a request", "the window", "the kernel", "the router",
    "the engine", "a batch", "the queue", "memory", "the process",
    "the network", "a signal", "the buffer", "an index", "the store",
];
pub const VERBS: &[&str] = &[
    "updates", "freezes", "restores", "computes", "routes", "stores",
    "evicts", "scans", "emits", "tracks", "samples", "decodes",
    "encodes", "schedules", "balances", "monitors", "rewrites", "reads",
];
pub const OBJECTS: &[&str] = &[
    "the key value pairs", "the attention scores", "a sliding window",
    "the frozen rows", "the active cache", "every request", "the logits",
    "the relevance signal", "a freeze timer", "the entropy trace",
    "the next token", "the decode step", "the batch queue",
    "the memory budget", "the recovery ladder", "the context",
];
pub const ADVERBS: &[&str] = &[
    "quickly", "slowly", "carefully", "eagerly", "lazily", "often",
    "rarely", "smoothly", "safely", "twice", "in order", "at once",
];
pub const CONNECTIVES: &[&str] = &["then", "meanwhile", "however", "therefore", "later", "next"];

pub const FILLER_SENTENCES: &[&str] = &[
    "the grass is green and the sky is blue here. ",
    "one two three four five six seven eight nine ten. ",
    "the quick brown fox jumps over the lazy dog again. ",
    "rain falls on the hills and rivers run to the sea. ",
    "day follows night and night follows day as always. ",
];

/// One template sentence (mirrors data.py `sentence`).
pub fn sentence(rng: &mut Pcg64) -> String {
    let mut s = format!(
        "{} {} {}",
        rng.choice(SUBJECTS),
        rng.choice(VERBS),
        rng.choice(OBJECTS)
    );
    if rng.f64() < 0.4 {
        s.push(' ');
        s.push_str(*rng.choice(ADVERBS));
    }
    if rng.f64() < 0.3 {
        s.push(' ');
        s.push_str(*rng.choice(CONNECTIVES));
        s.push_str(&format!(
            " {} {} {}",
            rng.choice(SUBJECTS),
            rng.choice(VERBS),
            rng.choice(OBJECTS)
        ));
    }
    s.push_str(". ");
    s
}

/// Template prose of at least `n_bytes` bytes (truncated to exactly).
pub fn prose(rng: &mut Pcg64, n_bytes: usize) -> String {
    let mut out = String::new();
    while out.len() < n_bytes {
        out.push_str(&sentence(rng));
    }
    out.truncate(n_bytes);
    out
}

/// Repetitive haystack filler (mirrors data.py `filler`).
pub fn filler(rng: &mut Pcg64, n_bytes: usize) -> String {
    let mut out = String::new();
    while out.len() < n_bytes {
        out.push_str(*rng.choice(FILLER_SENTENCES));
    }
    out.truncate(n_bytes);
    out
}

/// Passkey retrieval prompt WITHOUT the answer (mirrors
/// data.py `make_passkey_prompt`): the model must produce the digits.
pub fn passkey_prompt(rng: &mut Pcg64, total_len: usize, key: &str) -> String {
    let head = format!("the pass key is {key}. remember it. ");
    let tail = "what is the pass key? the pass key is ";
    let fill = total_len.saturating_sub(head.len() + tail.len());
    format!("{head}{}{tail}", filler(rng, fill))
}

/// A random 5-digit passkey (paper §4.3).
pub fn random_passkey(rng: &mut Pcg64) -> String {
    format!("{}", rng.gen_range(10_000, 100_000))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prose_has_requested_length() {
        let mut rng = Pcg64::new(1);
        assert_eq!(prose(&mut rng, 500).len(), 500);
    }

    #[test]
    fn sentences_are_templates() {
        let mut rng = Pcg64::new(2);
        for _ in 0..20 {
            let s = sentence(&mut rng);
            assert!(s.ends_with(". "));
            assert!(SUBJECTS.iter().any(|sub| s.starts_with(sub)), "{s}");
        }
    }

    #[test]
    fn passkey_prompt_contains_needle_and_query() {
        let mut rng = Pcg64::new(3);
        let p = passkey_prompt(&mut rng, 600, "44181");
        assert!(p.contains("the pass key is 44181. remember it."));
        assert!(p.ends_with("what is the pass key? the pass key is "));
        assert!(!p[40..p.len() - 40].contains("44181"), "answer leaked into filler");
        assert!((590..=610).contains(&p.len()));
    }

    #[test]
    fn random_passkey_is_five_digits() {
        let mut rng = Pcg64::new(4);
        for _ in 0..100 {
            let k = random_passkey(&mut rng);
            assert_eq!(k.len(), 5);
            assert!(k.chars().all(|c| c.is_ascii_digit()));
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let mut a = Pcg64::new(9);
        let mut b = Pcg64::new(9);
        assert_eq!(prose(&mut a, 200), prose(&mut b, 200));
    }
}
