//! Serving workload traces: Poisson arrivals of generation requests
//! with template prompts — drives the serving_throughput bench and the
//! bench-client CLI.

use crate::util::rng::Pcg64;
use crate::workload::synthetic::prose;

#[derive(Debug, Clone)]
pub struct TraceRequest {
    /// arrival offset from trace start, in milliseconds
    pub arrival_ms: u64,
    pub prompt: String,
    pub max_new: usize,
}

/// Generate a Poisson-arrival request trace.
///
/// * `rate_per_s` — mean arrival rate
/// * `n` — number of requests
/// * prompt lengths uniform in [min_prompt, max_prompt] bytes
pub fn poisson_trace(
    seed: u64,
    n: usize,
    rate_per_s: f64,
    min_prompt: usize,
    max_prompt: usize,
    max_new: usize,
) -> Vec<TraceRequest> {
    let mut rng = Pcg64::new(seed);
    let mut t_ms = 0.0f64;
    (0..n)
        .map(|_| {
            t_ms += rng.exponential(rate_per_s) * 1000.0;
            let plen = rng.gen_range(min_prompt as u64, max_prompt as u64 + 1) as usize;
            TraceRequest {
                arrival_ms: t_ms as u64,
                prompt: prose(&mut rng, plen),
                max_new,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_ordered_and_rate_roughly_matches() {
        let tr = poisson_trace(7, 200, 10.0, 32, 64, 16);
        assert_eq!(tr.len(), 200);
        assert!(tr.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
        // 200 arrivals at 10/s ~ 20s span; tolerate 2x spread
        let span_s = tr.last().unwrap().arrival_ms as f64 / 1000.0;
        assert!((10.0..40.0).contains(&span_s), "span {span_s}");
    }

    #[test]
    fn prompts_in_range_and_deterministic() {
        let a = poisson_trace(3, 20, 5.0, 40, 80, 8);
        let b = poisson_trace(3, 20, 5.0, 40, 80, 8);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert!((40..=80).contains(&x.prompt.len()));
        }
    }
}
