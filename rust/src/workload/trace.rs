//! Serving workload traces: Poisson arrivals of generation requests
//! with template prompts — drives the serving_throughput bench and the
//! bench-client CLI.

use crate::util::rng::Pcg64;
use crate::workload::synthetic::prose;

#[derive(Debug, Clone)]
pub struct TraceRequest {
    /// arrival offset from trace start, in milliseconds
    pub arrival_ms: u64,
    pub prompt: String,
    pub max_new: usize,
}

/// Generate a Poisson-arrival request trace.
///
/// * `rate_per_s` — mean arrival rate
/// * `n` — number of requests
/// * prompt lengths uniform in [min_prompt, max_prompt] bytes
pub fn poisson_trace(
    seed: u64,
    n: usize,
    rate_per_s: f64,
    min_prompt: usize,
    max_prompt: usize,
    max_new: usize,
) -> Vec<TraceRequest> {
    let mut rng = Pcg64::new(seed);
    let mut t_ms = 0.0f64;
    (0..n)
        .map(|_| {
            t_ms += rng.exponential(rate_per_s) * 1000.0;
            let plen = rng.gen_range(min_prompt as u64, max_prompt as u64 + 1) as usize;
            TraceRequest {
                arrival_ms: t_ms as u64,
                prompt: prose(&mut rng, plen),
                max_new,
            }
        })
        .collect()
}

/// Periodic burst overlay for [`bursty_trace`]: every `every_s`
/// seconds the arrival rate multiplies by `factor` for `len_s`
/// seconds (the first burst starts at `every_s`, not at t=0).
#[derive(Debug, Clone, Copy)]
pub struct BurstProfile {
    pub every_s: f64,
    pub len_s: f64,
    pub factor: f64,
}

impl BurstProfile {
    fn rate_at(&self, t_s: f64, base_rate: f64) -> f64 {
        if self.every_s <= 0.0 || self.factor <= 1.0 {
            return base_rate;
        }
        let phase = t_s % self.every_s;
        // bursts sit at the end of each period: [every_s - len_s, every_s)
        if phase >= (self.every_s - self.len_s).max(0.0) {
            base_rate * self.factor
        } else {
            base_rate
        }
    }
}

/// Poisson arrivals with periodic bursts: piecewise-constant rate
/// (base between bursts, `base * factor` inside them), sampled by
/// drawing each inter-arrival gap at the rate in effect at the
/// current instant. Same prompt/length model as [`poisson_trace`];
/// `profile.factor <= 1` degenerates to a plain Poisson trace.
pub fn bursty_trace(
    seed: u64,
    n: usize,
    base_rate_per_s: f64,
    profile: BurstProfile,
    prompt_range: (usize, usize),
    max_new: usize,
) -> Vec<TraceRequest> {
    let (min_prompt, max_prompt) = prompt_range;
    let mut rng = Pcg64::new(seed);
    let mut t_s = 0.0f64;
    (0..n)
        .map(|_| {
            let rate = profile.rate_at(t_s, base_rate_per_s);
            t_s += rng.exponential(rate);
            let plen = rng.gen_range(min_prompt as u64, max_prompt as u64 + 1) as usize;
            TraceRequest {
                arrival_ms: (t_s * 1000.0) as u64,
                prompt: prose(&mut rng, plen),
                max_new,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_ordered_and_rate_roughly_matches() {
        let tr = poisson_trace(7, 200, 10.0, 32, 64, 16);
        assert_eq!(tr.len(), 200);
        assert!(tr.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
        // 200 arrivals at 10/s ~ 20s span; tolerate 2x spread
        let span_s = tr.last().unwrap().arrival_ms as f64 / 1000.0;
        assert!((10.0..40.0).contains(&span_s), "span {span_s}");
    }

    #[test]
    fn bursty_trace_compresses_arrivals_inside_bursts() {
        let profile = BurstProfile { every_s: 8.0, len_s: 2.0, factor: 6.0 };
        let tr = bursty_trace(11, 400, 10.0, profile, (32, 64), 16);
        assert_eq!(tr.len(), 400);
        assert!(tr.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
        // mean arrival rate inside burst windows must exceed the rate
        // outside them (the 6x overlay is unmistakable at n=400)
        let in_burst = |ms: u64| {
            let phase = (ms as f64 / 1000.0) % profile.every_s;
            phase >= profile.every_s - profile.len_s
        };
        let (mut burst, mut calm) = (0usize, 0usize);
        for r in &tr {
            if in_burst(r.arrival_ms) {
                burst += 1;
            } else {
                calm += 1;
            }
        }
        // bursts cover 1/4 of the timeline at 6x the rate: expect
        // roughly 2/3 of arrivals inside them; require a strict skew
        assert!(burst > calm, "burst={burst} calm={calm}");
        // degenerate profile reproduces the plain Poisson trace shape
        let flat = BurstProfile { every_s: 0.0, len_s: 0.0, factor: 1.0 };
        let a = bursty_trace(5, 50, 10.0, flat, (32, 64), 16);
        let b = poisson_trace(5, 50, 10.0, 32, 64, 16);
        for (x, y) in a.iter().zip(&b) {
            // same rng draw sequence; accumulation order differs by a
            // float rounding, so allow 1ms of slack on the timestamps
            assert!(x.arrival_ms.abs_diff(y.arrival_ms) <= 1);
            assert_eq!(x.prompt, y.prompt);
        }
    }

    #[test]
    fn prompts_in_range_and_deterministic() {
        let a = poisson_trace(3, 20, 5.0, 40, 80, 8);
        let b = poisson_trace(3, 20, 5.0, 40, 80, 8);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert!((40..=80).contains(&x.prompt.len()));
        }
    }
}
