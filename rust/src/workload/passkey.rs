//! Needle-in-haystack passkey retrieval (paper §4.3, Table 2).
//!
//! A 5-digit passkey is embedded at the start of a filler haystack; the
//! prompt ends with the query. Retrieval succeeds iff the model's
//! greedy continuation starts with the passkey digits. The experiment
//! runs under any `KvPolicy`, so benches can compare ASR-KF-EGR against
//! Full KV (parity is the paper's claim) and against irreversible
//! baselines (which lose the needle).

use crate::baselines::make_policy;
use crate::config::{EngineConfig, SamplingConfig};
use crate::engine::{GenStats, Generator};
use crate::error::Result;
use crate::runtime::Runtime;
use crate::util::rng::Pcg64;
use crate::workload::synthetic::{passkey_prompt, random_passkey};

#[derive(Debug, Clone)]
pub struct PasskeyOutcome {
    pub policy: String,
    pub target: String,
    pub retrieved: String,
    /// end-to-end retrieval: the model's greedy continuation matches
    /// the needle (requires the stand-in model to have copy skill —
    /// see EXPERIMENTS.md Table-2 discussion)
    pub pass: bool,
    /// mechanism-level probe: fraction of the needle's KV rows that are
    /// active or restorable at the end of the run. This is the paper's
    /// §3.3 reversibility claim measured directly: 1.0 for ASR-KF-EGR
    /// and Full KV, < 1.0 for irreversible eviction baselines once the
    /// needle leaves their kept set.
    pub needle_recoverable: f64,
    pub haystack_len: usize,
    pub stats: GenStats,
}

impl PasskeyOutcome {
    pub fn report(&self) -> String {
        format!(
            "passkey[{}] haystack={}B target={} retrieved={:?} -> {} | needle KV recoverable {:.0}% -> {}  (active {}/{}, compression {:.1}%)",
            self.policy,
            self.haystack_len,
            self.target,
            self.retrieved,
            if self.pass { "PASS" } else { "FAIL" },
            self.needle_recoverable * 100.0,
            if self.needle_recoverable == 1.0 { "PASS" } else { "FAIL" },
            self.stats.final_active_kv,
            self.stats.total_tokens,
            self.stats.compression * 100.0,
        )
    }
}

/// Run one passkey retrieval under `policy_name`. Greedy decoding
/// (T = 0), matching the paper's Table 2 setting.
pub fn run_passkey(
    rt: &Runtime,
    cfg: &EngineConfig,
    policy_name: &str,
    haystack_len: usize,
    seed: u64,
) -> Result<PasskeyOutcome> {
    let mut rng = Pcg64::new(seed);
    let target = random_passkey(&mut rng);
    let prompt = passkey_prompt(&mut rng, haystack_len, &target);

    let mut gen_cfg = cfg.clone();
    gen_cfg.sampling = SamplingConfig::greedy();
    let gen = Generator::new(rt, gen_cfg);
    let policy = make_policy(policy_name, &cfg.freeze)?;
    let out = gen.generate(&prompt, policy, 8)?;

    // needle digit positions: "the pass key is " is 16 bytes
    let needle_range = 16usize..21;
    let recoverable = needle_range
        .clone()
        .filter(|&p| {
            matches!(
                out.row_states.get(p),
                Some(crate::engine::generator::RowState::Active)
                    | Some(crate::engine::generator::RowState::Recoverable)
            )
        })
        .count();
    let retrieved: String = out.text.chars().take(5).collect();
    Ok(PasskeyOutcome {
        policy: policy_name.to_string(),
        pass: retrieved == target,
        target,
        retrieved,
        needle_recoverable: recoverable as f64 / needle_range.len() as f64,
        haystack_len,
        stats: out.stats,
    })
}
