//! ASR-KF-EGR: Adaptive Soft Rolling KV Freeze with Entropy-Guided
//! Recovery — a three-layer (rust coordinator / JAX model / Pallas
//! kernel) serving stack reproducing Metinov et al., 2025.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-reproduction results.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod error;
pub mod kv;
pub mod metrics;
pub mod model;
pub mod offload;
pub mod recovery;
pub mod runtime;
pub mod server;
pub mod util;
pub mod workload;
