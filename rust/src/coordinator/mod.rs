//! Serving coordinator: request admission, continuous batching, and
//! the coordinator thread that owns the PJRT runtime.
//!
//! Architecture (one box per thread):
//!
//! ```text
//!   TCP conn threads ──(bounded mpsc)──> coordinator thread
//!        ^                                 BatchEngine: slots + batched
//!        └──(per-request channel)──────────  decode + KV policies
//!                                               │ per-slot
//!                                               ▼
//!                                  offload::ShardedStore (x B slots)
//!                                   N x { hot │ cold(u8) │ spill }
//!                                   budgets partitioned 1/B per slot
//!                                   (then 1/N per shard within it)
//! ```
//!
//! Each slot owns a sharded tiered frozen-row store whose hot/cold
//! byte budgets are the server-wide budgets divided by the batch size
//! (remainder bytes on the leading slots), so one long-context session
//! cannot starve its neighbours' hot tiers; within a slot, positions
//! shard across `OffloadConfig::shards` worker-backed stores so the
//! slot's restore bursts execute in parallel.
//! Retiring sessions fold their staged-hit counters and per-tier
//! restore-latency histograms into `BatchEngine::stats` /
//! `BatchEngine::restore_hist`.

pub mod batcher;
pub mod request;

pub use batcher::BatchEngine;
pub use request::{GenParams, GenRequest, GenResponse};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::time::Instant;

use crate::config::{EngineConfig, ServerConfig};
use crate::error::{Error, Result};

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Client-side handle: submit requests, receive responses.
#[derive(Clone)]
pub struct CoordinatorHandle {
    tx: SyncSender<GenRequest>,
}

impl CoordinatorHandle {
    /// Submit a request; returns the receiver for its response.
    /// Errors immediately when the queue is full (admission control).
    pub fn submit(&self, params: GenParams) -> Result<std::sync::mpsc::Receiver<GenResponse>> {
        let (tx, rx) = std::sync::mpsc::channel();
        let req = GenRequest {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            params,
            arrived: Instant::now(),
            respond: tx,
        };
        self.tx
            .try_send(req)
            .map_err(|e| match e {
                std::sync::mpsc::TrySendError::Full(_) => {
                    Error::Coordinator("queue full (admission control)".into())
                }
                std::sync::mpsc::TrySendError::Disconnected(_) => {
                    Error::Coordinator("coordinator stopped".into())
                }
            })?;
        Ok(rx)
    }

    /// Submit and block for the result.
    pub fn generate_blocking(&self, params: GenParams) -> Result<GenResponse> {
        let rx = self.submit(params)?;
        rx.recv()
            .map_err(|_| Error::Coordinator("coordinator dropped the request".into()))
    }
}

/// Spawn the coordinator thread; returns (handle, join handle).
///
/// Dropping every `CoordinatorHandle` clone disconnects the queue and
/// the thread exits after finishing in-flight sessions.
pub fn spawn(
    cfg: EngineConfig,
    server: ServerConfig,
) -> Result<(CoordinatorHandle, std::thread::JoinHandle<()>)> {
    let (tx, rx): (SyncSender<GenRequest>, Receiver<GenRequest>) =
        sync_channel(server.queue_cap);
    // Engine construction happens inside the thread (PJRT client is not
    // Send), so surface startup errors through a one-shot channel.
    let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Option<String>>();
    let join = std::thread::Builder::new()
        .name("asrkf-coordinator".into())
        .spawn(move || {
            let mut engine = match BatchEngine::new(cfg, server) {
                Ok(e) => {
                    let _ = ready_tx.send(None);
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Some(format!("{e}")));
                    return;
                }
            };
            log::info!(
                "coordinator up: batch={} kv_capacity={}",
                engine.batch_size(),
                engine.kv_capacity()
            );
            engine.run(rx);
            log::info!(
                "coordinator down: {} completed, {} rejected, {} tokens, mean batch occupancy {:.2}",
                engine.stats.requests_completed,
                engine.stats.requests_rejected,
                engine.stats.tokens_generated,
                engine.stats.mean_batch_occupancy()
            );
            log::info!("{}", engine.ttft_hist.summary("ttft"));
            log::info!("{}", engine.e2e_hist.summary("e2e"));
            log::info!("{}", engine.step_hist.summary("step"));
            log::info!(
                "offload: staged hits {} / misses {}",
                engine.stats.staged_hits,
                engine.stats.staged_misses
            );
            log::info!(
                "restore batching: {} rows over {} spans",
                engine.batch_stats.restore_rows,
                engine.batch_stats.restore_spans
            );
            log::info!("{}", engine.batch_stats.restore_batch.summary("restore batch rows"));
            log::info!("{}", engine.restore_hist.hot.summary("restore(hot)"));
            log::info!("{}", engine.restore_hist.cold.summary("restore(cold)"));
            log::info!("{}", engine.plan_hist.summary("plan+observe"));
        })
        .map_err(Error::Io)?;
    match ready_rx.recv() {
        Ok(None) => Ok((CoordinatorHandle { tx }, join)),
        Ok(Some(err)) => Err(Error::Coordinator(err)),
        Err(_) => Err(Error::Coordinator("coordinator thread died at startup".into())),
    }
}
