//! Serving coordinator: QoS-aware continuous batching — per-class
//! priority queues, projection-based admission control, and the
//! coordinator thread that owns the PJRT runtime.
//!
//! Architecture (one box per thread):
//!
//! ```text
//!   TCP conn threads ──(bounded mpsc)──> coordinator thread
//!        ^                                 ClassQueues: Interactive |
//!        │                                   Standard | Batch
//!        │                                 AdmissionController:
//!        │                                   project hot slices, shed
//!        │                                   or typed-reject
//!        └──(per-request channel,          BatchEngine: slots + batched
//!            handed out as a Ticket)────────  decode + KV policies
//!                                               │ per occupied slot
//!                                               ▼
//!                                  offload::ShardedStore (x occupied)
//!                                   N x { hot │ cold(u8) │ spill }
//!                                   budgets split by class weight over
//!                                   occupied slots, reflowed at step
//!                                   boundaries (then 1/N per shard)
//! ```
//!
//! Requests carry a [`crate::config::QosClass`] and wait in per-class
//! FIFO queues; the scheduler always admits from the highest-priority
//! non-empty queue. Before a request takes a slot the admission
//! controller projects the class-weighted hot-tier split over the
//! would-be slot population and rejects (or sheds to a lower class)
//! when any slice falls below the envelope — surfaced to the caller as
//! a typed reject on the response. Occupied slots split the server-wide
//! tier budgets by class weight ([`crate::config::weighted_shares`]);
//! when a session retires, its budget reflows to the remaining slots at
//! the next step boundary (`Session::reslice_budgets`). Equal weights
//! reproduce the old static `1/B` split exactly. See `README.md` in
//! this directory for the projection math and reflow rules.
//!
//! Retiring sessions fold their staged-hit counters and per-tier
//! restore-latency histograms into `BatchEngine::stats` /
//! `BatchEngine::restore_hist`.

pub mod batcher;
pub mod qos;
pub mod request;

pub use batcher::BatchEngine;
pub use qos::{Admission, AdmissionController, ClassQueues};
pub use request::{GenParams, GenParamsBuilder, GenRequest, GenResponse, Reject, RejectReason};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::time::Instant;

use crate::config::{EngineConfig, ServerConfig};
use crate::error::{Error, Result};

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// A submitted request: its assigned id plus the channel its response
/// will arrive on. The id is the cancellation / correlation seam —
/// it is already stamped on the eventual [`GenResponse`] and every
/// log line about the request.
#[derive(Debug)]
pub struct Ticket {
    pub id: u64,
    pub rx: std::sync::mpsc::Receiver<GenResponse>,
}

impl Ticket {
    /// Block until the response lands.
    pub fn wait(self) -> Result<GenResponse> {
        self.rx
            .recv()
            .map_err(|_| Error::Coordinator("coordinator dropped the request".into()))
    }
}

/// Client-side handle: submit requests, receive responses.
#[derive(Clone)]
pub struct CoordinatorHandle {
    tx: SyncSender<GenRequest>,
}

impl CoordinatorHandle {
    /// Submit a request; returns its [`Ticket`]. Errors immediately
    /// when the handoff channel is full (back-pressure); per-class
    /// queue overflow and envelope rejects arrive asynchronously as
    /// typed rejects on the ticket instead.
    pub fn submit(&self, params: GenParams) -> Result<Ticket> {
        let (tx, rx) = std::sync::mpsc::channel();
        let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
        let req = GenRequest { id, params, arrived: Instant::now(), respond: tx };
        self.tx
            .try_send(req)
            .map_err(|e| match e {
                std::sync::mpsc::TrySendError::Full(_) => {
                    Error::Coordinator("queue full (admission control)".into())
                }
                std::sync::mpsc::TrySendError::Disconnected(_) => {
                    Error::Coordinator("coordinator stopped".into())
                }
            })?;
        Ok(Ticket { id, rx })
    }

    /// Submit and block for the result.
    pub fn generate_blocking(&self, params: GenParams) -> Result<GenResponse> {
        self.submit(params)?.wait()
    }
}

/// Spawn the coordinator thread; returns (handle, join handle).
///
/// Dropping every `CoordinatorHandle` clone disconnects the queue and
/// the thread exits after draining the class queues and finishing
/// in-flight sessions.
pub fn spawn(
    cfg: EngineConfig,
    server: ServerConfig,
) -> Result<(CoordinatorHandle, std::thread::JoinHandle<()>)> {
    let (tx, rx): (SyncSender<GenRequest>, Receiver<GenRequest>) =
        sync_channel(server.queue_cap);
    // Engine construction happens inside the thread (PJRT client is not
    // Send), so surface startup errors through a one-shot channel.
    let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Option<String>>();
    let join = std::thread::Builder::new()
        .name("asrkf-coordinator".into())
        .spawn(move || {
            let mut engine = match BatchEngine::new(cfg, server) {
                Ok(e) => {
                    let _ = ready_tx.send(None);
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Some(format!("{e}")));
                    return;
                }
            };
            log::info!(
                "coordinator up: batch={} kv_capacity={}",
                engine.batch_size(),
                engine.kv_capacity()
            );
            engine.run(rx);
            log::info!(
                "coordinator down: {} completed, {} rejected, {} shed, {} tokens, \
                 mean batch occupancy {:.2}",
                engine.stats.requests_completed,
                engine.stats.requests_rejected,
                engine.stats.requests_shed,
                engine.stats.tokens_generated,
                engine.stats.mean_batch_occupancy()
            );
            log::info!("{}", engine.ttft_hist.summary("ttft"));
            log::info!("{}", engine.e2e_hist.summary("e2e"));
            log::info!("{}", engine.queue_wait_hist.summary("queue wait"));
            log::info!("{}", engine.step_hist.summary("step"));
            log::info!(
                "offload: staged hits {} / misses {}",
                engine.stats.staged_hits,
                engine.stats.staged_misses
            );
            log::info!(
                "restore batching: {} rows over {} spans",
                engine.batch_stats.restore_rows,
                engine.batch_stats.restore_spans
            );
            log::info!("{}", engine.batch_stats.restore_batch.summary("restore batch rows"));
            log::info!("{}", engine.restore_hist.hot.summary("restore(hot)"));
            log::info!("{}", engine.restore_hist.cold.summary("restore(cold)"));
            log::info!("{}", engine.plan_hist.summary("plan+observe"));
        })
        .map_err(Error::Io)?;
    match ready_rx.recv() {
        Ok(None) => Ok((CoordinatorHandle { tx }, join)),
        Ok(Some(err)) => Err(Error::Coordinator(err)),
        Err(_) => Err(Error::Coordinator("coordinator thread died at startup".into())),
    }
}
