//! Continuous batching engine: the vLLM-style serving core.
//!
//! One coordinator thread owns the PJRT runtime, a persistent batched
//! KV buffer with `B` session slots, and the request loop:
//!
//!   1. admit queued requests into free slots (prefill via the B=1
//!      prefill bucket, rows copied into the slot),
//!   2. run ONE batched decode step for all occupied slots,
//!   3. per-slot policy bookkeeping — each slot's freezes and restores
//!      execute as one batch against the shared cache (contiguous
//!      position runs coalesce into span copies, see
//!      `engine::layout::scatter_rows`),
//!   4. retire finished sessions and answer their channels.
//!
//! Sessions join and leave between steps — decode never waits for the
//! batch to fill (continuous batching, not static batching).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use crate::baselines::make_policy;
use crate::config::{EngineConfig, ServerConfig};
use crate::coordinator::request::{GenRequest, GenResponse};
use crate::engine::layout::{insert_prefill, KvGeom};
use crate::engine::session::Session;
use crate::error::{Error, Result};
use crate::metrics::{
    BatchStats, Histogram, Registry, RestoreLatency, ServingStats, TierOccupancy,
};
use crate::model::tokenizer;
use crate::runtime::{DecodeInputs, DecodeProgram, Runtime};

struct Slot {
    session: Session,
    arrived: Instant,
    first_token_at: Option<Instant>,
    respond: std::sync::mpsc::Sender<GenResponse>,
    id: u64,
}

pub struct BatchEngine {
    rt: Runtime,
    cfg: EngineConfig,
    decode: std::rc::Rc<DecodeProgram>,
    geom: KvGeom,
    kv: Vec<f32>,
    slots: Vec<Option<Slot>>,
    /// per-slot plan buffers, refilled in place each step so plan
    /// construction never allocates in steady state
    plan_bufs: Vec<crate::kv::Plan>,
    pub stats: ServingStats,
    pub ttft_hist: Histogram,
    pub e2e_hist: Histogram,
    pub step_hist: Histogram,
    /// per-step policy control-plane time merged from retired sessions
    pub plan_hist: Histogram,
    /// per-tier restore latencies merged from retired sessions
    pub restore_hist: RestoreLatency,
    /// plan-batching telemetry merged from retired sessions
    pub batch_stats: BatchStats,
}

impl BatchEngine {
    pub fn new(cfg: EngineConfig, server: ServerConfig) -> Result<Self> {
        let rt = Runtime::load(&cfg.artifacts_dir)?;
        let model = rt.manifest.model.clone();
        // pick the decode bucket whose batch matches max_batch (largest
        // batch <= max_batch available in the manifest)
        let decode = {
            let spec = rt
                .manifest
                .programs
                .values()
                .filter_map(|p| match p.kind {
                    crate::runtime::ProgramKind::Decode { .. }
                        if p.batch <= server.max_batch && p.batch > 1 =>
                    {
                        Some((p.batch, p.name.clone()))
                    }
                    _ => None,
                })
                .max_by_key(|(b, _)| *b)
                .ok_or_else(|| {
                    Error::Coordinator(format!(
                        "no batched decode bucket with batch <= {}",
                        server.max_batch
                    ))
                })?;
            rt.decode_program(&spec.1)?
        };
        let geom = KvGeom::new(&model, decode.batch, decode.kv_len);
        let kv = vec![0.0f32; geom.floats()];
        let slots = (0..decode.batch).map(|_| None).collect();
        let plan_bufs = (0..decode.batch).map(|_| crate::kv::Plan::default()).collect();
        Ok(BatchEngine {
            rt,
            cfg,
            decode,
            geom,
            kv,
            slots,
            plan_bufs,
            stats: ServingStats::default(),
            ttft_hist: Histogram::default(),
            e2e_hist: Histogram::default(),
            step_hist: Histogram::default(),
            plan_hist: Histogram::default(),
            restore_hist: RestoreLatency::default(),
            batch_stats: BatchStats::default(),
        })
    }

    pub fn batch_size(&self) -> usize {
        self.slots.len()
    }

    pub fn kv_capacity(&self) -> usize {
        self.decode.kv_len
    }

    fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Serve until `rx` disconnects and all in-flight sessions finish.
    pub fn run(&mut self, rx: Receiver<GenRequest>) {
        let mut disconnected = false;
        loop {
            // admit as many requests as there are free slots
            while self.occupied() < self.slots.len() && !disconnected {
                match rx.try_recv() {
                    Ok(req) => self.admit(req),
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        disconnected = true;
                    }
                }
            }
            if self.occupied() == 0 {
                if disconnected {
                    return;
                }
                // idle: block for the next request
                match rx.recv_timeout(Duration::from_millis(100)) {
                    Ok(req) => self.admit(req),
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => return,
                }
                continue;
            }
            if let Err(e) = self.step() {
                log::error!("batched decode step failed: {e}");
                self.fail_all(&format!("engine failure: {e}"));
            }
        }
    }

    /// Admit one request: prefill and bind to a free slot.
    fn admit(&mut self, req: GenRequest) {
        let slot_idx = match self.slots.iter().position(|s| s.is_none()) {
            Some(i) => i,
            None => {
                let _ = req
                    .respond
                    .send(GenResponse::error(req.id, "no free slot (admission bug)"));
                return;
            }
        };
        match self.prefill_into_slot(&req, slot_idx) {
            Ok(()) => {}
            Err(e) => {
                self.stats.requests_rejected += 1;
                Registry::global().counter_add("asrkf_requests_rejected_total", &[], 1);
                let _ = req.respond.send(GenResponse::error(req.id, format!("{e}")));
            }
        }
    }

    fn prefill_into_slot(&mut self, req: &GenRequest, slot_idx: usize) -> Result<()> {
        let model = self.rt.manifest.model.clone();
        let tokens = tokenizer::encode(&req.params.prompt);
        if tokens.is_empty() {
            return Err(Error::Coordinator("empty prompt".into()));
        }
        let need = tokens.len() + req.params.max_new;
        if need > self.decode.kv_len {
            return Err(Error::Coordinator(format!(
                "request needs {need} KV rows, bucket capacity is {} (admission control)",
                self.decode.kv_len
            )));
        }
        let prefill = self.rt.prefill_for(tokens.len())?;
        let l = prefill.len;
        let mut padded = tokens.clone();
        padded.resize(l, b' ' as i32);
        let pf = prefill.run(&padded, &[tokens.len() as i32])?;
        self.stats.prefill_tokens += tokens.len() as u64;
        Registry::global().counter_add("asrkf_prefill_tokens_total", &[], tokens.len() as u64);

        insert_prefill(&mut self.kv, &self.geom, slot_idx, &pf.kv, l, tokens.len());

        let mut cfg = self.cfg.clone();
        cfg.sampling.seed = req.params.seed;
        // per-slot budget partition: B sessions share the configured
        // offload byte budgets (remainder bytes land on the leading
        // slots). Each slot's session then shards its slice across
        // `cfg.offload.shards` worker-backed stores, so a slot's
        // restore bursts parallelize without touching its neighbours.
        cfg.offload = cfg.offload.partitioned(self.slots.len(), slot_idx);
        // persistent spill: each slot owns a subdirectory, so slot
        // stores never share manifests or record files (the manifest's
        // one-writer-per-directory contract). A restarted coordinator
        // re-attaches to the same slot dirs — reclaiming dead
        // sessions' records by default, recovering them when the
        // request asks to resume. The slot dir carries no per-session
        // identity: resume_spill asserts the request continues the
        // sequence whose rows were left in this slot.
        if cfg.offload.spill_persist {
            if let Some(dir) = &cfg.offload.spill_dir {
                let slot_dir = std::path::Path::new(dir).join(format!("slot-{slot_idx}"));
                cfg.offload.spill_dir = Some(slot_dir.to_string_lossy().into_owned());
            }
        }
        let resume = req.params.resume_spill && cfg.offload.spill_persist;
        let policy = make_policy(&req.params.policy, &cfg.freeze)
            .map_err(Error::Coordinator)?;
        let mut session = if resume {
            Session::resume(
                req.id,
                tokens.clone(),
                req.params.max_new,
                policy,
                &cfg,
                self.decode.kv_len,
                model.kv_row_floats,
            )?
        } else {
            Session::new(
                req.id,
                tokens.clone(),
                req.params.max_new,
                policy,
                &cfg,
                self.decode.kv_len,
                model.kv_row_floats,
            )?
        };
        session.seed_prefill(pf.logits_last, &pf.scores_last, tokens.len());

        self.slots[slot_idx] = Some(Slot {
            session,
            arrived: req.arrived,
            first_token_at: None,
            respond: req.respond.clone(),
            id: req.id,
        });
        Ok(())
    }

    /// One batched decode step over all occupied slots.
    pub fn step(&mut self) -> Result<()> {
        let t0 = Instant::now();
        let b = self.slots.len();
        let s = self.decode.kv_len;
        let r = self.cfg.freeze.r_budget.min(self.decode.r_budget.max(1));

        let mut tokens = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut mask = vec![0.0f32; b * s];
        let mut planned = vec![false; b];

        let mut failed: Vec<(usize, String)> = Vec::new();
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if let Some(slot) = slot {
                let sess = &mut slot.session;
                tokens[i] = sess.next_token();
                // per-slot freeze/restore data movement on the shared
                // cache; the slot's plan buffer is refilled in place
                match sess.apply_plan(&mut self.kv, &self.geom, i, r, &mut self.plan_bufs[i]) {
                    Ok(()) => {
                        pos[i] = sess.len as i32;
                        mask[i * s..(i + 1) * s].copy_from_slice(&sess.mask);
                        planned[i] = true;
                    }
                    // offload failure (storage invariant / spill I/O):
                    // fail this session, keep the rest of the batch
                    Err(e) => failed.push((i, format!("{e}"))),
                }
            }
            // free slots decode a dummy token at pos 0; outputs ignored
            // and their KV rows are overwritten on the next prefill.
        }
        for (i, msg) in failed {
            log::error!("slot {i}: retiring session after storage failure: {msg}");
            if let Some(slot) = self.slots[i].take() {
                let _ = slot.respond.send(GenResponse::error(slot.id, msg));
            }
        }
        if !planned.iter().any(|&p| p) {
            return Ok(()); // every occupied slot failed this step
        }

        let out = self.decode.run(&DecodeInputs {
            tokens: &tokens,
            kv: &self.kv,
            mask: &mask,
            pos: &pos,
        })?;
        self.stats.batches_dispatched += 1;
        self.stats.batch_occupancy_sum += self.occupied() as u64;
        Registry::global().publish(|reg| {
            reg.counter_add("asrkf_batches_dispatched_total", &[], 1);
            reg.count_record("asrkf_batch_occupancy", &[], self.occupied() as u64);
        });

        let model_vocab = self.rt.manifest.model.vocab;
        let now = Instant::now();
        for i in 0..b {
            if !planned[i] {
                continue;
            }
            let plan = &self.plan_bufs[i];
            let slot_pos = pos[i] as usize;
            // write the new KV row for this lane
            crate::engine::layout::write_new_row(
                &mut self.kv, &self.geom, i, slot_pos, &out.k_new, &out.v_new,
            );
            let absorb_err = {
                let slot = self.slots[i].as_mut().unwrap();
                let sess = &mut slot.session;
                let logits = out.logits[i * model_vocab..(i + 1) * model_vocab].to_vec();
                let scores = &out.scores[i * s..(i + 1) * s];
                // recovery in batched mode: SR/WR/FR apply via policy; RR
                // is disabled (rewalk would stall the whole batch —
                // documented); the returned action is therefore unused
                sess.absorb(tokens[i], logits, scores, plan, out.timing, Duration::ZERO)
                    .err()
            };
            if let Some(e) = absorb_err {
                log::error!("slot {i}: retiring session after staging failure: {e}");
                if let Some(slot) = self.slots[i].take() {
                    let _ = slot.respond.send(GenResponse::error(slot.id, format!("{e}")));
                }
                continue;
            }
            let slot = self.slots[i].as_mut().unwrap();
            let sess = &mut slot.session;
            if slot.first_token_at.is_none() {
                slot.first_token_at = Some(now);
                self.ttft_hist.record(now - slot.arrived);
                Registry::global().time_record("asrkf_ttft_us", &[], now - slot.arrived);
            }
            self.stats.tokens_generated += 1;
            Registry::global().counter_add("asrkf_tokens_generated_total", &[], 1);

            if sess.is_done() {
                let e2e = now - slot.arrived;
                self.e2e_hist.record(e2e);
                // land in-flight speculative restores before reading
                // the retiring store's counters — a shard out with a
                // worker is invisible to the aggregates below
                if let Err(e) = sess.store.settle() {
                    log::error!("slot {i}: settling restore pipeline at retirement: {e}");
                }
                // fold the retiring session's offload telemetry into
                // the engine-wide aggregates and the process registry
                // (flows only: the retiring store's gauges are stale by
                // definition — live occupancy is published per step)
                sess.publish_to_registry(Registry::global());
                Registry::global().publish(|reg| {
                    reg.counter_add("asrkf_requests_completed_total", &[], 1);
                    reg.time_record("asrkf_e2e_us", &[], e2e);
                });
                let offload = sess.offload_summary();
                self.stats.staged_hits += offload.staged_hits;
                self.stats.staged_misses += offload.staged_misses;
                self.restore_hist.merge(&sess.store.restore_latency());
                self.plan_hist.merge(&sess.plan_hist);
                // batch_stats is the single aggregate of per-session
                // batching counters (rows/spans live there)
                self.batch_stats.merge(&sess.batch);
                let plan_latency = sess.plan_latency();
                let resp = GenResponse {
                    id: slot.id,
                    text: sess.generated_text(),
                    error: None,
                    prompt_tokens: sess.prompt_len,
                    generated_tokens: sess.generated(),
                    final_active_kv: sess.active_kv(),
                    compression: 1.0 - sess.active_kv() as f64 / sess.len.max(1) as f64,
                    ttft: slot.first_token_at.unwrap() - slot.arrived,
                    e2e,
                    offload,
                    plan_latency,
                };
                let _ = slot.respond.send(resp);
                self.stats.requests_completed += 1;
                self.slots[i] = None;
            }
        }
        // live occupancy across every occupied slot, summed per tier.
        // Published without a shard label: slot stores partition one
        // budget, so per-shard gauge series would collide across slots.
        let mut occ = TierOccupancy::default();
        for slot in self.slots.iter().flatten() {
            let o = slot.session.store.occupancy();
            occ.hot_rows += o.hot_rows;
            occ.hot_bytes += o.hot_bytes;
            occ.cold_rows += o.cold_rows;
            occ.cold_bytes += o.cold_bytes;
            occ.spill_rows += o.spill_rows;
            occ.spill_bytes += o.spill_bytes;
        }
        Registry::global().publish(|reg| {
            for (tier, rows, bytes) in [
                ("hot", occ.hot_rows, occ.hot_bytes),
                ("cold", occ.cold_rows, occ.cold_bytes),
                ("spill", occ.spill_rows, occ.spill_bytes),
            ] {
                reg.gauge_set("asrkf_tier_rows", &[("tier", tier)], rows as f64);
                reg.gauge_set("asrkf_tier_bytes", &[("tier", tier)], bytes as f64);
            }
        });
        self.step_hist.record(t0.elapsed());
        Ok(())
    }

    fn fail_all(&mut self, msg: &str) {
        for slot in self.slots.iter_mut() {
            if let Some(s) = slot.take() {
                let _ = s.respond.send(GenResponse::error(s.id, msg));
            }
        }
    }
}
