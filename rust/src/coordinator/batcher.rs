//! Continuous batching engine: the vLLM-style serving core, QoS-aware.
//!
//! One coordinator thread owns the PJRT runtime, a persistent batched
//! KV buffer with `B` session slots, and the request loop:
//!
//!   1. drain arrivals into per-class priority queues
//!      ([`ClassQueues`]); overflow is a typed `queue_full` reject,
//!   2. admit from the highest-priority queue into free slots while the
//!      admission projection holds ([`AdmissionController`]: every
//!      occupied slot's class-weighted hot slice must clear the
//!      envelope, with shed-to-lower-class before reject), prefill via
//!      the B=1 prefill bucket,
//!   3. reflow tier budgets at the step boundary when the slot
//!      population changed (`Session::reslice_budgets` — freed budget
//!      from retired sessions flows to the occupied slots),
//!   4. run ONE batched decode step for all occupied slots,
//!   5. per-slot policy bookkeeping — each slot's freezes and restores
//!      execute as one batch against the shared cache (contiguous
//!      position runs coalesce into span copies, see
//!      `engine::layout::scatter_rows`),
//!   6. retire finished sessions and answer their channels.
//!
//! Sessions join and leave between steps — decode never waits for the
//! batch to fill (continuous batching, not static batching).

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

use crate::baselines::make_policy;
use crate::config::{EngineConfig, QosClass, ServerConfig};
use crate::coordinator::qos::{Admission, AdmissionController, ClassQueues};
use crate::coordinator::request::{GenRequest, GenResponse, Reject, RejectReason};
use crate::engine::layout::{insert_prefill, KvGeom};
use crate::engine::session::Session;
use crate::error::{Error, Result};
use crate::metrics::{
    BatchStats, Histogram, Registry, RestoreLatency, ServingStats, TierOccupancy,
};
use crate::model::tokenizer;
use crate::runtime::{DecodeInputs, DecodeProgram, Runtime};

struct Slot {
    session: Session,
    arrived: Instant,
    first_token_at: Option<Instant>,
    respond: std::sync::mpsc::Sender<GenResponse>,
    id: u64,
    /// Effective QoS class (after any admission shed): scheduling
    /// weight for budget reflow and the `class` label on this slot's
    /// latency series.
    class: QosClass,
}

pub struct BatchEngine {
    rt: Runtime,
    cfg: EngineConfig,
    decode: std::rc::Rc<DecodeProgram>,
    geom: KvGeom,
    kv: Vec<f32>,
    slots: Vec<Option<Slot>>,
    /// Occupied-slot count maintained on admit/retire so the hot loop
    /// never rescans `slots` (it used to, several times per step).
    occupied_count: usize,
    /// Slot population changed since the last step boundary — budgets
    /// need a reflow before the next decode.
    rebalance_pending: bool,
    /// Per-class arrival queues, popped in priority order.
    queues: ClassQueues<GenRequest>,
    admission: AdmissionController,
    /// per-slot plan buffers, refilled in place each step so plan
    /// construction never allocates in steady state
    plan_bufs: Vec<crate::kv::Plan>,
    pub stats: ServingStats,
    pub ttft_hist: Histogram,
    pub e2e_hist: Histogram,
    /// time from submit to slot admission (queue wait, all classes;
    /// per-class distributions go to the registry)
    pub queue_wait_hist: Histogram,
    pub step_hist: Histogram,
    /// per-step policy control-plane time merged from retired sessions
    pub plan_hist: Histogram,
    /// per-tier restore latencies merged from retired sessions
    pub restore_hist: RestoreLatency,
    /// plan-batching telemetry merged from retired sessions
    pub batch_stats: BatchStats,
}

impl BatchEngine {
    pub fn new(cfg: EngineConfig, server: ServerConfig) -> Result<Self> {
        let rt = Runtime::load(&cfg.artifacts_dir)?;
        let model = rt.manifest.model.clone();
        // pick the decode bucket whose batch matches max_batch (largest
        // batch <= max_batch available in the manifest)
        let decode = {
            let spec = rt
                .manifest
                .programs
                .values()
                .filter_map(|p| match p.kind {
                    crate::runtime::ProgramKind::Decode { .. }
                        if p.batch <= server.max_batch && p.batch > 1 =>
                    {
                        Some((p.batch, p.name.clone()))
                    }
                    _ => None,
                })
                .max_by_key(|(b, _)| *b)
                .ok_or_else(|| {
                    Error::Coordinator(format!(
                        "no batched decode bucket with batch <= {}",
                        server.max_batch
                    ))
                })?;
            rt.decode_program(&spec.1)?
        };
        let geom = KvGeom::new(&model, decode.batch, decode.kv_len);
        let kv = vec![0.0f32; geom.floats()];
        let slots = (0..decode.batch).map(|_| None).collect();
        let plan_bufs = (0..decode.batch).map(|_| crate::kv::Plan::default()).collect();
        let admission =
            AdmissionController::new(server.qos.clone(), &cfg.offload, model.kv_row_floats);
        let queues = ClassQueues::new(server.qos.queue_depth);
        Ok(BatchEngine {
            rt,
            cfg,
            decode,
            geom,
            kv,
            slots,
            occupied_count: 0,
            rebalance_pending: false,
            queues,
            admission,
            plan_bufs,
            stats: ServingStats::default(),
            ttft_hist: Histogram::default(),
            e2e_hist: Histogram::default(),
            queue_wait_hist: Histogram::default(),
            step_hist: Histogram::default(),
            plan_hist: Histogram::default(),
            restore_hist: RestoreLatency::default(),
            batch_stats: BatchStats::default(),
        })
    }

    pub fn batch_size(&self) -> usize {
        self.slots.len()
    }

    pub fn kv_capacity(&self) -> usize {
        self.decode.kv_len
    }

    fn occupied(&self) -> usize {
        debug_assert_eq!(
            self.occupied_count,
            self.slots.iter().filter(|s| s.is_some()).count(),
            "occupancy counter out of sync with the slot array"
        );
        self.occupied_count
    }

    /// Vacate slot `i` (retire/fail): keeps the occupancy counter in
    /// sync and marks the budgets for reflow at the next step boundary.
    fn clear_slot(&mut self, i: usize) -> Option<Slot> {
        let slot = self.slots[i].take();
        if slot.is_some() {
            self.occupied_count -= 1;
            self.rebalance_pending = true;
        }
        slot
    }

    /// Classes of the occupied slots in slot order, with slot indices —
    /// the member list every budget split is computed over.
    fn occupied_members(&self) -> Vec<(usize, QosClass)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|s| (i, s.class)))
            .collect()
    }

    /// Serve until `rx` disconnects, the class queues drain, and all
    /// in-flight sessions finish.
    pub fn run(&mut self, rx: Receiver<GenRequest>) {
        let mut disconnected = false;
        loop {
            // drain arrivals into the class queues (overflow rejects
            // immediately, so the producer side never wedges)
            while !disconnected {
                match rx.try_recv() {
                    Ok(req) => self.enqueue(req),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => disconnected = true,
                }
            }
            // admit in priority order while slots are free; rejects and
            // sheds resolve inside admit()
            while self.occupied() < self.slots.len() {
                match self.queues.pop() {
                    Some((_, req)) => self.admit(req),
                    None => break,
                }
            }
            self.publish_queue_depths();
            if self.occupied() == 0 {
                // the admit loop only stops on empty queues while slots
                // are free, so idle here means nothing is waiting
                if disconnected {
                    return;
                }
                match rx.recv_timeout(Duration::from_millis(100)) {
                    Ok(req) => {
                        self.enqueue(req);
                        continue;
                    }
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => {
                        disconnected = true;
                        continue;
                    }
                }
            }
            if let Err(e) = self.step() {
                log::error!("batched decode step failed: {e}");
                self.fail_all(&format!("engine failure: {e}"));
            }
        }
    }

    /// Queue one arrival at its requested class; a full class queue is
    /// a typed `queue_full` reject.
    fn enqueue(&mut self, req: GenRequest) {
        let class = req.params.qos;
        if let Err(req) = self.queues.push(class, req) {
            let depth = self.queues.depths()[class.index()];
            let detail = format!("{} queue full at depth {depth}", class.as_str());
            self.reject(req, RejectReason::QueueFull, detail);
        }
    }

    /// Answer a request with a typed admission reject.
    fn reject(&mut self, req: GenRequest, reason: RejectReason, detail: String) {
        let requested = req.params.qos;
        self.stats.requests_rejected += 1;
        Registry::global().publish(|reg| {
            reg.counter_add("asrkf_requests_rejected_total", &[], 1);
            reg.counter_add(
                "asrkf_admission_total",
                &[("class", requested.as_str()), ("decision", "reject")],
                1,
            );
        });
        let reject = Reject { reason, requested, detail };
        let _ = req.respond.send(GenResponse::rejected(req.id, reject));
    }

    fn publish_queue_depths(&self) {
        let depths = self.queues.depths();
        Registry::global().publish(|reg| {
            for c in QosClass::ALL {
                reg.gauge_set(
                    "asrkf_queue_depth",
                    &[("class", c.as_str())],
                    depths[c.index()] as f64,
                );
            }
        });
    }

    /// Admit one request: capacity check, admission projection (with
    /// shed-to-lower-class), then prefill into a free slot.
    fn admit(&mut self, req: GenRequest) {
        let requested = req.params.qos;
        let waited = Instant::now().saturating_duration_since(req.arrived);
        self.queue_wait_hist.record(waited);
        Registry::global().time_record(
            "asrkf_queue_wait_us",
            &[("class", requested.as_str())],
            waited,
        );

        let tokens = tokenizer::encode(&req.params.prompt);
        if tokens.is_empty() {
            self.stats.requests_rejected += 1;
            Registry::global().counter_add("asrkf_requests_rejected_total", &[], 1);
            let _ = req.respond.send(GenResponse::error(req.id, "empty prompt"));
            return;
        }
        let need = tokens.len() + req.params.max_new;
        if need > self.decode.kv_len {
            let detail = format!(
                "request needs {need} KV rows, bucket capacity is {}",
                self.decode.kv_len
            );
            self.reject(req, RejectReason::KvCapacity, detail);
            return;
        }

        let occupied: Vec<QosClass> =
            self.occupied_members().into_iter().map(|(_, c)| c).collect();
        let class = match self.admission.admit(&occupied, requested) {
            Admission::Admit => requested,
            Admission::Shed(lower) => {
                self.stats.requests_shed += 1;
                Registry::global().counter_add(
                    "asrkf_admission_total",
                    &[("class", requested.as_str()), ("decision", "shed")],
                    1,
                );
                log::info!("request {} shed {} -> {}", req.id, requested.as_str(), lower.as_str());
                lower
            }
            Admission::Reject(reason) => {
                let detail = format!(
                    "projected hot-tier slice below the {}-B admission envelope",
                    self.admission.floor_bytes()
                );
                self.reject(req, reason, detail);
                return;
            }
        };

        let slot_idx = match self.slots.iter().position(|s| s.is_none()) {
            Some(i) => i,
            None => {
                let _ = req
                    .respond
                    .send(GenResponse::error(req.id, "no free slot (admission bug)"));
                return;
            }
        };
        match self.prefill_into_slot(&req, &tokens, slot_idx, class) {
            Ok(()) => {
                Registry::global().counter_add(
                    "asrkf_admission_total",
                    &[("class", class.as_str()), ("decision", "accept")],
                    1,
                );
            }
            Err(e) => {
                self.stats.requests_rejected += 1;
                Registry::global().publish(|reg| {
                    reg.counter_add("asrkf_requests_rejected_total", &[], 1);
                    reg.counter_add(
                        "asrkf_admission_total",
                        &[("class", requested.as_str()), ("decision", "reject")],
                        1,
                    );
                });
                let _ = req.respond.send(GenResponse::error(req.id, format!("{e}")));
            }
        }
    }

    fn prefill_into_slot(
        &mut self,
        req: &GenRequest,
        tokens: &[i32],
        slot_idx: usize,
        class: QosClass,
    ) -> Result<()> {
        let model = self.rt.manifest.model.clone();
        let tokens = tokens.to_vec();
        let prefill = self.rt.prefill_for(tokens.len())?;
        let l = prefill.len;
        let mut padded = tokens.clone();
        padded.resize(l, b' ' as i32);
        let pf = prefill.run(&padded, &[tokens.len() as i32])?;
        self.stats.prefill_tokens += tokens.len() as u64;
        Registry::global().counter_add("asrkf_prefill_tokens_total", &[], tokens.len() as u64);

        insert_prefill(&mut self.kv, &self.geom, slot_idx, &pf.kv, l, tokens.len());

        let mut cfg = self.cfg.clone();
        cfg.sampling.seed = req.params.seed;
        // class-weighted budget slice over the would-be slot population
        // (occupied slots + this one, in slot order): the same split
        // the reflow installs for the incumbents at the next step
        // boundary, so the population's slices are consistent from the
        // first decode. Equal class weights with a full batch reproduce
        // the old static `partitioned(B, slot)` split. Each slot's
        // session then shards its slice across `cfg.offload.shards`
        // worker-backed stores, so a slot's restore bursts parallelize
        // without touching its neighbours.
        let mut members = self.occupied_members();
        let rank = members.iter().filter(|&&(i, _)| i < slot_idx).count();
        members.insert(rank, (slot_idx, class));
        let classes: Vec<QosClass> = members.iter().map(|&(_, c)| c).collect();
        let shares = self.admission.shares(&classes, cfg.offload.cold_budget_bytes);
        (cfg.offload.hot_budget_bytes, cfg.offload.cold_budget_bytes) = shares[rank];
        // persistent spill: each slot owns a subdirectory, so slot
        // stores never share manifests or record files (the manifest's
        // one-writer-per-directory contract). A restarted coordinator
        // re-attaches to the same slot dirs — reclaiming dead
        // sessions' records by default, recovering them when the
        // request asks to resume. The slot dir carries no per-session
        // identity: resume_spill asserts the request continues the
        // sequence whose rows were left in this slot.
        if cfg.offload.spill_persist {
            if let Some(dir) = &cfg.offload.spill_dir {
                let slot_dir = std::path::Path::new(dir).join(format!("slot-{slot_idx}"));
                cfg.offload.spill_dir = Some(slot_dir.to_string_lossy().into_owned());
            }
        }
        let resume = req.params.resume_spill && cfg.offload.spill_persist;
        let policy = make_policy(&req.params.policy, &cfg.freeze)
            .map_err(Error::Coordinator)?;
        let mut session = if resume {
            Session::resume(
                req.id,
                tokens.clone(),
                req.params.max_new,
                policy,
                &cfg,
                self.decode.kv_len,
                model.kv_row_floats,
            )?
        } else {
            Session::new(
                req.id,
                tokens.clone(),
                req.params.max_new,
                policy,
                &cfg,
                self.decode.kv_len,
                model.kv_row_floats,
            )?
        };
        session.seed_prefill(pf.logits_last, &pf.scores_last, tokens.len());

        self.slots[slot_idx] = Some(Slot {
            session,
            arrived: req.arrived,
            first_token_at: None,
            respond: req.respond.clone(),
            id: req.id,
            class,
        });
        self.occupied_count += 1;
        // incumbents shrink to their share of the new split at the
        // next step boundary
        self.rebalance_pending = true;
        Ok(())
    }

    /// Install the class-weighted budget split for the current slot
    /// population (skipped when unchanged since the last boundary):
    /// freed budget from retired sessions reflows to the occupied
    /// slots, shrunken slices demote immediately inside the store. A
    /// session that cannot adopt its new slice retires with an error,
    /// like any other storage failure.
    fn rebalance_budgets(&mut self) {
        if !self.rebalance_pending {
            return;
        }
        self.rebalance_pending = false;
        let members = self.occupied_members();
        if members.is_empty() {
            return;
        }
        let classes: Vec<QosClass> = members.iter().map(|&(_, c)| c).collect();
        let shares = self.admission.shares(&classes, self.cfg.offload.cold_budget_bytes);
        let mut failed: Vec<(usize, String)> = Vec::new();
        for (rank, &(idx, _)) in members.iter().enumerate() {
            let (hot, cold) = shares[rank];
            if let Some(slot) = self.slots[idx].as_mut() {
                if let Err(e) = slot.session.reslice_budgets(hot, cold) {
                    failed.push((idx, format!("{e}")));
                }
            }
        }
        for (i, msg) in failed {
            log::error!("slot {i}: retiring session after budget reflow failure: {msg}");
            if let Some(slot) = self.clear_slot(i) {
                let _ = slot.respond.send(GenResponse::error(slot.id, msg));
            }
        }
    }

    /// One batched decode step over all occupied slots.
    pub fn step(&mut self) -> Result<()> {
        let t0 = Instant::now();
        // step boundary: adopt the weighted budget split if the slot
        // population changed since the last step
        self.rebalance_budgets();
        let b = self.slots.len();
        let s = self.decode.kv_len;
        let r = self.cfg.freeze.r_budget.min(self.decode.r_budget.max(1));

        let mut tokens = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut mask = vec![0.0f32; b * s];
        let mut planned = vec![false; b];

        let mut failed: Vec<(usize, String)> = Vec::new();
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if let Some(slot) = slot {
                let sess = &mut slot.session;
                tokens[i] = sess.next_token();
                // per-slot freeze/restore data movement on the shared
                // cache; the slot's plan buffer is refilled in place
                match sess.apply_plan(&mut self.kv, &self.geom, i, r, &mut self.plan_bufs[i]) {
                    Ok(()) => {
                        pos[i] = sess.len as i32;
                        mask[i * s..(i + 1) * s].copy_from_slice(&sess.mask);
                        planned[i] = true;
                    }
                    // offload failure (storage invariant / spill I/O):
                    // fail this session, keep the rest of the batch
                    Err(e) => failed.push((i, format!("{e}"))),
                }
            }
            // free slots decode a dummy token at pos 0; outputs ignored
            // and their KV rows are overwritten on the next prefill.
        }
        for (i, msg) in failed {
            log::error!("slot {i}: retiring session after storage failure: {msg}");
            if let Some(slot) = self.clear_slot(i) {
                let _ = slot.respond.send(GenResponse::error(slot.id, msg));
            }
        }
        if !planned.iter().any(|&p| p) {
            return Ok(()); // every occupied slot failed this step
        }

        let out = self.decode.run(&DecodeInputs {
            tokens: &tokens,
            kv: &self.kv,
            mask: &mask,
            pos: &pos,
        })?;
        self.stats.batches_dispatched += 1;
        self.stats.batch_occupancy_sum += self.occupied() as u64;
        Registry::global().publish(|reg| {
            reg.counter_add("asrkf_batches_dispatched_total", &[], 1);
            reg.count_record("asrkf_batch_occupancy", &[], self.occupied() as u64);
        });

        let model_vocab = self.rt.manifest.model.vocab;
        let now = Instant::now();
        for i in 0..b {
            if !planned[i] {
                continue;
            }
            let plan = &self.plan_bufs[i];
            let slot_pos = pos[i] as usize;
            // write the new KV row for this lane
            crate::engine::layout::write_new_row(
                &mut self.kv, &self.geom, i, slot_pos, &out.k_new, &out.v_new,
            );
            let absorb_err = {
                let slot = self.slots[i].as_mut().unwrap();
                let sess = &mut slot.session;
                let logits = out.logits[i * model_vocab..(i + 1) * model_vocab].to_vec();
                let scores = &out.scores[i * s..(i + 1) * s];
                // recovery in batched mode: SR/WR/FR apply via policy; RR
                // is disabled (rewalk would stall the whole batch —
                // documented); the returned action is therefore unused
                sess.absorb(tokens[i], logits, scores, plan, out.timing, Duration::ZERO)
                    .err()
            };
            if let Some(e) = absorb_err {
                log::error!("slot {i}: retiring session after staging failure: {e}");
                if let Some(slot) = self.clear_slot(i) {
                    let _ = slot.respond.send(GenResponse::error(slot.id, format!("{e}")));
                }
                continue;
            }
            let slot = self.slots[i].as_mut().unwrap();
            let sess = &mut slot.session;
            if slot.first_token_at.is_none() {
                slot.first_token_at = Some(now);
                self.ttft_hist.record(now - slot.arrived);
                // aggregate series (back-compat) + per-class breakdown
                Registry::global().publish(|reg| {
                    reg.time_record("asrkf_ttft_us", &[], now - slot.arrived);
                    reg.time_record(
                        "asrkf_ttft_us",
                        &[("class", slot.class.as_str())],
                        now - slot.arrived,
                    );
                });
            }
            self.stats.tokens_generated += 1;
            Registry::global().counter_add("asrkf_tokens_generated_total", &[], 1);

            if sess.is_done() {
                let e2e = now - slot.arrived;
                self.e2e_hist.record(e2e);
                // land in-flight speculative restores before reading
                // the retiring store's counters — a shard out with a
                // worker is invisible to the aggregates below
                if let Err(e) = sess.store.settle() {
                    log::error!("slot {i}: settling restore pipeline at retirement: {e}");
                }
                // fold the retiring session's offload telemetry into
                // the engine-wide aggregates and the process registry
                // (flows only: the retiring store's gauges are stale by
                // definition — live occupancy is published per step)
                sess.publish_to_registry(Registry::global());
                let class = slot.class;
                Registry::global().publish(|reg| {
                    reg.counter_add("asrkf_requests_completed_total", &[], 1);
                    reg.time_record("asrkf_e2e_us", &[], e2e);
                    reg.time_record("asrkf_e2e_us", &[("class", class.as_str())], e2e);
                });
                let offload = sess.offload_summary();
                self.stats.staged_hits += offload.staged_hits;
                self.stats.staged_misses += offload.staged_misses;
                self.restore_hist.merge(&sess.store.restore_latency());
                self.plan_hist.merge(&sess.plan_hist);
                // batch_stats is the single aggregate of per-session
                // batching counters (rows/spans live there)
                self.batch_stats.merge(&sess.batch);
                let plan_latency = sess.plan_latency();
                let resp = GenResponse {
                    id: slot.id,
                    text: sess.generated_text(),
                    error: None,
                    class,
                    reject: None,
                    prompt_tokens: sess.prompt_len,
                    generated_tokens: sess.generated(),
                    final_active_kv: sess.active_kv(),
                    compression: 1.0 - sess.active_kv() as f64 / sess.len.max(1) as f64,
                    ttft: slot.first_token_at.unwrap() - slot.arrived,
                    e2e,
                    offload,
                    plan_latency,
                };
                let _ = slot.respond.send(resp);
                self.stats.requests_completed += 1;
                self.clear_slot(i);
            }
        }
        // live occupancy across every occupied slot, summed per tier.
        // Published without a shard label: slot stores partition one
        // budget, so per-shard gauge series would collide across slots.
        let mut occ = TierOccupancy::default();
        let mut degraded = 0usize;
        for slot in self.slots.iter().flatten() {
            let o = slot.session.store.occupancy();
            occ.hot_rows += o.hot_rows;
            occ.hot_bytes += o.hot_bytes;
            occ.cold_rows += o.cold_rows;
            occ.cold_bytes += o.cold_bytes;
            occ.spill_rows += o.spill_rows;
            occ.spill_bytes += o.spill_bytes;
            degraded += slot.session.store.degraded_shards();
        }
        // degraded-mode admission: while any occupied slot's shards are
        // rebuilding from spill, the controller discounts their capacity
        // so new arrivals don't land on storage that is still warming
        // back up. The window closes by itself (see
        // `ShardedStore::degraded_shards`), so this poll both opens and
        // clears the discount.
        if self.admission.set_degraded(degraded) {
            log::warn!("admission capacity discount: {degraded} shard(s) degraded");
            Registry::global().gauge_set("asrkf_degraded_shards", &[], degraded as f64);
        }
        let mut per_class = [0usize; QosClass::COUNT];
        for slot in self.slots.iter().flatten() {
            per_class[slot.class.index()] += 1;
        }
        Registry::global().publish(|reg| {
            for (tier, rows, bytes) in [
                ("hot", occ.hot_rows, occ.hot_bytes),
                ("cold", occ.cold_rows, occ.cold_bytes),
                ("spill", occ.spill_rows, occ.spill_bytes),
            ] {
                reg.gauge_set("asrkf_tier_rows", &[("tier", tier)], rows as f64);
                reg.gauge_set("asrkf_tier_bytes", &[("tier", tier)], bytes as f64);
            }
            for c in QosClass::ALL {
                reg.gauge_set(
                    "asrkf_class_occupancy",
                    &[("class", c.as_str())],
                    per_class[c.index()] as f64,
                );
            }
        });
        self.step_hist.record(t0.elapsed());
        Ok(())
    }

    fn fail_all(&mut self, msg: &str) {
        for i in 0..self.slots.len() {
            if let Some(s) = self.clear_slot(i) {
                let _ = s.respond.send(GenResponse::error(s.id, msg));
            }
        }
    }
}
