//! QoS scheduling primitives for the continuous-batching coordinator:
//! per-class priority queues and the admission controller that projects
//! hot-tier usage before a request may take a slot.
//!
//! Both types are pure (no engine, no I/O): the batcher drives them
//! against real sessions, `benches/load_gen.rs` drives the same types
//! against a virtual-clock queueing model, and the unit tests below pin
//! their contracts without artifacts.

use std::collections::VecDeque;

use crate::config::{weighted_shares, OffloadConfig, QosClass, QosConfig};
use crate::coordinator::request::RejectReason;

/// One bounded FIFO per [`QosClass`], popped in priority order:
/// `Interactive` drains before `Standard` before `Batch`, FIFO within a
/// class. Generic over the queued item so the serving batcher
/// (`GenRequest`) and the load-generator simulation share the exact
/// scheduling structure.
#[derive(Debug)]
pub struct ClassQueues<T> {
    queues: [VecDeque<(QosClass, T)>; QosClass::COUNT],
    depth_cap: usize,
}

impl<T> ClassQueues<T> {
    /// `depth_cap` bounds each class queue (`QosConfig::queue_depth`).
    pub fn new(depth_cap: usize) -> Self {
        ClassQueues {
            queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            depth_cap: depth_cap.max(1),
        }
    }

    /// Enqueue at `class`; hands the item back when that class queue is
    /// at its depth cap (the caller turns it into a `queue_full`
    /// reject).
    pub fn push(&mut self, class: QosClass, item: T) -> Result<(), T> {
        let q = &mut self.queues[class.index()];
        if q.len() >= self.depth_cap {
            return Err(item);
        }
        q.push_back((class, item));
        Ok(())
    }

    /// Pop the head of the highest-priority non-empty class queue.
    pub fn pop(&mut self) -> Option<(QosClass, T)> {
        self.queues.iter_mut().find_map(VecDeque::pop_front)
    }

    pub fn len(&self) -> usize {
        self.queues.iter().map(VecDeque::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.queues.iter().all(VecDeque::is_empty)
    }

    /// Queue depth per class, indexed by [`QosClass::index`] (feeds the
    /// `asrkf_queue_depth` gauge).
    pub fn depths(&self) -> [usize; QosClass::COUNT] {
        [self.queues[0].len(), self.queues[1].len(), self.queues[2].len()]
    }
}

/// What the admission projection decided for a candidate request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Admit at the requested class.
    Admit,
    /// Admit, but served at this lower class (smaller budget weight).
    Shed(QosClass),
    /// No class assignment fits the envelope.
    Reject(RejectReason),
}

/// Projects hot-tier usage for a hypothetical slot population before a
/// request is admitted. The projection is exact, not a heuristic: it
/// runs the same [`weighted_shares`] split the batcher will apply at
/// the next step boundary and checks every member's hot slice against
/// the floor the stores enforce at construction — one row per shard —
/// scaled by the configured headroom. A request that fails at its own
/// class is retried at each lower class (shedding: a lighter weight
/// takes a smaller slice and leaves more for the incumbents) before an
/// outright reject.
#[derive(Debug, Clone)]
pub struct AdmissionController {
    qos: QosConfig,
    hot_budget_bytes: usize,
    shards: usize,
    row_bytes: usize,
    /// `quantize_cold = false` makes budgets advisory (nothing ever
    /// demotes), so projection always admits.
    enforcing: bool,
    /// Shards currently degraded (lost to a worker failure, or rebuilt
    /// within the re-warm window) across the occupied sessions, fed by
    /// the batcher each step. While non-zero, [`fits`] projects
    /// against a proportionally discounted hot budget so admission
    /// does not count capacity a rebuilding shard cannot yet serve.
    ///
    /// [`fits`]: AdmissionController::fits
    degraded_shards: usize,
}

impl AdmissionController {
    pub fn new(qos: QosConfig, offload: &OffloadConfig, row_floats: usize) -> Self {
        AdmissionController {
            qos,
            hot_budget_bytes: offload.hot_budget_bytes,
            shards: offload.shards.max(1),
            row_bytes: row_floats * std::mem::size_of::<f32>(),
            enforcing: offload.quantize_cold,
            degraded_shards: 0,
        }
    }

    /// Update the degraded-shard count (clamped to the shard count).
    /// Returns `true` when the value changed, so the caller can log the
    /// transition without tracking its own copy.
    pub fn set_degraded(&mut self, degraded: usize) -> bool {
        let clamped = degraded.min(self.shards);
        let changed = clamped != self.degraded_shards;
        self.degraded_shards = clamped;
        changed
    }

    /// The hot budget admission currently projects against: the
    /// configured budget scaled by the fraction of shards actually
    /// serving (`(shards - degraded) / shards`).
    fn effective_hot_bytes(&self) -> usize {
        if self.degraded_shards == 0 {
            return self.hot_budget_bytes;
        }
        let live = self.shards - self.degraded_shards;
        (self.hot_budget_bytes / self.shards) * live
    }

    pub fn weight(&self, class: QosClass) -> u64 {
        self.qos.weight(class)
    }

    /// The minimum acceptable per-slot hot slice: one row per shard
    /// (the floor `ShardedStore` construction and `set_budgets` reject
    /// below — a slice of `h` bytes over `n` shards gives its smallest
    /// shard `floor(h/n)`, so `h >= n * row_bytes` keeps every shard at
    /// one row or more), scaled by `1 + admission_headroom`.
    pub fn floor_bytes(&self) -> usize {
        let hard = self.shards * self.row_bytes;
        (hard as f64 * (1.0 + self.qos.admission_headroom as f64)).ceil() as usize
    }

    /// Per-member (hot, cold) budget slices for a slot population, in
    /// member order — the same split the batcher installs at step
    /// boundaries. `cold_budget_bytes` is passed by the caller since
    /// only hot participates in the admission floor.
    pub fn shares(&self, members: &[QosClass], cold_budget_bytes: usize) -> Vec<(usize, usize)> {
        let weights: Vec<u64> = members.iter().map(|&c| self.qos.weight(c)).collect();
        let hot = weighted_shares(self.hot_budget_bytes, &weights);
        let cold = weighted_shares(cold_budget_bytes, &weights);
        hot.into_iter().zip(cold).collect()
    }

    /// Would this slot population's hot slices all clear the floor?
    pub fn fits(&self, members: &[QosClass]) -> bool {
        if !self.enforcing || members.is_empty() {
            return true;
        }
        let weights: Vec<u64> = members.iter().map(|&c| self.qos.weight(c)).collect();
        let floor = self.floor_bytes();
        weighted_shares(self.effective_hot_bytes(), &weights).into_iter().all(|h| h >= floor)
    }

    /// Project admitting `requested` next to `occupied` (the classes of
    /// the currently occupied slots). Sheds downward until the
    /// projection fits; rejects when even `Batch` does not.
    pub fn admit(&self, occupied: &[QosClass], requested: QosClass) -> Admission {
        let mut class = requested;
        loop {
            let mut members = occupied.to_vec();
            members.push(class);
            if self.fits(&members) {
                return if class == requested { Admission::Admit } else { Admission::Shed(class) };
            }
            match class.lower() {
                Some(lower) => class = lower,
                None => return Admission::Reject(RejectReason::HotEnvelope),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queues_pop_priority_order_fifo_within_class() {
        let mut q: ClassQueues<u32> = ClassQueues::new(8);
        q.push(QosClass::Batch, 1).unwrap();
        q.push(QosClass::Interactive, 2).unwrap();
        q.push(QosClass::Standard, 3).unwrap();
        q.push(QosClass::Interactive, 4).unwrap();
        assert_eq!(q.len(), 4);
        assert_eq!(q.depths(), [2, 1, 1]);
        let order: Vec<(QosClass, u32)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(
            order,
            vec![
                (QosClass::Interactive, 2),
                (QosClass::Interactive, 4),
                (QosClass::Standard, 3),
                (QosClass::Batch, 1),
            ]
        );
        assert!(q.is_empty());
    }

    #[test]
    fn queue_depth_cap_hands_the_item_back() {
        let mut q: ClassQueues<u32> = ClassQueues::new(2);
        q.push(QosClass::Standard, 1).unwrap();
        q.push(QosClass::Standard, 2).unwrap();
        assert_eq!(q.push(QosClass::Standard, 3), Err(3), "per-class cap");
        // other classes are unaffected by a full neighbour
        q.push(QosClass::Batch, 4).unwrap();
        assert_eq!(q.depths(), [0, 2, 1]);
    }

    fn ctl(hot: usize, shards: usize, headroom: f32) -> AdmissionController {
        let offload = OffloadConfig {
            hot_budget_bytes: hot,
            shards,
            ..OffloadConfig::default()
        };
        let qos = QosConfig { admission_headroom: headroom, ..QosConfig::default() };
        // 256 floats -> 1024-B rows
        AdmissionController::new(qos, &offload, 256)
    }

    #[test]
    fn floor_scales_with_shards_and_headroom() {
        assert_eq!(ctl(1 << 20, 1, 0.0).floor_bytes(), 1024);
        assert_eq!(ctl(1 << 20, 4, 0.0).floor_bytes(), 4096);
        assert_eq!(ctl(1 << 20, 4, 0.25).floor_bytes(), 5120);
    }

    #[test]
    fn admits_when_every_projected_slice_clears_the_floor() {
        // floor 1280; four interactive members split 16 KiB into 4 KiB
        // slices — everything fits
        let c = ctl(16 << 10, 1, 0.25);
        let occupied = vec![QosClass::Interactive; 3];
        assert_eq!(c.admit(&occupied, QosClass::Interactive), Admission::Admit);
    }

    #[test]
    fn sheds_to_a_lighter_class_before_rejecting() {
        // weights [4,2,1], hot 4096 B, floor 1024 B, one Batch
        // incumbent. An Interactive candidate (weight 4) squeezes the
        // incumbent to 4096/5 = 819 B — under the floor; retried as
        // Standard (weight 2) the incumbent keeps 4096/3 = 1365 B and
        // the candidate's own 2731 B clears too -> shed to Standard.
        let c = ctl(4096, 1, 0.0);
        let occupied = vec![QosClass::Batch];
        assert_eq!(c.admit(&occupied, QosClass::Interactive), Admission::Shed(QosClass::Standard));
        // and a Standard request in the same state admits directly
        assert_eq!(c.admit(&occupied, QosClass::Standard), Admission::Admit);
    }

    #[test]
    fn rejects_when_even_batch_cannot_fit() {
        // 2 KiB hot over two interactive incumbents: any third member
        // pushes someone below the 1024-B floor
        let c = ctl(2 << 10, 1, 0.0);
        let occupied = vec![QosClass::Interactive, QosClass::Interactive];
        assert_eq!(
            c.admit(&occupied, QosClass::Interactive),
            Admission::Reject(RejectReason::HotEnvelope)
        );
        // an empty machine still rejects when one slice can't fit a row
        let tiny = ctl(512, 1, 0.0);
        assert_eq!(
            tiny.admit(&[], QosClass::Batch),
            Admission::Reject(RejectReason::HotEnvelope)
        );
    }

    #[test]
    fn degraded_shards_discount_admission_capacity() {
        // 8 KiB hot over 4 shards, floor 4096: two interactive members
        // split to 4096 B each — fits exactly with all shards live
        let mut c = ctl(8 << 10, 4, 0.0);
        let occupied = vec![QosClass::Interactive];
        assert_eq!(c.admit(&occupied, QosClass::Interactive), Admission::Admit);
        // one shard rebuilding: the projection loses a quarter of the
        // budget (6144 B over two members = 3072 B < floor) — even
        // shedding to Batch leaves the candidate ~1229 B, so reject
        assert!(c.set_degraded(1));
        assert!(!c.set_degraded(1), "unchanged value reports no transition");
        assert_eq!(
            c.admit(&occupied, QosClass::Interactive),
            Admission::Reject(RejectReason::HotEnvelope)
        );
        // the incumbent alone still fits on the discounted budget
        assert!(c.fits(&occupied));
        // recovery restores full capacity
        assert!(c.set_degraded(0));
        assert_eq!(c.admit(&occupied, QosClass::Interactive), Admission::Admit);
        // the count clamps at the shard total (capacity floor of zero)
        c.set_degraded(99);
        assert!(!c.fits(&occupied));
    }

    #[test]
    fn advisory_budgets_always_admit() {
        let offload = OffloadConfig {
            hot_budget_bytes: 64,
            quantize_cold: false,
            ..OffloadConfig::default()
        };
        let c = AdmissionController::new(QosConfig::default(), &offload, 256);
        assert_eq!(c.admit(&[QosClass::Interactive], QosClass::Interactive), Admission::Admit);
    }

    #[test]
    fn shares_with_equal_weights_match_partitioned_oracle() {
        let offload =
            OffloadConfig { hot_budget_bytes: 101, cold_budget_bytes: 31, ..Default::default() };
        let qos = QosConfig { weights: [3, 3, 3], ..QosConfig::default() };
        let c = AdmissionController::new(qos, &offload, 1);
        for n in 1..=5usize {
            let members = vec![QosClass::Interactive; n];
            let shares = c.shares(&members, offload.cold_budget_bytes);
            for (i, &(hot, cold)) in shares.iter().enumerate() {
                let p = offload.partitioned(n, i);
                assert_eq!(hot, p.hot_budget_bytes, "hot {n}@{i}");
                assert_eq!(cold, p.cold_budget_bytes, "cold {n}@{i}");
            }
        }
    }
}
