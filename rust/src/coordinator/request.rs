//! Request/response types flowing between the server frontend and the
//! coordinator thread.

use std::sync::mpsc;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct GenParams {
    pub prompt: String,
    pub max_new: usize,
    pub policy: String,
    pub seed: u64,
    /// Re-attach to (and recover from) this request's slot-scoped
    /// persistent spill directory instead of reclaiming a dead
    /// process's records. Only meaningful when the server runs with
    /// `--spill-persist`; recovery counters ride along on the response
    /// (`recovered_rows` / `recovery_errors`).
    pub resume_spill: bool,
}

#[derive(Debug)]
pub struct GenRequest {
    pub id: u64,
    pub params: GenParams,
    pub arrived: Instant,
    pub respond: mpsc::Sender<GenResponse>,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub text: String,
    pub error: Option<String>,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub final_active_kv: usize,
    pub compression: f64,
    /// time to first token (includes queueing + prefill)
    pub ttft: Duration,
    /// total end-to-end latency
    pub e2e: Duration,
    /// tiered frozen-KV storage snapshot at retirement
    pub offload: crate::offload::OffloadSummary,
    /// per-step policy control-plane time (`plan` + `observe`)
    pub plan_latency: crate::metrics::PlanLatency,
}

impl GenResponse {
    pub fn error(id: u64, msg: impl Into<String>) -> Self {
        GenResponse {
            id,
            text: String::new(),
            error: Some(msg.into()),
            prompt_tokens: 0,
            generated_tokens: 0,
            final_active_kv: 0,
            compression: 0.0,
            ttft: Duration::ZERO,
            e2e: Duration::ZERO,
            offload: crate::offload::OffloadSummary::default(),
            plan_latency: crate::metrics::PlanLatency::default(),
        }
    }
}
