//! Request/response types flowing between the server frontend and the
//! coordinator thread.
//!
//! Construction goes through [`GenParams::builder`] — the builder
//! carries the defaults (`policy = "asrkf"`, `seed = 0`,
//! `resume_spill = false`, `qos = Standard`) so call sites only state
//! what they mean, and adding a field stops being a repo-wide edit.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::config::QosClass;

#[derive(Debug, Clone)]
pub struct GenParams {
    pub prompt: String,
    pub max_new: usize,
    pub policy: String,
    pub seed: u64,
    /// Re-attach to (and recover from) this request's slot-scoped
    /// persistent spill directory instead of reclaiming a dead
    /// process's records. Only meaningful when the server runs with
    /// `--spill-persist`; recovery counters ride along on the response
    /// (`recovered_rows` / `recovery_errors`).
    pub resume_spill: bool,
    /// Requested QoS class: scheduling priority and budget weight.
    /// Admission may serve the request at a lower class (shed) — the
    /// response reports the class it actually ran under.
    pub qos: QosClass,
}

impl GenParams {
    /// Start building a request around its one mandatory field.
    pub fn builder(prompt: impl Into<String>) -> GenParamsBuilder {
        GenParamsBuilder { params: GenParams::with_defaults(prompt.into()) }
    }

    fn with_defaults(prompt: String) -> GenParams {
        GenParams {
            prompt,
            max_new: 64,
            policy: "asrkf".to_string(),
            seed: 0,
            resume_spill: false,
            qos: QosClass::Standard,
        }
    }
}

/// Builder for [`GenParams`]; see [`GenParams::builder`].
#[derive(Debug, Clone)]
pub struct GenParamsBuilder {
    params: GenParams,
}

impl GenParamsBuilder {
    pub fn max_new(mut self, max_new: usize) -> Self {
        self.params.max_new = max_new;
        self
    }

    pub fn policy(mut self, policy: impl Into<String>) -> Self {
        self.params.policy = policy.into();
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.params.seed = seed;
        self
    }

    pub fn resume_spill(mut self, resume_spill: bool) -> Self {
        self.params.resume_spill = resume_spill;
        self
    }

    pub fn qos(mut self, qos: QosClass) -> Self {
        self.params.qos = qos;
        self
    }

    pub fn build(self) -> GenParams {
        self.params
    }
}

#[derive(Debug)]
pub struct GenRequest {
    pub id: u64,
    pub params: GenParams,
    pub arrived: Instant,
    pub respond: mpsc::Sender<GenResponse>,
}

/// Why admission control turned a request away. Serialized on the wire
/// as the `reject.reason` field (`server/protocol.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The request's class queue was at `QosConfig::queue_depth`.
    QueueFull,
    /// `prompt + max_new` exceeds the decode KV capacity — no class
    /// change can make it fit.
    KvCapacity,
    /// Admitting the request would push some occupied slot's projected
    /// hot-tier slice below the admission envelope, even after shedding
    /// all the way down to `Batch`.
    HotEnvelope,
}

impl RejectReason {
    pub fn as_str(self) -> &'static str {
        match self {
            RejectReason::QueueFull => "queue_full",
            RejectReason::KvCapacity => "kv_capacity",
            RejectReason::HotEnvelope => "hot_envelope",
        }
    }
}

/// Typed admission reject riding on an error [`GenResponse`]: machine-
/// readable alongside the human-readable `error` string.
#[derive(Debug, Clone)]
pub struct Reject {
    pub reason: RejectReason,
    /// The class the request asked for (rejects are attributed to the
    /// requested class, not any shed target that was probed).
    pub requested: QosClass,
    pub detail: String,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub text: String,
    pub error: Option<String>,
    /// QoS class the request actually ran (or was rejected) under;
    /// lower than `GenParams::qos` when admission shed it.
    pub class: QosClass,
    /// Present iff admission control refused the request.
    pub reject: Option<Reject>,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub final_active_kv: usize,
    pub compression: f64,
    /// time to first token (includes queueing + prefill)
    pub ttft: Duration,
    /// total end-to-end latency
    pub e2e: Duration,
    /// tiered frozen-KV storage snapshot at retirement
    pub offload: crate::offload::OffloadSummary,
    /// per-step policy control-plane time (`plan` + `observe`)
    pub plan_latency: crate::metrics::PlanLatency,
}

impl GenResponse {
    pub fn error(id: u64, msg: impl Into<String>) -> Self {
        GenResponse {
            id,
            text: String::new(),
            error: Some(msg.into()),
            class: QosClass::Standard,
            reject: None,
            prompt_tokens: 0,
            generated_tokens: 0,
            final_active_kv: 0,
            compression: 0.0,
            ttft: Duration::ZERO,
            e2e: Duration::ZERO,
            offload: crate::offload::OffloadSummary::default(),
            plan_latency: crate::metrics::PlanLatency::default(),
        }
    }

    /// An admission reject: an error response carrying the typed
    /// reject detail. The `error` string always mentions "admission
    /// control" so legacy clients matching on the message keep working.
    pub fn rejected(id: u64, reject: Reject) -> Self {
        let mut resp = GenResponse::error(id, format!("{} (admission control)", reject.detail));
        resp.class = reject.requested;
        resp.reject = Some(reject);
        resp
    }
}
