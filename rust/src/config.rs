//! Runtime configuration: the paper's hyper-parameters (§4.1) plus
//! engine / recovery / serving knobs. Every bench and example builds on
//! these defaults; CLI flags override individual fields.

use crate::offload::codec::{CodecId, CodecLadder};
use crate::util::cli::Args;

/// Paper §4.1 hyper-parameters + scheduling extensions.
#[derive(Debug, Clone)]
pub struct FreezeConfig {
    /// Sliding window size K: the most recent K tokens are never scored
    /// or frozen (paper: "tokens outside the sliding window").
    pub window_k: usize,
    /// Attention threshold tau on Eq.2 scores.
    pub tau: f32,
    /// Softness parameter k in d = floor(sqrt(c)/k).
    pub softness_k: f32,
    /// History window W for low-importance detection counts c_j.
    pub history_w: usize,
    /// Attention-sink pinning: first n_sink tokens are never frozen
    /// (StreamingLLM-inspired safety, ablatable; DESIGN.md §5).
    pub n_sink: usize,
    /// Per-step freeze/restore row-transfer budget (R): max rows moved
    /// between the active cache and the frozen store per decode step
    /// (models batched PCIe transfers; the paper's prototype had no
    /// such bound — see EXPERIMENTS.md §5.2 for why it matters).
    pub r_budget: usize,
    /// Normalize Eq.2 scores by their step mean before comparing to tau.
    /// The paper uses raw scores with tau=0.5 on LLaMA-3; a trained
    /// stand-in model has a different score scale, so relative
    /// thresholding is the default (ablatable).
    pub relative_tau: bool,
}

impl Default for FreezeConfig {
    fn default() -> Self {
        FreezeConfig {
            window_k: 32,
            // NOTE: the paper's absolute tau=0.5 applies to LLaMA-3's
            // |q.k| scale. With relative thresholding (default), tau is
            // a multiple of the mean candidate score; 1.0 reproduces
            // the paper's "most stale tokens are flagged" regime on the
            // stand-in model (sweep in benches/ablation_sweep.rs).
            tau: 1.0,
            softness_k: 2.0,
            history_w: 2048,
            n_sink: 4,
            r_budget: 64,
            relative_tau: true,
        }
    }
}

impl FreezeConfig {
    pub fn from_args(args: &Args) -> Result<Self, String> {
        let d = FreezeConfig::default();
        Ok(FreezeConfig {
            window_k: args.usize_or("window-k", d.window_k)?,
            tau: args.f32_or("tau", d.tau)?,
            softness_k: args.f32_or("softness-k", d.softness_k)?,
            history_w: args.usize_or("history-w", d.history_w)?,
            n_sink: args.usize_or("n-sink", d.n_sink)?,
            r_budget: args.usize_or("r-budget", d.r_budget)?,
            relative_tau: !args.bool("absolute-tau"),
        })
    }
}

/// Sampling parameters (paper §4.1: T=0.7, top-k=40, top-p=0.9).
#[derive(Debug, Clone)]
pub struct SamplingConfig {
    pub temperature: f32,
    pub top_k: usize,
    pub top_p: f32,
    pub seed: u64,
}

impl Default for SamplingConfig {
    fn default() -> Self {
        SamplingConfig { temperature: 0.7, top_k: 40, top_p: 0.9, seed: 0 }
    }
}

impl SamplingConfig {
    pub fn greedy() -> Self {
        SamplingConfig { temperature: 0.0, top_k: 0, top_p: 1.0, seed: 0 }
    }

    pub fn from_args(args: &Args) -> Result<Self, String> {
        let d = SamplingConfig::default();
        Ok(SamplingConfig {
            temperature: args.f32_or("temperature", d.temperature)?,
            top_k: args.usize_or("top-k", d.top_k)?,
            top_p: args.f32_or("top-p", d.top_p)?,
            seed: args.u64_or("seed", d.seed)?,
        })
    }
}

/// How `offload::ShardedStore` maps sequence positions to shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPartition {
    /// `shard = pos % n`: contiguous position runs fan out round-robin,
    /// so even a short restore burst engages every shard (maximum
    /// restore parallelism, span copies degrade to single rows).
    Hash,
    /// `shard = (pos / block_rows) % n`: block-cyclic ranges — span
    /// copies stay contiguous within a shard (up to `block_rows` rows
    /// per span), at the cost of small bursts landing on fewer shards.
    Range,
}

impl ShardPartition {
    /// Flag-value spelling (also the spill manifest's identity field).
    pub fn as_str(self) -> &'static str {
        match self {
            ShardPartition::Hash => "hash",
            ShardPartition::Range => "range",
        }
    }

    /// Parse a `--shard-partition` flag value.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "hash" => Ok(ShardPartition::Hash),
            "range" => Ok(ShardPartition::Range),
            other => Err(format!("--shard-partition: expected 'hash' or 'range', got '{other}'")),
        }
    }
}

/// Tiered off-GPU frozen-KV storage knobs (`crate::offload`).
///
/// The store keeps every frozen row (the paper's "no permanent
/// information loss") but grades residency by predicted thaw step:
/// rows expected back soon stay **hot** (uncompressed host rows in a
/// block pool), rows predicted to stay frozen are demoted to the
/// **cold** tier (u8-per-float quantized, ~4x smaller) and optionally
/// to a file-backed **spill** tier for very long contexts.
#[derive(Debug, Clone)]
pub struct OffloadConfig {
    /// Byte budget for the hot tier (uncompressed rows). Exceeding it
    /// demotes the rows with the farthest predicted thaw first.
    pub hot_budget_bytes: usize,
    /// Byte budget for the cold tier; exceeding it spills (when a
    /// spill dir is configured) — rows are never dropped.
    pub cold_budget_bytes: usize,
    /// Admission/demotion horizon (steps): a row whose predicted thaw
    /// is at least this far away is quantized straight into the cold
    /// tier; hot rows that outstay this residency age are demoted.
    pub cold_after_steps: u64,
    /// Compress demoted rows. Derived from [`OffloadConfig::codec_ladder`]
    /// (`false` iff the ladder's sole rung is `raw`): when false,
    /// demotion is disabled entirely — every frozen row stays
    /// uncompressed in the hot tier and the byte budgets become
    /// advisory (lossless storage, unbounded growth). The legacy
    /// `--no-cold-quant` flag still parses (with a deprecation
    /// warning) as `--cold-codec raw`.
    pub quantize_cold: bool,
    /// Documented worst-case quantization error of the u8 rung as a
    /// fraction of the per-row value range (u8 affine: half a
    /// quantization step, plus f32 rounding at the row's magnitude).
    /// Verified by `tests/prop_offload.rs`.
    pub cold_quant_rel_error: f32,
    /// Eta-aware compression ladder (`--codec-ladder 0:u8,64:u4,512:ebq`):
    /// demotion picks the codec rung from the row's predicted thaw
    /// distance (`thaw_eta - now`), so rows expected back soon stay
    /// cheap to decode and far-future rows compress hardest. The
    /// default single-rung `0:u8` ladder reproduces the pre-ladder
    /// cold tier byte-for-byte (oracle-tested in
    /// `tests/prop_offload.rs`). `--cold-codec CODEC` is shorthand for
    /// a single-rung ladder.
    pub codec_ladder: CodecLadder,
    /// Relative error target of the `ebq` rung (`--ebq-rel-error`), as
    /// a fraction of the per-row value range: each 32-float block
    /// picks the smallest width in {0, 2, 4, 8} bits that meets it.
    pub ebq_rel_error: f32,
    /// Directory for the file-backed spill tier; `None` disables
    /// spilling (cold tier then overflows its budget rather than drop).
    pub spill_dir: Option<String>,
    /// Persist the spill tier across process restarts
    /// (`--spill-persist`): deterministic per-shard record files plus
    /// a per-directory manifest (generation-fenced, checksummed
    /// records), instead of per-PID files deleted on drop. A fresh
    /// store reclaims a dead process's leftovers; a resumed store
    /// (`ShardedStore::resume` / `Session::resume`) recovers them.
    /// Off by default — the ephemeral behavior is unchanged.
    pub spill_persist: bool,
    /// Staging look-ahead in steps: rows predicted to thaw within this
    /// many steps are promoted back into the hot tier ahead of their
    /// restore (prefetch-ahead). Applies to both the policy's hints
    /// (which reach at most `kv::PREFETCH_HORIZON` steps out) and the
    /// entropy-pressure sweep (whose effective ceiling is
    /// `cold_after_steps`, so speculative promotions are never undone
    /// by the next residency sweep). 0 disables prefetch.
    pub prefetch_ahead: u64,
    /// Entropy-pressure threshold (0..1 of the recovery trigger) above
    /// which the session stages likely-recovery rows ahead of time.
    pub stage_pressure: f32,
    /// Hot-pool slab granularity in rows (block layout for batched
    /// gather/scatter). Also the chunk width of the `Range` shard
    /// partition, so shard-local spans line up with hot-pool slabs.
    pub block_rows: usize,
    /// Number of `ShardedStore` shards a session's positions fan out
    /// across (1 disables the worker pool: single-store behavior).
    /// Each shard runs its own tiers, eta scheduler, and a
    /// `partitioned` slice of the byte budgets.
    pub shards: usize,
    /// Position-to-shard mapping (`--shard-partition hash|range`).
    pub shard_partition: ShardPartition,
    /// Capacity of each store's flight recorder (structured
    /// tier-transition events kept for `--trace-out`; per shard).
    /// 0 disables recording.
    pub flight_recorder_cap: usize,
    /// Overlapped restore pipeline (`--no-restore-pipeline` disables):
    /// at each step boundary the store speculatively submits the next
    /// `prefetch_ahead` steps' likely restores (eta-index query) to the
    /// worker pool, so spill reads and dequantization execute while the
    /// decode step computes; `take_batch` then consumes the landed rows
    /// instead of paying the tier I/O inline.
    pub pipeline: bool,
    /// Stall cap for the pipeline's late-arrival path, in steps: a
    /// speculative job still in flight this many steps after issue is
    /// reclaimed (blocking), and a landed row not consumed within this
    /// many steps is cancelled (its next restore runs synchronously).
    /// Bounded to >= 1 at config parse.
    pub restore_deadline_steps: u64,
    /// Cap on rows promoted per pressure-staging burst, and the global
    /// row budget of each speculative pipeline issue (split
    /// `ceil(rows / shards)` per shard). Bounded to [1, 65536] at
    /// config parse.
    pub stage_burst_rows: usize,
    /// Test-only fault injection: per-row artificial delay (µs) inside
    /// speculative pipeline reads, to force late arrivals and
    /// cancellations in equivalence tests. 0 (the default) disables it;
    /// intentionally not exposed as a CLI flag.
    pub pipeline_test_delay_us: u64,
    /// Bound on the pipeline's blocking late-arrival wait, in
    /// milliseconds: a `take` that beats its speculative read gives up
    /// after this long with a typed `Error::Offload` (and a
    /// `restore_timeout` flight cause) instead of blocking forever on
    /// a dead shard's reply. 0 (the default) keeps the pre-existing
    /// unbounded wait.
    pub restore_wait_timeout_ms: u64,
    /// Deterministic fault injection (`offload::fault`): the master
    /// seed. `None` (the default) leaves the injector entirely inert;
    /// `Some` arms the per-site rates below. Settable via
    /// `--fault-seed` or the `ASRKF_FAULT_SEED` env var.
    pub fault_seed: Option<u64>,
    /// Probability an individual spill read/write/free returns an
    /// injected I/O error (only with `fault_seed`).
    pub fault_io_rate: f64,
    /// Probability a spill record write is torn: truncated bytes are
    /// written, then the op errors (only with `fault_seed`).
    pub fault_torn_rate: f64,
    /// Probability a worker-pool op panics at entry, before touching
    /// its shard (only with `fault_seed`).
    pub fault_panic_rate: f64,
    /// Probability a worker-pool op sleeps `fault_delay_us` before
    /// executing — a delayed reply (only with `fault_seed`).
    pub fault_delay_rate: f64,
    /// Sleep applied when a reply-delay fault fires, in microseconds.
    pub fault_delay_us: u64,
    /// Total attempts for each spill I/O op (`offload::fault::
    /// RetryPolicy`): 1 disables retries (the pre-retry fail-fast
    /// behavior); the default 3 absorbs transient errors.
    pub io_retry_attempts: u32,
    /// First retry backoff in microseconds; doubles per retry, plus up
    /// to 50% seeded jitter.
    pub io_retry_backoff_us: u64,
    /// Wall-clock budget for one logical spill op including all its
    /// retries, in milliseconds. 0 disables the deadline.
    pub io_retry_deadline_ms: u64,
}

impl Default for OffloadConfig {
    fn default() -> Self {
        OffloadConfig {
            hot_budget_bytes: 64 << 20,
            cold_budget_bytes: 256 << 20,
            cold_after_steps: 8,
            quantize_cold: true,
            // u8 affine quantization: worst case = range/255/2 ≈ 0.00196;
            // small headroom for f32 rounding.
            cold_quant_rel_error: 0.002,
            codec_ladder: CodecLadder::default(),
            ebq_rel_error: 0.02,
            spill_dir: None,
            spill_persist: false,
            prefetch_ahead: 2,
            stage_pressure: 0.5,
            block_rows: 32,
            shards: 1,
            shard_partition: ShardPartition::Hash,
            flight_recorder_cap: 4096,
            pipeline: true,
            restore_deadline_steps: 4,
            stage_burst_rows: 64,
            pipeline_test_delay_us: 0,
            restore_wait_timeout_ms: 0,
            fault_seed: None,
            // Per-site rates only matter once fault_seed arms the
            // injector; the defaults make a bare `--fault-seed N` run
            // inject meaningfully (CI's fault smoke relies on this).
            fault_io_rate: 0.02,
            fault_torn_rate: 0.01,
            fault_panic_rate: 0.005,
            fault_delay_rate: 0.02,
            fault_delay_us: 200,
            io_retry_attempts: 3,
            io_retry_backoff_us: 100,
            io_retry_deadline_ms: 250,
        }
    }
}

impl OffloadConfig {
    pub fn from_args(args: &Args) -> Result<Self, String> {
        let d = OffloadConfig::default();
        let rate = |key: &str, dv: f64| -> Result<f64, String> {
            let v = args.f64_or(key, dv)?;
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("--{key}: expected a probability in [0, 1], got {v}"));
            }
            Ok(v)
        };
        let codec_ladder = {
            let ladder_spec = args.str_or("codec-ladder", "");
            let single = args.str_or("cold-codec", "");
            let legacy_raw = args.bool("no-cold-quant");
            let given =
                usize::from(!ladder_spec.is_empty()) + usize::from(!single.is_empty())
                    + usize::from(legacy_raw);
            if given > 1 {
                return Err(
                    "--codec-ladder, --cold-codec, and --no-cold-quant are mutually \
                     exclusive (they all set the compression ladder)"
                        .to_string(),
                );
            }
            if legacy_raw {
                log::warn!(
                    "--no-cold-quant is deprecated; use --cold-codec raw \
                     (or --codec-ladder) instead"
                );
                CodecLadder::single(CodecId::Raw)
            } else if !single.is_empty() {
                CodecLadder::single(CodecId::parse(&single).map_err(|e| format!("--cold-codec: {e}"))?)
            } else if !ladder_spec.is_empty() {
                CodecLadder::parse(&ladder_spec).map_err(|e| format!("--codec-ladder: {e}"))?
            } else {
                d.codec_ladder.clone()
            }
        };
        Ok(OffloadConfig {
            hot_budget_bytes: args.usize_or("hot-budget-mb", d.hot_budget_bytes >> 20)? << 20,
            cold_budget_bytes: args.usize_or("cold-budget-mb", d.cold_budget_bytes >> 20)? << 20,
            cold_after_steps: args.u64_or("cold-after", d.cold_after_steps)?,
            quantize_cold: !codec_ladder.is_raw(),
            cold_quant_rel_error: d.cold_quant_rel_error,
            ebq_rel_error: {
                let v = args.f32_or("ebq-rel-error", d.ebq_rel_error)?;
                if !v.is_finite() || v <= 0.0 || v > 0.5 {
                    return Err(format!(
                        "--ebq-rel-error: expected a relative error in (0, 0.5], got {v}"
                    ));
                }
                v
            },
            codec_ladder,
            spill_dir: {
                let s = args.str_or("spill-dir", "");
                if s.is_empty() { None } else { Some(s) }
            },
            spill_persist: args.bool("spill-persist"),
            prefetch_ahead: args.u64_or("prefetch-ahead", d.prefetch_ahead)?,
            stage_pressure: args.f32_or("stage-pressure", d.stage_pressure)?,
            block_rows: d.block_rows,
            shards: args.usize_in("shards", d.shards, 1, crate::offload::MAX_SHARDS)?,
            shard_partition: ShardPartition::parse(&args.str_or("shard-partition", "hash"))?,
            flight_recorder_cap: args.usize_or("flight-recorder-cap", d.flight_recorder_cap)?,
            pipeline: !args.bool("no-restore-pipeline"),
            restore_deadline_steps: {
                let v = args.u64_or("restore-deadline-steps", d.restore_deadline_steps)?;
                if v == 0 {
                    return Err(
                        "--restore-deadline-steps: 0 would reclaim every speculative job \
                         at the very next step (minimum is 1)"
                            .to_string(),
                    );
                }
                v
            },
            stage_burst_rows: args.usize_in("stage-burst-rows", d.stage_burst_rows, 1, 65536)?,
            pipeline_test_delay_us: d.pipeline_test_delay_us,
            restore_wait_timeout_ms: args
                .u64_or("restore-wait-timeout-ms", d.restore_wait_timeout_ms)?,
            fault_seed: {
                // CLI flag wins; the env var lets CI arm a smoke run
                // without threading a flag through every harness.
                let flag = args.str_or("fault-seed", "");
                let s = if flag.is_empty() {
                    std::env::var("ASRKF_FAULT_SEED").unwrap_or_default()
                } else {
                    flag
                };
                if s.is_empty() {
                    None
                } else {
                    Some(s.parse::<u64>().map_err(|_| {
                        format!("--fault-seed / ASRKF_FAULT_SEED: expected a u64 seed, got '{s}'")
                    })?)
                }
            },
            fault_io_rate: rate("fault-io-rate", d.fault_io_rate)?,
            fault_torn_rate: rate("fault-torn-rate", d.fault_torn_rate)?,
            fault_panic_rate: rate("fault-panic-rate", d.fault_panic_rate)?,
            fault_delay_rate: rate("fault-delay-rate", d.fault_delay_rate)?,
            fault_delay_us: args.u64_or("fault-delay-us", d.fault_delay_us)?,
            io_retry_attempts: args
                .usize_in("io-retry-attempts", d.io_retry_attempts as usize, 1, 64)?
                as u32,
            io_retry_backoff_us: args.u64_or("io-retry-backoff-us", d.io_retry_backoff_us)?,
            io_retry_deadline_ms: args.u64_or("io-retry-deadline-ms", d.io_retry_deadline_ms)?,
        })
    }

    /// Budget slice for partition member `slot` of `n` (coordinator
    /// slots or store shards): `total / n`, with the remainder bytes
    /// spread one-per-slot across the first `total % n` members so the
    /// slices sum exactly to the configured total (the old equal split
    /// silently dropped up to `n - 1` bytes per tier). Slices below one
    /// hot row are rejected at store construction, where the row size
    /// is known (`offload::ShardedStore::new`).
    pub fn partitioned(&self, n: usize, slot: usize) -> OffloadConfig {
        let n = n.max(1);
        let slot = slot.min(n - 1);
        let split = |total: usize| total / n + usize::from(slot < total % n);
        OffloadConfig {
            hot_budget_bytes: split(self.hot_budget_bytes),
            cold_budget_bytes: split(self.cold_budget_bytes),
            ..self.clone()
        }
    }

    /// Class-weighted budget slice for `member` of a weighted partition
    /// (continuous batching: one weight per occupied coordinator slot,
    /// taken from the slot's [`QosClass`]). Built on [`weighted_shares`],
    /// so equal weights reproduce [`OffloadConfig::partitioned`] exactly
    /// — the oracle tested in this module and in
    /// `tests/coordinator_test.rs`.
    pub fn weighted(&self, weights: &[u64], member: usize) -> OffloadConfig {
        if weights.is_empty() {
            return self.partitioned(1, 0);
        }
        let member = member.min(weights.len() - 1);
        OffloadConfig {
            hot_budget_bytes: weighted_shares(self.hot_budget_bytes, weights)[member],
            cold_budget_bytes: weighted_shares(self.cold_budget_bytes, weights)[member],
            ..self.clone()
        }
    }
}

/// Largest-remainder split of `total` into one share per weight:
/// member `i` gets `floor(total * w_i / sum(w))` plus at most one of
/// the leftover units, handed out by descending fractional remainder
/// (ties broken toward the lower index). Shares always sum exactly to
/// `total`. With equal weights the quotients and remainders are
/// identical for every member, so the leftover lands on the lowest
/// indices — byte-for-byte the [`OffloadConfig::partitioned`] split.
/// All-zero weights degrade to an equal split rather than divide by
/// zero.
pub fn weighted_shares(total: usize, weights: &[u64]) -> Vec<usize> {
    let n = weights.len();
    if n == 0 {
        return Vec::new();
    }
    let w_sum: u128 = weights.iter().map(|&w| w as u128).sum();
    if w_sum == 0 {
        return (0..n).map(|i| total / n + usize::from(i < total % n)).collect();
    }
    let mut shares = Vec::with_capacity(n);
    let mut remainders = Vec::with_capacity(n);
    let mut assigned = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        let scaled = total as u128 * w as u128;
        let base = (scaled / w_sum) as usize;
        shares.push(base);
        assigned += base;
        remainders.push((scaled % w_sum, i));
    }
    remainders.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    for &(_, i) in remainders.iter().take(total - assigned) {
        shares[i] += 1;
    }
    shares
}

/// Quality-of-service class attached to every coordinator request.
/// Declaration order is priority order: the scheduler always pops the
/// lowest-index non-empty class queue, and admission sheds toward
/// higher indices (lower classes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum QosClass {
    /// Latency-sensitive traffic: popped first, largest default budget
    /// weight.
    Interactive,
    /// The default for requests that don't state a class (and the class
    /// assigned to every legacy wire request).
    Standard,
    /// Throughput traffic: popped last, smallest weight, and the final
    /// shed target before an outright reject.
    Batch,
}

impl QosClass {
    pub const COUNT: usize = 3;
    /// All classes in priority order (highest first).
    pub const ALL: [QosClass; QosClass::COUNT] =
        [QosClass::Interactive, QosClass::Standard, QosClass::Batch];

    /// Wire/flag spelling (also the metrics `class` label value).
    pub fn as_str(self) -> &'static str {
        match self {
            QosClass::Interactive => "interactive",
            QosClass::Standard => "standard",
            QosClass::Batch => "batch",
        }
    }

    /// Parse a wire `class` field or `--class` flag value.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "interactive" => Ok(QosClass::Interactive),
            "standard" => Ok(QosClass::Standard),
            "batch" => Ok(QosClass::Batch),
            other => Err(format!(
                "qos class: expected 'interactive', 'standard' or 'batch', got '{other}'"
            )),
        }
    }

    /// Stable index into per-class arrays (priority order).
    pub fn index(self) -> usize {
        self as usize
    }

    /// The next lower class (shed target), or `None` from `Batch`.
    pub fn lower(self) -> Option<QosClass> {
        match self {
            QosClass::Interactive => Some(QosClass::Standard),
            QosClass::Standard => Some(QosClass::Batch),
            QosClass::Batch => None,
        }
    }
}

/// QoS scheduling knobs for the continuous-batching coordinator.
#[derive(Debug, Clone)]
pub struct QosConfig {
    /// Budget-slice weight per class, indexed by [`QosClass::index`]
    /// (`--qos-weights I,S,B`). Occupied slots split the tier budgets
    /// in proportion to their class weight (`weighted_shares`); equal
    /// weights reproduce the old static `1/B` split.
    pub weights: [u64; QosClass::COUNT],
    /// Per-class queue depth (`--qos-queue-depth`): arrivals beyond
    /// this on a class queue get a typed `queue_full` reject.
    pub queue_depth: usize,
    /// Admission headroom (`--admission-headroom`): the projected
    /// per-slot hot slice must clear `(1 + headroom)` times the hard
    /// floor (one row per shard) before a request is admitted at its
    /// class; below that it sheds toward `Batch`, then rejects.
    pub admission_headroom: f32,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig { weights: [4, 2, 1], queue_depth: 64, admission_headroom: 0.25 }
    }
}

impl QosConfig {
    pub fn from_args(args: &Args) -> Result<Self, String> {
        let d = QosConfig::default();
        let weights = {
            let spec = args.str_or(
                "qos-weights",
                &format!("{},{},{}", d.weights[0], d.weights[1], d.weights[2]),
            );
            let parts: Vec<&str> = spec.split(',').collect();
            if parts.len() != QosClass::COUNT {
                return Err(format!(
                    "--qos-weights: expected {} comma-separated weights \
                     (interactive,standard,batch), got '{spec}'",
                    QosClass::COUNT
                ));
            }
            let mut w = [0u64; QosClass::COUNT];
            for (i, p) in parts.iter().enumerate() {
                w[i] = p
                    .trim()
                    .parse::<u64>()
                    .map_err(|_| format!("--qos-weights: '{p}' is not a non-negative integer"))?;
            }
            if w.iter().all(|&x| x == 0) {
                return Err("--qos-weights: at least one class weight must be non-zero".to_string());
            }
            w
        };
        let headroom = args.f32_or("admission-headroom", d.admission_headroom)?;
        if !(0.0..=4.0).contains(&headroom) {
            return Err(format!("--admission-headroom: {headroom} outside [0, 4]"));
        }
        Ok(QosConfig {
            weights,
            queue_depth: args.usize_in("qos-queue-depth", d.queue_depth, 1, 1 << 20)?,
            admission_headroom: headroom,
        })
    }

    /// Weight for one class.
    pub fn weight(&self, class: QosClass) -> u64 {
        self.weights[class.index()]
    }
}

/// Entropy-guided recovery ladder (paper §3.6, implemented here).
#[derive(Debug, Clone)]
pub struct RecoveryConfig {
    pub enabled: bool,
    /// Spike trigger: H_t > ema + lambda * std.
    pub lambda: f32,
    /// EMA decay for the entropy baseline.
    pub ema_decay: f32,
    /// Minimum steps between interventions (cooldown).
    pub cooldown: usize,
    /// Window-reset horizon N (unfreeze tokens frozen in last N steps).
    pub wr_horizon: usize,
    /// Rewalk depth k (regenerate last k tokens after FR).
    pub rr_depth: usize,
    /// Steps a milder level gets to settle entropy before escalating.
    pub escalation_patience: usize,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig {
            enabled: false,
            lambda: 3.0,
            ema_decay: 0.95,
            cooldown: 8,
            wr_horizon: 32,
            rr_depth: 4,
            escalation_patience: 4,
        }
    }
}

/// Engine-level settings.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub artifacts_dir: String,
    pub freeze: FreezeConfig,
    pub sampling: SamplingConfig,
    pub recovery: RecoveryConfig,
    pub offload: OffloadConfig,
    /// Stop generation at this many new tokens if no EOS-like signal.
    pub max_new_tokens: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            artifacts_dir: "artifacts".to_string(),
            freeze: FreezeConfig::default(),
            sampling: SamplingConfig::default(),
            recovery: RecoveryConfig::default(),
            offload: OffloadConfig::default(),
            max_new_tokens: 500,
        }
    }
}

impl EngineConfig {
    pub fn from_args(args: &Args) -> Result<Self, String> {
        let d = EngineConfig::default();
        Ok(EngineConfig {
            artifacts_dir: args.str_or("artifacts", &d.artifacts_dir),
            freeze: FreezeConfig::from_args(args)?,
            sampling: SamplingConfig::from_args(args)?,
            recovery: RecoveryConfig {
                enabled: args.bool("recovery"),
                ..RecoveryConfig::default()
            },
            offload: OffloadConfig::from_args(args)?,
            max_new_tokens: args.usize_or("max-new-tokens", d.max_new_tokens)?,
        })
    }
}

/// Serving coordinator settings.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: String,
    /// Capacity of the socket → scheduler handoff channel; a full
    /// channel back-pressures `CoordinatorHandle::submit` (the
    /// per-class scheduling queues behind it are bounded separately by
    /// `qos.queue_depth`).
    pub queue_cap: usize,
    /// Max sessions batched together (bounded by decode bucket sizes).
    pub max_batch: usize,
    /// Batcher wait for fill (microseconds) before dispatching a
    /// partially-full batch.
    pub batch_wait_us: u64,
    /// QoS scheduling + admission knobs.
    pub qos: QosConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7341".to_string(),
            queue_cap: 256,
            max_batch: 8,
            batch_wait_us: 2000,
            qos: QosConfig::default(),
        }
    }
}

impl ServerConfig {
    pub fn from_args(args: &Args) -> Result<Self, String> {
        let d = ServerConfig::default();
        Ok(ServerConfig {
            addr: args.str_or("addr", &d.addr),
            queue_cap: args.usize_or("queue-cap", d.queue_cap)?,
            max_batch: args.usize_or("max-batch", d.max_batch)?,
            batch_wait_us: args.u64_or("batch-wait-us", d.batch_wait_us)?,
            qos: QosConfig::from_args(args)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn defaults_match_paper() {
        let f = FreezeConfig::default();
        assert_eq!(f.window_k, 32);
        assert_eq!(f.tau, 1.0);
        assert_eq!(f.softness_k, 2.0);
        let s = SamplingConfig::default();
        assert_eq!(s.temperature, 0.7);
        assert_eq!(s.top_k, 40);
        assert_eq!(s.top_p, 0.9);
    }

    #[test]
    fn cli_overrides() {
        let a = args(&["gen", "--tau", "0.3", "--window-k", "16", "--absolute-tau"]);
        let f = FreezeConfig::from_args(&a).unwrap();
        assert_eq!(f.tau, 0.3);
        assert_eq!(f.window_k, 16);
        assert!(!f.relative_tau);
    }

    #[test]
    fn greedy_sampling() {
        let s = SamplingConfig::greedy();
        assert_eq!(s.temperature, 0.0);
    }

    #[test]
    fn offload_defaults_and_overrides() {
        let d = OffloadConfig::default();
        assert!(d.quantize_cold);
        assert!(d.spill_dir.is_none());
        assert!(!d.spill_persist, "persistence must be opt-in");
        let a = args(&[
            "gen",
            "--hot-budget-mb",
            "8",
            "--cold-after",
            "16",
            "--no-cold-quant",
            "--spill-dir",
            "/tmp/spill",
            "--spill-persist",
        ]);
        let o = OffloadConfig::from_args(&a).unwrap();
        assert_eq!(o.hot_budget_bytes, 8 << 20);
        assert_eq!(o.cold_after_steps, 16);
        assert!(!o.quantize_cold);
        assert_eq!(o.spill_dir.as_deref(), Some("/tmp/spill"));
        assert!(o.spill_persist);
        assert_eq!(o.flight_recorder_cap, 4096, "flight recorder on by default");
        let a = args(&["gen", "--flight-recorder-cap", "0"]);
        let o = OffloadConfig::from_args(&a).unwrap();
        assert_eq!(o.flight_recorder_cap, 0);
        assert_eq!(o.partitioned(2, 1).flight_recorder_cap, 0, "partition carries the cap");
    }

    #[test]
    fn codec_ladder_flags_parse_and_map_legacy() {
        let d = OffloadConfig::default();
        assert_eq!(d.codec_ladder, CodecLadder::single(CodecId::U8), "default is u8-only");
        assert_eq!(d.ebq_rel_error, 0.02);

        // full ladder: eta thresholds pick the rung
        let a = args(&["gen", "--codec-ladder", "0:u8,64:u4,512:ebq", "--ebq-rel-error", "0.01"]);
        let o = OffloadConfig::from_args(&a).unwrap();
        assert!(o.quantize_cold);
        assert_eq!(o.ebq_rel_error, 0.01);
        assert_eq!(o.codec_ladder.pick(0), CodecId::U8);
        assert_eq!(o.codec_ladder.pick(64), CodecId::U4);
        assert_eq!(o.codec_ladder.pick(1000), CodecId::Ebq);
        assert_eq!(o.partitioned(2, 1).codec_ladder, o.codec_ladder, "partition carries it");

        // --cold-codec is single-rung shorthand; raw disables demotion
        let o = OffloadConfig::from_args(&args(&["gen", "--cold-codec", "u4"])).unwrap();
        assert_eq!(o.codec_ladder, CodecLadder::single(CodecId::U4));
        assert!(o.quantize_cold);
        let o = OffloadConfig::from_args(&args(&["gen", "--cold-codec", "raw"])).unwrap();
        assert!(o.codec_ladder.is_raw());
        assert!(!o.quantize_cold);

        // legacy --no-cold-quant still parses (deprecated), maps to raw
        let o = OffloadConfig::from_args(&args(&["gen", "--no-cold-quant"])).unwrap();
        assert!(o.codec_ladder.is_raw());
        assert!(!o.quantize_cold);

        // the three spellings are mutually exclusive; bad specs reject
        for bad in [
            args(&["gen", "--no-cold-quant", "--codec-ladder", "0:u8"]),
            args(&["gen", "--cold-codec", "u8", "--codec-ladder", "0:u8"]),
            args(&["gen", "--no-cold-quant", "--cold-codec", "raw"]),
            args(&["gen", "--codec-ladder", "5:u4"]),
            args(&["gen", "--codec-ladder", "0:u8,64:u4,64:ebq"]),
            args(&["gen", "--codec-ladder", "0:raw,64:u4"]),
            args(&["gen", "--cold-codec", "nope"]),
            args(&["gen", "--ebq-rel-error", "0"]),
            args(&["gen", "--ebq-rel-error", "0.9"]),
        ] {
            assert!(OffloadConfig::from_args(&bad).is_err(), "{:?} must reject", bad);
        }
    }

    #[test]
    fn pipeline_flags_parse_and_bound() {
        let d = OffloadConfig::default();
        assert!(d.pipeline, "restore pipeline is on by default");
        assert_eq!(d.restore_deadline_steps, 4);
        assert_eq!(d.stage_burst_rows, 64);
        assert_eq!(d.pipeline_test_delay_us, 0, "fault injection is test-only");

        let a = args(&[
            "gen",
            "--no-restore-pipeline",
            "--restore-deadline-steps",
            "9",
            "--stage-burst-rows",
            "128",
        ]);
        let o = OffloadConfig::from_args(&a).unwrap();
        assert!(!o.pipeline);
        assert_eq!(o.restore_deadline_steps, 9);
        assert_eq!(o.stage_burst_rows, 128);
        assert_eq!(o.partitioned(2, 0).stage_burst_rows, 128, "partition carries the burst");
        assert!(!o.partitioned(2, 1).pipeline, "partition carries the pipeline switch");

        // parse-time sanity bounds
        let zero_deadline = args(&["gen", "--restore-deadline-steps", "0"]);
        assert!(OffloadConfig::from_args(&zero_deadline).is_err());
        let zero_burst = args(&["gen", "--stage-burst-rows", "0"]);
        assert!(OffloadConfig::from_args(&zero_burst).is_err());
        let huge_burst = args(&["gen", "--stage-burst-rows", "65537"]);
        assert!(OffloadConfig::from_args(&huge_burst).is_err());
    }

    #[test]
    fn fault_flags_parse_validate_and_default_off() {
        let d = OffloadConfig::default();
        assert_eq!(d.fault_seed, None, "injection is off unless seeded");
        assert_eq!(d.restore_wait_timeout_ms, 0, "late-arrival wait unbounded by default");
        assert_eq!(d.io_retry_attempts, 3);

        let a = args(&[
            "gen",
            "--fault-seed",
            "42",
            "--fault-io-rate",
            "0.5",
            "--fault-torn-rate",
            "0",
            "--fault-panic-rate",
            "0.125",
            "--fault-delay-rate",
            "1",
            "--fault-delay-us",
            "50",
            "--restore-wait-timeout-ms",
            "250",
            "--io-retry-attempts",
            "5",
            "--io-retry-backoff-us",
            "10",
            "--io-retry-deadline-ms",
            "100",
        ]);
        let o = OffloadConfig::from_args(&a).unwrap();
        assert_eq!(o.fault_seed, Some(42));
        assert_eq!(o.fault_io_rate, 0.5);
        assert_eq!(o.fault_torn_rate, 0.0);
        assert_eq!(o.fault_panic_rate, 0.125);
        assert_eq!(o.fault_delay_rate, 1.0);
        assert_eq!(o.fault_delay_us, 50);
        assert_eq!(o.restore_wait_timeout_ms, 250);
        assert_eq!(o.io_retry_attempts, 5);
        assert_eq!(o.io_retry_backoff_us, 10);
        assert_eq!(o.io_retry_deadline_ms, 100);
        assert_eq!(o.partitioned(2, 1).fault_seed, Some(42), "partition carries the seed");
        assert_eq!(o.partitioned(2, 0).restore_wait_timeout_ms, 250);

        // rates are probabilities; a bad seed string is a parse error
        for bad in [
            args(&["gen", "--fault-io-rate", "1.5"]),
            args(&["gen", "--fault-panic-rate", "-0.1"]),
            args(&["gen", "--fault-seed", "not-a-seed"]),
            args(&["gen", "--io-retry-attempts", "0"]),
        ] {
            assert!(OffloadConfig::from_args(&bad).is_err());
        }
    }

    #[test]
    fn shard_partition_flag_spelling_roundtrips() {
        for p in [ShardPartition::Hash, ShardPartition::Range] {
            assert_eq!(ShardPartition::parse(p.as_str()).unwrap(), p);
        }
    }

    #[test]
    fn offload_partition_divides_budgets() {
        let o = OffloadConfig { hot_budget_bytes: 100, cold_budget_bytes: 40, ..Default::default() };
        for slot in 0..4 {
            let p = o.partitioned(4, slot);
            assert_eq!(p.hot_budget_bytes, 25);
            assert_eq!(p.cold_budget_bytes, 10);
        }
        // n=0 clamps to 1
        assert_eq!(o.partitioned(0, 0).hot_budget_bytes, 100);
    }

    #[test]
    fn offload_partition_distributes_remainder() {
        let o = OffloadConfig { hot_budget_bytes: 101, cold_budget_bytes: 10, ..Default::default() };
        // 101 / 3 = 33 rem 2: slots 0 and 1 get the extra bytes
        let hot: Vec<usize> = (0..3).map(|i| o.partitioned(3, i).hot_budget_bytes).collect();
        assert_eq!(hot, vec![34, 34, 33]);
        assert_eq!(hot.iter().sum::<usize>(), 101, "no bytes dropped");
        // 10 / 3 = 3 rem 1
        let cold: Vec<usize> = (0..3).map(|i| o.partitioned(3, i).cold_budget_bytes).collect();
        assert_eq!(cold, vec![4, 3, 3]);
        assert_eq!(cold.iter().sum::<usize>(), 10);
        // a budget smaller than n leaves the tail slots at zero (the
        // store rejects unusable hot slices at construction)
        let tiny = OffloadConfig { hot_budget_bytes: 2, ..Default::default() };
        assert_eq!(tiny.partitioned(3, 2).hot_budget_bytes, 0);
    }

    #[test]
    fn weighted_shares_sum_exactly_and_order_by_weight() {
        let s = weighted_shares(1000, &[4, 2, 1]);
        assert_eq!(s.iter().sum::<usize>(), 1000, "no bytes dropped");
        assert!(s[0] > s[1] && s[1] > s[2], "heavier class gets the bigger slice: {s:?}");
        // degenerate inputs
        assert!(weighted_shares(10, &[]).is_empty());
        assert_eq!(weighted_shares(7, &[0, 0, 0]), vec![3, 2, 2], "all-zero falls back to equal");
        assert_eq!(weighted_shares(5, &[0, 3]), vec![0, 5], "zero-weight member gets nothing");
    }

    #[test]
    fn equal_weights_reproduce_partitioned_oracle() {
        // The acceptance oracle: a uniform weight vector must reproduce
        // OffloadConfig::partitioned byte-for-byte, for every member,
        // totals with and without remainders, and any uniform weight.
        for total in [0usize, 1, 2, 10, 101, 4096, 64 << 20] {
            let o = OffloadConfig {
                hot_budget_bytes: total,
                cold_budget_bytes: total / 3,
                ..Default::default()
            };
            for n in 1..=8usize {
                for w in [1u64, 2, 7] {
                    let weights = vec![w; n];
                    for member in 0..n {
                        let ws = o.weighted(&weights, member);
                        let ps = o.partitioned(n, member);
                        let tag = format!("{total}/{n}@{member} w={w}");
                        assert_eq!(ws.hot_budget_bytes, ps.hot_budget_bytes, "hot {tag}");
                        assert_eq!(ws.cold_budget_bytes, ps.cold_budget_bytes, "cold {tag}");
                    }
                }
            }
        }
    }

    #[test]
    fn qos_class_spelling_roundtrips_and_orders() {
        for c in QosClass::ALL {
            assert_eq!(QosClass::parse(c.as_str()).unwrap(), c);
        }
        assert!(QosClass::parse("premium").is_err());
        assert!(QosClass::Interactive < QosClass::Standard);
        assert_eq!(QosClass::Interactive.lower(), Some(QosClass::Standard));
        assert_eq!(QosClass::Batch.lower(), None, "Batch is the last shed target");
        assert_eq!(QosClass::Batch.index(), 2);
    }

    #[test]
    fn qos_flags_parse_and_bound() {
        let d = QosConfig::default();
        assert_eq!(d.weights, [4, 2, 1]);
        assert_eq!(d.queue_depth, 64);
        assert!((d.admission_headroom - 0.25).abs() < 1e-6);

        let a = args(&[
            "serve",
            "--qos-weights",
            "8,2,1",
            "--qos-queue-depth",
            "16",
            "--admission-headroom",
            "0.5",
        ]);
        let q = QosConfig::from_args(&a).unwrap();
        assert_eq!(q.weights, [8, 2, 1]);
        assert_eq!(q.weight(QosClass::Interactive), 8);
        assert_eq!(q.queue_depth, 16);
        assert!((q.admission_headroom - 0.5).abs() < 1e-6);

        for bad in [
            vec!["serve", "--qos-weights", "1,2"],
            vec!["serve", "--qos-weights", "a,b,c"],
            vec!["serve", "--qos-weights", "0,0,0"],
            vec!["serve", "--qos-queue-depth", "0"],
            vec!["serve", "--admission-headroom", "9"],
        ] {
            assert!(QosConfig::from_args(&args(&bad)).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn server_config_from_args_carries_qos() {
        let s = ServerConfig::from_args(&args(&["serve"])).unwrap();
        assert_eq!(s.addr, "127.0.0.1:7341");
        assert_eq!(s.qos.weights, [4, 2, 1]);
        let a = args(&["serve", "--max-batch", "4", "--qos-weights", "1,1,1"]);
        let s = ServerConfig::from_args(&a).unwrap();
        assert_eq!(s.max_batch, 4);
        assert_eq!(s.qos.weights, [1, 1, 1]);
    }

    #[test]
    fn shard_flags_parse() {
        let d = OffloadConfig::default();
        assert_eq!(d.shards, 1);
        assert_eq!(d.shard_partition, ShardPartition::Hash);
        let a = args(&["serve", "--shards", "4", "--shard-partition", "range"]);
        let o = OffloadConfig::from_args(&a).unwrap();
        assert_eq!(o.shards, 4);
        assert_eq!(o.shard_partition, ShardPartition::Range);
        let bad = args(&["serve", "--shard-partition", "modulo"]);
        assert!(OffloadConfig::from_args(&bad).is_err());
        let out_of_range = args(&["serve", "--shards", "0"]);
        assert!(OffloadConfig::from_args(&out_of_range).is_err());
    }
}
