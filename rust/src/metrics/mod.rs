//! Serving metrics: latency histograms, counters, and CSV export used
//! by the coordinator and the bench harness, plus the per-tier
//! occupancy gauges and restore-latency histograms fed by the tiered
//! frozen-KV store (`crate::offload`).

pub mod flight;
pub mod registry;

pub use flight::{write_chrome_trace, Cause, FlightEvent, FlightRecorder, StepSpan};
pub use registry::{
    load_gen_csv_headers, parse_exposition, serving_csv_headers, start_interval_logger,
    MetricKind, MetricSpec, Registry, Snapshot, SnapshotBuilder, CATALOG, LOAD_GEN_CSV_COLUMNS,
    SERVING_CSV_COLUMNS,
};

use std::fmt::Write as _;
use std::time::Duration;

/// Log-bucketed latency histogram (microseconds, ~1.6x bucket growth).
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<u64>,
    counts: Vec<u64>,
    total: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        let mut bounds = Vec::new();
        let mut b = 1u64; // 1us
        while b < 600_000_000 {
            bounds.push(b);
            b = (b as f64 * 1.6).ceil() as u64;
        }
        let n = bounds.len();
        Histogram { bounds, counts: vec![0; n + 1], total: 0, sum_us: 0, max_us: 0 }
    }
}

impl Histogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = self.bounds.partition_point(|&b| b <= us);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> Duration {
        if self.total == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.total)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Approximate quantile from bucket upper bounds.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.total == 0 {
            return Duration::ZERO;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                let us = if i < self.bounds.len() { self.bounds[i] } else { self.max_us };
                return Duration::from_micros(us.min(self.max_us));
            }
        }
        Duration::from_micros(self.max_us)
    }

    pub fn summary(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:.3?} p50={:.3?} p90={:.3?} p99={:.3?} max={:.3?}",
            self.total,
            self.mean(),
            self.quantile(0.5),
            self.quantile(0.9),
            self.quantile(0.99),
            Duration::from_micros(self.max_us),
        )
    }

    /// Total recorded time in microseconds (exact, not bucket-derived).
    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    /// Fold another histogram into this one. Histograms with different
    /// bucket layouts cannot be merged meaningfully — in that case the
    /// merge is refused with a logged error instead of silently adding
    /// misaligned buckets (all histograms in this crate use
    /// `default()`, so a mismatch indicates a bug, not a data path).
    pub fn merge(&mut self, other: &Histogram) {
        if self.bounds != other.bounds {
            log::error!(
                "refusing to merge histograms with mismatched bucket layouts ({} vs {} buckets)",
                self.bounds.len(),
                other.bounds.len()
            );
            return;
        }
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Test-only constructor with a custom bucket layout, used to
    /// exercise the mismatched-merge guard.
    #[cfg(test)]
    fn with_bounds(bounds: Vec<u64>) -> Self {
        let n = bounds.len();
        Histogram { bounds, counts: vec![0; n + 1], total: 0, sum_us: 0, max_us: 0 }
    }
}

/// Log-bucketed histogram over dimensionless counts (batch sizes,
/// scheduler queue depths). Power-of-two buckets: a recorded value `v`
/// lands in the bucket whose upper bound is the smallest `2^k > v`.
#[derive(Debug, Clone)]
pub struct CountHistogram {
    bounds: Vec<u64>,
    counts: Vec<u64>,
    total: u64,
    sum: u64,
    max: u64,
}

impl Default for CountHistogram {
    fn default() -> Self {
        let bounds: Vec<u64> = (0..31).map(|k| 1u64 << k).collect();
        let n = bounds.len();
        CountHistogram { bounds, counts: vec![0; n + 1], total: 0, sum: 0, max: 0 }
    }
}

impl CountHistogram {
    pub fn record(&mut self, v: u64) {
        let idx = self.bounds.partition_point(|&b| b <= v);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v;
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate quantile from bucket upper bounds.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                let v = if i < self.bounds.len() { self.bounds[i] } else { self.max };
                return v.min(self.max);
            }
        }
        self.max
    }

    /// Total of all recorded values (exact, not bucket-derived).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Fold another histogram into this one. Refuses (with a logged
    /// error) when the bucket layouts differ — see `Histogram::merge`.
    pub fn merge(&mut self, other: &CountHistogram) {
        if self.bounds != other.bounds {
            log::error!(
                "refusing to merge count-histograms with mismatched bucket layouts ({} vs {} buckets)",
                self.bounds.len(),
                other.bounds.len()
            );
            return;
        }
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// Test-only constructor with a custom bucket layout.
    #[cfg(test)]
    fn with_bounds(bounds: Vec<u64>) -> Self {
        let n = bounds.len();
        CountHistogram { bounds, counts: vec![0; n + 1], total: 0, sum: 0, max: 0 }
    }

    pub fn summary(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:.1} p50={} p99={} max={}",
            self.total,
            self.mean(),
            self.quantile(0.5),
            self.quantile(0.99),
            self.max,
        )
    }
}

/// Plan-execution batching telemetry: how many rows each decode step's
/// freeze/restore batch moved, and how few contiguous spans those rows
/// coalesced into (`engine::layout::coalesce_runs`). `spans == rows`
/// means no coalescing happened; `spans << rows` is the batched-DMA
/// win FreeKV (arXiv 2505.13109) identifies as the recall bottleneck.
#[derive(Debug, Clone, Default)]
pub struct BatchStats {
    /// rows moved frozen -> active across all restore batches
    pub restore_rows: u64,
    /// contiguous spans those restore rows coalesced into
    pub restore_spans: u64,
    /// rows moved active -> frozen across all freeze batches
    pub freeze_rows: u64,
    /// contiguous spans those freeze rows coalesced into
    pub freeze_spans: u64,
    /// rows per non-empty restore batch
    pub restore_batch: CountHistogram,
    /// rows per non-empty freeze batch
    pub freeze_batch: CountHistogram,
}

impl BatchStats {
    pub fn record_restore(&mut self, rows: usize, spans: usize) {
        if rows == 0 {
            return;
        }
        self.restore_rows += rows as u64;
        self.restore_spans += spans as u64;
        self.restore_batch.record(rows as u64);
    }

    pub fn record_freeze(&mut self, rows: usize, spans: usize) {
        if rows == 0 {
            return;
        }
        self.freeze_rows += rows as u64;
        self.freeze_spans += spans as u64;
        self.freeze_batch.record(rows as u64);
    }

    pub fn merge(&mut self, other: &BatchStats) {
        self.restore_rows += other.restore_rows;
        self.restore_spans += other.restore_spans;
        self.freeze_rows += other.freeze_rows;
        self.freeze_spans += other.freeze_spans;
        self.restore_batch.merge(&other.restore_batch);
        self.freeze_batch.merge(&other.freeze_batch);
    }
}

/// Per-step policy control-plane cost summary (`plan` + `observe` time
/// per decode step), in `OffloadSummary` style: a small copyable
/// snapshot attached to `GenStats`/`GenResponse` and exported in the
/// server JSON, so the O(work)-not-O(context) contract of the indexed
/// policy (see `kv/README.md`) is observable per request.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanLatency {
    /// decode steps measured
    pub steps: u64,
    pub mean_us: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

impl PlanLatency {
    pub fn from_histogram(h: &Histogram) -> Self {
        PlanLatency {
            steps: h.count(),
            mean_us: h.mean().as_micros() as u64,
            p50_us: h.quantile(0.5).as_micros() as u64,
            p99_us: h.quantile(0.99).as_micros() as u64,
            max_us: h.max().as_micros() as u64,
        }
    }
}

// ---------------------------------------------------------------------------
// Tiered frozen-KV storage metrics (fed by `crate::offload::TieredStore`)

/// Storage tier of a frozen row (see `crate::offload`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierKind {
    Hot,
    Cold,
    Spill,
}

impl TierKind {
    /// Stable label value used in metric series and trace exports.
    pub fn as_str(&self) -> &'static str {
        match self {
            TierKind::Hot => "hot",
            TierKind::Cold => "cold",
            TierKind::Spill => "spill",
        }
    }
}

/// Per-step decode wall-clock attribution, accumulated by
/// `engine::Session`. The five segments tile the span from the start
/// of `apply_plan` to the end of `absorb` contiguously, so
/// `accounted_us()` equals `wall_us` up to the (sub-microsecond)
/// instants between adjacent clock reads:
///
/// * `plan` — policy `plan_into` + `observe` + entropy/recovery
///   bookkeeping (everything in `absorb` that is not staging/sweep),
/// * `restore` — frozen-row restore batches plus prefetch staging,
/// * `restore_wait` — time blocked on the speculative restore
///   pipeline (waiting for in-flight tier reads to land). Carved out
///   of whichever segment the wait occurred inside, so an effective
///   pipeline shows up as this segment shrinking toward zero while
///   the others keep their pure-CPU cost,
/// * `compute` — the device call window (upload/execute/download and
///   the host glue around it),
/// * `freeze` — freeze batches plus the store's per-step sweep.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StepSegments {
    /// decode steps measured
    pub steps: u64,
    pub plan_us: u64,
    pub restore_us: u64,
    /// time blocked waiting on in-flight speculative restores
    pub restore_wait_us: u64,
    pub compute_us: u64,
    pub freeze_us: u64,
    /// measured step wall-clock (apply_plan start -> absorb end)
    pub wall_us: u64,
}

impl StepSegments {
    /// Sum of the five attributed segments.
    pub fn accounted_us(&self) -> u64 {
        self.plan_us + self.restore_us + self.restore_wait_us + self.compute_us + self.freeze_us
    }

    /// Fraction of measured wall-clock the segments account for
    /// (1.0 when nothing was measured).
    pub fn coverage(&self) -> f64 {
        if self.wall_us == 0 {
            1.0
        } else {
            self.accounted_us() as f64 / self.wall_us as f64
        }
    }

    pub fn merge(&mut self, other: &StepSegments) {
        self.steps += other.steps;
        self.plan_us += other.plan_us;
        self.restore_us += other.restore_us;
        self.restore_wait_us += other.restore_wait_us;
        self.compute_us += other.compute_us;
        self.freeze_us += other.freeze_us;
        self.wall_us += other.wall_us;
    }
}

/// Point-in-time per-tier occupancy gauges, with high-water marks.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TierOccupancy {
    pub hot_rows: usize,
    pub hot_bytes: usize,
    pub cold_rows: usize,
    pub cold_bytes: usize,
    pub spill_rows: usize,
    pub spill_bytes: usize,
    pub peak_hot_bytes: usize,
    pub peak_cold_bytes: usize,
    pub peak_spill_bytes: usize,
    /// What the resident frozen rows would occupy uncompressed (f32) —
    /// the denominator for the cold-tier compression ratio.
    pub uncompressed_bytes: usize,
}

impl TierOccupancy {
    pub fn total_rows(&self) -> usize {
        self.hot_rows + self.cold_rows + self.spill_rows
    }

    pub fn total_bytes(&self) -> usize {
        self.hot_bytes + self.cold_bytes + self.spill_bytes
    }
}

/// Restore-latency histograms split by the tier a `take()` was served
/// from. A hot-tier restore is a plain copy; cold/spill restores pay
/// dequantization (and file I/O) — keeping them separate makes the
/// prefetch-ahead win measurable.
#[derive(Debug, Clone, Default)]
pub struct RestoreLatency {
    pub hot: Histogram,
    pub cold: Histogram,
    pub spill: Histogram,
}

impl RestoreLatency {
    pub fn record(&mut self, tier: TierKind, d: Duration) {
        match tier {
            TierKind::Hot => self.hot.record(d),
            TierKind::Cold => self.cold.record(d),
            TierKind::Spill => self.spill.record(d),
        }
    }

    pub fn merge(&mut self, other: &RestoreLatency) {
        self.hot.merge(&other.hot);
        self.cold.merge(&other.cold);
        self.spill.merge(&other.spill);
    }
}

/// Aggregated serving counters (exported as JSON by the server).
#[derive(Debug, Clone, Default)]
pub struct ServingStats {
    pub requests_completed: u64,
    pub requests_rejected: u64,
    /// Requests admitted at a lower QoS class than they asked for.
    pub requests_shed: u64,
    pub tokens_generated: u64,
    pub prefill_tokens: u64,
    pub batches_dispatched: u64,
    pub batch_occupancy_sum: u64,
    /// Frozen-row restores served from a prefetch-staged hot row
    /// (no decompression inside the decode step).
    pub staged_hits: u64,
    /// Restores that had to dequantize/read inline (cold or spill hit).
    pub staged_misses: u64,
}

impl ServingStats {
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batches_dispatched == 0 {
            0.0
        } else {
            self.batch_occupancy_sum as f64 / self.batches_dispatched as f64
        }
    }
}

/// Simple CSV writer for trace/figure exports.
pub fn write_csv_rows(path: &str, headers: &[&str], rows: &[Vec<String>]) -> std::io::Result<()> {
    let mut out = String::new();
    let _ = writeln!(out, "{}", headers.join(","));
    for r in rows {
        let _ = writeln!(out, "{}", r.join(","));
    }
    std::fs::write(path, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::default();
        for ms in 1..=100u64 {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.5);
        let p90 = h.quantile(0.9);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p90 && p90 <= p99);
        assert!(p50 >= Duration::from_millis(40) && p50 <= Duration::from_millis(80), "{p50:?}");
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn mean_accumulates() {
        let mut h = Histogram::default();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        assert_eq!(h.mean(), Duration::from_micros(200));
    }

    #[test]
    fn occupancy_math() {
        let s = ServingStats {
            batches_dispatched: 4,
            batch_occupancy_sum: 10,
            ..Default::default()
        };
        assert_eq!(s.mean_batch_occupancy(), 2.5);
    }

    #[test]
    fn histogram_merge_adds_counts() {
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        a.record(Duration::from_micros(100));
        b.record(Duration::from_micros(300));
        b.record(Duration::from_micros(500));
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.mean(), Duration::from_micros(300));
    }

    #[test]
    fn count_histogram_tracks_mean_and_max() {
        let mut h = CountHistogram::default();
        for v in [1u64, 2, 3, 64, 10] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.mean(), 16.0);
        assert_eq!(h.max(), 64);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        let mut other = CountHistogram::default();
        other.record(128);
        h.merge(&other);
        assert_eq!(h.count(), 6);
        assert_eq!(h.max(), 128);
    }

    #[test]
    fn batch_stats_skip_empty_batches() {
        let mut b = BatchStats::default();
        b.record_restore(0, 0);
        b.record_restore(8, 2);
        b.record_freeze(4, 4);
        assert_eq!(b.restore_rows, 8);
        assert_eq!(b.restore_spans, 2);
        assert_eq!(b.restore_batch.count(), 1, "empty batch must not count");
        assert_eq!(b.freeze_batch.count(), 1);
        let mut agg = BatchStats::default();
        agg.merge(&b);
        agg.merge(&b);
        assert_eq!(agg.restore_rows, 16);
        assert_eq!(agg.freeze_spans, 8);
    }

    #[test]
    fn plan_latency_summarizes_histogram() {
        let mut h = Histogram::default();
        assert_eq!(PlanLatency::from_histogram(&h), PlanLatency::default());
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        let p = PlanLatency::from_histogram(&h);
        assert_eq!(p.steps, 2);
        assert_eq!(p.mean_us, 200);
        assert_eq!(p.max_us, 300);
        assert!(p.p50_us <= p.p99_us);
    }

    #[test]
    fn histogram_merge_refuses_mismatched_layouts() {
        let mut a = Histogram::default();
        a.record(Duration::from_micros(100));
        let mut odd = Histogram::with_bounds(vec![10, 100, 1000]);
        odd.record(Duration::from_micros(50));
        a.merge(&odd);
        assert_eq!(a.count(), 1, "mismatched merge must be a logged no-op");
        assert_eq!(a.mean(), Duration::from_micros(100));

        let mut c = CountHistogram::default();
        c.record(4);
        let mut codd = CountHistogram::with_bounds(vec![2, 8]);
        codd.record(3);
        c.merge(&codd);
        assert_eq!(c.count(), 1);
        assert_eq!(c.max(), 4);
    }

    #[test]
    fn step_segments_accounting() {
        let mut s = StepSegments {
            steps: 1,
            plan_us: 10,
            restore_us: 15,
            restore_wait_us: 5,
            compute_us: 60,
            freeze_us: 10,
            wall_us: 100,
        };
        assert_eq!(s.accounted_us(), 100);
        assert!((s.coverage() - 1.0).abs() < 1e-9);
        s.merge(&StepSegments { steps: 1, wall_us: 50, compute_us: 50, ..Default::default() });
        assert_eq!(s.steps, 2);
        assert_eq!(s.wall_us, 150);
        assert_eq!(StepSegments::default().coverage(), 1.0);
    }

    #[test]
    fn restore_latency_routes_by_tier() {
        let mut r = RestoreLatency::default();
        r.record(TierKind::Hot, Duration::from_micros(1));
        r.record(TierKind::Cold, Duration::from_micros(2));
        r.record(TierKind::Cold, Duration::from_micros(3));
        assert_eq!(r.hot.count(), 1);
        assert_eq!(r.cold.count(), 2);
        assert_eq!(r.spill.count(), 0);
    }
}
